//! Fig. 8 — sharing incentive: per-user task completion ratio in the
//! shared cloud (SC) vs a *dedicated cloud* (DC) of k/n servers drawn
//! from the same Table I distribution (the paper's practical benchmark
//! from Sec. IV-D).
//!
//! Paper reference: pooling benefits most users; only ~2% complete
//! fewer tasks in the shared system, and only slightly.

use super::runner::{self, Job};
use super::{write_csv, EvalSetup};
use crate::cluster::Cluster;
use crate::sched::BestFitDrfh;
use crate::sim::{run, SimReport};
use crate::util::Pcg32;
use crate::workload::Trace;

#[derive(Clone, Debug)]
pub struct Fig8Result {
    /// (user, submitted, shared-cloud ratio, dedicated-cloud ratio)
    pub users: Vec<(usize, usize, f64, f64)>,
}

impl Fig8Result {
    /// Fraction of users strictly worse off in the shared cloud.
    pub fn frac_worse_in_shared(&self) -> f64 {
        let n = self.users.len().max(1);
        self.users
            .iter()
            .filter(|(_, _, sc, dc)| sc < dc)
            .count() as f64
            / n as f64
    }

    /// Largest ratio loss experienced by any user in the shared cloud.
    pub fn max_loss(&self) -> f64 {
        self.users
            .iter()
            .map(|(_, _, sc, dc)| (dc - sc).max(0.0))
            .fold(0.0, f64::max)
    }
}

/// Run the shared cloud once, then every user alone on its k/n-server
/// dedicated cloud (all dedicated runs in parallel — at full scale
/// this is the n = 100 long tail of the harness), and compare
/// completion ratios.
pub fn run_fig8(setup: &EvalSetup) -> Fig8Result {
    let shared = run(
        setup.cluster.clone(),
        &setup.trace,
        Box::new(BestFitDrfh::default()),
        setup.opts.clone(),
    );
    let n = setup.trace.users.len();
    let dc_size = (setup.cluster.len() / n).max(1);
    let active: Vec<usize> =
        (0..n).filter(|&u| shared.user_tasks[u].submitted > 0).collect();
    let jobs: Vec<Job<'_, SimReport>> = active
        .iter()
        .map(|&u| {
            let job: Job<'_, SimReport> = Box::new(move || {
                // dedicated cloud: k/n servers from the same distribution
                let mut rng = Pcg32::new(setup.seed ^ 0xdc, u as u64 + 1);
                let dc = Cluster::google_sample(dc_size, &mut rng);
                // the user's own jobs only (submit times preserved)
                let trace_u = Trace {
                    users: setup.trace.users.clone(),
                    jobs: setup
                        .trace
                        .jobs
                        .iter()
                        .filter(|j| j.user == u)
                        .cloned()
                        .collect(),
                };
                run(
                    dc,
                    &trace_u,
                    Box::new(BestFitDrfh::default()),
                    setup.opts.clone(),
                )
            });
            job
        })
        .collect();
    let users = active
        .iter()
        .zip(runner::run_parallel(jobs))
        .map(|(&u, dedicated)| {
            (
                u,
                shared.user_tasks[u].submitted,
                shared.user_tasks[u].ratio(),
                dedicated.user_tasks[u].ratio(),
            )
        })
        .collect();
    Fig8Result { users }
}

pub fn print(res: &Fig8Result) {
    println!("== Fig. 8: sharing incentive (shared vs dedicated cloud) ==");
    println!("users compared: {}", res.users.len());
    println!(
        "worse off in shared cloud: {:.0}% of users (paper: ~2%)",
        res.frac_worse_in_shared() * 100.0
    );
    println!(
        "max completion-ratio loss: {:.3} (paper: 'only slightly')",
        res.max_loss()
    );
    let mean_sc: f64 = res.users.iter().map(|u| u.2).sum::<f64>()
        / res.users.len().max(1) as f64;
    let mean_dc: f64 = res.users.iter().map(|u| u.3).sum::<f64>()
        / res.users.len().max(1) as f64;
    println!(
        "mean completion ratio: shared {:.2}, dedicated {:.2}",
        mean_sc, mean_dc
    );
    write_csv(
        "fig8_sharing_incentive.csv",
        "user,submitted,shared_ratio,dedicated_ratio",
        &res.users
            .iter()
            .map(|(u, n, sc, dc)| format!("{u},{n},{sc:.4},{dc:.4}"))
            .collect::<Vec<_>>(),
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sharing_benefits_most_users() {
        let setup = EvalSetup::with_duration(23, 120, 12, 12_000.0);
        let res = run_fig8(&setup);
        assert!(!res.users.is_empty());
        // pooling helps on average
        let mean_sc: f64 = res.users.iter().map(|u| u.2).sum::<f64>()
            / res.users.len() as f64;
        let mean_dc: f64 = res.users.iter().map(|u| u.3).sum::<f64>()
            / res.users.len() as f64;
        assert!(
            mean_sc >= mean_dc - 0.05,
            "shared {mean_sc:.3} much worse than dedicated {mean_dc:.3}"
        );
        // the paper's claim: few users are worse off
        assert!(
            res.frac_worse_in_shared() < 0.5,
            "too many users worse off: {:.2}",
            res.frac_worse_in_shared()
        );
    }
}
