//! Fig. 5 — CPU and memory utilization time series: Best-Fit DRFH vs
//! First-Fit DRFH vs the Slots scheduler on the 24-hour trace.
//!
//! Paper reference: both DRFH variants sustain much higher utilization
//! than Slots at all times, and Best-Fit uniformly beats First-Fit.

use super::runner::{self, SchedFactory};
use super::{write_csv, EvalSetup};
use crate::cluster::Cluster;
use crate::sched::{BestFitDrfh, FirstFitDrfh, Scheduler, SlotsScheduler};
use crate::sim::SimReport;

/// Reports for the three policies on the identical cluster + trace.
#[derive(Clone, Debug)]
pub struct Fig5Result {
    pub best_fit: SimReport,
    pub first_fit: SimReport,
    pub slots: SimReport,
}

/// The standard 3-policy comparison set (slots at the paper's best
/// setting, 14 per maximum server) — shared with
/// `benches/engine_scale.rs`, which times this exact sweep.
pub fn standard_factories() -> Vec<SchedFactory> {
    vec![
        Box::new(|_: &Cluster| {
            Box::new(BestFitDrfh::default()) as Box<dyn Scheduler>
        }),
        Box::new(|_: &Cluster| {
            Box::new(FirstFitDrfh::default()) as Box<dyn Scheduler>
        }),
        Box::new(|c: &Cluster| {
            Box::new(SlotsScheduler::new(c, 14)) as Box<dyn Scheduler>
        }),
    ]
}

/// The Best-Fit vs Slots-14 head-to-head (the pair Fig. 6 and Fig. 7
/// both evaluate) — kept next to [`standard_factories`] so the
/// comparison settings can't silently diverge between harnesses.
pub fn bestfit_vs_slots_factories() -> Vec<SchedFactory> {
    vec![
        Box::new(|_: &Cluster| {
            Box::new(BestFitDrfh::default()) as Box<dyn Scheduler>
        }),
        Box::new(|c: &Cluster| {
            Box::new(SlotsScheduler::new(c, 14)) as Box<dyn Scheduler>
        }),
    ]
}

/// Run the three-way comparison, one variant per worker thread.
pub fn run_fig5(setup: &EvalSetup) -> Fig5Result {
    let mut reports = runner::sweep(
        &setup.cluster,
        &setup.trace,
        &setup.opts,
        standard_factories(),
    );
    let slots = reports.pop().expect("slots report");
    let first_fit = reports.pop().expect("first-fit report");
    let best_fit = reports.pop().expect("best-fit report");
    Fig5Result { best_fit, first_fit, slots }
}

pub fn print(res: &Fig5Result) {
    println!("== Fig. 5: utilization time series (time-averaged) ==");
    println!(
        "{:<16} {:>10} {:>10} {:>12} {:>12}",
        "scheduler", "CPU util", "mem util", "tasks done", "jobs done"
    );
    for r in [&res.best_fit, &res.first_fit, &res.slots] {
        println!(
            "{:<16} {:>9.1}% {:>9.1}% {:>12} {:>12}",
            r.scheduler,
            r.avg_cpu_util * 100.0,
            r.avg_mem_util * 100.0,
            r.tasks_completed,
            r.jobs.len()
        );
    }
    println!("(paper: DRFH >> Slots; Best-Fit >= First-Fit uniformly)");
    // full time series CSV
    let n = res.best_fit.cpu_util.len();
    let rows: Vec<String> = (0..n)
        .map(|i| {
            format!(
                "{:.0},{:.4},{:.4},{:.4},{:.4},{:.4},{:.4}",
                res.best_fit.cpu_util.t[i],
                res.best_fit.cpu_util.v[i],
                res.best_fit.mem_util.v[i],
                res.first_fit.cpu_util.v[i],
                res.first_fit.mem_util.v[i],
                res.slots.cpu_util.v[i],
                res.slots.mem_util.v[i],
            )
        })
        .collect();
    write_csv(
        "fig5_utilization.csv",
        "t,bf_cpu,bf_mem,ff_cpu,ff_mem,slots_cpu,slots_mem",
        &rows,
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn drfh_beats_slots_on_utilization() {
        let setup = EvalSetup::with_duration(13, 120, 12, 12_000.0);
        let res = run_fig5(&setup);
        // the paper's headline: DRFH utilization well above Slots
        assert!(
            res.best_fit.avg_cpu_util > res.slots.avg_cpu_util,
            "bestfit {:.3} !> slots {:.3}",
            res.best_fit.avg_cpu_util,
            res.slots.avg_cpu_util
        );
        assert!(
            res.best_fit.avg_mem_util > res.slots.avg_mem_util,
            "bestfit mem {:.3} !> slots {:.3}",
            res.best_fit.avg_mem_util,
            res.slots.avg_mem_util
        );
        // and more work completed
        assert!(res.best_fit.tasks_completed >= res.slots.tasks_completed);
        // Best-Fit at least matches First-Fit on utilization
        assert!(
            res.best_fit.avg_cpu_util >= res.first_fit.avg_cpu_util * 0.97,
            "bestfit {:.3} << firstfit {:.3}",
            res.best_fit.avg_cpu_util,
            res.first_fit.avg_cpu_util
        );
    }
}
