//! Regenerates paper Fig. 7 (per-user task completion ratios,
//! Best-Fit vs Slots) and times the paired comparison.
//!
//! Run: `cargo bench --bench fig7_completion`

use drfh::experiments::{fig7, EvalSetup};
use drfh::util::bench::{bench, header};
use std::time::Duration;

fn main() {
    let setup = EvalSetup::with_duration(42, 300, 30, 21_600.0);
    let res = fig7::run_fig7(&setup);
    fig7::print(&res);

    header("fig7: paired completion-ratio runs");
    bench("fig7 paired run", Duration::from_secs(8), 10, || {
        fig7::run_fig7(&setup).users.len()
    });
}
