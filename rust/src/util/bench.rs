//! Tiny benchmark harness (substrate — criterion is unavailable
//! offline). Prints mean / p50 / min over timed iterations, sized to a
//! wall-clock budget, and can emit machine-readable JSON
//! (`BENCH_engine.json` et al.) so the perf trajectory is diffable
//! across PRs. Used by every `rust/benches/*.rs` target.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub min: Duration,
}

impl BenchResult {
    /// One JSON object per case: nanosecond-resolution timings plus
    /// the iteration count (built on `util::json`, the crate's one
    /// JSON writer).
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("name".to_string(), Json::Str(self.name.clone()));
        o.insert("iters".to_string(), Json::Num(self.iters as f64));
        o.insert(
            "mean_ns".to_string(),
            Json::Num(self.mean.as_nanos() as f64),
        );
        o.insert("p50_ns".to_string(), Json::Num(self.p50.as_nanos() as f64));
        o.insert("min_ns".to_string(), Json::Num(self.min.as_nanos() as f64));
        Json::Obj(o)
    }

    pub fn print(&self) {
        println!(
            "{:<44} {:>12} {:>12} {:>12}   ({} iters)",
            self.name,
            fmt_dur(self.mean),
            fmt_dur(self.p50),
            fmt_dur(self.min),
            self.iters
        );
    }
}

/// Pretty duration.
pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Print the header row for a group of cases.
pub fn header(group: &str) {
    println!("\n== bench: {group} ==");
    println!(
        "{:<44} {:>12} {:>12} {:>12}",
        "case", "mean", "p50", "min"
    );
}

/// Serialize a bench suite to a JSON document:
/// `{"suite": ..., "meta": {...}, "results": [...]}`. `meta` carries
/// config and derived figures (speedups, throughput) as typed
/// [`Json`] values.
pub fn suite_json(
    suite: &str,
    meta: &[(&str, Json)],
    results: &[BenchResult],
) -> String {
    let mut doc = BTreeMap::new();
    doc.insert("suite".to_string(), Json::Str(suite.to_string()));
    doc.insert(
        "meta".to_string(),
        Json::Obj(
            meta.iter().map(|(k, v)| (k.to_string(), v.clone())).collect(),
        ),
    );
    doc.insert(
        "results".to_string(),
        Json::Arr(results.iter().map(|r| r.to_json()).collect()),
    );
    Json::Obj(doc).to_string()
}

/// Write a bench suite JSON document, creating parent dirs on demand.
/// Best-effort like `experiments::write_csv`: returns whether the
/// write succeeded so callers can log the destination.
pub fn write_suite_json(
    path: &std::path::Path,
    suite: &str,
    meta: &[(&str, Json)],
    results: &[BenchResult],
) -> bool {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty()
            && std::fs::create_dir_all(dir).is_err()
        {
            return false;
        }
    }
    std::fs::write(path, suite_json(suite, meta, results)).is_ok()
}

/// Best-effort peak RSS (`VmHWM`) of this process in bytes — Linux
/// `/proc` only, `None` elsewhere. The kernel watermark is monotone
/// over the process lifetime, so callers comparing phases must run
/// the lighter phase *first* (see `benches/sim_scale.rs`).
pub fn peak_rss_bytes() -> Option<u64> {
    let s = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in s.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 =
                rest.trim().trim_end_matches("kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

/// Time `f` for exactly `iters` iterations — for heavyweight
/// end-to-end cases where the budget-based loop of [`bench`] would
/// run far too long. Warms up once first, except at `iters == 1`
/// where a warmup would double a deliberately slow single-shot case.
pub fn bench_n<T>(
    name: &str,
    iters: usize,
    f: impl FnMut() -> T,
) -> BenchResult {
    let res = bench_n_quiet(name, iters, f);
    res.print();
    res
}

/// [`bench_n`] without the printed row — for cases timed on
/// `experiments::runner` worker threads, where the caller prints after
/// the fan-out so rows don't interleave.
pub fn bench_n_quiet<T>(
    name: &str,
    iters: usize,
    mut f: impl FnMut() -> T,
) -> BenchResult {
    let iters = iters.max(1);
    if iters > 1 {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed());
    }
    samples.sort();
    let total: Duration = samples.iter().sum();
    BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean: total / samples.len() as u32,
        p50: samples[samples.len() / 2],
        min: samples[0],
    }
}

/// Time `f` repeatedly within `budget` (at least 3 runs, at most
/// `max_iters`), returning distribution statistics. `f` should return
/// something observable to keep the optimizer honest.
pub fn bench<T>(
    name: &str,
    budget: Duration,
    max_iters: usize,
    mut f: impl FnMut() -> T,
) -> BenchResult {
    // warmup
    std::hint::black_box(f());
    let mut samples = Vec::new();
    let start = Instant::now();
    while (samples.len() < 3
        || (start.elapsed() < budget && samples.len() < max_iters))
        && samples.len() < max_iters
    {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed());
    }
    samples.sort();
    let total: Duration = samples.iter().sum();
    let res = BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean: total / samples.len() as u32,
        p50: samples[samples.len() / 2],
        min: samples[0],
    };
    res.print();
    res
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_at_least_three_iters() {
        let r = bench("noop", Duration::from_millis(1), 100, || 1 + 1);
        assert!(r.iters >= 3);
        assert!(r.min <= r.mean);
    }

    #[test]
    fn bench_n_runs_exact_iters() {
        let r = bench_n("noop", 5, || 2 + 2);
        assert_eq!(r.iters, 5);
    }

    #[test]
    fn json_output_is_wellformed() {
        let r = bench_n("case \"a\"", 1, || 1);
        let doc = suite_json(
            "engine_scale",
            &[("servers", Json::Num(2000.0))],
            &[r],
        );
        // parseable by the in-tree JSON parser
        let v = crate::util::json::parse(&doc).expect("valid JSON");
        assert_eq!(
            v.get("suite").and_then(|s| s.as_str()),
            Some("engine_scale")
        );
        assert_eq!(
            v.get("meta")
                .and_then(|m| m.get("servers"))
                .and_then(|s| s.as_usize()),
            Some(2000)
        );
        assert_eq!(v.get("results").and_then(|r| r.as_arr()).map(|a| a.len()), Some(1));
    }

    #[test]
    fn duration_formatting() {
        assert!(fmt_dur(Duration::from_nanos(500)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(50)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(50)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).contains(" s"));
    }
}
