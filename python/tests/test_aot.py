"""AOT pipeline sanity: lowering produces loadable HLO text.

The Rust runtime's parser is exercised end-to-end in
rust/tests/picker_parity.rs; here we assert the Python side emits
well-formed HLO text for every declared variant shape and that the
manifest the Rust loader consumes is consistent.
"""

import json

from compile import aot


def test_lower_step_produces_hlo_text():
    text = aot.lower_step(4, 16, 2)
    assert "ENTRY" in text
    assert "HloModule" in text
    # tuple-return convention the Rust side unwraps with to_tuple2
    assert "tuple" in text.lower()


def test_lower_loop_produces_hlo_text():
    text = aot.lower_loop(4, 16, 2, 4)
    assert "ENTRY" in text
    assert "HloModule" in text


def test_variant_tables_are_consistent():
    # every variant must satisfy the tiling constraints of the kernels
    for n, k, m in aot.STEP_VARIANTS:
        assert n >= 1 and k >= 1
        assert 1 <= m <= 4
        assert k < 128 or k % 128 == 0, f"k={k} breaks server tiling"
        assert n < 128 or n % 128 == 0, f"n={n} breaks user tiling"
    for n, k, m, steps in aot.LOOP_VARIANTS:
        assert steps >= 1
        assert k < 128 or k % 128 == 0
        assert n < 128 or n % 128 == 0


def test_manifest_roundtrip(tmp_path):
    """A miniature end-to-end: write one artifact + manifest, reparse."""
    out = tmp_path / "artifacts"
    out.mkdir()
    text = aot.lower_step(4, 16, 2)
    (out / "step.hlo.txt").write_text(text)
    manifest = {
        "step": [{"n": 4, "k": 16, "m": 2, "file": "step.hlo.txt"}],
        "loop": [],
    }
    (out / "manifest.json").write_text(json.dumps(manifest))
    parsed = json.loads((out / "manifest.json").read_text())
    assert parsed["step"][0]["file"] == "step.hlo.txt"
    assert (out / "step.hlo.txt").read_text() == text
