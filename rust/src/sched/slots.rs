//! The slot-based baseline scheduler (paper Sec. VI / Table II; models
//! the Hadoop Fair Scheduler the paper compares against).
//!
//! Each server is partitioned into *slots*: the maximum server (1 CPU,
//! 1 mem in Table I's normalized units) is divided into `slots_per_max`
//! equal bundles, and every server hosts as many whole slots as the
//! bundle fits into its capacity (jointly across resources). A task
//! occupies exactly one slot regardless of its real demand; fairness is
//! max-min over *slot counts* (weighted), and real resource usage is
//! never checked — overcommitting a server is possible, in which case
//! the engine applies a processor-sharing slowdown to every task on it.
//! This is exactly the pathology the paper attributes to slot
//! schedulers: the single-resource abstraction ignores both server and
//! demand heterogeneity.

use super::{effective_weight, Pick, Scheduler, UserState};
use crate::cluster::{Cluster, ResVec};

/// The Slots policy.
pub struct SlotsScheduler {
    /// Number of slots the *maximum* server is divided into.
    pub slots_per_max: usize,
    /// Per-server slot capacity, derived from the cluster.
    slots_total: Vec<usize>,
    /// First server index that might have a free slot (§Perf: the
    /// naive per-placement linear scan was 53% of saturated runs; the
    /// cursor only moves forward past full servers and is pulled back
    /// by `on_free`, so it always lower-bounds the true first free
    /// slot and the picked server is identical to a full scan).
    free_hint: usize,
}

impl SlotsScheduler {
    /// Build for `cluster`, dividing the largest server into
    /// `slots_per_max` slots.
    pub fn new(cluster: &Cluster, slots_per_max: usize) -> Self {
        assert!(slots_per_max >= 1);
        let m = cluster.dims();
        // the "maximum server": componentwise max capacity
        let mut maxcap = ResVec::zeros(m);
        for s in &cluster.servers {
            for r in 0..m {
                maxcap[r] = maxcap[r].max(s.capacity[r]);
            }
        }
        let slot = maxcap.scale(1.0 / slots_per_max as f64);
        let slots_total = cluster
            .servers
            .iter()
            .map(|s| {
                // whole slots that fit jointly across all resources
                let mut n = usize::MAX;
                for r in 0..m {
                    if slot[r] > 0.0 {
                        n = n.min((s.capacity[r] / slot[r] + 1e-9) as usize);
                    }
                }
                n.max(1) // every server offers at least one slot
            })
            .collect();
        SlotsScheduler { slots_per_max, slots_total, free_hint: 0 }
    }

    /// Slot capacity of server `l`.
    pub fn slots_of(&self, l: usize) -> usize {
        self.slots_total[l]
    }

    /// Total slots in the cluster.
    pub fn total_slots(&self) -> usize {
        self.slots_total.iter().sum()
    }
}

impl Scheduler for SlotsScheduler {
    fn name(&self) -> &'static str {
        "slots"
    }

    fn pick(
        &mut self,
        cluster: &Cluster,
        users: &[UserState],
        eligible: &[bool],
    ) -> Pick {
        // fair sharing over slot counts: serve the pending user with the
        // fewest weighted running tasks (1 task = 1 slot); zero weights
        // use the shared guarded fallback (see `sched::effective_weight`)
        let mut best: Option<usize> = None;
        for i in 0..users.len() {
            if !eligible[i] || users[i].pending == 0 {
                continue;
            }
            let key = users[i].running as f64 / effective_weight(users[i].weight);
            match best {
                Some(b)
                    if users[b].running as f64
                        / effective_weight(users[b].weight)
                        <= key => {}
                _ => best = Some(i),
            }
        }
        let Some(u) = best else { return Pick::Idle };
        // first server with a free slot (resource demands NOT checked),
        // scanning from the cursor — everything before it is full
        let k = cluster.len();
        let mut l = self.free_hint;
        while l < k && cluster.servers[l].tasks >= self.slots_total[l] {
            l += 1;
        }
        self.free_hint = l;
        if l < k {
            Pick::Place { user: u, server: l }
        } else {
            Pick::Blocked { user: u }
        }
    }

    fn can_fit(
        &self,
        cluster: &Cluster,
        _users: &[UserState],
        _user: usize,
        server: usize,
    ) -> bool {
        cluster.servers[server].tasks < self.slots_total[server]
    }

    fn allows_overcommit(&self) -> bool {
        true
    }

    fn on_free(&mut self, server: usize) {
        if server < self.free_hint {
            self.free_hint = server;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Server;
    use crate::util::Pcg32;

    #[test]
    fn slot_counts_proportional_to_server_size() {
        let mut rng = Pcg32::seeded(5);
        let cluster = Cluster::google_sample(100, &mut rng);
        let s = SlotsScheduler::new(&cluster, 14);
        for (l, srv) in cluster.servers.iter().enumerate() {
            let expect = ((srv.capacity[0] * 14.0 + 1e-9) as usize)
                .min((srv.capacity[1] * 14.0 + 1e-9) as usize)
                .max(1);
            assert_eq!(s.slots_of(l), expect, "server {l}");
        }
    }

    #[test]
    fn unbalanced_servers_lose_slots() {
        // (1, 1) vs (1, 0.12): joint fit penalizes the unbalanced box
        let cluster = Cluster::from_capacities(&[
            ResVec::cpu_mem(1.0, 1.0),
            ResVec::cpu_mem(1.0, 0.12),
        ]);
        let s = SlotsScheduler::new(&cluster, 10);
        assert_eq!(s.slots_of(0), 10);
        assert_eq!(s.slots_of(1), 1);
    }

    #[test]
    fn fairness_by_running_count() {
        let cluster = Cluster::from_capacities(&[ResVec::cpu_mem(1.0, 1.0)]);
        let mut s = SlotsScheduler::new(&cluster, 4);
        let mk = |pending, running| UserState {
            demand: ResVec::cpu_mem(0.1, 0.1),
            weight: 1.0,
            pending,
            running,
            dom_share: 0.0,
            usage: ResVec::zeros(2),
            dom_delta: 0.1,
        };
        let users = vec![mk(1, 3), mk(1, 1)];
        assert_eq!(
            s.pick(&cluster, &users, &[true, true]),
            Pick::Place { user: 1, server: 0 }
        );
    }

    #[test]
    fn blocked_when_no_free_slots() {
        let mut cluster =
            Cluster::new(vec![Server::new(ResVec::cpu_mem(1.0, 1.0))]);
        let mut s = SlotsScheduler::new(&cluster, 2);
        cluster.servers[0].tasks = 2; // both slots taken
        let users = vec![UserState {
            demand: ResVec::cpu_mem(0.1, 0.1),
            weight: 1.0,
            pending: 1,
            running: 2,
            dom_share: 0.0,
            usage: ResVec::zeros(2),
            dom_delta: 0.1,
        }];
        assert_eq!(
            s.pick(&cluster, &users, &[true]),
            Pick::Blocked { user: 0 }
        );
        assert!(!s.can_fit(&cluster, &users, 0, 0));
        cluster.servers[0].tasks = 1;
        assert!(s.can_fit(&cluster, &users, 0, 0));
        assert!(s.allows_overcommit());
    }
}
