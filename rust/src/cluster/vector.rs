//! Fixed-capacity resource vectors (paper Sec. III-A).
//!
//! A `ResVec` holds up to [`MAX_RES`] resource quantities (CPU, memory,
//! storage, ...) inline — no heap allocation on the scheduling hot path.
//! Quantities are *absolute* units (cores, GB); the allocator normalizes
//! against pool totals where the paper's theory requires shares.

use std::fmt;
use std::ops::{Index, IndexMut};

/// Maximum number of resource dimensions supported inline.
pub const MAX_RES: usize = 4;

/// A small dense vector over the resource dimensions.
#[derive(Clone, Copy, PartialEq)]
pub struct ResVec {
    vals: [f64; MAX_RES],
    m: usize,
}

impl ResVec {
    /// All-zero vector with `m` dimensions.
    pub fn zeros(m: usize) -> Self {
        assert!(m >= 1 && m <= MAX_RES, "m={m} out of range");
        ResVec { vals: [0.0; MAX_RES], m }
    }

    /// Build from a slice (length = number of resources).
    pub fn from_slice(xs: &[f64]) -> Self {
        let mut v = Self::zeros(xs.len());
        v.vals[..xs.len()].copy_from_slice(xs);
        v
    }

    /// Two-resource convenience (CPU, memory) — the paper's setting.
    pub fn cpu_mem(cpu: f64, mem: f64) -> Self {
        Self::from_slice(&[cpu, mem])
    }

    /// Number of resource dimensions.
    #[inline]
    pub fn dims(&self) -> usize {
        self.m
    }

    /// Immutable view of the live dimensions.
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.vals[..self.m]
    }

    /// Elementwise sum.
    #[inline]
    pub fn add(&self, o: &ResVec) -> ResVec {
        debug_assert_eq!(self.m, o.m);
        let mut r = *self;
        for i in 0..self.m {
            r.vals[i] += o.vals[i];
        }
        r
    }

    /// Elementwise difference (may go negative — callers decide policy).
    #[inline]
    pub fn sub(&self, o: &ResVec) -> ResVec {
        debug_assert_eq!(self.m, o.m);
        let mut r = *self;
        for i in 0..self.m {
            r.vals[i] -= o.vals[i];
        }
        r
    }

    /// In-place add.
    #[inline]
    pub fn add_assign(&mut self, o: &ResVec) {
        debug_assert_eq!(self.m, o.m);
        for i in 0..self.m {
            self.vals[i] += o.vals[i];
        }
    }

    /// In-place subtract.
    #[inline]
    pub fn sub_assign(&mut self, o: &ResVec) {
        debug_assert_eq!(self.m, o.m);
        for i in 0..self.m {
            self.vals[i] -= o.vals[i];
        }
    }

    /// Scaled copy.
    #[inline]
    pub fn scale(&self, a: f64) -> ResVec {
        let mut r = *self;
        for i in 0..self.m {
            r.vals[i] *= a;
        }
        r
    }

    /// Elementwise `self <= o` (with tolerance; used for "fits").
    #[inline]
    pub fn le_eps(&self, o: &ResVec, eps: f64) -> bool {
        debug_assert_eq!(self.m, o.m);
        (0..self.m).all(|i| self.vals[i] <= o.vals[i] + eps)
    }

    /// Elementwise `self <= o` exactly.
    #[inline]
    pub fn le(&self, o: &ResVec) -> bool {
        self.le_eps(o, 0.0)
    }

    /// True iff every component is >= 0 (tolerates -eps).
    #[inline]
    pub fn non_negative(&self, eps: f64) -> bool {
        self.as_slice().iter().all(|&x| x >= -eps)
    }

    /// Largest component value.
    #[inline]
    pub fn max(&self) -> f64 {
        self.as_slice().iter().cloned().fold(f64::MIN, f64::max)
    }

    /// Smallest component value.
    #[inline]
    pub fn min(&self) -> f64 {
        self.as_slice().iter().cloned().fold(f64::MAX, f64::min)
    }

    /// Index of the largest component (first on ties) — the dominant
    /// resource when applied to a normalized demand vector.
    #[inline]
    pub fn argmax(&self) -> usize {
        let mut best = 0;
        for i in 1..self.m {
            if self.vals[i] > self.vals[best] {
                best = i;
            }
        }
        best
    }

    /// Elementwise division; `den` components of 0 map to +inf unless the
    /// numerator is also 0 (then 0).
    pub fn div(&self, den: &ResVec) -> ResVec {
        debug_assert_eq!(self.m, den.m);
        let mut r = *self;
        for i in 0..self.m {
            r.vals[i] = if den.vals[i] != 0.0 {
                self.vals[i] / den.vals[i]
            } else if self.vals[i] == 0.0 {
                0.0
            } else {
                f64::INFINITY
            };
        }
        r
    }

    /// max_r self_r / o_r — e.g. the dominant share of a usage vector
    /// against a capacity vector.
    pub fn max_ratio(&self, o: &ResVec) -> f64 {
        self.div(o).max()
    }

    /// Sum of components.
    #[inline]
    pub fn sum(&self) -> f64 {
        self.as_slice().iter().sum()
    }

    /// L1 distance.
    pub fn l1_dist(&self, o: &ResVec) -> f64 {
        debug_assert_eq!(self.m, o.m);
        (0..self.m)
            .map(|i| (self.vals[i] - o.vals[i]).abs())
            .sum()
    }

    /// True iff all components are strictly positive.
    pub fn all_positive(&self) -> bool {
        self.as_slice().iter().all(|&x| x > 0.0)
    }
}

impl Index<usize> for ResVec {
    type Output = f64;
    #[inline]
    fn index(&self, i: usize) -> &f64 {
        debug_assert!(i < self.m);
        &self.vals[i]
    }
}

impl IndexMut<usize> for ResVec {
    #[inline]
    fn index_mut(&mut self, i: usize) -> &mut f64 {
        debug_assert!(i < self.m);
        &mut self.vals[i]
    }
}

impl fmt::Debug for ResVec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "ResVec{:?}", self.as_slice())
    }
}

impl fmt::Display for ResVec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "(")?;
        for (i, v) in self.as_slice().iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{v:.4}")?;
        }
        write!(f, ")")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_indexing() {
        let v = ResVec::cpu_mem(2.0, 12.0);
        assert_eq!(v.dims(), 2);
        assert_eq!(v[0], 2.0);
        assert_eq!(v[1], 12.0);
    }

    #[test]
    #[should_panic]
    fn too_many_dims_panics() {
        ResVec::zeros(MAX_RES + 1);
    }

    #[test]
    fn arithmetic() {
        let a = ResVec::cpu_mem(1.0, 2.0);
        let b = ResVec::cpu_mem(0.5, 1.0);
        assert_eq!(a.add(&b), ResVec::cpu_mem(1.5, 3.0));
        assert_eq!(a.sub(&b), b);
        assert_eq!(a.scale(2.0), ResVec::cpu_mem(2.0, 4.0));
        let mut c = a;
        c.sub_assign(&b);
        assert_eq!(c, b);
    }

    #[test]
    fn ordering_and_ratios() {
        let d = ResVec::cpu_mem(0.2, 1.0);
        let c = ResVec::cpu_mem(2.0, 12.0);
        assert!(d.le(&c));
        assert!(!c.le(&d));
        assert_eq!(d.argmax(), 1);
        // ratios are (0.1, 1/12); the max is the CPU ratio 0.1
        assert!((d.max_ratio(&c) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn div_by_zero_semantics() {
        let a = ResVec::cpu_mem(1.0, 0.0);
        let b = ResVec::cpu_mem(0.0, 0.0);
        let r = a.div(&b);
        assert!(r[0].is_infinite());
        assert_eq!(r[1], 0.0);
    }

    #[test]
    fn l1_distance() {
        let a = ResVec::cpu_mem(1.0, 3.0);
        let b = ResVec::cpu_mem(2.0, 1.0);
        assert!((a.l1_dist(&b) - 3.0).abs() < 1e-12);
    }
}
