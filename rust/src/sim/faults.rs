//! Deterministic fault injection: failure plans, retry policy, and
//! outage/recovery records.
//!
//! A [`FaultPlan`] is a pre-compiled, fully deterministic schedule of
//! server down/up transitions. Plans are built offline — by the seeded
//! generators in [`crate::workload::gen`] (Poisson crash/repair,
//! correlated rack outages, one-off flash failures) or by hand from
//! raw intervals ([`FaultPlan::from_intervals`]) — and handed to the
//! engine through [`crate::sim::SimOpts::faults`]. The engine compiles
//! the plan into `ServerDown`/`ServerUp` events at construction time
//! and drains them through the one total `(time, seq)` order every
//! other event obeys, so the same plan and seed replay bit-identically
//! at every shard count, and [`FaultPlan::none`] pushes *zero* events
//! — the no-fault engine is byte-for-byte the pre-fault engine
//! (`tests/engine_parity.rs` pins both properties).
//!
//! Retries are governed by a [`RetryPolicy`]: a task evicted by a
//! crash re-enters its user's queue with only its *remaining* work,
//! after a deterministic exponential backoff computed as a pure
//! function of `(plan seed, task id, attempt)` — no wall clock, no
//! ambient RNG state, so the schedule is reproducible from the inputs
//! alone (property-tested) and `drfh lint`'s wall-clock rule covers
//! this module like every other decision-path module.

use crate::util::Pcg32;

/// One server transition in a fault plan (absolute simulation time).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultEvent {
    /// When the transition happens (seconds).
    pub time: f64,
    /// Which server (index into the cluster's pool).
    pub server: usize,
    /// `false` = the server crashes (down), `true` = it recovers (up).
    pub up: bool,
}

/// A deterministic schedule of server failures and recoveries.
///
/// Invariants maintained by the constructors: events are sorted by
/// `(time, server, up)`, per-server intervals are non-overlapping
/// (overlaps are merged), and every down has a matching later up
/// unless the outage extends past the generator's horizon.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    /// Seed for every derived deterministic draw (backoff jitter).
    pub seed: u64,
    /// Fairness-recovery tolerance: an outage counts as recovered at
    /// the first sample tick where the spread of weighted dominant
    /// shares across active users re-enters `baseline + envy_eps`
    /// (see [`OutageRecord`]).
    pub envy_eps: f64,
    /// The compiled transition schedule.
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// The empty plan: injects nothing, perturbs nothing. The engine
    /// running under `FaultPlan::none()` produces a bit-identical
    /// [`crate::sim::SimReport`] to the pre-fault engine at every
    /// shard count.
    pub fn none() -> Self {
        FaultPlan { seed: 0, envy_eps: 0.05, events: Vec::new() }
    }

    /// True when the plan schedules no transitions.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Build a plan from raw per-server outage intervals
    /// `(server, start, end)`: overlapping/adjacent intervals of the
    /// same server are merged, then each merged interval compiles to
    /// one down and one up event, sorted canonically by
    /// `(time, server, up)` so the engine's seq assignment — and
    /// therefore the whole replay — is a pure function of the
    /// intervals.
    pub fn from_intervals(
        seed: u64,
        envy_eps: f64,
        intervals: &[(usize, f64, f64)],
    ) -> Self {
        let mut by_server: Vec<(usize, f64, f64)> = intervals
            .iter()
            .copied()
            .filter(|&(_, s, e)| e > s && e > 0.0)
            .map(|(l, s, e)| (l, s.max(0.0), e))
            .collect();
        by_server.sort_by(|a, b| {
            a.0.cmp(&b.0).then_with(|| a.1.total_cmp(&b.1))
        });
        let mut events = Vec::new();
        let mut i = 0;
        while i < by_server.len() {
            let (l, mut s, mut e) = by_server[i];
            i += 1;
            while i < by_server.len()
                && by_server[i].0 == l
                && by_server[i].1 <= e
            {
                e = e.max(by_server[i].2);
                s = s.min(by_server[i].1);
                i += 1;
            }
            events.push(FaultEvent { time: s, server: l, up: false });
            events.push(FaultEvent { time: e, server: l, up: true });
        }
        events.sort_by(|a, b| {
            a.time
                .total_cmp(&b.time)
                .then_with(|| a.server.cmp(&b.server))
                .then_with(|| a.up.cmp(&b.up))
        });
        FaultPlan { seed, envy_eps, events }
    }
}

/// Retry discipline for tasks evicted by a server crash.
///
/// A task's first run is attempt 1. When attempt `a` is evicted and
/// `a < max_attempts`, the task's *remaining* work is re-queued after
/// [`RetryPolicy::backoff`] seconds; at `a == max_attempts` the task
/// is abandoned (counted in `SimReport::tasks_lost`, its job never
/// completes — degradation is a measured outcome, not an error).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RetryPolicy {
    /// Total attempts a task may consume (first run included). 0 is
    /// treated as 1 (the first run always happens).
    pub max_attempts: u32,
    /// Backoff after the first eviction (seconds).
    pub base: f64,
    /// Ceiling on the exponential term (seconds).
    pub cap: f64,
    /// Multiplicative jitter amplitude: the delay is scaled by a
    /// deterministic factor in `[1, 1 + jitter)` drawn from
    /// `(seed, task, attempt)`. 0 disables jitter.
    pub jitter: f64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 3,
            base: 30.0,
            cap: 3600.0,
            jitter: 0.5,
        }
    }
}

impl RetryPolicy {
    /// Backoff delay after attempt `attempt` (1-based) of `task`
    /// failed: `min(cap, base * 2^(attempt-1))`, scaled by the
    /// deterministic jitter factor. A pure function of
    /// `(seed, task, attempt)` — same inputs, same delay, on any
    /// machine at any shard count; no wall clock anywhere
    /// (`drfh lint` enforces this module stays that way).
    pub fn backoff(&self, seed: u64, task: u64, attempt: u32) -> f64 {
        let exp = attempt.saturating_sub(1).min(63);
        let nominal = (self.base * (exp as f64).exp2()).min(self.cap);
        if self.jitter <= 0.0 {
            return nominal;
        }
        // stream split by task, sequenced by attempt: adjacent tasks
        // and adjacent attempts draw from unrelated streams
        let mut rng = Pcg32::new(
            seed ^ task.wrapping_mul(0x9e37_79b9_7f4a_7c15),
            attempt as u64,
        );
        nominal * (1.0 + self.jitter * rng.f64())
    }

    /// The attempt budget with the "first run always happens" floor.
    pub fn attempt_cap(&self) -> u32 {
        self.max_attempts.max(1)
    }
}

/// One outage and its measured fairness recovery.
///
/// `baseline_envy` is the spread (max − min) of weighted dominant
/// shares (`UserState::share_key`) across *active* users (running or
/// pending work), captured immediately before the crash evicts
/// anything. `recovered_at` is the first sample tick at or after the
/// crash where the spread re-enters `baseline_envy + envy_eps`
/// ([`FaultPlan::envy_eps`]); `None` means fairness never recovered
/// before the horizon.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OutageRecord {
    /// Crash time (seconds).
    pub at: f64,
    /// The server that went down.
    pub server: usize,
    /// Pre-crash envy spread.
    pub baseline_envy: f64,
    /// First sample tick with the spread back inside the tolerance.
    pub recovered_at: Option<f64>,
}

impl OutageRecord {
    /// Recovery latency in seconds, when fairness recovered.
    pub fn recovery_time(&self) -> Option<f64> {
        self.recovered_at.map(|t| t - self.at)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_empty_and_cheap() {
        let p = FaultPlan::none();
        assert!(p.is_empty());
        assert_eq!(p.events.len(), 0);
    }

    #[test]
    fn intervals_merge_and_sort() {
        // server 3: [10, 20) and [15, 30) overlap -> one outage
        // [10, 30); server 1: [5, 8) stands alone.
        let p = FaultPlan::from_intervals(
            7,
            0.05,
            &[(3, 10.0, 20.0), (1, 5.0, 8.0), (3, 15.0, 30.0)],
        );
        assert_eq!(p.events, vec![
            FaultEvent { time: 5.0, server: 1, up: false },
            FaultEvent { time: 8.0, server: 1, up: true },
            FaultEvent { time: 10.0, server: 3, up: false },
            FaultEvent { time: 30.0, server: 3, up: true },
        ]);
    }

    #[test]
    fn degenerate_intervals_dropped() {
        let p = FaultPlan::from_intervals(
            0,
            0.05,
            &[(0, 10.0, 10.0), (0, 9.0, 3.0), (2, -5.0, -1.0)],
        );
        assert!(p.is_empty());
        // negative starts clamp to 0, keeping the down event pushable
        let p = FaultPlan::from_intervals(0, 0.05, &[(2, -5.0, 4.0)]);
        assert_eq!(p.events, vec![
            FaultEvent { time: 0.0, server: 2, up: false },
            FaultEvent { time: 4.0, server: 2, up: true },
        ]);
    }

    #[test]
    fn backoff_is_pure_and_monotone_in_attempt() {
        let pol = RetryPolicy::default();
        let a = pol.backoff(42, 1001, 1);
        let b = pol.backoff(42, 1001, 1);
        assert_eq!(a.to_bits(), b.to_bits(), "same inputs, same bits");
        // nominal doubling dominates the bounded jitter ratio
        let mut prev = pol.backoff(42, 1001, 1);
        for attempt in 2..6 {
            let d = pol.backoff(42, 1001, attempt);
            assert!(d > prev / (1.0 + pol.jitter), "attempt {attempt}");
            prev = d;
        }
        // the cap binds eventually
        let capped = RetryPolicy { jitter: 0.0, ..pol };
        assert_eq!(capped.backoff(0, 0, 30), capped.cap);
    }

    #[test]
    fn backoff_varies_by_task_and_seed() {
        let pol = RetryPolicy::default();
        let base = pol.backoff(42, 1001, 2);
        assert_ne!(base.to_bits(), pol.backoff(42, 1002, 2).to_bits());
        assert_ne!(base.to_bits(), pol.backoff(43, 1001, 2).to_bits());
        // all draws stay inside the documented [1, 1+jitter) band
        for task in 0..50u64 {
            let d = pol.backoff(7, task, 3);
            let nominal = pol.base * 4.0;
            assert!(d >= nominal && d < nominal * (1.0 + pol.jitter));
        }
    }

    #[test]
    fn zero_jitter_is_exactly_exponential() {
        let pol = RetryPolicy {
            max_attempts: 5,
            base: 10.0,
            cap: 1e9,
            jitter: 0.0,
        };
        assert_eq!(pol.backoff(9, 9, 1), 10.0);
        assert_eq!(pol.backoff(9, 9, 2), 20.0);
        assert_eq!(pol.backoff(9, 9, 3), 40.0);
    }

    #[test]
    fn recovery_time() {
        let rec = OutageRecord {
            at: 100.0,
            server: 4,
            baseline_envy: 0.01,
            recovered_at: Some(160.0),
        };
        assert_eq!(rec.recovery_time(), Some(60.0));
        let open = OutageRecord { recovered_at: None, ..rec };
        assert_eq!(open.recovery_time(), None);
    }
}
