//! §Perf L3/L2 bench: one scheduling decision and one batched loop,
//! native vs XLA, across cluster sizes. The paper's scheduler must
//! sustain thousands of placements per second on a 2,000-server pool.
//!
//! Run: `cargo bench --bench picker`

use drfh::runtime::{artifacts_available, backend_available, picker, XlaRuntime};
use drfh::util::bench::{bench, header};
use drfh::util::Pcg32;
use std::time::Duration;

fn instance(
    rng: &mut Pcg32,
    n: usize,
    k: usize,
    m: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<i32>) {
    (
        (0..k * m).map(|_| rng.uniform(0.1, 1.0) as f32).collect(),
        (0..n * m).map(|_| rng.uniform(0.01, 0.3) as f32).collect(),
        (0..n).map(|_| rng.uniform(0.0, 1.0) as f32).collect(),
        vec![1.0; n],
        vec![1; n],
    )
}

fn main() {
    let budget = Duration::from_millis(800);
    header("picker: one scheduling decision (native)");
    let mut rng = Pcg32::seeded(1);
    for &(n, k) in &[(16usize, 128usize), (64, 512), (128, 2048), (128, 8192)] {
        let (avail, demand, share, weight, active) =
            instance(&mut rng, n, k, 2);
        bench(
            &format!("native sched_step n={n} k={k}"),
            budget,
            100_000,
            || {
                picker::sched_step(
                    &avail, &demand, &share, &weight, &active, n, k, 2,
                )
            },
        );
    }

    header("picker: batched loop (native, 64 decisions/call)");
    for &(n, k) in &[(64usize, 512usize), (128, 2048)] {
        let (avail, demand, share, weight, _) = instance(&mut rng, n, k, 2);
        bench(
            &format!("native sched_loop n={n} k={k} t=64"),
            budget,
            10_000,
            || {
                let mut av = avail.clone();
                let mut sh = share.clone();
                let mut pe = vec![10i32; n];
                picker::sched_loop(
                    &mut av, &demand, &mut sh, &weight, &mut pe, n, k, 2, 64,
                )
            },
        );
    }

    if !backend_available() {
        println!("\n(no PJRT backend linked in — skipping XLA benches)");
        return;
    }
    if !artifacts_available() {
        println!("\n(artifacts/ missing — skipping XLA benches; run `make artifacts`)");
        return;
    }
    let rt = XlaRuntime::load_default().expect("artifacts");
    header("picker: one scheduling decision (XLA / PJRT)");
    for &(n, k) in &[(16usize, 128usize), (64, 512), (128, 2048)] {
        let (avail, demand, share, weight, active) =
            instance(&mut rng, n, k, 2);
        bench(
            &format!("xla sched_step n={n} k={k}"),
            budget,
            10_000,
            || {
                rt.sched_step(
                    &avail, &demand, &share, &weight, &active, n, k, 2,
                )
                .unwrap()
            },
        );
    }
    header("picker: batched loop (XLA, one PJRT call = 64 decisions)");
    for &(n, k) in &[(64usize, 512usize), (128, 2048)] {
        let (avail, demand, share, weight, _) = instance(&mut rng, n, k, 2);
        let pending = vec![10i32; n];
        bench(
            &format!("xla sched_loop n={n} k={k} t=64"),
            budget,
            10_000,
            || {
                rt.sched_loop(
                    &avail, &demand, &share, &weight, &pending, n, k, 2,
                )
                .unwrap()
            },
        );
    }
}
