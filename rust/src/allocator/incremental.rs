//! Event-driven dynamic DRFH: the exact fluid allocation (paper
//! eq. (7) + the progressive-filling rounds of Sec. V-A) maintained
//! *incrementally* across user churn, with one LP variable block per
//! **allocation class**, not per user.
//!
//! [`IncrementalDrfh`] owns one [`crate::solver::Solver`] for the whole
//! lifetime of the cluster and caches everything that survives events:
//! the server-class aggregation, normalized demands, the class capacity
//! rows, and — crucially — the simplex **basis**. `add_user`,
//! `remove_user`, `set_cap` and `set_weight` mutate the standing LP in
//! place; every [`IncrementalDrfh::allocate`] then runs the *same*
//! progressive-filling rounds as the from-scratch reference
//! ([`crate::allocator::solve`]) but re-solves each round warm from the
//! previous basis instead of rebuilding a tableau and running a full
//! two-phase solve. On dynamic-sharing sweeps (Fig. 4 style) this makes
//! consecutive solves near-incremental: a handful of dual/primal repair
//! pivots per event instead of hundreds of phase-1/phase-2 pivots.
//!
//! ## Allocation classes
//!
//! Users with bit-identical normalized demand row, guarded weight, and
//! cap (in dominant-share units) are interchangeable in eq. (7) — see
//! the `drfh` module docs for the averaging argument — so they share
//! one **class slot**: one `x_Ac` variable per server class plus one
//! pair of growth rows, scaled by the member count `k_A`. The LP
//! therefore sizes with (server classes × allocation classes),
//! independent of the user count, and the common events are trivial:
//!
//! * `add_user` on a live class increments its member count — **no
//!   column append, no row append, no coefficient edit** (the member
//!   scale `k_A` enters the growth rows at the next `allocate()`,
//!   which rewrites those coefficients every call anyway);
//! * `remove_user` that leaves the class populated is the same in
//!   reverse; the *last* departure pins the slot's rows to
//!   `Σ_c x_Ac = 0` and recycles the slot (LIFO) for the next new
//!   class;
//! * `set_cap` / `set_weight` migrate the user between classes
//!   (detach + attach) — at most one slot retire plus one slot rewire,
//!   still pure rhs/coefficient edits.
//!
//! Per-user shares come out by deterministic equal split,
//! `x_i = x_A / k_A`, bitwise identical across a class's members.
//!
//! ## LP shape and basis-reuse invariants
//!
//! Variables: one `x_Ac` per (class slot, server class) — the total
//! dominant share class *A* draws from server class *c* — plus one
//! shared *cumulative* growth variable `G` (the filling level since
//! the current `allocate()` began; the objective). Rows:
//!
//! * server-class capacity rows `Σ_A x_Ac · d_Ar <= cap_cr` — created
//!   once, never touched except to rewire a slot's demand
//!   coefficients when a new class claims it;
//! * per class slot, the growth equality — `Σ_c x_Ac − k_A·w_A·G = 0`
//!   while the class is actively filling, `Σ_c x_Ac = k_A·cap_A` once
//!   its task cap saturates — split into a **pair of `<=` rows**
//!   (`row_up` / `row_lo`). The pairing is what keeps every event
//!   warm-startable: appending or re-targeting a `<=` row only
//!   adds/retunes a slack, which the dual simplex repairs from the
//!   current basis, whereas a true equality row would need a fresh
//!   phase-1 artificial (see `solver::revised` docs);
//! * one `G <= g_max` cap row whose rhs is retuned every round. When
//!   no finite task cap remains among the active classes the row must
//!   not bind, and its stand-in rhs must stay **O(1)**: `G` provably
//!   never exceeds `1/max_active_weight` (an active member's dominant
//!   share `w·G` is at most the whole pool), so `2/max_active_weight`
//!   is slack and scale-safe. A huge sentinel (say 1e12) would be
//!   numerically catastrophic here: whenever a warm refactorization
//!   pivots `G` on the cap row, the sentinel rhs is eliminated into
//!   every row containing `G` and its absorption error (~1e12 · ε)
//!   wipes out the 1e-9 parity budget.
//!
//! The growth variable is *cumulative* (`Σx = k·w·G`, not
//! `Σx = f + k·w·δ` with per-round resets) precisely so that active
//! rows keep `rhs = 0` across rounds and the round-*r* optimum stays
//! feasible — literally the same point — after a saturation switch:
//! the newly saturated class's rows flip to `Σ_c x_Ac = k·cap`, which
//! the current solution already satisfies (`w·G* = cap` per member up
//! to the clamp epsilon). The refactorized basis is therefore primal
//! feasible and the next round continues with ordinary warm primal
//! pivots instead of falling back to a cold solve; only the *first*
//! round after class churn may go cold (its coefficient edits can lose
//! both feasibilities).
//!
//! Parity: the round structure, `delta_max` computation, saturation
//! thresholds and termination tests mirror `drfh::solve_classes`
//! class for class (members are bit-identical, so the reference's
//! per-user filling state collapses to the same per-class state), and
//! each round's LP has the identical feasible set, so the per-user
//! dominant shares `g` (unique across alternate LP optima) match the
//! from-scratch path to solver precision;
//! `tests/incremental_parity.rs` enforces this across randomized event
//! sequences. The per-class split `x` may differ between the two paths
//! when the optimum is non-unique — both splits are optimal.

use super::drfh::{FluidAllocation, FluidUser};
use super::NormalizedDemand;
use crate::cluster::{Cluster, ResVec, ServerClass};
use crate::sched::effective_weight;
use crate::solver::{LpResult, RowId, SolveStats, Solver, VarId};
use std::collections::HashMap;

/// Placeholder rhs for the growth-cap row at construction; every
/// `allocate()` round overwrites it before solving.
const GROWTH_CAP_INIT: f64 = 1.0;

/// Handle to a user inside an [`IncrementalDrfh`]. Stays valid until
/// `remove_user`; never reused while the user is present.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct UserId(usize);

/// Allocation-class identity: normalized demand row, guarded weight,
/// and cap in dominant-share units — all by exact bit pattern.
type ClassKey = (Vec<u64>, u64, u64);

/// The live population of one class slot.
#[derive(Clone, Debug)]
struct ClassUser {
    key: ClassKey,
    demand: NormalizedDemand,
    /// Guarded weight (`sched::effective_weight`), per member.
    weight: f64,
    /// Task cap in dominant-share units (`inf` when uncapped), per
    /// member.
    cap: f64,
    /// Number of users sharing this block.
    members: usize,
}

#[derive(Clone, Debug)]
struct Slot {
    /// One x_Ac variable per server class.
    vars: Vec<VarId>,
    /// `Σ_c x_Ac − k·w·G <= k·f`
    row_up: RowId,
    /// `−Σ_c x_Ac + k·w·G <= −k·f`
    row_lo: RowId,
    class: Option<ClassUser>,
}

/// One present user: their own spec and normalized demand (kept
/// per-user — class members share a *norm* row but may differ in
/// absolute `share`, which `tasks` recovery needs) plus the class slot
/// currently holding them.
#[derive(Clone, Debug)]
struct UserRec {
    spec: FluidUser,
    demand: NormalizedDemand,
    slot: usize,
}

/// The warm-started incremental fluid DRFH allocator. See module docs.
#[derive(Clone, Debug)]
pub struct IncrementalDrfh {
    classes: Vec<ServerClass>,
    total: ResVec,
    m: usize,
    solver: Solver,
    delta: VarId,
    delta_cap: RowId,
    /// Class capacity rows, `[class][resource]`.
    cap_rows: Vec<Vec<RowId>>,
    slots: Vec<Slot>,
    /// Vacant class-slot indices, reused LIFO.
    slot_free: Vec<usize>,
    /// Live allocation classes by identity. Order-independent HashMap
    /// use (lint hash-iter rule): keyed lookups only, never iterated —
    /// every traversal runs over `order` or ascending slot indices.
    by_key: HashMap<ClassKey, usize>,
    users: Vec<Option<UserRec>>,
    /// Vacant user-id indices, reused LIFO.
    user_free: Vec<usize>,
    /// Present user ids in insertion order — the user order of every
    /// [`FluidAllocation`] this allocator returns.
    order: Vec<usize>,
}

impl IncrementalDrfh {
    /// Build the standing LP skeleton for `cluster` (classes + totals
    /// are cached; the cluster itself is not retained).
    pub fn new(cluster: &Cluster) -> Self {
        Self::from_classes(cluster.classes(), cluster.total_capacity())
    }

    /// Same, over pre-aggregated server classes.
    pub fn from_classes(classes: Vec<ServerClass>, total: ResVec) -> Self {
        let m = total.dims();
        let mut solver = Solver::new();
        let delta = solver.add_var(1.0);
        let mut cap_rows = Vec::with_capacity(classes.len());
        for class in &classes {
            let mut rows = Vec::with_capacity(m);
            for r in 0..m {
                // zero-total guard (mirrors `drfh::empty_allocation`):
                // an unprovisioned resource contributes no capacity,
                // not a 0/0 NaN rhs
                let cap_share = if total[r] > 0.0 {
                    class.capacity[r] * class.count as f64 / total[r]
                } else {
                    0.0
                };
                rows.push(solver.add_row_le(&[], cap_share));
            }
            cap_rows.push(rows);
        }
        let delta_cap = solver.add_row_le(&[(delta, 1.0)], GROWTH_CAP_INIT);
        IncrementalDrfh {
            classes,
            total,
            m,
            solver,
            delta,
            delta_cap,
            cap_rows,
            slots: Vec::new(),
            slot_free: Vec::new(),
            by_key: HashMap::new(),
            users: Vec::new(),
            user_free: Vec::new(),
            order: Vec::new(),
        }
    }

    /// Number of present users.
    pub fn len(&self) -> usize {
        self.order.len()
    }

    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }

    /// The server classes the standing LP is expressed over.
    pub fn classes(&self) -> &[ServerClass] {
        &self.classes
    }

    /// Pool totals (absolute units).
    pub fn total(&self) -> &ResVec {
        &self.total
    }

    /// Present users in allocation order — a ready-made argument for
    /// the from-scratch reference `allocator::solve`.
    pub fn users(&self) -> Vec<FluidUser> {
        self.order
            .iter()
            .map(|&u| self.users[u].as_ref().unwrap().spec.clone())
            .collect()
    }

    /// Cumulative solver accounting (warm/cold solves, pivots, ...).
    pub fn solver_stats(&self) -> SolveStats {
        self.solver.stats()
    }

    /// Live allocation classes (occupied class slots).
    pub fn live_classes(&self) -> usize {
        self.by_key.len()
    }

    /// Structural variables of the standing LP — the LP-shape
    /// introspection hook: stays put when users join existing classes.
    pub fn lp_vars(&self) -> usize {
        self.solver.num_vars()
    }

    /// Find or create the class slot for `(demand, weight, cap)` and
    /// count one more member into it. Joining a live class touches the
    /// LP not at all; a new class reuses a vacant slot's variables and
    /// pair rows when one exists (rewiring its capacity-row
    /// coefficients) and appends fresh ones only otherwise — the warm
    /// basis survives every branch.
    fn attach(
        &mut self,
        demand: NormalizedDemand,
        weight: f64,
        cap: f64,
    ) -> usize {
        let key: ClassKey = (
            demand.norm.as_slice().iter().map(|x| x.to_bits()).collect(),
            weight.to_bits(),
            cap.to_bits(),
        );
        if let Some(&si) = self.by_key.get(&key) {
            self.slots[si].class.as_mut().unwrap().members += 1;
            return si;
        }
        let nc = self.classes.len();
        let si = match self.slot_free.pop() {
            Some(si) => si,
            None => {
                let vars: Vec<VarId> =
                    (0..nc).map(|_| self.solver.add_var(0.0)).collect();
                let up: Vec<(VarId, f64)> =
                    vars.iter().map(|&v| (v, 1.0)).collect();
                let lo: Vec<(VarId, f64)> =
                    vars.iter().map(|&v| (v, -1.0)).collect();
                let row_up = self.solver.add_row_le(&up, 0.0);
                let row_lo = self.solver.add_row_le(&lo, 0.0);
                self.slots.push(Slot { vars, row_up, row_lo, class: None });
                self.slots.len() - 1
            }
        };
        // (re)wire the slot's demand coefficients into the capacity rows
        for c in 0..nc {
            for r in 0..self.m {
                let row = self.cap_rows[c][r];
                let var = self.slots[si].vars[c];
                self.solver.set_coeff(row, var, demand.norm[r]);
            }
        }
        self.by_key.insert(key.clone(), si);
        self.slots[si].class =
            Some(ClassUser { key, demand, weight, cap, members: 1 });
        si
    }

    /// Count one member out of slot `si`. The last departure pins the
    /// slot's pair rows to `Σ_c x_Ac = 0` (releasing the capacity
    /// without disturbing the basis) and recycles the slot.
    fn detach(&mut self, si: usize) {
        let class = self.slots[si].class.as_mut().unwrap();
        class.members -= 1;
        if class.members > 0 {
            return;
        }
        let key = class.key.clone();
        self.slots[si].class = None;
        self.by_key.remove(&key);
        let (up, lo) = (self.slots[si].row_up, self.slots[si].row_lo);
        self.solver.set_coeff(up, self.delta, 0.0);
        self.solver.set_coeff(lo, self.delta, 0.0);
        self.solver.set_rhs(up, 0.0);
        self.solver.set_rhs(lo, 0.0);
        self.slot_free.push(si);
    }

    fn class_params(
        &self,
        user: &FluidUser,
    ) -> (NormalizedDemand, f64, f64) {
        let demand =
            NormalizedDemand::from_absolute(&user.demand, &self.total);
        let weight = effective_weight(user.weight);
        let cap = user
            .task_cap
            .map(|t| t * demand.share[demand.dominant])
            .unwrap_or(f64::INFINITY);
        (demand, weight, cap)
    }

    /// Join event. On an existing allocation class this is a pure
    /// member-count bump — no LP mutation of any kind.
    pub fn add_user(&mut self, user: FluidUser) -> UserId {
        let (demand, weight, cap) = self.class_params(&user);
        let slot = self.attach(demand.clone(), weight, cap);
        let rec = UserRec { spec: user, demand, slot };
        let uid = match self.user_free.pop() {
            Some(u) => {
                self.users[u] = Some(rec);
                u
            }
            None => {
                self.users.push(Some(rec));
                self.users.len() - 1
            }
        };
        self.order.push(uid);
        UserId(uid)
    }

    /// Departure event. Leaving a still-populated class is a pure
    /// member-count drop; the last member out retires the class slot
    /// (see [`Self::detach`] — capacity released, basis undisturbed).
    pub fn remove_user(&mut self, id: UserId) {
        let rec = self.users[id.0]
            .take()
            .expect("remove_user on an absent user");
        self.detach(rec.slot);
        self.order.retain(|&u| u != id.0);
        self.user_free.push(id.0);
    }

    /// Re-key a present user after a spec change: detach from the old
    /// class, attach to the (possibly new, possibly same) one.
    fn rekey(&mut self, id: UserId, spec: FluidUser) {
        let (demand, weight, cap) = self.class_params(&spec);
        let old_slot = self.users[id.0].as_ref().unwrap().slot;
        // detach first so a sole member's class slot frees up for
        // immediate LIFO reuse by the new key
        self.detach(old_slot);
        let slot = self.attach(demand.clone(), weight, cap);
        self.users[id.0] = Some(UserRec { spec, demand, slot });
    }

    /// Task-cap change event (paper Sec. V-A finite demands). May
    /// migrate the user between allocation classes.
    pub fn set_cap(&mut self, id: UserId, task_cap: Option<f64>) {
        let mut spec = self.users[id.0]
            .as_ref()
            .expect("set_cap on a removed user")
            .spec
            .clone();
        spec.task_cap = task_cap;
        self.rekey(id, spec);
    }

    /// Weight change event. May migrate the user between allocation
    /// classes.
    pub fn set_weight(&mut self, id: UserId, weight: f64) {
        let mut spec = self.users[id.0]
            .as_ref()
            .expect("set_weight on a removed user")
            .spec
            .clone();
        spec.weight = weight;
        self.rekey(id, spec);
    }

    /// Capacity event: server class `class` now has `count` live
    /// members (a crash shrinks it, a recovery restores it — see
    /// `sim::faults`). A pure rhs retune of the class's capacity rows:
    /// the warm basis survives, the dual simplex repairs any row the
    /// new rhs left violated. Shares stay normalized against the
    /// *nominal* pool total cached at construction, so demands, class
    /// keys, and every standing coefficient are untouched — only the
    /// capacity available to the filling rounds moves.
    pub fn set_class_count(&mut self, class: usize, count: usize) {
        self.classes[class].count = count;
        let cap = self.classes[class].capacity;
        for r in 0..self.m {
            let cap_share = if self.total[r] > 0.0 {
                cap[r] * count as f64 / self.total[r]
            } else {
                0.0
            };
            self.solver.set_rhs(self.cap_rows[class][r], cap_share);
        }
    }

    /// Re-equalize: run the progressive-filling rounds for the current
    /// class population, warm from the standing basis. Mirrors
    /// `drfh::solve_classes` round for round (same `delta_max`, same
    /// saturation thresholds, same termination) so the resulting
    /// dominant shares match the from-scratch path.
    pub fn allocate(&mut self) -> FluidAllocation {
        let nc = self.classes.len();
        let n = self.order.len();
        let demands: Vec<NormalizedDemand> = self
            .order
            .iter()
            .map(|&u| self.users[u].as_ref().unwrap().demand.clone())
            .collect();
        if n == 0 {
            return FluidAllocation {
                classes: self.classes.clone(),
                total: self.total,
                demands,
                x: Vec::new(),
                g: Vec::new(),
                tasks: Vec::new(),
                lp_pivots: 0,
                lp_solves: 0,
                alloc_classes: 0,
            };
        }
        // live class slots, ascending slot index — the deterministic
        // iteration order for everything per-class below
        let live: Vec<usize> = (0..self.slots.len())
            .filter(|&si| self.slots[si].class.is_some())
            .collect();
        let na = live.len();
        let weights: Vec<f64> = live
            .iter()
            .map(|&si| self.slots[si].class.as_ref().unwrap().weight)
            .collect();
        let caps: Vec<f64> = live
            .iter()
            .map(|&si| self.slots[si].class.as_ref().unwrap().cap)
            .collect();
        let counts: Vec<f64> = live
            .iter()
            .map(|&si| self.slots[si].class.as_ref().unwrap().members as f64)
            .collect();

        // Reset the filling state: every present class grows from zero
        // again (dynamic DRFH re-equalizes the whole allocation on
        // every event; only the solver basis carries over). Active
        // rows are `Σx − k·w·G = 0` and stay untouched until the class
        // saturates — see the module docs for why the growth variable
        // is cumulative. The member scale k enters here, which is why
        // joins/departures on live classes need no LP edits of their
        // own.
        let mut frozen = vec![0.0f64; na];
        let mut saturated: Vec<bool> =
            caps.iter().map(|&c| c <= 1e-15).collect();
        let mut xa = vec![vec![0.0f64; nc]; na];
        let mut lp_pivots = 0u64;
        let mut lp_solves = 0u32;
        for (a, &si) in live.iter().enumerate() {
            let (up, lo) = (self.slots[si].row_up, self.slots[si].row_lo);
            let kw = if saturated[a] { 0.0 } else { counts[a] * weights[a] };
            self.solver.set_coeff(up, self.delta, -kw);
            self.solver.set_coeff(lo, self.delta, kw);
            self.solver.set_rhs(up, 0.0);
            self.solver.set_rhs(lo, 0.0);
        }

        // cumulative filling level committed so far (G in the docs)
        let mut g_cum = 0.0f64;
        for _round in 0..na + 1 {
            if saturated.iter().all(|&s| s) {
                break;
            }
            // G bounded by the tightest cap among active classes;
            // equals the reference's `frozen + delta_max` since active
            // classes hold frozen = w·G exactly (per member). With no
            // finite cap the row gets the O(1) never-binding stand-in
            // (see module docs).
            let mut g_max = f64::INFINITY;
            let mut max_w = 0.0f64;
            for a in 0..na {
                if !saturated[a] {
                    max_w = max_w.max(weights[a]);
                    if caps[a].is_finite() {
                        g_max = g_max.min(caps[a] / weights[a]);
                    }
                }
            }
            // any bound >= 2/max_w can never bind (G <= 1/max_w), so
            // clamping there changes nothing while keeping the LP
            // free of large-magnitude rhs values
            let rhs = g_max.max(0.0).min(2.0 / max_w);
            self.solver.set_rhs(self.delta_cap, rhs);

            let (sol, g_star) = match self.solver.solve() {
                LpResult::Optimal { x, obj, pivots } => {
                    lp_pivots += pivots.search() as u64;
                    lp_solves += 1;
                    (x, obj)
                }
                other => {
                    panic!("incremental DRFH round LP not optimal: {other:?}")
                }
            };
            for (a, &si) in live.iter().enumerate() {
                for c in 0..nc {
                    xa[a][c] = sol[self.slots[si].vars[c].index()];
                }
            }
            // the reference's per-round progressive-filling increment
            let delta = g_star - g_cum;
            if delta <= 1e-12 {
                break; // capacity exhausted for all active classes
            }
            g_cum = g_star;
            let mut newly = 0;
            for (a, &si) in live.iter().enumerate() {
                if saturated[a] {
                    continue;
                }
                frozen[a] += weights[a] * delta;
                if caps[a].is_finite() && frozen[a] >= caps[a] - 1e-9 {
                    frozen[a] = caps[a];
                    saturated[a] = true;
                    newly += 1;
                    // freeze: Σx = k·cap — the current optimum already
                    // satisfies this (w·G* = cap per member up to the
                    // clamp epsilon), so the basis stays primal
                    // feasible
                    let (up, lo) =
                        (self.slots[si].row_up, self.slots[si].row_lo);
                    self.solver.set_coeff(up, self.delta, 0.0);
                    self.solver.set_coeff(lo, self.delta, 0.0);
                    self.solver.set_rhs(up, counts[a] * caps[a]);
                    self.solver.set_rhs(lo, -counts[a] * caps[a]);
                }
            }
            if newly == 0 {
                break; // no cap hit: capacity-limited optimum reached
            }
        }

        // Recover per-user shares: deterministic equal split within
        // each class — one division per (class, server class), fanned
        // out, so members are bitwise identical.
        let mut split_of_slot = vec![usize::MAX; self.slots.len()];
        let split: Vec<Vec<f64>> = live
            .iter()
            .enumerate()
            .map(|(a, &si)| {
                split_of_slot[si] = a;
                (0..nc).map(|c| xa[a][c] / counts[a]).collect()
            })
            .collect();
        let mut x = vec![vec![0.0f64; nc]; n];
        for (k, &u) in self.order.iter().enumerate() {
            let si = self.users[u].as_ref().unwrap().slot;
            x[k].copy_from_slice(&split[split_of_slot[si]]);
        }

        let g: Vec<f64> = x.iter().map(|xi| xi.iter().sum()).collect();
        let tasks: Vec<f64> = g
            .iter()
            .zip(&demands)
            .map(|(&gi, d)| gi / d.share[d.dominant])
            .collect();
        FluidAllocation {
            classes: self.classes.clone(),
            total: self.total,
            demands,
            x,
            g,
            tasks,
            lp_pivots,
            lp_solves,
            alloc_classes: na,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allocator;
    use crate::cluster::Cluster;

    fn fig1_users() -> Vec<FluidUser> {
        vec![
            FluidUser::unweighted(ResVec::cpu_mem(0.2, 1.0)),
            FluidUser::unweighted(ResVec::cpu_mem(1.0, 0.2)),
        ]
    }

    fn assert_matches_scratch(inc: &mut IncrementalDrfh, cluster: &Cluster) {
        let warm = inc.allocate();
        let scratch = allocator::solve(cluster, &inc.users());
        assert_eq!(warm.g.len(), scratch.g.len());
        for i in 0..warm.g.len() {
            assert!(
                (warm.g[i] - scratch.g[i]).abs() < 1e-8,
                "user {i}: warm g {} vs scratch {}",
                warm.g[i],
                scratch.g[i]
            );
        }
        assert!(warm.is_feasible(1e-7));
    }

    #[test]
    fn matches_scratch_on_fig1() {
        let cluster = Cluster::fig1_example();
        let mut inc = IncrementalDrfh::new(&cluster);
        for u in fig1_users() {
            inc.add_user(u);
        }
        let a = inc.allocate();
        assert!((a.g[0] - 5.0 / 7.0).abs() < 1e-6, "g1={}", a.g[0]);
        assert!((a.g[1] - 5.0 / 7.0).abs() < 1e-6, "g2={}", a.g[1]);
        assert!((a.tasks[0] - 10.0).abs() < 1e-5);
        assert!((a.tasks[1] - 10.0).abs() < 1e-5);
        assert_eq!(a.alloc_classes, 2);
    }

    #[test]
    fn join_depart_rejoin_reuses_slot() {
        let cluster = Cluster::fig1_example();
        let mut inc = IncrementalDrfh::new(&cluster);
        let users = fig1_users();
        let id0 = inc.add_user(users[0].clone());
        inc.add_user(users[1].clone());
        inc.allocate();
        inc.remove_user(id0);
        assert_eq!(inc.len(), 1);
        assert_eq!(inc.live_classes(), 1);
        assert_matches_scratch(&mut inc, &cluster);
        // rejoin with a different demand: the freed class slot is
        // rewired for the new key
        inc.add_user(FluidUser::unweighted(ResVec::cpu_mem(0.5, 0.5)));
        assert_eq!(inc.len(), 2);
        // slot recycled, no new slot appended
        assert_eq!(inc.slots.len(), 2);
        assert_matches_scratch(&mut inc, &cluster);
    }

    #[test]
    fn cap_and_weight_events_apply() {
        let cluster = Cluster::fig1_example();
        let mut inc = IncrementalDrfh::new(&cluster);
        let ids: Vec<UserId> =
            fig1_users().into_iter().map(|u| inc.add_user(u)).collect();
        inc.allocate();
        // cap user 1 at 2 tasks: user 2 absorbs the release
        inc.set_cap(ids[0], Some(2.0));
        let a = inc.allocate();
        assert!((a.tasks[0] - 2.0).abs() < 1e-5, "tasks={:?}", a.tasks);
        assert!(a.tasks[1] > 10.0, "user 2 should absorb: {:?}", a.tasks);
        assert_matches_scratch(&mut inc, &cluster);
        // uncap + double the weight: shares go 2:1
        inc.set_cap(ids[0], None);
        inc.set_weight(ids[0], 2.0);
        let a = inc.allocate();
        assert!(
            (a.g[0] - 2.0 * a.g[1]).abs() < 1e-6,
            "weighted shares {:?}",
            a.g
        );
        assert_matches_scratch(&mut inc, &cluster);
    }

    #[test]
    fn zero_weight_user_uses_guarded_semantics() {
        let cluster = Cluster::fig1_example();
        let mut inc = IncrementalDrfh::new(&cluster);
        let mut users = fig1_users();
        users[0].weight = 0.0;
        for u in users {
            inc.add_user(u);
        }
        let a = inc.allocate();
        assert!(a.g.iter().all(|g| g.is_finite()), "g = {:?}", a.g);
        // guarded to weight 1.0: the unweighted Fig. 3 optimum
        assert!((a.g[0] - 5.0 / 7.0).abs() < 1e-6, "g1 = {}", a.g[0]);
        assert!((a.g[1] - 5.0 / 7.0).abs() < 1e-6, "g2 = {}", a.g[1]);
    }

    #[test]
    fn empty_and_single_user() {
        let cluster = Cluster::fig1_example();
        let mut inc = IncrementalDrfh::new(&cluster);
        let a = inc.allocate();
        assert!(a.g.is_empty() && a.tasks.is_empty());
        assert_eq!(a.alloc_classes, 0);
        let id = inc.add_user(fig1_users()[0].clone());
        assert_matches_scratch(&mut inc, &cluster);
        inc.remove_user(id);
        let a = inc.allocate();
        assert!(a.g.is_empty());
        assert_eq!(inc.live_classes(), 0);
    }

    #[test]
    fn warm_solves_dominate_after_first_event() {
        let cluster = Cluster::fig1_example();
        let mut inc = IncrementalDrfh::new(&cluster);
        for u in fig1_users() {
            inc.add_user(u);
        }
        inc.allocate();
        for i in 0..6usize {
            // non-binding caps (fair share is 10 tasks): each rekey
            // recycles the just-freed slot with bit-identical demand
            // coefficients, so the standing LP only sees rhs churn and
            // every round after the first solve re-solves warm
            inc.set_cap(UserId(i % 2), Some(30.0 + i as f64));
            let a = inc.allocate();
            assert!((a.g[0] - 5.0 / 7.0).abs() < 1e-6, "g={:?}", a.g);
        }
        let st = inc.solver_stats();
        assert!(
            st.warm_solves > st.cold_solves + st.fallbacks,
            "warm path barely used: {st:?}"
        );
    }

    #[test]
    fn joining_an_existing_class_adds_no_columns() {
        let cluster = Cluster::fig1_example();
        let nc = cluster.classes().len();
        let mut inc = IncrementalDrfh::new(&cluster);
        let archetypes = [
            ResVec::cpu_mem(0.2, 1.0),
            ResVec::cpu_mem(1.0, 0.2),
            ResVec::cpu_mem(0.5, 0.5),
        ];
        for i in 0..100 {
            inc.add_user(FluidUser::unweighted(
                archetypes[i % archetypes.len()],
            ));
        }
        assert_eq!(inc.len(), 100);
        assert_eq!(inc.live_classes(), 3);
        // LP sized by (allocation classes x server classes) + G,
        // independent of the 100 users
        assert_eq!(inc.lp_vars(), 1 + 3 * nc);
        let before = inc.lp_vars();
        let extra = inc.add_user(FluidUser::unweighted(archetypes[0]));
        assert_eq!(inc.lp_vars(), before, "join on a live class appended");
        assert_eq!(inc.live_classes(), 3);
        let a = inc.allocate();
        assert_eq!(a.alloc_classes, 3);
        assert_matches_scratch(&mut inc, &cluster);
        inc.remove_user(extra);
        assert_eq!(inc.lp_vars(), before);
    }

    /// `set_class_count` (the fault layer's capacity edit) must agree
    /// with a from-scratch solve over the shrunken class list — and
    /// recover exactly when the count is restored.
    #[test]
    fn class_count_edit_matches_scratch() {
        let caps = [
            ResVec::cpu_mem(2.0, 12.0),
            ResVec::cpu_mem(2.0, 12.0),
            ResVec::cpu_mem(12.0, 2.0),
        ];
        let cluster = Cluster::from_capacities(&caps);
        let mut inc = IncrementalDrfh::new(&cluster);
        for u in fig1_users() {
            inc.add_user(u);
        }
        let nominal = inc.allocate();
        // crash one of the two (2, 12) servers: its class count drops
        let mem_class = (0..inc.classes().len())
            .find(|&c| inc.classes()[c].count == 2)
            .expect("duplicated class");
        inc.set_class_count(mem_class, 1);
        let degraded = inc.allocate();
        let scratch = allocator::drfh::solve_classes(
            inc.classes(),
            inc.total(),
            &inc.users(),
        );
        for i in 0..degraded.g.len() {
            assert!(
                (degraded.g[i] - scratch.g[i]).abs() < 1e-8,
                "user {i}: warm g {} vs scratch {}",
                degraded.g[i],
                scratch.g[i]
            );
            assert!(
                degraded.g[i] < nominal.g[i] - 1e-9,
                "losing a server must shrink shares: {} vs {}",
                degraded.g[i],
                nominal.g[i]
            );
        }
        assert!(degraded.is_feasible(1e-7));
        // recovery: restoring the count restores the nominal optimum
        inc.set_class_count(mem_class, 2);
        let recovered = inc.allocate();
        for i in 0..recovered.g.len() {
            assert!(
                (recovered.g[i] - nominal.g[i]).abs() < 1e-8,
                "user {i}: recovered g {} vs nominal {}",
                recovered.g[i],
                nominal.g[i]
            );
        }
        // a fully-crashed class is a legal edit too
        inc.set_class_count(mem_class, 0);
        let gone = inc.allocate();
        assert!(gone.is_feasible(1e-7));
        assert!(gone.g.iter().all(|g| g.is_finite()));
    }

    #[test]
    fn class_members_split_bitwise_equal() {
        let cluster = Cluster::fig1_example();
        let mut inc = IncrementalDrfh::new(&cluster);
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); 2];
        for i in 0..8usize {
            let d = if i % 2 == 0 {
                ResVec::cpu_mem(0.2, 1.0)
            } else {
                ResVec::cpu_mem(1.0, 0.2)
            };
            inc.add_user(FluidUser::unweighted(d));
            groups[i % 2].push(i);
        }
        let a = inc.allocate();
        assert_eq!(a.alloc_classes, 2);
        for members in &groups {
            let first = members[0];
            for &i in &members[1..] {
                assert_eq!(
                    a.g[i].to_bits(),
                    a.g[first].to_bits(),
                    "class members diverge: {} vs {}",
                    a.g[i],
                    a.g[first]
                );
                assert_eq!(a.x[i], a.x[first]);
            }
        }
        assert_matches_scratch(&mut inc, &cluster);
    }
}
