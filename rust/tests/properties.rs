//! Fairness property tests for the exact fluid DRFH allocation
//! (paper Propositions 1-7), randomized over many instances with the
//! in-tree deterministic RNG (proptest is unavailable offline; each
//! property sweeps seeds explicitly, which doubles as shrink-free
//! reproducibility — the failing seed is in the assert message).

use drfh::allocator::{self, per_server_drf, FluidUser, NormalizedDemand};
use drfh::cluster::{Cluster, ResVec};
use drfh::util::Pcg32;

fn random_cluster(rng: &mut Pcg32, max_servers: usize) -> Cluster {
    let k = 1 + rng.below(max_servers);
    Cluster::from_capacities(
        &(0..k)
            .map(|_| {
                ResVec::cpu_mem(rng.uniform(0.5, 8.0), rng.uniform(0.5, 8.0))
            })
            .collect::<Vec<_>>(),
    )
}

fn random_users(rng: &mut Pcg32, max_users: usize) -> Vec<FluidUser> {
    let n = 2 + rng.below(max_users - 1);
    (0..n)
        .map(|_| {
            FluidUser::unweighted(ResVec::cpu_mem(
                rng.uniform(0.05, 1.5),
                rng.uniform(0.05, 1.5),
            ))
        })
        .collect()
}

/// Proposition 1 (envy-freeness): no user schedules more tasks with
/// another user's allocation than with its own.
#[test]
fn prop1_envy_freeness() {
    for seed in 0..40u64 {
        let mut rng = Pcg32::seeded(1000 + seed);
        let cluster = random_cluster(&mut rng, 6);
        let users = random_users(&mut rng, 6);
        let a = allocator::solve(&cluster, &users);
        let n = users.len();
        for i in 0..n {
            // tasks user i schedules from its own allocation
            let own: f64 = (0..a.classes.len())
                .map(|c| a.demands[i].tasks_of(&a.alloc_share(i, c)))
                .sum();
            for j in 0..n {
                if i == j {
                    continue;
                }
                let envy: f64 = (0..a.classes.len())
                    .map(|c| a.demands[i].tasks_of(&a.alloc_share(j, c)))
                    .sum();
                assert!(
                    envy <= own + 1e-6,
                    "seed {seed}: user {i} envies {j}: {envy:.6} > {own:.6}"
                );
            }
        }
    }
}

/// Proposition 2 (Pareto optimality): no user's dominant share can grow
/// while every other user keeps at least its DRFH share. Verified by a
/// direct LP maximization per user.
#[test]
fn prop2_pareto_optimality() {
    use drfh::solver::{self, Lp, LpResult};
    for seed in 0..25u64 {
        let mut rng = Pcg32::seeded(2000 + seed);
        let cluster = random_cluster(&mut rng, 5);
        let users = random_users(&mut rng, 5);
        let a = allocator::solve(&cluster, &users);
        let n = users.len();
        let classes = &a.classes;
        let nc = classes.len();
        let total = a.total;
        for target in 0..n {
            let nv = n * nc;
            let var = |i: usize, c: usize| i * nc + c;
            let mut c_obj = vec![0.0; nv];
            for c in 0..nc {
                c_obj[var(target, c)] = 1.0;
            }
            let mut a_ub = Vec::new();
            let mut b_ub = Vec::new();
            for (c, class) in classes.iter().enumerate() {
                for r in 0..total.dims() {
                    let mut row = vec![0.0; nv];
                    for i in 0..n {
                        row[var(i, c)] = a.demands[i].norm[r];
                    }
                    a_ub.push(row);
                    b_ub.push(
                        class.capacity[r] * class.count as f64 / total[r],
                    );
                }
            }
            // others keep at least their DRFH share: -sum_c x_ic <= -g_i
            for i in 0..n {
                if i == target {
                    continue;
                }
                let mut row = vec![0.0; nv];
                for c in 0..nc {
                    row[var(i, c)] = -1.0;
                }
                a_ub.push(row);
                b_ub.push(-(a.g[i] - 1e-9));
            }
            let lp = Lp { n: nv, c: c_obj, a_ub, b_ub, ..Default::default() };
            match solver::solve(&lp) {
                LpResult::Optimal { obj, .. } => {
                    assert!(
                        obj <= a.g[target] + 1e-5,
                        "seed {seed}: user {target} could grow {:.6} -> {:.6}",
                        a.g[target],
                        obj
                    );
                }
                other => panic!("seed {seed}: LP failed {other:?}"),
            }
        }
    }
}

/// Proposition 3 (truthfulness): misreporting the demand vector never
/// increases the number of tasks scheduled (w.r.t. the true demand).
#[test]
fn prop3_truthfulness_randomized() {
    for seed in 0..40u64 {
        let mut rng = Pcg32::seeded(3000 + seed);
        let cluster = random_cluster(&mut rng, 5);
        let users = random_users(&mut rng, 5);
        let n = users.len();
        let honest = allocator::solve(&cluster, &users);
        let liar = rng.below(n);
        // random misreport (scale each component independently)
        let mut lied = users.clone();
        lied[liar].demand = ResVec::cpu_mem(
            (users[liar].demand[0] * rng.uniform(0.3, 3.0)).max(1e-3),
            (users[liar].demand[1] * rng.uniform(0.3, 3.0)).max(1e-3),
        );
        let dishonest = allocator::solve(&cluster, &lied);
        // tasks the liar can *actually* run from the lying allocation:
        // its real per-task demand applied to the received bundles
        let total = dishonest.total;
        let true_demand =
            NormalizedDemand::from_absolute(&users[liar].demand, &total);
        let lied_tasks: f64 = (0..dishonest.classes.len())
            .map(|c| true_demand.tasks_of(&dishonest.alloc_share(liar, c)))
            .sum();
        assert!(
            lied_tasks <= honest.tasks[liar] + 1e-6,
            "seed {seed}: user {liar} gained by lying: {:.6} > {:.6}",
            lied_tasks,
            honest.tasks[liar]
        );
    }
}

/// Proposition 7 (population monotonicity): removing a user never
/// reduces the remaining users' task counts.
#[test]
fn prop7_population_monotonicity() {
    for seed in 0..40u64 {
        let mut rng = Pcg32::seeded(7000 + seed);
        let cluster = random_cluster(&mut rng, 5);
        let users = random_users(&mut rng, 6);
        let n = users.len();
        let full = allocator::solve(&cluster, &users);
        let leaver = rng.below(n);
        let mut remaining = users.clone();
        remaining.remove(leaver);
        let reduced = allocator::solve(&cluster, &remaining);
        for (new_i, old_i) in (0..n).filter(|&i| i != leaver).enumerate() {
            assert!(
                reduced.tasks[new_i] >= full.tasks[old_i] - 1e-6,
                "seed {seed}: user {old_i} lost tasks after {leaver} left: \
                 {:.6} < {:.6}",
                reduced.tasks[new_i],
                full.tasks[old_i]
            );
        }
    }
}

/// Proposition 4 (single-server DRF): with one server, DRFH equalizes
/// per-server dominant shares exactly like DRF.
#[test]
fn prop4_single_server_reduces_to_drf() {
    for seed in 0..40u64 {
        let mut rng = Pcg32::seeded(4000 + seed);
        let cap =
            ResVec::cpu_mem(rng.uniform(2.0, 10.0), rng.uniform(2.0, 10.0));
        let cluster = Cluster::from_capacities(&[cap]);
        let users = random_users(&mut rng, 5);
        let a = allocator::solve(&cluster, &users);
        // compare against the closed-form single-server DRF
        let demands: Vec<ResVec> = users.iter().map(|u| u.demand).collect();
        let drf = per_server_drf::drf_single_server(&cap, &demands);
        for i in 0..users.len() {
            assert!(
                (a.tasks[i] - drf[i]).abs() < 1e-5,
                "seed {seed}: user {i}: DRFH {:.6} vs DRF {:.6}",
                a.tasks[i],
                drf[i]
            );
        }
    }
}

/// Proposition 5 (single-resource fairness): with m = 1 the allocation
/// is max-min fair (equal pool shares when uncapped).
#[test]
fn prop5_single_resource_fairness() {
    for seed in 0..20u64 {
        let mut rng = Pcg32::seeded(5000 + seed);
        let k = 1 + rng.below(5);
        let cluster = Cluster::from_capacities(
            &(0..k)
                .map(|_| ResVec::from_slice(&[rng.uniform(1.0, 8.0)]))
                .collect::<Vec<_>>(),
        );
        let n = 2 + rng.below(4);
        let users: Vec<FluidUser> = (0..n)
            .map(|_| {
                FluidUser::unweighted(ResVec::from_slice(&[
                    rng.uniform(0.1, 2.0)
                ]))
            })
            .collect();
        let a = allocator::solve(&cluster, &users);
        for i in 0..n {
            assert!(
                (a.g[i] - 1.0 / n as f64).abs() < 1e-6,
                "seed {seed}: user {i} share {:.6} != 1/{n}",
                a.g[i]
            );
        }
    }
}

/// Proposition 6 (bottleneck fairness): users sharing a global dominant
/// resource get equal shares of it.
#[test]
fn prop6_bottleneck_fairness() {
    let mut checked = 0;
    for seed in 0..30u64 {
        let mut rng = Pcg32::seeded(6000 + seed);
        let cluster = random_cluster(&mut rng, 5);
        let n = 2 + rng.below(4);
        // everyone strongly CPU-dominant
        let users: Vec<FluidUser> = (0..n)
            .map(|_| {
                let cpu = rng.uniform(0.5, 1.5);
                FluidUser::unweighted(ResVec::cpu_mem(
                    cpu,
                    cpu * rng.uniform(0.05, 0.3),
                ))
            })
            .collect();
        let total = cluster.total_capacity();
        let all_cpu_dom = users.iter().all(|u| {
            NormalizedDemand::from_absolute(&u.demand, &total).dominant == 0
        });
        if !all_cpu_dom {
            continue;
        }
        checked += 1;
        let a = allocator::solve(&cluster, &users);
        let g0 = a.g[0];
        for i in 1..n {
            assert!(
                (a.g[i] - g0).abs() < 1e-6,
                "seed {seed}: unequal bottleneck shares {:?}",
                a.g
            );
        }
    }
    assert!(checked >= 10, "too few applicable instances: {checked}");
}

/// Lemma 1 (non-wastefulness) + feasibility, caps and weights included.
#[test]
fn allocations_feasible_and_nonwasteful() {
    for seed in 0..40u64 {
        let mut rng = Pcg32::seeded(8000 + seed);
        let cluster = random_cluster(&mut rng, 6);
        let mut users = random_users(&mut rng, 6);
        // mix of capped and uncapped, weighted and unweighted users
        for u in users.iter_mut() {
            if rng.f64() < 0.5 {
                u.task_cap = Some(rng.uniform(0.0, 20.0));
            }
            if rng.f64() < 0.3 {
                u.weight = rng.uniform(0.5, 3.0);
            }
        }
        let a = allocator::solve(&cluster, &users);
        assert!(a.is_feasible(1e-6), "seed {seed}: infeasible");
        for (i, u) in users.iter().enumerate() {
            if let Some(cap) = u.task_cap {
                assert!(
                    a.tasks[i] <= cap + 1e-6,
                    "seed {seed}: user {i} exceeds cap"
                );
            }
        }
        // dominant share consistency: g_i == dominant_share(alloc_i)
        for i in 0..users.len() {
            let g_check: f64 = (0..a.classes.len())
                .map(|c| a.demands[i].dominant_share_of(&a.alloc_share(i, c)))
                .sum();
            assert!(
                (g_check - a.g[i]).abs() < 1e-6,
                "seed {seed}: user {i} share mismatch"
            );
        }
    }
}

/// Weighted DRFH: shares are proportional to weights (uncapped case).
#[test]
fn weighted_shares_proportional() {
    for seed in 0..20u64 {
        let mut rng = Pcg32::seeded(9000 + seed);
        let cluster = random_cluster(&mut rng, 5);
        let mut users = random_users(&mut rng, 4);
        for u in users.iter_mut() {
            u.weight = rng.uniform(0.5, 4.0);
        }
        let a = allocator::solve(&cluster, &users);
        let ratio0 = a.g[0] / users[0].weight;
        for i in 1..users.len() {
            let ri = a.g[i] / users[i].weight;
            assert!(
                (ri - ratio0).abs() < 1e-6 * ratio0.max(1.0),
                "seed {seed}: weighted shares not proportional {:?}",
                a.g
            );
        }
    }
}

/// Paper Sec. III-D: on the Fig. 1 instance the naive per-server DRF
/// strictly underperforms DRFH for *both* users (6 vs 10 tasks).
#[test]
fn naive_per_server_drf_is_dominated() {
    let cluster = Cluster::fig1_example();
    let demands =
        vec![ResVec::cpu_mem(0.2, 1.0), ResVec::cpu_mem(1.0, 0.2)];
    let users: Vec<FluidUser> =
        demands.iter().map(|d| FluidUser::unweighted(*d)).collect();
    let drfh = allocator::solve(&cluster, &users);
    let naive = per_server_drf::solve(&cluster, &demands);
    let naive_tasks = naive.tasks_per_user();
    for i in 0..2 {
        assert!(
            drfh.tasks[i] > naive_tasks[i] + 3.0,
            "user {i}: DRFH {:.1} should beat naive {:.1} by a wide margin",
            drfh.tasks[i],
            naive_tasks[i]
        );
    }
}

/// Proposition 1 on the *incremental* path: after join/depart/re-join
/// churn (slot recycling included), the warm-started allocation is
/// still envy-free — no user schedules more tasks from another user's
/// bundle than from its own.
#[test]
fn prop1_envy_freeness_incremental_path() {
    use drfh::allocator::incremental::IncrementalDrfh;
    for seed in 0..20u64 {
        let mut rng = Pcg32::seeded(11_000 + seed);
        let cluster = random_cluster(&mut rng, 6);
        let users = random_users(&mut rng, 6);
        let mut inc = IncrementalDrfh::new(&cluster);
        let mut ids: Vec<_> =
            users.iter().map(|u| inc.add_user(u.clone())).collect();
        // churn: drop one user mid-stream and re-add it, so the warm
        // basis crosses a departure and a slot reuse
        let drop_i = rng.below(ids.len());
        inc.remove_user(ids.remove(drop_i));
        inc.allocate();
        ids.push(inc.add_user(users[drop_i].clone()));
        let a = inc.allocate();
        let n = ids.len();
        assert_eq!(n, users.len());
        for i in 0..n {
            let own: f64 = (0..a.classes.len())
                .map(|c| a.demands[i].tasks_of(&a.alloc_share(i, c)))
                .sum();
            for j in 0..n {
                if i == j {
                    continue;
                }
                let envy: f64 = (0..a.classes.len())
                    .map(|c| a.demands[i].tasks_of(&a.alloc_share(j, c)))
                    .sum();
                assert!(
                    envy <= own + 1e-6,
                    "seed {seed}: user {i} envies {j}: {envy:.6} > {own:.6}"
                );
            }
        }
    }
}

/// Sharing incentive on the incremental path. The paper proves
/// envy-freeness, Pareto optimality and truthfulness and evaluates
/// sharing incentive *empirically* (Fig. 8); two directions are
/// guaranteed for the fluid allocation and checked here:
/// (a) symmetric users (identical demands) each get at least their
/// dedicated 1/n-slice-of-every-server allocation, and (b) in general
/// every user's dominant share is at least the *worst* per-user slice
/// share — the max-min optimum dominates the feasible equal-split
/// profile.
#[test]
fn sharing_incentive_incremental_path() {
    use drfh::allocator::incremental::IncrementalDrfh;
    for seed in 0..20u64 {
        let mut rng = Pcg32::seeded(12_000 + seed);
        let cluster = random_cluster(&mut rng, 5);
        let n = 2 + rng.below(4);
        let slice_caps = |d: usize| {
            cluster
                .servers
                .iter()
                .map(|s| s.capacity.scale(1.0 / d as f64))
                .collect::<Vec<_>>()
        };
        // (a) symmetric users
        let d = ResVec::cpu_mem(rng.uniform(0.05, 1.0), rng.uniform(0.05, 1.0));
        let mut inc = IncrementalDrfh::new(&cluster);
        for _ in 0..n {
            inc.add_user(FluidUser::unweighted(d));
        }
        let a = inc.allocate();
        let slice = Cluster::from_capacities(&slice_caps(n));
        let solo = allocator::solve(&slice, &[FluidUser::unweighted(d)]);
        for i in 0..n {
            assert!(
                a.tasks[i] >= solo.tasks[0] - 1e-6,
                "seed {seed}: symmetric user {i}: shared {:.6} < slice {:.6}",
                a.tasks[i],
                solo.tasks[0]
            );
        }
        // (b) heterogeneous users: min dominant share >= worst slice share
        let users = random_users(&mut rng, 5);
        let hn = users.len();
        let mut inc = IncrementalDrfh::new(&cluster);
        for u in &users {
            inc.add_user(u.clone());
        }
        let b = inc.allocate();
        let hslice = Cluster::from_capacities(&slice_caps(hn));
        // solve() on the slice cluster reports shares relative to the
        // *slice* pool; divide by n to express them against the full
        // pool like `b.g` is
        let worst_slice = users
            .iter()
            .map(|u| allocator::solve(&hslice, &[u.clone()]).g[0] / hn as f64)
            .fold(f64::INFINITY, f64::min);
        for i in 0..hn {
            assert!(
                b.g[i] >= worst_slice - 1e-6,
                "seed {seed}: user {i} share {:.6} < worst slice {:.6}",
                b.g[i],
                worst_slice
            );
        }
    }
}

/// Scheduler-level conservation invariants on a randomized simulation
/// (the engine is exercised end-to-end in `integration.rs`; here we
/// assert the invariant family proptest would: usage accounting closes).
#[test]
fn sim_conservation_randomized() {
    use drfh::sched::BestFitDrfh;
    use drfh::sim::{run, SimOpts};
    use drfh::workload::{GoogleLikeConfig, TraceGenerator};
    for seed in 0..6u64 {
        let mut rng = Pcg32::seeded(10_000 + seed);
        let cluster = Cluster::google_sample(30 + rng.below(40), &mut rng);
        let gen = TraceGenerator::new(GoogleLikeConfig {
            users: 4 + rng.below(8),
            duration: 3_000.0,
            jobs_per_user: 4.0,
            max_tasks_per_job: 60,
            ..Default::default()
        });
        let trace = gen.generate(seed * 17 + 3);
        let horizon = 2_000.0 + rng.uniform(0.0, 3_000.0);
        let r = run(
            cluster,
            &trace,
            Box::new(BestFitDrfh::default()),
            SimOpts {
                horizon,
                sample_dt: 50.0,
                track_user_series: false,
                ..SimOpts::default()
            },
        );
        assert!(r.tasks_completed <= r.tasks_placed);
        assert!(r.tasks_placed <= trace.total_tasks());
        let done: usize = r.user_tasks.iter().map(|u| u.completed).sum();
        assert_eq!(done, r.tasks_completed, "seed {seed}");
        let submitted: usize =
            r.user_tasks.iter().map(|u| u.submitted).sum();
        assert!(submitted <= trace.total_tasks());
        for &v in r.cpu_util.v.iter().chain(&r.mem_util.v) {
            assert!(
                (0.0..=1.0 + 1e-9).contains(&v),
                "seed {seed}: utilization out of range: {v}"
            );
        }
    }
}

/// Retry-backoff determinism (fault layer, satellite): the delay is a
/// pure function of `(seed, task, attempt)` — bit-identical on
/// re-evaluation, varying with every input, nominally doubling per
/// attempt up to the cap, inside the documented jitter band, and
/// exactly exponential with jitter disabled. Randomized over policies
/// the same way the fluid properties sweep seeds.
#[test]
fn retry_backoff_is_pure_and_bounded() {
    use drfh::sim::RetryPolicy;
    for seed in 0..30u64 {
        let mut rng = Pcg32::seeded(20_000 + seed);
        let pol = RetryPolicy {
            max_attempts: 1 + rng.below(8) as u32,
            base: rng.uniform(1.0, 120.0),
            cap: rng.uniform(300.0, 7_200.0),
            jitter: rng.uniform(0.0, 1.0),
        };
        let plan_seed = rng.below(1 << 30) as u64;
        for probe in 0..20u64 {
            let task = rng.below(1 << 20) as u64;
            let attempt = 1 + rng.below(12) as u32;
            let d = pol.backoff(plan_seed, task, attempt);
            // pure: same inputs, same bits
            assert_eq!(
                d.to_bits(),
                pol.backoff(plan_seed, task, attempt).to_bits(),
                "seed {seed} probe {probe}: backoff not reproducible"
            );
            // banded: nominal <= d < nominal * (1 + jitter)
            let nominal = (pol.base
                * (attempt.saturating_sub(1).min(63) as f64).exp2())
            .min(pol.cap);
            assert!(
                d >= nominal && d <= nominal * (1.0 + pol.jitter),
                "seed {seed} probe {probe}: {d} outside \
                 [{nominal}, {})",
                nominal * (1.0 + pol.jitter)
            );
            // input sensitivity: with jitter on, a different task or
            // plan seed draws from an unrelated stream
            if pol.jitter > 1e-3 {
                assert_ne!(
                    d.to_bits(),
                    pol.backoff(plan_seed, task ^ 1, attempt).to_bits(),
                    "seed {seed} probe {probe}: task did not move the draw"
                );
                assert_ne!(
                    d.to_bits(),
                    pol.backoff(plan_seed ^ 1, task, attempt).to_bits(),
                    "seed {seed} probe {probe}: seed did not move the draw"
                );
            }
        }
        // monotone nominal growth until the cap binds, then flat
        let exact = RetryPolicy { jitter: 0.0, ..pol };
        let mut prev = 0.0f64;
        for attempt in 1..=16u32 {
            let d = exact.backoff(plan_seed, 7, attempt);
            assert!(
                d >= prev,
                "seed {seed}: zero-jitter backoff not monotone"
            );
            assert!(d <= exact.cap, "seed {seed}: cap violated");
            let want = (exact.base
                * (attempt.saturating_sub(1) as f64).exp2())
            .min(exact.cap);
            assert_eq!(
                d, want,
                "seed {seed}: zero-jitter backoff not exactly exponential"
            );
            prev = d;
        }
    }
}

/// Churn-plan canonical form (churn satellite): on randomized
/// generator configs, every compiled plan alternates per user starting
/// from its initial presence — an absent-at-start user's first event
/// is a join, a present user's is a leave, and no transition is
/// redundant — with events time-sorted inside `[0, horizon)` and
/// `absent_at_start` sorted and deduplicated.
#[test]
fn churn_plans_are_canonical_randomized() {
    use drfh::workload::{generate_churn, ChurnGenConfig};
    for seed in 0..30u64 {
        let mut rng = Pcg32::seeded(30_000 + seed);
        let n = 2 + rng.below(40);
        let horizon = rng.uniform(2_000.0, 20_000.0);
        let cfg = ChurnGenConfig {
            leave_rate: rng.uniform(0.0, 2e-3),
            rejoin_rate: rng.uniform(1e-4, 2e-3),
            absent_frac: rng.uniform(0.0, 0.6),
            flash_at: (rng.f64() < 0.5)
                .then(|| rng.uniform(0.0, horizon)),
            flash_fraction: rng.uniform(0.05, 0.5),
            flash_hold: rng.uniform(0.0, horizon / 2.0),
            diurnal_amp: rng.uniform(0.0, 1.0),
            diurnal_period: rng.uniform(1_000.0, 90_000.0),
        };
        let plan = generate_churn(&cfg, n, horizon, seed);
        assert!(
            plan.absent_at_start.windows(2).all(|w| w[0] < w[1]),
            "seed {seed}: absent_at_start not sorted/deduped"
        );
        let mut present = vec![true; n];
        for &u in &plan.absent_at_start {
            assert!(u < n, "seed {seed}: absentee out of range");
            present[u] = false;
        }
        let mut prev = 0.0f64;
        for e in &plan.events {
            assert!(e.user < n, "seed {seed}: event user out of range");
            assert!(
                e.time >= prev && e.time >= 0.0 && e.time < horizon,
                "seed {seed}: event at {} outside order/horizon",
                e.time
            );
            assert_ne!(
                e.join, present[e.user],
                "seed {seed}: redundant transition for user {} at {}",
                e.user, e.time
            );
            present[e.user] = e.join;
            prev = e.time;
        }
    }
}

/// Stream isolation (churn satellite): the churn processes draw from
/// dedicated RNG streams, so (a) the initial-absence draw — the first
/// draw on each per-user stream — is invariant under every other
/// churn knob, (b) renewal transitions before the flash instant are
/// bitwise unchanged by enabling the flash (its cohort shuffle lives
/// on its own stream), and (c) trace and fault generation are bitwise
/// unchanged by churn generation running in between.
#[test]
fn churn_streams_are_isolated() {
    use drfh::workload::{
        generate_churn, generate_faults, ChurnGenConfig, FaultGenConfig,
        GoogleLikeConfig, TraceGenerator,
    };
    let horizon = 20_000.0;
    let base = ChurnGenConfig {
        leave_rate: 3e-4,
        absent_frac: 0.3,
        ..ChurnGenConfig::default()
    };
    let flash_at = 6_000.0;
    let flashy = ChurnGenConfig {
        flash_at: Some(flash_at),
        flash_fraction: 0.4,
        flash_hold: 2_000.0,
        ..base.clone()
    };
    let loud = ChurnGenConfig {
        leave_rate: 2e-3,
        rejoin_rate: 1e-3,
        diurnal_amp: 0.8,
        ..flashy.clone()
    };
    for seed in 0..10u64 {
        let a = generate_churn(&base, 64, horizon, seed);
        let b = generate_churn(&flashy, 64, horizon, seed);
        let c = generate_churn(&loud, 64, horizon, seed);
        // (a) same absentees no matter what the other processes do
        assert_eq!(
            a.absent_at_start, b.absent_at_start,
            "seed {seed}: flash moved the initial-absence draw"
        );
        assert_eq!(
            a.absent_at_start, c.absent_at_start,
            "seed {seed}: rates moved the initial-absence draw"
        );
        // (b) identical renewal prefix before the flash fires
        let pre = |p: &drfh::sim::ChurnPlan| {
            p.events
                .iter()
                .filter(|e| e.time < flash_at)
                .copied()
                .collect::<Vec<_>>()
        };
        assert_eq!(
            pre(&a),
            pre(&b),
            "seed {seed}: flash perturbed pre-flash renewal events"
        );
    }
    // (c) pure-function discipline: regenerating the trace and the
    // fault plan after compiling a churn plan reproduces them bitwise
    let gen = TraceGenerator::new(GoogleLikeConfig {
        users: 12,
        duration: 8_000.0,
        jobs_per_user: 3.0,
        ..Default::default()
    });
    let fcfg = FaultGenConfig {
        crash_rate: 5e-5,
        mean_downtime: 300.0,
        ..FaultGenConfig::default()
    };
    let t1 = gen.generate(17);
    let f1 = generate_faults(&fcfg, 40, 8_000.0, 17);
    let churn = generate_churn(&loud, 12, 8_000.0, 17);
    assert!(!churn.is_empty(), "isolation probe must actually churn");
    let t2 = gen.generate(17);
    let f2 = generate_faults(&fcfg, 40, 8_000.0, 17);
    assert_eq!(f1, f2, "churn generation perturbed the fault plan");
    assert_eq!(t1.jobs.len(), t2.jobs.len());
    assert_eq!(t1.total_tasks(), t2.total_tasks());
    for (x, y) in t1.jobs.iter().zip(&t2.jobs) {
        assert_eq!(x.submit.to_bits(), y.submit.to_bits());
        assert_eq!(x.user, y.user);
    }
    for (x, y) in t1.users.iter().zip(&t2.users) {
        assert_eq!(x.demand[0].to_bits(), y.demand[0].to_bits());
        assert_eq!(x.demand[1].to_bits(), y.demand[1].to_bits());
    }
}

/// Flash-crowd accounting (churn satellite): with both renewal rates
/// off, the flash is the whole plan — the cohort is exactly
/// `min(clamp(flash_fraction · n, 1, n), #absent)` users, every
/// member was absent at the flash instant, and each join pairs with
/// an in-horizon hold departure (or none when `flash_hold` is 0).
#[test]
fn flash_crowd_counts_randomized() {
    use drfh::workload::{generate_churn, ChurnGenConfig};
    for seed in 0..20u64 {
        let mut rng = Pcg32::seeded(31_000 + seed);
        let n = 5 + rng.below(60);
        let frac = rng.uniform(0.05, 0.9);
        let hold = if rng.f64() < 0.5 {
            0.0
        } else {
            rng.uniform(100.0, 5_000.0)
        };
        let at = 4_000.0;
        let horizon = 10_000.0;
        let cfg = ChurnGenConfig {
            leave_rate: 0.0,
            rejoin_rate: 0.0,
            absent_frac: rng.uniform(0.1, 0.9),
            flash_at: Some(at),
            flash_fraction: frac,
            flash_hold: hold,
            diurnal_amp: 0.0,
            diurnal_period: 86_400.0,
        };
        let plan = generate_churn(&cfg, n, horizon, 500 + seed);
        let want = ((frac * n as f64) as usize).clamp(1, n);
        let joins: Vec<usize> = plan
            .events
            .iter()
            .filter(|e| e.join && e.time == at)
            .map(|e| e.user)
            .collect();
        assert_eq!(
            joins.len(),
            want.min(plan.absent_at_start.len()),
            "seed {seed}: cohort size off (want {want}, {} absent)",
            plan.absent_at_start.len()
        );
        for &u in &joins {
            assert!(
                plan.initially_absent(u),
                "seed {seed}: flash joiner {u} was never absent"
            );
        }
        let hold_leaves = plan
            .events
            .iter()
            .filter(|e| !e.join && e.time == at + hold)
            .count();
        if hold > 0.0 && at + hold < horizon {
            assert_eq!(
                hold_leaves,
                joins.len(),
                "seed {seed}: flash joins without hold departures"
            );
            assert_eq!(plan.events.len(), 2 * joins.len());
        } else {
            assert_eq!(
                plan.events.iter().filter(|e| !e.join).count(),
                0,
                "seed {seed}: departures without a hold"
            );
            assert_eq!(plan.events.len(), joins.len());
        }
    }
}

/// A departure for a user that was never admitted is a strict no-op
/// (churn satellite): the hand-built redundant `Leave` — bypassing
/// the canonicalizer — consumes a queue slot and splits a wave, but
/// the engine's presence guard must keep the whole `SimReport`
/// bit-identical to the plan without it, sharded or not.
#[test]
fn never_admitted_departure_is_a_noop() {
    use drfh::sched::BestFitDrfh;
    use drfh::sim::{run, ChurnEvent, ChurnPlan, ShardCount, SimOpts};
    use drfh::workload::{GoogleLikeConfig, TraceGenerator};
    for seed in 0..4u64 {
        let mut rng = Pcg32::seeded(40_000 + seed);
        let cluster = Cluster::google_sample(20 + rng.below(20), &mut rng);
        let trace = TraceGenerator::new(GoogleLikeConfig {
            users: 5,
            duration: 3_000.0,
            jobs_per_user: 4.0,
            ..Default::default()
        })
        .generate(seed);
        let absent = ChurnPlan {
            seed: 1,
            absent_at_start: vec![2],
            events: vec![],
        };
        let noop = ChurnPlan {
            seed: 1,
            absent_at_start: vec![2],
            events: vec![ChurnEvent {
                time: 1_000.0,
                user: 2,
                join: false,
            }],
        };
        for shards in [1usize, 3] {
            let mk = |churn: &ChurnPlan| SimOpts {
                horizon: 3_000.0,
                sample_dt: 50.0,
                track_user_series: false,
                churn: churn.clone(),
                shards: ShardCount::Fixed(shards),
                ..SimOpts::default()
            };
            let ra = run(
                cluster.clone(),
                &trace,
                Box::new(BestFitDrfh::default()),
                mk(&absent),
            );
            let rb = run(
                cluster.clone(),
                &trace,
                Box::new(BestFitDrfh::default()),
                mk(&noop),
            );
            assert_eq!(
                ra, rb,
                "seed {seed} S={shards}: redundant departure perturbed \
                 the run"
            );
            assert_eq!(
                rb.user_leaves, 0,
                "seed {seed} S={shards}: no-op departure was counted"
            );
        }
    }
}

/// Online index maintenance matches a rebuilt scan (churn satellite):
/// random join/leave presence toggles — notified through the
/// `on_user_join`/`on_user_leave` hooks exactly like the engine —
/// interleaved with share-moving placements must keep the classed and
/// per-user incremental indexes pick-identical to the naive linear
/// scan, and every online structure must survive the
/// `audit_indices` cross-check against a fresh rebuild.
#[test]
fn online_index_updates_match_rebuilt_scan() {
    use drfh::sched::{BestFitDrfh, Scheduler, UserState};
    for seed in 0..20u64 {
        let mut rng = Pcg32::seeded(50_000 + seed);
        let cluster = Cluster::google_sample(3 + rng.below(6), &mut rng);
        let n = 3 + rng.below(8);
        let mut users: Vec<UserState> = (0..n)
            .map(|_| {
                let running = rng.below(40);
                let dom_delta = rng.uniform(0.001, 0.05);
                UserState {
                    demand: ResVec::cpu_mem(
                        rng.uniform(0.05, 0.3),
                        rng.uniform(0.05, 0.3),
                    ),
                    weight: 1.0,
                    pending: 1 + rng.below(10),
                    running,
                    dom_share: running as f64 * dom_delta,
                    usage: ResVec::zeros(2),
                    dom_delta,
                }
            })
            .collect();
        let mut eligible = vec![true; n];
        let mut naive = BestFitDrfh::naive();
        let mut indexed = vec![
            ("classed", BestFitDrfh::default()),
            ("per_user", BestFitDrfh::per_user()),
        ];
        for round in 0..30 {
            let want = naive.pick(&cluster, &users, &eligible);
            for (label, s) in indexed.iter_mut() {
                let got = s.pick(&cluster, &users, &eligible);
                assert_eq!(
                    got, want,
                    "seed {seed} round {round}: {label} diverged from \
                     the rebuilt scan"
                );
                let audit = s.audit_indices(&cluster, &users, &eligible);
                assert!(
                    audit.is_ok(),
                    "seed {seed} round {round}: {label} index drifted: \
                     {audit:?}"
                );
            }
            // random presence toggle, engine-style notification
            let u = rng.below(n);
            if eligible[u] {
                eligible[u] = false;
                naive.on_user_leave(u);
                for (_, s) in indexed.iter_mut() {
                    s.on_user_leave(u);
                }
            } else {
                eligible[u] = true;
                naive.on_user_join(u);
                for (_, s) in indexed.iter_mut() {
                    s.on_user_join(u);
                }
            }
            // occasionally move a share the way a placement would
            if rng.f64() < 0.5 {
                let v = rng.below(n);
                if users[v].pending > 0 && eligible[v] {
                    users[v].pending -= 1;
                    users[v].running += 1;
                    users[v].dom_share =
                        users[v].running as f64 * users[v].dom_delta;
                    naive.on_place(v, 0);
                    for (_, s) in indexed.iter_mut() {
                        s.on_place(v, 0);
                    }
                }
            }
        }
    }
}
