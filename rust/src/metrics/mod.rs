//! Simulation metrics: everything the paper's evaluation section plots.
//!
//! ## §Perf: bounded-memory mode
//!
//! At trace scale (10⁶ tasks and beyond) the seed's metrics grew
//! without bound: one [`JobRecord`] per completed job and one sample
//! per `sample_dt` per tracked series. [`MetricsMode::Streaming`]
//! caps both: time series decimate to a fixed point budget
//! ([`TimeSeries::decimate`] — drop every other point, doubling the
//! effective stride, so the retained grid still spans the whole
//! horizon and stays within plotting tolerance), and job completions
//! fold into [`JobStats`] — O(1)-memory count/mean/min/max
//! ([`crate::util::stats::StreamStats`]) plus P² completion-time
//! percentiles ([`crate::util::stats::P2Quantile`]) overall and per
//! Fig. 6b size bucket — instead of materializing `jobs`. Peak RSS is
//! then ~flat in task count; `benches/sim_scale.rs` records the
//! retained-point counts next to its throughput numbers.
//!
//! Per-user share *trajectories* (Fig. 4) get the same treatment in
//! [`shares`]: a [`ShareSketch`] holds each user's dominant-share
//! series under a fixed point budget with exact streaming summaries,
//! so trajectory reporting survives the ROADMAP's millions of users
//! (see [`crate::sim::SimOpts::share_sketch`]).

pub mod shares;

pub use shares::ShareSketch;

use crate::util::stats;
use crate::util::stats::{P2Quantile, StreamStats};

/// How the engine records per-run measurements (see
/// [`crate::sim::SimOpts::metrics`]).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum MetricsMode {
    /// Keep every sample and every completed-job record (the seed
    /// behavior; what the figure harnesses need).
    #[default]
    Full,
    /// Bounded memory: series decimate to at most `series_cap` points
    /// (0 = unbounded) and job completions stream into
    /// [`JobStats`] only — `SimReport::jobs` stays empty.
    Streaming { series_cap: usize },
}

impl MetricsMode {
    /// Streaming with the default point budget (2048 points ≈ 16 KiB
    /// per series — comfortably above plotting resolution).
    pub fn streaming() -> Self {
        MetricsMode::Streaming { series_cap: 2048 }
    }
}

/// A sampled time series (e.g. utilization over time, Fig. 5).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TimeSeries {
    pub t: Vec<f64>,
    pub v: Vec<f64>,
}

impl TimeSeries {
    pub fn push(&mut self, t: f64, v: f64) {
        self.t.push(t);
        self.v.push(v);
    }

    /// Halve the retained points (keep indices 0, 2, 4, …), doubling
    /// the effective sample stride. The time span is preserved up to
    /// one stride at the tail; repeated application under a fixed cap
    /// keeps memory bounded while the grid stays horizon-spanning.
    pub fn decimate(&mut self) {
        let keep_every_other = |v: &mut Vec<f64>| {
            let mut i = 0usize;
            v.retain(|_| {
                let keep = i % 2 == 0;
                i += 1;
                keep
            });
        };
        keep_every_other(&mut self.t);
        keep_every_other(&mut self.v);
    }

    /// Enforce a point budget (0 = unbounded): decimate whenever the
    /// series outgrows `cap`. Bounded between `cap / 2` and `cap`
    /// points at all times.
    pub fn enforce_cap(&mut self, cap: usize) {
        if cap > 0 && self.t.len() > cap {
            self.decimate();
        }
    }

    pub fn len(&self) -> usize {
        self.t.len()
    }

    pub fn is_empty(&self) -> bool {
        self.t.is_empty()
    }

    /// Time-weighted average over the sampled horizon.
    pub fn time_avg(&self) -> f64 {
        if self.t.len() < 2 {
            return stats::mean(&self.v);
        }
        let mut area = 0.0;
        for i in 1..self.t.len() {
            area += self.v[i - 1] * (self.t[i] - self.t[i - 1]);
        }
        let span = self.t[self.t.len() - 1] - self.t[0];
        if span > 0.0 {
            area / span
        } else {
            stats::mean(&self.v)
        }
    }

    /// Average of samples within [lo, hi].
    pub fn window_avg(&self, lo: f64, hi: f64) -> f64 {
        let vals: Vec<f64> = self
            .t
            .iter()
            .zip(&self.v)
            .filter(|(&t, _)| t >= lo && t <= hi)
            .map(|(_, &v)| v)
            .collect();
        stats::mean(&vals)
    }
}

/// A completed job record.
#[derive(Clone, Debug, PartialEq)]
pub struct JobRecord {
    pub job: usize,
    pub user: usize,
    pub num_tasks: usize,
    pub submit: f64,
    pub finish: f64,
}

impl JobRecord {
    pub fn completion_time(&self) -> f64 {
        self.finish - self.submit
    }
}

/// Streaming job-completion statistics, maintained by the engine in
/// every metrics mode (they are O(1) memory and cheap): completion
/// time moments and P² percentiles, overall and per Fig. 6b job-size
/// bucket. In [`MetricsMode::Streaming`] they are the *only*
/// job-completion output.
#[derive(Clone, Debug, PartialEq)]
pub struct JobStats {
    /// Completion-time (finish − submit) moments over completed jobs.
    pub jct: StreamStats,
    /// P² estimates of the 50th / 90th / 99th JCT percentiles.
    pub jct_p50: P2Quantile,
    pub jct_p90: P2Quantile,
    pub jct_p99: P2Quantile,
    /// Tasks-per-completed-job moments.
    pub tasks_per_job: StreamStats,
    /// JCT moments per [`JCT_BUCKETS`] size class.
    pub jct_by_bucket: Vec<StreamStats>,
}

impl Default for JobStats {
    fn default() -> Self {
        JobStats {
            jct: StreamStats::default(),
            jct_p50: P2Quantile::new(0.50),
            jct_p90: P2Quantile::new(0.90),
            jct_p99: P2Quantile::new(0.99),
            tasks_per_job: StreamStats::default(),
            jct_by_bucket: vec![StreamStats::default(); JCT_BUCKETS.len()],
        }
    }
}

impl JobStats {
    /// Fold in one completed job.
    pub fn record(&mut self, jct: f64, num_tasks: usize) {
        self.jct.push(jct);
        self.jct_p50.push(jct);
        self.jct_p90.push(jct);
        self.jct_p99.push(jct);
        self.tasks_per_job.push(num_tasks as f64);
        if let Some(b) = JCT_BUCKETS
            .iter()
            .position(|&(lo, hi)| num_tasks >= lo && num_tasks <= hi)
        {
            self.jct_by_bucket[b].push(jct);
        }
    }

    /// Completed-job count.
    pub fn count(&self) -> u64 {
        self.jct.count()
    }
}

/// Per-user task accounting for completion-ratio figures (Fig. 7/8).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct UserTaskCounts {
    pub submitted: usize,
    pub completed: usize,
}

impl UserTaskCounts {
    pub fn ratio(&self) -> f64 {
        if self.submitted == 0 {
            1.0
        } else {
            self.completed as f64 / self.submitted as f64
        }
    }
}

/// Job-size buckets used by Fig. 6b.
pub const JCT_BUCKETS: [(usize, usize); 5] =
    [(1, 10), (11, 50), (51, 100), (101, 500), (501, usize::MAX)];

/// Label for a Fig. 6b bucket.
pub fn bucket_label(b: (usize, usize)) -> String {
    if b.1 == usize::MAX {
        format!(">{}", b.0 - 1)
    } else {
        format!("{}-{}", b.0, b.1)
    }
}

/// Mean completion-time reduction of `ours` vs `base` per job-size
/// bucket, over jobs completed in both (paper Fig. 6b methodology).
pub fn jct_reduction_by_bucket(
    ours: &[JobRecord],
    base: &[JobRecord],
) -> Vec<(String, f64, usize)> {
    use std::collections::HashMap;
    // order-independent HashMap use: keyed `get` lookups only (the
    // iteration below runs over `ours`, in record order)
    let by_id: HashMap<usize, &JobRecord> =
        base.iter().map(|j| (j.job, j)).collect();
    JCT_BUCKETS
        .iter()
        .map(|&(lo, hi)| {
            let mut reductions = Vec::new();
            for j in ours {
                if j.num_tasks < lo || j.num_tasks > hi {
                    continue;
                }
                if let Some(b) = by_id.get(&j.job) {
                    let ours_t = j.completion_time();
                    let base_t = b.completion_time();
                    if base_t > 0.0 {
                        reductions.push(1.0 - ours_t / base_t);
                    }
                }
            }
            (
                bucket_label((lo, hi)),
                stats::mean(&reductions),
                reductions.len(),
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_avg_weighted() {
        let mut ts = TimeSeries::default();
        ts.push(0.0, 1.0);
        ts.push(1.0, 0.0); // value 1.0 held for [0,1)
        ts.push(3.0, 0.0); // value 0.0 held for [1,3)
        assert!((ts.time_avg() - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn window_avg_filters() {
        let mut ts = TimeSeries::default();
        for i in 0..10 {
            ts.push(i as f64, i as f64);
        }
        assert!((ts.window_avg(5.0, 9.0) - 7.0).abs() < 1e-12);
    }

    #[test]
    fn completion_ratio() {
        let c = UserTaskCounts { submitted: 4, completed: 3 };
        assert!((c.ratio() - 0.75).abs() < 1e-12);
        assert_eq!(UserTaskCounts::default().ratio(), 1.0);
    }

    #[test]
    fn decimation_bounds_memory_and_preserves_shape() {
        let mut ts = TimeSeries::default();
        let cap = 64;
        for i in 0..10_000 {
            ts.push(i as f64, (i % 100) as f64 / 100.0);
            ts.enforce_cap(cap);
        }
        assert!(ts.len() <= cap, "cap violated: {}", ts.len());
        assert!(ts.len() > cap / 2, "over-decimated: {}", ts.len());
        // grid still spans the horizon (first point kept exactly,
        // tail within one post-decimation stride)
        assert_eq!(ts.t[0], 0.0);
        assert!(*ts.t.last().unwrap() > 9_000.0);
        // strictly increasing grid survives decimation
        for w in ts.t.windows(2) {
            assert!(w[0] < w[1]);
        }
        // the time average stays within plotting tolerance of the
        // exact (undecimated) value, 0.495 (decimation aliases the
        // period-100 signal slightly — that bias is the accepted cost)
        assert!((ts.time_avg() - 0.495).abs() < 0.03, "{}", ts.time_avg());
    }

    #[test]
    fn decimate_keeps_even_indices() {
        let mut ts = TimeSeries::default();
        for i in 0..5 {
            ts.push(i as f64, 10.0 * i as f64);
        }
        ts.decimate();
        assert_eq!(ts.t, vec![0.0, 2.0, 4.0]);
        assert_eq!(ts.v, vec![0.0, 20.0, 40.0]);
        // cap 0 = unbounded: no decimation however long it grows
        let mut unb = TimeSeries::default();
        for i in 0..100 {
            unb.push(i as f64, 0.0);
            unb.enforce_cap(0);
        }
        assert_eq!(unb.len(), 100);
    }

    #[test]
    fn job_stats_stream_matches_records() {
        use crate::util::Pcg32;
        let mut rng = Pcg32::seeded(99);
        let mut js = JobStats::default();
        let mut jcts = Vec::new();
        for _ in 0..2_000 {
            let jct = rng.uniform(1.0, 5_000.0);
            let tasks = 1 + rng.below(600);
            js.record(jct, tasks);
            jcts.push(jct);
        }
        assert_eq!(js.count(), 2_000);
        assert!((js.jct.mean() - stats::mean(&jcts)).abs() < 1e-9);
        let exact_p90 = stats::percentile(&jcts, 90.0);
        let rel = (js.jct_p90.quantile() - exact_p90).abs() / exact_p90;
        assert!(rel < 0.1, "P² p90 {} vs {}", js.jct_p90.quantile(), exact_p90);
        // every job landed in exactly one bucket
        let bucketed: u64 =
            js.jct_by_bucket.iter().map(|b| b.count()).sum();
        assert_eq!(bucketed, 2_000);
    }

    #[test]
    fn buckets_and_reduction() {
        let ours = vec![JobRecord {
            job: 0,
            user: 0,
            num_tasks: 5,
            submit: 0.0,
            finish: 50.0,
        }];
        let base = vec![JobRecord {
            job: 0,
            user: 0,
            num_tasks: 5,
            submit: 0.0,
            finish: 100.0,
        }];
        let red = jct_reduction_by_bucket(&ours, &base);
        assert_eq!(red[0].2, 1);
        assert!((red[0].1 - 0.5).abs() < 1e-12);
        assert_eq!(red[1].2, 0);
        assert_eq!(bucket_label((501, usize::MAX)), ">500");
    }
}
