//! §Perf: class-keyed user state — per-event scheduler work that
//! scales with *distinct demand classes*, not user count.
//!
//! Google-like traces draw per-user demands from a handful of profile
//! classes ([`crate::workload::DemandTable`] proves it at trace-build
//! time), and the PS-DSF observation (Khamse-Ashari et al., 2017)
//! is that users with identical demand vectors are interchangeable to
//! the scheduler except for *how much they are currently running*.
//! This module exploits that for the DRFH progressive-filling
//! selection of paper Sec. V-B:
//!
//! * [`DemandClasses`] interns the scheduler-visible demand rows by
//!   exact bit pattern into dense `u32` class ids (the scheduler-side
//!   sibling of [`crate::workload::DemandTable`], which interns
//!   [`crate::workload::UserSpec`] rows at trace build). Everything
//!   derived from a demand row alone — Best-Fit H-score ratios,
//!   feasibility, blocked-index fit keys — is computed once per class
//!   and shared ([`crate::sched::index::PlacementIndex`] and
//!   [`crate::sched::index::BlockedIndex`] key their structures on
//!   these ids).
//!
//! * [`ClassedShareIndex`] replaces the per-user lazy
//!   [`crate::sched::index::ShareHeap`] for user selection. Users are
//!   grouped by the *pair* `(dom_delta, effective_weight)` (bit-exact
//!   interning): inside such a group the weighted share key
//!   `share_key = (running · dom_delta) / effective_weight` is a
//!   strictly increasing function of the integer `running`, so the
//!   group's lowest-share user is simply its `(running, user)`
//!   minimum — an exact, eagerly maintained `BTreeSet` ordered by
//!   small integers, no float heap churn. A pick then compares one
//!   candidate per *group* (a handful at trace scale) instead of
//!   popping through a heap with one entry per *user*.
//!
//! ## Decision parity
//!
//! The selection is bit-identical to [`crate::sched::min_share_user`]
//! (and therefore to the per-user `ShareHeap` path) under the engine
//! invariants that already hold everywhere:
//!
//! 1. `dom_share == running as f64 * dom_delta` bit-exactly (the
//!    engine recomputes it on every transition; asserted by
//!    `tests/engine_parity.rs::dom_share_stays_exact_over_long_runs`);
//! 2. demands are strictly positive and pool capacities positive
//!    ([`crate::workload::Trace::validate`]), so `dom_delta` is a
//!    finite positive number, and weights are `>= 0` **and finite**
//!    (also validate-enforced), so
//!    [`crate::sched::effective_weight`] is finite positive;
//!    degenerate constants outside those bounds collapse the group to
//!    index order, which is exactly the `(key, index)` tie-break when
//!    every key is the same constant;
//! 3. running counts stay far below 2^52, so distinct counts map to
//!    distinct key floats (monotonicity survives rounding).
//!
//! Groups whose constants violate (2) (possible only in hand-built
//! unit fixtures) degrade to index-ordered groups; the randomized
//! parity suites in `tests/engine_parity.rs` pin the classed path
//! against both the per-user index and the naive scans, including
//! zero-weight mixes.

use crate::cluster::ResVec;
use crate::sched::index::ShareHeap;
use crate::sched::{effective_weight, UserState};
use std::collections::{BTreeSet, HashMap};

/// Interned demand rows over the scheduler's `UserState` table: dense
/// `u32` class ids keyed by the exact bit pattern of the demand
/// vector, so ulp-different (or `-0.0` vs `0.0`) rows never alias and
/// per-class constants are bit-identical to their per-user
/// counterparts.
#[derive(Clone, Debug, Default)]
pub struct DemandClasses {
    /// Class id per user.
    pub class_of: Vec<u32>,
    /// Distinct demand rows, indexed by class id.
    pub rows: Vec<ResVec>,
}

impl DemandClasses {
    /// Intern `users`' demand rows (the one shared bit-exact
    /// interning implementation, [`crate::workload::intern_rows`]).
    pub fn build(users: &[UserState]) -> Self {
        let (rows, class_of) =
            crate::workload::intern_rows(users.iter().map(|u| &u.demand));
        DemandClasses { class_of, rows }
    }

    /// One class per user (no sharing) — the per-user reference
    /// layout, kept so the legacy path is a constructor flag away.
    pub fn identity(users: &[UserState]) -> Self {
        DemandClasses {
            class_of: (0..users.len() as u32).collect(),
            rows: users.iter().map(|u| u.demand).collect(),
        }
    }

    /// Online user add (churn layer): intern one appended user's
    /// demand row against the existing classes by exact bit pattern —
    /// the same discipline as the batch build — returning its class
    /// id (fresh rows get a fresh id). Equivalent to rebuilding over
    /// the extended user set (pinned by `tests/properties.rs`). The
    /// row scan is linear, but rows number in the tens where users
    /// number in the millions, and joins are rare events.
    pub fn add_user(&mut self, demand: &ResVec) -> u32 {
        let same_bits = |row: &ResVec| {
            row.dims() == demand.dims()
                && (0..row.dims())
                    .all(|r| row[r].to_bits() == demand[r].to_bits())
        };
        let c = match self.rows.iter().position(same_bits) {
            Some(c) => c as u32,
            None => {
                self.rows.push(*demand);
                (self.rows.len() - 1) as u32
            }
        };
        self.class_of.push(c);
        c
    }

    /// Number of distinct classes.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
}

// ----------------------------------------------------- share grouping

/// Sentinel: user currently has no entry in its group's set.
const NOT_STORED: u32 = u32::MAX;

/// Which per-user key a [`ClassedShareIndex`] ranks by.
///
/// The group machinery only needs the key to be
/// `(running · constant_a) / constant_b` for per-user constants; both
/// supported keys have that shape, so the same exact integer ordering
/// applies.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KeyMode {
    /// The weighted dominant share, `(running · dom_delta) /
    /// effective_weight` — bit-identical to [`UserState::share_key`]
    /// under the engine's `dom_share = running * dom_delta` invariant
    /// (the DRFH policies).
    #[default]
    DomShare,
    /// The weighted running-*count*, `running / effective_weight` —
    /// the slot-scheduler key (1 task = 1 slot, demand ignored).
    /// Implemented as `dom_delta := 1.0`, so `running as f64 * 1.0`
    /// is bitwise `running as f64` and parity with the naive slot
    /// scan is exact.
    RunningOnly,
}

/// The exact ranking key of `u` under `mode` — the single definition
/// both the grouped sets and the embedded fallback heap rank by.
#[inline]
fn key_for(mode: KeyMode, u: &UserState) -> f64 {
    match mode {
        KeyMode::DomShare => u.share_key(),
        KeyMode::RunningOnly => {
            u.running as f64 / effective_weight(u.weight)
        }
    }
}

/// One `(dom_delta, effective_weight)` aggregation group: every member
/// shares the key constants, so the member order by `(run_key, user)`
/// IS the order by `(share_key, user)`.
struct ShareGroup {
    dom_delta: f64,
    eff_weight: f64,
    /// Schedulable members, ordered by `(run_key, user id)`.
    members: BTreeSet<(u32, u32)>,
}

impl ShareGroup {
    /// The integer ordering key standing in for `share_key`. When the
    /// constants are degenerate (non-positive or non-finite
    /// `dom_delta`, or a non-finite effective weight — impossible on
    /// validated traces) every member's true key collapses to the
    /// same constant, so the key here collapses too and the group
    /// orders by user id alone, matching the `(key, index)` tie-break.
    #[inline]
    fn run_key(&self, running: usize) -> u32 {
        if self.dom_delta > 0.0
            && self.dom_delta.is_finite()
            && self.eff_weight.is_finite()
        {
            debug_assert!((running as u64) < NOT_STORED as u64);
            running as u32
        } else {
            0
        }
    }

    /// The exact weighted share key of a member running `r` tasks —
    /// the same arithmetic, in the same order, as
    /// [`UserState::share_key`] under the engine's
    /// `dom_share = running * dom_delta` invariant.
    #[inline]
    fn share_key(&self, r: u32) -> f64 {
        (r as f64 * self.dom_delta) / self.eff_weight
    }
}

/// Class-keyed progressive-filling index: the lowest weighted
/// dominant-share schedulable user, maintained per
/// `(dom_delta, effective_weight)` group.
///
/// Drop-in replacement for the per-user
/// [`crate::sched::index::ShareHeap`] inside
/// [`crate::sched::index::IndexedCore`]; the module docs state the
/// preconditions under which the two are decision-identical (all hold
/// on validated traces).
///
/// A pick compares one candidate per *group*, so the aggregation only
/// pays off when groups hold several users each. The build therefore
/// self-selects: when interning finds fewer than ~2 users per group
/// (e.g. continuously distributed per-user weights), the instance
/// falls back to an embedded [`ShareHeap`] — the decision stream is
/// bit-identical either way, and the worst case is exactly the
/// per-user layout rather than an O(#groups) = O(n) scan per pick.
///
/// Like [`crate::sched::index::PlacementIndex`], an instance snapshots
/// one user set on first use: demand-derived constants and weights are
/// read once, at build.
#[derive(Default)]
pub struct ClassedShareIndex {
    built: bool,
    /// Ranking key (see [`KeyMode`]); fixed at construction.
    mode: KeyMode,
    group_of: Vec<u32>,
    groups: Vec<ShareGroup>,
    /// `run_key` under which each user is currently stored
    /// (`NOT_STORED` when absent — blocked, ineligible, or drained).
    stored: Vec<u32>,
    dirty: Vec<u32>,
    is_dirty: Vec<bool>,
    /// Per-user fallback when grouping does not aggregate (see the
    /// struct docs); `Some` disables the group machinery entirely.
    fallback: Option<ShareHeap>,
}

impl ClassedShareIndex {
    pub fn new() -> Self {
        Self::default()
    }

    /// Rank by weighted running-count instead of weighted dominant
    /// share ([`KeyMode::RunningOnly`]) — the slot-scheduler
    /// aggregation, grouping users by `effective_weight` alone.
    pub fn by_weight() -> Self {
        ClassedShareIndex { mode: KeyMode::RunningOnly, ..Self::default() }
    }

    /// Number of aggregation groups (testing / diagnostics; 0 when
    /// the instance fell back to the per-user heap).
    pub fn group_count(&self) -> usize {
        self.groups.len()
    }

    /// Did the build fall back to the embedded per-user heap?
    pub fn is_fallback(&self) -> bool {
        self.fallback.is_some()
    }

    fn rebuild(&mut self, users: &[UserState]) {
        let n = users.len();
        self.groups.clear();
        self.fallback = None;
        self.group_of = Vec::with_capacity(n);
        // order-independent HashMap use (lint hash-iter rule): keyed
        // `entry` lookups only, never iterated — group ids are assigned
        // by user order (first appearance), not by map order
        let mut seen: HashMap<(u64, u64), u32> = HashMap::new();
        for u in users {
            let w = effective_weight(u.weight);
            // RunningOnly is DomShare with dom_delta := 1.0 (exact:
            // `r as f64 * 1.0` is bitwise `r as f64`), which also
            // collapses the grouping to effective weight alone
            let delta = match self.mode {
                KeyMode::DomShare => u.dom_delta,
                KeyMode::RunningOnly => 1.0,
            };
            let key = (delta.to_bits(), w.to_bits());
            let g = *seen.entry(key).or_insert_with(|| {
                self.groups.push(ShareGroup {
                    dom_delta: delta,
                    eff_weight: w,
                    members: BTreeSet::new(),
                });
                (self.groups.len() - 1) as u32
            });
            self.group_of.push(g);
        }
        if self.groups.len() * 2 > n {
            // fewer than ~2 users per group: aggregation loses to the
            // per-user heap's O(log n) — use it directly (ShareHeap
            // starts with every user dirty, mirroring the build)
            self.groups.clear();
            self.fallback = Some(ShareHeap::new());
        }
        self.stored = vec![NOT_STORED; n];
        self.is_dirty = vec![true; n];
        self.dirty = (0..n as u32).collect();
        self.built = true;
    }

    /// Online user add (churn layer): append one user without a
    /// rebuild — intern its key constants against the existing groups
    /// bit-exactly (the same first-appearance id assignment as the
    /// batch rebuild) and mark it dirty, so the next
    /// refresh inserts it iff schedulable. Decisions equal a teardown
    /// and rebuild over the extended user set (pinned by
    /// `tests/properties.rs`); only the fallback-vs-grouped choice is
    /// frozen at the original build (a perf heuristic, not a
    /// decision input). Before the first build this is a no-op.
    pub fn add_user(&mut self, user: &UserState) {
        if !self.built {
            return; // the initial build snapshots the full user set
        }
        let n = self.group_of.len();
        let w = effective_weight(user.weight);
        let delta = match self.mode {
            KeyMode::DomShare => user.dom_delta,
            KeyMode::RunningOnly => 1.0,
        };
        if let Some(heap) = &mut self.fallback {
            self.group_of.push(0); // unused under the fallback heap
            self.stored.push(NOT_STORED);
            self.is_dirty.push(false);
            heap.mark_dirty(n);
            return;
        }
        let found = self.groups.iter().position(|g| {
            g.dom_delta.to_bits() == delta.to_bits()
                && g.eff_weight.to_bits() == w.to_bits()
        });
        let g = match found {
            Some(g) => g as u32,
            None => {
                self.groups.push(ShareGroup {
                    dom_delta: delta,
                    eff_weight: w,
                    members: BTreeSet::new(),
                });
                (self.groups.len() - 1) as u32
            }
        };
        self.group_of.push(g);
        self.stored.push(NOT_STORED);
        self.is_dirty.push(true);
        self.dirty.push(n as u32);
    }

    /// Note that `u`'s key or schedulability may have changed; the
    /// next [`ClassedShareIndex::refresh`] re-syncs it.
    pub fn mark_dirty(&mut self, u: usize) {
        if !self.built {
            return; // the initial build marks every user dirty
        }
        if u >= self.stored.len() {
            // user set grew under us — resnapshot at the next refresh
            self.built = false;
            return;
        }
        if let Some(heap) = &mut self.fallback {
            heap.mark_dirty(u);
            return;
        }
        if !self.is_dirty[u] {
            self.is_dirty[u] = true;
            self.dirty.push(u as u32);
        }
    }

    /// Drop `u` from its group (blocked-user protocol); it re-enters
    /// via [`ClassedShareIndex::mark_dirty`] + refresh.
    pub fn remove(&mut self, u: usize) {
        if !self.built || u >= self.stored.len() {
            return;
        }
        if let Some(heap) = &mut self.fallback {
            heap.remove(u);
            return;
        }
        if self.stored[u] != NOT_STORED {
            let g = self.group_of[u] as usize;
            self.groups[g].members.remove(&(self.stored[u], u as u32));
            self.stored[u] = NOT_STORED;
        }
    }

    /// Re-sync `u` against the current engine state — the classed
    /// equivalent of `ShareHeap::reinsert`, used mid-drain right after
    /// a commit (and by [`ClassedShareIndex::refresh`] for each dirty
    /// user).
    pub fn resync(
        &mut self,
        u: usize,
        users: &[UserState],
        eligible: &[bool],
    ) {
        debug_assert!(self.built && u < self.stored.len());
        let schedulable = eligible[u] && users[u].pending > 0;
        let mode = self.mode;
        if let Some(heap) = &mut self.fallback {
            heap.reinsert(u, key_for(mode, &users[u]), schedulable);
            return;
        }
        let g = self.group_of[u] as usize;
        let desired = if schedulable {
            self.groups[g].run_key(users[u].running)
        } else {
            NOT_STORED
        };
        if desired == self.stored[u] {
            return;
        }
        if self.stored[u] != NOT_STORED {
            self.groups[g].members.remove(&(self.stored[u], u as u32));
        }
        if desired != NOT_STORED {
            self.groups[g].members.insert((desired, u as u32));
        }
        self.stored[u] = desired;
    }

    /// Flush dirty users (building the group table on first use).
    pub fn refresh(&mut self, users: &[UserState], eligible: &[bool]) {
        if !self.built || self.group_of.len() != users.len() {
            self.rebuild(users);
        }
        let mode = self.mode;
        if let Some(heap) = &mut self.fallback {
            heap.refresh_with(users, eligible, |u| key_for(mode, u));
            return;
        }
        while let Some(u) = self.dirty.pop() {
            let u = u as usize;
            self.is_dirty[u] = false;
            self.resync(u, users, eligible);
        }
    }

    /// Current minimum-key schedulable user: the minimum over one
    /// candidate per group — O(#groups), not O(#users) (or the
    /// embedded heap's pop when the build fell back). Call
    /// [`ClassedShareIndex::refresh`] first.
    pub fn peek_min(
        &mut self,
        users: &[UserState],
        eligible: &[bool],
    ) -> Option<usize> {
        if let Some(heap) = &mut self.fallback {
            return heap.peek_min(users, eligible);
        }
        let mut best: Option<(f64, u32)> = None;
        for grp in &self.groups {
            let Some(&(r, u)) = grp.members.first() else {
                continue;
            };
            debug_assert!(
                eligible[u as usize] && users[u as usize].pending > 0,
                "stale classed entry for user {u}"
            );
            let key = grp.share_key(r);
            let better = match best {
                None => true,
                Some((bk, bu)) => {
                    key.total_cmp(&bk).then_with(|| u.cmp(&bu)).is_lt()
                }
            };
            if better {
                best = Some((key, u));
            }
        }
        best.map(|(_, u)| u as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::min_share_user;
    use crate::util::Pcg32;

    fn mk_user(
        demand: ResVec,
        weight: f64,
        pending: usize,
        running: usize,
        dom_delta: f64,
    ) -> UserState {
        UserState {
            demand,
            weight,
            pending,
            running,
            dom_share: running as f64 * dom_delta,
            usage: ResVec::zeros(2),
            dom_delta,
        }
    }

    #[test]
    fn demand_classes_intern_by_bits() {
        let d = ResVec::cpu_mem(0.2, 0.3);
        let users = vec![
            mk_user(d, 1.0, 1, 0, 0.01),
            mk_user(ResVec::cpu_mem(0.4, 0.1), 1.0, 1, 0, 0.02),
            mk_user(d, 2.0, 1, 0, 0.01),
        ];
        let c = DemandClasses::build(&users);
        assert_eq!(c.len(), 2);
        assert_eq!(c.class_of[0], c.class_of[2]);
        assert_ne!(c.class_of[0], c.class_of[1]);
        assert_eq!(c.rows[c.class_of[1] as usize], ResVec::cpu_mem(0.4, 0.1));
        let id = DemandClasses::identity(&users);
        assert_eq!(id.len(), 3);
        assert_eq!(id.class_of, vec![0, 1, 2]);
        assert!(!c.is_empty());
    }

    /// The classed index agrees with the linear scan through
    /// randomized churn of running counts, pending work, eligibility
    /// and blocking — with many users per (class, weight) group and a
    /// zero-weight group in the mix. State is mutated under the engine
    /// invariant `dom_share = running * dom_delta`.
    #[test]
    fn classed_index_matches_linear_scan() {
        let deltas = [0.01f64, 0.02, 0.05];
        let weights = [1.0f64, 2.0, 0.0];
        let mut rng = Pcg32::seeded(909);
        let n = 18;
        let mut users: Vec<UserState> = (0..n)
            .map(|i| {
                let d = deltas[i % deltas.len()];
                mk_user(
                    ResVec::cpu_mem(d * 10.0, d * 5.0),
                    weights[(i / deltas.len()) % weights.len()],
                    rng.below(3),
                    rng.below(6),
                    d,
                )
            })
            .collect();
        for u in users.iter_mut() {
            u.dom_share = u.running as f64 * u.dom_delta;
        }
        let mut eligible = vec![true; n];
        let mut idx = ClassedShareIndex::new();
        for step in 0..600 {
            idx.refresh(&users, &eligible);
            let got = idx.peek_min(&users, &eligible);
            let want = min_share_user(&users, &eligible);
            assert_eq!(got, want, "step {step}");
            let u = rng.below(n);
            match rng.below(4) {
                0 => {
                    users[u].running = rng.below(8);
                    users[u].dom_share =
                        users[u].running as f64 * users[u].dom_delta;
                    idx.mark_dirty(u);
                }
                1 => {
                    users[u].pending = rng.below(3);
                    idx.mark_dirty(u);
                }
                2 if eligible[u] => {
                    // block u (engine: Pick::Blocked)
                    eligible[u] = false;
                    idx.remove(u);
                }
                _ => {
                    // unblock u (engine: on_ready)
                    eligible[u] = true;
                    idx.mark_dirty(u);
                }
            }
        }
        // 3 deltas x 2 *effective* weights: weight 0.0 goes through
        // the guarded fallback and lands in the weight-1.0 groups
        assert_eq!(idx.group_count(), 6);
        assert!(!idx.is_fallback(), "18 users / 6 groups must aggregate");
    }

    /// Continuously distributed per-user weights defeat grouping: the
    /// build must fall back to the embedded per-user heap (instead of
    /// an O(n) group scan per pick) and stay bit-identical to the
    /// linear scan through the same churn protocol.
    #[test]
    fn distinct_weights_fall_back_to_heap() {
        let mut rng = Pcg32::seeded(911);
        let n = 12;
        let mut users: Vec<UserState> = (0..n)
            .map(|i| {
                mk_user(
                    ResVec::cpu_mem(0.1, 0.2),
                    1.0 + i as f64 * 0.137, // all distinct
                    1 + rng.below(2),
                    rng.below(5),
                    0.03,
                )
            })
            .collect();
        let mut eligible = vec![true; n];
        let mut idx = ClassedShareIndex::new();
        idx.refresh(&users, &eligible);
        assert!(idx.is_fallback(), "12 users / 12 groups must fall back");
        assert_eq!(idx.group_count(), 0);
        for step in 0..300 {
            idx.refresh(&users, &eligible);
            assert_eq!(
                idx.peek_min(&users, &eligible),
                min_share_user(&users, &eligible),
                "step {step}"
            );
            let u = rng.below(n);
            match rng.below(3) {
                0 => {
                    users[u].running = rng.below(7);
                    users[u].dom_share =
                        users[u].running as f64 * users[u].dom_delta;
                    idx.mark_dirty(u);
                }
                1 if eligible[u] => {
                    eligible[u] = false;
                    idx.remove(u);
                }
                _ => {
                    eligible[u] = true;
                    idx.mark_dirty(u);
                }
            }
        }
    }

    /// [`KeyMode::RunningOnly`] ranks by the slot key
    /// `running / effective_weight`: the grouped index (same-weight
    /// users aggregate into one group each) and the per-user fallback
    /// (distinct weights) must both match the naive keep-first slot
    /// scan through churn.
    #[test]
    fn running_only_mode_matches_slot_scan() {
        let slot_key =
            |u: &UserState| u.running as f64 / effective_weight(u.weight);
        let naive_min = |users: &[UserState], eligible: &[bool]| {
            let mut best: Option<usize> = None;
            for i in 0..users.len() {
                if !eligible[i] || users[i].pending == 0 {
                    continue;
                }
                match best {
                    Some(b)
                        if slot_key(&users[b]) <= slot_key(&users[i]) => {}
                    _ => best = Some(i),
                }
            }
            best
        };
        // grouped: 12 users over 3 weights (incl. the zero-weight
        // fallback); fallback: 12 users with all-distinct weights
        for (label, per_user_weights) in
            [("grouped", false), ("fallback", true)]
        {
            let mut rng = Pcg32::seeded(913);
            let n = 12;
            let mut users: Vec<UserState> = (0..n)
                .map(|i| {
                    let w = if per_user_weights {
                        1.0 + i as f64 * 0.211
                    } else {
                        [1.0, 3.0, 0.0][i % 3]
                    };
                    // dom_delta varies so DomShare and RunningOnly
                    // would genuinely disagree — the test is keyed on
                    // running counts alone
                    mk_user(
                        ResVec::cpu_mem(0.1, 0.2),
                        w,
                        1 + rng.below(2),
                        rng.below(6),
                        0.01 + i as f64 * 0.003,
                    )
                })
                .collect();
            let mut eligible = vec![true; n];
            let mut idx = ClassedShareIndex::by_weight();
            idx.refresh(&users, &eligible);
            assert_eq!(idx.is_fallback(), per_user_weights, "{label}");
            if !per_user_weights {
                // weight 0.0 shares the effective-weight-1.0 group
                assert_eq!(idx.group_count(), 2, "{label}");
            }
            for step in 0..500 {
                idx.refresh(&users, &eligible);
                assert_eq!(
                    idx.peek_min(&users, &eligible),
                    naive_min(&users, &eligible),
                    "{label} step {step}"
                );
                let u = rng.below(n);
                match rng.below(4) {
                    0 => {
                        users[u].running = rng.below(8);
                        users[u].dom_share =
                            users[u].running as f64 * users[u].dom_delta;
                        idx.mark_dirty(u);
                    }
                    1 => {
                        users[u].pending = rng.below(3);
                        idx.mark_dirty(u);
                    }
                    2 if eligible[u] => {
                        eligible[u] = false;
                        idx.remove(u);
                    }
                    _ => {
                        eligible[u] = true;
                        idx.mark_dirty(u);
                    }
                }
            }
        }
    }

    /// Mid-drain resync (the reinsert-equivalent) keeps the index
    /// exact without a dirty-list round trip.
    #[test]
    fn resync_updates_in_place() {
        let d = ResVec::cpu_mem(0.1, 0.1);
        let mut users = vec![
            mk_user(d, 1.0, 2, 0, 0.01),
            mk_user(d, 1.0, 1, 1, 0.01),
        ];
        let eligible = vec![true, true];
        let mut idx = ClassedShareIndex::new();
        idx.refresh(&users, &eligible);
        assert_eq!(idx.peek_min(&users, &eligible), Some(0));
        // engine commits a placement for user 0
        users[0].running = 1;
        users[0].pending = 1;
        users[0].dom_share = 0.01;
        idx.resync(0, &users, &eligible);
        // tie at running = 1 -> lowest index
        assert_eq!(idx.peek_min(&users, &eligible), Some(0));
        // and one more: user 0 now runs more than user 1
        users[0].running = 2;
        users[0].dom_share = 0.02;
        idx.resync(0, &users, &eligible);
        assert_eq!(idx.peek_min(&users, &eligible), Some(1));
        // draining user 1's pending work removes it
        users[1].pending = 0;
        idx.resync(1, &users, &eligible);
        assert_eq!(idx.peek_min(&users, &eligible), Some(0));
    }
}
