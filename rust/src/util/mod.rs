//! Shared utilities built in-tree (this image has no crates.io access):
//! deterministic RNG, statistics, JSON and TOML-subset parsing, and a
//! tiny benchmark harness.

pub mod bench;
pub mod json;
pub mod rng;
pub mod stats;
pub mod toml_lite;

pub use rng::Pcg32;
