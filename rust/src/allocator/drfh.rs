//! The exact fluid DRFH allocation (paper Sec. IV, eq. (7)):
//!
//! ```text
//!   max  g    s.t.  Σ_i g_il · d_ir <= c_lr   ∀ server l, resource r
//!                   Σ_l g_il = w_i · g        ∀ user i
//! ```
//!
//! Identical servers are collapsed into classes (`Cluster::classes()`):
//! any class-level allocation can be split evenly across its members, so
//! the LP shrinks from `n·k` to `n·C` variables (C <= 10 for the Google
//! Table I pool) while remaining exact.
//!
//! Finite task demands (paper Sec. V-A) are handled by progressive
//! filling rounds: all unsaturated users' dominant shares grow at rates
//! proportional to their weights until one hits its cap, which freezes
//! it; repeat until no user can grow.
//!
//! [`solve`] re-solves each round's LP from scratch. It is the
//! from-scratch parity reference (the `::naive()` convention of
//! `sched::index`) for [`super::incremental::IncrementalDrfh`], which
//! maintains the same LP statefully and re-solves from a warm simplex
//! basis across rounds and join/departure/cap/weight events.

use super::NormalizedDemand;
use crate::cluster::{Cluster, ResVec, ServerClass};
use crate::sched::effective_weight;
use crate::solver::{self, Lp, LpResult};

/// A user as seen by the fluid allocator.
#[derive(Clone, Debug)]
pub struct FluidUser {
    /// Per-task demand in absolute units.
    pub demand: ResVec,
    /// Fair-share weight (1.0 = unweighted).
    pub weight: f64,
    /// Max number of (fractional) tasks the user can use; None = infinite.
    pub task_cap: Option<f64>,
}

impl FluidUser {
    pub fn unweighted(demand: ResVec) -> Self {
        FluidUser { demand, weight: 1.0, task_cap: None }
    }
}

/// The fluid DRFH allocation.
#[derive(Clone, Debug)]
pub struct FluidAllocation {
    /// Server classes the solution is expressed over.
    pub classes: Vec<ServerClass>,
    /// Pool totals (absolute units).
    pub total: ResVec,
    /// Normalized demands (paper terms) per user.
    pub demands: Vec<NormalizedDemand>,
    /// x[i][c]: global dominant share user i draws from class c.
    pub x: Vec<Vec<f64>>,
    /// g_i = Σ_c x[i][c]: each user's global dominant share.
    pub g: Vec<f64>,
    /// Number of (fractional) tasks each user schedules.
    pub tasks: Vec<f64>,
    /// Simplex search pivots spent across the progressive-filling
    /// rounds that produced this allocation (warm-start savings show
    /// up here — see `allocator::incremental`).
    pub lp_pivots: u64,
    /// Number of LP solves (one per progressive-filling round).
    pub lp_solves: u32,
}

impl FluidAllocation {
    /// Resource vector (pool-share units) user i holds in class c:
    /// A_ic = x_ic · d_i (Lemma 1 — non-wasteful allocations are
    /// proportional to the normalized demand).
    pub fn alloc_share(&self, i: usize, c: usize) -> ResVec {
        self.demands[i].norm.scale(self.x[i][c])
    }

    /// Resource vector (absolute units) user i holds in class c.
    pub fn alloc_absolute(&self, i: usize, c: usize) -> ResVec {
        let s = self.alloc_share(i, c);
        let mut a = s;
        for r in 0..a.dims() {
            a[r] = s[r] * self.total[r];
        }
        a
    }

    /// The minimum dominant share across users (the maximized objective
    /// for unweighted, uncapped instances).
    pub fn min_share(&self) -> f64 {
        self.g.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    /// Feasibility check: per class and resource, allocations within
    /// capacity (share units), with tolerance.
    pub fn is_feasible(&self, eps: f64) -> bool {
        let m = self.total.dims();
        for (c, class) in self.classes.iter().enumerate() {
            for r in 0..m {
                let cap_share =
                    class.capacity[r] * class.count as f64 / self.total[r];
                let used: f64 = (0..self.demands.len())
                    .map(|i| self.x[i][c] * self.demands[i].norm[r])
                    .sum();
                if used > cap_share + eps {
                    return false;
                }
            }
        }
        true
    }
}

/// Solve the exact fluid DRFH allocation for `users` on `cluster`.
pub fn solve(cluster: &Cluster, users: &[FluidUser]) -> FluidAllocation {
    solve_classes(&cluster.classes(), &cluster.total_capacity(), users)
}

/// Same, over pre-aggregated server classes.
pub fn solve_classes(
    classes: &[ServerClass],
    total: &ResVec,
    users: &[FluidUser],
) -> FluidAllocation {
    let n = users.len();
    let nc = classes.len();
    let m = total.dims();
    // Guarded weights throughout: trace validation allows weight 0
    // (ranked as weight 1.0 everywhere via `sched::effective_weight`);
    // the raw value here would put inf in the delta cap and a zero
    // growth coefficient in the equality rows, freezing the user at 0.
    let weights: Vec<f64> =
        users.iter().map(|u| effective_weight(u.weight)).collect();
    let demands: Vec<NormalizedDemand> = users
        .iter()
        .map(|u| NormalizedDemand::from_absolute(&u.demand, total))
        .collect();
    // caps in dominant-share units
    let caps: Vec<f64> = users
        .iter()
        .zip(&demands)
        .map(|(u, d)| {
            u.task_cap
                .map(|t| t * d.share[d.dominant])
                .unwrap_or(f64::INFINITY)
        })
        .collect();
    // class capacity in pool-share units
    let cap_share: Vec<ResVec> = classes
        .iter()
        .map(|c| {
            let mut v = ResVec::zeros(m);
            for r in 0..m {
                v[r] = c.capacity[r] * c.count as f64 / total[r];
            }
            v
        })
        .collect();

    // Progressive filling: frozen[i] = dominant share fixed so far.
    let mut frozen = vec![0.0f64; n];
    let mut saturated = vec![false; n];
    let mut x = vec![vec![0.0f64; nc]; n];
    let mut lp_pivots = 0u64;
    let mut lp_solves = 0u32;

    // Users already at cap 0 are trivially saturated.
    for i in 0..n {
        if caps[i] <= 1e-15 {
            saturated[i] = true;
        }
    }

    for _round in 0..n + 1 {
        if saturated.iter().all(|&s| s) {
            break;
        }
        // LP variables: x_ic (n·nc) then delta.
        let nv = n * nc + 1;
        let var = |i: usize, c: usize| i * nc + c;
        let dvar = nv - 1;

        let mut c_obj = vec![0.0; nv];
        c_obj[dvar] = 1.0;

        let mut a_ub: Vec<Vec<f64>> = Vec::new();
        let mut b_ub: Vec<f64> = Vec::new();
        // class capacity rows
        for (c, cs) in cap_share.iter().enumerate() {
            for r in 0..m {
                let mut row = vec![0.0; nv];
                for i in 0..n {
                    row[var(i, c)] = demands[i].norm[r];
                }
                a_ub.push(row);
                b_ub.push(cs[r]);
            }
        }
        // delta bounded by the tightest remaining cap among active users
        let mut delta_max = f64::INFINITY;
        for i in 0..n {
            if !saturated[i] && caps[i].is_finite() {
                delta_max = delta_max.min((caps[i] - frozen[i]) / weights[i]);
            }
        }
        if delta_max.is_finite() {
            let mut row = vec![0.0; nv];
            row[dvar] = 1.0;
            a_ub.push(row);
            b_ub.push(delta_max.max(0.0));
        }

        let mut a_eq: Vec<Vec<f64>> = Vec::new();
        let mut b_eq: Vec<f64> = Vec::new();
        for i in 0..n {
            let mut row = vec![0.0; nv];
            for c in 0..nc {
                row[var(i, c)] = 1.0;
            }
            if saturated[i] {
                // frozen users keep their total dominant share
                a_eq.push(row);
                b_eq.push(frozen[i]);
            } else {
                row[dvar] = -weights[i];
                a_eq.push(row);
                b_eq.push(frozen[i]);
            }
        }

        let lp = Lp { n: nv, c: c_obj, a_ub, b_ub, a_eq, b_eq };
        let (sol, delta) = match solver::solve(&lp) {
            LpResult::Optimal { x, obj, pivots } => {
                lp_pivots += pivots.search() as u64;
                lp_solves += 1;
                (x, obj)
            }
            other => panic!("DRFH round LP not optimal: {other:?}"),
        };
        // commit
        for i in 0..n {
            for c in 0..nc {
                x[i][c] = sol[var(i, c)];
            }
        }
        if delta <= 1e-12 {
            break; // capacity exhausted for all active users
        }
        let mut newly = 0;
        for i in 0..n {
            if !saturated[i] {
                frozen[i] += weights[i] * delta;
                if caps[i].is_finite() && frozen[i] >= caps[i] - 1e-9 {
                    frozen[i] = caps[i];
                    saturated[i] = true;
                    newly += 1;
                }
            }
        }
        if newly == 0 {
            break; // no cap hit: capacity-limited optimum reached
        }
    }

    let g: Vec<f64> = x.iter().map(|xi| xi.iter().sum()).collect();
    let tasks: Vec<f64> = g
        .iter()
        .zip(&demands)
        .map(|(&gi, d)| gi / d.share[d.dominant])
        .collect();
    FluidAllocation {
        classes: classes.to_vec(),
        total: *total,
        demands,
        x,
        g,
        tasks,
        lp_pivots,
        lp_solves,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;

    fn fig1_users() -> Vec<FluidUser> {
        vec![
            FluidUser::unweighted(ResVec::cpu_mem(0.2, 1.0)),
            FluidUser::unweighted(ResVec::cpu_mem(1.0, 0.2)),
        ]
    }

    #[test]
    fn paper_fig3_exact_allocation() {
        // DRFH on the Fig. 1 example: g = 5/7, 10 tasks each (Fig. 3)
        let cluster = Cluster::fig1_example();
        let a = solve(&cluster, &fig1_users());
        assert!((a.g[0] - 5.0 / 7.0).abs() < 1e-6, "g1={}", a.g[0]);
        assert!((a.g[1] - 5.0 / 7.0).abs() < 1e-6, "g2={}", a.g[1]);
        assert!((a.tasks[0] - 10.0).abs() < 1e-5);
        assert!((a.tasks[1] - 10.0).abs() < 1e-5);
        assert!(a.is_feasible(1e-9));
    }

    #[test]
    fn single_server_reduces_to_drf() {
        // one server (9 CPU, 18 GB); users (1,4) and (3,1) — the DRF
        // paper's canonical example: equalized dominant shares
        let cluster =
            Cluster::from_capacities(&[ResVec::cpu_mem(9.0, 18.0)]);
        let users = vec![
            FluidUser::unweighted(ResVec::cpu_mem(1.0, 4.0)),
            FluidUser::unweighted(ResVec::cpu_mem(3.0, 1.0)),
        ];
        let a = solve(&cluster, &users);
        // DRF: user1 gets 3 tasks (12 GB = 2/3 mem), user2 gets 2 tasks
        // (6 CPU = 2/3 cpu)
        assert!((a.g[0] - a.g[1]).abs() < 1e-6);
        assert!((a.g[0] - 2.0 / 3.0).abs() < 1e-6, "g={}", a.g[0]);
        assert!((a.tasks[0] - 3.0).abs() < 1e-5);
        assert!((a.tasks[1] - 2.0).abs() < 1e-5);
    }

    #[test]
    fn single_resource_max_min() {
        let cluster = Cluster::from_capacities(&[
            ResVec::from_slice(&[6.0]),
            ResVec::from_slice(&[4.0]),
        ]);
        let users = vec![
            FluidUser::unweighted(ResVec::from_slice(&[1.0])),
            FluidUser::unweighted(ResVec::from_slice(&[2.0])),
        ];
        let a = solve(&cluster, &users);
        // max-min: each gets half the pool (5 units) regardless of demand
        assert!((a.g[0] - 0.5).abs() < 1e-6);
        assert!((a.g[1] - 0.5).abs() < 1e-6);
        assert!((a.tasks[0] - 5.0).abs() < 1e-5);
        assert!((a.tasks[1] - 2.5).abs() < 1e-5);
    }

    #[test]
    fn weights_scale_shares() {
        let cluster = Cluster::fig1_example();
        let mut users = fig1_users();
        users[0].weight = 2.0;
        let a = solve(&cluster, &users);
        // weighted max-min: g_1 / 2 == g_2
        assert!(
            (a.g[0] - 2.0 * a.g[1]).abs() < 1e-6,
            "g = {:?}",
            a.g
        );
        assert!(a.is_feasible(1e-9));
    }

    /// Regression: a legal weight-0 user (trace validation allows
    /// them) must rank as weight 1.0 — the raw weight put `inf` in the
    /// delta cap and a zero growth coefficient in the user's equality
    /// row, freezing it at zero dominant share.
    #[test]
    fn zero_weight_user_uses_guarded_semantics() {
        let cluster = Cluster::fig1_example();
        let mut users = fig1_users();
        users[0].weight = 0.0;
        let a = solve(&cluster, &users);
        assert!(a.g.iter().all(|g| g.is_finite()), "g = {:?}", a.g);
        assert!(a.is_feasible(1e-9));
        // effective weights (1.0, 1.0): same optimum as the unweighted
        // Fig. 3 instance, g = 5/7 each
        assert!((a.g[0] - 5.0 / 7.0).abs() < 1e-6, "g1 = {}", a.g[0]);
        assert!((a.g[1] - 5.0 / 7.0).abs() < 1e-6, "g2 = {}", a.g[1]);

        // capped weight-0 user: the cap still binds at the guarded rate
        users[0].task_cap = Some(2.0);
        let a = solve(&cluster, &users);
        assert!((a.tasks[0] - 2.0).abs() < 1e-5, "tasks = {:?}", a.tasks);
        assert!(a.tasks[1] > 10.0, "user 2 should absorb the release");
    }

    #[test]
    fn finite_caps_release_resources() {
        let cluster = Cluster::fig1_example();
        let mut users = fig1_users();
        // user 1 only needs 2 tasks; user 2 should then grab more
        users[0].task_cap = Some(2.0);
        let a = solve(&cluster, &users);
        assert!((a.tasks[0] - 2.0).abs() < 1e-5, "tasks={:?}", a.tasks);
        assert!(a.tasks[1] > 10.0, "user 2 should exceed equal share");
        assert!(a.is_feasible(1e-9));
    }

    #[test]
    fn zero_cap_user_is_inactive() {
        let cluster = Cluster::fig1_example();
        let mut users = fig1_users();
        users[0].task_cap = Some(0.0);
        let a = solve(&cluster, &users);
        assert!(a.tasks[0].abs() < 1e-9);
        assert!(a.tasks[1] > 11.0, "tasks={:?}", a.tasks);
    }

    #[test]
    fn bottleneck_fairness() {
        // both users dominant on CPU -> equal CPU shares (max-min)
        let cluster = Cluster::fig1_example();
        let users = vec![
            FluidUser::unweighted(ResVec::cpu_mem(1.0, 0.1)),
            FluidUser::unweighted(ResVec::cpu_mem(1.0, 0.5)),
        ];
        let a = solve(&cluster, &users);
        assert!((a.g[0] - a.g[1]).abs() < 1e-6);
        // CPU is everyone's dominant resource, so the CPU share consumed
        // equals the sum of dominant shares (max-min over CPU under the
        // per-server packing constraints)
        let cpu_used: f64 = (0..2)
            .map(|i| {
                (0..a.classes.len())
                    .map(|c| a.alloc_share(i, c)[0])
                    .sum::<f64>()
            })
            .sum();
        assert!(
            (cpu_used - (a.g[0] + a.g[1])).abs() < 1e-9,
            "cpu_used={cpu_used} vs g sum {}",
            a.g[0] + a.g[1]
        );
    }

    #[test]
    fn many_random_instances_feasible_and_equalized() {
        use crate::util::Pcg32;
        let mut rng = Pcg32::seeded(21);
        for trial in 0..20 {
            let k = 2 + rng.below(6);
            let caps: Vec<ResVec> = (0..k)
                .map(|_| {
                    ResVec::cpu_mem(rng.uniform(1.0, 8.0), rng.uniform(1.0, 8.0))
                })
                .collect();
            let cluster = Cluster::from_capacities(&caps);
            let n = 2 + rng.below(5);
            let users: Vec<FluidUser> = (0..n)
                .map(|_| {
                    FluidUser::unweighted(ResVec::cpu_mem(
                        rng.uniform(0.05, 1.0),
                        rng.uniform(0.05, 1.0),
                    ))
                })
                .collect();
            let a = solve(&cluster, &users);
            assert!(a.is_feasible(1e-6), "trial {trial} infeasible");
            // uncapped unweighted DRFH equalizes all dominant shares
            let gmin = a.g.iter().cloned().fold(f64::INFINITY, f64::min);
            let gmax = a.g.iter().cloned().fold(0.0, f64::max);
            assert!(
                gmax - gmin < 1e-6,
                "trial {trial}: shares not equalized {:?}",
                a.g
            );
            assert!(gmin > 0.0, "trial {trial}: zero share");
        }
    }
}
