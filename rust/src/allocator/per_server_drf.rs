//! The naive per-server DRF extension (paper Sec. III-D) — the
//! strawman DRFH replaces. Applies the single-server DRF allocation
//! independently inside every server: each user's *per-server* dominant
//! share is equalized within each server, with every user present in
//! every server.
//!
//! The paper shows this is Pareto-inefficient: on the Fig. 1 example
//! each user schedules 6 tasks, versus 10 under DRFH (Fig. 2 vs Fig. 3).

use crate::cluster::{Cluster, ResVec};

/// Result of the naive allocation: tasks per user per server.
#[derive(Clone, Debug)]
pub struct PerServerDrf {
    /// tasks[i][l] — fractional tasks of user i on server l.
    pub tasks: Vec<Vec<f64>>,
}

impl PerServerDrf {
    /// Total tasks per user.
    pub fn tasks_per_user(&self) -> Vec<f64> {
        self.tasks.iter().map(|t| t.iter().sum()).collect()
    }
}

/// Closed-form fluid DRF inside one server (equal per-server dominant
/// shares, progressive filling with every user unsaturated):
///
/// Per unit of server-dominant share, user i consumes
/// `u_ir = D_ir · c_{l,r*_il} / D_{i,r*_il}` of resource r, where
/// `r*_il = argmax_r D_ir / c_lr`. The equalized share is
/// `x* = min_r c_lr / Σ_i u_ir`, and user i schedules
/// `x* · c_{l,r*_il} / D_{i,r*_il}` tasks.
pub fn drf_single_server(capacity: &ResVec, demands: &[ResVec]) -> Vec<f64> {
    let m = capacity.dims();
    let n = demands.len();
    if n == 0 {
        return vec![];
    }
    // per-user: dominant resource within this server, and consumption
    // per unit dominant share
    let mut unit = vec![ResVec::zeros(m); n];
    let mut tasks_per_share = vec![0.0f64; n];
    for (i, d) in demands.iter().enumerate() {
        let ratios = d.div(capacity);
        let rstar = ratios.argmax();
        let scale = capacity[rstar] / d[rstar]; // tasks per unit share
        tasks_per_share[i] = scale;
        for r in 0..m {
            unit[i][r] = d[r] * scale;
        }
    }
    // x* = min_r c_r / Σ_i unit_ir
    let mut x = f64::INFINITY;
    for r in 0..m {
        let tot: f64 = unit.iter().map(|u| u[r]).sum();
        if tot > 0.0 {
            x = x.min(capacity[r] / tot);
        }
    }
    tasks_per_share.iter().map(|&t| x * t).collect()
}

/// Apply DRF independently in every server of the cluster.
pub fn solve(cluster: &Cluster, demands: &[ResVec]) -> PerServerDrf {
    let n = demands.len();
    let mut tasks = vec![vec![0.0; cluster.len()]; n];
    for (l, s) in cluster.servers.iter().enumerate() {
        let t = drf_single_server(&s.capacity, demands);
        for i in 0..n {
            tasks[i][l] = t[i];
        }
    }
    PerServerDrf { tasks }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_fig2_allocation() {
        // Fig. 2: naive DRF gives user 1 five tasks on server 1 and one
        // on server 2 (and symmetrically for user 2): 6 tasks each.
        let cluster = Cluster::fig1_example();
        let demands = vec![
            ResVec::cpu_mem(0.2, 1.0),
            ResVec::cpu_mem(1.0, 0.2),
        ];
        let a = solve(&cluster, &demands);
        assert!((a.tasks[0][0] - 5.0).abs() < 1e-9, "{:?}", a.tasks);
        assert!((a.tasks[0][1] - 1.0).abs() < 1e-9);
        assert!((a.tasks[1][0] - 1.0).abs() < 1e-9);
        assert!((a.tasks[1][1] - 5.0).abs() < 1e-9);
        let per_user = a.tasks_per_user();
        assert!((per_user[0] - 6.0).abs() < 1e-9);
        assert!((per_user[1] - 6.0).abs() < 1e-9);
    }

    #[test]
    fn single_user_gets_whole_server() {
        let t = drf_single_server(
            &ResVec::cpu_mem(4.0, 8.0),
            &[ResVec::cpu_mem(1.0, 1.0)],
        );
        // CPU binds: 4 tasks
        assert!((t[0] - 4.0).abs() < 1e-9);
    }

    #[test]
    fn allocation_feasible_per_server() {
        use crate::util::Pcg32;
        let mut rng = Pcg32::seeded(31);
        for _ in 0..20 {
            let cap = ResVec::cpu_mem(
                rng.uniform(1.0, 10.0),
                rng.uniform(1.0, 10.0),
            );
            let n = 1 + rng.below(6);
            let demands: Vec<ResVec> = (0..n)
                .map(|_| {
                    ResVec::cpu_mem(
                        rng.uniform(0.05, 2.0),
                        rng.uniform(0.05, 2.0),
                    )
                })
                .collect();
            let t = drf_single_server(&cap, &demands);
            for r in 0..2 {
                let used: f64 = t
                    .iter()
                    .zip(&demands)
                    .map(|(&ti, d)| ti * d[r])
                    .sum();
                assert!(used <= cap[r] + 1e-9, "resource {r} over");
            }
            // at least one resource is saturated (Pareto within server)
            let saturated = (0..2).any(|r| {
                let used: f64 = t
                    .iter()
                    .zip(&demands)
                    .map(|(&ti, d)| ti * d[r])
                    .sum();
                (used - cap[r]).abs() < 1e-6
            });
            assert!(saturated, "no resource saturated");
        }
    }
}
