//! Small statistics toolkit shared by metrics and the experiment
//! harness: means, percentiles, empirical CDFs, Jain's fairness index,
//! and the streaming accumulators ([`StreamStats`], [`P2Quantile`])
//! behind the engine's bounded-memory metrics mode.
//!
//! ## §Perf: selection instead of sorting
//!
//! [`percentile`] and [`cdf_points`] used to clone and *fully sort*
//! their input on every call — O(n log n) per quantile, which the
//! figure harnesses call repeatedly over job-completion vectors. Both
//! now run on `select_nth_unstable_by` (introselect): O(n) for one
//! percentile, O(n log k) for k CDF quantiles via recursive
//! multiselect. The comparator is still [`f64::total_cmp`], so the
//! NaN-tolerant semantics (NaNs group at the sign-matching extreme,
//! never a panic) are unchanged — selection over a total order yields
//! exactly the values a full sort would put at those ranks, which the
//! equivalence tests assert bit-for-bit against the sort-based
//! reference.

/// Arithmetic mean; 0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation; 0 for fewer than 2 samples.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64)
        .sqrt()
}

/// Linear-interpolated percentile, p in [0, 100]. NaN-tolerant:
/// selects with `total_cmp` instead of panicking mid-comparison (NaNs
/// group at the extremes by sign bit — positive NaNs last, negative
/// NaNs first — so a NaN-bearing input yields NaN percentiles at the
/// affected end rather than a panic). O(n) via introselect; the
/// values match the sort-based reference exactly (see module docs).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let (_, &mut lo_v, upper) =
        v.select_nth_unstable_by(lo, f64::total_cmp);
    if lo == hi {
        lo_v
    } else {
        // the (lo+1)-th order statistic is the minimum of the upper
        // partition (non-empty: hi > lo implies a fractional rank,
        // so lo < len - 1)
        let hi_v = upper
            .iter()
            .copied()
            .min_by(f64::total_cmp)
            .expect("fractional rank implies lo < len - 1");
        lo_v + (rank - lo as f64) * (hi_v - lo_v)
    }
}

/// Place every rank in `ranks` (strictly increasing, relative to the
/// whole array, each `< base + v.len()`) at its sorted position in
/// `v` (a sub-slice starting at absolute index `base`), by recursive
/// partitioning around the median requested rank — O(n log k).
fn multiselect(v: &mut [f64], ranks: &[usize], base: usize) {
    if ranks.is_empty() {
        return;
    }
    let m = ranks.len() / 2;
    let mid = ranks[m] - base;
    let (left, _, right) = v.select_nth_unstable_by(mid, f64::total_cmp);
    multiselect(left, &ranks[..m], base);
    multiselect(right, &ranks[m + 1..], base + mid + 1);
}

/// Empirical CDF evaluated at `points` many equally spaced quantiles;
/// returns (value, fraction <= value) pairs suitable for plotting.
/// NaN-tolerant like [`percentile`]; O(n log points) via multiselect
/// when that beats a full sort.
pub fn cdf_points(xs: &[f64], points: usize) -> Vec<(f64, f64)> {
    if xs.is_empty() || points == 0 {
        return vec![];
    }
    let n = xs.len();
    let idxs: Vec<usize> = (0..points)
        .map(|i| {
            let q = (i as f64 + 1.0) / points as f64;
            ((q * n as f64).ceil() as usize).clamp(1, n) - 1
        })
        .collect();
    let mut v = xs.to_vec();
    if points >= n || n < 64 {
        // dense quantile grid or tiny input: one sort is cheaper
        v.sort_by(f64::total_cmp);
    } else {
        let mut ranks = idxs.clone();
        ranks.dedup(); // idxs is nondecreasing; multiselect wants strict
        multiselect(&mut v, &ranks, 0);
    }
    idxs.iter()
        .enumerate()
        .map(|(i, &idx)| {
            let q = (i as f64 + 1.0) / points as f64;
            (v[idx], q)
        })
        .collect()
}

/// Jain's fairness index: (Σx)² / (n·Σx²); 1 = perfectly fair.
pub fn jain_index(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    let s: f64 = xs.iter().sum();
    let s2: f64 = xs.iter().map(|x| x * x).sum();
    if s2 == 0.0 {
        1.0
    } else {
        s * s / (xs.len() as f64 * s2)
    }
}

// ------------------------------------------------- streaming moments

/// Online count / mean / variance / min / max (Welford) — O(1) memory
/// however many samples arrive; the bounded-memory metrics mode
/// aggregates job-completion stats through this.
#[derive(Clone, Debug, PartialEq)]
pub struct StreamStats {
    count: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Default for StreamStats {
    fn default() -> Self {
        StreamStats {
            count: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
}

impl StreamStats {
    pub fn push(&mut self, x: f64) {
        self.count += 1;
        let d = x - self.mean;
        self.mean += d / self.count as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// 0 for no samples (matching [`mean`] on an empty slice).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.mean
        }
    }

    /// Population standard deviation; 0 for fewer than 2 samples
    /// (matching [`std_dev`]).
    pub fn std_dev(&self) -> f64 {
        if self.count < 2 {
            0.0
        } else {
            (self.m2 / self.count as f64).sqrt()
        }
    }

    /// 0 for no samples.
    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// 0 for no samples.
    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }
}

// ---------------------------------------------------- P² quantiles

/// Streaming quantile estimator (Jain & Chlamtac's P² algorithm,
/// CACM 1985): five markers track the running p-quantile in O(1)
/// memory. Exact for the first five observations; afterwards a
/// piecewise-parabolic approximation whose error vanishes as the
/// sample grows. The bounded-memory metrics mode uses it for job
/// completion-time percentiles.
#[derive(Clone, Debug, PartialEq)]
pub struct P2Quantile {
    p: f64,
    count: u64,
    /// Marker heights (the first `count` entries, sorted, while
    /// `count < 5`).
    q: [f64; 5],
    /// Actual marker positions (1-based; integral, kept as f64 for
    /// the update arithmetic).
    n: [f64; 5],
    /// Desired marker positions.
    np: [f64; 5],
    /// Desired-position increments per observation.
    dn: [f64; 5],
}

impl P2Quantile {
    /// Estimator for the `p`-quantile, `p` in (0, 1) (e.g. 0.5 for
    /// the median).
    pub fn new(p: f64) -> Self {
        assert!(
            p > 0.0 && p < 1.0,
            "quantile p={p} outside (0, 1): the five-marker scheme \
             degenerates at the extremes"
        );
        P2Quantile {
            p,
            count: 0,
            q: [0.0; 5],
            n: [1.0, 2.0, 3.0, 4.0, 5.0],
            np: [1.0, 1.0 + 2.0 * p, 1.0 + 4.0 * p, 3.0 + 2.0 * p, 5.0],
            dn: [0.0, p / 2.0, p, (1.0 + p) / 2.0, 1.0],
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn push(&mut self, x: f64) {
        if self.count < 5 {
            // exact phase: insertion-sort into the live prefix
            let mut i = self.count as usize;
            self.q[i] = x;
            while i > 0 && self.q[i - 1] > self.q[i] {
                self.q.swap(i - 1, i);
                i -= 1;
            }
            self.count += 1;
            return;
        }
        self.count += 1;
        // locate the cell, updating the extreme markers
        let k = if x < self.q[0] {
            self.q[0] = x;
            0
        } else if x >= self.q[4] {
            self.q[4] = x;
            3
        } else {
            // q[k] <= x < q[k+1]
            (0..4).rfind(|&i| self.q[i] <= x).unwrap_or(0)
        };
        for i in (k + 1)..5 {
            self.n[i] += 1.0;
        }
        for i in 0..5 {
            self.np[i] += self.dn[i];
        }
        // nudge the three middle markers toward their desired spots
        for i in 1..4 {
            let d = self.np[i] - self.n[i];
            if (d >= 1.0 && self.n[i + 1] - self.n[i] > 1.0)
                || (d <= -1.0 && self.n[i - 1] - self.n[i] < -1.0)
            {
                let d = d.signum();
                let qp = self.parabolic(i, d);
                self.q[i] = if self.q[i - 1] < qp && qp < self.q[i + 1] {
                    qp
                } else {
                    self.linear(i, d)
                };
                self.n[i] += d;
            }
        }
    }

    fn parabolic(&self, i: usize, d: f64) -> f64 {
        let (q, n) = (&self.q, &self.n);
        q[i] + d / (n[i + 1] - n[i - 1])
            * ((n[i] - n[i - 1] + d) * (q[i + 1] - q[i])
                / (n[i + 1] - n[i])
                + (n[i + 1] - n[i] - d) * (q[i] - q[i - 1])
                    / (n[i] - n[i - 1]))
    }

    fn linear(&self, i: usize, d: f64) -> f64 {
        let j = if d > 0.0 { i + 1 } else { i - 1 };
        self.q[i]
            + d * (self.q[j] - self.q[i]) / (self.n[j] - self.n[i])
    }

    /// Current estimate; exact (interpolated, like [`percentile`])
    /// while fewer than five samples have arrived, 0 when empty.
    pub fn quantile(&self) -> f64 {
        let c = self.count as usize;
        if c == 0 {
            return 0.0;
        }
        if c < 5 {
            let rank = self.p * (c - 1) as f64;
            let lo = rank.floor() as usize;
            let hi = rank.ceil() as usize;
            return if lo == hi {
                self.q[lo]
            } else {
                self.q[lo]
                    + (rank - lo as f64) * (self.q[hi] - self.q[lo])
            };
        }
        self.q[2]
    }
}

/// Histogram with `bins` equal-width bins over [lo, hi].
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<usize> {
    let mut h = vec![0usize; bins];
    if hi <= lo || bins == 0 {
        return h;
    }
    let w = (hi - lo) / bins as f64;
    for &x in xs {
        if x >= lo && x < hi {
            // `(x - lo) / w` can round up to exactly `bins` for x just
            // below hi (e.g. lo 0, hi 3.5, bins 5, x = 3.5 - 1 ulp):
            // clamp the index instead of walking off the array
            h[(((x - lo) / w) as usize).min(bins - 1)] += 1;
        } else if (x - hi).abs() < 1e-12 {
            h[bins - 1] += 1;
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    /// The pre-selection sort-based implementations, kept verbatim as
    /// the equivalence references for the O(n) paths.
    fn percentile_sort_ref(xs: &[f64], p: f64) -> f64 {
        if xs.is_empty() {
            return 0.0;
        }
        let mut v = xs.to_vec();
        v.sort_by(f64::total_cmp);
        let rank = (p / 100.0) * (v.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            v[lo]
        } else {
            v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
        }
    }

    fn cdf_sort_ref(xs: &[f64], points: usize) -> Vec<(f64, f64)> {
        if xs.is_empty() || points == 0 {
            return vec![];
        }
        let mut v = xs.to_vec();
        v.sort_by(f64::total_cmp);
        let n = v.len();
        (0..points)
            .map(|i| {
                let q = (i as f64 + 1.0) / points as f64;
                let idx = ((q * n as f64).ceil() as usize).clamp(1, n) - 1;
                (v[idx], q)
            })
            .collect()
    }

    /// bit-exact equality that treats NaN == NaN (same bits).
    fn bits_eq(a: f64, b: f64) -> bool {
        a.to_bits() == b.to_bits() || (a == b)
    }

    #[test]
    fn selection_percentile_matches_sort_reference() {
        let mut rng = Pcg32::seeded(404);
        for trial in 0..40 {
            let n = 1 + rng.below(300);
            let mut xs: Vec<f64> = (0..n)
                .map(|_| {
                    // duplicates, negatives, ±0.0, and the occasional NaN
                    match rng.below(10) {
                        0 => 0.0,
                        1 => -0.0,
                        2 => f64::NAN,
                        3 => -f64::NAN,
                        4 => rng.uniform(-5.0, 5.0).round(),
                        _ => rng.uniform(-1e6, 1e6),
                    }
                })
                .collect();
            if trial % 3 == 0 {
                xs.retain(|x| !x.is_nan()); // plenty of NaN-free runs too
                if xs.is_empty() {
                    xs.push(1.0);
                }
            }
            for p in [0.0, 1.0, 25.0, 50.0, 73.3, 90.0, 99.0, 100.0] {
                let fast = percentile(&xs, p);
                let slow = percentile_sort_ref(&xs, p);
                assert!(
                    bits_eq(fast, slow),
                    "trial {trial} p={p}: {fast} != {slow}"
                );
            }
        }
    }

    #[test]
    fn multiselect_cdf_matches_sort_reference() {
        let mut rng = Pcg32::seeded(505);
        for trial in 0..30 {
            // sizes straddling the n < 64 sort cutoff and points >= n
            let n = 1 + rng.below(400);
            let xs: Vec<f64> = (0..n)
                .map(|_| match rng.below(8) {
                    0 => f64::NAN,
                    1 => rng.uniform(0.0, 3.0).round(),
                    _ => rng.uniform(0.0, 1e4),
                })
                .collect();
            for points in [1usize, 2, 7, 10, 50, 100, 500] {
                let fast = cdf_points(&xs, points);
                let slow = cdf_sort_ref(&xs, points);
                assert_eq!(fast.len(), slow.len());
                for (i, (a, b)) in fast.iter().zip(&slow).enumerate() {
                    assert!(
                        bits_eq(a.0, b.0) && a.1 == b.1,
                        "trial {trial} points={points} idx {i}: \
                         {a:?} != {b:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn stream_stats_match_batch() {
        let mut rng = Pcg32::seeded(606);
        let xs: Vec<f64> = (0..500).map(|_| rng.uniform(-3.0, 9.0)).collect();
        let mut s = StreamStats::default();
        for &x in &xs {
            s.push(x);
        }
        assert_eq!(s.count(), 500);
        assert!((s.mean() - mean(&xs)).abs() < 1e-9);
        assert!((s.std_dev() - std_dev(&xs)).abs() < 1e-9);
        let mn = xs.iter().copied().fold(f64::INFINITY, f64::min);
        let mx = xs.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        assert_eq!(s.min(), mn);
        assert_eq!(s.max(), mx);
        // empty accumulator mirrors the empty-slice conventions
        let e = StreamStats::default();
        assert_eq!((e.mean(), e.std_dev(), e.min(), e.max()), (0.0, 0.0, 0.0, 0.0));
    }

    #[test]
    fn p2_exact_below_five_samples() {
        let mut q = P2Quantile::new(0.5);
        assert_eq!(q.quantile(), 0.0);
        for x in [5.0, 1.0, 3.0] {
            q.push(x);
        }
        // exact phase must agree with `percentile` on the same data
        assert!((q.quantile() - percentile(&[5.0, 1.0, 3.0], 50.0)).abs() < 1e-12);
        q.push(2.0);
        q.push(4.0);
        assert!((q.quantile() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn p2_converges_on_skewed_data() {
        // tolerance widens with tail depth: P² is an approximation
        // and the p99 marker sees ~200 effective samples here
        for (p, pct, tol) in
            [(0.5, 50.0, 0.08), (0.9, 90.0, 0.10), (0.99, 99.0, 0.25)]
        {
            let mut rng = Pcg32::seeded(707);
            let mut est = P2Quantile::new(p);
            let mut xs = Vec::new();
            for _ in 0..20_000 {
                // exponential × uniform scale: heavy right tail like
                // JCT data
                let u = rng.uniform(0.0, 1.0).max(1e-12);
                let x = (-(u.ln())) * rng.uniform(10.0, 1000.0);
                est.push(x);
                xs.push(x);
            }
            let exact = percentile(&xs, pct);
            let got = est.quantile();
            let rel = (got - exact).abs() / exact.abs().max(1e-12);
            assert!(
                rel < tol,
                "p={p}: P² {got} vs exact {exact} (rel {rel:.3})"
            );
        }
    }

    #[test]
    fn mean_and_std() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert!((std_dev(&[2.0, 4.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn cdf_monotone() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        let cdf = cdf_points(&xs, 10);
        for w in cdf.windows(2) {
            assert!(w[0].0 <= w[1].0);
            assert!(w[0].1 <= w[1].1);
        }
        assert_eq!(cdf.last().unwrap().0, 5.0);
    }

    #[test]
    fn jain_extremes() {
        assert!((jain_index(&[1.0, 1.0, 1.0]) - 1.0).abs() < 1e-12);
        let skew = jain_index(&[1.0, 0.0, 0.0]);
        assert!((skew - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_counts() {
        let h = histogram(&[0.1, 0.2, 0.9, 1.0], 0.0, 1.0, 2);
        assert_eq!(h, vec![2, 2]);
    }

    /// Regression: x one ulp below hi used to compute bin index ==
    /// bins and panic on `h[bins]` (float division rounds up); the
    /// index is clamped into the last bin. Both literals are exact
    /// f64 values verified to trigger the rounding.
    #[test]
    fn histogram_clamps_rounded_up_bin() {
        // (x - lo) / w == 5.0 exactly for x = nextafter(3.5, -inf)
        let h = histogram(&[3.4999999999999996], 0.0, 3.5, 5);
        assert_eq!(h.iter().sum::<usize>(), 1);
        assert_eq!(h[4], 1);
        // and == 10.0 for x = nextafter(7.0, -inf)
        let h = histogram(&[6.999999999999999], 0.0, 7.0, 10);
        assert_eq!(h.iter().sum::<usize>(), 1);
        assert_eq!(h[9], 1);
    }

    /// Regression: NaN samples used to panic `partial_cmp().unwrap()`
    /// inside the sort; `total_cmp` groups them at the sign-matching
    /// extreme instead. Both NaN signs are covered — runtime NaNs
    /// (e.g. `0.0/0.0` on x86-64) often carry the sign bit.
    #[test]
    fn percentile_and_cdf_tolerate_nan() {
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert!(percentile(&xs, 100.0).is_nan()); // +NaN ranks last
        let cdf = cdf_points(&xs, 4);
        assert_eq!(cdf.len(), 4);
        assert_eq!(cdf[0].0, 1.0); // finite values keep their order
        // negative NaN ranks first: the low end goes NaN, the high
        // end stays finite — and still no panic
        let neg = [3.0, -f64::NAN, 1.0, 2.0];
        assert!(percentile(&neg, 0.0).is_nan());
        assert_eq!(percentile(&neg, 100.0), 3.0);
        assert_eq!(cdf_points(&neg, 4).len(), 4);
    }
}
