//! First-Fit DRFH (paper Sec. V-B): progressive filling that places the
//! chosen user's task on the *first* (lowest-index) server that fits —
//! the simpler sibling of Best-Fit, kept as an evaluation baseline
//! (Fig. 5 compares the two).
//!
//! §Perf: like Best-Fit, the default construction runs on the
//! class-keyed incremental index (the per-demand-class server heaps
//! minimize the server *index* instead of the H-score);
//! [`FirstFitDrfh::per_user`] keeps the PR 1 per-user heaps and
//! [`FirstFitDrfh::naive`] the seed's linear scan as bit-identical
//! references.

use super::index::{IndexedCore, ScoreKind};
use super::{drain_by_picks, min_share_user, DrainCtx, Pick, Scheduler, UserState};
use crate::cluster::{Cluster, ResVec};

/// The First-Fit DRFH policy.
pub struct FirstFitDrfh {
    /// The incremental decision core (default), or `None` for the
    /// reference linear scan. Both paths emit identical decisions.
    core: Option<IndexedCore>,
}

impl Default for FirstFitDrfh {
    fn default() -> Self {
        FirstFitDrfh { core: Some(IndexedCore::new(ScoreKind::FirstFit)) }
    }
}

impl FirstFitDrfh {
    /// The seed's linear-scan path — the parity reference and the
    /// naive baseline in `benches/engine_scale.rs`.
    pub fn naive() -> Self {
        FirstFitDrfh { core: None }
    }

    /// The PR 1 per-user index layout — the scaling baseline in
    /// `benches/user_scale.rs` and the intermediate parity reference
    /// for the class-keyed default.
    pub fn per_user() -> Self {
        FirstFitDrfh { core: Some(IndexedCore::per_user(ScoreKind::FirstFit)) }
    }

    /// Is this instance on the indexed hot path?
    pub fn is_indexed(&self) -> bool {
        self.core.is_some()
    }

    /// Is this instance on the class-keyed (interned) index?
    pub fn is_classed(&self) -> bool {
        self.core.as_ref().is_some_and(IndexedCore::is_classed)
    }
}

/// First server that fits `demand`, by index.
pub fn first_server(cluster: &Cluster, demand: &ResVec) -> Option<usize> {
    cluster.servers.iter().position(|s| s.fits(demand))
}

impl Scheduler for FirstFitDrfh {
    fn name(&self) -> &'static str {
        "firstfit-drfh"
    }

    fn pick(
        &mut self,
        cluster: &Cluster,
        users: &[UserState],
        eligible: &[bool],
    ) -> Pick {
        match &mut self.core {
            Some(core) => core.pick(cluster, users, eligible),
            None => match min_share_user(users, eligible) {
                None => Pick::Idle,
                Some(u) => match first_server(cluster, &users[u].demand) {
                    Some(l) => Pick::Place { user: u, server: l },
                    None => Pick::Blocked { user: u },
                },
            },
        }
    }

    /// Batched wave: one index refresh for the whole wave (the naive
    /// configuration stays on the single-pick reference loop).
    fn drain(&mut self, ctx: &mut dyn DrainCtx) {
        if self.core.is_none() {
            drain_by_picks(self, ctx);
            return;
        }
        self.core.as_mut().expect("indexed core").drain(ctx);
    }

    fn can_fit(
        &self,
        cluster: &Cluster,
        users: &[UserState],
        user: usize,
        server: usize,
    ) -> bool {
        cluster.servers[server].fits(&users[user].demand)
    }

    fn on_place(&mut self, user: usize, server: usize) {
        if let Some(core) = &mut self.core {
            core.on_touch(user, server);
        }
    }

    fn on_complete(&mut self, user: usize, server: usize) {
        if let Some(core) = &mut self.core {
            core.on_touch(user, server);
        }
    }

    fn on_ready(&mut self, user: usize) {
        if let Some(core) = &mut self.core {
            core.on_ready(user);
        }
    }

    fn on_user_join(&mut self, user: usize) {
        if let Some(core) = &mut self.core {
            core.on_user_join(user);
        }
    }

    fn on_user_leave(&mut self, user: usize) {
        if let Some(core) = &mut self.core {
            core.on_user_leave(user);
        }
    }

    fn on_server_down(&mut self, server: usize) {
        if let Some(core) = &mut self.core {
            core.on_server_down(server);
        }
    }

    fn on_server_up(&mut self, server: usize) {
        if let Some(core) = &mut self.core {
            core.on_server_up(server);
        }
    }

    fn on_topology(&mut self, shards: usize) {
        if let Some(core) = &mut self.core {
            core.set_shards(shards);
        }
    }

    fn audit_indices(
        &mut self,
        cluster: &Cluster,
        users: &[UserState],
        eligible: &[bool],
    ) -> Result<(), String> {
        // the naive path has no index to drift
        match &mut self.core {
            Some(core) => core.audit_check(cluster, users, eligible),
            None => Ok(()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Server;

    #[test]
    fn takes_lowest_index_server() {
        let cluster = Cluster::new(vec![
            Server::new(ResVec::cpu_mem(0.1, 0.1)), // too small
            Server::new(ResVec::cpu_mem(1.0, 1.0)),
            Server::new(ResVec::cpu_mem(5.0, 5.0)),
        ]);
        let users = vec![UserState {
            demand: ResVec::cpu_mem(0.5, 0.5),
            weight: 1.0,
            pending: 1,
            running: 0,
            dom_share: 0.0,
            usage: ResVec::zeros(2),
            dom_delta: 0.1,
        }];
        for mut sched in [FirstFitDrfh::default(), FirstFitDrfh::naive()] {
            assert_eq!(
                sched.pick(&cluster, &users, &[true]),
                Pick::Place { user: 0, server: 1 }
            );
        }
    }
}
