//! Dense two-phase primal simplex — the one-shot **parity reference**.
//!
//! Substrate for the exact fluid DRFH allocator (paper eq. (7) is a
//! linear program). Solves
//!
//! ```text
//!   maximize    c · x
//!   subject to  A_ub x <= b_ub
//!               A_eq x  = b_eq
//!               x >= 0
//! ```
//!
//! [`solve`] builds a dense tableau, runs phase 1 (feasibility) and
//! phase 2 (optimality), and discards everything. It is deliberately
//! the simplest correct implementation in the tree: the sparse revised
//! simplex behind the warm-startable [`super::revised::Solver`] must
//! agree with it to 1e-9 on every instance (`tests/solver_fuzz.rs`),
//! the same naive-reference discipline as `sched::index::naive` and
//! `allocator::drfh::solve_per_user`.
//!
//! Pivoting uses Dantzig's rule (most negative reduced cost) with a
//! stall detector that falls back to Bland's rule when the objective
//! stops improving, which guarantees termination on degenerate
//! instances; pivot counts are surfaced in [`PivotCounts`].

/// A linear program in standard inequality/equality form.
#[derive(Clone, Debug, Default)]
pub struct Lp {
    /// Number of structural variables.
    pub n: usize,
    /// Objective coefficients (maximized), length n.
    pub c: Vec<f64>,
    /// Inequality rows a·x <= b.
    pub a_ub: Vec<Vec<f64>>,
    pub b_ub: Vec<f64>,
    /// Equality rows a·x == b.
    pub a_eq: Vec<Vec<f64>>,
    pub b_eq: Vec<f64>,
}

/// Pivot-level accounting for one solve, surfaced in
/// [`LpResult::Optimal`] so callers can report warm-start savings.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PivotCounts {
    /// Phase-1 (feasibility search) pivots — cold solves only.
    pub phase1: u32,
    /// Phase-2 (optimality search) pivots.
    pub phase2: u32,
    /// Dual-simplex repair pivots — warm solves only.
    pub dual: u32,
    /// Basis factorization eliminations (one eta per basic column):
    /// the warm-start refactorization plus any in-solve eta-file
    /// refreshes of the sparse core. Deterministic O(rows) work, kept
    /// separate from the *search* pivots above.
    pub factor: u32,
    /// Stall events that tripped the Bland's-rule fallback.
    pub stalls: u32,
    /// True when the solve started from a reused basis.
    pub warm: bool,
}

impl PivotCounts {
    /// Search pivots: phase-1 + phase-2 + dual repair (excludes the
    /// deterministic refactorization eliminations).
    pub fn search(&self) -> u32 {
        self.phase1 + self.phase2 + self.dual
    }
}

/// Solver outcome.
#[derive(Clone, Debug, PartialEq)]
pub enum LpResult {
    Optimal { x: Vec<f64>, obj: f64, pivots: PivotCounts },
    Infeasible,
    Unbounded,
}

pub(super) const EPS: f64 = 1e-9;

struct Tableau {
    rows: usize,
    cols: usize, // structural + slack + artificial + rhs
    t: Vec<f64>,
    basis: Vec<usize>,
}

impl Tableau {
    #[inline]
    fn at(&self, r: usize, c: usize) -> f64 {
        self.t[r * self.cols + c]
    }
    #[inline]
    fn at_mut(&mut self, r: usize, c: usize) -> &mut f64 {
        &mut self.t[r * self.cols + c]
    }

    fn pivot(&mut self, pr: usize, pc: usize) {
        let cols = self.cols;
        let pv = self.at(pr, pc);
        debug_assert!(pv.abs() > EPS);
        let inv = 1.0 / pv;
        for c in 0..cols {
            *self.at_mut(pr, c) *= inv;
        }
        for r in 0..self.rows {
            if r == pr {
                continue;
            }
            let f = self.at(r, pc);
            if f.abs() > 0.0 {
                for c in 0..cols {
                    let v = self.at(pr, c);
                    *self.at_mut(r, c) -= f * v;
                }
            }
        }
        self.basis[pr - 1] = pc; // row 0 is the objective
    }

    /// Primal simplex on the current objective row (row 0), maximizing.
    /// Dantzig entering rule; a stall (no objective improvement for
    /// `rows + 16` consecutive pivots) switches to Bland's rule until
    /// the next strict improvement, which guarantees termination on
    /// degenerate instances. Returns `(bounded, pivots, stalls)`.
    fn optimize(&mut self, allowed_cols: usize) -> (bool, u32, u32) {
        let mut pivots = 0u32;
        let mut stalls = 0u32;
        let mut bland = false;
        let mut since_improve = 0u32;
        let stall_limit = self.rows as u32 + 16;
        let mut last_obj = self.at(0, self.cols - 1);
        loop {
            // entering column: reduced profit must be positive
            let mut enter = None;
            if bland {
                // lowest-index rule (anti-cycling)
                for c in 0..allowed_cols {
                    if self.at(0, c) < -EPS {
                        enter = Some(c);
                        break;
                    }
                }
            } else {
                // most negative reduced cost
                let mut best = -EPS;
                for c in 0..allowed_cols {
                    let v = self.at(0, c);
                    if v < best {
                        best = v;
                        enter = Some(c);
                    }
                }
            }
            let Some(pc) = enter else { return (true, pivots, stalls) };
            // leaving: min ratio, ties -> lowest basis index (Bland)
            let mut leave: Option<(usize, f64)> = None;
            for r in 1..self.rows {
                let a = self.at(r, pc);
                if a > EPS {
                    let ratio = self.at(r, self.cols - 1) / a;
                    match leave {
                        None => leave = Some((r, ratio)),
                        Some((br, bratio)) => {
                            if ratio < bratio - EPS
                                || (ratio < bratio + EPS
                                    && self.basis[r - 1] < self.basis[br - 1])
                            {
                                leave = Some((r, ratio));
                            }
                        }
                    }
                }
            }
            let Some((pr, _)) = leave else { return (false, pivots, stalls) };
            self.pivot(pr, pc);
            pivots += 1;
            let obj = self.at(0, self.cols - 1);
            if obj > last_obj + EPS {
                last_obj = obj;
                since_improve = 0;
                bland = false;
            } else {
                since_improve += 1;
                if !bland && since_improve >= stall_limit {
                    bland = true;
                    stalls += 1;
                }
            }
        }
    }
}

/// Solve the LP one-shot with the dense two-phase tableau. See module
/// docs for the accepted form. This is the parity reference for the
/// sparse revised [`super::revised::Solver`].
pub fn solve(lp: &Lp) -> LpResult {
    let n = lp.n;
    assert_eq!(lp.c.len(), n);
    assert_eq!(lp.a_ub.len(), lp.b_ub.len());
    assert_eq!(lp.a_eq.len(), lp.b_eq.len());
    for row in lp.a_ub.iter().chain(&lp.a_eq) {
        assert_eq!(row.len(), n);
    }
    let m = lp.a_ub.len() + lp.a_eq.len();

    // Normalize rows to b >= 0.
    // <= with b>=0 -> slack(+1);  flipped(>=) -> surplus(-1)+artificial;
    // == -> artificial.
    let mut rows_a: Vec<Vec<f64>> = Vec::with_capacity(m);
    let mut rows_b: Vec<f64> = Vec::with_capacity(m);
    let mut kind: Vec<u8> = Vec::with_capacity(m); // 0 = <=, 1 = >=, 2 = ==
    let ub = lp.a_ub.iter().zip(&lp.b_ub).map(|(a, &b)| (a, b, false));
    let eq = lp.a_eq.iter().zip(&lp.b_eq).map(|(a, &b)| (a, b, true));
    for (a, b, is_eq) in ub.chain(eq) {
        let flip = b < 0.0;
        let (a, b) = if flip {
            (a.iter().map(|&x| -x).collect(), -b)
        } else {
            (a.clone(), b)
        };
        rows_a.push(a);
        rows_b.push(b);
        kind.push(match (is_eq, flip) {
            (false, false) => 0,
            (false, true) => 1,
            (true, _) => 2,
        });
    }

    let n_slack = kind.iter().filter(|&&k| k != 2).count();
    let n_art = kind.iter().filter(|&&k| k != 0).count();
    let art_start = n + n_slack;
    let cols = n + n_slack + n_art + 1;

    let mut tab = Tableau {
        rows: m + 1,
        cols,
        t: vec![0.0; (m + 1) * cols],
        basis: vec![0; m],
    };

    // fill constraint rows
    let mut slack_i = 0;
    let mut art_i = 0;
    for r in 0..m {
        for c in 0..n {
            *tab.at_mut(r + 1, c) = rows_a[r][c];
        }
        *tab.at_mut(r + 1, cols - 1) = rows_b[r];
        match kind[r] {
            0 => {
                *tab.at_mut(r + 1, n + slack_i) = 1.0;
                tab.basis[r] = n + slack_i;
                slack_i += 1;
            }
            1 => {
                *tab.at_mut(r + 1, n + slack_i) = -1.0; // surplus
                slack_i += 1;
                *tab.at_mut(r + 1, art_start + art_i) = 1.0;
                tab.basis[r] = art_start + art_i;
                art_i += 1;
            }
            _ => {
                *tab.at_mut(r + 1, art_start + art_i) = 1.0;
                tab.basis[r] = art_start + art_i;
                art_i += 1;
            }
        }
    }

    let mut counts = PivotCounts::default();

    // ---- Phase 1: maximize -(sum of artificials) ----
    if n_art > 0 {
        for c in art_start..art_start + n_art {
            *tab.at_mut(0, c) = 1.0; // minimize sum == maximize negative
        }
        // price out: subtract artificial basic rows from objective
        for r in 0..m {
            if tab.basis[r] >= art_start {
                for c in 0..cols {
                    let v = tab.at(r + 1, c);
                    *tab.at_mut(0, c) -= v;
                }
            }
        }
        let (ok, p1, s1) = tab.optimize(cols - 1);
        counts.phase1 = p1;
        counts.stalls += s1;
        if !ok {
            // phase 1 cannot be unbounded
            return LpResult::Infeasible;
        }
        let obj1 = -tab.at(0, cols - 1);
        if obj1.abs() > 1e-6 {
            return LpResult::Infeasible;
        }
        // drive remaining basic artificials out of the basis
        for r in 0..m {
            if tab.basis[r] >= art_start {
                for c in 0..art_start {
                    if tab.at(r + 1, c).abs() > EPS {
                        tab.pivot(r + 1, c);
                        break;
                    }
                }
                // no structural pivot available: redundant row,
                // leave the artificial basic at 0
            }
        }
    }

    // ---- Phase 2: maximize c·x ----
    for c in 0..cols {
        *tab.at_mut(0, c) = 0.0;
    }
    for (c, &v) in lp.c.iter().enumerate() {
        *tab.at_mut(0, c) = -v;
    }
    // price out basic structural variables
    for r in 0..m {
        let b = tab.basis[r];
        if b < n {
            let f = lp.c[b];
            if f != 0.0 {
                for c in 0..cols {
                    let v = tab.at(r + 1, c);
                    *tab.at_mut(0, c) += f * v;
                }
            }
        }
    }
    // forbid artificials from re-entering: only structural + slack
    let (ok, p2, s2) = tab.optimize(art_start);
    counts.phase2 = p2;
    counts.stalls += s2;
    if !ok {
        return LpResult::Unbounded;
    }

    let mut x = vec![0.0; n];
    for r in 0..m {
        let b = tab.basis[r];
        if b < n {
            x[b] = tab.at(r + 1, cols - 1).max(0.0);
        }
    }
    let obj = lp.c.iter().zip(&x).map(|(a, b)| a * b).sum();
    LpResult::Optimal { x, obj, pivots: counts }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn optimal(lp: &Lp) -> (Vec<f64>, f64) {
        match solve(lp) {
            LpResult::Optimal { x, obj, .. } => (x, obj),
            other => panic!("expected optimal, got {other:?}"),
        }
    }

    #[test]
    fn basic_2d() {
        // max x + y st x <= 2, y <= 3, x + y <= 4
        let lp = Lp {
            n: 2,
            c: vec![1.0, 1.0],
            a_ub: vec![
                vec![1.0, 0.0],
                vec![0.0, 1.0],
                vec![1.0, 1.0],
            ],
            b_ub: vec![2.0, 3.0, 4.0],
            ..Default::default()
        };
        let (_, obj) = optimal(&lp);
        assert!((obj - 4.0).abs() < 1e-9);
    }

    #[test]
    fn equality_constraints() {
        // max 3x + 2y st x + y == 4, x <= 3
        let lp = Lp {
            n: 2,
            c: vec![3.0, 2.0],
            a_ub: vec![vec![1.0, 0.0]],
            b_ub: vec![3.0],
            a_eq: vec![vec![1.0, 1.0]],
            b_eq: vec![4.0],
        };
        let (x, obj) = optimal(&lp);
        assert!((x[0] - 3.0).abs() < 1e-9 && (x[1] - 1.0).abs() < 1e-9);
        assert!((obj - 11.0).abs() < 1e-9);
    }

    #[test]
    fn infeasible_detected() {
        // x <= 1, x == 2
        let lp = Lp {
            n: 1,
            c: vec![1.0],
            a_ub: vec![vec![1.0]],
            b_ub: vec![1.0],
            a_eq: vec![vec![1.0]],
            b_eq: vec![2.0],
        };
        assert_eq!(solve(&lp), LpResult::Infeasible);
    }

    #[test]
    fn unbounded_detected() {
        let lp = Lp {
            n: 2,
            c: vec![1.0, 0.0],
            a_ub: vec![vec![-1.0, 0.0]],
            b_ub: vec![0.0],
            ..Default::default()
        };
        assert_eq!(solve(&lp), LpResult::Unbounded);
    }

    #[test]
    fn negative_rhs_flips_to_ge() {
        // max -x st -x <= -2  (i.e. x >= 2); optimum x = 2
        let lp = Lp {
            n: 1,
            c: vec![-1.0],
            a_ub: vec![vec![-1.0]],
            b_ub: vec![-2.0],
            ..Default::default()
        };
        let (x, obj) = optimal(&lp);
        assert!((x[0] - 2.0).abs() < 1e-9);
        assert!((obj + 2.0).abs() < 1e-9);
    }

    #[test]
    fn degenerate_does_not_cycle() {
        // classic degeneracy example (cycles under unguarded Dantzig;
        // the stall detector's Bland fallback must terminate it)
        let lp = Lp {
            n: 4,
            c: vec![0.75, -150.0, 0.02, -6.0],
            a_ub: vec![
                vec![0.25, -60.0, -0.04, 9.0],
                vec![0.5, -90.0, -0.02, 3.0],
                vec![0.0, 0.0, 1.0, 0.0],
            ],
            b_ub: vec![0.0, 0.0, 1.0],
            ..Default::default()
        };
        let (_, obj) = optimal(&lp);
        assert!((obj - 0.05).abs() < 1e-6, "obj={obj}");
    }

    #[test]
    fn drfh_fig3_shape() {
        // the paper's eq.(7) for the Fig.1 example, class-aggregated:
        // users d1=(1/5,1), d2=(1,1/5); servers c1=(2,12), c2=(12,2)
        // (absolute units; demand normalized vectors scaled by dominant
        //  D: user1 dom share unit consumes (0.2, 1.0), user2 (1.0, 0.2)
        //  per *task*; with task = 1 GB mem for u1, 1 CPU for u2 —
        //  variables g_il in units of dominant-resource *fraction*).
        // Here we solve in task units: x_il tasks of user i on server l.
        // max g; per server: sum_i x_il * D_i <= c_l; per user:
        // sum_l x_il * Ddom_i/total_dom = g.
        // u1: D=(0.2,1), dom resource mem, total mem 14.
        // u2: D=(1,0.2), dom cpu, total cpu 14.
        let lp = Lp {
            n: 5, // x11 x12 x21 x22 g
            c: vec![0.0, 0.0, 0.0, 0.0, 1.0],
            a_ub: vec![
                // server 1 cpu: .2 x11 + 1 x21 <= 2
                vec![0.2, 0.0, 1.0, 0.0, 0.0],
                // server 1 mem: 1 x11 + .2 x21 <= 12
                vec![1.0, 0.0, 0.2, 0.0, 0.0],
                // server 2 cpu: .2 x12 + 1 x22 <= 12
                vec![0.0, 0.2, 0.0, 1.0, 0.0],
                // server 2 mem: 1 x12 + .2 x22 <= 2
                vec![0.0, 1.0, 0.0, 0.2, 0.0],
            ],
            b_ub: vec![2.0, 12.0, 12.0, 2.0],
            a_eq: vec![
                // user 1: (x11 + x12)/14 == g
                vec![1.0 / 14.0, 1.0 / 14.0, 0.0, 0.0, -1.0],
                // user 2: (x21 + x22)/14 == g
                vec![0.0, 0.0, 1.0 / 14.0, 1.0 / 14.0, -1.0],
            ],
            b_eq: vec![0.0, 0.0],
        };
        let (x, obj) = optimal(&lp);
        // paper: g = 5/7, 10 tasks each
        assert!((obj - 5.0 / 7.0).abs() < 1e-6, "g={obj}");
        assert!((x[0] + x[1] - 10.0).abs() < 1e-6);
        assert!((x[2] + x[3] - 10.0).abs() < 1e-6);
    }

    #[test]
    fn random_lps_feasible_and_consistent() {
        use crate::util::Pcg32;
        let mut rng = Pcg32::seeded(99);
        for trial in 0..50 {
            let n = 2 + rng.below(4);
            let mu = 1 + rng.below(4);
            let c: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let a_ub: Vec<Vec<f64>> = (0..mu)
                .map(|_| (0..n).map(|_| rng.uniform(0.0, 1.0)).collect())
                .collect();
            let b_ub: Vec<f64> = (0..mu).map(|_| rng.uniform(0.5, 2.0)).collect();
            let lp = Lp { n, c, a_ub, b_ub, ..Default::default() };
            // all-positive rows with positive b and bounded x -> optimal
            match solve(&lp) {
                LpResult::Optimal { x, obj, .. } => {
                    for (row, &b) in lp.a_ub.iter().zip(&lp.b_ub) {
                        let lhs: f64 =
                            row.iter().zip(&x).map(|(a, v)| a * v).sum();
                        assert!(lhs <= b + 1e-6, "trial {trial} violated");
                    }
                    assert!(x.iter().all(|&v| v >= -1e-9));
                    let cobj: f64 =
                        lp.c.iter().zip(&x).map(|(a, v)| a * v).sum();
                    assert!((cobj - obj).abs() < 1e-6);
                    // objective at least as good as x = 0
                    assert!(obj >= -1e-9);
                }
                LpResult::Unbounded => {
                    // possible if some c_j > 0 has a zero column; rows are
                    // dense positive so only if a coefficient drew ~0 —
                    // accept but ensure some positive c exists
                    assert!(lp.c.iter().any(|&v| v > 0.0));
                }
                LpResult::Infeasible => panic!("trial {trial} infeasible"),
            }
        }
    }

    #[test]
    fn pivot_counts_surfaced() {
        let lp = Lp {
            n: 2,
            c: vec![1.0, 1.0],
            a_ub: vec![vec![1.0, 0.0], vec![0.0, 1.0], vec![1.0, 1.0]],
            b_ub: vec![2.0, 3.0, 4.0],
            ..Default::default()
        };
        match solve(&lp) {
            LpResult::Optimal { pivots, .. } => {
                assert!(pivots.phase2 > 0, "{pivots:?}");
                assert!(!pivots.warm);
                assert_eq!(pivots.dual, 0);
            }
            other => panic!("{other:?}"),
        }
    }
}
