//! The exact fluid DRFH allocation (paper Sec. IV, eq. (7)):
//!
//! ```text
//!   max  g    s.t.  Σ_i g_il · d_ir <= c_lr   ∀ server l, resource r
//!                   Σ_l g_il = w_i · g        ∀ user i
//! ```
//!
//! Identical servers are collapsed into classes (`Cluster::classes()`):
//! any class-level allocation can be split evenly across its members, so
//! the LP shrinks from `n·k` to `n·C` variables (C <= 10 for the Google
//! Table I pool) while remaining exact.
//!
//! The same argument collapses the *user* axis. Users with bit-identical
//! normalized demand row, weight, and cap are interchangeable in eq. (7):
//! averaging any feasible solution over the members of such an
//! **allocation class** preserves feasibility (the capacity rows only see
//! class totals) and every per-user equality row `Σ_l g_il = frozen_i +
//! w·δ` forces the same total on each member. [`solve`] therefore builds
//! one variable block per allocation class — `Σ_c x_Ac − k_A·w_A·δ =
//! k_A·frozen_A` for a class of `k_A` members — and recovers per-user
//! shares by deterministic equal split (`x_i = x_A / k_A`, bitwise
//! identical across members). LP size scales with (server classes ×
//! demand classes), independent of the user count; demand rows are
//! interned through the same `workload::intern_rows` the class-keyed
//! scheduler uses.
//!
//! Finite task demands (paper Sec. V-A) are handled by progressive
//! filling rounds: all unsaturated users' dominant shares grow at rates
//! proportional to their weights until one hits its cap, which freezes
//! it; repeat until no user can grow. Class members share one cap (the
//! cap is part of the class key), so classes saturate atomically.
//!
//! [`solve_per_user`] keeps the seed's one-variable-block-per-user LP in
//! tree as the from-scratch parity reference (the `::naive()` convention
//! of `sched::index`) for the classed path, and [`solve`] itself is the
//! reference for [`super::incremental::IncrementalDrfh`], which maintains
//! the same classed LP statefully and re-solves from a warm simplex
//! basis across rounds and join/departure/cap/weight events.

use super::NormalizedDemand;
use crate::cluster::{Cluster, ResVec, ServerClass};
use crate::sched::effective_weight;
use crate::solver::{self, Lp, LpResult};
use crate::workload::intern_rows;
use std::collections::HashMap;

/// A user as seen by the fluid allocator.
#[derive(Clone, Debug)]
pub struct FluidUser {
    /// Per-task demand in absolute units.
    pub demand: ResVec,
    /// Fair-share weight (1.0 = unweighted).
    pub weight: f64,
    /// Max number of (fractional) tasks the user can use; None = infinite.
    pub task_cap: Option<f64>,
}

impl FluidUser {
    pub fn unweighted(demand: ResVec) -> Self {
        FluidUser { demand, weight: 1.0, task_cap: None }
    }
}

/// The fluid DRFH allocation.
#[derive(Clone, Debug)]
pub struct FluidAllocation {
    /// Server classes the solution is expressed over.
    pub classes: Vec<ServerClass>,
    /// Pool totals (absolute units).
    pub total: ResVec,
    /// Normalized demands (paper terms) per user.
    pub demands: Vec<NormalizedDemand>,
    /// x[i][c]: global dominant share user i draws from class c.
    pub x: Vec<Vec<f64>>,
    /// g_i = Σ_c x[i][c]: each user's global dominant share.
    pub g: Vec<f64>,
    /// Number of (fractional) tasks each user schedules.
    pub tasks: Vec<f64>,
    /// Simplex search pivots spent across the progressive-filling
    /// rounds that produced this allocation (warm-start savings show
    /// up here — see `allocator::incremental`).
    pub lp_pivots: u64,
    /// Number of LP solves (one per progressive-filling round).
    pub lp_solves: u32,
    /// Allocation classes the LP was actually built over: distinct
    /// (demand row, weight, cap) triples for the classed path, the raw
    /// user count for the per-user reference path.
    pub alloc_classes: usize,
}

impl FluidAllocation {
    /// Resource vector (pool-share units) user i holds in class c:
    /// A_ic = x_ic · d_i (Lemma 1 — non-wasteful allocations are
    /// proportional to the normalized demand).
    pub fn alloc_share(&self, i: usize, c: usize) -> ResVec {
        self.demands[i].norm.scale(self.x[i][c])
    }

    /// Resource vector (absolute units) user i holds in class c.
    pub fn alloc_absolute(&self, i: usize, c: usize) -> ResVec {
        let s = self.alloc_share(i, c);
        let mut a = s;
        for r in 0..a.dims() {
            a[r] = s[r] * self.total[r];
        }
        a
    }

    /// The minimum dominant share across users (the maximized objective
    /// for unweighted, uncapped instances).
    pub fn min_share(&self) -> f64 {
        self.g.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    /// Feasibility check: per class and resource, allocations within
    /// capacity (share units), with tolerance.
    pub fn is_feasible(&self, eps: f64) -> bool {
        let m = self.total.dims();
        for (c, class) in self.classes.iter().enumerate() {
            for r in 0..m {
                let cap_share =
                    class.capacity[r] * class.count as f64 / self.total[r];
                let used: f64 = (0..self.demands.len())
                    .map(|i| self.x[i][c] * self.demands[i].norm[r])
                    .sum();
                if used > cap_share + eps {
                    return false;
                }
            }
        }
        true
    }
}

/// Per-user inputs the progressive-filling loops need, shared by the
/// classed and per-user paths: guarded weights, normalized demands,
/// caps in dominant-share units, class capacities in pool-share units.
struct Inputs {
    weights: Vec<f64>,
    demands: Vec<NormalizedDemand>,
    caps: Vec<f64>,
    cap_share: Vec<ResVec>,
}

fn inputs(
    classes: &[ServerClass],
    total: &ResVec,
    users: &[FluidUser],
) -> Inputs {
    let m = total.dims();
    // Guarded weights throughout: trace validation allows weight 0
    // (ranked as weight 1.0 everywhere via `sched::effective_weight`);
    // the raw value here would put inf in the delta cap and a zero
    // growth coefficient in the equality rows, freezing the user at 0.
    let weights: Vec<f64> =
        users.iter().map(|u| effective_weight(u.weight)).collect();
    let demands: Vec<NormalizedDemand> = users
        .iter()
        .map(|u| NormalizedDemand::from_absolute(&u.demand, total))
        .collect();
    // caps in dominant-share units
    let caps: Vec<f64> = users
        .iter()
        .zip(&demands)
        .map(|(u, d)| {
            u.task_cap
                .map(|t| t * d.share[d.dominant])
                .unwrap_or(f64::INFINITY)
        })
        .collect();
    // class capacity in pool-share units
    let cap_share: Vec<ResVec> = classes
        .iter()
        .map(|c| {
            let mut v = ResVec::zeros(m);
            for r in 0..m {
                v[r] = c.capacity[r] * c.count as f64 / total[r];
            }
            v
        })
        .collect();
    Inputs { weights, demands, caps, cap_share }
}

/// Solve the exact fluid DRFH allocation for `users` on `cluster`
/// (class-collapsed LP — see the module docs).
pub fn solve(cluster: &Cluster, users: &[FluidUser]) -> FluidAllocation {
    solve_classes(&cluster.classes(), &cluster.total_capacity(), users)
}

/// The well-defined allocation for a pool with an exhausted resource
/// (a fault plan can crash every server holding one — see
/// `sim::faults`): everybody gets zero. Without this guard the
/// capacity rows divide by the zero total and feed NaN/inf into the
/// simplex. Demands still normalize finitely
/// ([`NormalizedDemand::from_absolute`] zero-total semantics).
fn empty_allocation(
    classes: &[ServerClass],
    total: &ResVec,
    users: &[FluidUser],
) -> FluidAllocation {
    let n = users.len();
    let demands: Vec<NormalizedDemand> = users
        .iter()
        .map(|u| NormalizedDemand::from_absolute(&u.demand, total))
        .collect();
    FluidAllocation {
        classes: classes.to_vec(),
        total: *total,
        demands,
        x: vec![vec![0.0; classes.len()]; n],
        g: vec![0.0; n],
        tasks: vec![0.0; n],
        lp_pivots: 0,
        lp_solves: 0,
        alloc_classes: 0,
    }
}

/// Same, over pre-aggregated server classes.
pub fn solve_classes(
    classes: &[ServerClass],
    total: &ResVec,
    users: &[FluidUser],
) -> FluidAllocation {
    let n = users.len();
    let nc = classes.len();
    let m = total.dims();
    if (0..m).any(|r| total[r] <= 0.0) {
        return empty_allocation(classes, total, users);
    }
    let Inputs { weights, demands, caps, cap_share } =
        inputs(classes, total, users);

    // Allocation classes: distinct (normalized demand row, weight,
    // cap) triples, all compared by exact bit pattern. The demand rows
    // go through the scheduler's interner; weight and cap key the
    // second level.
    let (_, drow_class) = intern_rows(demands.iter().map(|d| &d.norm));
    let mut class_of: Vec<usize> = Vec::with_capacity(n);
    let mut members: Vec<usize> = Vec::new(); // per class
    let mut rep: Vec<usize> = Vec::new(); // representative user
    // order-independent HashMap use (lint hash-iter rule): keyed
    // `entry` lookups only, never iterated — class ids are assigned by
    // input order (first appearance), not by map order
    let mut seen: HashMap<(u32, u64, u64), usize> = HashMap::new();
    for i in 0..n {
        let key =
            (drow_class[i], weights[i].to_bits(), caps[i].to_bits());
        let a = *seen.entry(key).or_insert_with(|| {
            rep.push(i);
            members.push(0);
            rep.len() - 1
        });
        members[a] += 1;
        class_of.push(a);
    }
    let na = rep.len();

    // Per-allocation-class state (members are bit-identical, so they
    // freeze and saturate together): frozen = per-member dominant
    // share fixed so far.
    let a_weight: Vec<f64> = rep.iter().map(|&i| weights[i]).collect();
    let a_cap: Vec<f64> = rep.iter().map(|&i| caps[i]).collect();
    let mut frozen = vec![0.0f64; na];
    let mut saturated = vec![false; na];
    let mut xa = vec![vec![0.0f64; nc]; na];
    let mut lp_pivots = 0u64;
    let mut lp_solves = 0u32;

    // Classes already at cap 0 are trivially saturated.
    for a in 0..na {
        if a_cap[a] <= 1e-15 {
            saturated[a] = true;
        }
    }

    for _round in 0..na + 1 {
        if saturated.iter().all(|&s| s) {
            break;
        }
        // LP variables: x_Ac (na·nc class totals) then delta.
        let nv = na * nc + 1;
        let var = |a: usize, c: usize| a * nc + c;
        let dvar = nv - 1;

        let mut c_obj = vec![0.0; nv];
        c_obj[dvar] = 1.0;

        let mut a_ub: Vec<Vec<f64>> = Vec::new();
        let mut b_ub: Vec<f64> = Vec::new();
        // server-class capacity rows (class totals already include the
        // member count — no per-user fan-out)
        for (c, cs) in cap_share.iter().enumerate() {
            for r in 0..m {
                let mut row = vec![0.0; nv];
                for (a, &ri) in rep.iter().enumerate() {
                    row[var(a, c)] = demands[ri].norm[r];
                }
                a_ub.push(row);
                b_ub.push(cs[r]);
            }
        }
        // delta bounded by the tightest remaining cap among active
        // classes (per-member units, identical within a class)
        let mut delta_max = f64::INFINITY;
        for a in 0..na {
            if !saturated[a] && a_cap[a].is_finite() {
                delta_max =
                    delta_max.min((a_cap[a] - frozen[a]) / a_weight[a]);
            }
        }
        if delta_max.is_finite() {
            let mut row = vec![0.0; nv];
            row[dvar] = 1.0;
            a_ub.push(row);
            b_ub.push(delta_max.max(0.0));
        }

        let mut a_eq: Vec<Vec<f64>> = Vec::new();
        let mut b_eq: Vec<f64> = Vec::new();
        for a in 0..na {
            let k = members[a] as f64;
            let mut row = vec![0.0; nv];
            for c in 0..nc {
                row[var(a, c)] = 1.0;
            }
            if saturated[a] {
                // frozen classes keep their total dominant share
                a_eq.push(row);
                b_eq.push(k * frozen[a]);
            } else {
                row[dvar] = -k * a_weight[a];
                a_eq.push(row);
                b_eq.push(k * frozen[a]);
            }
        }

        let lp = Lp { n: nv, c: c_obj, a_ub, b_ub, a_eq, b_eq };
        let (sol, delta) = match solver::solve(&lp) {
            LpResult::Optimal { x, obj, pivots } => {
                lp_pivots += pivots.search() as u64;
                lp_solves += 1;
                (x, obj)
            }
            other => panic!("DRFH round LP not optimal: {other:?}"),
        };
        // commit class totals
        for a in 0..na {
            for c in 0..nc {
                xa[a][c] = sol[var(a, c)];
            }
        }
        if delta <= 1e-12 {
            break; // capacity exhausted for all active classes
        }
        let mut newly = 0;
        for a in 0..na {
            if !saturated[a] {
                frozen[a] += a_weight[a] * delta;
                if a_cap[a].is_finite() && frozen[a] >= a_cap[a] - 1e-9 {
                    frozen[a] = a_cap[a];
                    saturated[a] = true;
                    newly += 1;
                }
            }
        }
        if newly == 0 {
            break; // no cap hit: capacity-limited optimum reached
        }
    }

    // Recover per-user shares: deterministic equal split within each
    // class — one division per (class, server class), fanned out, so
    // members are bitwise identical.
    let mut x = vec![vec![0.0f64; nc]; n];
    let split: Vec<Vec<f64>> = (0..na)
        .map(|a| {
            let k = members[a] as f64;
            (0..nc).map(|c| xa[a][c] / k).collect()
        })
        .collect();
    for i in 0..n {
        x[i].copy_from_slice(&split[class_of[i]]);
    }

    let g: Vec<f64> = x.iter().map(|xi| xi.iter().sum()).collect();
    let tasks: Vec<f64> = g
        .iter()
        .zip(&demands)
        .map(|(&gi, d)| gi / d.share[d.dominant])
        .collect();
    FluidAllocation {
        classes: classes.to_vec(),
        total: *total,
        demands,
        x,
        g,
        tasks,
        lp_pivots,
        lp_solves,
        alloc_classes: na,
    }
}

/// Per-user-variable reference: the seed's LP with one variable block
/// per user. Exponentially larger than [`solve`] on class-collapsible
/// populations — kept as the parity reference and bench baseline.
pub fn solve_per_user(
    cluster: &Cluster,
    users: &[FluidUser],
) -> FluidAllocation {
    solve_classes_per_user(
        &cluster.classes(),
        &cluster.total_capacity(),
        users,
    )
}

/// Same, over pre-aggregated server classes.
pub fn solve_classes_per_user(
    classes: &[ServerClass],
    total: &ResVec,
    users: &[FluidUser],
) -> FluidAllocation {
    let n = users.len();
    let nc = classes.len();
    let m = total.dims();
    if (0..m).any(|r| total[r] <= 0.0) {
        return empty_allocation(classes, total, users);
    }
    let Inputs { weights, demands, caps, cap_share } =
        inputs(classes, total, users);

    // Progressive filling: frozen[i] = dominant share fixed so far.
    let mut frozen = vec![0.0f64; n];
    let mut saturated = vec![false; n];
    let mut x = vec![vec![0.0f64; nc]; n];
    let mut lp_pivots = 0u64;
    let mut lp_solves = 0u32;

    // Users already at cap 0 are trivially saturated.
    for i in 0..n {
        if caps[i] <= 1e-15 {
            saturated[i] = true;
        }
    }

    for _round in 0..n + 1 {
        if saturated.iter().all(|&s| s) {
            break;
        }
        // LP variables: x_ic (n·nc) then delta.
        let nv = n * nc + 1;
        let var = |i: usize, c: usize| i * nc + c;
        let dvar = nv - 1;

        let mut c_obj = vec![0.0; nv];
        c_obj[dvar] = 1.0;

        let mut a_ub: Vec<Vec<f64>> = Vec::new();
        let mut b_ub: Vec<f64> = Vec::new();
        // class capacity rows
        for (c, cs) in cap_share.iter().enumerate() {
            for r in 0..m {
                let mut row = vec![0.0; nv];
                for i in 0..n {
                    row[var(i, c)] = demands[i].norm[r];
                }
                a_ub.push(row);
                b_ub.push(cs[r]);
            }
        }
        // delta bounded by the tightest remaining cap among active users
        let mut delta_max = f64::INFINITY;
        for i in 0..n {
            if !saturated[i] && caps[i].is_finite() {
                delta_max = delta_max.min((caps[i] - frozen[i]) / weights[i]);
            }
        }
        if delta_max.is_finite() {
            let mut row = vec![0.0; nv];
            row[dvar] = 1.0;
            a_ub.push(row);
            b_ub.push(delta_max.max(0.0));
        }

        let mut a_eq: Vec<Vec<f64>> = Vec::new();
        let mut b_eq: Vec<f64> = Vec::new();
        for i in 0..n {
            let mut row = vec![0.0; nv];
            for c in 0..nc {
                row[var(i, c)] = 1.0;
            }
            if saturated[i] {
                // frozen users keep their total dominant share
                a_eq.push(row);
                b_eq.push(frozen[i]);
            } else {
                row[dvar] = -weights[i];
                a_eq.push(row);
                b_eq.push(frozen[i]);
            }
        }

        let lp = Lp { n: nv, c: c_obj, a_ub, b_ub, a_eq, b_eq };
        let (sol, delta) = match solver::solve(&lp) {
            LpResult::Optimal { x, obj, pivots } => {
                lp_pivots += pivots.search() as u64;
                lp_solves += 1;
                (x, obj)
            }
            other => panic!("DRFH round LP not optimal: {other:?}"),
        };
        // commit
        for i in 0..n {
            for c in 0..nc {
                x[i][c] = sol[var(i, c)];
            }
        }
        if delta <= 1e-12 {
            break; // capacity exhausted for all active users
        }
        let mut newly = 0;
        for i in 0..n {
            if !saturated[i] {
                frozen[i] += weights[i] * delta;
                if caps[i].is_finite() && frozen[i] >= caps[i] - 1e-9 {
                    frozen[i] = caps[i];
                    saturated[i] = true;
                    newly += 1;
                }
            }
        }
        if newly == 0 {
            break; // no cap hit: capacity-limited optimum reached
        }
    }

    let g: Vec<f64> = x.iter().map(|xi| xi.iter().sum()).collect();
    let tasks: Vec<f64> = g
        .iter()
        .zip(&demands)
        .map(|(&gi, d)| gi / d.share[d.dominant])
        .collect();
    FluidAllocation {
        classes: classes.to_vec(),
        total: *total,
        demands,
        x,
        g,
        tasks,
        lp_pivots,
        lp_solves,
        alloc_classes: n,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;

    fn fig1_users() -> Vec<FluidUser> {
        vec![
            FluidUser::unweighted(ResVec::cpu_mem(0.2, 1.0)),
            FluidUser::unweighted(ResVec::cpu_mem(1.0, 0.2)),
        ]
    }

    #[test]
    fn paper_fig3_exact_allocation() {
        // DRFH on the Fig. 1 example: g = 5/7, 10 tasks each (Fig. 3)
        let cluster = Cluster::fig1_example();
        let a = solve(&cluster, &fig1_users());
        assert!((a.g[0] - 5.0 / 7.0).abs() < 1e-6, "g1={}", a.g[0]);
        assert!((a.g[1] - 5.0 / 7.0).abs() < 1e-6, "g2={}", a.g[1]);
        assert!((a.tasks[0] - 10.0).abs() < 1e-5);
        assert!((a.tasks[1] - 10.0).abs() < 1e-5);
        assert!(a.is_feasible(1e-9));
        assert_eq!(a.alloc_classes, 2);
    }

    #[test]
    fn single_server_reduces_to_drf() {
        // one server (9 CPU, 18 GB); users (1,4) and (3,1) — the DRF
        // paper's canonical example: equalized dominant shares
        let cluster =
            Cluster::from_capacities(&[ResVec::cpu_mem(9.0, 18.0)]);
        let users = vec![
            FluidUser::unweighted(ResVec::cpu_mem(1.0, 4.0)),
            FluidUser::unweighted(ResVec::cpu_mem(3.0, 1.0)),
        ];
        let a = solve(&cluster, &users);
        // DRF: user1 gets 3 tasks (12 GB = 2/3 mem), user2 gets 2 tasks
        // (6 CPU = 2/3 cpu)
        assert!((a.g[0] - a.g[1]).abs() < 1e-6);
        assert!((a.g[0] - 2.0 / 3.0).abs() < 1e-6, "g={}", a.g[0]);
        assert!((a.tasks[0] - 3.0).abs() < 1e-5);
        assert!((a.tasks[1] - 2.0).abs() < 1e-5);
    }

    #[test]
    fn single_resource_max_min() {
        let cluster = Cluster::from_capacities(&[
            ResVec::from_slice(&[6.0]),
            ResVec::from_slice(&[4.0]),
        ]);
        let users = vec![
            FluidUser::unweighted(ResVec::from_slice(&[1.0])),
            FluidUser::unweighted(ResVec::from_slice(&[2.0])),
        ];
        let a = solve(&cluster, &users);
        // max-min: each gets half the pool (5 units) regardless of demand
        assert!((a.g[0] - 0.5).abs() < 1e-6);
        assert!((a.g[1] - 0.5).abs() < 1e-6);
        assert!((a.tasks[0] - 5.0).abs() < 1e-5);
        assert!((a.tasks[1] - 2.5).abs() < 1e-5);
    }

    #[test]
    fn weights_scale_shares() {
        let cluster = Cluster::fig1_example();
        let mut users = fig1_users();
        users[0].weight = 2.0;
        let a = solve(&cluster, &users);
        // weighted max-min: g_1 / 2 == g_2
        assert!(
            (a.g[0] - 2.0 * a.g[1]).abs() < 1e-6,
            "g = {:?}",
            a.g
        );
        assert!(a.is_feasible(1e-9));
    }

    /// Regression: a legal weight-0 user (trace validation allows
    /// them) must rank as weight 1.0 — the raw weight put `inf` in the
    /// delta cap and a zero growth coefficient in the user's equality
    /// row, freezing it at zero dominant share.
    #[test]
    fn zero_weight_user_uses_guarded_semantics() {
        let cluster = Cluster::fig1_example();
        let mut users = fig1_users();
        users[0].weight = 0.0;
        let a = solve(&cluster, &users);
        assert!(a.g.iter().all(|g| g.is_finite()), "g = {:?}", a.g);
        assert!(a.is_feasible(1e-9));
        // effective weights (1.0, 1.0): same optimum as the unweighted
        // Fig. 3 instance, g = 5/7 each
        assert!((a.g[0] - 5.0 / 7.0).abs() < 1e-6, "g1 = {}", a.g[0]);
        assert!((a.g[1] - 5.0 / 7.0).abs() < 1e-6, "g2 = {}", a.g[1]);

        // capped weight-0 user: the cap still binds at the guarded rate
        users[0].task_cap = Some(2.0);
        let a = solve(&cluster, &users);
        assert!((a.tasks[0] - 2.0).abs() < 1e-5, "tasks = {:?}", a.tasks);
        assert!(a.tasks[1] > 10.0, "user 2 should absorb the release");
    }

    #[test]
    fn finite_caps_release_resources() {
        let cluster = Cluster::fig1_example();
        let mut users = fig1_users();
        // user 1 only needs 2 tasks; user 2 should then grab more
        users[0].task_cap = Some(2.0);
        let a = solve(&cluster, &users);
        assert!((a.tasks[0] - 2.0).abs() < 1e-5, "tasks={:?}", a.tasks);
        assert!(a.tasks[1] > 10.0, "user 2 should exceed equal share");
        assert!(a.is_feasible(1e-9));
    }

    #[test]
    fn zero_cap_user_is_inactive() {
        let cluster = Cluster::fig1_example();
        let mut users = fig1_users();
        users[0].task_cap = Some(0.0);
        let a = solve(&cluster, &users);
        assert!(a.tasks[0].abs() < 1e-9);
        assert!(a.tasks[1] > 11.0, "tasks={:?}", a.tasks);
    }

    /// Regression: a resource whose pool total hit zero (every server
    /// holding it crashed) must yield the empty allocation, not NaN/inf
    /// capacity rows inside the simplex.
    #[test]
    fn exhausted_resource_yields_empty_allocation() {
        let users = fig1_users();
        for caps in [
            vec![ResVec::cpu_mem(0.0, 12.0)], // one resource exhausted
            vec![ResVec::cpu_mem(0.0, 0.0)],  // pool fully gone
            vec![ResVec::cpu_mem(0.0, 4.0), ResVec::cpu_mem(0.0, 8.0)],
        ] {
            let cluster = Cluster::from_capacities(&caps);
            for a in
                [solve(&cluster, &users), solve_per_user(&cluster, &users)]
            {
                assert!(a.g.iter().all(|&g| g == 0.0), "g = {:?}", a.g);
                assert!(a.tasks.iter().all(|&t| t == 0.0));
                assert!(a
                    .x
                    .iter()
                    .all(|xi| xi.iter().all(|&v| v == 0.0)));
                assert_eq!(a.lp_solves, 0);
                assert_eq!(a.alloc_classes, 0);
                assert!(a
                    .demands
                    .iter()
                    .all(|d| d.norm.as_slice().iter().all(|v| v.is_finite())));
            }
        }
    }

    #[test]
    fn bottleneck_fairness() {
        // both users dominant on CPU -> equal CPU shares (max-min)
        let cluster = Cluster::fig1_example();
        let users = vec![
            FluidUser::unweighted(ResVec::cpu_mem(1.0, 0.1)),
            FluidUser::unweighted(ResVec::cpu_mem(1.0, 0.5)),
        ];
        let a = solve(&cluster, &users);
        assert!((a.g[0] - a.g[1]).abs() < 1e-6);
        // CPU is everyone's dominant resource, so the CPU share consumed
        // equals the sum of dominant shares (max-min over CPU under the
        // per-server packing constraints)
        let cpu_used: f64 = (0..2)
            .map(|i| {
                (0..a.classes.len())
                    .map(|c| a.alloc_share(i, c)[0])
                    .sum::<f64>()
            })
            .sum();
        assert!(
            (cpu_used - (a.g[0] + a.g[1])).abs() < 1e-9,
            "cpu_used={cpu_used} vs g sum {}",
            a.g[0] + a.g[1]
        );
    }

    #[test]
    fn many_random_instances_feasible_and_equalized() {
        use crate::util::Pcg32;
        let mut rng = Pcg32::seeded(21);
        for trial in 0..20 {
            let k = 2 + rng.below(6);
            let caps: Vec<ResVec> = (0..k)
                .map(|_| {
                    ResVec::cpu_mem(rng.uniform(1.0, 8.0), rng.uniform(1.0, 8.0))
                })
                .collect();
            let cluster = Cluster::from_capacities(&caps);
            let n = 2 + rng.below(5);
            let users: Vec<FluidUser> = (0..n)
                .map(|_| {
                    FluidUser::unweighted(ResVec::cpu_mem(
                        rng.uniform(0.05, 1.0),
                        rng.uniform(0.05, 1.0),
                    ))
                })
                .collect();
            let a = solve(&cluster, &users);
            assert!(a.is_feasible(1e-6), "trial {trial} infeasible");
            // uncapped unweighted DRFH equalizes all dominant shares
            let gmin = a.g.iter().cloned().fold(f64::INFINITY, f64::min);
            let gmax = a.g.iter().cloned().fold(0.0, f64::max);
            assert!(
                gmax - gmin < 1e-6,
                "trial {trial}: shares not equalized {:?}",
                a.g
            );
            assert!(gmin > 0.0, "trial {trial}: zero share");
        }
    }

    // ---- class collapse ------------------------------------------

    /// Duplicated users collapse into one variable block, and the
    /// equal split hands every member a bitwise-identical share.
    #[test]
    fn duplicate_users_collapse_and_split_exactly() {
        let cluster = Cluster::fig1_example();
        let mut users = Vec::new();
        for _ in 0..6 {
            users.push(FluidUser::unweighted(ResVec::cpu_mem(0.2, 1.0)));
        }
        for _ in 0..4 {
            users.push(FluidUser {
                demand: ResVec::cpu_mem(1.0, 0.2),
                weight: 2.0,
                task_cap: Some(3.0),
            });
        }
        let a = solve(&cluster, &users);
        assert_eq!(a.alloc_classes, 2, "10 users, 2 allocation classes");
        // bitwise-equal shares within each class (f64 ==, not a
        // tolerance: the split is one division fanned out)
        for i in 1..6 {
            assert_eq!(a.g[0], a.g[i], "class-0 split not exact");
            assert_eq!(a.x[0], a.x[i]);
        }
        for i in 7..10 {
            assert_eq!(a.g[6], a.g[i], "class-1 split not exact");
            assert_eq!(a.x[6], a.x[i]);
        }
        assert!(a.is_feasible(1e-9));
    }

    /// A user whose demand row differs by one ulp must NOT share a
    /// class — bit-identical semantics above all.
    #[test]
    fn class_key_is_bitwise() {
        let cluster = Cluster::fig1_example();
        let d = ResVec::cpu_mem(0.2, 1.0);
        let mut d2 = d;
        d2[0] = f64::from_bits(d[0].to_bits() + 1);
        let users = vec![
            FluidUser::unweighted(d),
            FluidUser::unweighted(d),
            FluidUser::unweighted(d2),
        ];
        let a = solve(&cluster, &users);
        assert_eq!(a.alloc_classes, 2);
        // weight and cap are part of the key too
        let mut w = FluidUser::unweighted(d);
        w.weight = 2.0;
        let mut cp = FluidUser::unweighted(d);
        cp.task_cap = Some(5.0);
        let a = solve(
            &cluster,
            &[
                FluidUser::unweighted(d),
                w,
                cp,
                FluidUser::unweighted(d),
            ],
        );
        assert_eq!(a.alloc_classes, 3);
    }

    /// The classed LP must agree with the per-user reference LP on
    /// random class-collapsible instances: same shares, caps, weights.
    #[test]
    fn classed_matches_per_user_reference() {
        use crate::util::Pcg32;
        let mut rng = Pcg32::seeded(77);
        for trial in 0..15 {
            let k = 2 + rng.below(4);
            let caps: Vec<ResVec> = (0..k)
                .map(|_| {
                    ResVec::cpu_mem(rng.uniform(2.0, 8.0), rng.uniform(2.0, 8.0))
                })
                .collect();
            let cluster = Cluster::from_capacities(&caps);
            // a few archetypes, many members each
            let narch = 1 + rng.below(3);
            let archetypes: Vec<FluidUser> = (0..narch)
                .map(|a| FluidUser {
                    demand: ResVec::cpu_mem(
                        rng.uniform(0.05, 0.8),
                        rng.uniform(0.05, 0.8),
                    ),
                    weight: 1.0 + a as f64,
                    task_cap: if rng.below(2) == 0 {
                        Some(1.0 + rng.below(8) as f64)
                    } else {
                        None
                    },
                })
                .collect();
            let n = 3 + rng.below(6);
            let users: Vec<FluidUser> =
                (0..n).map(|i| archetypes[i % narch].clone()).collect();
            let classed = solve(&cluster, &users);
            let reference = solve_per_user(&cluster, &users);
            assert!(
                classed.alloc_classes <= narch,
                "trial {trial}: {} classes for {narch} archetypes",
                classed.alloc_classes
            );
            for i in 0..n {
                assert!(
                    (classed.g[i] - reference.g[i]).abs() < 1e-7,
                    "trial {trial} user {i}: classed {} vs per-user {}",
                    classed.g[i],
                    reference.g[i]
                );
            }
            assert!(classed.is_feasible(1e-6), "trial {trial}");
        }
    }
}
