//! Randomized cross-validation of the two simplex cores: the sparse
//! revised solver (`solver::Solver`, product-form inverse + eta file)
//! against the dense two-phase tableau (`solver::solve`), which stays
//! in-tree precisely to be this reference.
//!
//! Instance families: guaranteed-feasible (random point + slacked
//! rows, so negative coefficients and bounded/unbounded mixes all
//! occur), certificate-infeasible (appended nonnegative row with
//! negative rhs), certificate-unbounded (costed variable absent from
//! every row), and degenerate (duplicated rows/columns, zero-slack
//! rows). On every instance both cores must return the same result
//! variant, and optimal objectives must agree to **1e-9** (relative);
//! both `x` vectors are checked feasible against the raw LP data.
//!
//! The edit-stream test drives the sparse solver's warm path through
//! random `set_rhs` / `set_coeff` / `set_obj` / row-(de)activation /
//! var-append / fix-unfix sequences while an independently maintained
//! shadow LP is solved dense from scratch after every edit — warm and
//! scratch must never disagree.
//!
//! `SOLVER_FUZZ_SMOKE=1` shrinks the trial counts for the dedicated
//! CI step; the full counts run in the regular `cargo test` pass.

use drfh::solver::{self, Lp, LpResult, RowId, Solver, VarId};
use drfh::util::Pcg32;

fn smoke() -> bool {
    std::env::var_os("SOLVER_FUZZ_SMOKE").is_some()
}

/// Guaranteed-feasible instance: draw a nonnegative point `x0`, then
/// give every `<=` row a nonnegative slack at `x0` and every `==` row
/// the exact rhs. Coefficients may be negative, so boundedness is NOT
/// guaranteed — both cores must agree on Unbounded too.
fn solvable_lp(rng: &mut Pcg32) -> Lp {
    let n = 1 + rng.below(6);
    let mu = 1 + rng.below(6);
    let me = if rng.f64() < 0.4 { rng.below(3) } else { 0 };
    let x0: Vec<f64> = (0..n)
        .map(|_| if rng.f64() < 0.3 { 0.0 } else { rng.uniform(0.0, 3.0) })
        .collect();
    let mut lp = Lp {
        n,
        c: (0..n).map(|_| rng.uniform(-2.0, 3.0)).collect(),
        ..Lp::default()
    };
    for _ in 0..mu {
        let row: Vec<f64> = (0..n)
            .map(|_| {
                if rng.f64() < 0.35 {
                    0.0
                } else {
                    rng.uniform(-1.5, 2.5)
                }
            })
            .collect();
        let at_x0: f64 = row.iter().zip(&x0).map(|(a, x)| a * x).sum();
        // zero slack with some probability: degenerate vertex at x0
        let slack =
            if rng.f64() < 0.25 { 0.0 } else { rng.uniform(0.0, 4.0) };
        lp.b_ub.push(at_x0 + slack);
        lp.a_ub.push(row);
    }
    for _ in 0..me {
        let row: Vec<f64> = (0..n)
            .map(|_| {
                if rng.f64() < 0.35 {
                    0.0
                } else {
                    rng.uniform(-1.0, 2.0)
                }
            })
            .collect();
        let at_x0: f64 = row.iter().zip(&x0).map(|(a, x)| a * x).sum();
        lp.b_eq.push(at_x0);
        lp.a_eq.push(row);
    }
    lp
}

/// Certificate-infeasible: a nonnegative row with negative rhs can
/// never be satisfied by x >= 0.
fn infeasible_lp(rng: &mut Pcg32) -> Lp {
    let mut lp = solvable_lp(rng);
    let row: Vec<f64> =
        (0..lp.n).map(|_| rng.uniform(0.1, 1.0)).collect();
    let rhs = -rng.uniform(0.5, 2.0);
    if rng.f64() < 0.5 {
        lp.a_ub.push(row);
        lp.b_ub.push(rhs);
    } else {
        lp.a_eq.push(row);
        lp.b_eq.push(rhs);
    }
    lp
}

/// Certificate-unbounded: append a variable with positive cost that
/// appears in no row of the (feasible) instance.
fn unbounded_lp(rng: &mut Pcg32) -> Lp {
    let mut lp = solvable_lp(rng);
    lp.n += 1;
    lp.c.push(rng.uniform(0.5, 2.0));
    for row in lp.a_ub.iter_mut().chain(lp.a_eq.iter_mut()) {
        row.push(0.0);
    }
    lp
}

/// Degeneracy stress: duplicate a row and a column of a solvable
/// instance verbatim.
fn degenerate_lp(rng: &mut Pcg32) -> Lp {
    let mut lp = solvable_lp(rng);
    if !lp.a_ub.is_empty() {
        let r = rng.below(lp.a_ub.len());
        lp.a_ub.push(lp.a_ub[r].clone());
        lp.b_ub.push(lp.b_ub[r]);
    }
    let j = rng.below(lp.n);
    lp.n += 1;
    lp.c.push(lp.c[j]);
    for row in lp.a_ub.iter_mut().chain(lp.a_eq.iter_mut()) {
        let a = row[j];
        row.push(a);
    }
    lp
}

fn assert_feasible(lp: &Lp, x: &[f64], ctx: &str) {
    assert_eq!(x.len(), lp.n, "{ctx}: solution length");
    for (j, &xj) in x.iter().enumerate() {
        assert!(xj >= -1e-9, "{ctx}: x[{j}] = {xj} negative");
    }
    for (i, row) in lp.a_ub.iter().enumerate() {
        let lhs: f64 = row.iter().zip(x).map(|(a, v)| a * v).sum();
        assert!(
            lhs <= lp.b_ub[i] + 1e-6 * (1.0 + lp.b_ub[i].abs()),
            "{ctx}: ub row {i} violated: {lhs} > {}",
            lp.b_ub[i]
        );
    }
    for (i, row) in lp.a_eq.iter().enumerate() {
        let lhs: f64 = row.iter().zip(x).map(|(a, v)| a * v).sum();
        assert!(
            (lhs - lp.b_eq[i]).abs() <= 1e-6 * (1.0 + lp.b_eq[i].abs()),
            "{ctx}: eq row {i} violated: {lhs} != {}",
            lp.b_eq[i]
        );
    }
}

/// The core check: identical result variant; on Optimal, objectives
/// within 1e-9 (relative) and both solutions feasible.
fn check_parity(lp: &Lp, ctx: &str) {
    let dense = solver::solve(lp);
    let sparse = Solver::from_lp(lp).solve();
    match (&dense, &sparse) {
        (
            LpResult::Optimal { x: xd, obj: od, .. },
            LpResult::Optimal { x: xs, obj: os, .. },
        ) => {
            assert!(
                (od - os).abs() <= 1e-9 * (1.0 + od.abs()),
                "{ctx}: objective parity: dense {od} vs sparse {os}"
            );
            assert_feasible(lp, xd, &format!("{ctx} dense"));
            assert_feasible(lp, xs, &format!("{ctx} sparse"));
            // the sparse objective is consistent with its own x
            let dot: f64 = lp.c.iter().zip(xs).map(|(c, v)| c * v).sum();
            assert!(
                (dot - os).abs() <= 1e-7 * (1.0 + os.abs()),
                "{ctx}: sparse obj {os} vs c.x {dot}"
            );
        }
        (LpResult::Infeasible, LpResult::Infeasible)
        | (LpResult::Unbounded, LpResult::Unbounded) => {}
        _ => panic!(
            "{ctx}: result variant mismatch: dense {dense:?} vs sparse \
             {sparse:?}"
        ),
    }
}

#[test]
fn sparse_dense_parity_on_random_instances() {
    let trials = if smoke() { 40 } else { 160 };
    let mut rng = Pcg32::seeded(0xF0221);
    for t in 0..trials {
        let lp = solvable_lp(&mut rng);
        check_parity(&lp, &format!("solvable trial {t}"));
    }
}

#[test]
fn infeasible_and_unbounded_instances_agree() {
    let trials = if smoke() { 20 } else { 80 };
    let mut rng = Pcg32::seeded(0xF0222);
    for t in 0..trials {
        let lp = infeasible_lp(&mut rng);
        let ctx = format!("infeasible trial {t}");
        assert_eq!(
            solver::solve(&lp),
            LpResult::Infeasible,
            "{ctx}: dense"
        );
        check_parity(&lp, &ctx);

        let lp = unbounded_lp(&mut rng);
        let ctx = format!("unbounded trial {t}");
        assert_eq!(solver::solve(&lp), LpResult::Unbounded, "{ctx}: dense");
        check_parity(&lp, &ctx);
    }
}

#[test]
fn degenerate_instances_agree() {
    let trials = if smoke() { 20 } else { 80 };
    let mut rng = Pcg32::seeded(0xF0223);
    for t in 0..trials {
        let lp = degenerate_lp(&mut rng);
        check_parity(&lp, &format!("degenerate trial {t}"));
    }
}

// ---- warm-vs-cold edit streams ------------------------------------

/// Dense mirror of the incrementally edited solver state. Fixed
/// variables are only ever fixed at 0.0 here, so mirroring them is
/// "column vanishes": zero objective + zero coefficients.
struct Shadow {
    obj: Vec<f64>,
    fixed: Vec<bool>,
    rows: Vec<ShadowRow>,
}

struct ShadowRow {
    coeffs: Vec<f64>,
    rhs: f64,
    eq: bool,
    active: bool,
}

impl Shadow {
    fn to_lp(&self) -> Lp {
        let n = self.obj.len();
        let mut lp = Lp {
            n,
            c: (0..n)
                .map(|j| if self.fixed[j] { 0.0 } else { self.obj[j] })
                .collect(),
            ..Lp::default()
        };
        for row in &self.rows {
            if !row.active {
                continue;
            }
            let coeffs: Vec<f64> = (0..n)
                .map(|j| if self.fixed[j] { 0.0 } else { row.coeffs[j] })
                .collect();
            if row.eq {
                lp.a_eq.push(coeffs);
                lp.b_eq.push(row.rhs);
            } else {
                lp.a_ub.push(coeffs);
                lp.b_ub.push(row.rhs);
            }
        }
        lp
    }
}

#[test]
fn warm_vs_cold_after_edit_streams() {
    let streams = if smoke() { 6 } else { 18 };
    let edits = if smoke() { 12 } else { 24 };
    for stream in 0..streams {
        let mut rng = Pcg32::seeded(0xED17 + stream);
        // seed state: a solvable instance, loaded into both sides
        let lp0 = solvable_lp(&mut rng);
        let mut s = Solver::new();
        let mut vids: Vec<VarId> = Vec::new();
        let mut rids: Vec<RowId> = Vec::new();
        let mut shadow = Shadow {
            obj: lp0.c.clone(),
            fixed: vec![false; lp0.n],
            rows: Vec::new(),
        };
        for &c in &lp0.c {
            vids.push(s.add_var(c));
        }
        for (row, &rhs) in lp0.a_ub.iter().zip(&lp0.b_ub) {
            let coeffs: Vec<(VarId, f64)> = vids
                .iter()
                .zip(row)
                .filter(|(_, &a)| a != 0.0)
                .map(|(&v, &a)| (v, a))
                .collect();
            rids.push(s.add_row_le(&coeffs, rhs));
            shadow.rows.push(ShadowRow {
                coeffs: row.clone(),
                rhs,
                eq: false,
                active: true,
            });
        }
        for (row, &rhs) in lp0.a_eq.iter().zip(&lp0.b_eq) {
            let coeffs: Vec<(VarId, f64)> = vids
                .iter()
                .zip(row)
                .filter(|(_, &a)| a != 0.0)
                .map(|(&v, &a)| (v, a))
                .collect();
            rids.push(s.add_row_eq(&coeffs, rhs));
            shadow.rows.push(ShadowRow {
                coeffs: row.clone(),
                rhs,
                eq: true,
                active: true,
            });
        }

        for ev in 0..edits {
            let ctx = format!("stream {stream} edit {ev}");
            let r = rng.f64();
            if r < 0.25 {
                let i = rng.below(rids.len());
                let rhs = rng.uniform(-1.0, 5.0);
                s.set_rhs(rids[i], rhs);
                shadow.rows[i].rhs = rhs;
            } else if r < 0.45 {
                let i = rng.below(rids.len());
                let j = rng.below(vids.len());
                let a = if rng.f64() < 0.25 {
                    0.0
                } else {
                    rng.uniform(-1.5, 2.5)
                };
                s.set_coeff(rids[i], vids[j], a);
                shadow.rows[i].coeffs[j] = a;
            } else if r < 0.6 {
                let j = rng.below(vids.len());
                let c = rng.uniform(-2.0, 3.0);
                s.set_obj(vids[j], c);
                shadow.obj[j] = c;
            } else if r < 0.7 {
                let i = rng.below(rids.len());
                if shadow.rows[i].active {
                    s.deactivate_row(rids[i]);
                    shadow.rows[i].active = false;
                } else {
                    s.activate_row(rids[i]);
                    shadow.rows[i].active = true;
                }
            } else if r < 0.8 {
                let j = rng.below(vids.len());
                if shadow.fixed[j] {
                    s.unfix_var(vids[j]);
                    shadow.fixed[j] = false;
                } else {
                    s.fix_var(vids[j], 0.0);
                    shadow.fixed[j] = true;
                }
            } else if r < 0.9 {
                let c = rng.uniform(-1.0, 2.0);
                vids.push(s.add_var(c));
                shadow.obj.push(c);
                shadow.fixed.push(false);
                for row in &mut shadow.rows {
                    row.coeffs.push(0.0);
                }
            } else {
                let coeffs: Vec<f64> = (0..vids.len())
                    .map(|_| {
                        if rng.f64() < 0.5 {
                            0.0
                        } else {
                            rng.uniform(-1.0, 2.0)
                        }
                    })
                    .collect();
                let rhs = rng.uniform(0.0, 5.0);
                let sparse_coeffs: Vec<(VarId, f64)> = vids
                    .iter()
                    .zip(&coeffs)
                    .filter(|(_, &a)| a != 0.0)
                    .map(|(&v, &a)| (v, a))
                    .collect();
                rids.push(s.add_row_le(&sparse_coeffs, rhs));
                shadow.rows.push(ShadowRow {
                    coeffs,
                    rhs,
                    eq: false,
                    active: true,
                });
            }

            let warm = s.solve();
            let mirror = shadow.to_lp();
            let dense = solver::solve(&mirror);
            let cold = Solver::from_lp(&mirror).solve();
            match (&dense, &warm) {
                (
                    LpResult::Optimal { obj: od, .. },
                    LpResult::Optimal { x: xw, obj: ow, .. },
                ) => {
                    assert!(
                        (od - ow).abs() <= 1e-9 * (1.0 + od.abs()),
                        "{ctx}: warm obj {ow} vs dense {od}"
                    );
                    // the warm solution, restricted to unfixed
                    // columns, must satisfy the mirror LP
                    assert_feasible(&mirror, xw, &format!("{ctx} warm"));
                }
                (LpResult::Infeasible, LpResult::Infeasible)
                | (LpResult::Unbounded, LpResult::Unbounded) => {}
                _ => panic!(
                    "{ctx}: dense {dense:?} vs warm {warm:?}"
                ),
            }
            assert_eq!(
                std::mem::discriminant(&cold),
                std::mem::discriminant(&warm),
                "{ctx}: cold-sparse vs warm-sparse variant"
            );
        }
        let st = s.stats();
        assert!(
            st.warm_solves > 0,
            "stream {stream}: warm path never engaged: {st:?}"
        );
    }
}
