//! Quickstart: the paper's worked example (Fig. 1-3) through the
//! public API.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Builds the two-server heterogeneous cluster of Fig. 1, solves the
//! exact fluid DRFH allocation (Fig. 3), contrasts it with the naive
//! per-server DRF allocation (Fig. 2), and then replays the same
//! instance through the discrete Best-Fit scheduler to show the
//! implementation converges to the fluid optimum.

use drfh::allocator::{self, per_server_drf, FluidUser};
use drfh::cluster::{Cluster, ResVec};
use drfh::sched::BestFitDrfh;
use drfh::sim::{run, SimOpts};
use drfh::workload::{JobSpec, TaskSpec, Trace, UserSpec};

fn main() {
    println!("=== DRFH quickstart: the paper's Fig. 1 example ===\n");

    // Fig. 1: server 1 = (2 CPU, 12 GB), server 2 = (12 CPU, 2 GB);
    // user 1 tasks need (0.2 CPU, 1 GB), user 2 tasks (1 CPU, 0.2 GB).
    let cluster = Cluster::fig1_example();
    let demands = [ResVec::cpu_mem(0.2, 1.0), ResVec::cpu_mem(1.0, 0.2)];
    println!("cluster: {} servers, total {} (CPU, GB)", cluster.len(),
             cluster.total_capacity());
    for (i, d) in demands.iter().enumerate() {
        println!("user {}: per-task demand {}", i + 1, d);
    }

    // --- naive per-server DRF (paper Fig. 2): 6 tasks per user -------
    let naive = per_server_drf::solve(&cluster, &demands);
    let naive_tasks = naive.tasks_per_user();
    println!("\n-- naive per-server DRF (paper Fig. 2) --");
    for (i, t) in naive_tasks.iter().enumerate() {
        println!("user {}: {:.1} tasks", i + 1, t);
    }

    // --- exact fluid DRFH (paper Fig. 3): 10 tasks per user ----------
    let users: Vec<FluidUser> =
        demands.iter().map(|d| FluidUser::unweighted(*d)).collect();
    let fluid = allocator::solve(&cluster, &users);
    println!("\n-- exact fluid DRFH (paper Fig. 3) --");
    for i in 0..2 {
        println!(
            "user {}: global dominant share g = {:.4} (paper: 5/7 ≈ 0.7143), \
             {:.1} tasks",
            i + 1,
            fluid.g[i],
            fluid.tasks[i]
        );
    }

    // --- discrete Best-Fit DRFH converges to the fluid optimum -------
    let trace = Trace {
        users: demands
            .iter()
            .map(|d| UserSpec { demand: *d, weight: 1.0 })
            .collect(),
        jobs: (0..2)
            .map(|u| JobSpec {
                id: u,
                user: u,
                submit: 0.0,
                tasks: vec![TaskSpec { duration: 1_000.0 }; 12],
            })
            .collect(),
    };
    let report = run(
        cluster,
        &trace,
        Box::new(BestFitDrfh::default()),
        SimOpts { horizon: 10.0, sample_dt: 5.0, track_user_series: false, ..SimOpts::default() },
    );
    println!("\n-- discrete Best-Fit DRFH scheduler --");
    println!(
        "placed {} tasks (fluid optimum: 20 = 10 + 10)",
        report.tasks_placed
    );
    assert_eq!(report.tasks_placed, 20, "discrete != fluid optimum");
    println!("\nOK: Best-Fit DRFH reproduces the paper's allocation.");
}
