//! Regenerates paper Fig. 5 (utilization time series: Best-Fit vs
//! First-Fit vs Slots) and times each scheduler's full simulation —
//! this is the end-to-end §Perf driver for the L3 hot path.
//!
//! Run: `cargo bench --bench fig5_utilization`
//! Full scale: `drfh exp fig5 --servers 2000`

use drfh::experiments::{fig5, EvalSetup};
use drfh::sched::{BestFitDrfh, FirstFitDrfh, SlotsScheduler};
use drfh::sim::run;
use drfh::util::bench::{bench, header};
use std::time::Duration;

fn main() {
    let setup = EvalSetup::with_duration(42, 300, 30, 21_600.0);
    let res = fig5::run_fig5(&setup);
    fig5::print(&res);

    header("fig5: full simulation per scheduler (300 servers, 6 h)");
    bench("bestfit-drfh", Duration::from_secs(5), 20, || {
        run(
            setup.cluster.clone(),
            &setup.trace,
            Box::new(BestFitDrfh::default()),
            setup.opts.clone(),
        )
        .tasks_completed
    });
    bench("firstfit-drfh", Duration::from_secs(5), 20, || {
        run(
            setup.cluster.clone(),
            &setup.trace,
            Box::new(FirstFitDrfh::default()),
            setup.opts.clone(),
        )
        .tasks_completed
    });
    bench("slots-14", Duration::from_secs(5), 20, || {
        run(
            setup.cluster.clone(),
            &setup.trace,
            Box::new(SlotsScheduler::new(&setup.cluster, 14)),
            setup.opts.clone(),
        )
        .tasks_completed
    });
}
