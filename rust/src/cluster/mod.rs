//! The heterogeneous server pool: resource vectors, servers, clusters,
//! and the Google Table I configuration distribution.

pub mod pool;
pub mod server;
pub mod shard;
pub mod vector;

pub use pool::{Cluster, ServerClass, GOOGLE_CLASSES};
pub use server::{Server, FIT_EPS};
pub use shard::{ShardCount, ShardSpec};
pub use vector::{ResVec, MAX_RES};
