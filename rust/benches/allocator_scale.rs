//! §Perf bench: the exact fluid DRFH allocator — one-shot solves as
//! users and cluster size grow, the per-server DRF baseline, and the
//! headline case: **event-stream incremental vs from-scratch** dynamic
//! DRFH (join/depart/cap/weight churn, re-equalized after every
//! event). The warm-started path must beat the from-scratch re-solves
//! on the k = 2000 configs; because container timers are unreliable,
//! the deterministic simplex **search-pivot counts** are recorded next
//! to the wall-clock numbers and are the primary savings metric.
//! A user-count sweep (10³ → 10⁶ users over ~10 demand archetypes)
//! pins down the class-collapse claim: the LP's variable count — and
//! near enough its solve time — stays flat while the per-user
//! reference LP grows a variable block per user.
//!
//! All case groups fan out on `experiments::runner` (quiet timing on
//! the workers, rows printed after each fan-out). Results go to
//! `BENCH_allocator.json` at the repo root (override with
//! `BENCH_OUT=/path.json`); CI runs `ALLOC_SMOKE=1` for a small-scale
//! smoke pass.
//!
//! Run: `cargo bench --bench allocator_scale`

use drfh::allocator::incremental::{IncrementalDrfh, UserId};
use drfh::allocator::{self, per_server_drf, FluidUser};
use drfh::cluster::{Cluster, ResVec};
use drfh::experiments::runner::{self, Job};
use drfh::solver::SolveStats;
use drfh::util::bench::{bench_n_quiet, header, write_suite_json, BenchResult};
use drfh::util::json::Json;
use drfh::util::Pcg32;

/// One dynamic-sharing event. Indices are taken modulo the live user
/// count at apply time, so warm and scratch appliers stay in lockstep.
#[derive(Clone, Debug)]
enum Ev {
    Join(FluidUser),
    Depart(usize),
    SetCap(usize, Option<f64>),
    SetWeight(usize, f64),
}

fn random_user(rng: &mut Pcg32) -> FluidUser {
    FluidUser {
        demand: ResVec::cpu_mem(
            rng.uniform(0.02, 0.5),
            rng.uniform(0.02, 0.5),
        ),
        weight: if rng.f64() < 0.3 { rng.uniform(0.5, 3.0) } else { 1.0 },
        task_cap: if rng.f64() < 0.4 {
            Some(rng.uniform(5.0, 400.0))
        } else {
            None
        },
    }
}

fn event_stream(
    seed: u64,
    initial: usize,
    events: usize,
) -> (Vec<FluidUser>, Vec<Ev>) {
    let mut rng = Pcg32::seeded(seed);
    let init: Vec<FluidUser> =
        (0..initial).map(|_| random_user(&mut rng)).collect();
    let mut n = initial;
    let mut evs = Vec::with_capacity(events);
    for _ in 0..events {
        let r = rng.f64();
        if (r < 0.30 && n < 2 * initial) || n <= 2 {
            evs.push(Ev::Join(random_user(&mut rng)));
            n += 1;
        } else if r < 0.50 {
            evs.push(Ev::Depart(rng.below(n)));
            n -= 1;
        } else if r < 0.75 {
            let cap = if rng.f64() < 0.5 {
                Some(rng.uniform(5.0, 400.0))
            } else {
                None
            };
            evs.push(Ev::SetCap(rng.below(n), cap));
        } else {
            evs.push(Ev::SetWeight(rng.below(n), rng.uniform(0.25, 4.0)));
        }
    }
    (init, evs)
}

/// Warm path: one solver/basis across the whole stream. Returns a
/// trajectory checksum (Σ of all dominant shares), total search
/// pivots, and the stream's cumulative solver accounting (the
/// `dual_cap_hits` counter in particular: a non-zero value means the
/// dual-simplex repair gave up mid-stream and fell back cold — worth
/// surfacing next to the pivot savings it erodes).
fn run_warm(
    cluster: &Cluster,
    init: &[FluidUser],
    evs: &[Ev],
) -> (f64, u64, SolveStats) {
    let mut inc = IncrementalDrfh::new(cluster);
    let mut ids: Vec<UserId> =
        init.iter().map(|u| inc.add_user(u.clone())).collect();
    let mut check = 0.0f64;
    let mut pivots = 0u64;
    let a = inc.allocate();
    pivots += a.lp_pivots;
    check += a.g.iter().sum::<f64>();
    for ev in evs {
        match ev {
            Ev::Join(u) => ids.push(inc.add_user(u.clone())),
            Ev::Depart(i) => {
                let id = ids.remove(i % ids.len());
                inc.remove_user(id);
            }
            Ev::SetCap(i, cap) => inc.set_cap(ids[i % ids.len()], *cap),
            Ev::SetWeight(i, w) => inc.set_weight(ids[i % ids.len()], *w),
        }
        let a = inc.allocate();
        pivots += a.lp_pivots;
        check += a.g.iter().sum::<f64>();
    }
    let stats = inc.solver_stats();
    (check, pivots, stats)
}

/// From-scratch reference: identical event applications on a plain
/// user vector, full `allocator::solve` after every event.
fn run_scratch(
    cluster: &Cluster,
    init: &[FluidUser],
    evs: &[Ev],
) -> (f64, u64) {
    let mut users: Vec<FluidUser> = init.to_vec();
    let mut check = 0.0f64;
    let mut pivots = 0u64;
    let a = allocator::solve(cluster, &users);
    pivots += a.lp_pivots;
    check += a.g.iter().sum::<f64>();
    for ev in evs {
        match ev {
            Ev::Join(u) => users.push(u.clone()),
            Ev::Depart(i) => {
                let i = i % users.len();
                users.remove(i);
            }
            Ev::SetCap(i, cap) => {
                let i = i % users.len();
                users[i].task_cap = *cap;
            }
            Ev::SetWeight(i, w) => {
                let i = i % users.len();
                users[i].weight = *w;
            }
        }
        let a = allocator::solve(cluster, &users);
        pivots += a.lp_pivots;
        check += a.g.iter().sum::<f64>();
    }
    (check, pivots)
}

struct StreamCase {
    tag: String,
    warm: BenchResult,
    scratch: BenchResult,
    warm_pivots: u64,
    scratch_pivots: u64,
    /// Times the warm path's dual-simplex repair hit its iteration cap
    /// and forced a cold fallback (from `SolveStats::dual_cap_hits`).
    dual_cap_hits: u64,
}

fn stream_case(
    servers: usize,
    users: usize,
    events: usize,
    iters: usize,
    seed: u64,
) -> StreamCase {
    let mut rng = Pcg32::seeded(seed);
    let cluster = Cluster::google_sample(servers, &mut rng);
    let (init, evs) = event_stream(seed * 31 + 7, users, events);
    let mut warm_pivots = 0u64;
    let mut warm_check = 0.0f64;
    let mut dual_cap_hits = 0u64;
    let warm = bench_n_quiet(
        &format!("stream-warm k={servers} n={users} e={events}"),
        iters,
        || {
            let (c, p, st) = run_warm(&cluster, &init, &evs);
            warm_check = c;
            warm_pivots = p;
            dual_cap_hits = st.dual_cap_hits;
            p
        },
    );
    let mut scratch_pivots = 0u64;
    let mut scratch_check = 0.0f64;
    let scratch = bench_n_quiet(
        &format!("stream-scratch k={servers} n={users} e={events}"),
        iters,
        || {
            let (c, p) = run_scratch(&cluster, &init, &evs);
            scratch_check = c;
            scratch_pivots = p;
            p
        },
    );
    // cheap parity guard (tests/incremental_parity.rs is the real proof)
    assert!(
        (warm_check - scratch_check).abs()
            <= 1e-6 * (1.0 + warm_check.abs()),
        "k={servers} n={users}: trajectory checksum diverged: \
         warm {warm_check} vs scratch {scratch_check}"
    );
    StreamCase {
        tag: format!("k{servers}_n{users}"),
        warm,
        scratch,
        warm_pivots,
        scratch_pivots,
        dual_cap_hits,
    }
}

/// One user-count sweep point: the class-collapsed LP must keep its
/// size (and near enough its solve time) flat as the user count grows
/// past it by orders of magnitude.
struct SweepCase {
    n: usize,
    classed: BenchResult,
    /// Per-user-variable reference — only run while tractable.
    per_user: Option<BenchResult>,
    alloc_classes: usize,
    lp_vars: usize,
    /// LP variable-count change from one more join on a live class
    /// (must be zero: the acceptance criterion for class-keyed state).
    join_lp_vars_delta: usize,
}

fn main() {
    let smoke = std::env::var_os("ALLOC_SMOKE").is_some();
    let mut results: Vec<BenchResult> = Vec::new();
    let mut meta: Vec<(String, Json)> = vec![
        ("smoke".to_string(), Json::Bool(smoke)),
        ("estimated".to_string(), Json::Bool(false)),
    ];

    // ---- one-shot solves, fanned out on the sweep runtime ---------
    let one_shot: &[(usize, usize)] = if smoke {
        &[(200, 8)]
    } else {
        &[(100, 5), (500, 20), (2000, 50), (2000, 100), (12_583, 100)]
    };
    let iters = if smoke { 2 } else { 5 };
    header("exact fluid DRFH one-shot solve (Table I classes)");
    let jobs: Vec<Job<'_, (BenchResult, u64)>> = one_shot
        .iter()
        .map(|&(servers, users)| {
            let job: Job<'_, (BenchResult, u64)> = Box::new(move || {
                let mut rng = Pcg32::seeded(7);
                let cluster = if servers == 12_583 {
                    Cluster::google_full()
                } else {
                    Cluster::google_sample(servers, &mut rng)
                };
                let fluid: Vec<FluidUser> = (0..users)
                    .map(|_| {
                        FluidUser::unweighted(ResVec::cpu_mem(
                            rng.uniform(0.02, 0.5),
                            rng.uniform(0.02, 0.5),
                        ))
                    })
                    .collect();
                let mut pivots = 0u64;
                let r = bench_n_quiet(
                    &format!("drfh solve k={servers} n={users}"),
                    iters,
                    || {
                        let a = allocator::solve(&cluster, &fluid);
                        pivots = a.lp_pivots;
                        a.g.len()
                    },
                );
                (r, pivots)
            });
            job
        })
        .collect();
    for (r, pivots) in runner::run_parallel(jobs) {
        r.print();
        println!("{:<44} {pivots} search pivots per solve", "");
        results.push(r);
    }

    // ---- event streams: incremental vs from-scratch ---------------
    let streams: &[(usize, usize, usize)] = if smoke {
        &[(200, 8, 12)]
    } else {
        &[(2000, 50, 60), (2000, 100, 60)]
    };
    let stream_iters = if smoke { 1 } else { 3 };
    header("dynamic DRFH event streams: incremental vs from-scratch");
    let jobs: Vec<Job<'_, StreamCase>> = streams
        .iter()
        .map(|&(k, n, e)| {
            let job: Job<'_, StreamCase> = Box::new(move || {
                stream_case(k, n, e, stream_iters, 40 + n as u64)
            });
            job
        })
        .collect();
    for case in runner::run_parallel(jobs) {
        case.warm.print();
        case.scratch.print();
        let speedup = case.scratch.mean.as_secs_f64()
            / case.warm.mean.as_secs_f64().max(1e-12);
        let pivot_ratio = case.scratch_pivots as f64
            / case.warm_pivots.max(1) as f64;
        println!(
            "{:<44} pivots {} -> {} ({pivot_ratio:.1}x fewer), \
             {speedup:.2}x wall-clock",
            format!("  {}", case.tag),
            case.scratch_pivots,
            case.warm_pivots
        );
        if case.warm_pivots >= case.scratch_pivots {
            println!(
                "WARNING: {} warm path did not reduce search pivots",
                case.tag
            );
        }
        meta.push((
            format!("stream_{}_pivots_warm", case.tag),
            Json::Num(case.warm_pivots as f64),
        ));
        meta.push((
            format!("stream_{}_pivots_scratch", case.tag),
            Json::Num(case.scratch_pivots as f64),
        ));
        meta.push((
            format!("stream_{}_pivot_ratio", case.tag),
            Json::Num(pivot_ratio),
        ));
        meta.push((
            format!("stream_{}_speedup_wallclock", case.tag),
            Json::Num(speedup),
        ));
        meta.push((
            format!("stream_{}_dual_cap_hits", case.tag),
            Json::Num(case.dual_cap_hits as f64),
        ));
        results.push(case.warm);
        results.push(case.scratch);
    }

    // ---- user-count sweep: classed LP vs per-user LP ---------------
    // ~10 demand archetypes regardless of n, so the collapsed LP keeps
    // ~10 variable blocks while the per-user reference grows a block
    // per user; the reference is only run while it stays tractable.
    let user_sweep: &[usize] = if smoke {
        &[1_000]
    } else {
        &[1_000, 10_000, 100_000, 1_000_000]
    };
    const PER_USER_MAX: usize = 1_000;
    let sweep_iters = if smoke { 1 } else { 2 };
    header("user-count sweep at 10 demand classes: classed vs per-user");
    let jobs: Vec<Job<'_, SweepCase>> = user_sweep
        .iter()
        .map(|&n| {
            let job: Job<'_, SweepCase> = Box::new(move || {
                let mut rng = Pcg32::seeded(2024);
                let cluster = Cluster::google_sample(200, &mut rng);
                let archetypes: Vec<ResVec> = (0..10)
                    .map(|_| {
                        ResVec::cpu_mem(
                            rng.uniform(0.02, 0.5),
                            rng.uniform(0.02, 0.5),
                        )
                    })
                    .collect();
                let users: Vec<FluidUser> = (0..n)
                    .map(|i| FluidUser::unweighted(archetypes[i % 10]))
                    .collect();
                let mut alloc_classes = 0usize;
                let classed = bench_n_quiet(
                    &format!("classed solve n={n}"),
                    sweep_iters,
                    || {
                        let a = allocator::solve(&cluster, &users);
                        alloc_classes = a.alloc_classes;
                        a.g.len()
                    },
                );
                let per_user = (n <= PER_USER_MAX).then(|| {
                    bench_n_quiet(
                        &format!("per-user solve n={n}"),
                        sweep_iters,
                        || allocator::solve_per_user(&cluster, &users).g.len(),
                    )
                });
                // LP-shape introspection via the standing allocator:
                // one more member of a live class appends nothing
                let mut inc = IncrementalDrfh::new(&cluster);
                for u in &users {
                    inc.add_user(u.clone());
                }
                let lp_vars = inc.lp_vars();
                inc.add_user(FluidUser::unweighted(archetypes[0]));
                let join_lp_vars_delta = inc.lp_vars() - lp_vars;
                SweepCase {
                    n,
                    classed,
                    per_user,
                    alloc_classes,
                    lp_vars,
                    join_lp_vars_delta,
                }
            });
            job
        })
        .collect();
    for case in runner::run_parallel(jobs) {
        case.classed.print();
        let n = case.n;
        println!(
            "{:<44} {} classes, {} LP vars, join delta {}",
            format!("  users_{n}"),
            case.alloc_classes,
            case.lp_vars,
            case.join_lp_vars_delta
        );
        if case.join_lp_vars_delta != 0 {
            println!(
                "WARNING: users_{n} join on a live class appended {} vars",
                case.join_lp_vars_delta
            );
        }
        meta.push((
            format!("users_{n}_alloc_classes"),
            Json::Num(case.alloc_classes as f64),
        ));
        meta.push((
            format!("users_{n}_lp_vars"),
            Json::Num(case.lp_vars as f64),
        ));
        meta.push((
            format!("users_{n}_join_lp_vars_delta"),
            Json::Num(case.join_lp_vars_delta as f64),
        ));
        results.push(case.classed);
        if let Some(per_user) = case.per_user {
            per_user.print();
            let speedup = per_user.mean.as_secs_f64()
                / case.classed.mean.as_secs_f64().max(1e-12);
            println!(
                "{:<44} {speedup:.2}x classed speedup",
                format!("  users_{n}")
            );
            meta.push((
                format!("users_{n}_speedup_classed"),
                Json::Num(speedup),
            ));
            results.push(per_user);
        }
    }

    // ---- finite caps (progressive rounds) -------------------------
    let capped: &[usize] = if smoke { &[8] } else { &[20, 50] };
    let capped_servers = if smoke { 200 } else { 1000 };
    header("exact solve with finite caps (progressive rounds)");
    let jobs: Vec<Job<'_, BenchResult>> = capped
        .iter()
        .map(|&users| {
            let job: Job<'_, BenchResult> = Box::new(move || {
                let mut rng = Pcg32::seeded(11);
                let cluster =
                    Cluster::google_sample(capped_servers, &mut rng);
                let fluid: Vec<FluidUser> = (0..users)
                    .map(|i| FluidUser {
                        demand: ResVec::cpu_mem(
                            rng.uniform(0.02, 0.5),
                            rng.uniform(0.02, 0.5),
                        ),
                        weight: 1.0,
                        task_cap: Some(10.0 + i as f64 * 40.0),
                    })
                    .collect();
                bench_n_quiet(
                    &format!(
                        "drfh solve capped k={capped_servers} n={users}"
                    ),
                    iters,
                    || allocator::solve(&cluster, &fluid).lp_solves,
                )
            });
            job
        })
        .collect();
    for r in runner::run_parallel(jobs) {
        r.print();
        results.push(r);
    }

    // ---- naive per-server DRF baseline (Sec. III-D) ---------------
    let per_server: &[usize] = if smoke { &[200] } else { &[500, 2000] };
    header("naive per-server DRF baseline (Sec. III-D)");
    let jobs: Vec<Job<'_, BenchResult>> = per_server
        .iter()
        .map(|&servers| {
            let job: Job<'_, BenchResult> = Box::new(move || {
                let mut rng = Pcg32::seeded(13);
                let cluster = Cluster::google_sample(servers, &mut rng);
                let demands: Vec<ResVec> = (0..50)
                    .map(|_| {
                        ResVec::cpu_mem(
                            rng.uniform(0.02, 0.5),
                            rng.uniform(0.02, 0.5),
                        )
                    })
                    .collect();
                bench_n_quiet(
                    &format!("per-server drf k={servers} n=50"),
                    iters,
                    || per_server_drf::solve(&cluster, &demands),
                )
            });
            job
        })
        .collect();
    for r in runner::run_parallel(jobs) {
        r.print();
        results.push(r);
    }

    // ---- JSON trajectory ------------------------------------------
    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_allocator.json")
            .to_string()
    });
    let meta_refs: Vec<(&str, Json)> =
        meta.iter().map(|(k, v)| (k.as_str(), v.clone())).collect();
    let path = std::path::PathBuf::from(&out);
    if write_suite_json(&path, "allocator_scale", &meta_refs, &results) {
        println!("\nwrote {}", path.display());
    } else {
        println!("\ncould not write {} (read-only fs?)", path.display());
    }
}
