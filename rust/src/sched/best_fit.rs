//! Best-Fit DRFH (paper Sec. V-B): serve the pending user with the
//! lowest weighted global dominant share, placing its task on the
//! feasible server minimizing the fitness heuristic
//! `H(i,l) = || D_i/D_i1 − c̄_l/c̄_l1 ||_1` (eq. (9)).
//!
//! If the lowest-share user fits nowhere the engine blocks it and asks
//! again, so progressive filling continues with the next-lowest user —
//! matching the fused XLA kernel's "min share among users with a fit"
//! semantics (see `runtime::picker`).

use super::{min_share_user, Pick, Scheduler, UserState};
use crate::cluster::{Cluster, ResVec};

/// The Best-Fit DRFH policy.
///
/// Two progressive-filling variants (the paper leaves the blocked-user
/// case unspecified; its Fig. 4 equal-share trajectories imply the
/// strict reading, while the Fig. 5 utilization numbers imply the
/// work-conserving one — we implement both and ablate):
///
/// * **work-conserving** (default): when the lowest-share user fits on
///   no server, the next-lowest is served instead;
/// * **strict**: scheduling stalls until the lowest-share user fits,
///   keeping shares exactly equalized at the cost of utilization.
#[derive(Default)]
pub struct BestFitDrfh {
    /// Stall behind the lowest-share user instead of skipping it.
    pub strict: bool,
}

impl BestFitDrfh {
    /// The strict (exactly-equalizing, non-work-conserving) variant.
    pub fn strict_filling() -> Self {
        BestFitDrfh { strict: true }
    }
}

/// H(i, l): L1 distance between demand and availability profiles, both
/// normalized by their first component (paper eq. (9)).
pub fn fitness(demand: &ResVec, avail: &ResVec) -> f64 {
    let m = demand.dims();
    let dden = if demand[0] != 0.0 { demand[0] } else { 1.0 };
    let aden = if avail[0] != 0.0 { avail[0] } else { 1.0 };
    let mut h = 0.0;
    for r in 0..m {
        h += (demand[r] / dden - avail[r] / aden).abs();
    }
    h
}

/// Best feasible server for `demand`, lowest H then lowest index;
/// None when nothing fits. (§Perf: flattened hot loop — demand ratios
/// hoisted, fit check fused with availability computation; identical
/// decisions to the naive `fits` + `fitness` composition.)
pub fn best_server(cluster: &Cluster, demand: &ResVec) -> Option<usize> {
    use crate::cluster::FIT_EPS;
    let m = demand.dims();
    let dden = if demand[0] != 0.0 { demand[0] } else { 1.0 };
    let mut dratio = [0.0f64; crate::cluster::MAX_RES];
    for r in 0..m {
        dratio[r] = demand[r] / dden;
    }
    let mut best_h = f64::INFINITY;
    let mut best_l: Option<usize> = None;
    'servers: for (l, s) in cluster.servers.iter().enumerate() {
        let mut avail = [0.0f64; crate::cluster::MAX_RES];
        for r in 0..m {
            let a = s.capacity[r] - s.usage[r];
            if demand[r] > a + FIT_EPS {
                continue 'servers; // does not fit
            }
            avail[r] = if a > 0.0 { a } else { 0.0 };
        }
        let aden = if avail[0] != 0.0 { avail[0] } else { 1.0 };
        let mut h = 0.0;
        for r in 0..m {
            h += (dratio[r] - avail[r] / aden).abs();
        }
        if h < best_h {
            best_h = h;
            best_l = Some(l);
        }
    }
    best_l
}

impl Scheduler for BestFitDrfh {
    fn name(&self) -> &'static str {
        "bestfit-drfh"
    }

    fn pick(
        &mut self,
        cluster: &Cluster,
        users: &[UserState],
        eligible: &[bool],
    ) -> Pick {
        if self.strict {
            // strict progressive filling: nobody is served while the
            // lowest-share pending user fits nowhere
            let all = vec![true; users.len()];
            return match min_share_user(users, &all) {
                None => Pick::Idle,
                Some(u) => match best_server(cluster, &users[u].demand) {
                    Some(l) => Pick::Place { user: u, server: l },
                    None => Pick::Idle,
                },
            };
        }
        match min_share_user(users, eligible) {
            None => Pick::Idle,
            Some(u) => match best_server(cluster, &users[u].demand) {
                Some(l) => Pick::Place { user: u, server: l },
                None => Pick::Blocked { user: u },
            },
        }
    }

    fn can_fit(
        &self,
        cluster: &Cluster,
        users: &[UserState],
        user: usize,
        server: usize,
    ) -> bool {
        cluster.servers[server].fits(&users[user].demand)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Server;

    fn users_fixture() -> Vec<UserState> {
        let total = ResVec::cpu_mem(14.0, 14.0);
        [ResVec::cpu_mem(0.2, 1.0), ResVec::cpu_mem(1.0, 0.2)]
            .iter()
            .map(|d| UserState {
                demand: *d,
                weight: 1.0,
                pending: 5,
                running: 0,
                dom_share: 0.0,
                usage: ResVec::zeros(2),
                dom_delta: d.div(&total).max(),
            })
            .collect()
    }

    #[test]
    fn fitness_prefers_matching_profile() {
        let demand = ResVec::cpu_mem(0.2, 1.0); // memory-heavy
        let mem_server = ResVec::cpu_mem(2.0, 12.0);
        let cpu_server = ResVec::cpu_mem(12.0, 2.0);
        assert!(fitness(&demand, &mem_server) < fitness(&demand, &cpu_server));
    }

    #[test]
    fn routes_fig1_users_to_matching_servers() {
        let cluster = Cluster::fig1_example();
        let mut users = users_fixture();
        let mut sched = BestFitDrfh::default();
        let all = [true, true];
        // equal shares: user 0 first (tie), routed to the memory server
        assert_eq!(
            sched.pick(&cluster, &users, &all),
            Pick::Place { user: 0, server: 0 }
        );
        users[0].dom_share = 0.5;
        // now user 1 has the lower share: routed to the CPU server
        assert_eq!(
            sched.pick(&cluster, &users, &all),
            Pick::Place { user: 1, server: 1 }
        );
    }

    #[test]
    fn blocked_when_min_share_user_fits_nowhere() {
        let cluster =
            Cluster::new(vec![Server::new(ResVec::cpu_mem(0.6, 0.6))]);
        let mut users = users_fixture();
        users[0].demand = ResVec::cpu_mem(1.0, 1.0);
        users[1].demand = ResVec::cpu_mem(0.5, 0.5);
        users[1].dom_share = 0.9;
        let mut sched = BestFitDrfh::default();
        // user 0 has min share but no fit -> Blocked
        assert_eq!(
            sched.pick(&cluster, &users, &[true, true]),
            Pick::Blocked { user: 0 }
        );
        // engine masks it out; next call places user 1
        assert_eq!(
            sched.pick(&cluster, &users, &[false, true]),
            Pick::Place { user: 1, server: 0 }
        );
    }

    #[test]
    fn idle_when_no_pending() {
        let cluster = Cluster::fig1_example();
        let mut users = users_fixture();
        users[0].pending = 0;
        users[1].pending = 0;
        let mut sched = BestFitDrfh::default();
        assert_eq!(sched.pick(&cluster, &users, &[true, true]), Pick::Idle);
    }

    #[test]
    fn can_fit_checks_demand() {
        let cluster = Cluster::fig1_example();
        let users = users_fixture();
        let sched = BestFitDrfh::default();
        assert!(sched.can_fit(&cluster, &users, 0, 0));
        let tiny = Cluster::new(vec![Server::new(ResVec::cpu_mem(0.1, 0.1))]);
        assert!(!sched.can_fit(&tiny, &users, 0, 0));
    }
}
