//! Minimal `anyhow`-style error handling (substrate — crates.io is
//! unavailable offline).
//!
//! Provides the small API surface the crate actually uses: a
//! string-backed [`Error`], a defaulted [`Result`] alias, the
//! [`anyhow!`](crate::anyhow) / [`bail!`](crate::bail) macros, and a
//! [`Context`] extension trait for decorating foreign errors. The
//! semantics match `anyhow` closely enough that swapping the real crate
//! back in is a one-line import change.

use std::fmt;

/// A boxed, human-readable error message.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg(m: impl Into<String>) -> Self {
        Error { msg: m.into() }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

/// `Result` defaulted to [`Error`], like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($arg)*)))
    };
}

// Make `use crate::util::error::{anyhow, bail}` work: `#[macro_export]`
// puts the macros at the crate root; re-export them here so call sites
// can import them alongside `Result` and `Context`.
pub use crate::{anyhow, bail};

/// Attach context to an error, like `anyhow::Context`.
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;

    /// Wrap the error with a lazily computed context message.
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx.to_string()))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("boom {}", 42)
    }

    #[test]
    fn macros_and_display() {
        let e = anyhow!("x = {}", 7);
        assert_eq!(format!("{e}"), "x = 7");
        assert_eq!(format!("{e:?}"), "x = 7");
        assert!(matches!(fails(), Err(_)));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(
            std::io::Error::new(std::io::ErrorKind::NotFound, "missing"),
        );
        let e = r.with_context(|| "reading file").unwrap_err();
        assert!(format!("{e}").starts_with("reading file: "));
        let o: Option<u32> = None;
        let e = o.context("empty").unwrap_err();
        assert_eq!(format!("{e}"), "empty");
    }
}
