//! First-Fit DRFH (paper Sec. V-B): progressive filling that places the
//! chosen user's task on the *first* (lowest-index) server that fits —
//! the simpler sibling of Best-Fit, kept as an evaluation baseline
//! (Fig. 5 compares the two).

use super::{min_share_user, Pick, Scheduler, UserState};
use crate::cluster::{Cluster, ResVec};

/// The First-Fit DRFH policy.
#[derive(Default)]
pub struct FirstFitDrfh;

/// First server that fits `demand`, by index.
pub fn first_server(cluster: &Cluster, demand: &ResVec) -> Option<usize> {
    cluster.servers.iter().position(|s| s.fits(demand))
}

impl Scheduler for FirstFitDrfh {
    fn name(&self) -> &'static str {
        "firstfit-drfh"
    }

    fn pick(
        &mut self,
        cluster: &Cluster,
        users: &[UserState],
        eligible: &[bool],
    ) -> Pick {
        match min_share_user(users, eligible) {
            None => Pick::Idle,
            Some(u) => match first_server(cluster, &users[u].demand) {
                Some(l) => Pick::Place { user: u, server: l },
                None => Pick::Blocked { user: u },
            },
        }
    }

    fn can_fit(
        &self,
        cluster: &Cluster,
        users: &[UserState],
        user: usize,
        server: usize,
    ) -> bool {
        cluster.servers[server].fits(&users[user].demand)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Server;

    #[test]
    fn takes_lowest_index_server() {
        let cluster = Cluster::new(vec![
            Server::new(ResVec::cpu_mem(0.1, 0.1)), // too small
            Server::new(ResVec::cpu_mem(1.0, 1.0)),
            Server::new(ResVec::cpu_mem(5.0, 5.0)),
        ]);
        let users = vec![UserState {
            demand: ResVec::cpu_mem(0.5, 0.5),
            weight: 1.0,
            pending: 1,
            running: 0,
            dom_share: 0.0,
            usage: ResVec::zeros(2),
            dom_delta: 0.1,
        }];
        let mut sched = FirstFitDrfh;
        assert_eq!(
            sched.pick(&cluster, &users, &[true]),
            Pick::Place { user: 0, server: 1 }
        );
    }
}
