"""Pallas kernel for Best-Fit DRFH server scoring (paper eq. (9)).

For every user i and server l the kernel computes

    H(i, l) = sum_r | D_ir / D_i0  -  avail_lr / avail_l0 |

masks out servers that cannot fit the task (``any_r avail_lr < D_ir``)
and reduces per user to the best (lowest-H, lowest-index) feasible
server. Semantics match ``ref.score_servers`` exactly, including
first-occurrence tie-breaking.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the (k, m) available-
resource matrix is streamed HBM->VMEM in 128-server tiles via BlockSpec;
the demand matrix (n <= 128 users x m <= 4 resources) stays resident in
VMEM across the whole grid. Each grid step does an elementwise VPU pass
over one tile plus an [n, TILE] reduction; the running per-user best is
carried in the output refs across sequential grid steps (the canonical
TPU accumulator pattern). The kernel is memory-bound: ~O(n*m) flops per
avail byte, no MXU work. ``interpret=True`` is mandatory on this image —
the CPU PJRT plugin cannot execute Mosaic custom-calls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SERVER_TILE = 128


def _score_kernel(avail_ref, demand_ref, best_h_ref, best_idx_ref):
    """One grid step: fold a tile of servers into the running best."""
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        best_h_ref[...] = jnp.full_like(best_h_ref[...], jnp.inf)
        best_idx_ref[...] = jnp.full_like(best_idx_ref[...], -1)

    avail = avail_ref[...]  # [T, m]
    demand = demand_ref[...]  # [n, m]

    # ratios relative to resource 0 (paper's D_i1 / c-bar_l1), div-by-0 safe
    dden = jnp.where(demand[:, 0:1] != 0.0, demand[:, 0:1], 1.0)
    aden = jnp.where(avail[:, 0:1] != 0.0, avail[:, 0:1], 1.0)
    dratio = demand / dden  # [n, m]
    aratio = avail / aden  # [T, m]

    h = jnp.sum(jnp.abs(dratio[:, None, :] - aratio[None, :, :]), axis=-1)
    fit = jnp.all(avail[None, :, :] >= demand[:, None, :], axis=-1)
    h = jnp.where(fit, h, jnp.inf)  # [n, T]

    tile_min = jnp.min(h, axis=1)  # [n]
    tile_arg = jnp.argmin(h, axis=1).astype(jnp.int32) + t * avail.shape[0]

    # strict < keeps the earliest tile on ties; argmin keeps the earliest
    # server within a tile -> global first-occurrence semantics.
    better = tile_min < best_h_ref[...]
    best_idx_ref[...] = jnp.where(better, tile_arg, best_idx_ref[...])
    best_h_ref[...] = jnp.where(better, tile_min, best_h_ref[...])


@functools.partial(jax.jit, static_argnames=("tile",))
def score_servers(avail, demand, *, tile=SERVER_TILE):
    """Pallas-backed all-pairs best-fit scoring.

    Args:
      avail:  f32[k, m], k divisible by ``tile`` (or k < tile).
      demand: f32[n, m].

    Returns:
      (best_h f32[n], best_server i32[n]); +inf/-1 when no server fits.
    """
    avail = jnp.asarray(avail, jnp.float32)
    demand = jnp.asarray(demand, jnp.float32)
    k, m = avail.shape
    n = demand.shape[0]
    t = min(tile, k)
    if k % t != 0:
        raise ValueError(f"k={k} not divisible by tile={t}")
    grid = k // t
    best_h, best_idx = pl.pallas_call(
        _score_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((t, m), lambda i: (i, 0)),  # stream server tiles
            pl.BlockSpec((n, m), lambda i: (0, 0)),  # demands stay resident
        ],
        out_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(avail, demand)
    return best_h, best_idx
