//! Decision parity: the indexed scheduling core (`sched::index`), the
//! batched drain path (`Scheduler::drain`), the indexed Slots user
//! selection, and the timer-wheel event queue (`sim::wheel`) must
//! emit decision streams *bit-identical* to the seed's single-pick
//! linear-scan, binary-heap path — same committed placements, same
//! blocked/unblocked churn, same metrics — on randomized traces that
//! exercise saturation (blocking), completions (unblocking), and
//! weighted users.
//!
//! Since the engine drives policies through `Scheduler::drain`, the
//! recording wrapper logs at the [`DrainCtx`] boundary — every
//! `place`/`block` the policy commits flows through it — so the
//! comparison covers the full blocked-user protocol for both the
//! batched override and the default pick-loop, not just aggregate
//! counts.

use drfh::cluster::{Cluster, ResVec};
use drfh::sched::{
    BestFitDrfh, DrainCtx, FirstFitDrfh, Pick, Scheduler, SlotsScheduler,
    UserState,
};
use drfh::sim::{
    run, ChurnEvent, ChurnPlan, FaultPlan, QueueKind, RetryPolicy,
    ShardCount, SimOpts,
};
use drfh::util::Pcg32;
use drfh::workload::{
    generate_churn, generate_faults, ChurnGenConfig, FaultGenConfig,
    GoogleLikeConfig, JobSpec, TaskSpec, Trace, TraceGenerator, UserSpec,
};
use std::cell::RefCell;
use std::rc::Rc;

/// One committed decision observed at the engine boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Op {
    Place { user: usize, server: usize },
    Block { user: usize },
}

/// Logs every `place`/`block` a drained policy commits while
/// delegating to the engine's real ctx.
struct RecordingCtx<'c> {
    inner: &'c mut dyn DrainCtx,
    log: Rc<RefCell<Vec<Op>>>,
}

impl DrainCtx for RecordingCtx<'_> {
    fn cluster(&self) -> &Cluster {
        self.inner.cluster()
    }

    fn users(&self) -> &[UserState] {
        self.inner.users()
    }

    fn eligible(&self) -> &[bool] {
        self.inner.eligible()
    }

    fn place(&mut self, user: usize, server: usize) {
        self.log.borrow_mut().push(Op::Place { user, server });
        self.inner.place(user, server);
    }

    fn block(&mut self, user: usize) {
        self.log.borrow_mut().push(Op::Block { user });
        self.inner.block(user);
    }
}

/// Records the decision stream while delegating everything (including
/// the drain override and the incremental-index notifications) to the
/// wrapped policy.
struct Recording<S> {
    inner: S,
    log: Rc<RefCell<Vec<Op>>>,
}

impl<S: Scheduler> Scheduler for Recording<S> {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn pick(
        &mut self,
        cluster: &Cluster,
        users: &[UserState],
        eligible: &[bool],
    ) -> Pick {
        self.inner.pick(cluster, users, eligible)
    }

    fn drain(&mut self, ctx: &mut dyn DrainCtx) {
        let mut rctx = RecordingCtx { inner: ctx, log: self.log.clone() };
        self.inner.drain(&mut rctx);
    }

    fn can_fit(
        &self,
        cluster: &Cluster,
        users: &[UserState],
        user: usize,
        server: usize,
    ) -> bool {
        self.inner.can_fit(cluster, users, user, server)
    }

    fn allows_overcommit(&self) -> bool {
        self.inner.allows_overcommit()
    }

    fn on_free(&mut self, server: usize) {
        self.inner.on_free(server);
    }

    fn on_place(&mut self, user: usize, server: usize) {
        self.inner.on_place(user, server);
    }

    fn on_complete(&mut self, user: usize, server: usize) {
        self.inner.on_complete(user, server);
    }

    fn on_ready(&mut self, user: usize) {
        self.inner.on_ready(user);
    }

    fn on_user_join(&mut self, user: usize) {
        self.inner.on_user_join(user);
    }

    fn on_user_leave(&mut self, user: usize) {
        self.inner.on_user_leave(user);
    }

    fn on_server_down(&mut self, server: usize) {
        self.inner.on_server_down(server);
    }

    fn on_server_up(&mut self, server: usize) {
        self.inner.on_server_up(server);
    }
}

/// Forces the single-pick reference drain over any policy: delegates
/// everything EXCEPT `drain`, which falls back to the trait default
/// (`drain_by_picks`). Wrapping an indexed policy in this yields the
/// indexed per-decision path the engine ran before batching.
struct SinglePick<S>(S);

impl<S: Scheduler> Scheduler for SinglePick<S> {
    fn name(&self) -> &'static str {
        self.0.name()
    }

    fn pick(
        &mut self,
        cluster: &Cluster,
        users: &[UserState],
        eligible: &[bool],
    ) -> Pick {
        self.0.pick(cluster, users, eligible)
    }

    // NOTE: no `drain` override — the default pick-loop runs.

    fn can_fit(
        &self,
        cluster: &Cluster,
        users: &[UserState],
        user: usize,
        server: usize,
    ) -> bool {
        self.0.can_fit(cluster, users, user, server)
    }

    fn allows_overcommit(&self) -> bool {
        self.0.allows_overcommit()
    }

    fn on_free(&mut self, server: usize) {
        self.0.on_free(server);
    }

    fn on_place(&mut self, user: usize, server: usize) {
        self.0.on_place(user, server);
    }

    fn on_complete(&mut self, user: usize, server: usize) {
        self.0.on_complete(user, server);
    }

    fn on_ready(&mut self, user: usize) {
        self.0.on_ready(user);
    }

    fn on_user_join(&mut self, user: usize) {
        self.0.on_user_join(user);
    }

    fn on_user_leave(&mut self, user: usize) {
        self.0.on_user_leave(user);
    }

    fn on_server_down(&mut self, server: usize) {
        self.0.on_server_down(server);
    }

    fn on_server_up(&mut self, server: usize) {
        self.0.on_server_up(server);
    }
}

/// Run `trace` through both sides of a policy pair and assert the full
/// decision streams (and headline metrics) are identical.
fn assert_parity<A, B>(
    label: &str,
    cluster: &Cluster,
    trace: &Trace,
    opts: &SimOpts,
    fast: A,
    reference: B,
) where
    A: Scheduler + 'static,
    B: Scheduler + 'static,
{
    let log_a = Rc::new(RefCell::new(Vec::new()));
    let log_b = Rc::new(RefCell::new(Vec::new()));
    let ra = run(
        cluster.clone(),
        trace,
        Box::new(Recording { inner: fast, log: log_a.clone() }),
        opts.clone(),
    );
    let rb = run(
        cluster.clone(),
        trace,
        Box::new(Recording { inner: reference, log: log_b.clone() }),
        opts.clone(),
    );
    let a = log_a.borrow();
    let b = log_b.borrow();
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert_eq!(x, y, "{label}: decision {i} diverged");
    }
    assert_eq!(a.len(), b.len(), "{label}: decision-stream lengths differ");
    assert_eq!(ra.tasks_placed, rb.tasks_placed, "{label}: placed");
    assert_eq!(ra.tasks_completed, rb.tasks_completed, "{label}: completed");
    assert_eq!(ra.cpu_util.v, rb.cpu_util.v, "{label}: cpu util series");
    assert_eq!(ra.mem_util.v, rb.mem_util.v, "{label}: mem util series");
    assert!(ra.tasks_placed > 0, "{label}: degenerate run placed nothing");
}

fn random_setup(
    rng_seed: u64,
    trace_seed: u64,
) -> (Cluster, Trace, SimOpts) {
    let mut rng = Pcg32::seeded(rng_seed);
    let cluster = Cluster::google_sample(30 + rng.below(50), &mut rng);
    let gen = TraceGenerator::new(GoogleLikeConfig {
        users: 4 + rng.below(8),
        duration: 4_000.0,
        jobs_per_user: 6.0,
        max_tasks_per_job: 80,
        ..Default::default()
    });
    let trace = gen.generate(trace_seed);
    let opts = SimOpts {
        horizon: 4_000.0,
        sample_dt: 100.0,
        track_user_series: false,
        ..SimOpts::default()
    };
    (cluster, trace, opts)
}

/// The constructors must select the path their name promises — the
/// parity runs below are meaningless if both sides are the same path.
#[test]
fn constructors_select_the_expected_path() {
    assert!(BestFitDrfh::default().is_indexed());
    assert!(BestFitDrfh::default().is_classed());
    assert!(BestFitDrfh::per_user().is_indexed());
    assert!(!BestFitDrfh::per_user().is_classed());
    assert!(!BestFitDrfh::naive().is_indexed());
    assert!(!BestFitDrfh::naive().is_classed());
    assert!(!BestFitDrfh::strict_filling().is_indexed());
    assert!(FirstFitDrfh::default().is_indexed());
    assert!(FirstFitDrfh::default().is_classed());
    assert!(FirstFitDrfh::per_user().is_indexed());
    assert!(!FirstFitDrfh::per_user().is_classed());
    assert!(!FirstFitDrfh::naive().is_indexed());
    let cluster = Cluster::fig1_example();
    assert!(SlotsScheduler::new(&cluster, 14).is_indexed());
    assert!(!SlotsScheduler::naive(&cluster, 14).is_indexed());
}

/// Randomized Google-like traces on a deliberately tight cluster so
/// blocking/unblocking dominates — the paths that could diverge.
/// Batched indexed drain vs the seed's naive single-pick scans.
#[test]
fn randomized_traces_bestfit() {
    for seed in 0..5u64 {
        let (cluster, trace, opts) =
            random_setup(9_100 + seed, seed * 31 + 7);
        assert_parity(
            &format!("bestfit seed {seed}"),
            &cluster,
            &trace,
            &opts,
            BestFitDrfh::default(),
            BestFitDrfh::naive(),
        );
    }
}

#[test]
fn randomized_traces_firstfit() {
    for seed in 0..5u64 {
        let (cluster, trace, opts) =
            random_setup(9_500 + seed, seed * 37 + 5);
        assert_parity(
            &format!("firstfit seed {seed}"),
            &cluster,
            &trace,
            &opts,
            FirstFitDrfh::default(),
            FirstFitDrfh::naive(),
        );
    }
}

/// Batched drain vs single-pick drain over the SAME indexed policy:
/// isolates the wave batching itself (the indexed-vs-naive runs above
/// change two variables at once).
#[test]
fn batched_vs_single_pick_drain() {
    for seed in 0..4u64 {
        let (cluster, trace, opts) =
            random_setup(9_900 + seed, seed * 41 + 3);
        assert_parity(
            &format!("batched bestfit seed {seed}"),
            &cluster,
            &trace,
            &opts,
            BestFitDrfh::default(),
            SinglePick(BestFitDrfh::default()),
        );
        assert_parity(
            &format!("batched firstfit seed {seed}"),
            &cluster,
            &trace,
            &opts,
            FirstFitDrfh::default(),
            SinglePick(FirstFitDrfh::default()),
        );
    }
}

/// Indexed vs naive Slots user selection on randomized traces —
/// overcommit plus the processor-sharing slowdown makes completion
/// (and thus unblock) timing especially sensitive to any ranking
/// drift.
#[test]
fn randomized_traces_slots() {
    for seed in 0..4u64 {
        let (cluster, trace, opts) =
            random_setup(9_700 + seed, seed * 43 + 11);
        for slots in [10usize, 14] {
            assert_parity(
                &format!("slots-{slots} seed {seed}"),
                &cluster,
                &trace,
                &opts,
                SlotsScheduler::new(&cluster, slots),
                SlotsScheduler::naive(&cluster, slots),
            );
        }
    }
}

// ------------------------------------------- class-keyed user state

/// Class-keyed vs per-user scheduler state on workloads with real
/// demand-row sharing (many users per interned class, a zero-weight
/// cohort in the weight cycle): the decision streams AND headline
/// metrics must be identical across all three paths — classed
/// (default), the PR 1 per-user index, and the naive scans.
#[test]
fn class_keyed_state_parity() {
    use drfh::experiments::user_scale::classed_trace;
    for seed in 0..4u64 {
        let mut rng = Pcg32::seeded(13_000 + seed);
        let cluster = Cluster::google_sample(30 + rng.below(30), &mut rng);
        // 40 users over 4 demand classes x 3 effective weights = at
        // most 12 share groups for 40 users, comfortably under the
        // fall-back threshold: the grouped machinery (not the embedded
        // per-user heap) is what runs here, with several users per
        // group and per class
        let trace = classed_trace(40, 4, 2_500, 4_000.0, 100 + seed);
        let opts = SimOpts {
            horizon: 4_000.0,
            sample_dt: 100.0,
            ..SimOpts::default()
        };
        assert_parity(
            &format!("classed-vs-per-user bestfit seed {seed}"),
            &cluster,
            &trace,
            &opts,
            BestFitDrfh::default(),
            BestFitDrfh::per_user(),
        );
        assert_parity(
            &format!("classed-vs-naive bestfit seed {seed}"),
            &cluster,
            &trace,
            &opts,
            BestFitDrfh::default(),
            BestFitDrfh::naive(),
        );
        assert_parity(
            &format!("classed-vs-per-user firstfit seed {seed}"),
            &cluster,
            &trace,
            &opts,
            FirstFitDrfh::default(),
            FirstFitDrfh::per_user(),
        );
    }
}

/// Property test (satellite): interned class-keyed decisions are
/// bit-identical to per-user state across randomized weight /
/// zero-weight mixes — the weights are re-drawn per user on top of
/// the classed demand rows, so `(dom_delta, weight)` groups form and
/// dissolve at random: the guarded weight-0 draws merge into the
/// weight-1 groups, while the continuous draws usually leave too few
/// users per group and exercise the build's per-user-heap fall-back —
/// both paths must stay bit-identical to `::per_user()`.
#[test]
fn classed_decisions_bit_identical_across_weight_mixes() {
    use drfh::experiments::user_scale::classed_trace;
    for seed in 0..6u64 {
        let mut rng = Pcg32::seeded(14_000 + seed);
        let cluster = Cluster::google_sample(25 + rng.below(25), &mut rng);
        let mut trace = classed_trace(18, 4, 2_000, 3_500.0, 200 + seed);
        for u in trace.users.iter_mut() {
            u.weight = if rng.f64() < 0.25 {
                0.0
            } else {
                rng.uniform(0.25, 4.0)
            };
        }
        trace.validate().expect("weight mix stays valid");
        let opts = SimOpts {
            horizon: 3_500.0,
            sample_dt: 100.0,
            ..SimOpts::default()
        };
        assert_parity(
            &format!("weight-mix bestfit seed {seed}"),
            &cluster,
            &trace,
            &opts,
            BestFitDrfh::default(),
            BestFitDrfh::per_user(),
        );
        assert_parity(
            &format!("weight-mix firstfit seed {seed}"),
            &cluster,
            &trace,
            &opts,
            FirstFitDrfh::default(),
            FirstFitDrfh::per_user(),
        );
    }
}

/// Heavily saturated hand-built instance: more demand than capacity,
/// long and short tasks, so every completion re-opens the blocked set.
#[test]
fn saturated_blocking_churn() {
    let mut rng = Pcg32::seeded(777);
    let cluster = Cluster::google_sample(12, &mut rng);
    let users: Vec<UserSpec> = (0..6)
        .map(|_| UserSpec {
            demand: ResVec::cpu_mem(
                rng.uniform(0.1, 0.45),
                rng.uniform(0.1, 0.45),
            ),
            weight: rng.uniform(0.5, 2.0),
        })
        .collect();
    let jobs: Vec<JobSpec> = (0..18)
        .map(|j| JobSpec {
            id: j,
            user: j % 6,
            submit: (j as f64) * 40.0,
            tasks: vec![
                TaskSpec { duration: 150.0 + 70.0 * (j % 5) as f64 };
                25
            ],
        })
        .collect();
    let trace = Trace { users, jobs };
    let opts = SimOpts {
        horizon: 5_000.0,
        sample_dt: 50.0,
        track_user_series: false,
        ..SimOpts::default()
    };
    assert_parity(
        "saturated bestfit",
        &cluster,
        &trace,
        &opts,
        BestFitDrfh::default(),
        BestFitDrfh::naive(),
    );
    assert_parity(
        "saturated firstfit",
        &cluster,
        &trace,
        &opts,
        FirstFitDrfh::default(),
        FirstFitDrfh::naive(),
    );
    assert_parity(
        "saturated slots",
        &cluster,
        &trace,
        &opts,
        SlotsScheduler::new(&cluster, 14),
        SlotsScheduler::naive(&cluster, 14),
    );
}

/// Weighted users including a zero-weight one: both paths must apply
/// the same guarded `effective_weight` semantics.
#[test]
fn zero_weight_user_parity() {
    let cluster = Cluster::from_capacities(&[
        ResVec::cpu_mem(4.0, 4.0),
        ResVec::cpu_mem(2.0, 6.0),
    ]);
    let users = vec![
        UserSpec { demand: ResVec::cpu_mem(0.5, 0.5), weight: 0.0 },
        UserSpec { demand: ResVec::cpu_mem(0.4, 0.6), weight: 2.0 },
        UserSpec { demand: ResVec::cpu_mem(0.6, 0.4), weight: 1.0 },
    ];
    let jobs: Vec<JobSpec> = (0..3)
        .map(|u| JobSpec {
            id: u,
            user: u,
            submit: 0.0,
            tasks: vec![TaskSpec { duration: 200.0 }; 30],
        })
        .collect();
    let trace = Trace { users, jobs };
    let opts = SimOpts {
        horizon: 2_000.0,
        sample_dt: 50.0,
        track_user_series: false,
        ..SimOpts::default()
    };
    assert_parity(
        "zero-weight bestfit",
        &cluster,
        &trace,
        &opts,
        BestFitDrfh::default(),
        BestFitDrfh::naive(),
    );
    assert_parity(
        "zero-weight slots",
        &cluster,
        &trace,
        &opts,
        SlotsScheduler::new(&cluster, 14),
        SlotsScheduler::naive(&cluster, 14),
    );
}

// --------------------------------------------------- dom_share drift

/// Asserts `dom_share == running * dom_delta` bit-exactly for every
/// user at every decision the engine commits.
struct AssertShares<S>(S);

struct AssertSharesCtx<'c> {
    inner: &'c mut dyn DrainCtx,
}

impl AssertSharesCtx<'_> {
    fn check(&self) {
        for (i, u) in self.inner.users().iter().enumerate() {
            let want = u.running as f64 * u.dom_delta;
            assert!(
                u.dom_share.to_bits() == want.to_bits(),
                "user {i}: dom_share {} != running({}) * dom_delta({}) = {}",
                u.dom_share,
                u.running,
                u.dom_delta,
                want
            );
        }
    }
}

impl DrainCtx for AssertSharesCtx<'_> {
    fn cluster(&self) -> &Cluster {
        self.inner.cluster()
    }

    fn users(&self) -> &[UserState] {
        self.inner.users()
    }

    fn eligible(&self) -> &[bool] {
        self.inner.eligible()
    }

    fn place(&mut self, user: usize, server: usize) {
        self.check();
        self.inner.place(user, server);
        self.check();
    }

    fn block(&mut self, user: usize) {
        self.inner.block(user);
    }
}

impl<S: Scheduler> Scheduler for AssertShares<S> {
    fn name(&self) -> &'static str {
        self.0.name()
    }

    fn pick(
        &mut self,
        cluster: &Cluster,
        users: &[UserState],
        eligible: &[bool],
    ) -> Pick {
        self.0.pick(cluster, users, eligible)
    }

    fn drain(&mut self, ctx: &mut dyn DrainCtx) {
        let mut actx = AssertSharesCtx { inner: ctx };
        actx.check(); // completions since the last wave stayed exact
        self.0.drain(&mut actx);
    }

    fn can_fit(
        &self,
        cluster: &Cluster,
        users: &[UserState],
        user: usize,
        server: usize,
    ) -> bool {
        self.0.can_fit(cluster, users, user, server)
    }

    fn allows_overcommit(&self) -> bool {
        self.0.allows_overcommit()
    }

    fn on_free(&mut self, server: usize) {
        self.0.on_free(server);
    }

    fn on_place(&mut self, user: usize, server: usize) {
        self.0.on_place(user, server);
    }

    fn on_complete(&mut self, user: usize, server: usize) {
        self.0.on_complete(user, server);
    }

    fn on_ready(&mut self, user: usize) {
        self.0.on_ready(user);
    }

    fn on_user_join(&mut self, user: usize) {
        self.0.on_user_join(user);
    }

    fn on_user_leave(&mut self, user: usize) {
        self.0.on_user_leave(user);
    }

    fn on_server_down(&mut self, server: usize) {
        self.0.on_server_down(server);
    }

    fn on_server_up(&mut self, server: usize) {
        self.0.on_server_up(server);
    }
}

/// Regression for the dominant-share drift: the engine used to
/// accumulate `dom_share += / -= dom_delta` (clamping negatives), so
/// thousands of place/complete cycles biased the very key schedulers
/// sort by. The engine now recomputes `running * dom_delta` on every
/// transition; over a long saturated run with heavy churn the
/// identity must hold *bit-exactly* at every decision boundary.
#[test]
fn dom_share_stays_exact_over_long_runs() {
    for seed in [5u64, 6] {
        let (cluster, trace, opts) = random_setup(8_000 + seed, seed * 17);
        let report = run(
            cluster.clone(),
            &trace,
            Box::new(AssertShares(BestFitDrfh::default())),
            opts.clone(),
        );
        assert!(
            report.tasks_completed > 100,
            "need churn to exercise drift, got {}",
            report.tasks_completed
        );
        // same invariant through the single-pick path
        run(
            cluster,
            &trace,
            Box::new(AssertShares(SinglePick(BestFitDrfh::naive()))),
            opts,
        );
    }
}

// ------------------------------------------------ event-queue parity

/// Run the same policy + trace on the timer wheel and on the naive
/// binary heap and assert the decision streams AND the entire
/// [`drfh::sim::SimReport`] are identical — every placement, every
/// utilization sample, every job record, every derived float. The
/// queues drain in the same total `(time, seq)` order, so nothing
/// downstream may differ.
fn assert_queue_parity<S, F>(
    label: &str,
    cluster: &Cluster,
    trace: &Trace,
    opts: &SimOpts,
    mk: F,
) where
    S: Scheduler + 'static,
    F: Fn() -> S,
{
    let log_w = Rc::new(RefCell::new(Vec::new()));
    let log_h = Rc::new(RefCell::new(Vec::new()));
    let rw = run(
        cluster.clone(),
        trace,
        Box::new(Recording { inner: mk(), log: log_w.clone() }),
        SimOpts { queue: QueueKind::Wheel, ..opts.clone() },
    );
    let rh = run(
        cluster.clone(),
        trace,
        Box::new(Recording { inner: mk(), log: log_h.clone() }),
        SimOpts { queue: QueueKind::Heap, ..opts.clone() },
    );
    let w = log_w.borrow();
    let h = log_h.borrow();
    for (i, (x, y)) in w.iter().zip(h.iter()).enumerate() {
        assert_eq!(x, y, "{label}: decision {i} diverged");
    }
    assert_eq!(w.len(), h.len(), "{label}: decision-stream lengths");
    assert_eq!(rw, rh, "{label}: SimReports diverged");
    assert!(rw.tasks_placed > 0, "{label}: degenerate run placed nothing");
}

/// Wheel vs heap on randomized Google-like traces, across the policy
/// spectrum (demand-based DRFH and the overcommitting Slots baseline
/// whose PS completion times are maximally sensitive to event order).
#[test]
fn wheel_vs_heap_randomized() {
    for seed in 0..4u64 {
        let (cluster, trace, opts) =
            random_setup(11_000 + seed, seed * 29 + 13);
        assert_queue_parity(
            &format!("wheel bestfit seed {seed}"),
            &cluster,
            &trace,
            &opts,
            BestFitDrfh::default,
        );
        assert_queue_parity(
            &format!("wheel slots seed {seed}"),
            &cluster,
            &trace,
            &opts,
            || SlotsScheduler::new(&cluster, 14),
        );
    }
}

/// Wheel vs heap on the Fig. 5 configuration (the acceptance gate:
/// `EvalSetup` is exactly the generator the Fig. 5 harness and the
/// scale benches run), with user series tracked so every report
/// surface is compared.
#[test]
fn wheel_vs_heap_fig5_config() {
    use drfh::experiments::EvalSetup;
    let setup = EvalSetup::with_duration(42, 150, 15, 6_000.0);
    let opts = SimOpts { track_user_series: true, ..setup.opts.clone() };
    assert_queue_parity(
        "fig5 bestfit",
        &setup.cluster,
        &setup.trace,
        &opts,
        BestFitDrfh::default,
    );
    assert_queue_parity(
        "fig5 firstfit",
        &setup.cluster,
        &setup.trace,
        &opts,
        FirstFitDrfh::default,
    );
}

/// Satellite regression guard for the parity claim: `Arrival`,
/// `ServerCheck`, and `Sample` events engineered onto the *same*
/// timestamps must drain in identical `seq` order from both queues.
/// Everything here lands on a 10 s grid: submits are multiples of 10,
/// durations are multiples of 10 (and DRFH tasks run at rate 1, so
/// completions hit the grid exactly), and `sample_dt` is 10 — every
/// wave is a three-way collision whose resolution the engine derives
/// purely from the queue's (time, seq) order.
#[test]
fn simultaneous_events_tiebreak_parity() {
    let mut rng = Pcg32::seeded(4242);
    let cluster = Cluster::google_sample(10, &mut rng);
    let users: Vec<UserSpec> = (0..5)
        .map(|_| UserSpec {
            demand: ResVec::cpu_mem(
                rng.uniform(0.1, 0.4),
                rng.uniform(0.1, 0.4),
            ),
            weight: 1.0,
        })
        .collect();
    let jobs: Vec<JobSpec> = (0..25)
        .map(|j| JobSpec {
            id: j,
            user: j % 5,
            submit: ((j / 5) as f64) * 10.0, // 5 arrivals per timestamp
            tasks: vec![
                TaskSpec { duration: 10.0 * (1 + j % 4) as f64 };
                12
            ],
        })
        .collect();
    let trace = Trace { users, jobs };
    let opts = SimOpts {
        horizon: 1_000.0,
        sample_dt: 10.0,
        track_user_series: false,
        ..SimOpts::default()
    };
    assert_queue_parity(
        "tie-break bestfit",
        &cluster,
        &trace,
        &opts,
        BestFitDrfh::default,
    );
    // the naive single-pick reference path over the heap/wheel pair
    assert_queue_parity(
        "tie-break naive bestfit",
        &cluster,
        &trace,
        &opts,
        BestFitDrfh::naive,
    );
    // Slots overcommits: PS rate changes reschedule ServerChecks that
    // keep colliding with the sample grid while rates are 1
    assert_queue_parity(
        "tie-break slots",
        &cluster,
        &trace,
        &opts,
        || SlotsScheduler::new(&cluster, 14),
    );
}

/// Auto-tuned wheel geometry is perf-only: a run under
/// `QueueKind::Auto` must be bit-identical to the heap reference
/// (and therefore to the default wheel), share sketches included.
#[test]
fn auto_wheel_geometry_parity() {
    use drfh::sim::MetricsMode;
    for seed in 0..2u64 {
        let (cluster, trace, opts) =
            random_setup(15_000 + seed, seed * 19 + 3);
        let opts = SimOpts {
            queue: QueueKind::Auto,
            share_sketch: Some(32),
            ..opts
        };
        let ra = run(
            cluster.clone(),
            &trace,
            Box::new(BestFitDrfh::default()),
            opts.clone(),
        );
        let rh = run(
            cluster.clone(),
            &trace,
            Box::new(BestFitDrfh::default()),
            SimOpts { queue: QueueKind::Heap, ..opts.clone() },
        );
        assert_eq!(ra, rh, "auto-geometry run diverged from heap (seed {seed})");
        // streaming metrics on top of the auto wheel: decisions still
        // identical
        let rs = run(
            cluster,
            &trace,
            Box::new(BestFitDrfh::default()),
            SimOpts { metrics: MetricsMode::streaming(), ..opts },
        );
        assert_eq!(rs.tasks_placed, rh.tasks_placed);
        assert_eq!(rs.job_stats, rh.job_stats);
    }
}

/// Engine-level share sketches: budgeted sketches must not perturb
/// decisions, must stay under their point budget, and must agree with
/// the exact trajectory (`track_user_series`) on the summary
/// quantities.
#[test]
fn share_sketches_bound_memory_and_error() {
    let (cluster, trace, opts) = random_setup(16_000, 99);
    let budget = 32usize;
    let opts = SimOpts {
        track_user_series: true,
        share_sketch: Some(budget),
        ..opts
    };
    let r = run(
        cluster.clone(),
        &trace,
        Box::new(BestFitDrfh::default()),
        opts.clone(),
    );
    // sketches must not change the simulation
    let r0 = run(
        cluster,
        &trace,
        Box::new(BestFitDrfh::default()),
        SimOpts { share_sketch: None, ..opts },
    );
    assert_eq!(r.tasks_placed, r0.tasks_placed);
    assert_eq!(r.cpu_util, r0.cpu_util);
    assert!(r0.share_sketches.is_empty());
    assert_eq!(r.share_sketches.len(), trace.users.len());
    let samples = r.cpu_util.len(); // one sketch sample per tick
    assert!(samples > budget, "horizon too short to force decimation");
    for (u, sketch) in r.share_sketches.iter().enumerate() {
        let exact = &r.user_dom_share[u];
        assert_eq!(sketch.count(), exact.len() as u64, "user {u}");
        assert!(sketch.series.len() <= budget, "user {u} over budget");
        // the sketch's last sample is the exact trajectory's last value
        assert_eq!(sketch.last, *exact.v.last().unwrap(), "user {u}");
        // bounded error on the time average (decimated vs exact grid)
        let err = (sketch.series.time_avg() - exact.time_avg()).abs();
        assert!(err < 0.05, "user {u}: time-avg drift {err}");
        // exact streaming max equals the trajectory max
        let vmax =
            exact.v.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert_eq!(sketch.stats.max(), vmax, "user {u}");
    }
}

// ----------------------------------------------- sharded data plane

/// Run the same policy + trace at several shard counts and assert the
/// decision streams AND the entire [`drfh::sim::SimReport`] are
/// bit-identical to the sequential (S = 1) engine — the sharded drain
/// is a wall-clock lever only, never a behavioral fork.
fn assert_shard_parity<S, F>(
    label: &str,
    cluster: &Cluster,
    trace: &Trace,
    opts: &SimOpts,
    mk: F,
) where
    S: Scheduler + 'static,
    F: Fn() -> S,
{
    let log_ref = Rc::new(RefCell::new(Vec::new()));
    let r_ref = run(
        cluster.clone(),
        trace,
        Box::new(Recording { inner: mk(), log: log_ref.clone() }),
        SimOpts { shards: ShardCount::Fixed(1), ..opts.clone() },
    );
    assert!(r_ref.tasks_placed > 0, "{label}: degenerate run placed nothing");
    for shards in [2usize, 3, 8] {
        let log_s = Rc::new(RefCell::new(Vec::new()));
        let r_s = run(
            cluster.clone(),
            trace,
            Box::new(Recording { inner: mk(), log: log_s.clone() }),
            SimOpts { shards: ShardCount::Fixed(shards), ..opts.clone() },
        );
        let a = log_ref.borrow();
        let b = log_s.borrow();
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(x, y, "{label} S={shards}: decision {i} diverged");
        }
        assert_eq!(a.len(), b.len(), "{label} S={shards}: stream lengths");
        assert_eq!(r_ref, r_s, "{label} S={shards}: SimReports diverged");
    }
}

/// The tentpole acceptance matrix: randomized Google-like traces ×
/// shard counts {1, 2, 3, 8} × every queue kind, for both the DRFH
/// indexed policies and the overcommitting Slots baseline (whose PS
/// completion times are maximally sensitive to any drain-order
/// drift). Full-report equality includes utilization series, job
/// records, and share sketches.
#[test]
fn sharded_engine_matches_sequential() {
    for seed in 0..3u64 {
        let (cluster, trace, opts) =
            random_setup(17_000 + seed, seed * 23 + 9);
        for kind in [QueueKind::Wheel, QueueKind::Heap, QueueKind::Auto] {
            let opts = SimOpts {
                queue: kind,
                share_sketch: Some(32),
                track_user_series: true,
                ..opts.clone()
            };
            assert_shard_parity(
                &format!("sharded bestfit seed {seed} {kind:?}"),
                &cluster,
                &trace,
                &opts,
                BestFitDrfh::default,
            );
        }
        assert_shard_parity(
            &format!("sharded slots seed {seed}"),
            &cluster,
            &trace,
            &opts,
            || SlotsScheduler::new(&cluster, 14),
        );
        assert_shard_parity(
            &format!("sharded naive firstfit seed {seed}"),
            &cluster,
            &trace,
            &opts,
            FirstFitDrfh::naive,
        );
    }
}

/// Engineered cross-shard collisions: everything lands on a 10 s grid
/// (arrivals, completions at rate 1, and the sample tick), so every
/// wave mixes `Arrival`s (lane 0), `ServerCheck`s owned by *different*
/// shards, and a `Sample` barrier at the same timestamp. The merge
/// cursor must reconcile the cross-lane picks in the exact global
/// `(time, seq)` order the sequential engine uses, at every shard
/// count and on both queue kinds.
#[test]
fn cross_shard_simultaneous_events_tiebreak() {
    let mut rng = Pcg32::seeded(4343);
    let cluster = Cluster::google_sample(10, &mut rng);
    let users: Vec<UserSpec> = (0..5)
        .map(|_| UserSpec {
            demand: ResVec::cpu_mem(
                rng.uniform(0.1, 0.4),
                rng.uniform(0.1, 0.4),
            ),
            weight: 1.0,
        })
        .collect();
    let jobs: Vec<JobSpec> = (0..25)
        .map(|j| JobSpec {
            id: j,
            user: j % 5,
            submit: ((j / 5) as f64) * 10.0, // 5 arrivals per timestamp
            tasks: vec![
                TaskSpec { duration: 10.0 * (1 + j % 4) as f64 };
                12
            ],
        })
        .collect();
    let trace = Trace { users, jobs };
    for kind in [QueueKind::Wheel, QueueKind::Heap] {
        let opts = SimOpts {
            horizon: 1_000.0,
            sample_dt: 10.0,
            track_user_series: false,
            queue: kind,
            ..SimOpts::default()
        };
        // 10 servers over 8 shards: most shards own a single server,
        // so simultaneous completions almost always span shards
        assert_shard_parity(
            &format!("cross-shard bestfit {kind:?}"),
            &cluster,
            &trace,
            &opts,
            BestFitDrfh::default,
        );
        // Slots overcommits: PS rate changes reschedule ServerChecks
        // that keep colliding with the sample grid while rates are 1
        assert_shard_parity(
            &format!("cross-shard slots {kind:?}"),
            &cluster,
            &trace,
            &opts,
            || SlotsScheduler::new(&cluster, 14),
        );
    }
}

/// Streaming metrics must not perturb the simulation: identical
/// decision streams and identical streaming job statistics, with the
/// report differing only in what is *retained*.
#[test]
fn streaming_metrics_decision_parity() {
    use drfh::sim::MetricsMode;
    let (cluster, trace, opts) = random_setup(12_000, 77);
    let log_s = Rc::new(RefCell::new(Vec::new()));
    let log_f = Rc::new(RefCell::new(Vec::new()));
    let rs = run(
        cluster.clone(),
        &trace,
        Box::new(Recording {
            inner: BestFitDrfh::default(),
            log: log_s.clone(),
        }),
        SimOpts {
            metrics: MetricsMode::Streaming { series_cap: 16 },
            ..opts.clone()
        },
    );
    let rf = run(
        cluster.clone(),
        &trace,
        Box::new(Recording {
            inner: BestFitDrfh::default(),
            log: log_f.clone(),
        }),
        opts.clone(),
    );
    assert_eq!(*log_s.borrow(), *log_f.borrow(), "decision streams");
    assert_eq!(rs.tasks_placed, rf.tasks_placed);
    assert_eq!(rs.tasks_completed, rf.tasks_completed);
    assert_eq!(rs.job_stats, rf.job_stats, "streaming job stats");
    assert_eq!(rs.user_tasks, rf.user_tasks);
    assert!(rs.jobs.is_empty() && !rf.jobs.is_empty());
    assert!(rs.cpu_util.len() <= 16 && rs.cpu_util.len() < rf.cpu_util.len());
    // the decimated series stays within plotting tolerance even at a
    // punishingly small cap (16 points for a 41-sample horizon)
    assert!(
        (rs.avg_cpu_util - rf.avg_cpu_util).abs() < 0.08,
        "decimated avg {} vs full {}",
        rs.avg_cpu_util,
        rf.avg_cpu_util
    );
}

// ------------------------------------------- wave-boundary auditor

/// Audit-mode decision neutrality: a run with the wave-boundary
/// invariant auditor enabled (`SimOpts::audit`) must produce a
/// [`drfh::sim::SimReport`] bit-identical to the unaudited run, at
/// every shard count. The audited leg doubles as a full-trace
/// invariant pass — any violated invariant panics the run.
fn assert_audit_parity<S, F>(
    label: &str,
    cluster: &Cluster,
    trace: &Trace,
    opts: &SimOpts,
    mk: F,
) where
    S: Scheduler + 'static,
    F: Fn() -> S,
{
    for shards in [1usize, 3, 8] {
        let base = SimOpts {
            shards: ShardCount::Fixed(shards),
            ..opts.clone()
        };
        let r_off = run(
            cluster.clone(),
            trace,
            Box::new(mk()),
            SimOpts { audit: false, ..base.clone() },
        );
        let r_on = run(
            cluster.clone(),
            trace,
            Box::new(mk()),
            SimOpts { audit: true, ..base },
        );
        assert!(
            r_off.tasks_placed > 0,
            "{label} S={shards}: degenerate run placed nothing"
        );
        assert_eq!(
            r_off, r_on,
            "{label} S={shards}: audited run diverged from unaudited"
        );
    }
}

/// The engineered same-timestamp collision trace of
/// `cross_shard_simultaneous_events_tiebreak`, as a reusable builder:
/// every wave mixes arrivals, cross-shard completions, and a sample
/// barrier on a 10 s grid.
fn tiebreak_trace(seed: u64) -> (Cluster, Trace) {
    let mut rng = Pcg32::seeded(seed);
    let cluster = Cluster::google_sample(10, &mut rng);
    let users: Vec<UserSpec> = (0..5)
        .map(|_| UserSpec {
            demand: ResVec::cpu_mem(
                rng.uniform(0.1, 0.4),
                rng.uniform(0.1, 0.4),
            ),
            weight: 1.0,
        })
        .collect();
    let jobs: Vec<JobSpec> = (0..25)
        .map(|j| JobSpec {
            id: j,
            user: j % 5,
            submit: ((j / 5) as f64) * 10.0,
            tasks: vec![
                TaskSpec { duration: 10.0 * (1 + j % 4) as f64 };
                12
            ],
        })
        .collect();
    (cluster, Trace { users, jobs })
}

/// The satellite acceptance matrix: audit-on vs audit-off over the
/// Fig. 5 configuration (every report surface tracked) and the
/// engineered cross-shard tie-break trace, for the indexed DRFH
/// policies, the naive reference, and the overcommitting Slots
/// baseline — each across shard counts {1, 3, 8}.
#[test]
fn audit_mode_is_decision_neutral() {
    use drfh::experiments::EvalSetup;
    let setup = EvalSetup::with_duration(42, 150, 15, 6_000.0);
    let opts = SimOpts { track_user_series: true, ..setup.opts.clone() };
    assert_audit_parity(
        "audit fig5 bestfit",
        &setup.cluster,
        &setup.trace,
        &opts,
        BestFitDrfh::default,
    );
    assert_audit_parity(
        "audit fig5 firstfit",
        &setup.cluster,
        &setup.trace,
        &opts,
        FirstFitDrfh::default,
    );

    let (cluster, trace) = tiebreak_trace(4343);
    let opts = SimOpts {
        horizon: 1_000.0,
        sample_dt: 10.0,
        track_user_series: false,
        ..SimOpts::default()
    };
    assert_audit_parity(
        "audit tie-break bestfit",
        &cluster,
        &trace,
        &opts,
        BestFitDrfh::default,
    );
    assert_audit_parity(
        "audit tie-break naive bestfit",
        &cluster,
        &trace,
        &opts,
        BestFitDrfh::naive,
    );
    assert_audit_parity(
        "audit tie-break slots",
        &cluster,
        &trace,
        &opts,
        || SlotsScheduler::new(&cluster, 14),
    );
}

/// The auditor actually audits: corrupting engine state that every
/// unaudited run would silently accept must panic the audited run
/// with the structured "DRFH audit failure" dump at the first wave.
#[test]
fn audit_trips_on_corrupted_server_state() {
    use drfh::sim::Simulation;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    let mut rng = Pcg32::seeded(99);
    let cluster = Cluster::google_sample(4, &mut rng);
    let trace = Trace {
        users: vec![UserSpec {
            demand: ResVec::cpu_mem(0.2, 0.2),
            weight: 1.0,
        }],
        jobs: vec![JobSpec {
            id: 0,
            user: 0,
            submit: 0.0,
            tasks: vec![TaskSpec { duration: 10.0 }; 4],
        }],
    };
    let opts = SimOpts { audit: true, ..SimOpts::default() };
    let mut sim = Simulation::new(
        cluster,
        &trace,
        Box::new(BestFitDrfh::default()),
        opts,
    );
    // phantom usage with no backing run entries: capacity
    // conservation is violated from the first wave on
    sim.cluster.servers[0].usage = ResVec::cpu_mem(0.5, 0.5);
    let err = catch_unwind(AssertUnwindSafe(move || sim.run()))
        .expect_err("audited run accepted corrupted server usage");
    let msg = err
        .downcast_ref::<String>()
        .cloned()
        .unwrap_or_else(|| {
            err.downcast_ref::<&str>().map(|s| s.to_string()).unwrap()
        });
    assert!(
        msg.contains("DRFH audit failure"),
        "unexpected panic message: {msg}"
    );
    assert!(msg.contains("capacity"), "unexpected panic message: {msg}");
}

/// The index-vs-naive cross-check trips on real index drift: mutating
/// a user's dominant share behind the policy's back (no `mark_dirty`,
/// no engine notification) makes the cached `ShareHeap` argmin
/// disagree with a fresh naive scan, and
/// [`Scheduler::audit_indices`] must report it.
#[test]
fn corrupted_index_trips_audit_indices() {
    let mut rng = Pcg32::seeded(77);
    let cluster = Cluster::google_sample(4, &mut rng);
    // engine-consistent users: `dom_share == running as f64 * dom_delta`
    // holds bitwise (the classed index re-derives shares from the
    // running count, the per-user heap caches `share_key` — both must
    // agree with the naive scan on healthy state)
    let mk_user = |running: usize| UserState {
        demand: ResVec::cpu_mem(0.1, 0.1),
        weight: 1.0,
        pending: 5,
        running,
        dom_share: running as f64 * 0.01,
        usage: ResVec::zeros(2),
        dom_delta: 0.01,
    };
    let mut users = vec![mk_user(50), mk_user(0)];
    let eligible = vec![true, true];
    for sched in [BestFitDrfh::per_user, BestFitDrfh::default] {
        let mut sched = sched();
        // a real pick builds the incremental indexes: user 1 holds
        // the lowest share
        match sched.pick(&cluster, &users, &eligible) {
            Pick::Place { user, .. } => assert_eq!(user, 1),
            p => panic!("expected a placement, got {p:?}"),
        }
        assert!(
            sched.audit_indices(&cluster, &users, &eligible).is_ok(),
            "audit_indices flagged a healthy index"
        );
        // corrupt the authoritative state without any notification:
        // the cached argmin (user 1) now disagrees with a naive scan
        // (user 0)
        users[0].dom_share = -1.0;
        let res = sched.audit_indices(&cluster, &users, &eligible);
        assert!(
            res.is_err(),
            "audit_indices missed a stale share index"
        );
        users[0].dom_share = 50.0 * 0.01; // restore for the next variant
    }
}

// ------------------------------------------------- fault injection

/// `FaultPlan::none()` parity (the PR's acceptance gate): an explicit
/// empty plan — and a plan whose outages all land past the horizon, so
/// it compiles to zero queued events — must produce a [`drfh::sim::
/// SimReport`] bit-identical to the default run, for Best-Fit,
/// First-Fit, and Slots, at S ∈ {1, 3, 8}. A non-default retry policy
/// rides along on the empty-plan leg: with nothing to evict it must
/// never be consulted.
#[test]
fn fault_plan_none_is_bit_identical() {
    use drfh::experiments::EvalSetup;
    let setup = EvalSetup::with_duration(42, 120, 12, 5_000.0);
    let h = setup.opts.horizon;
    let mks: Vec<(&str, fn(&Cluster) -> Box<dyn Scheduler>)> = vec![
        ("bestfit", |_| Box::new(BestFitDrfh::default())),
        ("firstfit", |_| Box::new(FirstFitDrfh::default())),
        ("slots", |c| Box::new(SlotsScheduler::new(c, 14))),
    ];
    for (name, mk) in mks {
        for shards in [1usize, 3, 8] {
            let base = SimOpts {
                shards: ShardCount::Fixed(shards),
                ..setup.opts.clone()
            };
            let r_default = run(
                setup.cluster.clone(),
                &setup.trace,
                mk(&setup.cluster),
                base.clone(),
            );
            let r_none = run(
                setup.cluster.clone(),
                &setup.trace,
                mk(&setup.cluster),
                SimOpts {
                    faults: FaultPlan::none(),
                    retry: RetryPolicy {
                        max_attempts: 9,
                        base: 1.0,
                        cap: 10.0,
                        jitter: 0.0,
                    },
                    ..base.clone()
                },
            );
            assert_eq!(
                r_default, r_none,
                "{name} S={shards}: FaultPlan::none() perturbed the run"
            );
            // every event past the horizon is dropped at push time, so
            // this plan is behaviorally empty too
            let late = FaultPlan::from_intervals(
                7,
                0.05,
                &[(0, h + 10.0, h + 20.0), (3, h + 1.0, h + 5.0)],
            );
            let r_late = run(
                setup.cluster.clone(),
                &setup.trace,
                mk(&setup.cluster),
                SimOpts { faults: late, ..base },
            );
            assert_eq!(
                r_default, r_late,
                "{name} S={shards}: past-horizon plan perturbed the run"
            );
            assert_eq!(r_default.evictions, 0);
            assert_eq!(r_default.wasted_s, 0.0);
            assert!(r_default.outages.is_empty());
        }
    }
}

/// Mid-wave crash collisions across shards: the tie-break trace puts
/// arrivals, completions, and the sample barrier on a 10 s grid, and
/// the plan downs servers exactly on that grid (two in the same wave,
/// one off-grid, one repeat outage on a recovered server) — so the
/// `ServerDown`/`ServerUp` barriers split waves that are already
/// three-way collisions. Decision streams and full `SimReport`s must
/// be identical at S ∈ {1, 2, 3, 8} on both queue kinds, and the plan
/// must actually evict (else the matrix proves nothing).
#[test]
fn midwave_crash_parity_across_shards() {
    let (cluster, trace) = tiebreak_trace(4343);
    let plan = FaultPlan::from_intervals(
        11,
        0.05,
        &[
            (0, 20.0, 60.0),
            (3, 20.0, 90.0),   // second down in the same wave
            (5, 35.0, 55.0),   // off-grid: splits between grid waves
            (0, 200.0, 260.0), // repeat outage on a recovered server
        ],
    );
    let retry = RetryPolicy {
        max_attempts: 3,
        base: 5.0,
        cap: 40.0,
        jitter: 0.5,
    };
    for kind in [QueueKind::Wheel, QueueKind::Heap] {
        let opts = SimOpts {
            horizon: 1_000.0,
            sample_dt: 10.0,
            track_user_series: false,
            queue: kind,
            faults: plan.clone(),
            retry,
            ..SimOpts::default()
        };
        assert_shard_parity(
            &format!("midwave bestfit {kind:?}"),
            &cluster,
            &trace,
            &opts,
            BestFitDrfh::default,
        );
        assert_shard_parity(
            &format!("midwave slots {kind:?}"),
            &cluster,
            &trace,
            &opts,
            || SlotsScheduler::new(&cluster, 14),
        );
    }
    let opts = SimOpts {
        horizon: 1_000.0,
        sample_dt: 10.0,
        track_user_series: false,
        faults: plan,
        retry,
        ..SimOpts::default()
    };
    let r = run(
        cluster.clone(),
        &trace,
        Box::new(BestFitDrfh::default()),
        opts,
    );
    assert!(r.evictions > 0, "crash plan evicted nothing");
    assert_eq!(r.evictions, r.retries + r.tasks_lost);
    assert_eq!(r.outages.len(), 4, "one record per compiled down event");
}

/// Seeded replay: the same generator config + seed compiles to the
/// same plan, and the same plan + trace replays to a bit-identical
/// `SimReport` — rerun or sharded. A different fault seed moves the
/// plan.
#[test]
fn seeded_fault_replay_is_reproducible() {
    use drfh::experiments::EvalSetup;
    let setup = EvalSetup::with_duration(7, 100, 10, 5_000.0);
    let cfg = FaultGenConfig {
        crash_rate: 4e-5,
        mean_downtime: 400.0,
        flash_at: Some(1_200.0),
        flash_fraction: 0.2,
        flash_downtime: 900.0,
        ..FaultGenConfig::default()
    };
    let (k, h) = (setup.cluster.len(), setup.opts.horizon);
    let plan = generate_faults(&cfg, k, h, 99);
    assert_eq!(
        plan,
        generate_faults(&cfg, k, h, 99),
        "same seed must compile the same plan"
    );
    assert_ne!(
        plan.events,
        generate_faults(&cfg, k, h, 100).events,
        "a different fault seed must move the plan"
    );
    let mk_opts = |shards| SimOpts {
        faults: plan.clone(),
        shards: ShardCount::Fixed(shards),
        ..setup.opts.clone()
    };
    let r1 = run(
        setup.cluster.clone(),
        &setup.trace,
        Box::new(BestFitDrfh::default()),
        mk_opts(1),
    );
    assert!(r1.evictions > 0, "replay guard needs a non-vacuous plan");
    let r2 = run(
        setup.cluster.clone(),
        &setup.trace,
        Box::new(BestFitDrfh::default()),
        mk_opts(1),
    );
    assert_eq!(r1, r2, "same plan + seed must replay bit-identically");
    let r8 = run(
        setup.cluster.clone(),
        &setup.trace,
        Box::new(BestFitDrfh::default()),
        mk_opts(8),
    );
    assert_eq!(r1, r8, "sharded faulted replay diverged from S=1");
}

/// Audit neutrality with a live fault plan: the fault invariants
/// (down-server drain, attempt budgets, parked-retry slots) run every
/// wave on healthy state without tripping, and the audited report
/// stays bit-identical to the unaudited one across shard counts.
#[test]
fn audit_mode_is_decision_neutral_under_faults() {
    let (cluster, trace) = tiebreak_trace(4545);
    let plan = FaultPlan::from_intervals(
        3,
        0.05,
        &[(1, 20.0, 80.0), (4, 50.0, 120.0), (7, 100.0, 160.0)],
    );
    let opts = SimOpts {
        horizon: 1_000.0,
        sample_dt: 10.0,
        track_user_series: false,
        faults: plan,
        ..SimOpts::default()
    };
    assert_audit_parity(
        "audit faulted bestfit",
        &cluster,
        &trace,
        &opts,
        BestFitDrfh::default,
    );
    assert_audit_parity(
        "audit faulted slots",
        &cluster,
        &trace,
        &opts,
        || SlotsScheduler::new(&cluster, 14),
    );
}

/// The fault auditor actually audits: phantom usage on a server the
/// plan downs at t = 0 survives the eviction drain (no run entries
/// back it), so the down server retains usage the fault invariant
/// forbids — the audited run must panic with the structured dump and
/// name the fault invariant.
#[test]
fn audit_trips_on_phantom_usage_on_a_down_server() {
    use drfh::sim::Simulation;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    let mut rng = Pcg32::seeded(99);
    let cluster = Cluster::google_sample(4, &mut rng);
    let trace = Trace {
        users: vec![UserSpec {
            demand: ResVec::cpu_mem(0.2, 0.2),
            weight: 1.0,
        }],
        jobs: vec![JobSpec {
            id: 0,
            user: 0,
            submit: 0.0,
            tasks: vec![TaskSpec { duration: 10.0 }; 4],
        }],
    };
    let plan = FaultPlan::from_intervals(1, 0.05, &[(0, 0.0, 50.0)]);
    let opts = SimOpts { audit: true, faults: plan, ..SimOpts::default() };
    let mut sim = Simulation::new(
        cluster,
        &trace,
        Box::new(BestFitDrfh::default()),
        opts,
    );
    sim.cluster.servers[0].usage = ResVec::cpu_mem(0.5, 0.5);
    let err = catch_unwind(AssertUnwindSafe(move || sim.run()))
        .expect_err("audited faulted run accepted phantom down-server usage");
    let msg = err
        .downcast_ref::<String>()
        .cloned()
        .unwrap_or_else(|| {
            err.downcast_ref::<&str>().map(|s| s.to_string()).unwrap()
        });
    assert!(
        msg.contains("DRFH audit failure"),
        "unexpected panic message: {msg}"
    );
    assert!(
        msg.contains("faults:"),
        "fault invariant missing from the dump: {msg}"
    );
}

// ---------------------------------------------------- user churn

/// `ChurnPlan::none()` parity (the PR's acceptance gate): an explicit
/// empty plan — and a plan whose transitions all land past the
/// horizon, so it sets `has_churn` but compiles to zero queued events
/// — must produce a [`drfh::sim::SimReport`] bit-identical to the
/// default run, for Best-Fit, First-Fit, and Slots, at S ∈ {1, 3, 8}.
/// The past-horizon leg is the sharp one: it proves the engine's
/// presence/epoch gates are decision-neutral while armed, not just
/// skipped.
#[test]
fn churn_plan_none_is_bit_identical() {
    use drfh::experiments::EvalSetup;
    let setup = EvalSetup::with_duration(42, 120, 12, 5_000.0);
    let h = setup.opts.horizon;
    let mks: Vec<(&str, fn(&Cluster) -> Box<dyn Scheduler>)> = vec![
        ("bestfit", |_| Box::new(BestFitDrfh::default())),
        ("firstfit", |_| Box::new(FirstFitDrfh::default())),
        ("slots", |c| Box::new(SlotsScheduler::new(c, 14))),
    ];
    for (name, mk) in mks {
        for shards in [1usize, 3, 8] {
            let base = SimOpts {
                shards: ShardCount::Fixed(shards),
                ..setup.opts.clone()
            };
            let r_default = run(
                setup.cluster.clone(),
                &setup.trace,
                mk(&setup.cluster),
                base.clone(),
            );
            let r_none = run(
                setup.cluster.clone(),
                &setup.trace,
                mk(&setup.cluster),
                SimOpts { churn: ChurnPlan::none(), ..base.clone() },
            );
            assert_eq!(
                r_default, r_none,
                "{name} S={shards}: ChurnPlan::none() perturbed the run"
            );
            // every transition past the horizon is dropped at push
            // time (consuming no seq), so this plan is behaviorally
            // empty too — even though `has_churn` is armed
            let late = ChurnPlan::from_transitions(
                7,
                vec![],
                vec![
                    ChurnEvent { time: h + 10.0, user: 0, join: false },
                    ChurnEvent { time: h + 20.0, user: 0, join: true },
                    ChurnEvent { time: h + 1.0, user: 3, join: false },
                ],
            );
            assert!(!late.is_empty(), "the late plan must arm has_churn");
            let r_late = run(
                setup.cluster.clone(),
                &setup.trace,
                mk(&setup.cluster),
                SimOpts { churn: late, ..base },
            );
            assert_eq!(
                r_default, r_late,
                "{name} S={shards}: past-horizon churn plan perturbed \
                 the run"
            );
            assert_eq!(r_default.user_joins, 0);
            assert_eq!(r_default.user_leaves, 0);
            assert_eq!(r_default.tasks_abandoned, 0);
            assert_eq!(r_default.abandoned_s, 0.0);
        }
    }
}

/// Mid-wave join/leave collisions across shards: the tie-break trace
/// puts arrivals, completions, and the sample barrier on a 10 s grid;
/// the churn plan fires transitions exactly on that grid and the
/// stacked fault plan downs servers at the *same* instants — so at
/// t = 20 one wave mixes two `ServerDown`s, a `UserLeave`, a
/// `UserJoin`, five arrivals, and the sample barrier. Decision
/// streams and full `SimReport`s must be identical at S ∈ {1, 2, 3,
/// 8} on both queue kinds, and the plan must actually churn (else
/// the matrix proves nothing).
#[test]
fn midwave_churn_parity_across_shards() {
    let (cluster, trace) = tiebreak_trace(4343);
    let churn = ChurnPlan::from_transitions(
        13,
        vec![2], // user 2 misses its t = 0 and t = 10 arrivals
        vec![
            // same wave as ServerDown(0)/ServerDown(3) + 5 arrivals
            ChurnEvent { time: 20.0, user: 0, join: false },
            ChurnEvent { time: 20.0, user: 2, join: true },
            // off-grid, same instant as ServerDown(5)
            ChurnEvent { time: 35.0, user: 1, join: false },
            // on-grid leave colliding with the t = 40 arrivals
            ChurnEvent { time: 40.0, user: 3, join: false },
            // rejoin in the ServerUp(3) wave
            ChurnEvent { time: 90.0, user: 0, join: true },
            // rejoins colliding with the repeat outage window
            ChurnEvent { time: 200.0, user: 1, join: true },
            ChurnEvent { time: 260.0, user: 3, join: true },
        ],
    );
    assert_eq!(churn.events.len(), 7, "no transition should be dropped");
    let faults = FaultPlan::from_intervals(
        11,
        0.05,
        &[
            (0, 20.0, 60.0),
            (3, 20.0, 90.0),
            (5, 35.0, 55.0),
            (0, 200.0, 260.0),
        ],
    );
    let retry = RetryPolicy {
        max_attempts: 3,
        base: 5.0,
        cap: 40.0,
        jitter: 0.5,
    };
    for kind in [QueueKind::Wheel, QueueKind::Heap] {
        let opts = SimOpts {
            horizon: 1_000.0,
            sample_dt: 10.0,
            track_user_series: false,
            queue: kind,
            churn: churn.clone(),
            faults: faults.clone(),
            retry,
            ..SimOpts::default()
        };
        assert_shard_parity(
            &format!("midwave churn bestfit {kind:?}"),
            &cluster,
            &trace,
            &opts,
            BestFitDrfh::default,
        );
        assert_shard_parity(
            &format!("midwave churn slots {kind:?}"),
            &cluster,
            &trace,
            &opts,
            || SlotsScheduler::new(&cluster, 14),
        );
    }
    let opts = SimOpts {
        horizon: 1_000.0,
        sample_dt: 10.0,
        track_user_series: false,
        churn,
        faults,
        retry,
        ..SimOpts::default()
    };
    let r = run(
        cluster.clone(),
        &trace,
        Box::new(BestFitDrfh::default()),
        opts,
    );
    // every in-horizon transition applies exactly once
    assert_eq!(r.user_leaves, 3, "leaves not applied");
    assert_eq!(r.user_joins, 4, "joins not applied");
    assert!(r.tasks_abandoned > 0, "churn plan abandoned nothing");
    assert!(r.abandoned_s > 0.0, "no evicted in-flight work recorded");
    assert!(r.evictions > 0, "stacked crash plan evicted nothing");
}

/// Seeded replay: the same churn generator config + seed compiles to
/// the same plan, and the same plan + trace replays to a bit-identical
/// `SimReport` — rerun or sharded. A different churn seed moves the
/// plan.
#[test]
fn seeded_churn_replay_is_reproducible() {
    use drfh::experiments::EvalSetup;
    let setup = EvalSetup::with_duration(7, 100, 10, 5_000.0);
    let cfg = ChurnGenConfig {
        leave_rate: 2e-4,
        absent_frac: 0.2,
        flash_at: Some(1_200.0),
        flash_fraction: 0.3,
        flash_hold: 800.0,
        ..ChurnGenConfig::default()
    };
    let (n, h) = (setup.trace.users.len(), setup.opts.horizon);
    let plan = generate_churn(&cfg, n, h, 99);
    assert_eq!(
        plan,
        generate_churn(&cfg, n, h, 99),
        "same seed must compile the same plan"
    );
    assert_ne!(
        plan.events,
        generate_churn(&cfg, n, h, 100).events,
        "a different churn seed must move the plan"
    );
    let mk_opts = |shards| SimOpts {
        churn: plan.clone(),
        shards: ShardCount::Fixed(shards),
        ..setup.opts.clone()
    };
    let r1 = run(
        setup.cluster.clone(),
        &setup.trace,
        Box::new(BestFitDrfh::default()),
        mk_opts(1),
    );
    assert!(r1.user_leaves > 0, "replay guard needs a non-vacuous plan");
    assert!(r1.user_joins > 0, "replay guard needs rejoins in-horizon");
    let r2 = run(
        setup.cluster.clone(),
        &setup.trace,
        Box::new(BestFitDrfh::default()),
        mk_opts(1),
    );
    assert_eq!(r1, r2, "same plan + seed must replay bit-identically");
    let r8 = run(
        setup.cluster.clone(),
        &setup.trace,
        Box::new(BestFitDrfh::default()),
        mk_opts(8),
    );
    assert_eq!(r1, r8, "sharded churned replay diverged from S=1");
}

/// Audit neutrality with a live churn plan: the churn invariants
/// (departed users ineligible, presence/epoch bookkeeping, abandoned
/// counters in the capacity balance) run every wave on healthy state
/// without tripping, and the audited report stays bit-identical to
/// the unaudited one across shard counts.
#[test]
fn audit_mode_is_decision_neutral_under_churn() {
    let (cluster, trace) = tiebreak_trace(4545);
    let churn = ChurnPlan::from_transitions(
        5,
        vec![4],
        vec![
            ChurnEvent { time: 20.0, user: 0, join: false },
            ChurnEvent { time: 30.0, user: 4, join: true },
            ChurnEvent { time: 50.0, user: 2, join: false },
            ChurnEvent { time: 120.0, user: 0, join: true },
            ChurnEvent { time: 300.0, user: 2, join: true },
        ],
    );
    let opts = SimOpts {
        horizon: 1_000.0,
        sample_dt: 10.0,
        track_user_series: false,
        churn,
        ..SimOpts::default()
    };
    assert_audit_parity(
        "audit churned bestfit",
        &cluster,
        &trace,
        &opts,
        BestFitDrfh::default,
    );
    assert_audit_parity(
        "audit churned slots",
        &cluster,
        &trace,
        &opts,
        || SlotsScheduler::new(&cluster, 14),
    );
}
