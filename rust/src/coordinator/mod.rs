//! Online scheduling coordinator: the paper's scheduler as a service.
//!
//! A dedicated OS thread owns the scheduling state and receives task
//! submissions over an mpsc channel; placements stream back on another
//! channel. The decision hot path batches placements through the AOT
//! `sched_loop` XLA artifact when available (one PJRT call = up to 64
//! decisions) and falls back to the native picker otherwise — Python is
//! never involved at serving time.

use crate::cluster::{Cluster, ResVec};
use crate::runtime::{picker, XlaRuntime};
use crate::util::error::{anyhow, Result};
use std::path::PathBuf;
use std::sync::mpsc;
use std::thread::JoinHandle;

/// A task submission: `count` tasks for `user`.
#[derive(Clone, Debug)]
pub struct Submission {
    pub user: usize,
    pub count: usize,
}

/// A placement decision streamed back to the client.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlacementEvent {
    pub user: usize,
    pub server: usize,
}

enum Msg {
    Submit(Submission),
    /// Enqueue several submissions atomically before draining once —
    /// simultaneous arrivals compete fairly instead of first-come-all.
    SubmitMany(Vec<Submission>),
    /// Task finished on a server: return its resources.
    Finish { user: usize, server: usize },
    Snapshot(mpsc::Sender<CoordinatorStats>),
    Shutdown,
}

/// Coordinator statistics snapshot.
#[derive(Clone, Debug, Default)]
pub struct CoordinatorStats {
    pub placed: usize,
    pub pending: Vec<i32>,
    pub share: Vec<f32>,
    pub decisions_per_call: f64,
    pub xla_calls: usize,
}

/// Which engine computes batched decisions. PJRT handles are not
/// `Send`, so the XLA runtime is loaded *inside* the coordinator thread
/// from the given artifacts directory.
#[derive(Clone, Debug)]
pub enum Engine {
    Native,
    Xla(PathBuf),
}

/// Handle to a running coordinator thread.
pub struct Coordinator {
    tx: mpsc::Sender<Msg>,
    /// Placement decisions, in order.
    pub placements: mpsc::Receiver<PlacementEvent>,
    join: Option<JoinHandle<()>>,
}

struct State {
    avail: Vec<f32>,
    demand: Vec<f32>,
    share: Vec<f32>,
    weight: Vec<f32>,
    pending: Vec<i32>,
    n: usize,
    k: usize,
    m: usize,
    engine: Option<XlaRuntime>,
    placed: usize,
    xla_calls: usize,
    decisions_total: usize,
}

impl State {
    /// Drain as many placements as possible, emitting events.
    fn drain(&mut self, out: &mpsc::Sender<PlacementEvent>) {
        loop {
            let decisions = match &self.engine {
                None => {
                    let step = 64;
                    picker::sched_loop(
                        &mut self.avail,
                        &self.demand,
                        &mut self.share,
                        &self.weight,
                        &mut self.pending,
                        self.n,
                        self.k,
                        self.m,
                        step,
                    )
                }
                Some(rt) => {
                    let outcome = rt
                        .sched_loop(
                            &self.avail,
                            &self.demand,
                            &self.share,
                            &self.weight,
                            &self.pending,
                            self.n,
                            self.k,
                            self.m,
                        )
                        .expect("XLA sched_loop failed");
                    self.avail.copy_from_slice(&outcome.avail);
                    self.share.copy_from_slice(&outcome.share);
                    self.pending.copy_from_slice(&outcome.pending);
                    self.xla_calls += 1;
                    outcome.decisions
                }
            };
            let mut all_placed = true;
            let mut any = false;
            for (u, s) in &decisions {
                if *u >= 0 {
                    any = true;
                    self.placed += 1;
                    self.decisions_total += 1;
                    let _ = out.send(PlacementEvent {
                        user: *u as usize,
                        server: *s as usize,
                    });
                } else {
                    all_placed = false;
                }
            }
            // fully used batch => maybe more work; otherwise done
            if !any || !all_placed {
                break;
            }
        }
    }
}

impl Coordinator {
    /// Spawn a coordinator for `cluster` and the given per-user demands
    /// and weights.
    pub fn spawn(
        cluster: &Cluster,
        demands: &[ResVec],
        weights: &[f64],
        engine: Engine,
    ) -> Self {
        let m = cluster.dims();
        let n = demands.len();
        let k = cluster.len();
        let mut avail = Vec::with_capacity(k * m);
        for s in &cluster.servers {
            let a = s.available();
            for r in 0..m {
                avail.push(a[r] as f32);
            }
        }
        let mut demand = Vec::with_capacity(n * m);
        for d in demands {
            for r in 0..m {
                demand.push(d[r] as f32);
            }
        }
        let share = vec![0.0; n];
        let weight: Vec<f32> = weights.iter().map(|&w| w as f32).collect();
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ptx, prx) = mpsc::channel::<PlacementEvent>();
        let join = std::thread::spawn(move || {
            // PJRT handles are thread-bound: load the runtime here.
            let rt = match engine {
                Engine::Native => None,
                Engine::Xla(dir) => Some(
                    XlaRuntime::load(&dir)
                        .expect("loading XLA artifacts in coordinator"),
                ),
            };
            let mut st = State {
                avail,
                demand,
                share,
                weight,
                pending: vec![0; n],
                n,
                k,
                m,
                engine: rt,
                placed: 0,
                xla_calls: 0,
                decisions_total: 0,
            };
            while let Ok(msg) = rx.recv() {
                match msg {
                    Msg::Submit(s) => {
                        st.pending[s.user] += s.count as i32;
                        st.drain(&ptx);
                    }
                    Msg::SubmitMany(subs) => {
                        for s in subs {
                            st.pending[s.user] += s.count as i32;
                        }
                        st.drain(&ptx);
                    }
                    Msg::Finish { user, server } => {
                        // return the task's resources and dominant share
                        let mut dom = 0.0f32;
                        for r in 0..st.m {
                            let d = st.demand[user * st.m + r];
                            st.avail[server * st.m + r] += d;
                            dom = dom.max(d);
                        }
                        st.share[user] = (st.share[user] - dom).max(0.0);
                        st.drain(&ptx);
                    }
                    Msg::Snapshot(reply) => {
                        let _ = reply.send(CoordinatorStats {
                            placed: st.placed,
                            pending: st.pending.clone(),
                            share: st.share.clone(),
                            decisions_per_call: if st.xla_calls > 0 {
                                st.decisions_total as f64
                                    / st.xla_calls as f64
                            } else {
                                0.0
                            },
                            xla_calls: st.xla_calls,
                        });
                    }
                    Msg::Shutdown => break,
                }
            }
        });
        Coordinator { tx, placements: prx, join: Some(join) }
    }

    /// Submit `count` tasks for `user`.
    pub fn submit(&self, user: usize, count: usize) -> Result<()> {
        self.tx
            .send(Msg::Submit(Submission { user, count }))
            .map_err(|_| anyhow!("coordinator closed"))
    }

    /// Submit a batch atomically: all tasks are queued before any
    /// placement happens, so simultaneous arrivals compete fairly.
    pub fn submit_many(&self, subs: Vec<Submission>) -> Result<()> {
        self.tx
            .send(Msg::SubmitMany(subs))
            .map_err(|_| anyhow!("coordinator closed"))
    }

    /// Report a task completion (frees resources, may trigger more
    /// placements).
    pub fn finish(&self, user: usize, server: usize) -> Result<()> {
        self.tx
            .send(Msg::Finish { user, server })
            .map_err(|_| anyhow!("coordinator closed"))
    }

    /// Fetch a statistics snapshot (synchronous round-trip, so all
    /// previously sent messages have been processed when it returns).
    pub fn stats(&self) -> Result<CoordinatorStats> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Msg::Snapshot(tx))
            .map_err(|_| anyhow!("coordinator closed"))?;
        rx.recv().map_err(|_| anyhow!("coordinator died"))
    }

    /// Stop the coordinator and wait for the thread to exit.
    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            j.join().map_err(|_| anyhow!("coordinator panicked"))?;
        }
        Ok(())
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_coordinator_places_and_rebalances() {
        // Fig. 1-style instance with power-of-two demands so that f32
        // accumulation is exact: mem server (2, 8), cpu server (8, 2);
        // user 0 = (0.25, 1) mem-heavy, user 1 = (1, 0.25) cpu-heavy.
        let cluster = Cluster::from_capacities(&[
            ResVec::cpu_mem(2.0, 8.0),
            ResVec::cpu_mem(8.0, 2.0),
        ]);
        let demands =
            vec![ResVec::cpu_mem(0.25, 1.0), ResVec::cpu_mem(1.0, 0.25)];
        let weights = vec![1.0, 1.0];
        let coord =
            Coordinator::spawn(&cluster, &demands, &weights, Engine::Native);
        // interleave submissions so both users are queued while the
        // cluster fills (messages are processed in order)
        for _ in 0..9 {
            coord.submit(0, 1).unwrap();
            coord.submit(1, 1).unwrap();
        }
        let stats = coord.stats().unwrap();
        // each matching server fits exactly 8 tasks of its user
        assert_eq!(stats.placed, 16, "pending={:?}", stats.pending);
        assert_eq!(stats.pending, vec![1, 1]);

        // collect placements and check the routing
        let mut placements = Vec::new();
        while let Ok(p) = coord.placements.try_recv() {
            placements.push(p);
        }
        assert_eq!(placements.len(), 16);
        assert!(placements
            .iter()
            .all(|p| (p.user == 0) == (p.server == 0)));

        // finishing a task frees capacity for one more
        coord.finish(0, 0).unwrap();
        let stats = coord.stats().unwrap();
        assert_eq!(stats.placed, 17);
        coord.shutdown().unwrap();
    }

    #[test]
    fn shares_equalize_between_identical_users() {
        let cluster =
            Cluster::from_capacities(&[ResVec::cpu_mem(4.0, 4.0)]);
        let demands =
            vec![ResVec::cpu_mem(0.5, 0.5), ResVec::cpu_mem(0.5, 0.5)];
        let coord = Coordinator::spawn(
            &cluster,
            &demands,
            &[1.0, 1.0],
            Engine::Native,
        );
        for _ in 0..10 {
            coord.submit(0, 1).unwrap();
            coord.submit(1, 1).unwrap();
        }
        let stats = coord.stats().unwrap();
        // 8 fit; progressive filling alternates users -> 4/4
        assert_eq!(stats.placed, 8);
        assert!((stats.share[0] - stats.share[1]).abs() < 1e-6);
        coord.shutdown().unwrap();
    }
}
