//! Discrete task schedulers (paper Sec. V-B and the evaluation
//! baselines).
//!
//! A scheduler is a *policy*: given the current cluster and user states
//! it picks the next `(user, server)` placement. The simulation engine
//! owns all state mutation — committing resources, maintaining dominant
//! shares, firing events — so policies stay side-effect-free and
//! trivially swappable. Policies that keep incremental state (the
//! [`index`] structures, the Slots free-slot cursor) are fed by the
//! notification hooks below; notifications carry *which* entity changed
//! and policies re-read the authoritative state on the next `pick`.
//!
//! ## The blocked-user protocol
//!
//! Concluding "nothing can be placed" naively costs O(n·k) at every
//! scheduling opportunity, which dominates saturated-cluster runs. The
//! engine therefore caches a *blocked* set: when `pick` returns
//! [`Pick::Blocked`], the user is excluded from `eligible` until some
//! server frees resources, at which point the engine re-checks only
//! that server via [`Scheduler::can_fit`] — and, via
//! [`index::BlockedIndex`], only the blocked users whose minimum
//! demand component fits under the freed server's smallest headroom.
//! Demands are static per user (paper Sec. III-A), so a blocked
//! verdict stays valid until capacity is released. A re-eligible user
//! is announced to the policy through [`Scheduler::on_ready`].
//!
//! ## The batched-drain protocol
//!
//! The engine no longer asks for decisions one `pick` at a time: at
//! every event wave it hands the policy a [`DrainCtx`] and calls
//! [`Scheduler::drain`] once, and the policy places *all* placeable
//! work before returning. State ownership is unchanged — the policy
//! still never mutates cluster/user state directly; it calls
//! [`DrainCtx::place`] / [`DrainCtx::block`] and the engine commits
//! the placement (resources, queues, dominant shares, completion
//! events) before the call returns, so the policy always reads
//! post-commit state through the ctx accessors.
//!
//! Two implementations exist, with *bit-identical* decision streams
//! (asserted by `tests/engine_parity.rs`):
//!
//! * the **default** ([`drain_by_picks`]) — a loop over
//!   [`Scheduler::pick`], one virtual call and one index refresh per
//!   decision; this is the parity reference, and what naive policies
//!   and the XLA wrapper run;
//! * the **batched** override (Best-Fit / First-Fit via
//!   [`index::IndexedCore::drain`]) — one [`index::ShareHeap`] /
//!   [`index::PlacementIndex`] refresh per event wave, then the
//!   per-placement bookkeeping is applied inline (re-key the placed
//!   user, re-score the touched server) without re-entering the
//!   dirty-flag machinery, amortizing the refresh bookkeeping across
//!   the whole wave.
//!
//! Inside one drain the engine does not fire `on_place` (the policy
//! made the decision and already knows); the default loop self-
//! notifies so indexed policies that do not override `drain` keep
//! their incremental state current.
//!
//! ## §Perf: the indexed hot path
//!
//! The DRFH policies ship three decision paths with *bit-identical*
//! outputs (asserted by `tests/engine_parity.rs` on randomized traces
//! and by the unit parities in [`index`] and [`users`]):
//!
//! * the **naive** path — `min_share_user` O(n) + `best_server` /
//!   `first_server` O(k·m) linear scans, kept as the reference and
//!   constructed via `BestFitDrfh::naive()` / `FirstFitDrfh::naive()`;
//! * the **per-user indexed** path — [`index::ShareHeap`] +
//!   one [`index::PlacementIndex`] heap per user, maintained
//!   incrementally from the engine notifications, making a pick
//!   O(log n + log k) amortized (`BestFitDrfh::per_user()` /
//!   `FirstFitDrfh::per_user()`, the PR 1 layout);
//! * the **class-keyed** path (default) — user selection aggregated
//!   over `(dom_delta, weight)` groups ([`users::ClassedShareIndex`];
//!   builds whose groups do not aggregate fall back to an embedded
//!   per-user heap, so the worst case is the PR 1 layout) and
//!   placement/blocked structures shared per interned demand
//!   class ([`users::DemandClasses`]), so per-event maintenance
//!   scales with *distinct demand classes* rather than user count —
//!   the difference between O(n) and O(C) work per placement when n
//!   runs to the millions and C stays at tens
//!   (`benches/user_scale.rs`).
//!
//! Methodology: `benches/engine_scale.rs` times full simulations on
//! the Fig. 5 configuration (k = 2,000 Google-distribution servers,
//! saturated 24 h-style trace) against the naive path and
//! `benches/user_scale.rs` sweeps the user count at fixed class count
//! against the per-user path, reporting placement throughput and
//! speedups and writing `BENCH_engine.json` / `BENCH_users.json`;
//! decision parity is enforced separately (placement-count guards in
//! the benches, full pick-stream equality in
//! `tests/engine_parity.rs`) so speed never buys semantic drift.

pub mod best_fit;
pub mod first_fit;
pub mod index;
pub mod slots;
pub mod users;
pub mod xla;

pub use best_fit::BestFitDrfh;
pub use first_fit::FirstFitDrfh;
pub use slots::SlotsScheduler;
pub use xla::XlaBestFit;

use crate::cluster::{Cluster, ResVec};

/// Per-user scheduling state maintained by the engine.
#[derive(Clone, Debug)]
pub struct UserState {
    /// Per-task demand (absolute units).
    pub demand: ResVec,
    /// Fair-share weight.
    pub weight: f64,
    /// Queued (not yet placed) tasks.
    pub pending: usize,
    /// Currently running tasks.
    pub running: usize,
    /// Global dominant share currently held (pool-share units).
    pub dom_share: f64,
    /// Resources currently held (absolute units).
    pub usage: ResVec,
    /// Per-task dominant-resource demand in pool-share units
    /// (engine-precomputed: max_r demand_r / total_r).
    pub dom_delta: f64,
}

/// Guarded fair-share weight: a zero weight falls back to 1.0 instead
/// of producing inf/NaN share keys. This is the single source of truth
/// for zero-weight semantics — `runtime::picker::select_user` and the
/// Pallas kernel (`kernels/dominant.py`: `where(weight != 0, weight,
/// 1.0)`) implement the same rule in f32.
#[inline]
pub fn effective_weight(w: f64) -> f64 {
    if w != 0.0 {
        w
    } else {
        1.0
    }
}

impl UserState {
    /// Weighted progressive-filling key: lowest goes first.
    #[inline]
    pub fn share_key(&self) -> f64 {
        self.dom_share / effective_weight(self.weight)
    }
}

/// Outcome of one policy invocation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pick {
    /// Place one task of `user` on `server`.
    Place { user: usize, server: usize },
    /// `user` would be served next but fits on no server right now;
    /// the engine removes it from `eligible` until capacity frees up.
    Blocked { user: usize },
    /// No eligible user has pending work.
    Idle,
}

/// The engine surface a batched [`Scheduler::drain`] works against.
///
/// The engine owns all state mutation: the policy reads the current
/// state through the accessors and commits decisions through
/// [`DrainCtx::place`] / [`DrainCtx::block`]. Both mutators return
/// with the engine state already updated, so the next accessor call
/// observes the commit (exactly what a fresh `pick` invocation would
/// have seen under the single-pick protocol).
pub trait DrainCtx {
    /// Current cluster state (post any commits this drain).
    fn cluster(&self) -> &Cluster;
    /// Current per-user scheduling state.
    fn users(&self) -> &[UserState];
    /// Eligibility mask (blocked users are excluded by the engine).
    fn eligible(&self) -> &[bool];
    /// Commit one task of `user` onto `server`: resources, queues,
    /// dominant share, and the completion event are all updated
    /// before this returns. The engine does NOT echo `on_place` back
    /// during a drain — the deciding policy updates its own state.
    fn place(&mut self, user: usize, server: usize);
    /// `user` fits on no server right now: the engine removes it from
    /// `eligible` until some server frees capacity (the blocked-user
    /// protocol above), then announces it via [`Scheduler::on_ready`].
    fn block(&mut self, user: usize);
}

/// The reference drain: a loop of single [`Scheduler::pick`] calls,
/// exactly the engine's pre-batching `schedule_loop`. This is the
/// default [`Scheduler::drain`] body (kept callable by policies whose
/// override only covers some configurations) and the parity baseline
/// the batched implementations are asserted against.
pub fn drain_by_picks<S: Scheduler + ?Sized>(
    sched: &mut S,
    ctx: &mut dyn DrainCtx,
) {
    loop {
        match sched.pick(ctx.cluster(), ctx.users(), ctx.eligible()) {
            Pick::Idle => return,
            Pick::Blocked { user } => ctx.block(user),
            Pick::Place { user, server } => {
                ctx.place(user, server);
                // self-notify: the engine is silent during a drain
                sched.on_place(user, server);
            }
        }
    }
}

/// A scheduling policy. (Not `Send`: the XLA-backed policy wraps PJRT
/// handles that must stay on their creating thread.)
pub trait Scheduler {
    /// Human-readable policy name (used in reports).
    fn name(&self) -> &'static str;

    /// Pick the next placement among users with `eligible[i] == true`
    /// (the engine guarantees those have pending > 0). Must not mutate
    /// cluster/user state.
    fn pick(
        &mut self,
        cluster: &Cluster,
        users: &[UserState],
        eligible: &[bool],
    ) -> Pick;

    /// Could one task of `user` be placed on `server` right now? Used
    /// by the engine to unblock users when `server` frees capacity.
    ///
    /// Contract: the verdict may depend on `user` only through its
    /// demand vector (every in-tree policy checks either
    /// `server.fits(demand)` or a user-independent slot count). The
    /// engine relies on this to probe one representative per blocked
    /// demand class instead of every blocked user
    /// ([`index::BlockedIndex::candidate_classes`]).
    fn can_fit(
        &self,
        cluster: &Cluster,
        users: &[UserState],
        user: usize,
        server: usize,
    ) -> bool;

    /// Batched decision path: place every placeable task for this
    /// event wave through `ctx`, returning once nothing further can
    /// be placed. Decisions MUST match what a loop of `pick` calls
    /// would produce (enforced by `tests/engine_parity.rs`); the
    /// default body is exactly that loop ([`drain_by_picks`]).
    /// Policies with incremental indexes override this to refresh
    /// once per wave instead of once per decision.
    fn drain(&mut self, ctx: &mut dyn DrainCtx) {
        drain_by_picks(self, ctx);
    }

    /// May placements exceed server capacity? Only the Slots baseline
    /// says yes (it ignores real demands); the engine then applies the
    /// processor-sharing slowdown.
    fn allows_overcommit(&self) -> bool {
        false
    }

    /// Notification: a task released capacity on `server`. Lets
    /// policies maintain incremental state (the Slots free-slot cursor).
    fn on_free(&mut self, _server: usize) {}

    /// Notification: the engine committed one task of `user` onto
    /// `server` (fired after the commit). `user`'s share/pending and
    /// `server`'s availability changed.
    fn on_place(&mut self, _user: usize, _server: usize) {}

    /// Notification: one task of `user` completed on `server` (fired
    /// after the release, alongside [`Scheduler::on_free`]). `user`'s
    /// share and `server`'s availability changed.
    fn on_complete(&mut self, _user: usize, _server: usize) {}

    /// Notification: `user` (re-)entered the schedulable set — new
    /// work arrived or the engine unblocked it after a completion.
    fn on_ready(&mut self, _user: usize) {}

    /// Notification: `user` joined the cluster
    /// ([`crate::sim::ChurnPlan`]). Fired after the engine re-admitted
    /// it to `eligible` and before any pending work is announced via
    /// [`Scheduler::on_ready`]. Indexed policies re-key the user; the
    /// engine state is authoritative, so ignoring this (the default)
    /// is correct for stateless policies.
    fn on_user_join(&mut self, _user: usize) {}

    /// Notification: `user` left the cluster. Fired *after* the engine
    /// evicted its running tasks (each eviction fired
    /// [`Scheduler::on_complete`]), discarded its queued work, and
    /// removed it from `eligible`. Indexed policies drop the user from
    /// their share/blocked structures here; an ineligible user is
    /// never picked anyway, so ignoring this (the default) is correct
    /// for stateless policies.
    fn on_user_leave(&mut self, _user: usize) {}

    /// Notification: `server` crashed ([`crate::sim::FaultPlan`]).
    /// Fired *after* the engine evicted its run entries (each eviction
    /// fired [`Scheduler::on_complete`]) and before its capacity is
    /// zeroed. Indexed policies drop the server from their placement
    /// structures here; a zero-capacity server is infeasible to every
    /// fit/score path anyway, so ignoring this (the default) is
    /// correct for stateless policies.
    fn on_server_down(&mut self, _server: usize) {}

    /// Notification: `server` recovered — its saved capacity has just
    /// been restored. Indexed policies re-admit the server; the
    /// engine re-probes blocked users right after this returns.
    fn on_server_up(&mut self, _server: usize) {}

    /// Notification: the engine runs its sharded data plane with
    /// `shards` server-pool shards (fired once, before any event).
    /// Indexed policies mirror the layout (per-shard placement heaps,
    /// [`index::PlacementIndex::set_shards`]) so their maintenance
    /// stays shard-local; the cross-shard argmin keeps selections
    /// identical, so ignoring this (the default) is always correct.
    fn on_topology(&mut self, _shards: usize) {}

    /// Audit hook for the wave-boundary invariant auditor
    /// ([`crate::sim::audit`]): cross-check any incremental decision
    /// index this policy maintains against a fresh naive scan of the
    /// authoritative engine state, returning `Err(description)` on
    /// divergence. Implementations MUST be decision-neutral — only
    /// the refreshes and lazy pops the next `pick`/`drain` would have
    /// performed anyway are allowed, so an audit-enabled run stays
    /// bit-identical to an audit-off run. Policies without an index
    /// (the naive references) keep this default no-op.
    fn audit_indices(
        &mut self,
        _cluster: &Cluster,
        _users: &[UserState],
        _eligible: &[bool],
    ) -> Result<(), String> {
        Ok(())
    }
}

/// Lowest weighted-share eligible user (first on ties) — the
/// progressive-filling selection shared by the DRFH policies (naive
/// reference path; the indexed path is [`index::ShareHeap`]).
pub fn min_share_user(users: &[UserState], eligible: &[bool]) -> Option<usize> {
    let mut best: Option<usize> = None;
    for i in 0..users.len() {
        if !eligible[i] || users[i].pending == 0 {
            continue;
        }
        match best {
            Some(b) if users[b].share_key() <= users[i].share_key() => {}
            _ => best = Some(i),
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn user(share: f64, pending: usize) -> UserState {
        UserState {
            demand: ResVec::cpu_mem(0.1, 0.1),
            weight: 1.0,
            pending,
            running: 0,
            dom_share: share,
            usage: ResVec::zeros(2),
            dom_delta: 0.01,
        }
    }

    #[test]
    fn min_share_respects_eligibility_and_pending() {
        let users =
            vec![user(0.5, 1), user(0.1, 0), user(0.2, 3), user(0.2, 1)];
        let all = vec![true; 4];
        assert_eq!(min_share_user(&users, &all), Some(2)); // tie -> lowest idx
        let mask = vec![true, true, false, true];
        assert_eq!(min_share_user(&users, &mask), Some(3));
        assert_eq!(min_share_user(&users, &[false; 4]), None);
    }

    #[test]
    fn weighted_key() {
        let mut u = user(0.4, 1);
        u.weight = 2.0;
        assert!((u.share_key() - 0.2).abs() < 1e-12);
    }

    /// Zero weights must not poison the ordering with inf/NaN: the key
    /// falls back to weight 1.0, matching `picker::select_user` and the
    /// Pallas kernel.
    #[test]
    fn zero_weight_uses_guarded_semantics() {
        assert_eq!(effective_weight(0.0), 1.0);
        assert_eq!(effective_weight(2.5), 2.5);
        let mut u = user(0.4, 1);
        u.weight = 0.0;
        assert!(u.share_key().is_finite());
        assert!((u.share_key() - 0.4).abs() < 1e-12);

        // a zero-weight user is ranked as if weight were 1.0
        let mut zero_w = user(0.3, 1);
        zero_w.weight = 0.0;
        let users = vec![user(0.4, 1), zero_w, user(0.35, 1)];
        assert_eq!(min_share_user(&users, &[true; 3]), Some(1));
    }

    /// The f64 policy ranking and the f32 picker ranking agree on
    /// zero-weight handling.
    #[test]
    fn share_key_matches_picker_select_user() {
        let shares = [0.5f64, 0.3, 0.4, 0.2];
        let weights = [1.0f64, 0.0, 2.0, 0.5];
        let users: Vec<UserState> = shares
            .iter()
            .zip(&weights)
            .map(|(&s, &w)| {
                let mut u = user(s, 1);
                u.weight = w;
                u
            })
            .collect();
        let native = min_share_user(&users, &[true; 4]);
        let share32: Vec<f32> = shares.iter().map(|&s| s as f32).collect();
        let weight32: Vec<f32> = weights.iter().map(|&w| w as f32).collect();
        let picked = crate::runtime::picker::select_user(
            &share32,
            &weight32,
            &[true; 4],
        );
        assert_eq!(native, Some(picked as usize));
    }
}
