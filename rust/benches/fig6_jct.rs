//! Regenerates paper Fig. 6 (job completion times: CDF + per-size
//! reduction buckets) and times the paired comparison.
//!
//! Run: `cargo bench --bench fig6_jct`

use drfh::experiments::{fig6, EvalSetup};
use drfh::util::bench::{bench, header};
use std::time::Duration;

fn main() {
    let setup = EvalSetup::with_duration(42, 300, 30, 21_600.0);
    let res = fig6::run_fig6(&setup);
    fig6::print(&res);

    header("fig6: paired best-fit + slots runs");
    bench("fig6 paired run", Duration::from_secs(8), 10, || {
        fig6::run_fig6(&setup).matched.len()
    });
}
