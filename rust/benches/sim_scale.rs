//! §Perf headline for PR 4: the trace-scale simulation data plane.
//!
//! Runs the paper's Fig. 5 configuration scaled to ~10⁶ offered tasks
//! at k = 2,000 servers and times three variants of the same Best-Fit
//! DRFH simulation:
//!
//! * `wheel-streaming` — timer-wheel event queue + bounded-memory
//!   streaming metrics (the new data plane; run FIRST so the process
//!   RSS watermark reflects it alone);
//! * `wheel-full` — timer wheel with full metric retention;
//! * `heap-full` — the seed's binary-heap queue (naive parity
//!   reference).
//!
//! Targets: **≥3× tasks/sec** for the wheel+streaming plane over the
//! heap path, and peak memory **~flat in task count** under streaming
//! metrics (retained metric points bounded by the series cap instead
//! of growing with jobs/samples). Placement counts are asserted equal
//! across all variants here as a cheap guard; full bit-identical
//! report parity is enforced by `tests/engine_parity.rs` and the
//! `drfh exp sim-scale` harness.
//!
//! PR 6 adds the **shard sweep**: the same wheel+streaming simulation
//! at `[sim] shards` = 1, 2, 4, … up to the core count (one trace
//! across all cores, bit-identical merge — target **≥4×** at 8
//! shards). Placement counts are asserted equal across every shard
//! count; the bit-exact proof is
//! `tests/engine_parity.rs::sharded_engine_matches_sequential`.
//!
//! Results go to `BENCH_sim.json` at the repo root (override with
//! `BENCH_OUT=/path.json`); CI runs the small-scale smoke via
//! `SIM_SMOKE=1`, and the shard-sweep smoke (S ∈ {1, cores} only)
//! via `SIM_SHARD_SMOKE=1`.
//!
//! Run: `cargo bench --bench sim_scale`

use drfh::experiments::EvalSetup;
use drfh::metrics::MetricsMode;
use drfh::sched::BestFitDrfh;
use drfh::sim::{run, QueueKind, ShardCount, SimOpts, SimReport};
use drfh::util::bench::{
    bench_n, header, peak_rss_bytes, write_suite_json, BenchResult,
};
use drfh::util::json::Json;

struct Case {
    bench: BenchResult,
    report: SimReport,
    vmhwm_after: Option<u64>,
}

fn run_case(
    name: &str,
    iters: usize,
    setup: &EvalSetup,
    queue: QueueKind,
    metrics: MetricsMode,
    shards: ShardCount,
) -> Case {
    let mut report = None;
    let bench = bench_n(name, iters, || {
        let opts =
            SimOpts { queue, metrics, shards, ..setup.opts.clone() };
        let rep = run(
            setup.cluster.clone(),
            &setup.trace,
            Box::new(BestFitDrfh::default()),
            opts,
        );
        let placed = rep.tasks_placed;
        report = Some(rep);
        placed
    });
    Case {
        bench,
        report: report.expect("bench ran at least once"),
        vmhwm_after: peak_rss_bytes(),
    }
}

fn retained_points(rep: &SimReport) -> usize {
    rep.cpu_util.len() + rep.mem_util.len() + rep.jobs.len()
}

fn main() {
    let shard_smoke = std::env::var_os("SIM_SHARD_SMOKE").is_some();
    let smoke = std::env::var_os("SIM_SMOKE").is_some() || shard_smoke;
    // full scale: ~2.2e-4 jobs/(server·s) × 2000 servers × 32400 s
    // ≈ 14.3 k jobs ≈ 1.03 M tasks (see EvalSetup::with_duration)
    let (servers, users, duration, iters) = if smoke {
        (200usize, 20usize, 3_600.0f64, 1usize)
    } else {
        (2_000, 100, 32_400.0, 1)
    };
    let setup = EvalSetup::with_duration(2024, servers, users, duration);
    let offered = setup.trace.total_tasks();
    println!(
        "sim_scale: k={servers} n={users} horizon={duration:.0}s \
         ({offered} tasks offered){}",
        if smoke { " [smoke]" } else { "" }
    );

    header("sim_scale: full simulation, queue x metrics variants");
    // streaming first: the VmHWM watermark is monotone, so this
    // ordering lets the JSON show the bounded-memory plane's own peak
    let streaming = run_case(
        "wheel-streaming",
        iters,
        &setup,
        QueueKind::Wheel,
        MetricsMode::streaming(),
        ShardCount::Fixed(1),
    );
    let wheel_full = run_case(
        "wheel-full",
        iters,
        &setup,
        QueueKind::Wheel,
        MetricsMode::Full,
        ShardCount::Fixed(1),
    );
    let heap_full = run_case(
        "heap-full",
        iters,
        &setup,
        QueueKind::Heap,
        MetricsMode::Full,
        ShardCount::Fixed(1),
    );

    // cheap parity guards; the real proof is tests/engine_parity.rs
    assert_eq!(
        heap_full.report.tasks_placed, wheel_full.report.tasks_placed,
        "heap/wheel placement counts diverged"
    );
    assert_eq!(
        heap_full.report.tasks_completed,
        wheel_full.report.tasks_completed,
        "heap/wheel completion counts diverged"
    );
    assert_eq!(
        streaming.report.tasks_placed, wheel_full.report.tasks_placed,
        "streaming metrics changed the simulation itself"
    );
    assert_eq!(
        streaming.report.job_stats, wheel_full.report.job_stats,
        "streaming job stats diverged from full-mode job stats"
    );
    assert!(
        streaming.report.jobs.is_empty(),
        "streaming mode must not materialize job records"
    );

    let secs = |c: &Case| c.bench.mean.as_secs_f64().max(1e-12);
    let tps = |c: &Case| c.report.tasks_completed as f64 / secs(c);
    let pps = |c: &Case| c.report.tasks_placed as f64 / secs(c);
    let speedup_streaming = secs(&heap_full) / secs(&streaming);
    let speedup_wheel = secs(&heap_full) / secs(&wheel_full);
    println!(
        "\nheap-full       : {:>10.0} tasks/s  {:>10.0} placements/s",
        tps(&heap_full),
        pps(&heap_full)
    );
    println!(
        "wheel-full      : {:>10.0} tasks/s  {:>10.0} placements/s  ({speedup_wheel:.2}x)",
        tps(&wheel_full),
        pps(&wheel_full)
    );
    println!(
        "wheel-streaming : {:>10.0} tasks/s  {:>10.0} placements/s  ({speedup_streaming:.2}x)",
        tps(&streaming),
        pps(&streaming)
    );
    println!(
        "retained metric points: streaming {} vs full {} \
         (bounded vs growing); VmHWM after streaming/full/heap: {:?}/{:?}/{:?}",
        retained_points(&streaming.report),
        retained_points(&wheel_full.report),
        streaming.vmhwm_after,
        wheel_full.vmhwm_after,
        heap_full.vmhwm_after,
    );
    if !smoke && speedup_streaming < 3.0 {
        println!(
            "WARNING: wheel+streaming speedup {speedup_streaming:.2}x \
             below the 3x target"
        );
    }

    // ---- shard sweep: the same wheel+streaming plane at S = 1 → cores
    let hw = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1);
    let shard_counts: Vec<usize> = if shard_smoke {
        // CI smoke: the endpoints only
        if hw > 1 { vec![1, hw] } else { vec![1] }
    } else {
        let mut v = vec![1usize];
        let mut s = 2;
        while s < hw {
            v.push(s);
            s *= 2;
        }
        if hw > 1 {
            v.push(hw);
        }
        v
    };
    header("sim_scale: shard sweep (wheel + streaming)");
    let mut shard_cases: Vec<(usize, Case)> = Vec::new();
    for &s in &shard_counts {
        let case = run_case(
            &format!("shards-{s}"),
            iters,
            &setup,
            QueueKind::Wheel,
            MetricsMode::streaming(),
            ShardCount::Fixed(s),
        );
        // cheap parity guards across shard counts (bit-exact proof:
        // tests/engine_parity.rs::sharded_engine_matches_sequential)
        assert_eq!(
            case.report.tasks_placed, streaming.report.tasks_placed,
            "shards={s} changed placement counts"
        );
        assert_eq!(
            case.report.job_stats, streaming.report.job_stats,
            "shards={s} changed job statistics"
        );
        shard_cases.push((s, case));
    }
    let shard_base = secs(&shard_cases[0].1);
    for (s, case) in &shard_cases {
        println!(
            "shards-{s:<8} : {:>10.0} tasks/s  ({:.2}x vs 1 shard)",
            tps(case),
            shard_base / secs(case)
        );
    }

    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_sim.json")
            .to_string()
    });
    let opt_num = |v: Option<u64>| match v {
        Some(x) => Json::Num(x as f64),
        None => Json::Null,
    };
    let meta = [
        ("servers", Json::Num(servers as f64)),
        ("users", Json::Num(users as f64)),
        ("horizon_s", Json::Num(duration)),
        ("tasks_offered", Json::Num(offered as f64)),
        (
            "tasks_placed",
            Json::Num(wheel_full.report.tasks_placed as f64),
        ),
        (
            "tasks_completed",
            Json::Num(wheel_full.report.tasks_completed as f64),
        ),
        ("smoke", Json::Bool(smoke)),
        ("speedup_wheel_vs_heap", Json::Num(speedup_wheel)),
        (
            "speedup_streaming_vs_heap",
            Json::Num(speedup_streaming),
        ),
        ("tasks_per_sec_heap", Json::Num(tps(&heap_full))),
        ("tasks_per_sec_wheel", Json::Num(tps(&wheel_full))),
        ("tasks_per_sec_streaming", Json::Num(tps(&streaming))),
        ("placements_per_sec_heap", Json::Num(pps(&heap_full))),
        ("placements_per_sec_wheel", Json::Num(pps(&wheel_full))),
        (
            "placements_per_sec_streaming",
            Json::Num(pps(&streaming)),
        ),
        (
            "retained_points_streaming",
            Json::Num(retained_points(&streaming.report) as f64),
        ),
        (
            "retained_points_full",
            Json::Num(retained_points(&wheel_full.report) as f64),
        ),
        (
            "vmhwm_after_streaming_bytes",
            opt_num(streaming.vmhwm_after),
        ),
        (
            "vmhwm_after_full_bytes",
            opt_num(wheel_full.vmhwm_after),
        ),
        ("vmhwm_after_heap_bytes", opt_num(heap_full.vmhwm_after)),
    ];
    // per-shard-count throughput/speedup entries carry dynamic keys
    let mut meta: Vec<(String, Json)> =
        meta.into_iter().map(|(k, v)| (k.to_string(), v)).collect();
    meta.push((
        "shard_counts".to_string(),
        Json::Arr(
            shard_counts.iter().map(|&s| Json::Num(s as f64)).collect(),
        ),
    ));
    meta.push(("cores".to_string(), Json::Num(hw as f64)));
    meta.push(("shard_smoke".to_string(), Json::Bool(shard_smoke)));
    for (s, case) in &shard_cases {
        meta.push((
            format!("tasks_per_sec_shards_{s}"),
            Json::Num(tps(case)),
        ));
        meta.push((
            format!("speedup_shards_{s}"),
            Json::Num(shard_base / secs(case)),
        ));
    }
    let meta_refs: Vec<(&str, Json)> =
        meta.iter().map(|(k, v)| (k.as_str(), v.clone())).collect();
    let mut results =
        vec![streaming.bench, wheel_full.bench, heap_full.bench];
    results.extend(shard_cases.into_iter().map(|(_, c)| c.bench));
    let path = std::path::PathBuf::from(&out);
    if write_suite_json(&path, "sim_scale", &meta_refs, &results) {
        println!("\nwrote {}", path.display());
    } else {
        println!("\ncould not write {} (read-only fs?)", path.display());
    }
}
