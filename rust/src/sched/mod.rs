//! Discrete task schedulers (paper Sec. V-B and the evaluation
//! baselines).
//!
//! A scheduler is a *policy*: given the current cluster and user states
//! it picks the next `(user, server)` placement. The simulation engine
//! owns all state mutation — committing resources, maintaining dominant
//! shares, firing events — so policies stay side-effect-free and
//! trivially swappable.
//!
//! ## The blocked-user protocol
//!
//! Concluding "nothing can be placed" naively costs O(n·k) at every
//! scheduling opportunity, which dominates saturated-cluster runs. The
//! engine therefore caches a *blocked* set: when `pick` returns
//! [`Pick::Blocked`], the user is excluded from `eligible` until some
//! server frees resources, at which point the engine re-checks only
//! that server via [`Scheduler::can_fit`]. Demands are static per user
//! (paper Sec. III-A), so a blocked verdict stays valid until capacity
//! is released.

pub mod best_fit;
pub mod first_fit;
pub mod slots;
pub mod xla;

pub use best_fit::BestFitDrfh;
pub use first_fit::FirstFitDrfh;
pub use slots::SlotsScheduler;
pub use xla::XlaBestFit;

use crate::cluster::{Cluster, ResVec};

/// Per-user scheduling state maintained by the engine.
#[derive(Clone, Debug)]
pub struct UserState {
    /// Per-task demand (absolute units).
    pub demand: ResVec,
    /// Fair-share weight.
    pub weight: f64,
    /// Queued (not yet placed) tasks.
    pub pending: usize,
    /// Currently running tasks.
    pub running: usize,
    /// Global dominant share currently held (pool-share units).
    pub dom_share: f64,
    /// Resources currently held (absolute units).
    pub usage: ResVec,
    /// Per-task dominant-resource demand in pool-share units
    /// (engine-precomputed: max_r demand_r / total_r).
    pub dom_delta: f64,
}

impl UserState {
    /// Weighted progressive-filling key: lowest goes first.
    #[inline]
    pub fn share_key(&self) -> f64 {
        self.dom_share / self.weight
    }
}

/// Outcome of one policy invocation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pick {
    /// Place one task of `user` on `server`.
    Place { user: usize, server: usize },
    /// `user` would be served next but fits on no server right now;
    /// the engine removes it from `eligible` until capacity frees up.
    Blocked { user: usize },
    /// No eligible user has pending work.
    Idle,
}

/// A scheduling policy. (Not `Send`: the XLA-backed policy wraps PJRT
/// handles that must stay on their creating thread.)
pub trait Scheduler {
    /// Human-readable policy name (used in reports).
    fn name(&self) -> &'static str;

    /// Pick the next placement among users with `eligible[i] == true`
    /// (the engine guarantees those have pending > 0). Must not mutate
    /// cluster/user state.
    fn pick(
        &mut self,
        cluster: &Cluster,
        users: &[UserState],
        eligible: &[bool],
    ) -> Pick;

    /// Could one task of `user` be placed on `server` right now? Used
    /// by the engine to unblock users when `server` frees capacity.
    fn can_fit(
        &self,
        cluster: &Cluster,
        users: &[UserState],
        user: usize,
        server: usize,
    ) -> bool;

    /// May placements exceed server capacity? Only the Slots baseline
    /// says yes (it ignores real demands); the engine then applies the
    /// processor-sharing slowdown.
    fn allows_overcommit(&self) -> bool {
        false
    }

    /// Notification: a task released capacity on `server`. Lets
    /// policies maintain incremental state (the Slots free-slot cursor).
    fn on_free(&mut self, _server: usize) {}
}

/// Lowest weighted-share eligible user (first on ties) — the
/// progressive-filling selection shared by the DRFH policies.
pub fn min_share_user(users: &[UserState], eligible: &[bool]) -> Option<usize> {
    let mut best: Option<usize> = None;
    for i in 0..users.len() {
        if !eligible[i] || users[i].pending == 0 {
            continue;
        }
        match best {
            Some(b) if users[b].share_key() <= users[i].share_key() => {}
            _ => best = Some(i),
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn user(share: f64, pending: usize) -> UserState {
        UserState {
            demand: ResVec::cpu_mem(0.1, 0.1),
            weight: 1.0,
            pending,
            running: 0,
            dom_share: share,
            usage: ResVec::zeros(2),
            dom_delta: 0.01,
        }
    }

    #[test]
    fn min_share_respects_eligibility_and_pending() {
        let users =
            vec![user(0.5, 1), user(0.1, 0), user(0.2, 3), user(0.2, 1)];
        let all = vec![true; 4];
        assert_eq!(min_share_user(&users, &all), Some(2)); // tie -> lowest idx
        let mask = vec![true, true, false, true];
        assert_eq!(min_share_user(&users, &mask), Some(3));
        assert_eq!(min_share_user(&users, &[false; 4]), None);
    }

    #[test]
    fn weighted_key() {
        let mut u = user(0.4, 1);
        u.weight = 2.0;
        assert!((u.share_key() - 0.2).abs() < 1e-12);
    }
}
