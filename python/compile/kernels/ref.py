"""Pure-jnp reference oracle for the DRFH scheduling kernels.

These functions define the *semantics* that both the Pallas kernels
(bestfit.py / dominant.py) and the native Rust picker must reproduce
bit-for-bit on f32 inputs:

  * ``score_servers`` — paper eq. (9): for every (user, server) pair the
    fitness ``H(i,l) = sum_r |D_ir/D_i0 - avail_lr/avail_l0|`` together with
    the feasibility mask ``all_r(avail_lr >= D_ir)``; reduced per user to
    the best (lowest-H, lowest-index) feasible server.
  * ``select_user`` — progressive filling (paper Sec. V-B): among active
    users that have at least one feasible server, pick the one with the
    lowest weighted global dominant share (ties -> lowest index).
  * ``sched_step`` — one scheduling decision composing the two.
  * ``sched_loop`` — T consecutive decisions with state updates, used by
    the Rust coordinator to batch placements into a single PJRT call.

Tie-breaking is everywhere "first occurrence of the minimum", which the
kernels implement with strict-< accumulator updates and jnp.argmin's
first-occurrence guarantee.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

# Infeasible placements score +inf; users with no feasible server are
# excluded from selection.
INF = jnp.inf


def _safe_div(num, den):
    """num / den with den==0 mapped to den=1 (masked out by callers)."""
    safe = jnp.where(den != 0.0, den, 1.0)
    return num / safe


def score_servers(avail, demand):
    """All-pairs best-fit scoring (paper eq. (9)).

    Args:
      avail:  f32[k, m] per-server available resources (absolute units).
      demand: f32[n, m] per-user per-task demand (absolute units).

    Returns:
      best_h:      f32[n] lowest feasible H per user (+inf if none fits).
      best_server: i32[n] argmin server per user (-1 if none fits).
    """
    avail = jnp.asarray(avail, jnp.float32)
    demand = jnp.asarray(demand, jnp.float32)
    # ratios relative to resource 0, paper's D_i1 / c-bar_l1 convention
    dratio = _safe_div(demand, demand[:, 0:1])  # [n, m]
    aratio = _safe_div(avail, avail[:, 0:1])  # [k, m]
    h = jnp.sum(
        jnp.abs(dratio[:, None, :] - aratio[None, :, :]), axis=-1
    )  # [n, k]
    fit = jnp.all(avail[None, :, :] >= demand[:, None, :], axis=-1)  # [n, k]
    # a server with zero available resource-0 cannot host positive demand
    # and is already excluded by `fit`; keep H finite-safe regardless.
    h = jnp.where(fit, h, INF)
    best_h = jnp.min(h, axis=1)
    best_server = jnp.where(
        jnp.isfinite(best_h), jnp.argmin(h, axis=1).astype(jnp.int32), -1
    )
    return best_h, best_server


def select_user(share, weight, mask):
    """Masked argmin of share/weight; -1 when the mask is empty.

    Args:
      share:  f32[n] current global dominant shares.
      weight: f32[n] positive user weights.
      mask:   bool[n] user is eligible (active AND has a feasible server).

    Returns:
      i32 scalar user index, -1 if no user is eligible.
    """
    share = jnp.asarray(share, jnp.float32)
    weight = jnp.asarray(weight, jnp.float32)
    key = jnp.where(mask, _safe_div(share, weight), INF)
    u = jnp.argmin(key).astype(jnp.int32)
    return jnp.where(jnp.isfinite(key[u]), u, jnp.int32(-1))


def sched_step(avail, demand, share, weight, active):
    """One progressive-filling decision.

    Returns (u, s): the user served and the server hosting the task,
    both -1 if no placement is possible.
    """
    best_h, best_server = score_servers(avail, demand)
    eligible = jnp.logical_and(active, jnp.isfinite(best_h))
    u = select_user(share, weight, eligible)
    s = jnp.where(u >= 0, best_server[jnp.maximum(u, 0)], jnp.int32(-1))
    return u, s


def sched_loop(avail, demand, share, weight, pending, steps):
    """`steps` consecutive decisions with state updates.

    Args:
      avail:   f32[k, m]; demand: f32[n, m]; share: f32[n];
      weight:  f32[n]; pending: i32[n] tasks not yet placed.
      steps:   static int, number of decisions to attempt.

    Returns:
      decisions: i32[steps, 2] (user, server), -1/-1 for no-op steps.
      avail', share', pending': updated state.
    """
    demand = jnp.asarray(demand, jnp.float32)
    weight = jnp.asarray(weight, jnp.float32)
    dom = jnp.max(demand, axis=1)  # dominant-resource demand per task

    def body(t, state):
        avail, share, pending, decisions = state
        active = pending > 0
        u, s = sched_step(avail, demand, share, weight, active)
        ok = u >= 0
        uu = jnp.maximum(u, 0)
        ss = jnp.maximum(s, 0)
        delta = jnp.where(ok, 1.0, 0.0).astype(jnp.float32)
        avail = avail.at[ss].add(-demand[uu] * delta)
        share = share.at[uu].add(dom[uu] * delta)
        pending = pending.at[uu].add(jnp.where(ok, -1, 0).astype(jnp.int32))
        decisions = decisions.at[t].set(
            jnp.where(ok, jnp.stack([u, s]), jnp.array([-1, -1], jnp.int32))
        )
        return avail, share, pending, decisions

    decisions = jnp.full((steps, 2), -1, jnp.int32)
    avail, share, pending, decisions = lax.fori_loop(
        0,
        steps,
        body,
        (
            jnp.asarray(avail, jnp.float32),
            jnp.asarray(share, jnp.float32),
            jnp.asarray(pending, jnp.int32),
            decisions,
        ),
    )
    return decisions, avail, share, pending
