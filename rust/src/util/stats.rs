//! Small statistics toolkit shared by metrics and the experiment
//! harness: means, percentiles, empirical CDFs, Jain's fairness index.

/// Arithmetic mean; 0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation; 0 for fewer than 2 samples.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64)
        .sqrt()
}

/// Linear-interpolated percentile, p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Empirical CDF evaluated at `points` many equally spaced quantiles;
/// returns (value, fraction <= value) pairs suitable for plotting.
pub fn cdf_points(xs: &[f64], points: usize) -> Vec<(f64, f64)> {
    if xs.is_empty() {
        return vec![];
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    (0..points)
        .map(|i| {
            let q = (i as f64 + 1.0) / points as f64;
            let idx = ((q * n as f64).ceil() as usize).clamp(1, n) - 1;
            (v[idx], q)
        })
        .collect()
}

/// Jain's fairness index: (Σx)² / (n·Σx²); 1 = perfectly fair.
pub fn jain_index(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    let s: f64 = xs.iter().sum();
    let s2: f64 = xs.iter().map(|x| x * x).sum();
    if s2 == 0.0 {
        1.0
    } else {
        s * s / (xs.len() as f64 * s2)
    }
}

/// Histogram with `bins` equal-width bins over [lo, hi].
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<usize> {
    let mut h = vec![0usize; bins];
    if hi <= lo || bins == 0 {
        return h;
    }
    let w = (hi - lo) / bins as f64;
    for &x in xs {
        if x >= lo && x < hi {
            h[((x - lo) / w) as usize] += 1;
        } else if (x - hi).abs() < 1e-12 {
            h[bins - 1] += 1;
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert!((std_dev(&[2.0, 4.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn cdf_monotone() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        let cdf = cdf_points(&xs, 10);
        for w in cdf.windows(2) {
            assert!(w[0].0 <= w[1].0);
            assert!(w[0].1 <= w[1].1);
        }
        assert_eq!(cdf.last().unwrap().0, 5.0);
    }

    #[test]
    fn jain_extremes() {
        assert!((jain_index(&[1.0, 1.0, 1.0]) - 1.0).abs() < 1e-12);
        let skew = jain_index(&[1.0, 0.0, 0.0]);
        assert!((skew - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_counts() {
        let h = histogram(&[0.1, 0.2, 0.9, 1.0], 0.0, 1.0, 2);
        assert_eq!(h, vec![2, 2]);
    }
}
