//! The slot-based baseline scheduler (paper Sec. VI / Table II; models
//! the Hadoop Fair Scheduler the paper compares against).
//!
//! Each server is partitioned into *slots*: the maximum server (1 CPU,
//! 1 mem in Table I's normalized units) is divided into `slots_per_max`
//! equal bundles, and every server hosts as many whole slots as the
//! bundle fits into its capacity (jointly across resources). A task
//! occupies exactly one slot regardless of its real demand; fairness is
//! max-min over *slot counts* (weighted), and real resource usage is
//! never checked — overcommitting a server is possible, in which case
//! the engine applies a processor-sharing slowdown to every task on it.
//! This is exactly the pathology the paper attributes to slot
//! schedulers: the single-resource abstraction ignores both server and
//! demand heterogeneity.
//!
//! §Perf: both halves of a pick are indexed. The server side is the
//! `free_hint` cursor (below); the user side runs on the class-keyed
//! aggregation ([`ClassedShareIndex::by_weight`]) ranked by the
//! weighted running-slot count `running / effective_weight` — users
//! sharing an effective weight collapse into one `(running, user)`
//! ordered group, so a pick compares one candidate per *weight class*
//! (with the per-user heap as the automatic fallback when weights
//! don't aggregate) instead of the naive O(n) scan per pick, which
//! dominated Table II sweeps at k = 12,583.
//! [`SlotsScheduler::naive`] keeps the linear scan as the
//! bit-identical reference (parity in `tests/engine_parity.rs`).

use super::users::ClassedShareIndex;
use super::{effective_weight, Pick, Scheduler, UserState};
use crate::cluster::{Cluster, ResVec};

/// The fair-sharing key: weighted running-slot count (1 task = 1
/// slot). The classed index computes the same arithmetic under
/// [`crate::sched::users::KeyMode::RunningOnly`] (`running * 1.0 /
/// effective_weight` — the `* 1.0` is exact), so the two argmins are
/// bit-identical, tie-breaks included.
#[inline]
fn slot_key(u: &UserState) -> f64 {
    u.running as f64 / effective_weight(u.weight)
}

/// The Slots policy.
pub struct SlotsScheduler {
    /// Number of slots the *maximum* server is divided into.
    pub slots_per_max: usize,
    /// Per-server slot capacity, derived from the cluster. A crashed
    /// server's entry is zeroed ([`Scheduler::on_server_down`]) so the
    /// cursor scan and `can_fit` both read it as full.
    slots_total: Vec<usize>,
    /// Nominal slot capacities, restored on recovery.
    slots_saved: Vec<usize>,
    /// First server index that might have a free slot (§Perf: the
    /// naive per-placement linear scan was 53% of saturated runs; the
    /// cursor only moves forward past full servers and is pulled back
    /// by `on_free`, so it always lower-bounds the true first free
    /// slot and the picked server is identical to a full scan).
    free_hint: usize,
    /// Class-keyed index over `slot_key` (default;
    /// [`ClassedShareIndex::by_weight`] aggregates by effective
    /// weight), or `None` for the naive O(n) user scan. Both paths
    /// emit identical decisions.
    users_index: Option<ClassedShareIndex>,
}

impl SlotsScheduler {
    /// Build for `cluster`, dividing the largest server into
    /// `slots_per_max` slots.
    pub fn new(cluster: &Cluster, slots_per_max: usize) -> Self {
        assert!(slots_per_max >= 1);
        let m = cluster.dims();
        // the "maximum server": componentwise max capacity
        let mut maxcap = ResVec::zeros(m);
        for s in &cluster.servers {
            for r in 0..m {
                maxcap[r] = maxcap[r].max(s.capacity[r]);
            }
        }
        let slot = maxcap.scale(1.0 / slots_per_max as f64);
        let slots_total: Vec<usize> = cluster
            .servers
            .iter()
            .map(|s| {
                // whole slots that fit jointly across all resources
                let mut n = usize::MAX;
                for r in 0..m {
                    if slot[r] > 0.0 {
                        n = n.min((s.capacity[r] / slot[r] + 1e-9) as usize);
                    }
                }
                n.max(1) // every server offers at least one slot
            })
            .collect();
        SlotsScheduler {
            slots_per_max,
            slots_saved: slots_total.clone(),
            slots_total,
            free_hint: 0,
            users_index: Some(ClassedShareIndex::by_weight()),
        }
    }

    /// The seed's linear-scan user selection — the parity reference
    /// and the naive baseline in `benches/table2_slots.rs`.
    pub fn naive(cluster: &Cluster, slots_per_max: usize) -> Self {
        SlotsScheduler {
            users_index: None,
            ..Self::new(cluster, slots_per_max)
        }
    }

    /// Is this instance on the indexed user-selection path?
    pub fn is_indexed(&self) -> bool {
        self.users_index.is_some()
    }

    /// Weight-class groups in the user index (testing / diagnostics);
    /// `None` when naive or when the index fell back per-user.
    pub fn weight_groups(&self) -> Option<usize> {
        let idx = self.users_index.as_ref()?;
        (!idx.is_fallback()).then(|| idx.group_count())
    }

    /// Slot capacity of server `l`.
    pub fn slots_of(&self, l: usize) -> usize {
        self.slots_total[l]
    }

    /// Total slots in the cluster.
    pub fn total_slots(&self) -> usize {
        self.slots_total.iter().sum()
    }
}

impl Scheduler for SlotsScheduler {
    fn name(&self) -> &'static str {
        "slots"
    }

    fn pick(
        &mut self,
        cluster: &Cluster,
        users: &[UserState],
        eligible: &[bool],
    ) -> Pick {
        // fair sharing over slot counts: serve the pending user with the
        // fewest weighted running tasks (1 task = 1 slot); zero weights
        // use the shared guarded fallback (see `sched::effective_weight`)
        let best = match &mut self.users_index {
            Some(idx) => {
                idx.refresh(users, eligible);
                idx.peek_min(users, eligible)
            }
            None => {
                let mut best: Option<usize> = None;
                for i in 0..users.len() {
                    if !eligible[i] || users[i].pending == 0 {
                        continue;
                    }
                    match best {
                        Some(b)
                            if slot_key(&users[b])
                                <= slot_key(&users[i]) => {}
                        _ => best = Some(i),
                    }
                }
                best
            }
        };
        let Some(u) = best else { return Pick::Idle };
        // first server with a free slot (resource demands NOT checked),
        // scanning from the cursor — everything before it is full
        let k = cluster.len();
        let mut l = self.free_hint;
        while l < k && cluster.servers[l].tasks >= self.slots_total[l] {
            l += 1;
        }
        self.free_hint = l;
        if l < k {
            Pick::Place { user: u, server: l }
        } else {
            // drop u from the index until the engine unblocks it
            // (on_ready), mirroring the IndexedCore blocked protocol
            if let Some(idx) = &mut self.users_index {
                idx.remove(u);
            }
            Pick::Blocked { user: u }
        }
    }

    fn can_fit(
        &self,
        cluster: &Cluster,
        _users: &[UserState],
        _user: usize,
        server: usize,
    ) -> bool {
        cluster.servers[server].tasks < self.slots_total[server]
    }

    fn allows_overcommit(&self) -> bool {
        true
    }

    fn on_free(&mut self, server: usize) {
        if server < self.free_hint {
            self.free_hint = server;
        }
    }

    fn on_server_down(&mut self, server: usize) {
        // zero slots: the cursor skips it and `can_fit` rejects it; the
        // cursor need not move back since the server only got *less*
        // usable
        self.slots_total[server] = 0;
    }

    fn on_server_up(&mut self, server: usize) {
        self.slots_total[server] = self.slots_saved[server];
        if server < self.free_hint {
            self.free_hint = server;
        }
    }

    fn on_place(&mut self, user: usize, _server: usize) {
        if let Some(idx) = &mut self.users_index {
            idx.mark_dirty(user); // running/pending changed
        }
    }

    fn on_complete(&mut self, user: usize, _server: usize) {
        if let Some(idx) = &mut self.users_index {
            idx.mark_dirty(user); // running changed
        }
    }

    fn on_ready(&mut self, user: usize) {
        if let Some(idx) = &mut self.users_index {
            idx.mark_dirty(user);
        }
    }

    fn on_user_join(&mut self, user: usize) {
        if let Some(idx) = &mut self.users_index {
            idx.mark_dirty(user);
        }
    }

    fn on_user_leave(&mut self, user: usize) {
        // drop the live entry now instead of riding a lazy resync,
        // mirroring the Blocked protocol above
        if let Some(idx) = &mut self.users_index {
            idx.remove(user);
        }
    }

    fn audit_indices(
        &mut self,
        _cluster: &Cluster,
        users: &[UserState],
        eligible: &[bool],
    ) -> Result<(), String> {
        // cross-check the class-keyed user index against the naive
        // keep-first slot-key scan (the indexless `pick` path above);
        // refresh + peek are exactly what the next pick would do, so
        // this stays decision-neutral
        let Some(idx) = &mut self.users_index else {
            return Ok(());
        };
        idx.refresh(users, eligible);
        let got = idx.peek_min(users, eligible);
        let mut want: Option<usize> = None;
        for i in 0..users.len() {
            if !eligible[i] || users[i].pending == 0 {
                continue;
            }
            match want {
                Some(b) if slot_key(&users[b]) <= slot_key(&users[i]) => {}
                _ => want = Some(i),
            }
        }
        if got != want {
            return Err(format!(
                "slots user index argmin {got:?} != naive slot scan {want:?}"
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Server;
    use crate::util::Pcg32;

    #[test]
    fn slot_counts_proportional_to_server_size() {
        let mut rng = Pcg32::seeded(5);
        let cluster = Cluster::google_sample(100, &mut rng);
        let s = SlotsScheduler::new(&cluster, 14);
        for (l, srv) in cluster.servers.iter().enumerate() {
            let expect = ((srv.capacity[0] * 14.0 + 1e-9) as usize)
                .min((srv.capacity[1] * 14.0 + 1e-9) as usize)
                .max(1);
            assert_eq!(s.slots_of(l), expect, "server {l}");
        }
    }

    #[test]
    fn unbalanced_servers_lose_slots() {
        // (1, 1) vs (1, 0.12): joint fit penalizes the unbalanced box
        let cluster = Cluster::from_capacities(&[
            ResVec::cpu_mem(1.0, 1.0),
            ResVec::cpu_mem(1.0, 0.12),
        ]);
        let s = SlotsScheduler::new(&cluster, 10);
        assert_eq!(s.slots_of(0), 10);
        assert_eq!(s.slots_of(1), 1);
    }

    #[test]
    fn constructors_select_the_expected_path() {
        let cluster = Cluster::from_capacities(&[ResVec::cpu_mem(1.0, 1.0)]);
        assert!(SlotsScheduler::new(&cluster, 4).is_indexed());
        assert!(!SlotsScheduler::naive(&cluster, 4).is_indexed());
        assert_eq!(
            SlotsScheduler::naive(&cluster, 4).total_slots(),
            SlotsScheduler::new(&cluster, 4).total_slots()
        );
    }

    #[test]
    fn fairness_by_running_count() {
        let cluster = Cluster::from_capacities(&[ResVec::cpu_mem(1.0, 1.0)]);
        let mk = |pending, running| UserState {
            demand: ResVec::cpu_mem(0.1, 0.1),
            weight: 1.0,
            pending,
            running,
            dom_share: 0.0,
            usage: ResVec::zeros(2),
            dom_delta: 0.1,
        };
        let users = vec![mk(1, 3), mk(1, 1)];
        for mut s in
            [SlotsScheduler::new(&cluster, 4), SlotsScheduler::naive(&cluster, 4)]
        {
            assert_eq!(
                s.pick(&cluster, &users, &[true, true]),
                Pick::Place { user: 1, server: 0 }
            );
        }
    }

    /// A zero-weight user ranks through the guarded fallback on both
    /// user-selection paths.
    #[test]
    fn zero_weight_ranks_identically() {
        let cluster = Cluster::from_capacities(&[ResVec::cpu_mem(1.0, 1.0)]);
        let mk = |running, weight| UserState {
            demand: ResVec::cpu_mem(0.1, 0.1),
            weight,
            pending: 1,
            running,
            dom_share: 0.0,
            usage: ResVec::zeros(2),
            dom_delta: 0.1,
        };
        // weight 0 -> effective 1.0: key 2.0 beats user 0's 3.0
        let users = vec![mk(3, 1.0), mk(2, 0.0)];
        for mut s in
            [SlotsScheduler::new(&cluster, 4), SlotsScheduler::naive(&cluster, 4)]
        {
            assert_eq!(
                s.pick(&cluster, &users, &[true, true]),
                Pick::Place { user: 1, server: 0 }
            );
        }
    }

    /// The class-keyed user selection aggregates same-weight users and
    /// stays pick-for-pick identical to the naive scan across churn of
    /// running counts, pending work, and the blocked/ready protocol.
    #[test]
    fn classed_user_selection_matches_naive() {
        let mut rng = Pcg32::seeded(917);
        let cluster = Cluster::from_capacities(&[
            ResVec::cpu_mem(1.0, 1.0),
            ResVec::cpu_mem(0.5, 0.5),
        ]);
        let n = 16;
        let mut users: Vec<UserState> = (0..n)
            .map(|i| UserState {
                demand: ResVec::cpu_mem(0.1, 0.1),
                weight: [1.0, 2.0, 0.0, 4.0][i % 4],
                pending: 1 + rng.below(2),
                running: rng.below(5),
                dom_share: 0.0,
                usage: ResVec::zeros(2),
                dom_delta: 0.1,
            })
            .collect();
        let mut fast = SlotsScheduler::new(&cluster, 4);
        let mut naive = SlotsScheduler::naive(&cluster, 4);
        let mut eligible = vec![true; n];
        for step in 0..400 {
            let a = fast.pick(&cluster, &users, &eligible);
            let b = naive.pick(&cluster, &users, &eligible);
            assert_eq!(a, b, "step {step}");
            match a {
                Pick::Place { user, .. } => {
                    // engine would commit; emulate the notification
                    users[user].running += 1;
                    users[user].pending -= 1;
                    fast.on_place(user, 0);
                    naive.on_place(user, 0);
                }
                Pick::Blocked { user } => {
                    eligible[user] = false;
                }
                Pick::Idle => {}
            }
            // random completions / new work keep the churn going
            let u = rng.below(n);
            match rng.below(3) {
                0 if users[u].running > 0 => {
                    users[u].running -= 1;
                    fast.on_complete(u, 0);
                    naive.on_complete(u, 0);
                }
                1 => {
                    users[u].pending += 1;
                    if !eligible[u] {
                        eligible[u] = true;
                        fast.on_ready(u);
                        naive.on_ready(u);
                    } else {
                        fast.on_ready(u);
                        naive.on_ready(u);
                    }
                }
                _ => {}
            }
        }
        // weights {1.0, 2.0, 0.0, 4.0} -> effective {1.0, 2.0, 4.0}:
        // three weight classes, 16 users — aggregation engaged
        assert_eq!(fast.weight_groups(), Some(3));
        assert_eq!(naive.weight_groups(), None);
    }

    /// A crashed server offers zero slots (cursor skips it, `can_fit`
    /// rejects it); recovery restores the nominal count and pulls the
    /// cursor back so the server is re-probed.
    #[test]
    fn server_down_zeroes_slots_and_up_restores() {
        let cluster = Cluster::from_capacities(&[
            ResVec::cpu_mem(1.0, 1.0),
            ResVec::cpu_mem(1.0, 1.0),
        ]);
        let users = vec![UserState {
            demand: ResVec::cpu_mem(0.1, 0.1),
            weight: 1.0,
            pending: 1,
            running: 0,
            dom_share: 0.0,
            usage: ResVec::zeros(2),
            dom_delta: 0.1,
        }];
        for mut s in
            [SlotsScheduler::new(&cluster, 2), SlotsScheduler::naive(&cluster, 2)]
        {
            let nominal = s.slots_of(0);
            assert!(nominal >= 1);
            s.on_server_down(0);
            assert_eq!(s.slots_of(0), 0);
            assert!(!s.can_fit(&cluster, &users, 0, 0));
            // the cursor walks past the dead server to the next one
            assert_eq!(
                s.pick(&cluster, &users, &[true]),
                Pick::Place { user: 0, server: 1 }
            );
            s.on_server_up(0);
            assert_eq!(s.slots_of(0), nominal);
            assert!(s.can_fit(&cluster, &users, 0, 0));
            // cursor pulled back: server 0 is picked again
            assert_eq!(
                s.pick(&cluster, &users, &[true]),
                Pick::Place { user: 0, server: 0 }
            );
        }
    }

    #[test]
    fn blocked_when_no_free_slots() {
        let mut cluster =
            Cluster::new(vec![Server::new(ResVec::cpu_mem(1.0, 1.0))]);
        cluster.servers[0].tasks = 2; // both slots taken
        let users = vec![UserState {
            demand: ResVec::cpu_mem(0.1, 0.1),
            weight: 1.0,
            pending: 1,
            running: 2,
            dom_share: 0.0,
            usage: ResVec::zeros(2),
            dom_delta: 0.1,
        }];
        for mut s in
            [SlotsScheduler::new(&cluster, 2), SlotsScheduler::naive(&cluster, 2)]
        {
            assert_eq!(
                s.pick(&cluster, &users, &[true]),
                Pick::Blocked { user: 0 }
            );
            assert!(!s.can_fit(&cluster, &users, 0, 0));
            cluster.servers[0].tasks = 1;
            assert!(s.can_fit(&cluster, &users, 0, 0));
            assert!(s.allows_overcommit());
            cluster.servers[0].tasks = 2; // restore for the next path
        }
    }
}
