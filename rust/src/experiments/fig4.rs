//! Fig. 4 — dynamic allocation: three users join a 100-server pool at
//! t = 0, 200 and 500 s; Best-Fit DRFH continuously re-equalizes the
//! global dominant shares, and resources are rebalanced when user 1
//! finishes its backlog and departs.
//!
//! Paper reference points: alone, user 1 holds ~40% CPU / ~62% memory;
//! with user 2 both settle at ~44% dominant share; with all three at
//! ~26%; after user 1 departs the remaining two rebalance upward.

use super::write_csv;
use crate::cluster::Cluster;
use crate::sched::BestFitDrfh;
use crate::sim::{run, SimOpts, SimReport};
use crate::util::Pcg32;
use crate::workload::gen::fig4_trace;

/// Measured phase averages (dominant share per user in a time window).
#[derive(Clone, Debug)]
pub struct Fig4Result {
    pub report: SimReport,
    /// (label, window, per-user mean dominant share)
    pub phases: Vec<(String, (f64, f64), [f64; 3])>,
    /// user 1 departure time (all tasks done), if reached
    pub depart: Option<f64>,
    pub total_cpu: f64,
    pub total_mem: f64,
}

/// Run the Fig. 4 scenario.
pub fn run_fig4(seed: u64) -> Fig4Result {
    let mut rng = Pcg32::new(seed, 0xf4);
    let cluster = Cluster::google_sample(100, &mut rng);
    let total = cluster.total_capacity();
    // Backlogs sized so user 1 drains around t ~ 1000-1100 s while
    // users 2 and 3 stay busy through the 2000 s horizon.
    let trace = fig4_trace([700, 4000, 4000], [100.0, 100.0, 100.0]);
    let opts = SimOpts {
        horizon: 2_000.0,
        sample_dt: 5.0,
        track_user_series: true,
        ..SimOpts::default()
    };
    // strict filling: the paper's Fig. 4 shows exactly equalized
    // shares, which requires stalling behind blocked users
    let report = run(cluster, &trace, Box::new(BestFitDrfh::strict_filling()), opts);

    let depart = report
        .jobs
        .iter()
        .find(|j| j.user == 0)
        .map(|j| j.finish);
    let d = depart.unwrap_or(2_000.0);
    let windows = [
        ("user 1 alone".to_string(), (50.0, 200.0)),
        ("users 1+2".to_string(), (250.0, 500.0)),
        ("users 1+2+3".to_string(), (550.0, (d - 50.0).min(1_000.0))),
        ("after user 1 departs".to_string(), (d + 50.0, 2_000.0)),
    ];
    let phases = windows
        .iter()
        .map(|(label, (lo, hi))| {
            let mut shares = [0.0; 3];
            for u in 0..3 {
                shares[u] = report.user_dom_share[u].window_avg(*lo, *hi);
            }
            (label.clone(), (*lo, *hi), shares)
        })
        .collect();

    Fig4Result {
        report,
        phases,
        depart,
        total_cpu: total[0],
        total_mem: total[1],
    }
}

/// Print the paper-style summary and dump the full time series CSV.
pub fn print(res: &Fig4Result) {
    println!("== Fig. 4: dynamic allocation, 3 users on 100 servers ==");
    println!(
        "pool: {:.2} CPU units, {:.2} memory units (paper: 52.75 / 51.32)",
        res.total_cpu, res.total_mem
    );
    match res.depart {
        Some(t) => println!("user 1 departs at {t:.0} s (paper: 1080 s)"),
        None => println!("user 1 still active at horizon"),
    }
    println!("{:<24} {:>12} {:>8} {:>8} {:>8}", "phase", "window", "u1", "u2", "u3");
    for (label, (lo, hi), s) in &res.phases {
        println!(
            "{:<24} [{:>4.0},{:>4.0}] {:>7.1}% {:>7.1}% {:>7.1}%",
            label,
            lo,
            hi,
            s[0] * 100.0,
            s[1] * 100.0,
            s[2] * 100.0
        );
    }
    println!(
        "(paper: alone 62% mem-dominant; two users 44%/44%; three 26% each)"
    );
    // CSV: t, per-user dominant/cpu/mem shares
    let ts = &res.report.user_dom_share[0].t;
    let rows: Vec<String> = (0..ts.len())
        .map(|i| {
            let mut row = format!("{:.1}", ts[i]);
            for u in 0..3 {
                row.push_str(&format!(
                    ",{:.4},{:.4},{:.4}",
                    res.report.user_dom_share[u].v[i],
                    res.report.user_cpu_share[u].v[i],
                    res.report.user_mem_share[u].v[i]
                ));
            }
            row
        })
        .collect();
    write_csv(
        "fig4_dynamic_shares.csv",
        "t,u1_dom,u1_cpu,u1_mem,u2_dom,u2_cpu,u2_mem,u3_dom,u3_cpu,u3_mem",
        &rows,
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_phases_equalize() {
        let res = run_fig4(42);
        // phase 2: users 1 and 2 share -> dominant shares within 15%
        let p2 = res.phases[1].2;
        assert!(p2[0] > 0.0 && p2[1] > 0.0);
        assert!(
            (p2[0] - p2[1]).abs() / p2[0].max(p2[1]) < 0.15,
            "two-user shares {p2:?} not equalized"
        );
        // phase 3: all three active and roughly equal
        let p3 = res.phases[2].2;
        assert!(p3.iter().all(|&s| s > 0.0), "{p3:?}");
        let mx = p3.iter().cloned().fold(0.0, f64::max);
        let mn = p3.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(mx - mn < 0.12 * mx + 0.03, "three-user shares {p3:?}");
        // alone phase: user 1 above its fair-shared level
        assert!(res.phases[0].2[0] > p3[0]);
    }

    #[test]
    fn fig4_user1_departs_and_shares_rebalance() {
        let res = run_fig4(42);
        let d = res.depart.expect("user 1 must finish");
        assert!(d > 500.0 && d < 1_800.0, "departure at {d}");
        // after departure users 2/3 get more than in the 3-user phase
        let p3 = res.phases[2].2;
        let p4 = res.phases[3].2;
        assert!(p4[1] > p3[1] * 1.1, "u2 {} -> {}", p3[1], p4[1]);
        assert!(p4[2] > p3[2] * 1.1, "u3 {} -> {}", p3[2], p4[2]);
        assert!(p4[0] < 0.02, "u1 share should vanish, got {}", p4[0]);
    }
}
