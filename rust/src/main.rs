//! `drfh` — launcher CLI for the DRFH reproduction.
//!
//! ```text
//! drfh exp <fig4|fig4-fluid|table2|fig5|fig6|fig7|fig8|faults|churn|sim-scale|user-scale|all>
//!          [--seed N] [--servers K] [--users N] [--duration S]
//!          regenerate a paper figure/table or run a §Perf harness
//!          (`faults` replays a seeded crash/flash plan and reports
//!          goodput, wasted work, and fairness-recovery latency;
//!          `churn` replays a seeded join/leave plan and reports
//!          warm-start pivot savings and flash-crowd share recovery)
//! drfh sim --config exp.toml                      run a configured simulation
//! drfh lint [--src DIR] [--corpus true]           determinism conformance linter
//! drfh solve                                      exact fluid DRFH on the Fig. 1 example
//! drfh picker-check [--trials N] [--seed N]       native vs XLA decision parity
//! drfh serve [--servers K] [--users N] [--tasks T] online coordinator demo
//! ```
//!
//! (Hand-rolled argument parsing — clap is unavailable offline.)

use drfh::util::error::{anyhow, bail, Result};
use drfh::allocator::{self, FluidUser};
use drfh::cluster::{Cluster, ResVec};
use drfh::config::ExperimentConfig;
use drfh::coordinator::{Coordinator, Engine};
use drfh::experiments::{self, EvalSetup};
use drfh::runtime::{self, picker, XlaRuntime};
use drfh::sim;
use drfh::util::Pcg32;

const USAGE: &str = "\
drfh — Dominant Resource Fairness with Heterogeneous Servers (paper reproduction)

USAGE:
  drfh exp <fig4|fig4-fluid|table2|fig5|fig6|fig7|fig8|faults|churn|sim-scale|user-scale|all>
           [--seed N] [--servers K] [--users N] [--duration SECONDS]
  drfh sim --config <exp.toml>
  drfh lint [--src DIR] [--corpus true]
  drfh solve
  drfh picker-check [--trials N] [--seed N]
  drfh serve [--servers K] [--users N] [--tasks T]
";

/// Tiny flag parser: --key value pairs after the positional args.
struct Flags(Vec<(String, String)>);

impl Flags {
    fn parse(args: &[String]) -> Result<Self> {
        let mut flags = Vec::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(key) = a.strip_prefix("--") {
                let val = args
                    .get(i + 1)
                    .ok_or_else(|| anyhow!("missing value for --{key}"))?;
                flags.push((key.to_string(), val.clone()));
                i += 2;
            } else {
                bail!("unexpected argument '{a}'");
            }
        }
        Ok(Flags(flags))
    }

    fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.0.iter().find(|(k, _)| k == key) {
            None => Ok(default),
            Some((_, v)) => v
                .parse()
                .map_err(|_| anyhow!("bad value for --{key}: '{v}'")),
        }
    }

    fn get_str(&self, key: &str) -> Option<&str> {
        self.0.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    match cmd.as_str() {
        "exp" => {
            let which = args
                .get(1)
                .ok_or_else(|| anyhow!("exp needs a figure name"))?
                .clone();
            let flags = Flags::parse(&args[2..])?;
            run_exp(
                &which,
                flags.get("seed", 42u64)?,
                flags.get("servers", 2000usize)?,
                flags.get("users", 100usize)?,
                flags.get("duration", 86_400.0f64)?,
            )
        }
        "sim" => {
            let flags = Flags::parse(&args[1..])?;
            let cfg = flags
                .get_str("config")
                .ok_or_else(|| anyhow!("sim needs --config"))?;
            run_sim(std::path::Path::new(cfg))
        }
        "lint" => {
            let flags = Flags::parse(&args[1..])?;
            run_lint(flags.get_str("src"), flags.get("corpus", false)?)
        }
        "solve" => run_solve(),
        "picker-check" => {
            let flags = Flags::parse(&args[1..])?;
            run_picker_check(flags.get("trials", 100usize)?, flags.get("seed", 7u64)?)
        }
        "serve" => {
            let flags = Flags::parse(&args[1..])?;
            run_serve(
                flags.get("servers", 200usize)?,
                flags.get("users", 16usize)?,
                flags.get("tasks", 2000usize)?,
            )
        }
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

fn run_exp(
    which: &str,
    seed: u64,
    servers: usize,
    users: usize,
    duration: f64,
) -> Result<()> {
    let setup = || EvalSetup::with_duration(seed, servers, users, duration);
    match which {
        "fig4" => {
            let res = experiments::fig4::run_fig4(seed);
            experiments::fig4::print(&res);
        }
        "fig4-fluid" => {
            let res = experiments::fig4_fluid::run_fig4_fluid(seed);
            experiments::fig4_fluid::print(&res);
        }
        "table2" => {
            let s = setup();
            let rows = experiments::table2::run_table2(&s);
            experiments::table2::print(&rows);
        }
        "fig5" => {
            let s = setup();
            let res = experiments::fig5::run_fig5(&s);
            experiments::fig5::print(&res);
        }
        "fig6" => {
            let s = setup();
            let res = experiments::fig6::run_fig6(&s);
            experiments::fig6::print(&res);
        }
        "fig7" => {
            let s = setup();
            let res = experiments::fig7::run_fig7(&s);
            experiments::fig7::print(&res);
        }
        "fig8" => {
            let s = setup();
            let res = experiments::fig8::run_fig8(&s);
            experiments::fig8::print(&res);
        }
        "faults" => {
            let s = setup();
            let cfg = experiments::faults::default_fault_config(duration);
            let res = experiments::faults::run_faults(&s, &cfg);
            experiments::faults::print(&res);
        }
        "churn" => {
            let s = setup();
            let cfg = experiments::churn::default_churn_config(duration);
            let res = experiments::churn::run_churn(&s, &cfg);
            experiments::churn::print(&res);
            if !res.parity_ok() {
                bail!("churn warm-vs-scratch allocation parity failure");
            }
        }
        "sim-scale" => {
            let s = setup();
            let res = experiments::sim_scale::run_sim_scale(&s);
            experiments::sim_scale::print(&res);
            if !res.queue_parity_ok() || !res.streaming_semantics_ok() {
                bail!("sim-scale data-plane parity failure");
            }
        }
        "user-scale" => {
            let res = experiments::user_scale::run_user_scale(
                seed, servers, users, duration,
            );
            experiments::user_scale::print(&res);
            if !res.parity_ok() {
                bail!("user-scale class-keyed parity failure");
            }
        }
        "all" => {
            let res = experiments::fig4::run_fig4(seed);
            experiments::fig4::print(&res);
            let f4f = experiments::fig4_fluid::run_fig4_fluid(seed);
            experiments::fig4_fluid::print(&f4f);
            let s = setup();
            let rows = experiments::table2::run_table2(&s);
            experiments::table2::print(&rows);
            let f5 = experiments::fig5::run_fig5(&s);
            experiments::fig5::print(&f5);
            let f6 = experiments::fig6::run_fig6(&s);
            experiments::fig6::print(&f6);
            let f7 = experiments::fig7::run_fig7(&s);
            experiments::fig7::print(&f7);
            let f8 = experiments::fig8::run_fig8(&s);
            experiments::fig8::print(&f8);
        }
        other => bail!("unknown experiment '{other}'"),
    }
    Ok(())
}

fn run_sim(path: &std::path::Path) -> Result<()> {
    let cfg = ExperimentConfig::load(path)?;
    let cluster = cfg.build_cluster();
    let trace = cfg.build_trace();
    let sched = cfg.build_scheduler(&cluster)?;
    println!(
        "simulating: {} servers, {} users, {} jobs, {} tasks, policy {}",
        cluster.len(),
        trace.users.len(),
        trace.jobs.len(),
        trace.total_tasks(),
        sched.name()
    );
    let mut opts = cfg.sim_opts()?;
    // [faults] / [churn] sections, when present, compile to
    // deterministic plans
    opts.faults = cfg.build_fault_plan(cluster.len());
    opts.churn = cfg.build_churn_plan(trace.users.len());
    let had_faults = !opts.faults.is_empty();
    let had_churn = !opts.churn.is_empty();
    let report = sim::run(cluster, &trace, sched, opts);
    println!(
        "done: {} placed, {} completed, cpu {:.1}%, mem {:.1}%, jobs {}",
        report.tasks_placed,
        report.tasks_completed,
        report.avg_cpu_util * 100.0,
        report.avg_mem_util * 100.0,
        // job_stats counts in every metrics mode; report.jobs is
        // empty under `metrics = "streaming"`
        report.job_stats.count()
    );
    if had_faults {
        println!(
            "faults: {} outages, {} evictions, {} retries, {} lost, \
             goodput {:.1} h, wasted {:.1} h",
            report.outages.len(),
            report.evictions,
            report.retries,
            report.tasks_lost,
            report.goodput_s / 3600.0,
            report.wasted_s / 3600.0
        );
    }
    if had_churn {
        println!(
            "churn: {} joins, {} leaves, {} tasks abandoned ({:.1} h)",
            report.user_joins,
            report.user_leaves,
            report.tasks_abandoned,
            report.abandoned_s / 3600.0
        );
    }
    Ok(())
}

fn run_lint(src: Option<&str>, corpus: bool) -> Result<()> {
    use drfh::analysis::lint;
    let findings = if corpus {
        // CI sanity check: the embedded violation corpus must trip
        // every rule, so `drfh lint --corpus true` must exit non-zero.
        lint::lint_corpus()
    } else {
        let root = match src {
            Some(dir) => std::path::PathBuf::from(dir),
            // Works from the repo root (CI) and from rust/ alike.
            None => ["rust/src", "src"]
                .iter()
                .map(std::path::PathBuf::from)
                .find(|p| p.join("lib.rs").is_file())
                .ok_or_else(|| {
                    anyhow!("cannot find the source tree; pass --src DIR")
                })?,
        };
        // lint_crate also walks the sibling benches/ and tests/
        // harness trees (skipped when absent, so a bare --src dir
        // still lints).
        lint::lint_crate(&root)
            .map_err(|e| anyhow!("lint walk failed: {e}"))?
    };
    for f in &findings {
        println!("{f}");
    }
    if findings.is_empty() {
        println!("drfh lint: clean");
        Ok(())
    } else {
        bail!("drfh lint: {} finding(s)", findings.len())
    }
}

fn run_solve() -> Result<()> {
    println!("== exact fluid DRFH on the paper's Fig. 1 example ==");
    let cluster = Cluster::fig1_example();
    let users = vec![
        FluidUser::unweighted(ResVec::cpu_mem(0.2, 1.0)),
        FluidUser::unweighted(ResVec::cpu_mem(1.0, 0.2)),
    ];
    let a = allocator::solve(&cluster, &users);
    for i in 0..2 {
        println!(
            "user {}: dominant share g = {:.4} (paper: 5/7 = {:.4}), tasks = {:.2}",
            i + 1,
            a.g[i],
            5.0 / 7.0,
            a.tasks[i]
        );
    }
    let naive = allocator::per_server_drf::solve(
        &cluster,
        &[ResVec::cpu_mem(0.2, 1.0), ResVec::cpu_mem(1.0, 0.2)],
    );
    let per_user = naive.tasks_per_user();
    println!(
        "naive per-server DRF (paper Fig. 2): {:.1} and {:.1} tasks",
        per_user[0], per_user[1]
    );
    Ok(())
}

fn run_picker_check(trials: usize, seed: u64) -> Result<()> {
    if !runtime::backend_available() {
        bail!("no PJRT backend linked in (stub runtime::xla)");
    }
    if !runtime::artifacts_available() {
        bail!("artifacts missing; run `make artifacts` first");
    }
    let rt = XlaRuntime::load_default()?;
    println!("loaded variants: {:?}", rt.step_variants());
    let mut rng = Pcg32::seeded(seed);
    let mut agree = 0usize;
    for t in 0..trials {
        let (n, k, m) = (1 + rng.below(16), 1 + rng.below(128), 2);
        let avail: Vec<f32> =
            (0..k * m).map(|_| rng.uniform(0.0, 1.0) as f32).collect();
        let demand: Vec<f32> =
            (0..n * m).map(|_| rng.uniform(0.01, 0.5) as f32).collect();
        let share: Vec<f32> =
            (0..n).map(|_| rng.uniform(0.0, 1.0) as f32).collect();
        let weight: Vec<f32> = vec![1.0; n];
        let active: Vec<i32> =
            (0..n).map(|_| i32::from(rng.f64() > 0.2)).collect();
        let native = picker::sched_step(
            &avail, &demand, &share, &weight, &active, n, k, m,
        );
        let xla = rt
            .sched_step(&avail, &demand, &share, &weight, &active, n, k, m)?;
        if native == xla {
            agree += 1;
        } else {
            println!("trial {t}: native {native:?} != xla {xla:?}");
        }
    }
    println!("{agree}/{trials} decisions identical");
    if agree != trials {
        bail!("picker parity failure");
    }
    Ok(())
}

fn run_serve(servers: usize, users: usize, tasks: usize) -> Result<()> {
    let mut rng = Pcg32::seeded(1);
    let cluster = Cluster::google_sample(servers, &mut rng);
    let demands: Vec<ResVec> = (0..users)
        .map(|_| {
            ResVec::cpu_mem(rng.uniform(0.02, 0.3), rng.uniform(0.02, 0.3))
        })
        .collect();
    let weights = vec![1.0; users];
    let engine = if runtime::backend_available()
        && runtime::artifacts_available()
    {
        Engine::Xla(runtime::artifacts_dir())
    } else {
        println!("(XLA backend/artifacts unavailable; using native engine)");
        Engine::Native
    };
    let coord = Coordinator::spawn(&cluster, &demands, &weights, engine);
    let t0 = std::time::Instant::now();
    for u in 0..users {
        coord.submit(u, tasks / users)?;
    }
    let stats = coord.stats()?;
    let dt = t0.elapsed();
    println!(
        "placed {} of {} tasks in {:.1} ms ({:.0} placements/s), \
         {} XLA calls ({:.1} decisions/call)",
        stats.placed,
        tasks,
        dt.as_secs_f64() * 1e3,
        stats.placed as f64 / dt.as_secs_f64(),
        stats.xla_calls,
        stats.decisions_per_call
    );
    coord.shutdown()?;
    Ok(())
}
