//! Shared utilities built in-tree (this image has no crates.io access):
//! deterministic RNG, statistics, JSON and TOML-subset parsing, a tiny
//! benchmark harness, and `anyhow`-style error handling.

pub mod bench;
pub mod error;
pub mod json;
pub mod rng;
pub mod stats;
pub mod toml_lite;

pub use rng::Pcg32;
