//! Minimal JSON parser + writer (substrate — this image has no
//! crates.io access, so serde_json is unavailable; see Cargo.toml).
//!
//! Supports the full JSON grammar except exotic number forms; good for
//! the artifact manifest, trace capsules, and results files this crate
//! reads and writes.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(o) => o.get(key),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser { s: input.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.s.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.s.len()
            && matches!(self.s[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.s.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.s[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.s[start..self.i])
            .ok()
            .and_then(|t| t.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.s.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(
                                &self.s[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| "bad \\u escape".to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            out.push(
                                char::from_u32(code).unwrap_or('\u{fffd}'),
                            );
                            self.i += 4;
                        }
                        other => {
                            return Err(format!("bad escape {other:?}"))
                        }
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 code point
                    let rest = std::str::from_utf8(&self.s[self.i..])
                        .map_err(|_| "invalid utf-8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                other => return Err(format!("expected , or ] got {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                other => return Err(format!("expected , or }} got {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2.5, -3], "b": {"c": "x\ny", "d": true}, "e": null}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1], Json::Num(2.5));
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str(), Some("x\ny"));
        assert_eq!(v.get("e"), Some(&Json::Null));
        let reparsed = parse(&v.to_string()).unwrap();
        assert_eq!(v, reparsed);
    }

    #[test]
    fn numbers_and_exponents() {
        assert_eq!(parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(parse("-2.5e-2").unwrap(), Json::Num(-0.025));
        assert_eq!(parse("0").unwrap(), Json::Num(0.0));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("123 456").is_err());
        assert!(parse("{'single': 1}").is_err());
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\u{1}".into());
        let s = v.to_string();
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            parse(r#""Aé""#).unwrap(),
            Json::Str("Aé".into())
        );
    }
}
