//! Stub PJRT surface, API-compatible with the slice of `xla-rs` the
//! runtime uses (client, executable, literal, HLO-text parsing).
//!
//! The build image bakes in the Python-side toolchain but not the
//! native `xla_extension` bindings, so this module stands in for the
//! real crate: every entry point type-checks against
//! [`super::XlaRuntime`] and fails at *runtime* with a clear
//! "backend unavailable" error. Tests probe
//! [`super::backend_available`] (false here) alongside
//! [`super::artifacts_available`] and skip gracefully, so swapping the
//! real bindings back in is a matter of replacing this `mod xla` with
//! `use xla;` (and flipping [`AVAILABLE`]) — no call-site changes.

use std::path::Path;

/// Whether a real PJRT backend is linked in. The stub is never
/// executable, so XLA-dependent tests skip when this is false even if
/// AOT artifacts are present on disk.
pub const AVAILABLE: bool = false;

/// Error type mirroring `xla::Error` closely enough for `{e:?}`.
pub struct Error(pub &'static str);

impl std::fmt::Debug for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "xla backend unavailable: {}", self.0)
    }
}

const UNAVAILABLE: &str =
    "built without native PJRT bindings (stub runtime::xla)";

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient;

impl PjRtClient {
    /// Real impl: spin up the PJRT CPU plugin. Stub: unavailable.
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(Error(UNAVAILABLE))
    }

    /// Compile a computation on this client.
    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error(UNAVAILABLE))
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    /// Real impl: parse HLO *text* (see `python/compile/aot.py` for why
    /// text, not proto). Stub: unavailable.
    pub fn from_text_file(_path: &Path) -> Result<HloModuleProto, Error> {
        Err(Error(UNAVAILABLE))
    }
}

/// An XLA computation wrapping an HLO module (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A compiled, loaded executable (stub: never constructed).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with literal arguments, returning per-device buffers.
    pub fn execute<L>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error(UNAVAILABLE))
    }
}

/// A device buffer (stub: never constructed).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error(UNAVAILABLE))
    }
}

/// A host literal (stub: carries no data).
pub struct Literal;

impl Literal {
    /// Build a rank-1 literal from host data.
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Err(Error(UNAVAILABLE))
    }

    pub fn to_tuple2(self) -> Result<(Literal, Literal), Error> {
        Err(Error(UNAVAILABLE))
    }

    pub fn to_tuple4(
        self,
    ) -> Result<(Literal, Literal, Literal, Literal), Error> {
        Err(Error(UNAVAILABLE))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(Error(UNAVAILABLE))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_loud_and_early() {
        let e = PjRtClient::cpu().err().expect("stub must not succeed");
        assert!(format!("{e:?}").contains("unavailable"));
        assert!(HloModuleProto::from_text_file(Path::new("x")).is_err());
    }
}
