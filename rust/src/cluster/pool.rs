//! The heterogeneous resource pool (paper Sec. III-A) and the Google
//! cluster server-configuration distribution (paper Table I).

use super::server::Server;
use super::vector::ResVec;
use crate::util::Pcg32;

/// Paper Table I: configurations of servers in one of Google's clusters.
/// (count, CPUs, memory), CPU/memory normalized to the maximum server.
pub const GOOGLE_CLASSES: [(usize, f64, f64); 10] = [
    (6732, 0.50, 0.50),
    (3863, 0.50, 0.25),
    (1001, 0.50, 0.75),
    (795, 1.00, 1.00),
    (126, 0.25, 0.25),
    (52, 0.50, 0.12),
    (5, 0.50, 0.03),
    (5, 0.50, 0.97),
    (3, 1.00, 0.50),
    (1, 0.50, 0.06),
];

/// A group of identical servers — the exact fluid allocator exploits
/// this to collapse per-server constraints into per-class constraints.
#[derive(Clone, Debug)]
pub struct ServerClass {
    pub capacity: ResVec,
    pub count: usize,
}

/// The cluster: a vector of heterogeneous servers.
#[derive(Clone, Debug)]
pub struct Cluster {
    pub servers: Vec<Server>,
    m: usize,
}

impl Cluster {
    /// Build from explicit servers.
    pub fn new(servers: Vec<Server>) -> Self {
        assert!(!servers.is_empty(), "cluster needs at least one server");
        let m = servers[0].capacity.dims();
        assert!(
            servers.iter().all(|s| s.capacity.dims() == m),
            "mixed resource dimensionality"
        );
        Cluster { servers, m }
    }

    /// Build from capacity vectors.
    pub fn from_capacities(caps: &[ResVec]) -> Self {
        Self::new(caps.iter().map(|c| Server::new(*c)).collect())
    }

    /// The paper's running example (Fig. 1): server 1 = (2 CPU, 12 GB),
    /// server 2 = (12 CPU, 2 GB).
    pub fn fig1_example() -> Self {
        Self::from_capacities(&[
            ResVec::cpu_mem(2.0, 12.0),
            ResVec::cpu_mem(12.0, 2.0),
        ])
    }

    /// Sample `k` servers i.i.d. from the Google Table I distribution
    /// (weights = class populations). Deterministic given the RNG.
    pub fn google_sample(k: usize, rng: &mut Pcg32) -> Self {
        let weights: Vec<f64> =
            GOOGLE_CLASSES.iter().map(|&(c, _, _)| c as f64).collect();
        let servers = (0..k)
            .map(|_| {
                let cls = rng.choice_weighted(&weights);
                let (_, cpu, mem) = GOOGLE_CLASSES[cls];
                Server::with_class(ResVec::cpu_mem(cpu, mem), cls)
            })
            .collect();
        Self::new(servers)
    }

    /// The full 12,583-server Google cluster of Table I (every class at
    /// its exact population).
    pub fn google_full() -> Self {
        let mut servers = Vec::new();
        for (cls, &(count, cpu, mem)) in GOOGLE_CLASSES.iter().enumerate() {
            for _ in 0..count {
                servers.push(Server::with_class(ResVec::cpu_mem(cpu, mem), cls));
            }
        }
        Self::new(servers)
    }

    /// Number of resource dimensions.
    #[inline]
    pub fn dims(&self) -> usize {
        self.m
    }

    /// Number of servers.
    #[inline]
    pub fn len(&self) -> usize {
        self.servers.len()
    }

    /// True when the pool is empty (never, by construction).
    pub fn is_empty(&self) -> bool {
        self.servers.is_empty()
    }

    /// Total capacity across all servers.
    pub fn total_capacity(&self) -> ResVec {
        let mut t = ResVec::zeros(self.m);
        for s in &self.servers {
            t.add_assign(&s.capacity);
        }
        t
    }

    /// Total *effective* usage across all servers: per-server usage
    /// discounted by the overcommit slowdown (`Server::effective_usage`)
    /// — resources making progress, not resources merely claimed.
    pub fn total_effective_usage(&self) -> ResVec {
        let mut t = ResVec::zeros(self.m);
        for s in &self.servers {
            let e = s.effective_usage();
            for r in 0..self.m {
                t[r] += e[r];
            }
        }
        t
    }

    /// Per-resource utilization in [0, 1].
    pub fn utilization(&self) -> ResVec {
        self.total_effective_usage().div(&self.total_capacity())
    }

    /// Group servers by identical capacity vectors (order-preserving);
    /// used by the exact fluid allocator. Derived from
    /// [`Cluster::class_members`] so the allocator and the scheduling
    /// index can never disagree on the class partition.
    pub fn classes(&self) -> Vec<ServerClass> {
        self.class_members()
            .into_iter()
            .map(|(capacity, members)| ServerClass {
                capacity,
                count: members.len(),
            })
            .collect()
    }

    /// Group servers by identical capacity, returning each class's
    /// member indices (order-preserving). The scheduling index
    /// (`sched::index::ServerIndex`) builds its class buckets from
    /// this; unlike [`Cluster::classes`] it keeps the membership, not
    /// just the count.
    pub fn class_members(&self) -> Vec<(ResVec, Vec<u32>)> {
        let mut classes: Vec<(ResVec, Vec<u32>)> = Vec::new();
        for (l, s) in self.servers.iter().enumerate() {
            match classes.iter_mut().find(|(cap, _)| *cap == s.capacity) {
                Some((_, members)) => members.push(l as u32),
                None => classes.push((s.capacity, vec![l as u32])),
            }
        }
        classes
    }

    /// Flatten current availability into a row-major f32 matrix [k, m]
    /// for the XLA picker.
    pub fn avail_matrix_f32(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.len() * self.m);
        for s in &self.servers {
            let a = s.available();
            for r in 0..self.m {
                out.push(a[r] as f32);
            }
        }
        out
    }

    /// Reset all usage to zero.
    pub fn reset(&mut self) {
        for s in &mut self.servers {
            s.usage = ResVec::zeros(self.m);
            s.tasks = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn google_table1_totals() {
        let c = Cluster::google_full();
        assert_eq!(c.len(), 12_583);
        let t = c.total_capacity();
        // Σ count·cpu and Σ count·mem from Table I
        let exp_cpu: f64 =
            GOOGLE_CLASSES.iter().map(|&(n, c, _)| n as f64 * c).sum();
        let exp_mem: f64 =
            GOOGLE_CLASSES.iter().map(|&(n, _, m)| n as f64 * m).sum();
        assert!((t[0] - exp_cpu).abs() < 1e-9);
        assert!((t[1] - exp_mem).abs() < 1e-9);
    }

    #[test]
    fn google_sample_is_deterministic_and_from_table() {
        let mut r1 = Pcg32::seeded(9);
        let mut r2 = Pcg32::seeded(9);
        let a = Cluster::google_sample(500, &mut r1);
        let b = Cluster::google_sample(500, &mut r2);
        for (x, y) in a.servers.iter().zip(&b.servers) {
            assert_eq!(x.capacity, y.capacity);
        }
        for s in &a.servers {
            assert!(GOOGLE_CLASSES
                .iter()
                .any(|&(_, c, m)| s.capacity == ResVec::cpu_mem(c, m)));
        }
    }

    #[test]
    fn sample_distribution_tracks_weights() {
        let mut rng = Pcg32::seeded(10);
        let c = Cluster::google_sample(20_000, &mut rng);
        let majority = c
            .servers
            .iter()
            .filter(|s| s.capacity == ResVec::cpu_mem(0.5, 0.5))
            .count();
        // class 0 is 6732/12583 ≈ 53.5% of the population
        let frac = majority as f64 / 20_000.0;
        assert!((frac - 0.535).abs() < 0.02, "frac={frac}");
    }

    #[test]
    fn utilization_and_effective_usage() {
        let mut c = Cluster::fig1_example();
        c.servers[0].commit(&ResVec::cpu_mem(1.0, 6.0));
        let u = c.utilization();
        assert!((u[0] - 1.0 / 14.0).abs() < 1e-12);
        assert!((u[1] - 6.0 / 14.0).abs() < 1e-12);
        // overcommit: usage discounted by the thrashing slowdown
        c.servers[0].commit(&ResVec::cpu_mem(5.0, 20.0));
        let eff = c.servers[0].effective_usage();
        let u = c.utilization();
        assert!((u[0] - eff[0] / 14.0).abs() < 1e-12);
        assert!((u[1] - eff[1] / 14.0).abs() < 1e-12);
        assert!(u[1] < 12.0 / 14.0, "thrashing must cost utilization");
    }

    #[test]
    fn classes_collapse_identical_servers() {
        let mut rng = Pcg32::seeded(11);
        let c = Cluster::google_sample(1000, &mut rng);
        let classes = c.classes();
        assert!(classes.len() <= 10);
        assert_eq!(
            classes.iter().map(|x| x.count).sum::<usize>(),
            c.len()
        );
    }
}
