//! §Perf headline for PR 5: class-keyed user state.
//!
//! Sweeps the user count 10³ → 10⁶ at a FIXED ~10 demand classes on
//! the k = 2,000 Fig. 5 cluster and times the same Best-Fit DRFH
//! simulation on both scheduler-state layouts:
//!
//! * `classed` — the default class-keyed path (`sched::users`):
//!   user selection aggregated over `(dom_delta, weight)` groups,
//!   placement/blocked structures shared per interned demand class —
//!   per-event work scales with classes, not users;
//! * `per-user` — the PR 1 layout (`BestFitDrfh::per_user()`): one
//!   `ShareHeap` entry and one placement heap per user. Its per-event
//!   cost grows with n (each touched server re-scores every user) and
//!   its memory with n·k, so the sweep caps it at `PER_USER_CAP`
//!   users by default — set `USER_SCALE_FULL=1` to run it at every
//!   point (the 10⁶ point takes a long while and a lot of memory by
//!   construction; that is the point).
//!
//! Offered work is held constant across the sweep, so throughput
//! differences isolate the per-event scheduler cost. Target: classed
//! per-event cost ~flat in user count (sublinear growth across the
//! sweep) and **≥5× tasks/sec** over the per-user layout at 10⁶
//! users / 10 classes. Placement counts are asserted equal wherever
//! both paths run (cheap guard); full bit-identical report parity is
//! enforced by `tests/engine_parity.rs` and `drfh exp user-scale`.
//!
//! Results go to `BENCH_users.json` at the repo root (override with
//! `BENCH_OUT=/path.json`); CI runs the small-scale smoke via
//! `USER_SCALE_SMOKE=1`.
//!
//! Run: `cargo bench --bench user_scale`

use drfh::cluster::Cluster;
use drfh::experiments::user_scale::{classed_trace, DEFAULT_CLASSES};
use drfh::sched::BestFitDrfh;
use drfh::sim::{run, SimOpts, SimReport};
use drfh::util::bench::{bench_n, header, write_suite_json, BenchResult};
use drfh::util::json::Json;
use drfh::util::Pcg32;
use std::collections::BTreeMap;

/// Per-user path cap without `USER_SCALE_FULL=1`: its per-event cost
/// grows with n AND its placement index holds up to n·k heap entries
/// (~3 GB at 10⁵ users × 2,000 servers), so default runs stop at 10⁴
/// users — demonstrating the growth without exhausting the machine.
const PER_USER_CAP: usize = 10_000;

struct Case {
    bench: BenchResult,
    report: SimReport,
}

fn run_case(
    name: &str,
    setup: &(Cluster, drfh::workload::Trace, SimOpts),
    per_user: bool,
) -> Case {
    let (cluster, trace, opts) = setup;
    let mut report = None;
    let bench = bench_n(name, 1, || {
        let sched = if per_user {
            BestFitDrfh::per_user()
        } else {
            BestFitDrfh::default()
        };
        let rep =
            run(cluster.clone(), trace, Box::new(sched), opts.clone());
        let placed = rep.tasks_placed;
        report = Some(rep);
        placed
    });
    Case { bench, report: report.expect("bench ran at least once") }
}

fn tasks_per_sec(c: &Case) -> f64 {
    c.report.tasks_completed as f64 / c.bench.mean.as_secs_f64().max(1e-12)
}

fn per_event_ns(c: &Case) -> f64 {
    let events =
        (c.report.tasks_placed + c.report.tasks_completed).max(1) as f64;
    c.bench.mean.as_nanos() as f64 / events
}

fn main() {
    let smoke = std::env::var_os("USER_SCALE_SMOKE").is_some();
    let full = std::env::var_os("USER_SCALE_FULL").is_some();
    let (servers, total_tasks, duration, sweep): (usize, usize, f64, Vec<usize>) =
        if smoke {
            (200, 8_000, 3_600.0, vec![1_000, 5_000])
        } else {
            (2_000, 200_000, 14_400.0, vec![
                1_000, 10_000, 100_000, 1_000_000,
            ])
        };
    let classes = DEFAULT_CLASSES;
    let per_user_cap = if full { usize::MAX } else { PER_USER_CAP };
    println!(
        "user_scale: k={servers} classes={classes} ~{total_tasks} tasks \
         over {duration:.0}s, users swept {sweep:?}{}",
        if smoke { " [smoke]" } else { "" }
    );
    header("user_scale: class-keyed vs per-user scheduler state");

    let mut results: Vec<BenchResult> = Vec::new();
    let mut rows: Vec<Json> = Vec::new();
    let mut classed_event_ns: Vec<(usize, f64)> = Vec::new();
    let mut last_speedup: Option<(usize, f64)> = None;
    for &n in &sweep {
        let mut rng = Pcg32::new(2026, 0xc1);
        let cluster = Cluster::google_sample(servers, &mut rng);
        let trace = classed_trace(n, classes, total_tasks, duration, 2026);
        let opts = SimOpts {
            horizon: duration,
            sample_dt: (duration / 200.0).max(10.0),
            ..SimOpts::default()
        };
        let setup = (cluster, trace, opts);
        let classed = run_case(&format!("classed-n{n}"), &setup, false);
        classed_event_ns.push((n, per_event_ns(&classed)));
        let mut row = BTreeMap::new();
        row.insert("users".to_string(), Json::Num(n as f64));
        row.insert(
            "tasks_per_sec_classed".to_string(),
            Json::Num(tasks_per_sec(&classed)),
        );
        row.insert(
            "per_event_ns_classed".to_string(),
            Json::Num(per_event_ns(&classed)),
        );
        if n <= per_user_cap {
            let per_user =
                run_case(&format!("per-user-n{n}"), &setup, true);
            // cheap parity guard; the bit-identical proof lives in
            // tests/engine_parity.rs
            assert_eq!(
                classed.report.tasks_placed, per_user.report.tasks_placed,
                "classed/per-user placement counts diverged at n={n}"
            );
            let speedup = per_user.bench.mean.as_secs_f64()
                / classed.bench.mean.as_secs_f64().max(1e-12);
            println!(
                "  n={n:>9}: classed {:>10.0} tasks/s ({:>7.0} ns/event), \
                 per-user {:>10.0} tasks/s -> {speedup:.2}x",
                tasks_per_sec(&classed),
                per_event_ns(&classed),
                tasks_per_sec(&per_user),
            );
            row.insert(
                "tasks_per_sec_per_user".to_string(),
                Json::Num(tasks_per_sec(&per_user)),
            );
            row.insert(
                "per_event_ns_per_user".to_string(),
                Json::Num(per_event_ns(&per_user)),
            );
            row.insert("speedup".to_string(), Json::Num(speedup));
            last_speedup = Some((n, speedup));
            results.push(per_user.bench);
        } else {
            println!(
                "  n={n:>9}: classed {:>10.0} tasks/s ({:>7.0} ns/event); \
                 per-user path skipped (cap {per_user_cap}; set \
                 USER_SCALE_FULL=1 to run it)",
                tasks_per_sec(&classed),
                per_event_ns(&classed),
            );
            row.insert("tasks_per_sec_per_user".to_string(), Json::Null);
            row.insert("per_event_ns_per_user".to_string(), Json::Null);
            row.insert("speedup".to_string(), Json::Null);
        }
        results.push(classed.bench);
        rows.push(Json::Obj(row));
    }

    // flatness: classed per-event cost across three decades of users
    let (n_lo, ns_lo) = classed_event_ns[0];
    let (n_hi, ns_hi) = *classed_event_ns.last().expect("non-empty sweep");
    let growth = ns_hi / ns_lo.max(1e-12);
    println!(
        "\nclassed per-event cost: {ns_lo:.0} ns at n={n_lo} -> \
         {ns_hi:.0} ns at n={n_hi} ({growth:.2}x across {:.0}x users)",
        n_hi as f64 / n_lo as f64
    );
    if !smoke && growth > 3.0 {
        println!(
            "WARNING: classed per-event cost grew {growth:.2}x across \
             the sweep — expected ~flat in user count"
        );
    }
    if let Some((n, s)) = last_speedup {
        if !smoke && s < 5.0 && n >= PER_USER_CAP {
            println!(
                "WARNING: classed speedup {s:.2}x at n={n} below the \
                 5x target"
            );
        }
    }

    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_users.json")
            .to_string()
    });
    let meta = [
        ("servers", Json::Num(servers as f64)),
        ("classes", Json::Num(classes as f64)),
        ("tasks_offered_approx", Json::Num(total_tasks as f64)),
        ("horizon_s", Json::Num(duration)),
        ("smoke", Json::Bool(smoke)),
        ("per_user_cap", Json::Num(per_user_cap.min(1 << 52) as f64)),
        ("per_event_cost_growth_classed", Json::Num(growth)),
        ("sweep", Json::Arr(rows)),
    ];
    let path = std::path::PathBuf::from(&out);
    if write_suite_json(&path, "user_scale", &meta, &results) {
        println!("\nwrote {}", path.display());
    } else {
        println!("\ncould not write {} (read-only fs?)", path.display());
    }
}
