//! §Perf bench: the exact fluid DRFH allocator (LP on server classes)
//! as users and cluster size grow, plus the per-server DRF baseline.
//!
//! Run: `cargo bench --bench allocator_scale`

use drfh::allocator::{self, per_server_drf, FluidUser};
use drfh::cluster::{Cluster, ResVec};
use drfh::util::bench::{bench, header};
use drfh::util::Pcg32;
use std::time::Duration;

fn main() {
    let budget = Duration::from_millis(1000);
    header("exact fluid DRFH solve (Table I classes)");
    for &(servers, users) in
        &[(100usize, 5usize), (500, 20), (2000, 50), (2000, 100), (12583, 100)]
    {
        let mut rng = Pcg32::seeded(7);
        let cluster = if servers == 12_583 {
            Cluster::google_full()
        } else {
            Cluster::google_sample(servers, &mut rng)
        };
        let fluid_users: Vec<FluidUser> = (0..users)
            .map(|_| {
                FluidUser::unweighted(ResVec::cpu_mem(
                    rng.uniform(0.02, 0.5),
                    rng.uniform(0.02, 0.5),
                ))
            })
            .collect();
        bench(
            &format!("drfh solve k={servers} n={users}"),
            budget,
            1_000,
            || allocator::solve(&cluster, &fluid_users),
        );
    }

    header("exact solve with finite caps (progressive rounds)");
    for &users in &[20usize, 50] {
        let mut rng = Pcg32::seeded(11);
        let cluster = Cluster::google_sample(1000, &mut rng);
        let fluid_users: Vec<FluidUser> = (0..users)
            .map(|i| FluidUser {
                demand: ResVec::cpu_mem(
                    rng.uniform(0.02, 0.5),
                    rng.uniform(0.02, 0.5),
                ),
                weight: 1.0,
                task_cap: Some(10.0 + i as f64 * 40.0),
            })
            .collect();
        bench(
            &format!("drfh solve capped k=1000 n={users}"),
            budget,
            1_000,
            || allocator::solve(&cluster, &fluid_users),
        );
    }

    header("naive per-server DRF baseline (Sec. III-D)");
    for &servers in &[500usize, 2000] {
        let mut rng = Pcg32::seeded(13);
        let cluster = Cluster::google_sample(servers, &mut rng);
        let demands: Vec<ResVec> = (0..50)
            .map(|_| {
                ResVec::cpu_mem(rng.uniform(0.02, 0.5), rng.uniform(0.02, 0.5))
            })
            .collect();
        bench(
            &format!("per-server drf k={servers} n=50"),
            budget,
            1_000,
            || per_server_drf::solve(&cluster, &demands),
        );
    }
}
