//! Static analysis: the in-tree determinism conformance linter.
//!
//! Every fairness property the DRFH reproduction defends (exact global
//! dominant-share argmin, bit-exact parity against the `naive()`
//! references) rests on source-level conventions that the compiler and
//! clippy cannot express: no hash-order iteration in decision paths,
//! total-order float comparisons, no wall-clock or entropy sources in
//! the simulation, every [`crate::sched::Scheduler`] paired with a
//! parity reference. [`lint`] machine-checks those conventions with a
//! zero-dependency lexer in the spirit of [`crate::util::toml_lite`]:
//! no syn, no regex, just enough token discipline (comments, strings,
//! raw strings, char literals) to scan real Rust without false hits
//! inside literals.
//!
//! Entry points: [`lint::lint_crate`] walks the whole package —
//! `src/` with the full rule set plus the `benches/` and `tests/`
//! harness trees with the `float-sort` and `wall-clock` rules —
//! [`lint::lint_tree`] walks one source tree, and
//! [`lint::lint_source`] lints one file (what the embedded violation
//! corpus and the self-tests use). The `drfh lint` CLI subcommand and
//! the CI gate sit on top of these. The rule table lives in
//! ARCHITECTURE.md §"Correctness tooling".

pub mod lint;

pub use lint::{
    lint_crate, lint_source, lint_tree, Finding, Rule, VIOLATION_CORPUS,
};
