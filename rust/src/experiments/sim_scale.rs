//! §Perf diagnostic for the trace-scale simulation data plane
//! (`drfh exp sim-scale`): run the same Best-Fit DRFH simulation on
//! the naive binary-heap event queue and on the timer wheel (full and
//! streaming metrics), check the parity and memory invariants, and
//! report throughput.
//!
//! This is the `exp`-level smoke path for `benches/sim_scale.rs`: the
//! bench produces the committed `BENCH_sim.json` numbers at k = 2000
//! / ~10⁶ tasks; this harness runs at whatever scale the CLI asks for
//! (`--servers/--users/--duration`) and is cheap enough for tests.

use crate::experiments::EvalSetup;
use crate::metrics::MetricsMode;
use crate::sched::BestFitDrfh;
use crate::sim::{run, QueueKind, SimOpts, SimReport};
use std::time::{Duration, Instant};

/// One timed variant.
pub struct QueueRun {
    pub label: &'static str,
    pub report: SimReport,
    pub wall: Duration,
}

impl QueueRun {
    /// Completed tasks per wall-clock second.
    pub fn tasks_per_sec(&self) -> f64 {
        self.report.tasks_completed as f64
            / self.wall.as_secs_f64().max(1e-12)
    }

    /// Committed placements per wall-clock second.
    pub fn placements_per_sec(&self) -> f64 {
        self.report.tasks_placed as f64 / self.wall.as_secs_f64().max(1e-12)
    }

    /// Retained metric points (series samples + job records) — the
    /// memory the metrics layer holds at end of run.
    pub fn retained_points(&self) -> usize {
        let series = |ts: &crate::metrics::TimeSeries| ts.len();
        let mut pts = series(&self.report.cpu_util)
            + series(&self.report.mem_util)
            + self.report.jobs.len();
        for s in self
            .report
            .user_dom_share
            .iter()
            .chain(&self.report.user_cpu_share)
            .chain(&self.report.user_mem_share)
        {
            pts += s.len();
        }
        pts
    }
}

/// The three-variant comparison.
pub struct SimScaleResult {
    pub heap_full: QueueRun,
    pub wheel_full: QueueRun,
    pub wheel_streaming: QueueRun,
    pub tasks_offered: usize,
}

impl SimScaleResult {
    /// Wall-clock speedup of the wheel over the heap (same metrics).
    pub fn wheel_speedup(&self) -> f64 {
        self.heap_full.wall.as_secs_f64()
            / self.wheel_full.wall.as_secs_f64().max(1e-12)
    }

    /// The load-bearing invariant: heap and wheel runs are
    /// *bit-identical* — every decision, sample and job record.
    pub fn queue_parity_ok(&self) -> bool {
        self.heap_full.report == self.wheel_full.report
    }

    /// Streaming mode must not change the simulation itself: same
    /// placements/completions and identical streaming job statistics;
    /// only the retention policy differs.
    pub fn streaming_semantics_ok(&self) -> bool {
        let s = &self.wheel_streaming.report;
        let f = &self.wheel_full.report;
        s.tasks_placed == f.tasks_placed
            && s.tasks_completed == f.tasks_completed
            && s.job_stats == f.job_stats
            && s.jobs.is_empty()
    }

    /// Decimated utilization stays within plotting tolerance of the
    /// full series (Fig. 5's quantity).
    pub fn streaming_util_delta(&self) -> f64 {
        (self.wheel_streaming.report.avg_cpu_util
            - self.wheel_full.report.avg_cpu_util)
            .abs()
    }
}

fn timed(
    setup: &EvalSetup,
    label: &'static str,
    queue: QueueKind,
    metrics: MetricsMode,
) -> QueueRun {
    let opts = SimOpts { queue, metrics, ..setup.opts.clone() };
    let t0 = Instant::now();
    let report = run(
        setup.cluster.clone(),
        &setup.trace,
        Box::new(BestFitDrfh::default()),
        opts,
    );
    QueueRun { label, report, wall: t0.elapsed() }
}

/// Run the three variants sequentially (timing comparisons must not
/// share cores) and return the comparison.
pub fn run_sim_scale(setup: &EvalSetup) -> SimScaleResult {
    let heap_full =
        timed(setup, "heap-full", QueueKind::Heap, MetricsMode::Full);
    let wheel_full =
        timed(setup, "wheel-full", QueueKind::Wheel, MetricsMode::Full);
    // cap at a quarter of the expected sample count so decimation
    // actually fires at every scale this harness runs at (EvalSetup's
    // sample_dt floor keeps series <= ~721 points, below the 2048
    // production default — a default-cap run would test nothing)
    let samples = (setup.opts.horizon / setup.opts.sample_dt) as usize;
    let series_cap = (samples / 4).max(8);
    let wheel_streaming = timed(
        setup,
        "wheel-streaming",
        QueueKind::Wheel,
        MetricsMode::Streaming { series_cap },
    );
    SimScaleResult {
        heap_full,
        wheel_full,
        wheel_streaming,
        tasks_offered: setup.trace.total_tasks(),
    }
}

pub fn print(res: &SimScaleResult) {
    println!("== sim-scale: event-queue / metrics data-plane check ==");
    println!(
        "offered {} tasks; parity heap==wheel: {}; streaming semantics: {}",
        res.tasks_offered,
        if res.queue_parity_ok() { "OK (bit-identical)" } else { "FAILED" },
        if res.streaming_semantics_ok() { "OK" } else { "FAILED" },
    );
    for rrun in [&res.heap_full, &res.wheel_full, &res.wheel_streaming] {
        println!(
            "{:<16} {:>9.1} ms  {:>10.0} tasks/s  {:>10.0} placements/s  \
             {:>9} retained pts",
            rrun.label,
            rrun.wall.as_secs_f64() * 1e3,
            rrun.tasks_per_sec(),
            rrun.placements_per_sec(),
            rrun.retained_points(),
        );
    }
    println!(
        "wheel speedup {:.2}x; streaming avg-util delta {:.4} \
         (plotting tolerance)",
        res.wheel_speedup(),
        res.streaming_util_delta(),
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The exp-level smoke: a small Fig. 5-shaped setup must pass the
    /// parity and streaming invariants end to end.
    #[test]
    fn smoke_invariants_hold() {
        let setup = EvalSetup::with_duration(42, 60, 8, 2_500.0);
        let res = run_sim_scale(&setup);
        assert!(res.queue_parity_ok(), "heap vs wheel reports diverged");
        assert!(res.streaming_semantics_ok());
        // decimation really fired (the harness caps below the sample
        // count) and stayed within plotting tolerance
        assert!(
            res.wheel_streaming.report.cpu_util.len()
                < res.wheel_full.report.cpu_util.len(),
            "streaming run never decimated — the tolerance check is vacuous"
        );
        assert!(
            res.streaming_util_delta() < 0.05,
            "decimated avg drifted {}",
            res.streaming_util_delta()
        );
        assert!(res.heap_full.report.tasks_placed > 0);
        // streaming retains no more points than full mode
        assert!(
            res.wheel_streaming.report.job_stats.count()
                == res.wheel_full.report.job_stats.count()
        );
        assert!(
            res.wheel_streaming.retained_points()
                <= res.wheel_full.retained_points()
        );
    }
}
