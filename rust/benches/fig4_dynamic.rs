//! Regenerates paper Fig. 4 — the discrete dynamic-allocation run and
//! its fluid counterpart (warm-started incremental allocator) — fanned
//! out on `experiments::runner`, then times both end to end.
//!
//! Run: `cargo bench --bench fig4_dynamic`

use drfh::experiments::runner::{self, Job};
use drfh::experiments::{fig4, fig4_fluid};
use drfh::util::bench::{bench_n, header};

enum Out {
    Discrete(fig4::Fig4Result),
    Fluid(fig4_fluid::Fig4FluidResult),
}

fn main() {
    // regenerate both variants once (in parallel), with full summaries
    let jobs: Vec<Job<'static, Out>> = vec![
        Box::new(|| Out::Discrete(fig4::run_fig4(42))),
        Box::new(|| Out::Fluid(fig4_fluid::run_fig4_fluid(42))),
    ];
    for out in runner::run_parallel(jobs) {
        match out {
            Out::Discrete(res) => fig4::print(&res),
            Out::Fluid(res) => fig4_fluid::print(&res),
        }
    }

    header("fig4: dynamic allocation (100 servers), discrete vs fluid");
    bench_n("fig4 discrete run (2000 s)", 3, || {
        fig4::run_fig4(42).report.tasks_placed
    });
    bench_n("fig4 fluid run (incremental + scratch)", 3, || {
        fig4_fluid::run_fig4_fluid(42).warm_pivots
    });
}
