//! The slot-based baseline scheduler (paper Sec. VI / Table II; models
//! the Hadoop Fair Scheduler the paper compares against).
//!
//! Each server is partitioned into *slots*: the maximum server (1 CPU,
//! 1 mem in Table I's normalized units) is divided into `slots_per_max`
//! equal bundles, and every server hosts as many whole slots as the
//! bundle fits into its capacity (jointly across resources). A task
//! occupies exactly one slot regardless of its real demand; fairness is
//! max-min over *slot counts* (weighted), and real resource usage is
//! never checked — overcommitting a server is possible, in which case
//! the engine applies a processor-sharing slowdown to every task on it.
//! This is exactly the pathology the paper attributes to slot
//! schedulers: the single-resource abstraction ignores both server and
//! demand heterogeneity.
//!
//! §Perf: both halves of a pick are indexed. The server side is the
//! `free_hint` cursor (below); the user side reuses the
//! [`ShareHeap`] machinery keyed on the weighted running-slot count
//! `running / effective_weight` instead of the naive O(n) scan per
//! pick, which dominated Table II sweeps at k = 12,583.
//! [`SlotsScheduler::naive`] keeps the linear scan as the
//! bit-identical reference (parity in `tests/engine_parity.rs`).

use super::index::ShareHeap;
use super::{effective_weight, Pick, Scheduler, UserState};
use crate::cluster::{Cluster, ResVec};

/// The fair-sharing key: weighted running-slot count (1 task = 1
/// slot). The single place both the naive scan and the heap compute
/// it, so their argmins are bit-identical.
#[inline]
fn slot_key(u: &UserState) -> f64 {
    u.running as f64 / effective_weight(u.weight)
}

/// The Slots policy.
pub struct SlotsScheduler {
    /// Number of slots the *maximum* server is divided into.
    pub slots_per_max: usize,
    /// Per-server slot capacity, derived from the cluster.
    slots_total: Vec<usize>,
    /// First server index that might have a free slot (§Perf: the
    /// naive per-placement linear scan was 53% of saturated runs; the
    /// cursor only moves forward past full servers and is pulled back
    /// by `on_free`, so it always lower-bounds the true first free
    /// slot and the picked server is identical to a full scan).
    free_hint: usize,
    /// Lazy min-heap over `slot_key` (default), or `None` for the
    /// naive O(n) user scan. Both paths emit identical decisions.
    users_heap: Option<ShareHeap>,
}

impl SlotsScheduler {
    /// Build for `cluster`, dividing the largest server into
    /// `slots_per_max` slots.
    pub fn new(cluster: &Cluster, slots_per_max: usize) -> Self {
        assert!(slots_per_max >= 1);
        let m = cluster.dims();
        // the "maximum server": componentwise max capacity
        let mut maxcap = ResVec::zeros(m);
        for s in &cluster.servers {
            for r in 0..m {
                maxcap[r] = maxcap[r].max(s.capacity[r]);
            }
        }
        let slot = maxcap.scale(1.0 / slots_per_max as f64);
        let slots_total = cluster
            .servers
            .iter()
            .map(|s| {
                // whole slots that fit jointly across all resources
                let mut n = usize::MAX;
                for r in 0..m {
                    if slot[r] > 0.0 {
                        n = n.min((s.capacity[r] / slot[r] + 1e-9) as usize);
                    }
                }
                n.max(1) // every server offers at least one slot
            })
            .collect();
        SlotsScheduler {
            slots_per_max,
            slots_total,
            free_hint: 0,
            users_heap: Some(ShareHeap::new()),
        }
    }

    /// The seed's linear-scan user selection — the parity reference
    /// and the naive baseline in `benches/table2_slots.rs`.
    pub fn naive(cluster: &Cluster, slots_per_max: usize) -> Self {
        SlotsScheduler { users_heap: None, ..Self::new(cluster, slots_per_max) }
    }

    /// Is this instance on the indexed user-selection path?
    pub fn is_indexed(&self) -> bool {
        self.users_heap.is_some()
    }

    /// Slot capacity of server `l`.
    pub fn slots_of(&self, l: usize) -> usize {
        self.slots_total[l]
    }

    /// Total slots in the cluster.
    pub fn total_slots(&self) -> usize {
        self.slots_total.iter().sum()
    }
}

impl Scheduler for SlotsScheduler {
    fn name(&self) -> &'static str {
        "slots"
    }

    fn pick(
        &mut self,
        cluster: &Cluster,
        users: &[UserState],
        eligible: &[bool],
    ) -> Pick {
        // fair sharing over slot counts: serve the pending user with the
        // fewest weighted running tasks (1 task = 1 slot); zero weights
        // use the shared guarded fallback (see `sched::effective_weight`)
        let best = match &mut self.users_heap {
            Some(heap) => {
                heap.refresh_with(users, eligible, slot_key);
                heap.peek_min(users, eligible)
            }
            None => {
                let mut best: Option<usize> = None;
                for i in 0..users.len() {
                    if !eligible[i] || users[i].pending == 0 {
                        continue;
                    }
                    match best {
                        Some(b)
                            if slot_key(&users[b])
                                <= slot_key(&users[i]) => {}
                        _ => best = Some(i),
                    }
                }
                best
            }
        };
        let Some(u) = best else { return Pick::Idle };
        // first server with a free slot (resource demands NOT checked),
        // scanning from the cursor — everything before it is full
        let k = cluster.len();
        let mut l = self.free_hint;
        while l < k && cluster.servers[l].tasks >= self.slots_total[l] {
            l += 1;
        }
        self.free_hint = l;
        if l < k {
            Pick::Place { user: u, server: l }
        } else {
            // drop u from the heap until the engine unblocks it
            // (on_ready), mirroring the IndexedCore blocked protocol
            if let Some(heap) = &mut self.users_heap {
                heap.remove(u);
            }
            Pick::Blocked { user: u }
        }
    }

    fn can_fit(
        &self,
        cluster: &Cluster,
        _users: &[UserState],
        _user: usize,
        server: usize,
    ) -> bool {
        cluster.servers[server].tasks < self.slots_total[server]
    }

    fn allows_overcommit(&self) -> bool {
        true
    }

    fn on_free(&mut self, server: usize) {
        if server < self.free_hint {
            self.free_hint = server;
        }
    }

    fn on_place(&mut self, user: usize, _server: usize) {
        if let Some(heap) = &mut self.users_heap {
            heap.mark_dirty(user); // running/pending changed
        }
    }

    fn on_complete(&mut self, user: usize, _server: usize) {
        if let Some(heap) = &mut self.users_heap {
            heap.mark_dirty(user); // running changed
        }
    }

    fn on_ready(&mut self, user: usize) {
        if let Some(heap) = &mut self.users_heap {
            heap.mark_dirty(user);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Server;
    use crate::util::Pcg32;

    #[test]
    fn slot_counts_proportional_to_server_size() {
        let mut rng = Pcg32::seeded(5);
        let cluster = Cluster::google_sample(100, &mut rng);
        let s = SlotsScheduler::new(&cluster, 14);
        for (l, srv) in cluster.servers.iter().enumerate() {
            let expect = ((srv.capacity[0] * 14.0 + 1e-9) as usize)
                .min((srv.capacity[1] * 14.0 + 1e-9) as usize)
                .max(1);
            assert_eq!(s.slots_of(l), expect, "server {l}");
        }
    }

    #[test]
    fn unbalanced_servers_lose_slots() {
        // (1, 1) vs (1, 0.12): joint fit penalizes the unbalanced box
        let cluster = Cluster::from_capacities(&[
            ResVec::cpu_mem(1.0, 1.0),
            ResVec::cpu_mem(1.0, 0.12),
        ]);
        let s = SlotsScheduler::new(&cluster, 10);
        assert_eq!(s.slots_of(0), 10);
        assert_eq!(s.slots_of(1), 1);
    }

    #[test]
    fn constructors_select_the_expected_path() {
        let cluster = Cluster::from_capacities(&[ResVec::cpu_mem(1.0, 1.0)]);
        assert!(SlotsScheduler::new(&cluster, 4).is_indexed());
        assert!(!SlotsScheduler::naive(&cluster, 4).is_indexed());
        assert_eq!(
            SlotsScheduler::naive(&cluster, 4).total_slots(),
            SlotsScheduler::new(&cluster, 4).total_slots()
        );
    }

    #[test]
    fn fairness_by_running_count() {
        let cluster = Cluster::from_capacities(&[ResVec::cpu_mem(1.0, 1.0)]);
        let mk = |pending, running| UserState {
            demand: ResVec::cpu_mem(0.1, 0.1),
            weight: 1.0,
            pending,
            running,
            dom_share: 0.0,
            usage: ResVec::zeros(2),
            dom_delta: 0.1,
        };
        let users = vec![mk(1, 3), mk(1, 1)];
        for mut s in
            [SlotsScheduler::new(&cluster, 4), SlotsScheduler::naive(&cluster, 4)]
        {
            assert_eq!(
                s.pick(&cluster, &users, &[true, true]),
                Pick::Place { user: 1, server: 0 }
            );
        }
    }

    /// A zero-weight user ranks through the guarded fallback on both
    /// user-selection paths.
    #[test]
    fn zero_weight_ranks_identically() {
        let cluster = Cluster::from_capacities(&[ResVec::cpu_mem(1.0, 1.0)]);
        let mk = |running, weight| UserState {
            demand: ResVec::cpu_mem(0.1, 0.1),
            weight,
            pending: 1,
            running,
            dom_share: 0.0,
            usage: ResVec::zeros(2),
            dom_delta: 0.1,
        };
        // weight 0 -> effective 1.0: key 2.0 beats user 0's 3.0
        let users = vec![mk(3, 1.0), mk(2, 0.0)];
        for mut s in
            [SlotsScheduler::new(&cluster, 4), SlotsScheduler::naive(&cluster, 4)]
        {
            assert_eq!(
                s.pick(&cluster, &users, &[true, true]),
                Pick::Place { user: 1, server: 0 }
            );
        }
    }

    #[test]
    fn blocked_when_no_free_slots() {
        let mut cluster =
            Cluster::new(vec![Server::new(ResVec::cpu_mem(1.0, 1.0))]);
        cluster.servers[0].tasks = 2; // both slots taken
        let users = vec![UserState {
            demand: ResVec::cpu_mem(0.1, 0.1),
            weight: 1.0,
            pending: 1,
            running: 2,
            dom_share: 0.0,
            usage: ResVec::zeros(2),
            dom_delta: 0.1,
        }];
        for mut s in
            [SlotsScheduler::new(&cluster, 2), SlotsScheduler::naive(&cluster, 2)]
        {
            assert_eq!(
                s.pick(&cluster, &users, &[true]),
                Pick::Blocked { user: 0 }
            );
            assert!(!s.can_fit(&cluster, &users, 0, 0));
            cluster.servers[0].tasks = 1;
            assert!(s.can_fit(&cluster, &users, 0, 0));
            assert!(s.allows_overcommit());
            cluster.servers[0].tasks = 2; // restore for the next path
        }
    }
}
