//! §Perf headline: indexed vs naive placement hot path on the paper's
//! Fig. 5 configuration (k = 2,000 Table I servers, 100 users,
//! saturated Google-like trace).
//!
//! The naive path pays O(n + k·m) per decision (rescan every user,
//! rescan every server); the indexed path (`sched::index`) pays
//! O(log n + log k) amortized per decision and O(n·m) per
//! place/complete event. Target: **≥5× end-to-end speedup** at
//! k = 2,000, with decision parity enforced separately by
//! `tests/engine_parity.rs` (and placement-count equality asserted
//! here as a cheap guard).
//!
//! A second section times the paper's standard 3-policy comparison
//! (Best-Fit / First-Fit / Slots-14, the Fig. 5 sweep) sequentially
//! vs through `experiments::runner`'s scoped-thread fan-out — target
//! **≥2.5× wall-clock** on a ≥4-core box (the sweep has 3 jobs, so
//! the ceiling is 3×; 2-core CI smoke machines warn instead of fail).
//!
//! Results go to `BENCH_engine.json` at the repo root (override with
//! `BENCH_OUT=/path.json`) to start the perf trajectory; CI runs the
//! small-scale smoke via `ENGINE_SCALE_SMOKE=1`.
//!
//! Run: `cargo bench --bench engine_scale`

use drfh::experiments::{fig5, runner, EvalSetup};
use drfh::sched::{BestFitDrfh, FirstFitDrfh, Scheduler};
use drfh::sim::run;
use drfh::util::bench::{bench_n, header, write_suite_json, BenchResult};
use drfh::util::json::Json;

fn run_case(
    name: &str,
    iters: usize,
    setup: &EvalSetup,
    mk: impl Fn() -> Box<dyn Scheduler>,
) -> (BenchResult, usize) {
    let mut placed = 0usize;
    let r = bench_n(name, iters, || {
        let rep = run(
            setup.cluster.clone(),
            &setup.trace,
            mk(),
            setup.opts.clone(),
        );
        placed = rep.tasks_placed;
        placed
    });
    (r, placed)
}

fn main() {
    let smoke = std::env::var_os("ENGINE_SCALE_SMOKE").is_some();
    let (servers, users, duration, iters) = if smoke {
        (200usize, 20usize, 3_600.0f64, 2usize)
    } else {
        (2_000, 100, 21_600.0, 1)
    };
    let setup = EvalSetup::with_duration(42, servers, users, duration);
    println!(
        "engine_scale: k={servers} n={users} horizon={duration:.0}s \
         ({} tasks offered){}",
        setup.trace.total_tasks(),
        if smoke { " [smoke]" } else { "" }
    );

    header("engine_scale: full simulation, naive vs indexed");
    let (bf_naive, placed_bf_naive) =
        run_case("bestfit-naive", iters, &setup, || {
            Box::new(BestFitDrfh::naive())
        });
    let (bf_idx, placed_bf_idx) =
        run_case("bestfit-indexed", iters, &setup, || {
            Box::new(BestFitDrfh::default())
        });
    let (ff_naive, placed_ff_naive) =
        run_case("firstfit-naive", iters, &setup, || {
            Box::new(FirstFitDrfh::naive())
        });
    let (ff_idx, placed_ff_idx) =
        run_case("firstfit-indexed", iters, &setup, || {
            Box::new(FirstFitDrfh::default())
        });

    // cheap parity guard; the real proof is tests/engine_parity.rs
    assert_eq!(
        placed_bf_naive, placed_bf_idx,
        "best-fit indexed/naive placement counts diverged"
    );
    assert_eq!(
        placed_ff_naive, placed_ff_idx,
        "first-fit indexed/naive placement counts diverged"
    );

    let speedup_bf =
        bf_naive.mean.as_secs_f64() / bf_idx.mean.as_secs_f64().max(1e-12);
    let speedup_ff =
        ff_naive.mean.as_secs_f64() / ff_idx.mean.as_secs_f64().max(1e-12);
    let thr = |placed: usize, r: &BenchResult| {
        placed as f64 / r.mean.as_secs_f64().max(1e-12)
    };
    println!(
        "\nbest-fit : {:>10.0} -> {:>10.0} placements/s  ({speedup_bf:.2}x)",
        thr(placed_bf_naive, &bf_naive),
        thr(placed_bf_idx, &bf_idx),
    );
    println!(
        "first-fit: {:>10.0} -> {:>10.0} placements/s  ({speedup_ff:.2}x)",
        thr(placed_ff_naive, &ff_naive),
        thr(placed_ff_idx, &ff_idx),
    );
    if !smoke && speedup_bf < 5.0 {
        println!(
            "WARNING: best-fit speedup {speedup_bf:.2}x below the 5x target"
        );
    }
    if !smoke && speedup_ff < 5.0 {
        println!(
            "WARNING: first-fit speedup {speedup_ff:.2}x below the 5x target"
        );
    }

    // ---- 3-policy sweep: sequential vs parallel ------------------
    header("engine_scale: 3-policy sweep (fig5 set), sequential vs parallel");
    let mut placed_seq: Vec<usize> = Vec::new();
    let seq_sweep = bench_n("sweep-sequential", iters, || {
        placed_seq = runner::sweep_sequential(
            &setup.cluster,
            &setup.trace,
            &setup.opts,
            &fig5::standard_factories(),
        )
        .iter()
        .map(|r| r.tasks_placed)
        .collect();
        placed_seq.iter().sum::<usize>()
    });
    let mut placed_par: Vec<usize> = Vec::new();
    let par_sweep = bench_n("sweep-parallel", iters, || {
        placed_par = runner::sweep(
            &setup.cluster,
            &setup.trace,
            &setup.opts,
            fig5::standard_factories(),
        )
        .iter()
        .map(|r| r.tasks_placed)
        .collect();
        placed_par.iter().sum::<usize>()
    });
    // cheap parity guard, like the indexed/naive section: the fan-out
    // must return the same per-variant results in the same order
    assert_eq!(
        placed_seq, placed_par,
        "parallel sweep per-variant placements diverged from sequential"
    );
    let placed_sweep: usize = placed_par.iter().sum();
    let speedup_sweep = seq_sweep.mean.as_secs_f64()
        / par_sweep.mean.as_secs_f64().max(1e-12);
    let sweep_workers = runner::worker_count(3);
    println!(
        "\n3-policy sweep: {speedup_sweep:.2}x parallel speedup \
         ({sweep_workers} worker threads)"
    );
    if !smoke && speedup_sweep < 2.5 {
        println!(
            "WARNING: sweep speedup {speedup_sweep:.2}x below the 2.5x \
             target (needs >= 3 idle cores)"
        );
    }

    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_engine.json")
            .to_string()
    });
    let meta = [
        ("servers", Json::Num(servers as f64)),
        ("users", Json::Num(users as f64)),
        ("horizon_s", Json::Num(duration)),
        ("tasks_offered", Json::Num(setup.trace.total_tasks() as f64)),
        ("tasks_placed", Json::Num(placed_bf_idx as f64)),
        ("smoke", Json::Bool(smoke)),
        ("speedup_bestfit", Json::Num(speedup_bf)),
        ("speedup_firstfit", Json::Num(speedup_ff)),
        ("speedup_sweep_parallel", Json::Num(speedup_sweep)),
        (
            "sweep_tasks_placed_total",
            Json::Num(placed_sweep as f64),
        ),
        ("sweep_worker_threads", Json::Num(sweep_workers as f64)),
        (
            "placements_per_sec_bestfit_indexed",
            Json::Num(thr(placed_bf_idx, &bf_idx)),
        ),
        (
            "placements_per_sec_bestfit_naive",
            Json::Num(thr(placed_bf_naive, &bf_naive)),
        ),
    ];
    let results = [bf_naive, bf_idx, ff_naive, ff_idx, seq_sweep, par_sweep];
    let path = std::path::PathBuf::from(&out);
    if write_suite_json(&path, "engine_scale", &meta, &results) {
        println!("\nwrote {}", path.display());
    } else {
        println!("\ncould not write {} (read-only fs?)", path.display());
    }
}
