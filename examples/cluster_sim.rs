//! End-to-end driver: the full system on a real (synthetic-Google)
//! workload — trace generation, cluster sampling from Table I, all
//! three schedulers, the XLA-accelerated picker when artifacts are
//! present, and the paper's headline metrics.
//!
//! ```bash
//! make artifacts && cargo run --release --example cluster_sim
//! ```
//!
//! This is the repository's E2E validation (see EXPERIMENTS.md): it
//! proves the three layers compose — the Rust coordinator replays a
//! 24-hour-scaled trace, and the same decisions flow through the
//! AOT-compiled Pallas/JAX kernels via PJRT.

use drfh::cluster::Cluster;
use drfh::experiments::EvalSetup;
use drfh::runtime::{artifacts_available, backend_available, XlaRuntime};
use drfh::sched::{BestFitDrfh, FirstFitDrfh, SlotsScheduler, XlaBestFit};
use drfh::sim::{run, SimOpts};
use drfh::util::Pcg32;
use drfh::workload::{GoogleLikeConfig, TraceGenerator};
use std::sync::Arc;
use std::time::Instant;

fn main() {
    // A 400-server / 40-user / 4-hour slice of the paper's setup —
    // large enough to show the utilization gap, small enough to finish
    // in seconds. Scale up with `drfh exp fig5 --servers 2000`.
    let setup = EvalSetup::with_duration(42, 400, 40, 14_400.0);
    println!(
        "cluster: {} servers ({} classes), total {:.1} CPU / {:.1} mem units",
        setup.cluster.len(),
        setup.cluster.classes().len(),
        setup.cluster.total_capacity()[0],
        setup.cluster.total_capacity()[1],
    );
    println!(
        "trace: {} users, {} jobs, {} tasks over {:.0} s\n",
        setup.trace.users.len(),
        setup.trace.jobs.len(),
        setup.trace.total_tasks(),
        setup.opts.horizon,
    );

    println!(
        "{:<18} {:>9} {:>9} {:>10} {:>10} {:>9}",
        "scheduler", "CPU util", "mem util", "tasks", "jobs", "wall"
    );
    let mut rows = Vec::new();
    let schedulers: Vec<(&str, Box<dyn drfh::sched::Scheduler>)> = vec![
        ("bestfit-drfh", Box::new(BestFitDrfh::default())),
        ("firstfit-drfh", Box::new(FirstFitDrfh::default())),
        ("slots-14", Box::new(SlotsScheduler::new(&setup.cluster, 14))),
    ];
    for (name, sched) in schedulers {
        let t0 = Instant::now();
        let r = run(
            setup.cluster.clone(),
            &setup.trace,
            sched,
            setup.opts.clone(),
        );
        let wall = t0.elapsed();
        println!(
            "{:<18} {:>8.1}% {:>8.1}% {:>10} {:>10} {:>8.2}s",
            name,
            r.avg_cpu_util * 100.0,
            r.avg_mem_util * 100.0,
            r.tasks_completed,
            r.jobs.len(),
            wall.as_secs_f64()
        );
        rows.push((name.to_string(), r));
    }

    // headline: DRFH vs slots utilization and completed work
    let bf = &rows[0].1;
    let slots = &rows[2].1;
    println!(
        "\nheadline: Best-Fit DRFH vs Slots — CPU {:.1}% vs {:.1}% \
         ({:+.0}% relative), tasks {} vs {} ({:+.0}%)",
        bf.avg_cpu_util * 100.0,
        slots.avg_cpu_util * 100.0,
        (bf.avg_cpu_util / slots.avg_cpu_util - 1.0) * 100.0,
        bf.tasks_completed,
        slots.tasks_completed,
        (bf.tasks_completed as f64 / slots.tasks_completed.max(1) as f64
            - 1.0)
            * 100.0,
    );

    // XLA path: same policy, decisions computed by the AOT kernels
    if backend_available() && artifacts_available() {
        println!("\n-- XLA-accelerated picker (AOT Pallas/JAX via PJRT) --");
        let rt = Arc::new(XlaRuntime::load_default().expect("artifacts"));
        let mut rng = Pcg32::seeded(9);
        let cluster = Cluster::google_sample(120, &mut rng);
        let gen = TraceGenerator::new(GoogleLikeConfig {
            users: 12,
            duration: 3_600.0,
            jobs_per_user: 8.0,
            max_tasks_per_job: 100,
            ..Default::default()
        });
        let trace = gen.generate(3);
        let opts = SimOpts {
            horizon: 3_600.0,
            sample_dt: 60.0,
            track_user_series: false,
            ..SimOpts::default()
        };
        let t0 = Instant::now();
        let native = run(
            cluster.clone(),
            &trace,
            Box::new(BestFitDrfh::default()),
            opts.clone(),
        );
        let t_native = t0.elapsed();
        let t0 = Instant::now();
        let xla = run(
            cluster,
            &trace,
            Box::new(XlaBestFit::new(rt)),
            opts,
        );
        let t_xla = t0.elapsed();
        println!(
            "native: {} placements in {:.2}s; XLA: {} placements in {:.2}s",
            native.tasks_placed,
            t_native.as_secs_f64(),
            xla.tasks_placed,
            t_xla.as_secs_f64()
        );
        let diff =
            (native.tasks_placed as i64 - xla.tasks_placed as i64).abs();
        assert!(diff <= 2, "native and XLA schedules diverged");
        println!("decision parity: OK (Δplacements = {diff})");
    } else {
        println!("\n(artifacts/ missing — run `make artifacts` to exercise the XLA path)");
    }
}
