//! Simulation metrics: everything the paper's evaluation section plots.

use crate::util::stats;

/// A sampled time series (e.g. utilization over time, Fig. 5).
#[derive(Clone, Debug, Default)]
pub struct TimeSeries {
    pub t: Vec<f64>,
    pub v: Vec<f64>,
}

impl TimeSeries {
    pub fn push(&mut self, t: f64, v: f64) {
        self.t.push(t);
        self.v.push(v);
    }

    pub fn len(&self) -> usize {
        self.t.len()
    }

    pub fn is_empty(&self) -> bool {
        self.t.is_empty()
    }

    /// Time-weighted average over the sampled horizon.
    pub fn time_avg(&self) -> f64 {
        if self.t.len() < 2 {
            return stats::mean(&self.v);
        }
        let mut area = 0.0;
        for i in 1..self.t.len() {
            area += self.v[i - 1] * (self.t[i] - self.t[i - 1]);
        }
        let span = self.t[self.t.len() - 1] - self.t[0];
        if span > 0.0 {
            area / span
        } else {
            stats::mean(&self.v)
        }
    }

    /// Average of samples within [lo, hi].
    pub fn window_avg(&self, lo: f64, hi: f64) -> f64 {
        let vals: Vec<f64> = self
            .t
            .iter()
            .zip(&self.v)
            .filter(|(&t, _)| t >= lo && t <= hi)
            .map(|(_, &v)| v)
            .collect();
        stats::mean(&vals)
    }
}

/// A completed job record.
#[derive(Clone, Debug)]
pub struct JobRecord {
    pub job: usize,
    pub user: usize,
    pub num_tasks: usize,
    pub submit: f64,
    pub finish: f64,
}

impl JobRecord {
    pub fn completion_time(&self) -> f64 {
        self.finish - self.submit
    }
}

/// Per-user task accounting for completion-ratio figures (Fig. 7/8).
#[derive(Clone, Debug, Default)]
pub struct UserTaskCounts {
    pub submitted: usize,
    pub completed: usize,
}

impl UserTaskCounts {
    pub fn ratio(&self) -> f64 {
        if self.submitted == 0 {
            1.0
        } else {
            self.completed as f64 / self.submitted as f64
        }
    }
}

/// Job-size buckets used by Fig. 6b.
pub const JCT_BUCKETS: [(usize, usize); 5] =
    [(1, 10), (11, 50), (51, 100), (101, 500), (501, usize::MAX)];

/// Label for a Fig. 6b bucket.
pub fn bucket_label(b: (usize, usize)) -> String {
    if b.1 == usize::MAX {
        format!(">{}", b.0 - 1)
    } else {
        format!("{}-{}", b.0, b.1)
    }
}

/// Mean completion-time reduction of `ours` vs `base` per job-size
/// bucket, over jobs completed in both (paper Fig. 6b methodology).
pub fn jct_reduction_by_bucket(
    ours: &[JobRecord],
    base: &[JobRecord],
) -> Vec<(String, f64, usize)> {
    use std::collections::HashMap;
    let by_id: HashMap<usize, &JobRecord> =
        base.iter().map(|j| (j.job, j)).collect();
    JCT_BUCKETS
        .iter()
        .map(|&(lo, hi)| {
            let mut reductions = Vec::new();
            for j in ours {
                if j.num_tasks < lo || j.num_tasks > hi {
                    continue;
                }
                if let Some(b) = by_id.get(&j.job) {
                    let ours_t = j.completion_time();
                    let base_t = b.completion_time();
                    if base_t > 0.0 {
                        reductions.push(1.0 - ours_t / base_t);
                    }
                }
            }
            (
                bucket_label((lo, hi)),
                stats::mean(&reductions),
                reductions.len(),
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_avg_weighted() {
        let mut ts = TimeSeries::default();
        ts.push(0.0, 1.0);
        ts.push(1.0, 0.0); // value 1.0 held for [0,1)
        ts.push(3.0, 0.0); // value 0.0 held for [1,3)
        assert!((ts.time_avg() - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn window_avg_filters() {
        let mut ts = TimeSeries::default();
        for i in 0..10 {
            ts.push(i as f64, i as f64);
        }
        assert!((ts.window_avg(5.0, 9.0) - 7.0).abs() < 1e-12);
    }

    #[test]
    fn completion_ratio() {
        let c = UserTaskCounts { submitted: 4, completed: 3 };
        assert!((c.ratio() - 0.75).abs() < 1e-12);
        assert_eq!(UserTaskCounts::default().ratio(), 1.0);
    }

    #[test]
    fn buckets_and_reduction() {
        let ours = vec![JobRecord {
            job: 0,
            user: 0,
            num_tasks: 5,
            submit: 0.0,
            finish: 50.0,
        }];
        let base = vec![JobRecord {
            job: 0,
            user: 0,
            num_tasks: 5,
            submit: 0.0,
            finish: 100.0,
        }];
        let red = jct_reduction_by_bucket(&ours, &base);
        assert_eq!(red[0].2, 1);
        assert!((red[0].1 - 0.5).abs() < 1e-12);
        assert_eq!(red[1].2, 0);
        assert_eq!(bucket_label((501, usize::MAX)), ">500");
    }
}
