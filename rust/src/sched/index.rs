//! Incremental scheduling index: the sublinear placement hot path.
//!
//! The naive DRFH policies pay O(n + k·m) per decision — rescan every
//! user for the minimum weighted dominant share, rescan every server
//! for the best feasible fit — and the engine pays O(n) more per
//! completion to re-check blocked users. Over a day-long Google-trace
//! run (Fig. 5: k = 2,000 servers, hundreds of thousands of
//! placements) those linear scans dominate wall-clock. This module
//! replaces them with incrementally maintained structures, while
//! keeping every *decision* bit-identical to the linear scans (proved
//! by `tests/engine_parity.rs`):
//!
//! * [`ShareHeap`] — a lazy min-heap over weighted dominant-share keys
//!   `(share_key, user)`. O(log n) amortized per update instead of an
//!   O(n) rescan per pick.
//! * [`ServerIndex`] — servers bucketed by capacity class with a lazy
//!   per-class per-resource *max-free skyline*: a sound upper bound on
//!   available capacity used to skip entire classes during rebuilds
//!   and feasibility pre-checks.
//! * [`PlacementIndex`] — lazy min-heaps over feasible-server keys
//!   (Best-Fit H-score or First-Fit index), kept per *demand class*
//!   ([`crate::sched::users::DemandClasses`]): scores depend on the
//!   demand vector alone, so users sharing a row share one heap. A
//!   cluster mutation touches one server, so maintaining the heaps
//!   costs O(C·m) score probes + O(log k) pushes for the (few)
//!   classes the server still fits — C distinct classes, not n users
//!   — instead of every subsequent pick paying O(k·m).
//!   [`PlacementIndex::per_user`] keeps the PR 1 one-heap-per-user
//!   layout as the reference.
//! * [`BlockedIndex`] — blocked users grouped by demand class and
//!   keyed by the class's minimum demand component, so a completion
//!   re-checks one representative per candidate class (classes whose
//!   smallest requirement fits under the freed server's smallest
//!   headroom — a necessary condition for fitting), not every blocked
//!   user.
//!
//! ## Invariants
//!
//! 1. *Lazy heap freshness*: every heap entry carries the stamp of the
//!    (user|server) it was pushed for; an entry is live iff its stamp
//!    matches the current stamp. Mutating a key bumps the stamp and
//!    (when still relevant) pushes a fresh entry; stale entries are
//!    discarded on pop. Each live element has exactly one live entry.
//! 2. *Score identity*: indexed and naive paths share the scoring
//!    arithmetic ([`score_server`]) and compare keys lexicographically
//!    by `(key, index)` with `f64::total_cmp`, so argmins — including
//!    tie-breaks — are identical.
//! 3. *Skyline soundness*: `ServerIndex` bounds satisfy
//!    `max_free[c][r] >= max_{l in class c} (capacity_lr - usage_lr)`
//!    at every refresh point (commits only lower true availability;
//!    releases are folded in via [`ServerIndex::note_avail`]), so a
//!    class pruned by the skyline truly contains no fitting server.
//! 4. *Blocked-key necessity*: if a task with demand D fits a server
//!    with availability A (componentwise D ≤ A + ε), then
//!    `min_r D_r ≤ min_r A_r + ε`; filtering blocked users by that key
//!    never skips one that could fit.

use crate::cluster::{Cluster, ResVec, Server, ShardSpec, FIT_EPS, MAX_RES};
use crate::sched::users::{ClassedShareIndex, DemandClasses};
use crate::sched::{DrainCtx, Pick, UserState};
use std::cmp::Ordering;
use std::collections::{BTreeSet, BinaryHeap};

// ------------------------------------------------------------ heap entry

/// Heap entry ordered ascending by `(key, idx)`; `stamp` carries the
/// lazy-invalidation epoch and does not participate in the order.
#[derive(Clone, Copy, Debug)]
struct MinEntry {
    key: f64,
    idx: u32,
    stamp: u64,
}

impl PartialEq for MinEntry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for MinEntry {}
impl PartialOrd for MinEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for MinEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed: BinaryHeap is a max-heap, we want the smallest
        // (key, idx) on top
        other
            .key
            .total_cmp(&self.key)
            .then_with(|| other.idx.cmp(&self.idx))
    }
}

// ------------------------------------------------------------ ShareHeap

/// Lazy min-heap over weighted dominant-share keys.
///
/// Mirrors [`super::min_share_user`] exactly: among users with
/// `eligible[u] && pending > 0`, the one with the smallest
/// `share_key()`, lowest index on ties.
#[derive(Default)]
pub struct ShareHeap {
    heap: BinaryHeap<MinEntry>,
    stamp: Vec<u64>,
    dirty: Vec<u32>,
    is_dirty: Vec<bool>,
}

impl ShareHeap {
    pub fn new() -> Self {
        Self::default()
    }

    fn grow(&mut self, n: usize) {
        while self.stamp.len() < n {
            let u = self.stamp.len() as u32;
            self.stamp.push(0);
            self.is_dirty.push(true);
            self.dirty.push(u);
        }
    }

    /// Note that `u`'s key or schedulability may have changed; the
    /// next [`ShareHeap::refresh`] re-inserts it.
    pub fn mark_dirty(&mut self, u: usize) {
        if u >= self.stamp.len() {
            self.grow(u + 1);
            return;
        }
        if !self.is_dirty[u] {
            self.is_dirty[u] = true;
            self.dirty.push(u as u32);
        }
    }

    /// Drop `u` from the heap (lazy): its entries become stale. Used
    /// when a user is blocked; it re-enters via [`ShareHeap::mark_dirty`].
    pub fn remove(&mut self, u: usize) {
        if u < self.stamp.len() {
            self.stamp[u] += 1;
        }
    }

    /// Flush dirty users: bump their stamp and push a fresh entry for
    /// those currently schedulable.
    pub fn refresh(&mut self, users: &[UserState], eligible: &[bool]) {
        self.refresh_with(users, eligible, UserState::share_key);
    }

    /// [`ShareHeap::refresh`] under a caller-chosen key. The heap is
    /// key-agnostic — the DRFH policies rank by `share_key`, the Slots
    /// baseline by weighted running-slot count — but one instance must
    /// be fed a single key function for its whole life (mixed keys
    /// would interleave incomparable entries).
    pub fn refresh_with(
        &mut self,
        users: &[UserState],
        eligible: &[bool],
        key: impl Fn(&UserState) -> f64,
    ) {
        self.grow(users.len());
        while let Some(u) = self.dirty.pop() {
            let u = u as usize;
            self.is_dirty[u] = false;
            self.stamp[u] += 1;
            if eligible[u] && users[u].pending > 0 {
                self.heap.push(MinEntry {
                    key: key(&users[u]),
                    idx: u as u32,
                    stamp: self.stamp[u],
                });
            }
        }
        self.compact();
    }

    /// Re-key `u` mid-drain, right after the engine committed its
    /// placement: equivalent to `mark_dirty(u)` + `refresh`, minus the
    /// dirty-list bookkeeping (the wave's opening refresh already ran,
    /// so nothing else is dirty). `schedulable` is the caller's read
    /// of `eligible[u] && pending > 0` post-commit.
    pub fn reinsert(&mut self, u: usize, key: f64, schedulable: bool) {
        debug_assert!(u < self.stamp.len(), "reinsert before refresh");
        self.stamp[u] += 1;
        if schedulable {
            self.heap.push(MinEntry {
                key,
                idx: u as u32,
                stamp: self.stamp[u],
            });
        }
        self.compact();
    }

    /// Drop stale entries once the heap outgrows the live set.
    fn compact(&mut self) {
        if self.heap.len() > 4 * self.stamp.len() + 64 {
            let stamp = &self.stamp;
            self.heap.retain(|e| e.stamp == stamp[e.idx as usize]);
        }
    }

    /// Current minimum-key schedulable user (the entry stays in the
    /// heap). Call [`ShareHeap::refresh`] first.
    pub fn peek_min(
        &mut self,
        users: &[UserState],
        eligible: &[bool],
    ) -> Option<usize> {
        while let Some(top) = self.heap.peek() {
            let u = top.idx as usize;
            if top.stamp == self.stamp[u] {
                if eligible[u] && users[u].pending > 0 {
                    return Some(u);
                }
                // entry is fresh but the user is no longer
                // schedulable (defensive): drop it; the engine's
                // on_ready notification re-inserts it later
                self.stamp[u] += 1;
            }
            self.heap.pop();
        }
        None
    }
}

// ----------------------------------------------------------- ServerIndex

/// A capacity class: servers with identical capacity vectors, plus a
/// lazy per-resource upper bound on their free capacity.
#[derive(Clone, Debug)]
pub struct ClassBucket {
    pub capacity: ResVec,
    pub members: Vec<u32>,
    max_free: [f64; MAX_RES],
}

impl ClassBucket {
    /// Could *some* member fit `demand`? Sound: never false when a
    /// member fits (invariant 3); may be true when none does.
    pub fn may_fit(&self, demand: &ResVec) -> bool {
        (0..demand.dims()).all(|r| demand[r] <= self.max_free[r] + FIT_EPS)
    }

    /// Current skyline bound for resource `r` (testing hook).
    pub fn max_free(&self, r: usize) -> f64 {
        self.max_free[r]
    }
}

/// Class-bucketed server availability summary (the max-free skyline).
pub struct ServerIndex {
    classes: Vec<ClassBucket>,
    class_of: Vec<u32>,
    updates: usize,
    refresh_every: usize,
}

impl ServerIndex {
    /// Group `cluster`'s servers by identical capacity and compute the
    /// exact skyline.
    pub fn build(cluster: &Cluster) -> Self {
        let mut class_of = vec![0u32; cluster.len()];
        let classes: Vec<ClassBucket> = cluster
            .class_members()
            .into_iter()
            .enumerate()
            .map(|(c, (capacity, members))| {
                for &l in &members {
                    class_of[l as usize] = c as u32;
                }
                ClassBucket { capacity, members, max_free: [0.0; MAX_RES] }
            })
            .collect();
        let mut idx = ServerIndex {
            classes,
            class_of,
            updates: 0,
            refresh_every: 8 * cluster.len().max(8),
        };
        idx.recompute(cluster);
        idx
    }

    pub fn classes(&self) -> &[ClassBucket] {
        &self.classes
    }

    pub fn class_of(&self, l: usize) -> usize {
        self.class_of[l] as usize
    }

    /// Fold server `l`'s current availability into its class bound.
    /// Commits leave the bound stale-high (sound); periodically the
    /// exact skyline is recomputed to restore tightness.
    pub fn note_avail(&mut self, cluster: &Cluster, l: usize) {
        let s = &cluster.servers[l];
        let c = self.class_of[l] as usize;
        for r in 0..s.capacity.dims() {
            let a = s.headroom(r);
            if a > self.classes[c].max_free[r] {
                self.classes[c].max_free[r] = a;
            }
        }
        self.updates += 1;
        if self.updates >= self.refresh_every {
            self.recompute(cluster);
        }
    }

    fn recompute(&mut self, cluster: &Cluster) {
        self.updates = 0;
        for c in self.classes.iter_mut() {
            c.max_free = [0.0; MAX_RES];
            for &l in &c.members {
                let s = &cluster.servers[l as usize];
                for r in 0..s.capacity.dims() {
                    let a = s.headroom(r);
                    if a > c.max_free[r] {
                        c.max_free[r] = a;
                    }
                }
            }
        }
    }

    /// Sound feasibility pre-check across the whole pool.
    pub fn may_fit_anywhere(&self, demand: &ResVec) -> bool {
        self.classes.iter().any(|c| c.may_fit(demand))
    }
}

// -------------------------------------------------------------- scoring

/// Which key the placement index minimizes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScoreKind {
    /// Best-Fit DRFH: key = H(i, l) (paper eq. (9)), ties by index.
    BestFit,
    /// First-Fit DRFH: key = server index.
    FirstFit,
}

/// Per-user demand ratios relative to resource 0 — the hoisted half of
/// the H-score (paper eq. (9)).
pub fn dratio_of(demand: &ResVec) -> [f64; MAX_RES] {
    let m = demand.dims();
    let dden = if demand[0] != 0.0 { demand[0] } else { 1.0 };
    let mut dr = [0.0f64; MAX_RES];
    for r in 0..m {
        dr[r] = demand[r] / dden;
    }
    dr
}

/// Score server `l` for a demand: `None` when the task does not fit,
/// `Some(key)` otherwise. The arithmetic (including the FIT_EPS
/// feasibility predicate and the avail/aden guards) is shared with the
/// naive scans so indexed argmins are bit-identical (invariant 2).
pub fn score_server(
    kind: ScoreKind,
    demand: &ResVec,
    dratio: &[f64; MAX_RES],
    s: &Server,
    l: usize,
) -> Option<f64> {
    let m = demand.dims();
    match kind {
        ScoreKind::FirstFit => {
            if s.fits(demand) {
                Some(l as f64)
            } else {
                None
            }
        }
        ScoreKind::BestFit => {
            let mut avail = [0.0f64; MAX_RES];
            for r in 0..m {
                let a = s.headroom(r);
                if demand[r] > a + FIT_EPS {
                    return None; // does not fit
                }
                avail[r] = if a > 0.0 { a } else { 0.0 };
            }
            let aden = if avail[0] != 0.0 { avail[0] } else { 1.0 };
            let mut h = 0.0;
            for r in 0..m {
                h += (dratio[r] - avail[r] / aden).abs();
            }
            Some(h)
        }
    }
}

// --------------------------------------------------------- PlacementIndex

/// Lazy min-heaps over feasible-server keys — one per demand *class*
/// (§Perf: feasibility and both [`ScoreKind`] keys are functions of
/// the demand vector alone, so every user of a class shares one heap
/// and one dirty-rescore; per-event maintenance is O(classes), not
/// O(users)) — maintained incrementally from place/complete
/// notifications. [`PlacementIndex::per_user`] disables the interning
/// (one class per user) to reproduce the PR 1 per-user layout.
///
/// Under the engine's sharded data plane
/// ([`PlacementIndex::set_shards`]) each class keeps one heap per
/// server-pool shard, so a server rescore touches only its owner
/// shard's heaps; [`PlacementIndex::best_server`] reconciles the
/// per-shard minima with a cross-shard argmin under the same
/// `(key, index)` total order a single heap would use, so selections
/// are shard-count independent (the partition of a set's minimum is
/// the minimum of the partitions' minima).
pub struct PlacementIndex {
    kind: ScoreKind,
    /// Share heaps between users with bit-identical demand rows?
    intern: bool,
    servers: Option<ServerIndex>,
    /// Demand class per user (identity map under `per_user`).
    class_of: Vec<u32>,
    /// Distinct demand rows, by class id.
    class_demand: Vec<ResVec>,
    /// One heap per `(class, shard)` pair, at `class * shards + shard`
    /// (a single heap per class when unsharded).
    heaps: Vec<BinaryHeap<MinEntry>>,
    /// Requested shard count (applied at the next build).
    nshards: usize,
    /// The shard layout the heaps were built for.
    spec: ShardSpec,
    stamp: Vec<u64>,
    dirty: Vec<u32>,
    is_dirty: Vec<bool>,
    /// Hoisted H-score ratios, by class id.
    dratio: Vec<[f64; MAX_RES]>,
    k: usize,
    n_users: usize,
    /// Debug-only guard against reusing one index across different
    /// same-sized clusters/user sets (see [`IndexedCore`] ownership).
    #[cfg(debug_assertions)]
    fingerprint: f64,
    /// The engine legitimately edited capacity in place (fault layer:
    /// zero on crash, restore on recovery) — re-baseline the
    /// fingerprint instead of flagging reuse.
    #[cfg(debug_assertions)]
    fingerprint_dirty: bool,
}

/// Capacity+demand fingerprint for the debug reuse guard. Usage is
/// deliberately excluded — it changes during a run.
#[cfg(debug_assertions)]
fn state_fingerprint(cluster: &Cluster, users: &[UserState]) -> f64 {
    let mut f = 0.0;
    for s in &cluster.servers {
        f += s.capacity.sum();
    }
    for u in users {
        f += u.demand.sum() * 1e-3;
    }
    f
}

impl PlacementIndex {
    /// Class-keyed index (the default): users sharing a demand row
    /// share heaps and rescores.
    pub fn new(kind: ScoreKind) -> Self {
        Self::with_interning(kind, true)
    }

    /// One class per user — the PR 1 per-user layout, kept as the
    /// scaling baseline (`benches/user_scale.rs`).
    pub fn per_user(kind: ScoreKind) -> Self {
        Self::with_interning(kind, false)
    }

    fn with_interning(kind: ScoreKind, intern: bool) -> Self {
        PlacementIndex {
            kind,
            intern,
            servers: None,
            class_of: Vec::new(),
            class_demand: Vec::new(),
            heaps: Vec::new(),
            nshards: 1,
            spec: ShardSpec::contiguous(0, 1),
            stamp: Vec::new(),
            dirty: Vec::new(),
            is_dirty: Vec::new(),
            dratio: Vec::new(),
            k: 0,
            n_users: 0,
            #[cfg(debug_assertions)]
            fingerprint: 0.0,
            #[cfg(debug_assertions)]
            fingerprint_dirty: false,
        }
    }

    /// Distinct demand classes the index maintains heaps for
    /// (testing / diagnostics; equals the user count under
    /// [`PlacementIndex::per_user`]).
    pub fn class_count(&self) -> usize {
        self.class_demand.len()
    }

    /// Mirror the engine's shard layout: one heap per
    /// `(demand class, shard)` pair, reconciled by the cross-shard
    /// argmin in [`PlacementIndex::best_server`]. Selections are
    /// shard-count independent, so this is locality-only; the engine
    /// wires it once, before any event, through
    /// [`crate::sched::Scheduler::on_topology`]. Changing the count
    /// after a build forces a rebuild at the next refresh.
    pub fn set_shards(&mut self, shards: usize) {
        let shards = shards.max(1);
        if shards != self.nshards {
            self.nshards = shards;
            self.servers = None; // rebuild under the new layout
        }
    }

    /// The shard count the heaps are currently laid out for
    /// (testing / diagnostics).
    pub fn shard_count(&self) -> usize {
        self.spec.shards()
    }

    /// Note that server `l`'s availability changed; the next
    /// [`PlacementIndex::refresh`] re-scores it for every user.
    pub fn mark_server_dirty(&mut self, l: usize) {
        if self.servers.is_none() || l >= self.is_dirty.len() {
            return; // not built yet — the full build covers it
        }
        if !self.is_dirty[l] {
            self.is_dirty[l] = true;
            self.dirty.push(l as u32);
        }
    }

    /// The engine edited server *capacity* in place (fault layer:
    /// zeroed on crash, restored on recovery). Feasibility and score
    /// updates ride the normal dirty path
    /// ([`PlacementIndex::mark_server_dirty`]); this only re-baselines
    /// the debug-build reuse fingerprint, which would otherwise read
    /// the edit as "a different cluster".
    pub fn note_capacity_edit(&mut self) {
        #[cfg(debug_assertions)]
        {
            self.fingerprint_dirty = true;
        }
    }

    fn ensure_built(&mut self, cluster: &Cluster, users: &[UserState]) {
        if self.servers.is_some()
            && self.k == cluster.len()
            && self.n_users == users.len()
        {
            #[cfg(debug_assertions)]
            {
                if self.fingerprint_dirty {
                    self.fingerprint_dirty = false;
                    self.fingerprint = state_fingerprint(cluster, users);
                }
                debug_assert!(
                    (self.fingerprint - state_fingerprint(cluster, users))
                        .abs()
                        < 1e-9,
                    "PlacementIndex reused across a different cluster/user \
                     set; construct a fresh policy per simulation"
                );
            }
            return;
        }
        let k = cluster.len();
        self.k = k;
        self.n_users = users.len();
        self.servers = Some(ServerIndex::build(cluster));
        self.spec = ShardSpec::contiguous(k, self.nshards);
        self.stamp = vec![0; k];
        self.is_dirty = vec![false; k];
        self.dirty.clear();
        let classes = if self.intern {
            DemandClasses::build(users)
        } else {
            DemandClasses::identity(users)
        };
        self.dratio = classes.rows.iter().map(dratio_of).collect();
        let ns = self.spec.shards();
        self.heaps = (0..classes.rows.len() * ns)
            .map(|_| BinaryHeap::new())
            .collect();
        self.class_of = classes.class_of;
        self.class_demand = classes.rows;
        #[cfg(debug_assertions)]
        {
            self.fingerprint = state_fingerprint(cluster, users);
        }
        for c in 0..self.class_demand.len() {
            self.rebuild_class(cluster, c);
        }
    }

    /// Rebuild demand class `c`'s heaps (all of its shards) from
    /// scratch, visiting only server classes the skyline says could
    /// fit (invariant 3 makes the skip sound).
    fn rebuild_class(&mut self, cluster: &Cluster, c: usize) {
        let ns = self.spec.shards();
        let mut heaps = std::mem::take(&mut self.heaps);
        for heap in &mut heaps[c * ns..(c + 1) * ns] {
            heap.clear();
        }
        let demand = self.class_demand[c];
        let sidx = self.servers.as_ref().expect("built");
        for class in sidx.classes() {
            if !class.may_fit(&demand) {
                continue;
            }
            for &l in &class.members {
                let l = l as usize;
                if let Some(key) = score_server(
                    self.kind,
                    &demand,
                    &self.dratio[c],
                    &cluster.servers[l],
                    l,
                ) {
                    heaps[c * ns + self.spec.owner_of(l)].push(MinEntry {
                        key,
                        idx: l as u32,
                        stamp: self.stamp[l],
                    });
                }
            }
        }
        self.heaps = heaps;
    }

    /// Flush dirty servers: bump their stamp, fold the new availability
    /// into the skyline, and push fresh entries for users they still
    /// fit. Must run (via the owning policy's `pick`) after any
    /// commit/release and before the next [`PlacementIndex::best_server`].
    pub fn refresh(&mut self, cluster: &Cluster, users: &[UserState]) {
        self.ensure_built(cluster, users);
        let had_dirt = !self.dirty.is_empty();
        while let Some(l) = self.dirty.pop() {
            let l = l as usize;
            self.is_dirty[l] = false;
            self.rescore_one(cluster, users, l);
        }
        if had_dirt {
            self.compact(cluster, users);
        }
    }

    /// Re-score server `l` mid-drain, right after the engine committed
    /// a placement onto it: equivalent to `mark_server_dirty(l)` +
    /// `refresh`, minus the dirty-flag bookkeeping (the wave's opening
    /// refresh already ran, so no other server is dirty). Requires a
    /// preceding [`PlacementIndex::refresh`] to have built the index.
    pub fn rescore_server(
        &mut self,
        cluster: &Cluster,
        users: &[UserState],
        l: usize,
    ) {
        debug_assert!(
            self.servers.is_some() && l < self.stamp.len(),
            "rescore_server before refresh"
        );
        self.rescore_one(cluster, users, l);
        self.compact(cluster, users);
    }

    /// Bump `l`'s stamp, fold its availability into the skyline, and
    /// push fresh entries for every demand *class* it still fits —
    /// O(classes·m) score probes per touched server, however many
    /// users share those classes.
    fn rescore_one(
        &mut self,
        cluster: &Cluster,
        _users: &[UserState],
        l: usize,
    ) {
        self.stamp[l] += 1;
        self.servers
            .as_mut()
            .expect("built")
            .note_avail(cluster, l);
        let srv = &cluster.servers[l];
        let stamp = self.stamp[l];
        let ns = self.spec.shards();
        let owner = self.spec.owner_of(l);
        for (c, demand) in self.class_demand.iter().enumerate() {
            if let Some(key) =
                score_server(self.kind, demand, &self.dratio[c], srv, l)
            {
                self.heaps[c * ns + owner].push(MinEntry {
                    key,
                    idx: l as u32,
                    stamp,
                });
            }
        }
    }

    /// Rebuild any class whose per-shard heap has outgrown its shard's
    /// live set.
    fn compact(&mut self, cluster: &Cluster, _users: &[UserState]) {
        let ns = self.spec.shards();
        for c in 0..self.class_demand.len() {
            for s in 0..ns {
                if self.heaps[c * ns + s].len()
                    > 2 * self.spec.len_of(s) + 64
                {
                    self.rebuild_class(cluster, c);
                    break;
                }
            }
        }
    }

    /// Online user add (churn layer): append one user with `demand`
    /// without rebuilding — intern the row against the existing demand
    /// classes by exact bit pattern (the
    /// [`DemandClasses`] discipline), allocating fresh per-shard heaps
    /// and a rebuild for a genuinely new row only. Before the first
    /// build this is a no-op (the build snapshots the full user set).
    /// Equivalent to tearing the index down and rebuilding over the
    /// extended user set (pinned by `tests/properties.rs`).
    pub fn add_user(&mut self, cluster: &Cluster, demand: &ResVec) {
        if self.servers.is_none() {
            return; // not built yet — the full build covers it
        }
        let bits = |d: &ResVec| {
            let mut b = [0u64; MAX_RES];
            for r in 0..d.dims() {
                b[r] = d[r].to_bits();
            }
            (d.dims(), b)
        };
        let want = bits(demand);
        let class = if self.intern {
            self.class_demand.iter().position(|row| bits(row) == want)
        } else {
            None // per-user layout: every user is its own class
        };
        let c = match class {
            Some(c) => c,
            None => {
                let c = self.class_demand.len();
                self.class_demand.push(*demand);
                self.dratio.push(dratio_of(demand));
                let ns = self.spec.shards();
                for _ in 0..ns {
                    self.heaps.push(BinaryHeap::new());
                }
                self.rebuild_class(cluster, c);
                c
            }
        };
        self.class_of.push(c as u32);
        self.n_users += 1;
        #[cfg(debug_assertions)]
        {
            self.fingerprint_dirty = true;
        }
    }

    /// Lowest-key feasible server for user `i` (looked up through
    /// `i`'s demand class; entries stay in their heaps), or `None`
    /// when nothing fits. Under sharding this is the cross-shard
    /// argmin over per-shard lazy minima, compared by `(key, index)`
    /// with `f64::total_cmp` — exactly the order one merged heap would
    /// pop in, so the selection (ties included) is shard-count
    /// independent. Requires a preceding [`PlacementIndex::refresh`].
    pub fn best_server(&mut self, i: usize) -> Option<usize> {
        let c = self.class_of[i] as usize;
        let ns = self.spec.shards();
        let mut best: Option<(f64, u32)> = None;
        for s in 0..ns {
            let heap = &mut self.heaps[c * ns + s];
            // lazy-pop this shard's heap down to its live minimum
            while let Some(top) = heap.peek() {
                if top.stamp == self.stamp[top.idx as usize] {
                    let earlier = match best {
                        None => true,
                        Some((bk, bi)) => top
                            .key
                            .total_cmp(&bk)
                            .then_with(|| top.idx.cmp(&bi))
                            .is_lt(),
                    };
                    if earlier {
                        best = Some((top.key, top.idx));
                    }
                    break;
                }
                heap.pop();
            }
        }
        best.map(|(_, l)| l as usize)
    }

    /// The class skyline (testing / diagnostics).
    pub fn server_index(&self) -> Option<&ServerIndex> {
        self.servers.as_ref()
    }
}

// ----------------------------------------------------------- IndexedCore

/// The user-selection half of [`IndexedCore`]: the class-keyed
/// aggregation ([`ClassedShareIndex`], the default) or the per-user
/// lazy heap ([`ShareHeap`], the PR 1 layout, kept as the scaling
/// baseline and parity reference). Decision streams are bit-identical
/// (`tests/engine_parity.rs`).
enum RankIndex {
    PerUser(ShareHeap),
    Classed(ClassedShareIndex),
}

impl RankIndex {
    fn mark_dirty(&mut self, u: usize) {
        match self {
            RankIndex::PerUser(h) => h.mark_dirty(u),
            RankIndex::Classed(c) => c.mark_dirty(u),
        }
    }

    fn remove(&mut self, u: usize) {
        match self {
            RankIndex::PerUser(h) => h.remove(u),
            RankIndex::Classed(c) => c.remove(u),
        }
    }

    fn refresh(&mut self, users: &[UserState], eligible: &[bool]) {
        match self {
            RankIndex::PerUser(h) => h.refresh(users, eligible),
            RankIndex::Classed(c) => c.refresh(users, eligible),
        }
    }

    fn peek_min(
        &mut self,
        users: &[UserState],
        eligible: &[bool],
    ) -> Option<usize> {
        match self {
            RankIndex::PerUser(h) => h.peek_min(users, eligible),
            RankIndex::Classed(c) => c.peek_min(users, eligible),
        }
    }

    /// Re-key `u` mid-drain, right after the engine committed its
    /// placement (the wave's opening refresh already ran, so nothing
    /// else is stale).
    fn rekey_after_place(
        &mut self,
        u: usize,
        users: &[UserState],
        eligible: &[bool],
    ) {
        match self {
            RankIndex::PerUser(h) => {
                let schedulable = eligible[u] && users[u].pending > 0;
                h.reinsert(u, users[u].share_key(), schedulable);
            }
            RankIndex::Classed(c) => c.resync(u, users, eligible),
        }
    }
}

/// The shared indexed decision core embedded in the DRFH policies:
/// a user-selection index ([`ClassedShareIndex`] by default,
/// [`ShareHeap`] under [`IndexedCore::per_user`]) + [`PlacementIndex`]
/// + the blocked-drop protocol.
/// Best-Fit and First-Fit differ only in the [`ScoreKind`] they
/// construct this with, so the parity-critical plumbing (refresh
/// ordering, the `remove`-on-Blocked step, the notification wiring)
/// lives in exactly one place.
///
/// Ownership: a core (and therefore a policy instance) serves ONE
/// cluster + user set; the demand ratios, classes and heaps snapshot
/// them on first use. Debug builds assert against reuse with a
/// different same-sized cluster/user set.
pub struct IndexedCore {
    share: RankIndex,
    servers: PlacementIndex,
}

impl IndexedCore {
    /// Class-keyed core (the default): user selection aggregates over
    /// `(dom_delta, weight)` groups and placement heaps are shared per
    /// demand class, so per-event work scales with distinct classes.
    pub fn new(kind: ScoreKind) -> Self {
        IndexedCore {
            share: RankIndex::Classed(ClassedShareIndex::new()),
            servers: PlacementIndex::new(kind),
        }
    }

    /// The PR 1 per-user layout ([`ShareHeap`] + one placement heap
    /// per user) — the scaling baseline of `benches/user_scale.rs`
    /// and the near-parity reference for the classed path.
    pub fn per_user(kind: ScoreKind) -> Self {
        IndexedCore {
            share: RankIndex::PerUser(ShareHeap::new()),
            servers: PlacementIndex::per_user(kind),
        }
    }

    /// Is this core on the class-keyed path?
    pub fn is_classed(&self) -> bool {
        matches!(self.share, RankIndex::Classed(_))
    }

    /// Mirror the engine's sharded data plane in the placement index
    /// ([`PlacementIndex::set_shards`]); wired from the policies'
    /// [`crate::sched::Scheduler::on_topology`]. Selections are
    /// shard-count independent.
    pub fn set_shards(&mut self, shards: usize) {
        self.servers.set_shards(shards);
    }

    /// One progressive-filling decision, decision-identical to
    /// `min_share_user` + the naive server scan of the same
    /// [`ScoreKind`].
    pub fn pick(
        &mut self,
        cluster: &Cluster,
        users: &[UserState],
        eligible: &[bool],
    ) -> Pick {
        self.share.refresh(users, eligible);
        self.servers.refresh(cluster, users);
        match self.share.peek_min(users, eligible) {
            None => Pick::Idle,
            Some(u) => match self.servers.best_server(u) {
                Some(l) => Pick::Place { user: u, server: l },
                None => {
                    // drop u from the heap until the engine unblocks
                    // it (on_ready)
                    self.share.remove(u);
                    Pick::Blocked { user: u }
                }
            },
        }
    }

    /// One batched event wave ([`crate::sched::Scheduler::drain`]):
    /// refresh the
    /// indexes once, then keep them current inline after each commit —
    /// re-key the placed user, re-score the touched server — instead
    /// of re-entering the dirty-flag machinery per decision. Each
    /// inline update is operation-for-operation what `mark_dirty` +
    /// `refresh` would have done for the single entity that changed,
    /// so the decision stream is identical to a [`IndexedCore::pick`]
    /// loop (asserted end-to-end by `tests/engine_parity.rs`).
    pub fn drain(&mut self, ctx: &mut dyn DrainCtx) {
        self.share.refresh(ctx.users(), ctx.eligible());
        self.servers.refresh(ctx.cluster(), ctx.users());
        loop {
            let Some(u) = self.share.peek_min(ctx.users(), ctx.eligible())
            else {
                return;
            };
            match self.servers.best_server(u) {
                Some(l) => {
                    ctx.place(u, l);
                    self.share.rekey_after_place(
                        u,
                        ctx.users(),
                        ctx.eligible(),
                    );
                    self.servers.rescore_server(ctx.cluster(), ctx.users(), l);
                }
                None => {
                    self.share.remove(u);
                    ctx.block(u);
                }
            }
        }
    }

    /// A task of `user` was placed on / completed at `server`: both
    /// the user's share key and the server's availability changed.
    pub fn on_touch(&mut self, user: usize, server: usize) {
        self.share.mark_dirty(user);
        self.servers.mark_server_dirty(server);
    }

    /// `user` (re-)entered the schedulable set.
    pub fn on_ready(&mut self, user: usize) {
        self.share.mark_dirty(user);
    }

    /// `user` joined (churn layer): re-key it in the selection index.
    /// The engine restored its eligibility before this fires, so a
    /// plain dirty-mark is enough — the next refresh reinserts it iff
    /// it is schedulable (pending work is announced separately via
    /// [`IndexedCore::on_ready`]). Placement/blocked structures key on
    /// the demand class, which survives absence, so nothing else moves.
    pub fn on_user_join(&mut self, user: usize) {
        self.share.mark_dirty(user);
    }

    /// `user` left (churn layer): drop its live selection entry. The
    /// engine already evicted its tasks (each firing
    /// [`IndexedCore::on_touch`]) and cleared its eligibility, so this
    /// mirrors the blocked-drop step — the entry goes stale now instead
    /// of riding a lazy pop later.
    pub fn on_user_leave(&mut self, user: usize) {
        self.share.remove(user);
    }

    /// `server` crashed (fault layer): by the next refresh its
    /// capacity reads zero, so the rescore finds it infeasible for
    /// every demand class and the stamp bump stales its live heap
    /// entries — the server drops out of every placement heap.
    pub fn on_server_down(&mut self, server: usize) {
        self.servers.mark_server_dirty(server);
        self.servers.note_capacity_edit();
    }

    /// `server` recovered: its restored capacity re-scores as
    /// feasible and the server re-enters the heaps it fits.
    pub fn on_server_up(&mut self, server: usize) {
        self.servers.mark_server_dirty(server);
        self.servers.note_capacity_edit();
    }

    /// Wave-boundary cross-check for [`crate::sim::audit`]: prove both
    /// halves of the core against fresh naive scans of the
    /// authoritative state — the share argmin against
    /// [`super::min_share_user`], and (when a user is selectable) the
    /// placement argmin against the naive server scan of the same
    /// [`ScoreKind`]. Decision-neutral: only the refreshes and lazy
    /// pops the next [`IndexedCore::pick`] would perform anyway, so
    /// audit-on runs stay bit-identical to audit-off runs.
    pub fn audit_check(
        &mut self,
        cluster: &Cluster,
        users: &[UserState],
        eligible: &[bool],
    ) -> Result<(), String> {
        self.share.refresh(users, eligible);
        self.servers.refresh(cluster, users);
        let got = self.share.peek_min(users, eligible);
        let want = super::min_share_user(users, eligible);
        if got != want {
            return Err(format!(
                "share index argmin {got:?} != naive min_share_user {want:?}"
            ));
        }
        if let Some(u) = got {
            let got_l = self.servers.best_server(u);
            let want_l = match self.servers.kind {
                ScoreKind::BestFit => {
                    super::best_fit::best_server(cluster, &users[u].demand)
                }
                ScoreKind::FirstFit => {
                    super::first_fit::first_server(cluster, &users[u].demand)
                }
            };
            if got_l != want_l {
                return Err(format!(
                    "placement index best_server({u}) = {got_l:?} \
                     != naive scan {want_l:?}"
                ));
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------- BlockedIndex

/// Total-order f64 wrapper for BTree keys.
#[derive(Clone, Copy, Debug, PartialEq)]
struct F64Ord(f64);

impl Eq for F64Ord {}
impl PartialOrd for F64Ord {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for F64Ord {
    fn cmp(&self, other: &Self) -> Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// Blocked users grouped by demand class and keyed by the class's
/// minimum demand component, so a freed server re-checks only classes
/// that could possibly fit (invariant 4) — and, since the exact
/// [`crate::sched::Scheduler::can_fit`] verdict depends on the user
/// only through its demand class, one probe per candidate class
/// decides every blocked member at once
/// ([`BlockedIndex::candidate_classes`] /
/// [`BlockedIndex::class_members`]).
///
/// [`BlockedIndex::new`] builds the degenerate one-class-per-user
/// layout (the seed semantics); the engine constructs the shared
/// layout from the trace's interned
/// [`crate::workload::DemandTable`] via [`BlockedIndex::classed`].
pub struct BlockedIndex {
    /// Fit key (`min_r demand_r`) per class.
    key: Vec<f64>,
    /// Demand class per user.
    class_of: Vec<u32>,
    /// Blocked members per class.
    members: Vec<BTreeSet<u32>>,
    /// Classes with at least one blocked member, by fit key.
    set: BTreeSet<(F64Ord, u32)>,
    flags: Vec<bool>,
    len: usize,
}

impl BlockedIndex {
    /// Per-user layout: `fit_key[u]` = `min_r demand_ur` — the
    /// necessary-condition key — with each user its own class.
    pub fn new(fit_key: Vec<f64>) -> Self {
        let n = fit_key.len();
        Self::classed((0..n as u32).collect(), fit_key)
    }

    /// Class-keyed layout: `class_key[c]` = `min_r demand_cr` for each
    /// interned demand row, `class_of[u]` the row of user `u`.
    pub fn classed(class_of: Vec<u32>, class_key: Vec<f64>) -> Self {
        let n = class_of.len();
        let nc = class_key.len();
        debug_assert!(class_of.iter().all(|&c| (c as usize) < nc));
        BlockedIndex {
            key: class_key,
            class_of,
            members: vec![BTreeSet::new(); nc],
            set: BTreeSet::new(),
            flags: vec![false; n],
            len: 0,
        }
    }

    /// Online user add (churn layer): append one unblocked user in
    /// demand class `class` with fit key `class_key`
    /// (`min_r demand_r`). A fresh class id extends the key table;
    /// an existing id must carry its established key bit-for-bit.
    /// Equivalent to rebuilding over the extended user set.
    pub fn add_user(&mut self, class: u32, class_key: f64) {
        let c = class as usize;
        if c == self.key.len() {
            self.key.push(class_key);
            self.members.push(BTreeSet::new());
        } else {
            debug_assert!(c < self.key.len(), "class id skips ahead");
            debug_assert_eq!(self.key[c].to_bits(), class_key.to_bits());
        }
        self.class_of.push(class);
        self.flags.push(false);
    }

    pub fn insert(&mut self, u: usize) {
        if !self.flags[u] {
            self.flags[u] = true;
            self.len += 1;
            let c = self.class_of[u] as usize;
            if self.members[c].is_empty() {
                self.set.insert((F64Ord(self.key[c]), c as u32));
            }
            self.members[c].insert(u as u32);
        }
    }

    pub fn remove(&mut self, u: usize) {
        if self.flags[u] {
            self.flags[u] = false;
            self.len -= 1;
            let c = self.class_of[u] as usize;
            self.members[c].remove(&(u as u32));
            if self.members[c].is_empty() {
                self.set.remove(&(F64Ord(self.key[c]), c as u32));
            }
        }
    }

    pub fn is_blocked(&self, u: usize) -> bool {
        self.flags[u]
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Blocked users whose fit key is at most `free_min` — a superset
    /// of those that can fit a server whose smallest per-resource
    /// headroom is `free_min`; the caller still does the exact check.
    pub fn candidates(
        &self,
        free_min: f64,
    ) -> impl Iterator<Item = usize> + '_ {
        self.candidate_classes(free_min)
            .flat_map(move |c| self.class_members(c))
    }

    /// Demand classes with a blocked member whose fit key is at most
    /// `free_min` — the per-class version of
    /// [`BlockedIndex::candidates`]: probe
    /// [`crate::sched::Scheduler::can_fit`] on any one member and the
    /// verdict covers the whole class.
    pub fn candidate_classes(
        &self,
        free_min: f64,
    ) -> impl Iterator<Item = usize> + '_ {
        self.set
            .range(..=(F64Ord(free_min), u32::MAX))
            .map(|&(_, c)| c as usize)
    }

    /// Blocked members of class `c`, ascending by user id.
    pub fn class_members(
        &self,
        c: usize,
    ) -> impl Iterator<Item = usize> + '_ {
        self.members[c].iter().map(|&u| u as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::min_share_user;
    use crate::util::Pcg32;

    fn mk_user(share: f64, weight: f64, pending: usize) -> UserState {
        UserState {
            demand: ResVec::cpu_mem(0.1, 0.1),
            weight,
            pending,
            running: 0,
            dom_share: share,
            usage: ResVec::zeros(2),
            dom_delta: 0.01,
        }
    }

    /// ShareHeap agrees with the linear scan through randomized
    /// key/eligibility churn, including zero-weight users.
    #[test]
    fn share_heap_matches_linear_scan() {
        let mut rng = Pcg32::seeded(42);
        let n = 12;
        let mut users: Vec<UserState> = (0..n)
            .map(|_| {
                mk_user(
                    rng.uniform(0.0, 1.0),
                    if rng.f64() < 0.2 { 0.0 } else { rng.uniform(0.5, 2.0) },
                    rng.below(3),
                )
            })
            .collect();
        let mut eligible = vec![true; n];
        let mut heap = ShareHeap::new();
        for step in 0..500 {
            heap.refresh(&users, &eligible);
            let got = heap.peek_min(&users, &eligible);
            let want = min_share_user(&users, &eligible);
            assert_eq!(got, want, "step {step}");
            // random mutation, mirrored into the heap via the same
            // notifications the engine fires
            let u = rng.below(n);
            match rng.below(4) {
                0 => {
                    users[u].dom_share = rng.uniform(0.0, 1.0);
                    heap.mark_dirty(u);
                }
                1 => {
                    users[u].pending = rng.below(3);
                    heap.mark_dirty(u);
                }
                2 if eligible[u] => {
                    // block u (engine: Pick::Blocked)
                    eligible[u] = false;
                    heap.remove(u);
                }
                _ => {
                    // unblock u (engine: on_ready)
                    eligible[u] = true;
                    heap.mark_dirty(u);
                }
            }
        }
    }

    /// PlacementIndex agrees with the naive scans across random
    /// commit/release churn, for both score kinds and several shard
    /// layouts (the cross-shard argmin must reproduce the single-heap
    /// selection exactly, ties included); a mid-run `set_shards`
    /// re-layout must also be seamless.
    #[test]
    fn placement_index_matches_naive_scans() {
        use crate::sched::best_fit::best_server;
        use crate::sched::first_fit::first_server;
        for (kind, shards, seed) in [
            (ScoreKind::BestFit, 1usize, 7u64),
            (ScoreKind::BestFit, 3, 7),
            (ScoreKind::BestFit, 8, 7),
            (ScoreKind::FirstFit, 1, 8),
            (ScoreKind::FirstFit, 3, 8),
            (ScoreKind::FirstFit, 8, 8),
        ] {
            let mut rng = Pcg32::seeded(seed);
            let mut cluster = Cluster::google_sample(60, &mut rng);
            let users: Vec<UserState> = (0..6)
                .map(|_| {
                    let d = ResVec::cpu_mem(
                        rng.uniform(0.05, 0.4),
                        rng.uniform(0.05, 0.4),
                    );
                    UserState {
                        demand: d,
                        weight: 1.0,
                        pending: 1,
                        running: 0,
                        dom_share: 0.0,
                        usage: ResVec::zeros(2),
                        dom_delta: 0.01,
                    }
                })
                .collect();
            let mut index = PlacementIndex::new(kind);
            index.set_shards(shards);
            let mut committed: Vec<(usize, ResVec)> = Vec::new();
            for step in 0..400 {
                if step == 200 {
                    // re-layout mid-run: next refresh rebuilds, with
                    // no effect on any selection
                    index.set_shards(shards % 8 + 1);
                }
                index.refresh(&cluster, &users);
                if step == 0 {
                    assert_eq!(index.shard_count(), shards.min(60));
                }
                for (i, u) in users.iter().enumerate() {
                    let want = match kind {
                        ScoreKind::BestFit => best_server(&cluster, &u.demand),
                        ScoreKind::FirstFit => {
                            first_server(&cluster, &u.demand)
                        }
                    };
                    let got = index.best_server(i);
                    assert_eq!(
                        got, want,
                        "kind {kind:?} shards {shards} step {step} user {i}"
                    );
                    // skyline pre-check is sound: a fit anywhere implies
                    // may_fit_anywhere (the converse may not hold)
                    if want.is_some() {
                        assert!(
                            index
                                .server_index()
                                .expect("built")
                                .may_fit_anywhere(&u.demand),
                            "skyline refuted an existing fit (user {i})"
                        );
                    }
                }
                // random commit or release
                if !committed.is_empty() && rng.f64() < 0.4 {
                    let j = rng.below(committed.len());
                    let (l, d) = committed.swap_remove(j);
                    cluster.servers[l].release(&d);
                    index.mark_server_dirty(l);
                } else {
                    let l = rng.below(cluster.len());
                    let d = users[rng.below(users.len())].demand;
                    if cluster.servers[l].fits(&d) {
                        cluster.servers[l].commit(&d);
                        committed.push((l, d));
                        index.mark_server_dirty(l);
                    }
                }
            }
        }
    }

    /// The skyline never under-reports a class's free capacity.
    #[test]
    fn server_index_skyline_is_sound() {
        let mut rng = Pcg32::seeded(5);
        let mut cluster = Cluster::google_sample(40, &mut rng);
        let mut idx = ServerIndex::build(&cluster);
        let d = ResVec::cpu_mem(0.1, 0.1);
        for _ in 0..600 {
            let l = rng.below(cluster.len());
            if rng.f64() < 0.5 && cluster.servers[l].fits(&d) {
                cluster.servers[l].commit(&d);
            } else {
                // release only what is committed
                if cluster.servers[l].usage[0] >= d[0] {
                    cluster.servers[l].release(&d);
                }
            }
            idx.note_avail(&cluster, l);
            for c in 0..idx.classes().len() {
                let bucket = &idx.classes()[c];
                for &m in &bucket.members {
                    assert_eq!(idx.class_of(m as usize), c, "membership map");
                    let s = &cluster.servers[m as usize];
                    for r in 0..2 {
                        let a = s.capacity[r] - s.usage[r];
                        assert!(
                            bucket.max_free(r) >= a - 1e-12,
                            "skyline under-reports class {c} res {r}"
                        );
                    }
                }
            }
        }
    }

    /// Candidate filtering never skips a user that could fit.
    #[test]
    fn blocked_index_candidates_are_a_superset() {
        let mut rng = Pcg32::seeded(9);
        let demands: Vec<ResVec> = (0..20)
            .map(|_| {
                ResVec::cpu_mem(rng.uniform(0.05, 1.0), rng.uniform(0.05, 1.0))
            })
            .collect();
        let keys: Vec<f64> = demands.iter().map(|d| d.min()).collect();
        let mut idx = BlockedIndex::new(keys);
        for u in 0..20 {
            idx.insert(u);
        }
        assert_eq!(idx.len(), 20);
        for _ in 0..200 {
            let avail =
                ResVec::cpu_mem(rng.uniform(0.0, 1.2), rng.uniform(0.0, 1.2));
            let server = Server::new(avail);
            let free_min = avail.min() + FIT_EPS;
            let cands: Vec<usize> = idx.candidates(free_min).collect();
            for (u, d) in demands.iter().enumerate() {
                if server.fits(d) {
                    assert!(
                        cands.contains(&u),
                        "user {u} fits but was filtered out"
                    );
                }
            }
        }
        idx.remove(3);
        assert!(!idx.is_blocked(3));
        assert_eq!(idx.len(), 19);
        assert!(!idx.is_empty());
    }
}
