//! Optimization substrates for the paper's eq. (7) LP: the sparse
//! revised-simplex [`Solver`] (warm-startable — what the incremental
//! dynamic-DRFH allocator `allocator::incremental` re-solves from a
//! recorded basis) and the dense two-phase [`solve`] kept as its
//! 1e-9 parity reference (`tests/solver_fuzz.rs` holds the two cores
//! to each other).

pub mod revised;
pub mod simplex;

pub use revised::{RowId, SolveStats, Solver, VarId};
pub use simplex::{solve, Lp, LpResult, PivotCounts};
