//! Fluid (divisible-task) allocation mechanisms.
//!
//! * [`drfh`] — the paper's contribution: the exact DRFH allocation
//!   (eq. (7)), supporting weighted users and finite task demands via
//!   progressive-filling rounds (paper Sec. V-A).
//! * [`incremental`] — the event-driven dynamic DRFH allocator: the
//!   same exact allocation maintained across join/departure/cap/weight
//!   events on a warm-started simplex basis ([`drfh::solve`] stays the
//!   from-scratch parity reference);
//! * [`per_server_drf`] — the naive "run DRF inside every server"
//!   extension of Sec. III-D, kept as the inefficiency baseline.

pub mod drfh;
pub mod incremental;
pub mod per_server_drf;

pub use drfh::{solve, solve_per_user, FluidAllocation, FluidUser};
pub use incremental::IncrementalDrfh;

use crate::cluster::ResVec;

/// A user's demand expressed in the paper's normalized terms.
#[derive(Clone, Debug)]
pub struct NormalizedDemand {
    /// D_i: per-task demand as a *fraction of the total pool* per
    /// resource (paper Sec. III-A).
    pub share: ResVec,
    /// d_i = D_i / D_{i,r*}: demand normalized by the dominant demand.
    pub norm: ResVec,
    /// r*_i: index of the global dominant resource.
    pub dominant: usize,
}

impl NormalizedDemand {
    /// Normalize an absolute per-task demand against pool totals.
    ///
    /// A resource whose pool total is zero (every server holding it is
    /// down, or it was never provisioned) contributes a zero share
    /// rather than a NaN/inf from the division; if *every* demanded
    /// resource has an empty pool the normalized profile is all-zero
    /// and `dominant_share_of`/`tasks_of` report +inf (nothing binds —
    /// callers treat the user as unallocatable, see `drfh::solve`).
    pub fn from_absolute(demand: &ResVec, total: &ResVec) -> Self {
        let m = demand.dims();
        let mut share = ResVec::zeros(m);
        for r in 0..m {
            if total[r] > 0.0 {
                share[r] = demand[r] / total[r];
            }
        }
        let dominant = share.argmax();
        let norm = if share[dominant] > 0.0 {
            share.scale(1.0 / share[dominant])
        } else {
            ResVec::zeros(m)
        };
        NormalizedDemand { share, norm, dominant }
    }

    /// Global dominant share delivered by an allocation vector `a`
    /// (in pool-share units): min_r a_r / d_r (paper eq. (2)).
    pub fn dominant_share_of(&self, a: &ResVec) -> f64 {
        let mut g = f64::INFINITY;
        for r in 0..a.dims() {
            let d = self.norm[r];
            if d > 0.0 {
                g = g.min(a[r] / d);
            }
        }
        g
    }

    /// Tasks schedulable from an allocation vector in pool-share units:
    /// min_r a_r / D_r (paper eq. (1) for one bundle).
    pub fn tasks_of(&self, a: &ResVec) -> f64 {
        let mut n = f64::INFINITY;
        for r in 0..a.dims() {
            if self.share[r] > 0.0 {
                n = n.min(a[r] / self.share[r]);
            }
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_normalization() {
        // pool: 14 CPU, 14 GB; user 1: (0.2 CPU, 1 GB)
        let total = ResVec::cpu_mem(14.0, 14.0);
        let nd = NormalizedDemand::from_absolute(
            &ResVec::cpu_mem(0.2, 1.0),
            &total,
        );
        assert!((nd.share[0] - 1.0 / 70.0).abs() < 1e-12);
        assert!((nd.share[1] - 1.0 / 14.0).abs() < 1e-12);
        assert_eq!(nd.dominant, 1); // memory
        assert!((nd.norm[0] - 0.2).abs() < 1e-12);
        assert!((nd.norm[1] - 1.0).abs() < 1e-12);
    }

    /// Regression: a zeroed pool dimension (all servers holding that
    /// resource down) must not poison the normalization with NaN/inf.
    #[test]
    fn zero_total_yields_finite_normalization() {
        let demand = ResVec::cpu_mem(0.2, 1.0);
        // memory pool empty: the share is zero there, CPU dominates
        let nd = NormalizedDemand::from_absolute(
            &demand,
            &ResVec::cpu_mem(14.0, 0.0),
        );
        assert!((nd.share[0] - 0.2 / 14.0).abs() < 1e-12);
        assert_eq!(nd.share[1], 0.0);
        assert_eq!(nd.dominant, 0);
        assert!((nd.norm[0] - 1.0).abs() < 1e-12);
        assert_eq!(nd.norm[1], 0.0);
        assert!(nd.share.as_slice().iter().all(|x| x.is_finite()));
        assert!(nd.norm.as_slice().iter().all(|x| x.is_finite()));
        // fully empty pool: all-zero profile, nothing binds
        let nd = NormalizedDemand::from_absolute(
            &demand,
            &ResVec::cpu_mem(0.0, 0.0),
        );
        assert!(nd.share.as_slice().iter().all(|&x| x == 0.0));
        assert!(nd.norm.as_slice().iter().all(|&x| x == 0.0));
        assert_eq!(nd.dominant_share_of(&ResVec::cpu_mem(0.5, 0.5)), f64::INFINITY);
    }

    #[test]
    fn dominant_share_and_tasks() {
        let total = ResVec::cpu_mem(14.0, 14.0);
        let nd = NormalizedDemand::from_absolute(
            &ResVec::cpu_mem(0.2, 1.0),
            &total,
        );
        // allocate exactly server 1 = (2 CPU, 12 GB) in share units
        let a = ResVec::cpu_mem(2.0 / 14.0, 12.0 / 14.0);
        // CPU binds: g = (2/14)/0.2 = 5/7 — the paper's Fig. 3 value
        // for user 1 holding server 1 exclusively
        assert!((nd.dominant_share_of(&a) - 5.0 / 7.0).abs() < 1e-12);
        // tasks: min(2/0.2, 12/1) = 10
        assert!((nd.tasks_of(&a) - 10.0).abs() < 1e-9);
    }
}
