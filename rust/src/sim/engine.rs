//! Discrete-event cluster simulator.
//!
//! Drives a [`Trace`] through a [`Scheduler`] over a [`Cluster`] and
//! records everything the paper's evaluation section plots: utilization
//! time series (Fig. 5), per-user share trajectories (Fig. 4), job
//! completion times (Fig. 6), and per-user task completion ratios
//! (Fig. 7/8).
//!
//! ## Processor sharing
//!
//! DRFH schedulers never exceed server capacity, so their tasks run at
//! rate 1 and a task placed at `t` finishes at `t + duration`. The Slots
//! baseline, however, ignores real demands and can overcommit a server;
//! we model the resulting contention as egalitarian processor sharing
//! with thrashing: every task on server `l` progresses at rate
//! `f_l = min(1, 1/load_l³)` where `load_l = max_r usage_lr / c_lr`
//! (the cubic term models paging/scheduling overhead; see
//! `cluster::Server::rate`). Each server keeps a virtual
//! clock advancing at `f_l`; a task with service demand `w` placed at
//! virtual time `V` completes when the clock reaches `V + w`. Rate
//! changes (placements/completions) reschedule the server's next
//! completion event; stale events are skipped via a per-server
//! generation counter.
//!
//! ## §Perf: the trace-scale data plane
//!
//! Three independently gated pieces keep a ~10⁶-task, k = 2000 run
//! inside one machine's memory and cache budget (`benches/sim_scale.rs`
//! measures all three; `tests/engine_parity.rs` pins the semantics):
//!
//! * **Event queue** ([`SimOpts::queue`]): the engine drives a
//!   [`wheel::SimQueue`] — a calendar-style timer wheel
//!   ([`wheel::TimerWheel`], the default; [`QueueKind::Auto`] tunes
//!   its geometry to the trace's observed duration distribution) or
//!   the seed's `BinaryHeap` ([`wheel::HeapQueue`], the naive parity
//!   reference). All drain in the identical total `(time, seq)`
//!   order, so every scheduling decision and every derived float is
//!   bit-identical across queue choices; the wheel replaces O(log N)
//!   cache-hostile heap walks with O(1) bucket pushes and batched
//!   bucket sorts.
//!
//! * **Task arena** ([`TaskArena`]): per-job state lives in flat
//!   structure-of-arrays columns (u32 cursors/countdowns), task
//!   durations are borrowed once from the [`Trace`] instead of being
//!   cloned per job, per-user queues are flat `VecDeque<u32>` job-id
//!   rings, and per-user demand rows are interned
//!   ([`crate::workload::DemandTable`]) so derived per-task constants
//!   (dominant delta, blocked-index fit keys) are computed once per
//!   distinct row.
//!
//! * **Metrics gating** ([`SimOpts::metrics`]):
//!   [`MetricsMode::Streaming`] folds job completions into O(1)
//!   streaming accumulators ([`crate::metrics::JobStats`]) and keeps
//!   every time series under a fixed point budget by stride-doubling
//!   decimation, so peak RSS stays ~flat in task count.
//!   [`MetricsMode::Full`] (default) is the seed behavior the figure
//!   harnesses need. `job_stats` is maintained in both modes.
//!
//! ## §Perf: batched drain
//!
//! Scheduling opportunities are handed to the policy one *event wave*
//! at a time: `schedule_loop` builds an [`EngineCtx`] over the
//! engine's state and calls [`Scheduler::drain`] once, and the policy
//! commits every placeable task through [`DrainCtx::place`] /
//! [`DrainCtx::block`] before returning. The engine still owns all
//! state mutation (the ctx methods are the old `place`/block bodies);
//! what moved is the control loop, so indexed policies can refresh
//! their structures once per wave instead of once per decision. The
//! engine stays silent on `on_place` during a drain — the deciding
//! policy already knows — while completions between waves keep firing
//! `on_complete`/`on_free`/`on_ready` as before.
//!
//! ## §Perf: indexed hot path
//!
//! The engine feeds the policies' incremental indexes
//! (`sched::index`, `sched::users`) through three notifications —
//! `on_place` after a commit, `on_complete`/`on_free` after a
//! release, and `on_ready` when a user (re-)enters the schedulable
//! set — and keeps its own blocked set in a class-keyed
//! `sched::index::BlockedIndex` built over the trace's interned
//! demand rows ([`crate::workload::DemandTable`]): a completion on
//! server `l` re-checks only the blocked demand *classes* whose
//! minimum demand component fits under `l`'s smallest per-resource
//! headroom (a necessary condition for fitting), with one exact
//! `Scheduler::can_fit` probe per candidate class deciding every
//! blocked member of that class (the `can_fit` contract: verdicts
//! depend on the user only through its demand). The candidate set is
//! a provable superset of the users the seed's linear scan would
//! have unblocked, so the unblocked *set* — and therefore every
//! subsequent decision — is identical (asserted end-to-end by
//! `tests/engine_parity.rs`).

use crate::cluster::{Cluster, ResVec};
use crate::metrics::shares::ShareSketch;
use crate::metrics::{
    JobRecord, JobStats, MetricsMode, TimeSeries, UserTaskCounts,
};
use crate::sched::index::BlockedIndex;
use crate::sched::{DrainCtx, Scheduler, UserState};
use crate::sim::wheel::{self, EventQueue, QueueKind, SimQueue, TimerWheel};
use crate::workload::{TaskArena, Trace};
use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::collections::VecDeque;

/// Simulation options.
#[derive(Clone, Debug)]
pub struct SimOpts {
    /// Stop the clock here (seconds). Tasks still running are counted
    /// as incomplete (paper Fig. 7/8 use completion *ratios*).
    pub horizon: f64,
    /// Metrics sampling period (seconds).
    pub sample_dt: f64,
    /// Record per-user share time series (Fig. 4 needs it; the
    /// 2,000-server runs don't and save the memory).
    pub track_user_series: bool,
    /// Event-queue implementation (§Perf): the timer wheel by
    /// default; [`QueueKind::Auto`] re-tunes the wheel geometry from
    /// the trace's observed task-duration distribution
    /// ([`wheel::auto_geometry`] — perf-only, the drain order is
    /// geometry-independent); [`QueueKind::Heap`] is the seed's
    /// binary heap, kept as the naive parity reference. Decision
    /// streams are bit-identical in every case
    /// (`tests/engine_parity.rs`).
    pub queue: QueueKind,
    /// Metrics retention (§Perf): [`MetricsMode::Full`] keeps every
    /// sample and job record; [`MetricsMode::Streaming`] bounds
    /// memory for trace-scale runs.
    pub metrics: MetricsMode,
    /// Per-user dominant-share *sketches* (§Perf): `Some(budget)`
    /// maintains one [`ShareSketch`] per user — Welford moments, P²
    /// median/p90 and a trajectory decimated to at most `budget`
    /// points (0 = exact retention) — fed at every sample tick. The
    /// bounded-memory alternative to [`SimOpts::track_user_series`]
    /// for Fig. 4-style trajectories at large user counts.
    pub share_sketch: Option<usize>,
}

impl Default for SimOpts {
    fn default() -> Self {
        SimOpts {
            horizon: 86_400.0,
            sample_dt: 30.0,
            track_user_series: false,
            queue: QueueKind::Wheel,
            metrics: MetricsMode::Full,
            share_sketch: None,
        }
    }
}

/// Everything measured during a run.
#[derive(Clone, Debug, PartialEq)]
pub struct SimReport {
    pub scheduler: String,
    pub cpu_util: TimeSeries,
    pub mem_util: TimeSeries,
    /// Per-user global dominant share over time (when tracked).
    pub user_dom_share: Vec<TimeSeries>,
    /// Per-user dominant-share sketches (when
    /// [`SimOpts::share_sketch`] is set; empty otherwise).
    pub share_sketches: Vec<ShareSketch>,
    /// Per-user CPU / memory share of the pool over time (when tracked).
    pub user_cpu_share: Vec<TimeSeries>,
    pub user_mem_share: Vec<TimeSeries>,
    /// Jobs that completed before the horizon (empty under
    /// [`MetricsMode::Streaming`] — use [`SimReport::job_stats`]).
    pub jobs: Vec<JobRecord>,
    /// Streaming job-completion statistics (maintained in every
    /// metrics mode).
    pub job_stats: JobStats,
    pub user_tasks: Vec<UserTaskCounts>,
    pub tasks_placed: usize,
    pub tasks_completed: usize,
    /// Time-averaged utilizations over the horizon.
    pub avg_cpu_util: f64,
    pub avg_mem_util: f64,
}

// ---------------------------------------------------------------- events

#[derive(Clone, Copy, Debug, PartialEq)]
enum EventKind {
    Arrival(usize),
    ServerCheck { server: usize, gen: u64 },
    Sample,
}

type Event = wheel::Event<EventKind>;
type Events = SimQueue<EventKind>;

// ------------------------------------------------------------- run state

#[derive(Clone, Copy, Debug)]
struct RunEntry {
    vfinish: f64,
    seq: u64,
    user: u32,
    job: u32,
}

impl PartialEq for RunEntry {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}
impl Eq for RunEntry {}
impl PartialOrd for RunEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for RunEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap on (vfinish, seq)
        other
            .vfinish
            .total_cmp(&self.vfinish)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

struct ServerSim {
    vtime: f64,
    t_last: f64,
    rate: f64,
    gen: u64,
    running: BinaryHeap<RunEntry>,
}

impl ServerSim {
    fn new() -> Self {
        ServerSim {
            vtime: 0.0,
            t_last: 0.0,
            rate: 1.0,
            gen: 0,
            running: BinaryHeap::new(),
        }
    }

    #[inline]
    fn advance(&mut self, now: f64) {
        if now > self.t_last {
            self.vtime += self.rate * (now - self.t_last);
            self.t_last = now;
        }
    }
}

/// The simulator. `'a` covers both the policy and the replayed trace —
/// the [`TaskArena`] borrows every task duration straight from the
/// trace instead of cloning it.
pub struct Simulation<'a> {
    pub cluster: Cluster,
    pub users: Vec<UserState>,
    scheduler: Box<dyn Scheduler + 'a>,
    opts: SimOpts,

    /// Per-user round-robin ring of job ids with un-placed tasks.
    /// Tasks are drawn round-robin across the user's jobs (Hadoop
    /// Fair Scheduler semantics: fair across jobs within a pool), so
    /// a small job is never buried behind an earlier big one. The
    /// job's un-placed frontier itself is a u32 cursor in the arena —
    /// no per-job containers on this path.
    queues: Vec<VecDeque<u32>>,
    /// Flat SoA job/task state, durations borrowed from the trace.
    arena: TaskArena<'a>,
    servers: Vec<ServerSim>,
    events: Events,
    seq: u64,
    now: f64,

    eligible: Vec<bool>,
    blocked: BlockedIndex,
    /// Scratch buffers for unblock candidates (users / demand
    /// classes), avoiding per-completion allocation.
    scratch_unblock: Vec<usize>,
    scratch_classes: Vec<usize>,

    report: SimReport,
    total: ResVec,
}

impl<'a> Simulation<'a> {
    /// Build a simulation for `trace` on `cluster` under `scheduler`.
    pub fn new(
        cluster: Cluster,
        trace: &'a Trace,
        scheduler: Box<dyn Scheduler + 'a>,
        opts: SimOpts,
    ) -> Self {
        trace.validate().expect("invalid trace");
        let total = cluster.total_capacity();
        let m = cluster.dims();
        let arena = TaskArena::new(trace);
        // per-task constants derived once per *distinct* demand row
        // (bit-identical to the per-user computation they replace)
        let dom_deltas: Vec<f64> =
            arena.demands().per_user(|d| d.div(&total).max());
        // blocked-user fit keys: min_r demand_r per interned class,
        // with the user -> class map (see BlockedIndex docs)
        let class_fit: Vec<f64> = (0..arena.demands().classes())
            .map(|c| arena.demands().row(c).min())
            .collect();
        let class_of = arena.demands().class_map().to_vec();
        let users: Vec<UserState> = trace
            .users
            .iter()
            .zip(&dom_deltas)
            .map(|(u, &dom_delta)| UserState {
                demand: u.demand,
                weight: u.weight,
                pending: 0,
                running: 0,
                dom_share: 0.0,
                usage: ResVec::zeros(m),
                dom_delta,
            })
            .collect();
        let n = users.len();
        let k = cluster.len();
        let name = scheduler.name().to_string();
        let events = match opts.queue {
            QueueKind::Auto => {
                // perf-only: any geometry drains in the same total
                // (time, seq) order (see `wheel` docs)
                let (width, nb) = wheel::auto_geometry(
                    trace
                        .jobs
                        .iter()
                        .flat_map(|j| j.tasks.iter().map(|t| t.duration)),
                );
                SimQueue::Wheel(TimerWheel::with_params(width, nb))
            }
            kind => Events::new(kind),
        };
        let sketch_budget = opts.share_sketch;

        let mut sim = Simulation {
            cluster,
            users,
            scheduler,
            opts: opts.clone(),
            queues: vec![VecDeque::new(); n],
            arena,
            servers: (0..k).map(|_| ServerSim::new()).collect(),
            events,
            seq: 0,
            now: 0.0,
            eligible: vec![true; n],
            blocked: BlockedIndex::classed(class_of, class_fit),
            scratch_unblock: Vec::new(),
            scratch_classes: Vec::new(),
            report: SimReport {
                scheduler: name,
                cpu_util: TimeSeries::default(),
                mem_util: TimeSeries::default(),
                user_dom_share: vec![TimeSeries::default(); if opts.track_user_series { n } else { 0 }],
                share_sketches: match sketch_budget {
                    Some(budget) => {
                        vec![ShareSketch::with_budget(budget); n]
                    }
                    None => Vec::new(),
                },
                user_cpu_share: vec![TimeSeries::default(); if opts.track_user_series { n } else { 0 }],
                user_mem_share: vec![TimeSeries::default(); if opts.track_user_series { n } else { 0 }],
                jobs: Vec::new(),
                job_stats: JobStats::default(),
                user_tasks: vec![UserTaskCounts::default(); n],
                tasks_placed: 0,
                tasks_completed: 0,
                avg_cpu_util: 0.0,
                avg_mem_util: 0.0,
            },
            total,
        };
        for (j, job) in trace.jobs.iter().enumerate() {
            if job.submit <= opts.horizon {
                sim.push_event(job.submit, EventKind::Arrival(j));
            }
        }
        sim.push_event(0.0, EventKind::Sample);
        sim
    }

    fn push_event(&mut self, time: f64, kind: EventKind) {
        push_event_into(&mut self.events, &mut self.seq, time, kind);
    }

    /// Run to completion (horizon or event exhaustion) and return the
    /// report.
    ///
    /// All events sharing a timestamp are applied *before* the
    /// scheduler runs, so simultaneous arrivals compete fairly
    /// (progressive filling sees every queued task, not an accident of
    /// event ordering).
    pub fn run(mut self) -> SimReport {
        while let Some(ev) = self.events.pop() {
            if ev.time > self.opts.horizon {
                break;
            }
            self.now = ev.time;
            let mut need_sched = self.apply(ev.payload);
            while let Some(next) = self.events.peek() {
                if next.time > self.now {
                    break;
                }
                let next = self.events.pop().unwrap();
                need_sched |= self.apply(next.payload);
            }
            if need_sched {
                self.schedule_loop();
            }
        }
        self.report.avg_cpu_util = self.report.cpu_util.time_avg();
        self.report.avg_mem_util = self.report.mem_util.time_avg();
        self.report
    }

    /// Apply one event's state changes; returns true when a scheduling
    /// opportunity arises (arrival or completion).
    fn apply(&mut self, kind: EventKind) -> bool {
        match kind {
            EventKind::Arrival(j) => self.on_arrival(j),
            EventKind::ServerCheck { server, gen } => {
                self.on_server_check(server, gen)
            }
            EventKind::Sample => {
                self.on_sample();
                false
            }
        }
    }

    fn on_arrival(&mut self, j: usize) -> bool {
        let user = self.arena.job_user(j);
        self.queues[user].push_back(j as u32);
        let num_tasks = self.arena.job_len(j);
        self.users[user].pending += num_tasks;
        self.report.user_tasks[user].submitted += num_tasks;
        // a blocked user stays blocked (its demand is static); for the
        // rest, let indexed policies re-insert the user
        if !self.blocked.is_blocked(user) {
            self.scheduler.on_ready(user);
        }
        true
    }

    fn on_server_check(&mut self, l: usize, gen: u64) -> bool {
        if self.servers[l].gen != gen {
            return false; // stale event
        }
        self.servers[l].advance(self.now);
        let mut completed_any = false;
        while let Some(top) = self.servers[l].running.peek() {
            if top.vfinish <= self.servers[l].vtime + 1e-9 {
                let entry = self.servers[l].running.pop().unwrap();
                self.complete_task(l, entry);
                completed_any = true;
            } else {
                break;
            }
        }
        self.refresh_server(l);
        if completed_any {
            self.unblock_for_server(l);
        }
        completed_any
    }

    fn complete_task(&mut self, l: usize, entry: RunEntry) {
        let u = entry.user as usize;
        let demand = self.users[u].demand;
        self.cluster.servers[l].release(&demand);
        self.cluster.servers[l].tasks -= 1;
        self.scheduler.on_free(l);
        self.scheduler.on_complete(u, l);
        self.users[u].running -= 1;
        // Recompute, never accumulate: repeated `+= dom_delta` /
        // `-= dom_delta` cycles drift (float addition is not exactly
        // invertible), biasing the very key schedulers sort by. The
        // product form is exact for any running count and needs no
        // negative clamp.
        self.users[u].dom_share =
            self.users[u].running as f64 * self.users[u].dom_delta;
        self.users[u].usage.sub_assign(&demand);
        self.report.tasks_completed += 1;
        self.report.user_tasks[u].completed += 1;
        let j = entry.job as usize;
        if self.arena.complete_one(j) {
            let submit = self.arena.job_submit(j);
            let num_tasks = self.arena.job_len(j);
            self.report.job_stats.record(self.now - submit, num_tasks);
            if self.opts.metrics == MetricsMode::Full {
                self.report.jobs.push(JobRecord {
                    job: j,
                    user: self.arena.job_user(j),
                    num_tasks,
                    submit,
                    finish: self.now,
                });
            }
        }
    }

    /// Recompute a server's PS rate and (re)schedule its next
    /// completion check.
    fn refresh_server(&mut self, l: usize) {
        refresh_server_at(
            &self.cluster,
            &mut self.servers,
            &mut self.events,
            &mut self.seq,
            self.now,
            l,
        );
    }

    /// Re-check blocked users against server `l` after it freed
    /// capacity. Candidate *classes* are pre-filtered by the
    /// BlockedIndex necessary condition (min demand component vs.
    /// `l`'s smallest headroom), and one exact `can_fit` probe per
    /// class decides all of its blocked members at once (the
    /// [`Scheduler::can_fit`] contract: the verdict depends on the
    /// user only through its demand class) — O(classes) probes per
    /// completion, however many users are blocked. The unblocked
    /// *set* matches the seed's full per-user scan. The headroom
    /// filter is only sound for demand-based `can_fit`;
    /// overcommitting policies (Slots — slot-based fits, headroom may
    /// be negative) consider every blocked class, as before.
    fn unblock_for_server(&mut self, l: usize) {
        if self.blocked.is_empty() {
            return;
        }
        let free_min = if self.scheduler.allows_overcommit() {
            f64::INFINITY
        } else {
            self.cluster.servers[l].min_headroom() + crate::cluster::FIT_EPS
        };
        let mut classes = std::mem::take(&mut self.scratch_classes);
        classes.clear();
        classes.extend(self.blocked.candidate_classes(free_min));
        let mut cands = std::mem::take(&mut self.scratch_unblock);
        cands.clear();
        for &c in &classes {
            let probe = self
                .blocked
                .class_members(c)
                .next()
                .expect("candidate class has a blocked member");
            if self.scheduler.can_fit(&self.cluster, &self.users, probe, l) {
                cands.extend(self.blocked.class_members(c));
            }
        }
        for &u in &cands {
            self.blocked.remove(u);
            self.eligible[u] = true;
            self.scheduler.on_ready(u);
        }
        self.scratch_unblock = cands;
        self.scratch_classes = classes;
    }

    /// One scheduling opportunity: hand the whole event wave to the
    /// policy through [`Scheduler::drain`]. The [`EngineCtx`] borrows
    /// every engine field except the scheduler itself, so the policy
    /// can read post-commit state and commit further decisions while
    /// it holds the ctx.
    fn schedule_loop(&mut self) {
        let overcommit = self.scheduler.allows_overcommit();
        let mut ctx = EngineCtx {
            cluster: &mut self.cluster,
            users: &mut self.users,
            eligible: &mut self.eligible,
            blocked: &mut self.blocked,
            queues: &mut self.queues,
            arena: &mut self.arena,
            servers: &mut self.servers,
            events: &mut self.events,
            seq: &mut self.seq,
            now: self.now,
            report: &mut self.report,
            overcommit,
        };
        self.scheduler.drain(&mut ctx);
    }

    fn on_sample(&mut self) {
        let util = self.cluster.utilization();
        self.report.cpu_util.push(self.now, util[0]);
        if self.cluster.dims() > 1 {
            self.report.mem_util.push(self.now, util[1]);
        }
        if self.opts.track_user_series {
            for (u, us) in self.users.iter().enumerate() {
                self.report.user_dom_share[u].push(self.now, us.dom_share);
                self.report.user_cpu_share[u]
                    .push(self.now, us.usage[0] / self.total[0]);
                if self.cluster.dims() > 1 {
                    self.report.user_mem_share[u]
                        .push(self.now, us.usage[1] / self.total[1]);
                }
            }
        }
        if self.opts.share_sketch.is_some() {
            for (u, us) in self.users.iter().enumerate() {
                self.report.share_sketches[u].push(self.now, us.dom_share);
            }
        }
        if let MetricsMode::Streaming { series_cap } = self.opts.metrics {
            self.report.cpu_util.enforce_cap(series_cap);
            self.report.mem_util.enforce_cap(series_cap);
            if self.opts.track_user_series {
                for u in 0..self.users.len() {
                    self.report.user_dom_share[u].enforce_cap(series_cap);
                    self.report.user_cpu_share[u].enforce_cap(series_cap);
                    self.report.user_mem_share[u].enforce_cap(series_cap);
                }
            }
        }
        let next = self.now + self.opts.sample_dt;
        if next <= self.opts.horizon {
            self.push_event(next, EventKind::Sample);
        }
    }
}

// ------------------------------------------------------- drain plumbing

fn push_event_into(
    events: &mut Events,
    seq: &mut u64,
    time: f64,
    kind: EventKind,
) {
    *seq += 1;
    events.push(Event { time, seq: *seq, payload: kind });
}

/// Recompute server `l`'s PS rate and (re)schedule its next completion
/// check — shared between the completion path ([`Simulation`] methods)
/// and the drain path ([`EngineCtx::place`]).
fn refresh_server_at(
    cluster: &Cluster,
    servers: &mut [ServerSim],
    events: &mut Events,
    seq: &mut u64,
    now: f64,
    l: usize,
) {
    let srv = &mut servers[l];
    srv.rate = cluster.servers[l].rate();
    srv.gen += 1;
    if let Some(top) = srv.running.peek() {
        let dt = (top.vfinish - srv.vtime).max(0.0) / srv.rate;
        let eta = now + dt;
        let gen = srv.gen;
        push_event_into(events, seq, eta, EventKind::ServerCheck {
            server: l,
            gen,
        });
    }
}

/// The engine's side of the batched-drain protocol: disjoint mutable
/// borrows of every [`Simulation`] field a placement touches, so the
/// scheduler (the one field *not* borrowed) can be called with the ctx.
struct EngineCtx<'e, 't> {
    cluster: &'e mut Cluster,
    users: &'e mut [UserState],
    eligible: &'e mut [bool],
    blocked: &'e mut BlockedIndex,
    queues: &'e mut [VecDeque<u32>],
    arena: &'e mut TaskArena<'t>,
    servers: &'e mut [ServerSim],
    events: &'e mut Events,
    seq: &'e mut u64,
    now: f64,
    report: &'e mut SimReport,
    overcommit: bool,
}

impl DrainCtx for EngineCtx<'_, '_> {
    fn cluster(&self) -> &Cluster {
        &*self.cluster
    }

    fn users(&self) -> &[UserState] {
        &*self.users
    }

    fn eligible(&self) -> &[bool] {
        &*self.eligible
    }

    /// Commit one task of `u` onto `l` (the pre-batching
    /// `Simulation::place`, minus the `on_place` echo — the deciding
    /// policy updates its own state).
    fn place(&mut self, u: usize, l: usize) {
        let demand = self.users[u].demand;
        if !self.overcommit {
            debug_assert!(
                self.cluster.servers[l].fits(&demand),
                "scheduler violated capacity"
            );
        }
        // round-robin across the user's jobs: take one task from the
        // front job, then rotate it to the back if it has more
        let j = self.queues[u]
            .pop_front()
            .expect("placement without pending") as usize;
        let duration = self.arena.take_next(j);
        if self.arena.unplaced(j) > 0 {
            self.queues[u].push_back(j as u32);
        }
        self.users[u].pending -= 1;
        self.users[u].running += 1;
        // recompute, never accumulate — see `complete_task`
        self.users[u].dom_share =
            self.users[u].running as f64 * self.users[u].dom_delta;
        self.users[u].usage.add_assign(&demand);
        self.cluster.servers[l].commit(&demand);
        self.cluster.servers[l].tasks += 1;
        self.report.tasks_placed += 1;

        self.servers[l].advance(self.now);
        *self.seq += 1;
        let entry = RunEntry {
            vfinish: self.servers[l].vtime + duration,
            seq: *self.seq,
            user: u as u32,
            job: j as u32,
        };
        self.servers[l].running.push(entry);
        refresh_server_at(
            self.cluster,
            self.servers,
            self.events,
            self.seq,
            self.now,
            l,
        );
    }

    fn block(&mut self, u: usize) {
        self.blocked.insert(u);
        self.eligible[u] = false;
    }
}

/// Convenience: build and run in one call.
pub fn run<'a>(
    cluster: Cluster,
    trace: &'a Trace,
    scheduler: Box<dyn Scheduler + 'a>,
    opts: SimOpts,
) -> SimReport {
    Simulation::new(cluster, trace, scheduler, opts).run()
}
