//! L3 <-> L2/L1 bridge validation: the AOT-compiled XLA scheduling
//! kernels must make *identical* decisions to the native Rust picker on
//! the same f32 inputs — same argmins, same tie-breaking, same state
//! evolution through batched loops.
//!
//! Skips (with a message) when `make artifacts` has not produced the
//! AOT bundle.

use drfh::runtime::{
    artifacts_available, backend_available, picker, XlaRuntime,
};
use drfh::util::Pcg32;

fn runtime_or_skip() -> Option<XlaRuntime> {
    if !backend_available() {
        eprintln!("SKIP: built without a real PJRT backend (stub runtime::xla)");
        return None;
    }
    if !artifacts_available() {
        eprintln!("SKIP: artifacts/ missing; run `make artifacts`");
        return None;
    }
    Some(XlaRuntime::load_default().expect("loading artifacts"))
}

fn random_instance(
    rng: &mut Pcg32,
    n: usize,
    k: usize,
    m: usize,
    tight: bool,
) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<i32>) {
    let hi = if tight { 1.2 } else { 0.5 };
    let avail: Vec<f32> =
        (0..k * m).map(|_| rng.uniform(0.0, 1.0) as f32).collect();
    let demand: Vec<f32> =
        (0..n * m).map(|_| rng.uniform(0.01, hi) as f32).collect();
    let share: Vec<f32> =
        (0..n).map(|_| rng.uniform(0.0, 1.0) as f32).collect();
    let weight: Vec<f32> =
        (0..n).map(|_| rng.uniform(0.5, 2.0) as f32).collect();
    let active: Vec<i32> =
        (0..n).map(|_| i32::from(rng.f64() > 0.25)).collect();
    (avail, demand, share, weight, active)
}

#[test]
fn sched_step_decisions_identical() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Pcg32::seeded(101);
    for trial in 0..200 {
        let n = 1 + rng.below(16);
        let k = 1 + rng.below(128);
        let m = 2;
        let tight = rng.f64() < 0.3;
        let (avail, demand, share, weight, active) =
            random_instance(&mut rng, n, k, m, tight);
        let native = picker::sched_step(
            &avail, &demand, &share, &weight, &active, n, k, m,
        );
        let xla = rt
            .sched_step(&avail, &demand, &share, &weight, &active, n, k, m)
            .expect("xla step");
        assert_eq!(native, xla, "trial {trial} (n={n} k={k} tight={tight})");
    }
}

#[test]
fn sched_step_three_resources() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Pcg32::seeded(103);
    for trial in 0..50 {
        let n = 1 + rng.below(8);
        let k = 1 + rng.below(32);
        let m = 3;
        let (avail, demand, share, weight, active) =
            random_instance(&mut rng, n, k, m, false);
        let native = picker::sched_step(
            &avail, &demand, &share, &weight, &active, n, k, m,
        );
        let xla = rt
            .sched_step(&avail, &demand, &share, &weight, &active, n, k, m)
            .expect("xla step m=3");
        assert_eq!(native, xla, "trial {trial}");
    }
}

#[test]
fn sched_step_degenerate_cases() {
    let Some(rt) = runtime_or_skip() else { return };
    // all inactive
    let r = rt
        .sched_step(&[1.0, 1.0], &[0.5, 0.5], &[0.0], &[1.0], &[0], 1, 1, 2)
        .unwrap();
    assert_eq!(r, (-1, -1));
    // nothing fits
    let r = rt
        .sched_step(
            &[0.01, 0.01],
            &[0.5, 0.5],
            &[0.0],
            &[1.0],
            &[1],
            1,
            1,
            2,
        )
        .unwrap();
    assert_eq!(r, (-1, -1));
    // exact tie between identical servers: lowest index wins, in both
    let avail = vec![0.5f32, 0.5, 0.5, 0.5, 0.5, 0.5];
    let demand = vec![0.25f32, 0.25];
    let native =
        picker::sched_step(&avail, &demand, &[0.0], &[1.0], &[1], 1, 3, 2);
    let xla = rt
        .sched_step(&avail, &demand, &[0.0], &[1.0], &[1], 1, 3, 2)
        .unwrap();
    assert_eq!(native, xla);
    assert_eq!(xla.1, 0);
}

#[test]
fn sched_loop_batched_state_identical() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Pcg32::seeded(107);
    for trial in 0..25 {
        let n = 2 + rng.below(14);
        let k = 4 + rng.below(100);
        let m = 2;
        let (avail, demand, _share, weight, _active) =
            random_instance(&mut rng, n, k, m, false);
        let share = vec![0.0f32; n];
        let pending: Vec<i32> =
            (0..n).map(|_| rng.below(6) as i32).collect();
        let steps = rt.loop_steps(n, k, m).expect("loop variant");

        // native replay
        let mut av_n = avail.clone();
        let mut sh_n = share.clone();
        let mut pe_n = pending.clone();
        let dec_n = picker::sched_loop(
            &mut av_n, &demand, &mut sh_n, &weight, &mut pe_n, n, k, m, steps,
        );

        let out = rt
            .sched_loop(&avail, &demand, &share, &weight, &pending, n, k, m)
            .expect("xla loop");
        assert_eq!(out.decisions, dec_n, "trial {trial} decisions");
        assert_eq!(out.pending, pe_n, "trial {trial} pending");
        for (a, b) in out.avail.iter().zip(&av_n) {
            assert!((a - b).abs() < 1e-5, "trial {trial} avail {a} vs {b}");
        }
        for (a, b) in out.share.iter().zip(&sh_n) {
            assert!((a - b).abs() < 1e-5, "trial {trial} share {a} vs {b}");
        }
    }
}

/// The XLA-backed scheduler policy plays a whole (small) simulation and
/// lands on the same placement count as the native policy.
#[test]
fn xla_scheduler_in_simulation() {
    use drfh::cluster::Cluster;
    use drfh::sched::{BestFitDrfh, XlaBestFit};
    use drfh::sim::{run, SimOpts};
    use drfh::workload::{GoogleLikeConfig, TraceGenerator};
    use std::sync::Arc;

    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Pcg32::seeded(109);
    let cluster = Cluster::google_sample(50, &mut rng);
    let gen = TraceGenerator::new(GoogleLikeConfig {
        users: 6,
        duration: 1_500.0,
        jobs_per_user: 3.0,
        max_tasks_per_job: 40,
        ..Default::default()
    });
    let trace = gen.generate(7);
    let opts =
        SimOpts {
        horizon: 1_500.0,
        sample_dt: 50.0,
        track_user_series: false,
        ..SimOpts::default()
    };
    let native =
        run(cluster.clone(), &trace, Box::new(BestFitDrfh::default()), opts.clone());
    let xla = run(
        cluster,
        &trace,
        Box::new(XlaBestFit::new(Arc::new(rt))),
        opts,
    );
    // decision parity implies equal placement counts; minor f32-vs-f64
    // availability drift can move a task or two at the margin
    let diff =
        (native.tasks_placed as i64 - xla.tasks_placed as i64).abs();
    assert!(
        diff <= 2,
        "native {} vs xla {} placements",
        native.tasks_placed,
        xla.tasks_placed
    );
}
