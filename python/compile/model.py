"""L2: the DRFH scheduling decision as a JAX computation.

Composes the two Pallas kernels (kernels/bestfit.py, kernels/dominant.py)
into the progressive-filling decision the Rust coordinator executes on its
hot path:

  * ``sched_step``  — one decision: (avail, demand, share, weight, active)
                      -> (user, server), both -1 when nothing can be placed.
  * ``sched_loop``  — ``steps`` consecutive decisions with in-graph state
                      updates, so the coordinator can amortize one PJRT call
                      over a whole batch of placements.

Everything here is lowered ONCE by aot.py into artifacts/*.hlo.txt; Python
never runs at serving time.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from compile.kernels import bestfit, dominant


def sched_step(avail, demand, share, weight, active):
    """One progressive-filling decision (Pallas-backed).

    Args:
      avail:  f32[k, m] per-server available resources.
      demand: f32[n, m] per-user per-task demands.
      share:  f32[n] current global dominant shares.
      weight: f32[n] positive user weights.
      active: i32[n] nonzero iff the user has pending tasks.

    Returns:
      (u i32[1], s i32[1]): chosen user and server, -1/-1 if no placement
      is possible.
    """
    best_h, best_server = bestfit.score_servers(avail, demand)
    eligible = (jnp.asarray(active, jnp.int32) != 0) & jnp.isfinite(best_h)
    u = dominant.select_user(share, weight, eligible.astype(jnp.int32))
    uu = jnp.maximum(u[0], 0)
    s = jnp.where(u[0] >= 0, best_server[uu], jnp.int32(-1))
    return u, s.reshape((1,))


def sched_loop(avail, demand, share, weight, pending, *, steps):
    """``steps`` consecutive decisions with in-graph state updates.

    Args:
      avail: f32[k, m]; demand: f32[n, m]; share: f32[n]; weight: f32[n];
      pending: i32[n] tasks not yet placed; steps: static int.

    Returns:
      decisions i32[steps, 2] ((user, server) rows, -1/-1 no-ops),
      updated avail f32[k, m], share f32[n], pending i32[n].
    """
    avail = jnp.asarray(avail, jnp.float32)
    demand = jnp.asarray(demand, jnp.float32)
    share = jnp.asarray(share, jnp.float32)
    weight = jnp.asarray(weight, jnp.float32)
    pending = jnp.asarray(pending, jnp.int32)
    dom = jnp.max(demand, axis=1)  # per-task dominant-resource demand

    def body(t, state):
        avail, share, pending, decisions = state
        active = (pending > 0).astype(jnp.int32)
        u, s = sched_step(avail, demand, share, weight, active)
        u, s = u[0], s[0]
        ok = u >= 0
        uu = jnp.maximum(u, 0)
        ss = jnp.maximum(s, 0)
        delta = jnp.where(ok, 1.0, 0.0).astype(jnp.float32)
        avail = avail.at[ss].add(-demand[uu] * delta)
        share = share.at[uu].add(dom[uu] * delta)
        pending = pending.at[uu].add(jnp.where(ok, -1, 0).astype(jnp.int32))
        decisions = decisions.at[t].set(
            jnp.where(ok, jnp.stack([u, s]), jnp.array([-1, -1], jnp.int32))
        )
        return avail, share, pending, decisions

    decisions = jnp.full((steps, 2), -1, jnp.int32)
    avail, share, pending, decisions = lax.fori_loop(
        0, steps, body, (avail, share, pending, decisions)
    )
    return decisions, avail, share, pending
