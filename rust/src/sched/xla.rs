//! XLA-backed Best-Fit DRFH: the same policy as
//! [`super::BestFitDrfh`], but every decision is computed by the
//! AOT-compiled Pallas/JAX kernel through the PJRT runtime.
//!
//! Used to (a) prove the three layers compose — decision-for-decision
//! parity with the native policy is asserted in
//! `rust/tests/picker_parity.rs` — and (b) batch placements: the
//! `sched_loop` artifact performs up to 64 decisions per PJRT call for
//! coordinator-style workloads (see `coordinator`).

use super::{Pick, Scheduler, UserState};
use crate::cluster::Cluster;
use crate::runtime::XlaRuntime;
use std::sync::Arc;

/// Best-Fit DRFH evaluated by the XLA runtime.
pub struct XlaBestFit {
    rt: Arc<XlaRuntime>,
    /// scratch buffers reused across picks
    avail: Vec<f32>,
    demand: Vec<f32>,
    share: Vec<f32>,
    weight: Vec<f32>,
    active: Vec<i32>,
}

impl XlaBestFit {
    pub fn new(rt: Arc<XlaRuntime>) -> Self {
        XlaBestFit {
            rt,
            avail: Vec::new(),
            demand: Vec::new(),
            share: Vec::new(),
            weight: Vec::new(),
            active: Vec::new(),
        }
    }

    fn fill_buffers(
        &mut self,
        cluster: &Cluster,
        users: &[UserState],
        eligible: &[bool],
    ) {
        let m = cluster.dims();
        self.avail.clear();
        for s in &cluster.servers {
            let a = s.available();
            for r in 0..m {
                self.avail.push(a[r] as f32);
            }
        }
        self.demand.clear();
        self.share.clear();
        self.weight.clear();
        self.active.clear();
        for (i, u) in users.iter().enumerate() {
            for r in 0..m {
                self.demand.push(u.demand[r] as f32);
            }
            self.share.push(u.dom_share as f32);
            self.weight.push(u.weight as f32);
            self.active.push(i32::from(u.pending > 0 && eligible[i]));
        }
    }
}

// Documented exemption: the parity reference for the XLA picker is
// the native BestFitDrfh decision path itself, asserted trial-by-trial
// in `drfh picker-check` and `tests/picker_parity.rs` — a `naive()`
// constructor here would duplicate that reference.
// lint:allow(naive-parity)
impl Scheduler for XlaBestFit {
    fn name(&self) -> &'static str {
        "bestfit-drfh-xla"
    }

    fn pick(
        &mut self,
        cluster: &Cluster,
        users: &[UserState],
        eligible: &[bool],
    ) -> Pick {
        self.fill_buffers(cluster, users, eligible);
        let (u, s) = self
            .rt
            .sched_step(
                &self.avail,
                &self.demand,
                &self.share,
                &self.weight,
                &self.active,
                users.len(),
                cluster.len(),
                cluster.dims(),
            )
            .expect("XLA sched_step failed");
        // the kernel already skips users with no feasible server, so a
        // negative result means nothing can be placed at all
        if u < 0 || s < 0 {
            Pick::Idle
        } else {
            Pick::Place { user: u as usize, server: s as usize }
        }
    }

    fn can_fit(
        &self,
        cluster: &Cluster,
        users: &[UserState],
        user: usize,
        server: usize,
    ) -> bool {
        cluster.servers[server].fits(&users[user].demand)
    }
}
