//! Best-Fit DRFH (paper Sec. V-B): serve the pending user with the
//! lowest weighted global dominant share, placing its task on the
//! feasible server minimizing the fitness heuristic
//! `H(i,l) = || D_i/D_i1 − c̄_l/c̄_l1 ||_1` (eq. (9)).
//!
//! If the lowest-share user fits nowhere the engine blocks it and asks
//! again, so progressive filling continues with the next-lowest user —
//! matching the fused XLA kernel's "min share among users with a fit"
//! semantics (see `runtime::picker`).
//!
//! §Perf: the default construction runs on the class-keyed
//! incremental index ([`crate::sched::users::ClassedShareIndex`] +
//! the per-demand-class [`index::PlacementIndex`]) fed by the
//! engine's place/complete/ready notifications, so per-event work
//! scales with distinct demand classes rather than user count;
//! [`BestFitDrfh::per_user`] keeps the PR 1 per-user index layout
//! and [`BestFitDrfh::naive`] the seed's linear scans — all three
//! bit-identical (parity proved in `tests/engine_parity.rs`).

use super::index::{self, IndexedCore, ScoreKind};
use super::{drain_by_picks, min_share_user, DrainCtx, Pick, Scheduler, UserState};
use crate::cluster::{Cluster, ResVec};

/// The Best-Fit DRFH policy.
///
/// Two progressive-filling variants (the paper leaves the blocked-user
/// case unspecified; its Fig. 4 equal-share trajectories imply the
/// strict reading, while the Fig. 5 utilization numbers imply the
/// work-conserving one — we implement both and ablate):
///
/// * **work-conserving** (default): when the lowest-share user fits on
///   no server, the next-lowest is served instead;
/// * **strict**: scheduling stalls until the lowest-share user fits,
///   keeping shares exactly equalized at the cost of utilization.
pub struct BestFitDrfh {
    /// Stall behind the lowest-share user instead of skipping it.
    pub strict: bool,
    /// The incremental decision core (default), or `None` for the
    /// reference linear scans. Both paths emit identical decisions.
    core: Option<IndexedCore>,
}

impl Default for BestFitDrfh {
    fn default() -> Self {
        BestFitDrfh {
            strict: false,
            core: Some(IndexedCore::new(ScoreKind::BestFit)),
        }
    }
}

impl BestFitDrfh {
    /// The strict (exactly-equalizing, non-work-conserving) variant.
    /// Strict filling ignores the engine's blocked set, so it runs on
    /// the reference scans.
    pub fn strict_filling() -> Self {
        BestFitDrfh { strict: true, core: None }
    }

    /// The seed's linear-scan path — the parity reference and the
    /// naive baseline in `benches/engine_scale.rs`.
    pub fn naive() -> Self {
        BestFitDrfh { strict: false, core: None }
    }

    /// The PR 1 per-user index layout (`ShareHeap` + one placement
    /// heap per user) — the scaling baseline in
    /// `benches/user_scale.rs` and the intermediate parity reference
    /// for the class-keyed default.
    pub fn per_user() -> Self {
        BestFitDrfh {
            strict: false,
            core: Some(IndexedCore::per_user(ScoreKind::BestFit)),
        }
    }

    /// Is this instance on the indexed hot path?
    pub fn is_indexed(&self) -> bool {
        self.core.is_some()
    }

    /// Is this instance on the class-keyed (interned) index?
    pub fn is_classed(&self) -> bool {
        self.core.as_ref().is_some_and(IndexedCore::is_classed)
    }
}

/// H(i, l): L1 distance between demand and availability profiles, both
/// normalized by their first component (paper eq. (9)).
pub fn fitness(demand: &ResVec, avail: &ResVec) -> f64 {
    let m = demand.dims();
    let dden = if demand[0] != 0.0 { demand[0] } else { 1.0 };
    let aden = if avail[0] != 0.0 { avail[0] } else { 1.0 };
    let mut h = 0.0;
    for r in 0..m {
        h += (demand[r] / dden - avail[r] / aden).abs();
    }
    h
}

/// Best feasible server for `demand`, lowest H then lowest index;
/// None when nothing fits. (§Perf: the per-server scoring is
/// [`index::score_server`], shared verbatim with the indexed path so
/// both argmins — including tie-breaks — are bit-identical.)
pub fn best_server(cluster: &Cluster, demand: &ResVec) -> Option<usize> {
    let dratio = index::dratio_of(demand);
    let mut best_h = f64::INFINITY;
    let mut best_l: Option<usize> = None;
    for (l, s) in cluster.servers.iter().enumerate() {
        if let Some(h) =
            index::score_server(ScoreKind::BestFit, demand, &dratio, s, l)
        {
            if h.total_cmp(&best_h) == std::cmp::Ordering::Less {
                best_h = h;
                best_l = Some(l);
            }
        }
    }
    best_l
}

impl Scheduler for BestFitDrfh {
    fn name(&self) -> &'static str {
        "bestfit-drfh"
    }

    fn pick(
        &mut self,
        cluster: &Cluster,
        users: &[UserState],
        eligible: &[bool],
    ) -> Pick {
        if self.strict {
            // strict progressive filling: nobody is served while the
            // lowest-share pending user fits nowhere
            let all = vec![true; users.len()];
            return match min_share_user(users, &all) {
                None => Pick::Idle,
                Some(u) => match best_server(cluster, &users[u].demand) {
                    Some(l) => Pick::Place { user: u, server: l },
                    None => Pick::Idle,
                },
            };
        }
        match &mut self.core {
            Some(core) => core.pick(cluster, users, eligible),
            None => match min_share_user(users, eligible) {
                None => Pick::Idle,
                Some(u) => match best_server(cluster, &users[u].demand) {
                    Some(l) => Pick::Place { user: u, server: l },
                    None => Pick::Blocked { user: u },
                },
            },
        }
    }

    /// Batched wave: one index refresh for the whole wave (strict and
    /// naive configurations stay on the single-pick reference loop).
    fn drain(&mut self, ctx: &mut dyn DrainCtx) {
        if self.strict || self.core.is_none() {
            drain_by_picks(self, ctx);
            return;
        }
        self.core.as_mut().expect("indexed core").drain(ctx);
    }

    fn can_fit(
        &self,
        cluster: &Cluster,
        users: &[UserState],
        user: usize,
        server: usize,
    ) -> bool {
        cluster.servers[server].fits(&users[user].demand)
    }

    fn on_place(&mut self, user: usize, server: usize) {
        if let Some(core) = &mut self.core {
            core.on_touch(user, server);
        }
    }

    fn on_complete(&mut self, user: usize, server: usize) {
        if let Some(core) = &mut self.core {
            core.on_touch(user, server);
        }
    }

    fn on_ready(&mut self, user: usize) {
        if let Some(core) = &mut self.core {
            core.on_ready(user);
        }
    }

    fn on_user_join(&mut self, user: usize) {
        if let Some(core) = &mut self.core {
            core.on_user_join(user);
        }
    }

    fn on_user_leave(&mut self, user: usize) {
        if let Some(core) = &mut self.core {
            core.on_user_leave(user);
        }
    }

    fn on_server_down(&mut self, server: usize) {
        if let Some(core) = &mut self.core {
            core.on_server_down(server);
        }
    }

    fn on_server_up(&mut self, server: usize) {
        if let Some(core) = &mut self.core {
            core.on_server_up(server);
        }
    }

    fn on_topology(&mut self, shards: usize) {
        if let Some(core) = &mut self.core {
            core.set_shards(shards);
        }
    }

    fn audit_indices(
        &mut self,
        cluster: &Cluster,
        users: &[UserState],
        eligible: &[bool],
    ) -> Result<(), String> {
        // the naive path has no index to drift
        match &mut self.core {
            Some(core) => core.audit_check(cluster, users, eligible),
            None => Ok(()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Server;

    fn users_fixture() -> Vec<UserState> {
        let total = ResVec::cpu_mem(14.0, 14.0);
        [ResVec::cpu_mem(0.2, 1.0), ResVec::cpu_mem(1.0, 0.2)]
            .iter()
            .map(|d| UserState {
                demand: *d,
                weight: 1.0,
                pending: 5,
                running: 0,
                dom_share: 0.0,
                usage: ResVec::zeros(2),
                dom_delta: d.div(&total).max(),
            })
            .collect()
    }

    #[test]
    fn fitness_prefers_matching_profile() {
        let demand = ResVec::cpu_mem(0.2, 1.0); // memory-heavy
        let mem_server = ResVec::cpu_mem(2.0, 12.0);
        let cpu_server = ResVec::cpu_mem(12.0, 2.0);
        assert!(fitness(&demand, &mem_server) < fitness(&demand, &cpu_server));
    }

    #[test]
    fn routes_fig1_users_to_matching_servers() {
        for mut sched in [
            BestFitDrfh::default(),
            BestFitDrfh::per_user(),
            BestFitDrfh::naive(),
        ] {
            let cluster = Cluster::fig1_example();
            let mut users = users_fixture();
            let all = [true, true];
            // equal shares: user 0 first (tie), routed to the memory
            // server
            assert_eq!(
                sched.pick(&cluster, &users, &all),
                Pick::Place { user: 0, server: 0 }
            );
            // raise user 0's share the way the engine does: bump
            // `running` and recompute `dom_share = running * dom_delta`
            // (the class-keyed path ranks through exactly this
            // invariant)
            users[0].running = 5;
            users[0].dom_share = 5.0 * users[0].dom_delta;
            sched.on_place(0, 0); // engine would notify; no commit here
            // now user 1 has the lower share: routed to the CPU server
            assert_eq!(
                sched.pick(&cluster, &users, &all),
                Pick::Place { user: 1, server: 1 }
            );
        }
    }

    #[test]
    fn blocked_when_min_share_user_fits_nowhere() {
        for mut sched in [BestFitDrfh::default(), BestFitDrfh::naive()] {
            let cluster =
                Cluster::new(vec![Server::new(ResVec::cpu_mem(0.6, 0.6))]);
            let mut users = users_fixture();
            users[0].demand = ResVec::cpu_mem(1.0, 1.0);
            users[1].demand = ResVec::cpu_mem(0.5, 0.5);
            users[1].dom_share = 0.9;
            // user 0 has min share but no fit -> Blocked
            assert_eq!(
                sched.pick(&cluster, &users, &[true, true]),
                Pick::Blocked { user: 0 }
            );
            // engine masks it out; next call places user 1
            assert_eq!(
                sched.pick(&cluster, &users, &[false, true]),
                Pick::Place { user: 1, server: 0 }
            );
        }
    }

    #[test]
    fn idle_when_no_pending() {
        for mut sched in [BestFitDrfh::default(), BestFitDrfh::naive()] {
            let cluster = Cluster::fig1_example();
            let mut users = users_fixture();
            users[0].pending = 0;
            users[1].pending = 0;
            assert_eq!(sched.pick(&cluster, &users, &[true, true]), Pick::Idle);
        }
    }

    #[test]
    fn can_fit_checks_demand() {
        let cluster = Cluster::fig1_example();
        let users = users_fixture();
        let sched = BestFitDrfh::default();
        assert!(sched.can_fit(&cluster, &users, 0, 0));
        let tiny = Cluster::new(vec![Server::new(ResVec::cpu_mem(0.1, 0.1))]);
        assert!(!sched.can_fit(&tiny, &users, 0, 0));
    }
}
