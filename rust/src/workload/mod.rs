//! Workloads: users, jobs, tasks, and the synthetic Google-like trace
//! generator.
//!
//! The paper's evaluation replays Google cluster-usage traces [Reiss et
//! al.]. Those traces are no longer distributed in the original form, so
//! we substitute a generator calibrated to the published statistics (see
//! DESIGN.md §4): server configurations come *verbatim* from Table I
//! (`cluster::GOOGLE_CLASSES`), user demand profiles span CPU-heavy,
//! memory-heavy, and balanced classes, jobs arrive as a Poisson process,
//! tasks-per-job follow a heavy-tailed (Zipf-like) law, and task
//! durations follow a bounded Pareto.

pub mod arena;
pub mod gen;
pub mod trace;

pub use arena::{intern_rows, DemandTable, TaskArena};
pub use gen::{
    generate_churn, generate_faults, ChurnGenConfig, FaultGenConfig,
    GoogleLikeConfig, TraceGenerator,
};
pub use trace::{JobSpec, TaskSpec, Trace, UserSpec};
