//! Minimal TOML-subset parser (substrate — crates.io is unreachable on
//! this image, so the `toml` crate is unavailable).
//!
//! Supported: `[section]` tables (one level), `key = value` with
//! strings (`"..."` / `'...'`), integers, floats, booleans, and `#`
//! comments. That covers the experiment config format documented in
//! `config.rs`.

use std::collections::BTreeMap;

/// A parsed scalar value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Num(f64),
    Bool(bool),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|x| x as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parsed document: section -> key -> value. Top-level keys live in
/// the "" section.
#[derive(Clone, Debug, Default)]
pub struct Doc {
    pub sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Doc {
    /// Look up `section.key`.
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section).and_then(|s| s.get(key))
    }

    pub fn get_f64(&self, section: &str, key: &str) -> Option<f64> {
        self.get(section, key).and_then(|v| v.as_f64())
    }

    pub fn get_usize(&self, section: &str, key: &str) -> Option<usize> {
        self.get(section, key).and_then(|v| v.as_usize())
    }

    pub fn get_str(&self, section: &str, key: &str) -> Option<&str> {
        self.get(section, key).and_then(|v| v.as_str())
    }

    pub fn get_bool(&self, section: &str, key: &str) -> Option<bool> {
        self.get(section, key).and_then(|v| v.as_bool())
    }
}

/// Parse a TOML-subset document.
pub fn parse(input: &str) -> Result<Doc, String> {
    let mut doc = Doc::default();
    let mut section = String::new();
    for (lineno, raw) in input.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| format!("line {}: bad section", lineno + 1))?
                .trim();
            section = name.to_string();
            doc.sections.entry(section.clone()).or_default();
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
        let key = key.trim().to_string();
        let value = parse_value(value.trim())
            .map_err(|e| format!("line {}: {e}", lineno + 1))?;
        doc.sections
            .entry(section.clone())
            .or_default()
            .insert(key, value);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // naive but sufficient: a '#' outside quotes starts a comment
    let mut in_str: Option<char> = None;
    for (i, c) in line.char_indices() {
        match (c, in_str) {
            ('"', None) => in_str = Some('"'),
            ('\'', None) => in_str = Some('\''),
            ('"', Some('"')) => in_str = None,
            ('\'', Some('\'')) => in_str = None,
            ('#', None) => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if let Some(rest) = s.strip_prefix('"') {
        return rest
            .strip_suffix('"')
            .map(|v| Value::Str(v.to_string()))
            .ok_or_else(|| "unterminated string".to_string());
    }
    if let Some(rest) = s.strip_prefix('\'') {
        return rest
            .strip_suffix('\'')
            .map(|v| Value::Str(v.to_string()))
            .ok_or_else(|| "unterminated string".to_string());
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    s.replace('_', "")
        .parse::<f64>()
        .map(Value::Num)
        .map_err(|_| format!("cannot parse value '{s}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_config_shape() {
        let doc = parse(
            r#"
            seed = 42          # top-level
            [cluster]
            servers = 2000
            [scheduler]
            policy = "slots"
            slots_per_max = 14
            [sim]
            horizon = 86400.0
            track = true
            "#,
        )
        .unwrap();
        assert_eq!(doc.get_f64("", "seed"), Some(42.0));
        assert_eq!(doc.get_usize("cluster", "servers"), Some(2000));
        assert_eq!(doc.get_str("scheduler", "policy"), Some("slots"));
        assert_eq!(doc.get_bool("sim", "track"), Some(true));
    }

    #[test]
    fn comments_and_strings_with_hash() {
        let doc = parse("name = \"a#b\" # trailing").unwrap();
        assert_eq!(doc.get_str("", "name"), Some("a#b"));
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(parse("[unclosed").is_err());
        assert!(parse("novalue").is_err());
        assert!(parse("x = @@").is_err());
    }

    #[test]
    fn underscored_numbers() {
        let doc = parse("big = 1_000_000").unwrap();
        assert_eq!(doc.get_f64("", "big"), Some(1e6));
    }
}
