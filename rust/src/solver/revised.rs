//! Sparse revised simplex with a warm-startable [`Solver`].
//!
//! The production pivot core behind the exact fluid DRFH allocator
//! (paper eq. (7) is a linear program) and the event-driven
//! `allocator::incremental` path. Same problem form as the dense
//! reference [`super::simplex::solve`]:
//!
//! ```text
//!   maximize    c · x
//!   subject to  A_ub x <= b_ub
//!               A_eq x  = b_eq
//!               x >= 0
//! ```
//!
//! ## Revised simplex, product form
//!
//! The dense tableau costs O(rows · cols) *per pivot* because it
//! updates every entry it will mostly never read. The revised method
//! keeps the constraint matrix in sparse CSC columns ([`Cols`]) —
//! untouched for the whole solve — and represents the basis inverse as
//! a **product form**: an eta file, one [`Eta`] (elementary
//! Gauss-Jordan column) per factorization elimination or pivot.
//! Per iteration it computes only what the pivot rules read:
//!
//! * pricing: one BTRAN (`y = B^-T c_B`, etas applied in reverse)
//!   then a sparse dot per candidate column for the reduced cost;
//! * ratio test: one FTRAN (`d = B^-1 A_q`, etas applied in order)
//!   for the entering column only;
//! * update: O(nnz(d)) on the basic solution plus one appended eta.
//!
//! The eta file is refactorized from the current basis set every
//! [`ETA_REFRESH`] pivots (or on warm start), which both bounds the
//! FTRAN/BTRAN cost and washes out accumulated floating-point drift —
//! the same role the dense path's full rebuild played. A refresh that
//! turns out numerically singular is skipped and retried later; the
//! eta file it would have replaced is still valid.
//!
//! Pivot *rules* are byte-for-byte the dense reference's: Dantzig
//! entering (most negative reduced cost, first-of-max wins) with a
//! stall detector that falls back to Bland's rule, min-ratio leaving
//! with ties broken toward the lowest basic column id, and the same
//! column layout (structural | slacks | artificials), so the two cores
//! agree to 1e-9 on the fuzz corpus (`tests/solver_fuzz.rs`) and the
//! dense path stays in-tree as the parity reference.
//!
//! ## Basis-reuse invariants (unchanged from the dense `Solver`)
//!
//! The recorded basis is a **set of column identities** — structural
//! variable, the slack of row *r*, or the phase-1 artificial of row
//! *r* (kept only as a placeholder for redundant rows) — never
//! positions or numeric state. Every warm solve rebuilds the sparse
//! columns from the *current* row data and refactorizes the recorded
//! set (partial row pivoting), so no numerical error survives across
//! solves; only the combinatorial basis does. Edits maintain the set:
//! an appended `<=` row contributes its own slack, a deactivated row
//! retires its own slack/artificial. Edits that cannot keep the set
//! valid (appending an equality row, fixing a basic variable,
//! deactivating a row whose slack is not basic) invalidate it — the
//! next solve is cold. The warm path never trades correctness for
//! speed: a singular refactorization, a basis that is neither primal-
//! nor dual-feasible, a dual-simplex iteration-cap hit (counted in
//! [`SolveStats::dual_cap_hits`]), or a nonzero artificial placeholder
//! all fall back to the cold two-phase solve.
//!
//! Sized for the class-collapsed allocator: LP dimensions scale with
//! (server classes × demand classes), independent of user count, and
//! each event re-solve is a refactorization plus a handful of
//! dual/primal pivots.

use super::simplex::{Lp, LpResult, PivotCounts, EPS};

/// Minimum acceptable pivot magnitude when factorizing a basis;
/// anything smaller is treated as singular (cold fallback on the warm
/// path, skipped refresh mid-solve).
const SINGULAR_EPS: f64 = 1e-8;

/// Refactorize the eta file once it has grown this many etas past the
/// last factorization — bounds FTRAN/BTRAN cost and numerical drift.
const ETA_REFRESH: usize = 64;

/// Handle to a structural variable of a [`Solver`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct VarId(pub(crate) usize);

impl VarId {
    /// Index of the variable in solution vectors returned by
    /// [`Solver::solve`].
    #[inline]
    pub fn index(&self) -> usize {
        self.0
    }
}

/// Handle to a constraint row of a [`Solver`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RowId(pub(crate) usize);

impl RowId {
    pub fn index(&self) -> usize {
        self.0
    }
}

/// Cumulative [`Solver`] accounting across solves.
#[derive(Clone, Copy, Debug, Default)]
pub struct SolveStats {
    pub solves: u64,
    pub warm_solves: u64,
    pub cold_solves: u64,
    /// Warm attempts abandoned to a cold solve (singular basis, lost
    /// primal+dual feasibility, nonzero artificial placeholder, ...).
    pub fallbacks: u64,
    /// Search pivots (phase-1 + phase-2 + dual) across all solves.
    pub pivots: u64,
    /// Basis factorization eliminations across all solves (warm
    /// refactorizations plus in-solve eta-file refreshes).
    pub factor_elims: u64,
    pub stall_events: u64,
    /// Dual-simplex repair attempts that exhausted the iteration cap
    /// (`200 + 4·(rows+cols)`) and fell back to a cold solve. A warm
    /// path that stops saving pivots shows up here before it shows up
    /// in wall-clock — surfaced in the `allocator_scale` bench meta.
    pub dual_cap_hits: u64,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum RowKind {
    Le,
    Eq,
}

/// One column identity of the recorded basis set (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Basic {
    /// Structural variable (index into the solver's variable list).
    Var(usize),
    /// Slack of row `r` (also stands in for the surplus of a row the
    /// cold path flipped: the surplus of `-a·x <= -b` *is* `b - a·x`,
    /// the same quantity as the slack of `a·x <= b`).
    Slack(usize),
    /// Phase-1 artificial of row `r`, basic at zero on a redundant row.
    Art(usize),
}

/// One constraint row, stored sparsely: `(var, coeff)` pairs sorted by
/// variable id, no explicit zeros. Appending a variable to the solver
/// therefore costs nothing per row, and a class-collapsed allocator
/// row touches only the few variables of its own class block.
#[derive(Clone, Debug)]
struct RowData {
    coeffs: Vec<(u32, f64)>,
    rhs: f64,
    kind: RowKind,
    active: bool,
}

impl RowData {
    /// Set one coefficient, keeping the pair list sorted and zero-free.
    fn set(&mut self, v: usize, a: f64) {
        let vid = v as u32;
        match self.coeffs.binary_search_by_key(&vid, |&(i, _)| i) {
            Ok(k) => {
                if a == 0.0 {
                    self.coeffs.remove(k);
                } else {
                    self.coeffs[k].1 = a;
                }
            }
            Err(k) => {
                if a != 0.0 {
                    self.coeffs.insert(k, (vid, a));
                }
            }
        }
    }
}

// ------------------------------------------------------ sparse kernel

/// CSC-style sparse column storage for one solve's constraint matrix:
/// structural columns, slack columns (±1), artificial columns (+1),
/// built once per solve and never modified.
struct Cols {
    ptr: Vec<usize>,
    idx: Vec<u32>,
    val: Vec<f64>,
}

impl Cols {
    fn from_entries(columns: Vec<Vec<(u32, f64)>>) -> Self {
        let nnz: usize = columns.iter().map(Vec::len).sum();
        let mut ptr = Vec::with_capacity(columns.len() + 1);
        let mut idx = Vec::with_capacity(nnz);
        let mut val = Vec::with_capacity(nnz);
        ptr.push(0);
        for col in columns {
            for (i, v) in col {
                idx.push(i);
                val.push(v);
            }
            ptr.push(idx.len());
        }
        Cols { ptr, idx, val }
    }

    /// Number of columns.
    #[inline]
    fn n(&self) -> usize {
        self.ptr.len() - 1
    }

    /// Sparse dot of column `j` with a dense vector.
    #[inline]
    fn dot(&self, j: usize, y: &[f64]) -> f64 {
        let mut s = 0.0;
        for k in self.ptr[j]..self.ptr[j + 1] {
            s += self.val[k] * y[self.idx[k] as usize];
        }
        s
    }

    /// Scatter column `j` into a dense work vector (zeroed first).
    fn scatter(&self, j: usize, w: &mut [f64]) {
        for x in w.iter_mut() {
            *x = 0.0;
        }
        for k in self.ptr[j]..self.ptr[j + 1] {
            w[self.idx[k] as usize] = self.val[k];
        }
    }
}

/// One elementary Gauss-Jordan column of the product-form inverse:
/// identity with column `row` replaced by the pivot column (`pivot` on
/// the diagonal, `nz` off-diagonal).
struct Eta {
    row: u32,
    pivot: f64,
    nz: Vec<(u32, f64)>,
}

impl Eta {
    fn from_col(r: usize, w: &[f64]) -> Self {
        let mut nz = Vec::new();
        for (i, &v) in w.iter().enumerate() {
            if i != r && v != 0.0 {
                nz.push((i as u32, v));
            }
        }
        Eta { row: r as u32, pivot: w[r], nz }
    }
}

/// FTRAN: `w := B^-1 w`, applying etas in creation order.
fn ftran(etas: &[Eta], w: &mut [f64]) {
    for e in etas {
        let r = e.row as usize;
        let t = w[r] / e.pivot;
        if t != 0.0 {
            for &(i, d) in &e.nz {
                w[i as usize] -= d * t;
            }
        }
        w[r] = t;
    }
}

/// BTRAN: `w := B^-T w`, applying etas transposed in reverse order.
fn btran(etas: &[Eta], w: &mut [f64]) {
    for e in etas.iter().rev() {
        let r = e.row as usize;
        let mut s = w[r];
        for &(i, d) in &e.nz {
            s -= d * w[i as usize];
        }
        w[r] = s / e.pivot;
    }
}

enum DualOutcome {
    /// Primal feasibility restored after `n` pivots.
    Feasible(u32),
    /// A row certifies primal infeasibility (after `n` pivots).
    Infeasible(u32),
    /// Pivot budget exhausted after `n` pivots — caller should fall
    /// back to cold (and still account for the wasted pivots).
    GaveUp(u32),
}

/// One solve's working state: sparse columns, raw rhs, phase cost,
/// basis (column index per row), basic solution, and the eta file.
struct Engine {
    m: usize,
    cols: Cols,
    /// Raw right-hand side (never modified; `xb` is re-derived from it
    /// on every refactorization).
    b: Vec<f64>,
    /// Phase cost per column (phase-1: -1 on artificials; phase-2: the
    /// objective over structural columns).
    cost: Vec<f64>,
    /// Basic column per row.
    basic: Vec<usize>,
    /// Basic solution `x_B = B^-1 b`, pivot-updated.
    xb: Vec<f64>,
    etas: Vec<Eta>,
    /// Refactorize once `etas.len()` reaches this.
    refresh_at: usize,
    /// Factorization eliminations performed (one eta per basic column).
    factor: u32,
}

impl Engine {
    fn new(cols: Cols, b: Vec<f64>, cost: Vec<f64>) -> Self {
        let m = b.len();
        debug_assert_eq!(cost.len(), cols.n());
        Engine {
            m,
            cols,
            xb: b.clone(),
            b,
            cost,
            basic: vec![usize::MAX; m],
            etas: Vec::new(),
            refresh_at: ETA_REFRESH,
            factor: 0,
        }
    }

    /// `B^-1 A_j` for one column.
    fn ftran_col(&self, j: usize) -> Vec<f64> {
        let mut w = vec![0.0; self.m];
        self.cols.scatter(j, &mut w);
        ftran(&self.etas, &mut w);
        w
    }

    /// Row `r` of `B^-1` (as a dense vector): `B^-T e_r`.
    fn btran_unit(&self, r: usize) -> Vec<f64> {
        let mut w = vec![0.0; self.m];
        w[r] = 1.0;
        btran(&self.etas, &mut w);
        w
    }

    /// Simplex multipliers `y = B^-T c_B`.
    fn multipliers(&self) -> Vec<f64> {
        let mut y = vec![0.0; self.m];
        for r in 0..self.m {
            y[r] = self.cost[self.basic[r]];
        }
        btran(&self.etas, &mut y);
        y
    }

    /// Reduced cost of column `j` (dense tableau's objective row):
    /// `y·A_j - c_j`; entering candidates are `< -EPS`.
    #[inline]
    fn row0(&self, j: usize, y: &[f64]) -> f64 {
        self.cols.dot(j, y) - self.cost[j]
    }

    /// Current objective value `c_B · x_B` under the phase cost.
    fn obj(&self) -> f64 {
        (0..self.m).map(|r| self.cost[self.basic[r]] * self.xb[r]).sum()
    }

    /// Factorize the basis set `set` (column ids, in recorded order)
    /// from scratch: Gauss-Jordan with partial row pivoting, one eta
    /// per column, re-deriving the row assignment. Commits the new eta
    /// file, basis, and `x_B = B^-1 b` only on success; on a singular
    /// set the engine state is untouched and `false` is returned.
    fn factorize(&mut self, set: &[usize]) -> bool {
        debug_assert_eq!(set.len(), self.m);
        let mut etas: Vec<Eta> = Vec::with_capacity(self.m);
        let mut basic = vec![usize::MAX; self.m];
        let mut assigned = vec![false; self.m];
        let mut w = vec![0.0; self.m];
        let mut factor = 0u32;
        for &cj in set {
            self.cols.scatter(cj, &mut w);
            ftran(&etas, &mut w);
            let mut best_r = usize::MAX;
            let mut best_a = SINGULAR_EPS;
            for (r, done) in assigned.iter().enumerate() {
                if !done {
                    let a = w[r].abs();
                    if a > best_a {
                        best_a = a;
                        best_r = r;
                    }
                }
            }
            if best_r == usize::MAX {
                return false; // singular
            }
            etas.push(Eta::from_col(best_r, &w));
            assigned[best_r] = true;
            basic[best_r] = cj;
            factor += 1;
        }
        self.etas = etas;
        self.basic = basic;
        self.factor += factor;
        self.refresh_at = self.etas.len() + ETA_REFRESH;
        let mut xb = self.b.clone();
        ftran(&self.etas, &mut xb);
        self.xb = xb;
        true
    }

    /// Refactorize once the eta file has grown past `refresh_at`. A
    /// numerically singular refresh is skipped (the old eta file is
    /// still a valid inverse) and retried another `ETA_REFRESH` pivots
    /// later rather than every iteration.
    fn maybe_refresh(&mut self) {
        if self.etas.len() < self.refresh_at {
            return;
        }
        let set = self.basic.clone();
        if !self.factorize(&set) {
            self.refresh_at = self.etas.len() + ETA_REFRESH;
        }
    }

    /// Pivot column `q` in at row `pr`, given its FTRANed column `d`:
    /// update `x_B`, append one eta, reassign the row.
    fn pivot(&mut self, pr: usize, q: usize, d: &[f64]) {
        // the entering guard tests d[pr] against EPS *before* the
        // FTRAN is reused here, so only exact zero would divide badly
        debug_assert!(d[pr] != 0.0);
        let t = self.xb[pr] / d[pr];
        let mut nz = Vec::new();
        for (i, &di) in d.iter().enumerate() {
            if i != pr && di != 0.0 {
                self.xb[i] -= di * t;
                nz.push((i as u32, di));
            }
        }
        self.xb[pr] = t;
        self.etas.push(Eta { row: pr as u32, pivot: d[pr], nz });
        self.basic[pr] = q;
    }

    /// Primal simplex on the current phase cost, maximizing. Dantzig
    /// entering rule; a stall (no objective improvement for
    /// `rows + 16` consecutive pivots, rows counted as the dense
    /// tableau did — m + 1) switches to Bland's rule until the next
    /// strict improvement, which guarantees termination on degenerate
    /// instances. Returns `(bounded, pivots, stalls)`.
    fn optimize(&mut self, allowed_cols: usize) -> (bool, u32, u32) {
        let mut pivots = 0u32;
        let mut stalls = 0u32;
        let mut bland = false;
        let mut since_improve = 0u32;
        let stall_limit = (self.m + 1) as u32 + 16;
        let mut last_obj = self.obj();
        loop {
            self.maybe_refresh();
            let y = self.multipliers();
            // entering column: reduced profit must be positive
            let mut enter = None;
            if bland {
                // lowest-index rule (anti-cycling)
                for j in 0..allowed_cols {
                    if self.row0(j, &y) < -EPS {
                        enter = Some(j);
                        break;
                    }
                }
            } else {
                // most negative reduced cost
                let mut best = -EPS;
                for j in 0..allowed_cols {
                    let v = self.row0(j, &y);
                    if v < best {
                        best = v;
                        enter = Some(j);
                    }
                }
            }
            let Some(q) = enter else { return (true, pivots, stalls) };
            let d = self.ftran_col(q);
            // leaving: min ratio, ties -> lowest basic column (Bland)
            let mut leave: Option<(usize, f64)> = None;
            for r in 0..self.m {
                let a = d[r];
                if a > EPS {
                    let ratio = self.xb[r] / a;
                    match leave {
                        None => leave = Some((r, ratio)),
                        Some((br, bratio)) => {
                            if ratio < bratio - EPS
                                || (ratio < bratio + EPS
                                    && self.basic[r] < self.basic[br])
                            {
                                leave = Some((r, ratio));
                            }
                        }
                    }
                }
            }
            let Some((pr, _)) = leave else { return (false, pivots, stalls) };
            self.pivot(pr, q, &d);
            pivots += 1;
            let obj = self.obj();
            if obj > last_obj + EPS {
                last_obj = obj;
                since_improve = 0;
                bland = false;
            } else {
                since_improve += 1;
                if !bland && since_improve >= stall_limit {
                    bland = true;
                    stalls += 1;
                }
            }
        }
    }

    /// Dual simplex: restore `x_B >= 0` while keeping all reduced
    /// costs over the first `allowed_cols` columns non-negative.
    /// Requires a dual-feasible start. Artificial placeholder columns
    /// (beyond `allowed_cols`) are not real variables and are excluded
    /// from the entering set *and* from the infeasibility certificate.
    /// The iteration cap matches the dense reference's operand sizes
    /// (tableau rows m+1, columns incl. rhs).
    fn dual_simplex(&mut self, allowed_cols: usize) -> DualOutcome {
        let mut pivots = 0u32;
        let cap =
            200 + 4 * ((self.m as u32 + 1) + (self.cols.n() as u32 + 1));
        loop {
            self.maybe_refresh();
            // leaving row: most negative basic value
            let mut leave: Option<(usize, f64)> = None;
            for r in 0..self.m {
                let b = self.xb[r];
                if b < -EPS && leave.map_or(true, |(_, bb)| b < bb) {
                    leave = Some((r, b));
                }
            }
            let Some((pr, _)) = leave else {
                return DualOutcome::Feasible(pivots);
            };
            // entering: min |reduced cost / coeff| over negative
            // coefficients (first index wins ties — Bland-ish)
            let y = self.multipliers();
            let rho = self.btran_unit(pr);
            let mut enter: Option<(usize, f64)> = None;
            for j in 0..allowed_cols {
                let a = self.cols.dot(j, &rho);
                if a < -EPS {
                    let ratio = self.row0(j, &y) / (-a);
                    if enter.map_or(true, |(_, br)| ratio < br - EPS) {
                        enter = Some((j, ratio));
                    }
                }
            }
            let Some((q, _)) = enter else {
                return DualOutcome::Infeasible(pivots);
            };
            let d = self.ftran_col(q);
            self.pivot(pr, q, &d);
            pivots += 1;
            if pivots > cap {
                return DualOutcome::GaveUp(pivots);
            }
        }
    }

    /// Drive basic artificials (columns `>= art_start`) out of the
    /// basis after phase 1: pivot in the first eligible structural or
    /// slack column per row; a row with none is redundant and keeps
    /// its artificial basic at 0. Uncounted deterministic cleanup,
    /// like the dense reference.
    fn drive_out_artificials(&mut self, art_start: usize) {
        for r in 0..self.m {
            if self.basic[r] >= art_start {
                let rho = self.btran_unit(r);
                for c in 0..art_start {
                    if self.cols.dot(c, &rho).abs() > EPS {
                        let d = self.ftran_col(c);
                        self.pivot(r, c, &d);
                        break;
                    }
                }
            }
        }
    }
}

// ----------------------------------------------------------- Solver

/// Search pivots burnt by an abandoned warm attempt, carried into the
/// cold fallback so per-solve pivot reporting never undercounts the
/// warm path's true work: `(dual, phase2, stalls)`.
type WastedPivots = (u32, u32, u32);

/// A stateful LP that records its optimal basis and re-solves
/// incrementally after edits, on the sparse revised-simplex core. See
/// the module docs for the basis-reuse invariants;
/// [`super::simplex::solve`] stays as the one-shot dense parity
/// reference.
#[derive(Clone, Debug)]
pub struct Solver {
    obj: Vec<f64>,
    fixed: Vec<Option<f64>>,
    rows: Vec<RowData>,
    basis: Vec<Basic>,
    has_basis: bool,
    stats: SolveStats,
}

impl Default for Solver {
    fn default() -> Self {
        Self::new()
    }
}

impl Solver {
    /// An empty problem (no variables, no rows).
    pub fn new() -> Self {
        Solver {
            obj: Vec::new(),
            fixed: Vec::new(),
            rows: Vec::new(),
            basis: Vec::new(),
            has_basis: false,
            stats: SolveStats::default(),
        }
    }

    /// Build a solver from a one-shot [`Lp`] (variables in order, then
    /// the `a_ub` rows, then the `a_eq` rows).
    pub fn from_lp(lp: &Lp) -> Self {
        let n = lp.n;
        assert_eq!(lp.c.len(), n);
        assert_eq!(lp.a_ub.len(), lp.b_ub.len());
        assert_eq!(lp.a_eq.len(), lp.b_eq.len());
        for row in lp.a_ub.iter().chain(&lp.a_eq) {
            assert_eq!(row.len(), n);
        }
        let mut s = Solver::new();
        let vars: Vec<VarId> = lp.c.iter().map(|&c| s.add_var(c)).collect();
        for (a, &b) in lp.a_ub.iter().zip(&lp.b_ub) {
            let coeffs: Vec<(VarId, f64)> =
                vars.iter().zip(a).map(|(&v, &x)| (v, x)).collect();
            s.add_row_le(&coeffs, b);
        }
        for (a, &b) in lp.a_eq.iter().zip(&lp.b_eq) {
            let coeffs: Vec<(VarId, f64)> =
                vars.iter().zip(a).map(|(&v, &x)| (v, x)).collect();
            s.add_row_eq(&coeffs, b);
        }
        s
    }

    /// Number of structural variables.
    pub fn num_vars(&self) -> usize {
        self.obj.len()
    }

    /// Cumulative solve accounting.
    pub fn stats(&self) -> SolveStats {
        self.stats
    }

    /// True when the next [`Solver::solve`] will attempt a warm start.
    pub fn has_warm_basis(&self) -> bool {
        self.has_basis
    }

    /// Append a structural variable (objective coefficient `obj`,
    /// zero coefficients in every existing row — free, since rows are
    /// sparse). Keeps any recorded basis valid: the new variable
    /// enters nonbasic at 0.
    pub fn add_var(&mut self, obj: f64) -> VarId {
        let id = self.obj.len();
        self.obj.push(obj);
        self.fixed.push(None);
        VarId(id)
    }

    fn add_row(&mut self, kind: RowKind, rhs: f64) -> RowId {
        let id = self.rows.len();
        self.rows.push(RowData {
            coeffs: Vec::new(),
            rhs,
            kind,
            active: true,
        });
        if self.has_basis {
            match kind {
                // the new row's own slack joins the basis (B gains a
                // unit row/column: still nonsingular); a negative
                // residual is repaired by the dual simplex
                RowKind::Le => self.basis.push(Basic::Slack(id)),
                // an equality row has no slack to hide behind
                RowKind::Eq => self.invalidate_basis(),
            }
        }
        RowId(id)
    }

    /// Append a `coeffs · x <= rhs` row.
    pub fn add_row_le(&mut self, coeffs: &[(VarId, f64)], rhs: f64) -> RowId {
        let r = self.add_row(RowKind::Le, rhs);
        for &(v, a) in coeffs {
            self.rows[r.0].set(v.0, a);
        }
        r
    }

    /// Append a `coeffs · x == rhs` row (invalidates any warm basis —
    /// prefer paired `<=` rows for incrementally maintained problems).
    pub fn add_row_eq(&mut self, coeffs: &[(VarId, f64)], rhs: f64) -> RowId {
        let r = self.add_row(RowKind::Eq, rhs);
        for &(v, a) in coeffs {
            self.rows[r.0].set(v.0, a);
        }
        r
    }

    /// Replace a row's right-hand side. Basis-preserving.
    pub fn set_rhs(&mut self, r: RowId, rhs: f64) {
        self.rows[r.0].rhs = rhs;
    }

    /// Replace one coefficient of a row. Basis-preserving (the warm
    /// refactorization revalidates numerically).
    pub fn set_coeff(&mut self, r: RowId, v: VarId, a: f64) {
        self.rows[r.0].set(v.0, a);
    }

    /// Replace a variable's objective coefficient. Basis-preserving.
    pub fn set_obj(&mut self, v: VarId, c: f64) {
        self.obj[v.0] = c;
    }

    /// Drop a row from the problem (it can be re-activated later).
    pub fn deactivate_row(&mut self, r: RowId) {
        if !self.rows[r.0].active {
            return;
        }
        self.rows[r.0].active = false;
        if self.has_basis {
            // retire the row's own slack/artificial from the basis; if
            // neither is basic (the row was tight) the set no longer
            // matches the rows and the next solve is cold
            if let Some(pos) = self.basis.iter().position(
                |b| matches!(b, Basic::Slack(i) | Basic::Art(i) if *i == r.0),
            ) {
                self.basis.swap_remove(pos);
            } else {
                self.invalidate_basis();
            }
        }
    }

    /// Re-introduce a previously deactivated row.
    pub fn activate_row(&mut self, r: RowId) {
        if self.rows[r.0].active {
            return;
        }
        self.rows[r.0].active = true;
        if self.has_basis {
            match self.rows[r.0].kind {
                RowKind::Le => self.basis.push(Basic::Slack(r.0)),
                RowKind::Eq => self.invalidate_basis(),
            }
        }
    }

    /// Freeze a variable at `value`: it leaves the column set and its
    /// contribution folds into every row's rhs. Invalidates the basis
    /// only if the variable is currently basic.
    pub fn fix_var(&mut self, v: VarId, value: f64) {
        self.fixed[v.0] = Some(value);
        if self.has_basis
            && self
                .basis
                .iter()
                .any(|b| matches!(b, Basic::Var(i) if *i == v.0))
        {
            self.invalidate_basis();
        }
    }

    /// Release a frozen variable (re-enters nonbasic at 0).
    pub fn unfix_var(&mut self, v: VarId) {
        self.fixed[v.0] = None;
    }

    /// Forget the recorded basis; the next solve is cold.
    pub fn invalidate_basis(&mut self) {
        self.has_basis = false;
        self.basis.clear();
    }

    /// Solve the current problem: warm from the recorded basis when one
    /// is valid, falling back to the cold two-phase solve otherwise.
    /// Pivots burnt by an abandoned warm attempt are folded into the
    /// fallback solve's [`PivotCounts`], so per-solve reporting counts
    /// the warm path's full cost.
    pub fn solve(&mut self) -> LpResult {
        self.stats.solves += 1;
        let mut wasted: WastedPivots = (0, 0, 0);
        if self.has_basis {
            match self.try_warm() {
                Ok(res) => {
                    self.stats.warm_solves += 1;
                    return res;
                }
                Err(w) => {
                    self.stats.fallbacks += 1;
                    self.stats.pivots += (w.0 + w.1) as u64;
                    self.stats.stall_events += w.2 as u64;
                    self.invalidate_basis();
                    wasted = w;
                }
            }
        }
        self.stats.cold_solves += 1;
        let res = self.cold();
        match res {
            LpResult::Optimal { x, obj, mut pivots } => {
                pivots.dual += wasted.0;
                pivots.phase2 += wasted.1;
                pivots.stalls += wasted.2;
                LpResult::Optimal { x, obj, pivots }
            }
            other => other,
        }
    }

    fn record(&mut self, basic: &[usize], owner: &[Basic]) {
        self.basis = basic.iter().map(|&c| owner[c]).collect();
        self.has_basis = true;
    }

    /// Warm solve: rebuild the sparse columns from current row data,
    /// refactorize the recorded basis set, then repair with
    /// dual/primal pivots. `Err` = fall back to cold, carrying any
    /// search pivots the abandoned attempt burnt.
    fn try_warm(&mut self) -> Result<LpResult, WastedPivots> {
        let act: Vec<usize> =
            (0..self.rows.len()).filter(|&i| self.rows[i].active).collect();
        let m = act.len();
        if self.basis.len() != m {
            return Err((0, 0, 0));
        }
        let nvars = self.obj.len();
        let mut col_of_var = vec![usize::MAX; nvars];
        let mut free: Vec<usize> = Vec::new();
        for v in 0..nvars {
            if self.fixed[v].is_none() {
                col_of_var[v] = free.len();
                free.push(v);
            }
        }
        let nf = free.len();

        // column layout: free vars | slack per active <= row |
        // artificial placeholders (rows with a recorded Art entry) —
        // same order as the dense reference so tie-breaks agree
        let mut owner: Vec<Basic> = Vec::with_capacity(nf + m + 4);
        for &v in &free {
            owner.push(Basic::Var(v));
        }
        let mut slack_col = vec![usize::MAX; self.rows.len()];
        for &ri in &act {
            if self.rows[ri].kind == RowKind::Le {
                slack_col[ri] = owner.len();
                owner.push(Basic::Slack(ri));
            }
        }
        let allowed = owner.len();
        let mut art_col = vec![usize::MAX; self.rows.len()];
        for b in &self.basis {
            if let Basic::Art(ri) = *b {
                if art_col[ri] == usize::MAX {
                    art_col[ri] = owner.len();
                    owner.push(Basic::Art(ri));
                }
            }
        }
        let ncols = owner.len();

        // sparse columns + rhs, fixed variables folded into the rhs;
        // no sign normalization — the dual simplex handles negative b
        let mut entries: Vec<Vec<(u32, f64)>> = vec![Vec::new(); ncols];
        let mut b = Vec::with_capacity(m);
        for (k, &ri) in act.iter().enumerate() {
            let mut rhs = self.rows[ri].rhs;
            for &(v, a) in &self.rows[ri].coeffs {
                let v = v as usize;
                match self.fixed[v] {
                    Some(val) => rhs -= a * val,
                    None => entries[col_of_var[v]].push((k as u32, a)),
                }
            }
            if slack_col[ri] != usize::MAX {
                entries[slack_col[ri]].push((k as u32, 1.0));
            }
            if art_col[ri] != usize::MAX {
                entries[art_col[ri]].push((k as u32, 1.0));
            }
            b.push(rhs);
        }
        // phase-2 cost from the start (artificial placeholders cost 0)
        let mut cost = vec![0.0; ncols];
        for (j, &v) in free.iter().enumerate() {
            cost[j] = self.obj[v];
        }

        // map the recorded basis set to columns
        let mut bcols: Vec<usize> = Vec::with_capacity(m);
        for bb in &self.basis {
            let c = match *bb {
                Basic::Var(v) => {
                    if self.fixed[v].is_some() {
                        return Err((0, 0, 0));
                    }
                    col_of_var[v]
                }
                Basic::Slack(ri) => slack_col[ri],
                Basic::Art(ri) => art_col[ri],
            };
            if c == usize::MAX {
                return Err((0, 0, 0));
            }
            bcols.push(c);
        }
        {
            let mut seen = bcols.clone();
            seen.sort_unstable();
            if seen.windows(2).any(|w| w[0] == w[1]) {
                return Err((0, 0, 0)); // duplicate basis column: singular
            }
        }

        let mut eng = Engine::new(Cols::from_entries(entries), b, cost);
        if !eng.factorize(&bcols) {
            return Err((0, 0, 0)); // singular refactorization
        }
        self.stats.factor_elims += eng.factor as u64;
        let committed = eng.factor;
        let mut counts =
            PivotCounts { factor: eng.factor, warm: true, ..Default::default() };

        let primal_ok = eng.xb.iter().all(|&v| v >= -EPS);
        let y = eng.multipliers();
        let dual_ok = (0..allowed).all(|j| eng.row0(j, &y) >= -EPS);
        if !primal_ok {
            if !dual_ok {
                // neither simplex applies from here; don't guess
                return Err((0, 0, 0));
            }
            match eng.dual_simplex(allowed) {
                DualOutcome::Feasible(p) => {
                    counts.dual = p;
                }
                DualOutcome::Infeasible(p) => {
                    counts.dual = p;
                    self.stats.pivots += p as u64;
                    self.stats.factor_elims += (eng.factor - committed) as u64;
                    self.record(&eng.basic, &owner);
                    return Ok(LpResult::Infeasible);
                }
                DualOutcome::GaveUp(p) => {
                    self.stats.dual_cap_hits += 1;
                    self.stats.factor_elims += (eng.factor - committed) as u64;
                    return Err((p, 0, 0));
                }
            }
        }
        let (ok, p2, stalls) = eng.optimize(allowed);
        counts.phase2 = p2;
        counts.stalls = stalls;
        self.stats.factor_elims += (eng.factor - committed) as u64;
        counts.factor = eng.factor;
        // artificial placeholders are not real variables: with one
        // basic at a nonzero value the working problem is a strict
        // relaxation of the real one, so neither an optimal point nor
        // an unbounded ray in it proves anything (the real problem may
        // be infeasible) — only the cold phase-1 can decide
        for r in 0..m {
            if eng.basic[r] >= allowed && eng.xb[r].abs() > 1e-7 {
                return Err((counts.dual, p2, stalls));
            }
        }
        if !ok {
            self.stats.pivots += (counts.dual + p2) as u64;
            self.stats.stall_events += stalls as u64;
            self.record(&eng.basic, &owner);
            return Ok(LpResult::Unbounded);
        }
        self.stats.pivots += (counts.dual + p2) as u64;
        self.stats.stall_events += stalls as u64;

        let mut x = vec![0.0; nvars];
        for v in 0..nvars {
            if let Some(val) = self.fixed[v] {
                x[v] = val;
            }
        }
        for r in 0..m {
            let bc = eng.basic[r];
            if bc < nf {
                x[free[bc]] = eng.xb[r].max(0.0);
            }
        }
        let obj = self.obj.iter().zip(&x).map(|(a, b)| a * b).sum();
        self.record(&eng.basic, &owner);
        Ok(LpResult::Optimal { x, obj, pivots: counts })
    }

    /// Cold two-phase solve on the sparse core, recording the final
    /// basis for warm reuse. Row normalization, column layout, and
    /// pivot rules mirror the dense reference exactly.
    fn cold(&mut self) -> LpResult {
        let act: Vec<usize> =
            (0..self.rows.len()).filter(|&i| self.rows[i].active).collect();
        let m = act.len();
        let nvars = self.obj.len();
        let mut col_of_var = vec![usize::MAX; nvars];
        let mut free: Vec<usize> = Vec::new();
        for v in 0..nvars {
            if self.fixed[v].is_none() {
                col_of_var[v] = free.len();
                free.push(v);
            }
        }
        let nf = free.len();

        // Normalize rows to b >= 0 over the free columns (fixed
        // variables folded into the rhs).
        // <= with b>=0 -> slack(+1);  flipped(>=) -> surplus(-1)+artificial;
        // == -> artificial.
        let mut rows_b: Vec<f64> = Vec::with_capacity(m);
        let mut flip: Vec<bool> = Vec::with_capacity(m);
        let mut kind: Vec<u8> = Vec::with_capacity(m); // 0 = <=, 1 = >=, 2 = ==
        for &ri in &act {
            let row = &self.rows[ri];
            let mut b = row.rhs;
            for &(v, a) in &row.coeffs {
                if let Some(val) = self.fixed[v as usize] {
                    b -= a * val;
                }
            }
            let f = b < 0.0;
            rows_b.push(if f { -b } else { b });
            flip.push(f);
            kind.push(match (row.kind, f) {
                (RowKind::Le, false) => 0,
                (RowKind::Le, true) => 1,
                (RowKind::Eq, _) => 2,
            });
        }

        let n_slack = kind.iter().filter(|&&k| k != 2).count();
        let n_art = kind.iter().filter(|&&k| k != 0).count();
        let art_start = nf + n_slack;
        let ncols = nf + n_slack + n_art;

        // column owners, for recording the basis after the solve (the
        // surplus of a flipped row is the same quantity as its slack)
        let mut owner: Vec<Basic> = Vec::with_capacity(ncols);
        for &v in &free {
            owner.push(Basic::Var(v));
        }
        for (r, &ri) in act.iter().enumerate() {
            if kind[r] != 2 {
                owner.push(Basic::Slack(ri));
            }
        }
        for (r, &ri) in act.iter().enumerate() {
            if kind[r] != 0 {
                owner.push(Basic::Art(ri));
            }
        }

        // sparse columns + initial (all-slack/artificial, identity)
        // basis — no factorization needed
        let mut entries: Vec<Vec<(u32, f64)>> = vec![Vec::new(); ncols];
        for (r, &ri) in act.iter().enumerate() {
            let s = if flip[r] { -1.0 } else { 1.0 };
            for &(v, a) in &self.rows[ri].coeffs {
                let v = v as usize;
                if self.fixed[v].is_none() {
                    entries[col_of_var[v]].push((r as u32, s * a));
                }
            }
        }
        let mut basic0 = vec![usize::MAX; m];
        let mut slack_i = 0;
        let mut art_i = 0;
        for (r, &k) in kind.iter().enumerate() {
            match k {
                0 => {
                    entries[nf + slack_i].push((r as u32, 1.0));
                    basic0[r] = nf + slack_i;
                    slack_i += 1;
                }
                1 => {
                    entries[nf + slack_i].push((r as u32, -1.0)); // surplus
                    slack_i += 1;
                    entries[art_start + art_i].push((r as u32, 1.0));
                    basic0[r] = art_start + art_i;
                    art_i += 1;
                }
                _ => {
                    entries[art_start + art_i].push((r as u32, 1.0));
                    basic0[r] = art_start + art_i;
                    art_i += 1;
                }
            }
        }

        let cost = vec![0.0; ncols];
        let mut eng = Engine::new(Cols::from_entries(entries), rows_b, cost);
        eng.basic = basic0;

        let mut counts = PivotCounts::default();

        // ---- Phase 1: maximize -(sum of artificials) ----
        if n_art > 0 {
            for c in art_start..ncols {
                eng.cost[c] = -1.0;
            }
            let (ok, p1, s1) = eng.optimize(ncols);
            counts.phase1 = p1;
            counts.stalls += s1;
            self.stats.pivots += p1 as u64;
            self.stats.stall_events += s1 as u64;
            if !ok {
                // phase 1 cannot be unbounded
                self.stats.factor_elims += eng.factor as u64;
                self.record(&eng.basic, &owner);
                return LpResult::Infeasible;
            }
            let infeas = -eng.obj();
            if infeas.abs() > 1e-6 {
                self.stats.factor_elims += eng.factor as u64;
                self.record(&eng.basic, &owner);
                return LpResult::Infeasible;
            }
            // drive remaining basic artificials out of the basis (a
            // row with no eligible column is redundant and keeps its
            // artificial basic at 0)
            eng.drive_out_artificials(art_start);
        }

        // ---- Phase 2: maximize c·x ----
        for c in eng.cost.iter_mut() {
            *c = 0.0;
        }
        for (j, &v) in free.iter().enumerate() {
            eng.cost[j] = self.obj[v];
        }
        // forbid artificials from re-entering: only structural + slack
        let (ok, p2, s2) = eng.optimize(art_start);
        counts.phase2 = p2;
        counts.stalls += s2;
        counts.factor = eng.factor;
        self.stats.pivots += p2 as u64;
        self.stats.stall_events += s2 as u64;
        self.stats.factor_elims += eng.factor as u64;
        self.record(&eng.basic, &owner);
        if !ok {
            return LpResult::Unbounded;
        }

        let mut x = vec![0.0; nvars];
        for v in 0..nvars {
            if let Some(val) = self.fixed[v] {
                x[v] = val;
            }
        }
        for r in 0..m {
            let bc = eng.basic[r];
            if bc < nf {
                x[free[bc]] = eng.xb[r].max(0.0);
            }
        }
        let obj = self.obj.iter().zip(&x).map(|(a, b)| a * b).sum();
        LpResult::Optimal { x, obj, pivots: counts }
    }
}

#[cfg(test)]
mod tests {
    use super::super::simplex::solve;
    use super::*;

    fn solver_optimal(s: &mut Solver) -> (Vec<f64>, f64, PivotCounts) {
        match s.solve() {
            LpResult::Optimal { x, obj, pivots } => (x, obj, pivots),
            other => panic!("expected optimal, got {other:?}"),
        }
    }

    #[test]
    fn sparse_matches_dense_on_fixed_instances() {
        // the dense `solve` is the in-tree parity reference; the
        // sparse core must agree to 1e-9 on shapes it will meet
        let cases = vec![
            Lp {
                n: 2,
                c: vec![1.0, 1.0],
                a_ub: vec![
                    vec![1.0, 0.0],
                    vec![0.0, 1.0],
                    vec![1.0, 1.0],
                ],
                b_ub: vec![2.0, 3.0, 4.0],
                ..Default::default()
            },
            Lp {
                n: 2,
                c: vec![3.0, 2.0],
                a_ub: vec![vec![1.0, 0.0]],
                b_ub: vec![3.0],
                a_eq: vec![vec![1.0, 1.0]],
                b_eq: vec![4.0],
            },
            Lp {
                n: 1,
                c: vec![-1.0],
                a_ub: vec![vec![-1.0]],
                b_ub: vec![-2.0],
                ..Default::default()
            },
        ];
        for (i, lp) in cases.iter().enumerate() {
            let dense = solve(lp);
            let sparse = Solver::from_lp(lp).solve();
            match (dense, sparse) {
                (
                    LpResult::Optimal { obj: od, x: xd, .. },
                    LpResult::Optimal { obj: os, x: xs, .. },
                ) => {
                    assert!((od - os).abs() < 1e-9, "case {i}: {od} vs {os}");
                    for (a, b) in xd.iter().zip(&xs) {
                        assert!((a - b).abs() < 1e-9, "case {i}: x differs");
                    }
                }
                (d, s) => panic!("case {i}: dense {d:?} vs sparse {s:?}"),
            }
        }
    }

    #[test]
    fn sparse_detects_infeasible_and_unbounded() {
        let inf = Lp {
            n: 1,
            c: vec![1.0],
            a_ub: vec![vec![1.0]],
            b_ub: vec![1.0],
            a_eq: vec![vec![1.0]],
            b_eq: vec![2.0],
        };
        assert_eq!(Solver::from_lp(&inf).solve(), LpResult::Infeasible);
        let unb = Lp {
            n: 2,
            c: vec![1.0, 0.0],
            a_ub: vec![vec![-1.0, 0.0]],
            b_ub: vec![0.0],
            ..Default::default()
        };
        assert_eq!(Solver::from_lp(&unb).solve(), LpResult::Unbounded);
    }

    #[test]
    fn degenerate_does_not_cycle_sparse() {
        // classic degeneracy example (cycles under unguarded Dantzig;
        // the stall detector's Bland fallback must terminate it)
        let lp = Lp {
            n: 4,
            c: vec![0.75, -150.0, 0.02, -6.0],
            a_ub: vec![
                vec![0.25, -60.0, -0.04, 9.0],
                vec![0.5, -90.0, -0.02, 3.0],
                vec![0.0, 0.0, 1.0, 0.0],
            ],
            b_ub: vec![0.0, 0.0, 1.0],
            ..Default::default()
        };
        match Solver::from_lp(&lp).solve() {
            LpResult::Optimal { obj, .. } => {
                assert!((obj - 0.05).abs() < 1e-6, "obj={obj}")
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn eta_refresh_keeps_long_solves_exact() {
        // enough pivots to cross the ETA_REFRESH boundary at least
        // once: a chain of coupled caps forces one pivot per variable
        let n = 3 * ETA_REFRESH;
        let c = vec![1.0; n];
        let mut a_ub = Vec::with_capacity(n);
        let mut b_ub = Vec::with_capacity(n);
        for i in 0..n {
            let mut row = vec![0.0; n];
            row[i] = 1.0;
            if i > 0 {
                row[i - 1] = 0.5;
            }
            a_ub.push(row);
            b_ub.push(1.0 + (i % 7) as f64 * 0.25);
        }
        let lp = Lp { n, c, a_ub, b_ub, ..Default::default() };
        let dense = solve(&lp);
        let sparse = Solver::from_lp(&lp).solve();
        match (dense, sparse) {
            (
                LpResult::Optimal { obj: od, .. },
                LpResult::Optimal { obj: os, pivots, .. },
            ) => {
                assert!((od - os).abs() < 1e-9, "{od} vs {os}");
                assert!(
                    pivots.phase2 as usize >= ETA_REFRESH,
                    "test must cross the refresh boundary: {pivots:?}"
                );
            }
            (d, s) => panic!("dense {d:?} vs sparse {s:?}"),
        }
    }

    // ---- warm-start behaviour (ported from the dense Solver) ------

    #[test]
    fn warm_rhs_edit_resolves_from_basis() {
        // max x + y st x <= 2, y <= 3, x + y <= 4
        let mut s = Solver::new();
        let x = s.add_var(1.0);
        let y = s.add_var(1.0);
        s.add_row_le(&[(x, 1.0)], 2.0);
        s.add_row_le(&[(y, 1.0)], 3.0);
        let rxy = s.add_row_le(&[(x, 1.0), (y, 1.0)], 4.0);
        let (_, obj, p) = solver_optimal(&mut s);
        assert!((obj - 4.0).abs() < 1e-9);
        assert!(!p.warm);
        // loosen the joint cap: primal re-optimization from the basis
        s.set_rhs(rxy, 6.0);
        let (xv, obj, p) = solver_optimal(&mut s);
        assert!((obj - 5.0).abs() < 1e-9, "obj={obj}");
        assert!((xv[0] - 2.0).abs() < 1e-9 && (xv[1] - 3.0).abs() < 1e-9);
        assert!(p.warm, "expected a warm solve");
        assert!(p.search() <= 3, "too many warm pivots: {p:?}");
        // tighten it below the current point: dual-simplex repair
        s.set_rhs(rxy, 3.0);
        let (_, obj, p) = solver_optimal(&mut s);
        assert!((obj - 3.0).abs() < 1e-9, "obj={obj}");
        assert!(p.warm);
        assert!(p.dual >= 1, "expected dual repair pivots: {p:?}");
        let st = s.stats();
        assert_eq!(st.solves, 3);
        assert_eq!(st.cold_solves, 1);
        assert_eq!(st.warm_solves, 2);
        assert_eq!(st.dual_cap_hits, 0);
    }

    #[test]
    fn warm_append_and_deactivate_row() {
        let mut s = Solver::new();
        let x = s.add_var(1.0);
        s.add_row_le(&[(x, 1.0)], 5.0);
        let (_, obj, _) = solver_optimal(&mut s);
        assert!((obj - 5.0).abs() < 1e-9);
        // appended binding row: warm dual repair down to x = 2
        let tight = s.add_row_le(&[(x, 1.0)], 2.0);
        let (_, obj, p) = solver_optimal(&mut s);
        assert!((obj - 2.0).abs() < 1e-9, "obj={obj}");
        assert!(p.warm && p.dual >= 1, "{p:?}");
        // appended slack row stays warm through deactivation
        let loose = s.add_row_le(&[(x, 1.0)], 9.0);
        let (_, obj, p) = solver_optimal(&mut s);
        assert!((obj - 2.0).abs() < 1e-9);
        assert!(p.warm);
        s.deactivate_row(loose);
        let (_, obj, p) = solver_optimal(&mut s);
        assert!((obj - 2.0).abs() < 1e-9);
        assert!(p.warm, "slack-basic row removal should stay warm");
        // removing the binding row (its slack is nonbasic) goes cold,
        // and must still be correct
        s.deactivate_row(tight);
        let (_, obj, _) = solver_optimal(&mut s);
        assert!((obj - 5.0).abs() < 1e-9, "obj={obj}");
    }

    #[test]
    fn fix_and_unfix_var() {
        // max x + y st x + y <= 4, x <= 2
        let mut s = Solver::new();
        let x = s.add_var(1.0);
        let y = s.add_var(1.0);
        s.add_row_le(&[(x, 1.0), (y, 1.0)], 4.0);
        s.add_row_le(&[(x, 1.0)], 2.0);
        let (_, obj, _) = solver_optimal(&mut s);
        assert!((obj - 4.0).abs() < 1e-9);
        s.fix_var(y, 1.0);
        let (xv, obj, _) = solver_optimal(&mut s);
        assert!((obj - 3.0).abs() < 1e-9, "obj={obj}");
        assert!((xv[0] - 2.0).abs() < 1e-9 && (xv[1] - 1.0).abs() < 1e-9);
        s.unfix_var(y);
        let (_, obj, _) = solver_optimal(&mut s);
        assert!((obj - 4.0).abs() < 1e-9);
    }

    #[test]
    fn appended_var_enters_warm() {
        // max x st x <= 3; then add y with obj 2, y <= 1 coupled row
        let mut s = Solver::new();
        let x = s.add_var(1.0);
        s.add_row_le(&[(x, 1.0)], 3.0);
        let (_, obj, _) = solver_optimal(&mut s);
        assert!((obj - 3.0).abs() < 1e-9);
        let y = s.add_var(2.0);
        s.add_row_le(&[(y, 1.0)], 1.0);
        let (xv, obj, p) = solver_optimal(&mut s);
        assert!((obj - 5.0).abs() < 1e-9, "obj={obj}");
        assert!((xv[1] - 1.0).abs() < 1e-9);
        assert!(p.warm, "new column should enter from the warm basis");
    }

    #[test]
    fn warm_matches_cold_on_random_edits() {
        use crate::util::Pcg32;
        let mut rng = Pcg32::seeded(4242);
        for trial in 0..30 {
            let n = 2 + rng.below(4);
            let mu = 2 + rng.below(4);
            let c: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 1.0)).collect();
            let a_ub: Vec<Vec<f64>> = (0..mu)
                .map(|_| (0..n).map(|_| rng.uniform(0.05, 1.0)).collect())
                .collect();
            let b_ub: Vec<f64> =
                (0..mu).map(|_| rng.uniform(0.5, 2.0)).collect();
            let mut lp = Lp { n, c, a_ub, b_ub, ..Default::default() };
            let mut s = Solver::from_lp(&lp);
            s.solve();
            for edit in 0..4 {
                let r = rng.below(mu);
                let nb = rng.uniform(0.3, 2.5);
                lp.b_ub[r] = nb;
                s.set_rhs(RowId(r), nb);
                let warm = s.solve();
                let cold = solve(&lp);
                match (warm, cold) {
                    (
                        LpResult::Optimal { obj: ow, x: xw, .. },
                        LpResult::Optimal { obj: oc, .. },
                    ) => {
                        assert!(
                            (ow - oc).abs() < 1e-7,
                            "trial {trial} edit {edit}: {ow} vs {oc}"
                        );
                        // warm solution must satisfy the edited rows
                        for (row, &b) in lp.a_ub.iter().zip(&lp.b_ub) {
                            let lhs: f64 = row
                                .iter()
                                .zip(&xw)
                                .map(|(a, v)| a * v)
                                .sum();
                            assert!(
                                lhs <= b + 1e-6,
                                "trial {trial} edit {edit} violated"
                            );
                        }
                    }
                    (w, c) => {
                        panic!("trial {trial} edit {edit}: {w:?} vs {c:?}")
                    }
                }
            }
            let st = s.stats();
            assert!(st.warm_solves > 0, "trial {trial}: never warm");
        }
    }
}
