//! Regenerates paper Fig. 8 (sharing incentive: shared cloud vs
//! per-user dedicated clouds) and times the n+1 simulations.
//!
//! Run: `cargo bench --bench fig8_sharing`

use drfh::experiments::{fig8, EvalSetup};
use drfh::util::bench::{bench, header};
use std::time::Duration;

fn main() {
    let setup = EvalSetup::with_duration(42, 300, 30, 21_600.0);
    let res = fig8::run_fig8(&setup);
    fig8::print(&res);

    header("fig8: shared + n dedicated-cloud simulations");
    bench("fig8 full comparison", Duration::from_secs(10), 5, || {
        fig8::run_fig8(&setup).users.len()
    });
}
