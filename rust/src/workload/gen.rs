//! Synthetic Google-like workload generator (DESIGN.md §4 substitution).
//!
//! Calibration targets, from Reiss et al. "Heterogeneity and Dynamicity
//! of Clouds at Scale" (SoCC'12) and the DRFH paper's own setup:
//!   * demand heterogeneity: a mix of CPU-heavy, memory-heavy and
//!     balanced users (the paper's Fig. 1 motivation);
//!   * per-task demands are small fractions of one server (tasks must
//!     pack several-per-server for Best-Fit to matter);
//!   * tasks-per-job is heavy-tailed: most jobs are small, a few have
//!     thousands of tasks (drives the paper's Fig. 6b buckets);
//!   * task durations are heavy-tailed with means of minutes;
//!   * job arrivals are Poisson per user.

use super::trace::{JobSpec, TaskSpec, Trace, UserSpec};
use crate::cluster::ResVec;
use crate::sim::{ChurnEvent, ChurnPlan, FaultPlan};
use crate::util::Pcg32;

/// Demand profile classes (mirrors the paper's CPU-heavy / memory-heavy
/// task taxonomy; weights roughly even, as in the Google trace where
/// both kinds are prevalent).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DemandClass {
    CpuHeavy,
    MemHeavy,
    Balanced,
}

/// Generator configuration. Defaults reproduce the paper's Sec. VI
/// setup scaled to the configured cluster.
#[derive(Clone, Debug)]
pub struct GoogleLikeConfig {
    /// Number of users (tenants).
    pub users: usize,
    /// Trace duration in seconds (paper: 24 h).
    pub duration: f64,
    /// Mean jobs per user over the whole trace.
    pub jobs_per_user: f64,
    /// Max tasks in a single job (paper buckets go beyond 1000).
    pub max_tasks_per_job: usize,
    /// Zipf exponent for tasks-per-job (heavier tail when closer to 1).
    pub job_size_zipf_s: f64,
    /// Bounded-Pareto task durations [lo, hi] seconds with tail alpha.
    pub dur_lo: f64,
    pub dur_hi: f64,
    pub dur_alpha: f64,
    /// Class mix (CPU-heavy, mem-heavy, balanced) weights.
    pub class_mix: [f64; 3],
    /// Demand magnitude: log-normal mu/sigma of the *dominant* resource
    /// demand in absolute units (max-server = 1.0 as in Table I).
    pub dom_mu: f64,
    pub dom_sigma: f64,
    /// Ratio of non-dominant to dominant demand: uniform [lo, hi].
    pub skew_lo: f64,
    pub skew_hi: f64,
}

impl Default for GoogleLikeConfig {
    fn default() -> Self {
        GoogleLikeConfig {
            users: 100,
            duration: 86_400.0,
            jobs_per_user: 20.0,
            max_tasks_per_job: 3000,
            job_size_zipf_s: 1.35,
            dur_lo: 30.0,
            dur_hi: 10_800.0,
            dur_alpha: 1.3,
            class_mix: [0.4, 0.4, 0.2],
            // dominant demand ~ exp(N(-3.0, 1.0)): median ≈ 0.05 of the
            // max server with a heavy right tail to ~0.4 — matching the
            // wide per-task demand spread Reiss et al. report. The
            // spread is what separates DRFH from the slot scheduler:
            // small tasks are concurrency-limited by slot counts, big
            // ones overcommit servers.
            dom_mu: -3.0,
            dom_sigma: 1.0,
            skew_lo: 0.1,
            skew_hi: 0.5,
        }
    }
}

/// Deterministic trace generator.
pub struct TraceGenerator {
    pub config: GoogleLikeConfig,
}

impl TraceGenerator {
    pub fn new(config: GoogleLikeConfig) -> Self {
        TraceGenerator { config }
    }

    /// Draw a user demand vector: pick a class, a dominant magnitude,
    /// and a skew ratio for the other resource.
    fn draw_demand(&self, rng: &mut Pcg32) -> (ResVec, DemandClass) {
        let cfg = &self.config;
        let class = match rng.choice_weighted(&cfg.class_mix) {
            0 => DemandClass::CpuHeavy,
            1 => DemandClass::MemHeavy,
            _ => DemandClass::Balanced,
        };
        let dom = rng
            .lognormal(cfg.dom_mu, cfg.dom_sigma)
            .clamp(0.005, 0.9);
        let skew = rng.uniform(cfg.skew_lo, cfg.skew_hi);
        let d = match class {
            DemandClass::CpuHeavy => ResVec::cpu_mem(dom, dom * skew),
            DemandClass::MemHeavy => ResVec::cpu_mem(dom * skew, dom),
            DemandClass::Balanced => {
                let jitter = rng.uniform(0.8, 1.25);
                ResVec::cpu_mem(dom, (dom * jitter).clamp(0.005, 0.9))
            }
        };
        (d, class)
    }

    /// Generate the full trace. Jobs are globally sorted by submit time.
    pub fn generate(&self, seed: u64) -> Trace {
        let cfg = &self.config;
        let mut rng = Pcg32::new(seed, 0x9e37_79b9_7f4a_7c15);
        let users: Vec<UserSpec> = (0..cfg.users)
            .map(|_| {
                let (demand, _) = self.draw_demand(&mut rng);
                UserSpec { demand, weight: 1.0 }
            })
            .collect();

        let mut jobs: Vec<JobSpec> = Vec::new();
        for u in 0..cfg.users {
            // Poisson arrivals: exponential gaps with mean duration/rate
            let rate = cfg.jobs_per_user / cfg.duration;
            let mut t = rng.exp(rate);
            while t < cfg.duration {
                let ntasks = rng
                    .zipf(cfg.max_tasks_per_job, cfg.job_size_zipf_s)
                    .max(1);
                let tasks = (0..ntasks)
                    .map(|_| TaskSpec {
                        duration: rng.pareto_bounded(
                            cfg.dur_lo,
                            cfg.dur_hi,
                            cfg.dur_alpha,
                        ),
                    })
                    .collect();
                jobs.push(JobSpec { id: 0, user: u, submit: t, tasks });
                t += rng.exp(rate);
            }
        }
        sort_by_submit(&mut jobs);
        for (i, j) in jobs.iter_mut().enumerate() {
            j.id = i;
        }
        let trace = Trace { users, jobs };
        debug_assert!(trace.validate().is_ok());
        trace
    }
}

/// Global submit-time order. `total_cmp`, not `partial_cmp().unwrap()`:
/// the order must stay total (and the sort panic-free) even for the
/// NaN submits a degenerate generator config could produce — same
/// convention as `util::stats`.
fn sort_by_submit(jobs: &mut [JobSpec]) {
    jobs.sort_by(|a, b| a.submit.total_cmp(&b.submit));
}

/// The paper's Fig. 4 dynamic scenario: three users with fixed demands
/// joining at t = 0, 200, 500 s, each with a finite task backlog sized so
/// that user 1 departs around t ≈ 1080 s under fair sharing.
pub fn fig4_trace(tasks: [usize; 3], durations: [f64; 3]) -> Trace {
    let users = vec![
        UserSpec { demand: ResVec::cpu_mem(0.2, 0.3), weight: 1.0 },
        UserSpec { demand: ResVec::cpu_mem(0.5, 0.1), weight: 1.0 },
        UserSpec { demand: ResVec::cpu_mem(0.1, 0.3), weight: 1.0 },
    ];
    let submits = [0.0, 200.0, 500.0];
    let jobs = (0..3)
        .map(|u| JobSpec {
            id: u,
            user: u,
            submit: submits[u],
            tasks: vec![TaskSpec { duration: durations[u] }; tasks[u]],
        })
        .collect();
    Trace { users, jobs }
}

/// Fault-process configuration (`[faults]` in the experiment config):
/// three seeded generators compiled into one [`FaultPlan`] by
/// [`generate_faults`]. All rates are per second; a rate of 0 disables
/// that process.
#[derive(Clone, Debug)]
pub struct FaultGenConfig {
    /// Per-server Poisson crash rate (events/s per server).
    pub crash_rate: f64,
    /// Mean repair time for independent crashes (exponential).
    pub mean_downtime: f64,
    /// Servers per rack for correlated outages (0 disables racks).
    pub rack_size: usize,
    /// Per-rack Poisson outage rate; an outage downs the whole rack.
    pub rack_outage_rate: f64,
    /// Mean rack repair time (exponential).
    pub rack_downtime: f64,
    /// One-off "flash failure": at this instant an `flash_fraction` of
    /// all servers goes down at once (None disables).
    pub flash_at: Option<f64>,
    /// Fraction of servers the flash failure takes down.
    pub flash_fraction: f64,
    /// How long flash-failed servers stay down.
    pub flash_downtime: f64,
    /// Fairness-recovery tolerance carried into the plan
    /// ([`FaultPlan::envy_eps`]).
    pub envy_eps: f64,
}

impl Default for FaultGenConfig {
    fn default() -> Self {
        FaultGenConfig {
            crash_rate: 0.0,
            mean_downtime: 300.0,
            rack_size: 0,
            rack_outage_rate: 0.0,
            rack_downtime: 900.0,
            flash_at: None,
            flash_fraction: 0.1,
            flash_downtime: 600.0,
            envy_eps: 0.05,
        }
    }
}

impl FaultGenConfig {
    /// True when every process is disabled (the generated plan is
    /// [`FaultPlan::none`]-equivalent).
    pub fn is_empty(&self) -> bool {
        self.crash_rate <= 0.0
            && (self.rack_size == 0 || self.rack_outage_rate <= 0.0)
            && self.flash_at.is_none()
    }
}

/// Compile the configured fault processes for a `servers`-sized cluster
/// into a [`FaultPlan`], deterministically from `seed`. Every process
/// draws from its own Pcg32 *stream* (per-server crash processes on
/// streams `0..k`, per-rack outages above `RACK_STREAM`, the flash
/// shuffle on `FLASH_STREAM`), so plans are stable under changes to the
/// other processes' configs and independent of generation order.
pub fn generate_faults(
    cfg: &FaultGenConfig,
    servers: usize,
    horizon: f64,
    seed: u64,
) -> FaultPlan {
    const RACK_STREAM: u64 = 1 << 40;
    const FLASH_STREAM: u64 = 1 << 41;
    let mut intervals: Vec<(usize, f64, f64)> = Vec::new();
    // independent per-server crash/repair renewal processes
    if cfg.crash_rate > 0.0 && cfg.mean_downtime > 0.0 {
        for l in 0..servers {
            let mut rng = Pcg32::new(seed, l as u64);
            let mut t = rng.exp(cfg.crash_rate);
            while t < horizon {
                let down = rng.exp(1.0 / cfg.mean_downtime);
                intervals.push((l, t, t + down));
                t += down + rng.exp(cfg.crash_rate);
            }
        }
    }
    // correlated rack-scoped outages
    if cfg.rack_size > 0
        && cfg.rack_outage_rate > 0.0
        && cfg.rack_downtime > 0.0
    {
        let racks = servers.div_ceil(cfg.rack_size);
        for rack in 0..racks {
            let mut rng = Pcg32::new(seed, RACK_STREAM + rack as u64);
            let lo = rack * cfg.rack_size;
            let hi = (lo + cfg.rack_size).min(servers);
            let mut t = rng.exp(cfg.rack_outage_rate);
            while t < horizon {
                let down = rng.exp(1.0 / cfg.rack_downtime);
                for l in lo..hi {
                    intervals.push((l, t, t + down));
                }
                t += down + rng.exp(cfg.rack_outage_rate);
            }
        }
    }
    // one-off flash failure of a uniform server subset
    if let Some(at) = cfg.flash_at {
        if at < horizon && cfg.flash_fraction > 0.0 && cfg.flash_downtime > 0.0
        {
            let n = ((cfg.flash_fraction * servers as f64) as usize)
                .clamp(1, servers);
            let mut order: Vec<usize> = (0..servers).collect();
            let mut rng = Pcg32::new(seed, FLASH_STREAM);
            rng.shuffle(&mut order);
            for &l in &order[..n] {
                intervals.push((l, at, at + cfg.flash_downtime));
            }
        }
    }
    FaultPlan::from_intervals(seed, cfg.envy_eps, &intervals)
}

/// Churn-process configuration (`[churn]` in the experiment config):
/// per-user alternating leave/rejoin renewal processes, an optional
/// flash-crowd burst, and diurnal rate modulation, compiled into one
/// [`ChurnPlan`] by [`generate_churn`]. All rates are per second; a
/// leave rate of 0 (with no initial absentees and no flash) disables
/// churn entirely.
#[derive(Clone, Debug)]
pub struct ChurnGenConfig {
    /// Per-user Poisson departure rate while present (events/s).
    pub leave_rate: f64,
    /// Per-user Poisson rejoin rate while absent (events/s).
    pub rejoin_rate: f64,
    /// Fraction of users absent when the trace starts (each user
    /// draws independently on its own stream).
    pub absent_frac: f64,
    /// One-off "flash crowd": at this instant a cohort of
    /// `flash_fraction` of all users — drawn from those absent at
    /// that moment — joins at once (None disables).
    pub flash_at: Option<f64>,
    /// Fraction of the user population the flash crowd targets.
    pub flash_fraction: f64,
    /// How long flash joiners stay before leaving again (0 = they
    /// stay, subject to their own renewal process).
    pub flash_hold: f64,
    /// Diurnal modulation amplitude in `[0, 1]`: both rates are
    /// scaled by `1 + amp * sin(2πt/period)` via thinning (0
    /// disables).
    pub diurnal_amp: f64,
    /// Diurnal period in seconds.
    pub diurnal_period: f64,
}

impl Default for ChurnGenConfig {
    fn default() -> Self {
        ChurnGenConfig {
            leave_rate: 0.0,
            rejoin_rate: 1.0 / 1800.0,
            absent_frac: 0.0,
            flash_at: None,
            flash_fraction: 0.1,
            flash_hold: 1800.0,
            diurnal_amp: 0.0,
            diurnal_period: 86_400.0,
        }
    }
}

impl ChurnGenConfig {
    /// True when every process is disabled (the generated plan is
    /// [`ChurnPlan::none`]-equivalent).
    pub fn is_empty(&self) -> bool {
        self.leave_rate <= 0.0
            && self.absent_frac <= 0.0
            && self.flash_at.is_none()
    }
}

/// Next event of a rate-`base` Poisson process after `from`, with
/// diurnal thinning: candidates are drawn at the peak rate
/// `base * (1 + amp)` and accepted with probability
/// `rate(t) / peak`, which realizes the inhomogeneous rate
/// `base * (1 + amp * sin(2πt/period))` exactly. `None` when the
/// process is off or the next event falls past the horizon.
fn next_modulated(
    rng: &mut Pcg32,
    from: f64,
    base: f64,
    amp: f64,
    period: f64,
    horizon: f64,
) -> Option<f64> {
    if base <= 0.0 {
        return None;
    }
    let amp = amp.clamp(0.0, 1.0);
    if amp == 0.0 || period <= 0.0 {
        let t = from + rng.exp(base);
        return (t < horizon).then_some(t);
    }
    let peak = base * (1.0 + amp);
    let mut t = from;
    loop {
        t += rng.exp(peak);
        if t >= horizon {
            return None;
        }
        let phase = t / period * std::f64::consts::TAU;
        let rate = base * (1.0 + amp * phase.sin());
        if rng.f64() * peak <= rate {
            return Some(t);
        }
    }
}

/// Compile the configured churn processes for a `users`-sized trace
/// into a [`ChurnPlan`], deterministically from `seed`. Same stream
/// discipline as [`generate_faults`]: every process draws from its
/// own Pcg32 *stream* (per-user renewal processes on streams
/// `CHURN_STREAM + u`, the flash-cohort shuffle on
/// `CHURN_FLASH_STREAM`), disjoint from the trace generator's stream
/// and the fault streams — enabling churn perturbs no other
/// generated randomness (property-tested), and plans are stable
/// under changes to the other processes' configs.
pub fn generate_churn(
    cfg: &ChurnGenConfig,
    users: usize,
    horizon: f64,
    seed: u64,
) -> ChurnPlan {
    const CHURN_STREAM: u64 = 1 << 42;
    const CHURN_FLASH_STREAM: u64 = 1 << 43;
    if cfg.is_empty() || users == 0 {
        return ChurnPlan::none();
    }
    let mut absent: Vec<usize> = Vec::new();
    let mut events: Vec<ChurnEvent> = Vec::new();
    // presence immediately before the flash instant, maintained while
    // walking each user's renewal process (the flash cohort is drawn
    // from users absent at that moment)
    let mut absent_at_flash: Vec<bool> = vec![false; users];
    let flash_at = cfg.flash_at.filter(|&at| {
        at < horizon && cfg.flash_fraction > 0.0
    });
    for u in 0..users {
        let mut rng = Pcg32::new(seed, CHURN_STREAM + u as u64);
        let mut present =
            !(cfg.absent_frac > 0.0 && rng.f64() < cfg.absent_frac);
        if !present {
            absent.push(u);
        }
        let mut t = 0.0;
        loop {
            if let Some(at) = flash_at {
                if t < at {
                    absent_at_flash[u] = !present;
                }
            }
            let rate =
                if present { cfg.leave_rate } else { cfg.rejoin_rate };
            let Some(next) = next_modulated(
                &mut rng,
                t,
                rate,
                cfg.diurnal_amp,
                cfg.diurnal_period,
                horizon,
            ) else {
                break;
            };
            if let Some(at) = flash_at {
                if t < at && next >= at {
                    absent_at_flash[u] = !present;
                }
            }
            t = next;
            present = !present;
            events.push(ChurnEvent { time: t, user: u, join: present });
        }
    }
    // flash crowd: a shuffled cohort of then-absent users joins at
    // once, and (optionally) leaves again flash_hold later
    if let Some(at) = flash_at {
        let want = ((cfg.flash_fraction * users as f64) as usize)
            .clamp(1, users);
        let mut order: Vec<usize> = (0..users).collect();
        let mut rng = Pcg32::new(seed, CHURN_FLASH_STREAM);
        rng.shuffle(&mut order);
        let mut taken = 0;
        for &u in &order {
            if taken == want {
                break;
            }
            if !absent_at_flash[u] {
                continue;
            }
            taken += 1;
            events.push(ChurnEvent { time: at, user: u, join: true });
            if cfg.flash_hold > 0.0 && at + cfg.flash_hold < horizon {
                events.push(ChurnEvent {
                    time: at + cfg.flash_hold,
                    user: u,
                    join: false,
                });
            }
        }
    }
    ChurnPlan::from_transitions(seed, absent, events)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let g = TraceGenerator::new(GoogleLikeConfig {
            users: 10,
            jobs_per_user: 5.0,
            ..Default::default()
        });
        let a = g.generate(7);
        let b = g.generate(7);
        assert_eq!(a.jobs.len(), b.jobs.len());
        assert_eq!(a.total_tasks(), b.total_tasks());
        for (x, y) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(x.submit, y.submit);
            assert_eq!(x.num_tasks(), y.num_tasks());
        }
    }

    #[test]
    fn validates_and_spans_duration() {
        let g = TraceGenerator::new(GoogleLikeConfig {
            users: 20,
            duration: 10_000.0,
            ..Default::default()
        });
        let t = g.generate(3);
        t.validate().unwrap();
        assert!(t.horizon() <= 10_000.0);
        assert!(!t.jobs.is_empty());
    }

    #[test]
    fn job_sizes_heavy_tailed() {
        let g = TraceGenerator::new(GoogleLikeConfig {
            users: 50,
            jobs_per_user: 40.0,
            ..Default::default()
        });
        let t = g.generate(11);
        let sizes: Vec<usize> = t.jobs.iter().map(|j| j.num_tasks()).collect();
        let small = sizes.iter().filter(|&&s| s <= 10).count();
        let big = sizes.iter().filter(|&&s| s > 100).count();
        // most jobs are small, but the tail exists (paper Fig. 6b needs
        // populated buckets up to >1000 tasks)
        assert!(small as f64 / sizes.len() as f64 > 0.6);
        assert!(big > 0, "no large jobs generated");
    }

    #[test]
    fn demand_mix_has_both_cpu_and_mem_heavy() {
        let g = TraceGenerator::new(GoogleLikeConfig {
            users: 200,
            ..Default::default()
        });
        let t = g.generate(13);
        let cpu_heavy = t
            .users
            .iter()
            .filter(|u| u.demand[0] > u.demand[1])
            .count();
        let mem_heavy = t
            .users
            .iter()
            .filter(|u| u.demand[1] > u.demand[0])
            .count();
        assert!(cpu_heavy > 40, "cpu_heavy={cpu_heavy}");
        assert!(mem_heavy > 40, "mem_heavy={mem_heavy}");
    }

    #[test]
    fn demands_pack_many_per_server() {
        let g = TraceGenerator::new(GoogleLikeConfig::default());
        let t = g.generate(17);
        // median dominant demand well below half the max server
        let mut doms: Vec<f64> =
            t.users.iter().map(|u| u.demand.max()).collect();
        doms.sort_by(|a, b| a.total_cmp(b));
        assert!(doms[doms.len() / 2] < 0.25, "median={}", doms[doms.len() / 2]);
    }

    #[test]
    fn submit_sort_tolerates_nan() {
        // regression: this sort used `partial_cmp().unwrap()`, which
        // panics on the first NaN submit; `total_cmp` ranks NaN
        // deterministically instead. Both NaN signs, mirroring
        // `util::stats::percentile_and_cdf_tolerate_nan`.
        let mk = |submit| JobSpec {
            id: 0,
            user: 0,
            submit,
            tasks: vec![TaskSpec { duration: 1.0 }],
        };
        let mut jobs =
            vec![mk(3.0), mk(f64::NAN), mk(1.0), mk(-f64::NAN), mk(2.0)];
        sort_by_submit(&mut jobs);
        assert!(jobs[0].submit.is_nan()); // -NaN ranks first
        assert_eq!(jobs[1].submit, 1.0);
        assert_eq!(jobs[2].submit, 2.0);
        assert_eq!(jobs[3].submit, 3.0);
        assert!(jobs[4].submit.is_nan()); // +NaN ranks last
    }

    // ---- fault-plan generation -----------------------------------

    #[test]
    fn empty_fault_config_compiles_to_empty_plan() {
        let cfg = FaultGenConfig::default();
        assert!(cfg.is_empty());
        let plan = generate_faults(&cfg, 50, 10_000.0, 7);
        assert!(plan.is_empty());
    }

    #[test]
    fn fault_plan_deterministic_given_seed() {
        let cfg = FaultGenConfig {
            crash_rate: 1.0 / 2000.0,
            rack_size: 8,
            rack_outage_rate: 1.0 / 5000.0,
            flash_at: Some(4000.0),
            ..Default::default()
        };
        let a = generate_faults(&cfg, 64, 10_000.0, 21);
        let b = generate_faults(&cfg, 64, 10_000.0, 21);
        assert_eq!(a, b);
        assert!(!a.is_empty());
        let c = generate_faults(&cfg, 64, 10_000.0, 22);
        assert_ne!(a.events, c.events, "seed must matter");
    }

    #[test]
    fn crash_process_streams_are_per_server() {
        // growing the cluster must not move the existing servers'
        // crash events: each server draws from its own Pcg32 stream
        let cfg = FaultGenConfig {
            crash_rate: 1.0 / 1000.0,
            ..Default::default()
        };
        let small = generate_faults(&cfg, 16, 10_000.0, 5);
        let big = generate_faults(&cfg, 32, 10_000.0, 5);
        let carried: Vec<_> =
            big.events.iter().filter(|e| e.server < 16).collect();
        assert_eq!(small.events.len(), carried.len());
        for (a, b) in small.events.iter().zip(carried) {
            assert_eq!(a, b, "server stream drifted with cluster size");
        }
    }

    #[test]
    fn rack_outage_downs_whole_rack() {
        let cfg = FaultGenConfig {
            rack_size: 4,
            rack_outage_rate: 1.0 / 3000.0,
            ..Default::default()
        };
        let plan = generate_faults(&cfg, 8, 50_000.0, 3);
        assert!(!plan.is_empty());
        // every down time shared by a rack hits rack_size servers
        let downs: Vec<_> =
            plan.events.iter().filter(|e| !e.up).collect();
        let t0 = downs[0].time;
        let peers =
            downs.iter().filter(|e| e.time == t0).count();
        assert_eq!(peers % 4, 0, "rack outages must be rack-wide");
    }

    #[test]
    fn flash_failure_hits_the_configured_fraction() {
        let cfg = FaultGenConfig {
            flash_at: Some(100.0),
            flash_fraction: 0.25,
            flash_downtime: 60.0,
            ..Default::default()
        };
        let plan = generate_faults(&cfg, 40, 10_000.0, 9);
        let downs: Vec<_> =
            plan.events.iter().filter(|e| !e.up).collect();
        assert_eq!(downs.len(), 10); // 25% of 40
        assert!(downs.iter().all(|e| e.time == 100.0));
        let ups: Vec<_> = plan.events.iter().filter(|e| e.up).collect();
        assert!(ups.iter().all(|e| e.time == 160.0));
        // distinct servers
        let mut servers: Vec<usize> =
            downs.iter().map(|e| e.server).collect();
        servers.sort_unstable();
        servers.dedup();
        assert_eq!(servers.len(), 10);
    }

    // ---- churn-plan generation -----------------------------------

    #[test]
    fn empty_churn_config_compiles_to_empty_plan() {
        let cfg = ChurnGenConfig::default();
        assert!(cfg.is_empty());
        let plan = generate_churn(&cfg, 50, 10_000.0, 7);
        assert!(plan.is_empty());
    }

    #[test]
    fn churn_plan_deterministic_given_seed() {
        let cfg = ChurnGenConfig {
            leave_rate: 1.0 / 2000.0,
            rejoin_rate: 1.0 / 1000.0,
            absent_frac: 0.3,
            flash_at: Some(4000.0),
            diurnal_amp: 0.5,
            diurnal_period: 5000.0,
            ..Default::default()
        };
        let a = generate_churn(&cfg, 64, 10_000.0, 21);
        let b = generate_churn(&cfg, 64, 10_000.0, 21);
        assert_eq!(a, b);
        assert!(!a.is_empty());
        let c = generate_churn(&cfg, 64, 10_000.0, 22);
        assert_ne!(a, c, "seed must matter");
    }

    #[test]
    fn churn_streams_are_per_user() {
        // growing the user set must not move the existing users'
        // transitions: each user draws from its own Pcg32 stream
        let cfg = ChurnGenConfig {
            leave_rate: 1.0 / 800.0,
            rejoin_rate: 1.0 / 400.0,
            absent_frac: 0.25,
            ..Default::default()
        };
        let small = generate_churn(&cfg, 16, 10_000.0, 5);
        let big = generate_churn(&cfg, 32, 10_000.0, 5);
        let carried: Vec<_> =
            big.events.iter().filter(|e| e.user < 16).collect();
        assert_eq!(small.events.len(), carried.len());
        for (a, b) in small.events.iter().zip(carried) {
            assert_eq!(a, b, "user stream drifted with population size");
        }
        let carried_absent: Vec<usize> = big
            .absent_at_start
            .iter()
            .copied()
            .filter(|&u| u < 16)
            .collect();
        assert_eq!(small.absent_at_start, carried_absent);
    }

    #[test]
    fn flash_crowd_joins_an_absent_cohort() {
        // everyone absent, renewal processes off: the flash is the
        // only process, so counts are exact
        let cfg = ChurnGenConfig {
            leave_rate: 0.0,
            rejoin_rate: 0.0,
            absent_frac: 1.0,
            flash_at: Some(100.0),
            flash_fraction: 0.25,
            flash_hold: 60.0,
            ..Default::default()
        };
        let plan = generate_churn(&cfg, 40, 10_000.0, 9);
        assert_eq!(plan.absent_at_start.len(), 40);
        let joins: Vec<_> =
            plan.events.iter().filter(|e| e.join).collect();
        assert_eq!(joins.len(), 10); // 25% of 40
        assert!(joins.iter().all(|e| e.time == 100.0));
        let leaves: Vec<_> =
            plan.events.iter().filter(|e| !e.join).collect();
        assert_eq!(leaves.len(), 10);
        assert!(leaves.iter().all(|e| e.time == 160.0));
        // distinct users
        let mut cohort: Vec<usize> =
            joins.iter().map(|e| e.user).collect();
        cohort.sort_unstable();
        cohort.dedup();
        assert_eq!(cohort.len(), 10);
    }

    #[test]
    fn fig4_trace_matches_paper_setup() {
        let t = fig4_trace([100, 200, 300], [50.0, 60.0, 70.0]);
        assert_eq!(t.users.len(), 3);
        assert_eq!(t.jobs[1].submit, 200.0);
        assert_eq!(t.jobs[2].submit, 500.0);
        assert_eq!(t.users[0].demand, ResVec::cpu_mem(0.2, 0.3));
        t.validate().unwrap();
    }
}
