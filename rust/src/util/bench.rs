//! Tiny benchmark harness (substrate — criterion is unavailable
//! offline). Prints mean / p50 / min over timed iterations, sized to a
//! wall-clock budget. Used by every `rust/benches/*.rs` target.

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub min: Duration,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "{:<44} {:>12} {:>12} {:>12}   ({} iters)",
            self.name,
            fmt_dur(self.mean),
            fmt_dur(self.p50),
            fmt_dur(self.min),
            self.iters
        );
    }
}

/// Pretty duration.
pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Print the header row for a group of cases.
pub fn header(group: &str) {
    println!("\n== bench: {group} ==");
    println!(
        "{:<44} {:>12} {:>12} {:>12}",
        "case", "mean", "p50", "min"
    );
}

/// Time `f` repeatedly within `budget` (at least 3 runs, at most
/// `max_iters`), returning distribution statistics. `f` should return
/// something observable to keep the optimizer honest.
pub fn bench<T>(
    name: &str,
    budget: Duration,
    max_iters: usize,
    mut f: impl FnMut() -> T,
) -> BenchResult {
    // warmup
    std::hint::black_box(f());
    let mut samples = Vec::new();
    let start = Instant::now();
    while (samples.len() < 3
        || (start.elapsed() < budget && samples.len() < max_iters))
        && samples.len() < max_iters
    {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed());
    }
    samples.sort();
    let total: Duration = samples.iter().sum();
    let res = BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean: total / samples.len() as u32,
        p50: samples[samples.len() / 2],
        min: samples[0],
    };
    res.print();
    res
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_at_least_three_iters() {
        let r = bench("noop", Duration::from_millis(1), 100, || 1 + 1);
        assert!(r.iters >= 3);
        assert!(r.min <= r.mean);
    }

    #[test]
    fn duration_formatting() {
        assert!(fmt_dur(Duration::from_nanos(500)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(50)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(50)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).contains(" s"));
    }
}
