//! Optimization substrates: a dense two-phase simplex LP solver (the
//! substrate for the paper's eq. (7)) plus the warm-startable
//! [`Solver`] that the incremental dynamic-DRFH allocator
//! (`allocator::incremental`) re-solves from a recorded basis.

pub mod simplex;

pub use simplex::{
    solve, Lp, LpResult, PivotCounts, RowId, SolveStats, Solver, VarId,
};
