//! TOML experiment configuration: the launcher's input format.
//!
//! ```toml
//! seed = 42
//! [cluster]
//! servers = 2000
//! [workload]
//! users = 100
//! duration = 86400.0
//! jobs_per_user = 20.0
//! [sim]
//! horizon = 86400.0
//! sample_dt = 60.0
//! track_user_series = false
//! queue = "wheel"          # wheel | auto (trace-tuned wheel) | heap (naive parity reference)
//! metrics = "full"         # full | streaming (bounded memory)
//! share_sketch = 2048      # optional: per-user share-sketch point budget (0 = exact)
//! shards = "auto"          # 1 (sequential, default) | N | "auto" (per-core data-plane shards)
//! audit = false            # wave-boundary invariant auditor (sim::audit; also DRFH_AUDIT=1)
//! [scheduler]
//! policy = "bestfit"       # bestfit | firstfit | slots | bestfit-xla
//! slots_per_max = 14       # slots policy only
//! [faults]
//! crash_rate = 0.0         # per-server Poisson crash rate (events/s; 0 = off)
//! mean_downtime = 300.0    # mean repair time for independent crashes
//! rack_size = 0            # servers per rack for correlated outages (0 = off)
//! rack_outage_rate = 0.0   # per-rack Poisson outage rate
//! rack_downtime = 900.0    # mean rack repair time
//! flash_at = 0.0           # one-off flash-failure instant (unset = off)
//! flash_fraction = 0.1     # fraction of servers the flash takes down
//! flash_downtime = 600.0   # how long flash-failed servers stay down
//! seed = 0                 # fault-plan seed (unset = top-level seed)
//! envy_eps = 0.05          # fairness-recovery tolerance
//! retry_max_attempts = 3   # attempts per task before it counts lost
//! retry_base = 30.0        # base backoff (doubles per attempt)
//! retry_cap = 3600.0       # backoff ceiling
//! retry_jitter = 0.5       # multiplicative seeded jitter span
//! [churn]
//! leave_rate = 0.0         # per-user Poisson departure rate (events/s; 0 = off)
//! rejoin_rate = 0.000556   # per-user Poisson rejoin rate while absent
//! absent_frac = 0.0        # fraction of users absent at t = 0
//! flash_at = 0.0           # one-off flash-crowd instant (unset = off)
//! flash_frac = 0.1         # fraction of the population the flash crowd targets
//! flash_hold = 1800.0      # how long flash joiners stay before leaving
//! diurnal_amp = 0.0        # diurnal rate modulation amplitude in [0, 1]
//! diurnal_period = 86400.0 # diurnal period (seconds)
//! seed = 0                 # churn-plan seed (unset = top-level seed)
//! ```
//!
//! Parsed with the in-tree TOML-subset parser (`util::toml_lite`; the
//! `toml` crate is unavailable offline).

use crate::cluster::Cluster;
use crate::sched::{BestFitDrfh, FirstFitDrfh, Scheduler, SlotsScheduler};
use crate::sim::{
    ChurnPlan, FaultPlan, MetricsMode, QueueKind, RetryPolicy, ShardCount,
    SimOpts,
};
use crate::util::toml_lite;
use crate::util::Pcg32;
use crate::workload::{
    generate_churn, generate_faults, ChurnGenConfig, FaultGenConfig,
    GoogleLikeConfig, TraceGenerator,
};
use crate::util::error::{anyhow, bail, Context, Result};

#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Number of servers sampled from the Google Table I distribution.
    pub servers: usize,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig { servers: 2000 }
    }
}

#[derive(Clone, Debug)]
pub struct SchedulerConfig {
    /// bestfit | firstfit | slots | bestfit-xla
    pub policy: String,
    /// Slots per maximum server (slots policy only).
    pub slots_per_max: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig { policy: "bestfit".into(), slots_per_max: 14 }
    }
}

#[derive(Clone, Debug)]
pub struct SimConfig {
    pub horizon: f64,
    pub sample_dt: f64,
    pub track_user_series: bool,
    /// Event queue: "wheel" (default) | "auto" (wheel with geometry
    /// tuned from the trace's duration distribution) | "heap" (naive
    /// parity reference).
    pub queue: String,
    /// Metrics retention: "full" (default) | "streaming" (bounded
    /// memory for trace-scale runs).
    pub metrics: String,
    /// Per-user dominant-share sketch budget (points; 0 = exact
    /// retention). Unset = sketches off.
    pub share_sketch: Option<usize>,
    /// Data-plane shards: "1" (sequential, default) | "N" | "auto"
    /// (one shard per core). Reports are bit-identical across all
    /// choices; this is purely a wall-clock lever.
    pub shards: String,
    /// Wave-boundary invariant auditing (`crate::sim::audit`):
    /// decision-neutral, so reports stay bit-identical; panics with a
    /// structured dump on the first violated invariant.
    pub audit: bool,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            horizon: 86_400.0,
            sample_dt: 60.0,
            track_user_series: false,
            queue: "wheel".into(),
            metrics: "full".into(),
            share_sketch: None,
            shards: "1".into(),
            audit: false,
        }
    }
}

/// `[faults]`: the fault-injection processes ([`FaultGenConfig`]) plus
/// the per-job retry policy. Defaults leave every process off, so the
/// compiled plan is empty and the engine's fault layer stays fully
/// dormant (bit-identical to a fault-free build — see
/// `tests/engine_parity.rs`).
#[derive(Clone, Debug)]
pub struct FaultsConfig {
    /// The three seeded generators (crash / rack / flash).
    pub gen: FaultGenConfig,
    /// Fault-plan seed; unset = the top-level experiment seed.
    pub seed: Option<u64>,
    pub retry_max_attempts: u32,
    pub retry_base: f64,
    pub retry_cap: f64,
    pub retry_jitter: f64,
}

impl Default for FaultsConfig {
    fn default() -> Self {
        let retry = RetryPolicy::default();
        FaultsConfig {
            gen: FaultGenConfig::default(),
            seed: None,
            retry_max_attempts: retry.max_attempts,
            retry_base: retry.base,
            retry_cap: retry.cap,
            retry_jitter: retry.jitter,
        }
    }
}

/// `[churn]`: the user join/leave processes ([`ChurnGenConfig`]).
/// Defaults leave every process off, so the compiled plan is empty and
/// the engine's churn layer stays fully dormant (bit-identical to a
/// churn-free build — see `tests/engine_parity.rs`).
#[derive(Clone, Debug, Default)]
pub struct ChurnConfig {
    /// The seeded generators (renewal walks / flash crowd / diurnal).
    pub gen: ChurnGenConfig,
    /// Churn-plan seed; unset = the top-level experiment seed.
    pub seed: Option<u64>,
}

/// Top-level experiment configuration.
#[derive(Clone, Debug, Default)]
pub struct ExperimentConfig {
    pub seed: u64,
    pub cluster: ClusterConfig,
    pub workload: GoogleLikeConfig,
    pub sim: SimConfig,
    pub scheduler: SchedulerConfig,
    pub faults: FaultsConfig,
    pub churn: ChurnConfig,
}

impl ExperimentConfig {
    /// Parse from a TOML string (unset keys keep their defaults).
    pub fn from_toml(s: &str) -> Result<Self> {
        let doc = toml_lite::parse(s)
            .map_err(|e| anyhow!("parsing experiment config: {e}"))?;
        let mut cfg = ExperimentConfig::default();
        if let Some(seed) = doc.get("", "seed").and_then(|v| v.as_u64()) {
            cfg.seed = seed;
        }
        if let Some(v) = doc.get_usize("cluster", "servers") {
            cfg.cluster.servers = v;
        }
        let w = &mut cfg.workload;
        if let Some(v) = doc.get_usize("workload", "users") {
            w.users = v;
        }
        if let Some(v) = doc.get_f64("workload", "duration") {
            w.duration = v;
        }
        if let Some(v) = doc.get_f64("workload", "jobs_per_user") {
            w.jobs_per_user = v;
        }
        if let Some(v) = doc.get_usize("workload", "max_tasks_per_job") {
            w.max_tasks_per_job = v;
        }
        if let Some(v) = doc.get_f64("workload", "job_size_zipf_s") {
            w.job_size_zipf_s = v;
        }
        if let Some(v) = doc.get_f64("workload", "dur_lo") {
            w.dur_lo = v;
        }
        if let Some(v) = doc.get_f64("workload", "dur_hi") {
            w.dur_hi = v;
        }
        if let Some(v) = doc.get_f64("workload", "dur_alpha") {
            w.dur_alpha = v;
        }
        if let Some(v) = doc.get_f64("sim", "horizon") {
            cfg.sim.horizon = v;
        }
        if let Some(v) = doc.get_f64("sim", "sample_dt") {
            cfg.sim.sample_dt = v;
        }
        if let Some(v) = doc.get_bool("sim", "track_user_series") {
            cfg.sim.track_user_series = v;
        }
        if let Some(v) = doc.get_str("sim", "queue") {
            cfg.sim.queue = v.to_string();
        }
        if let Some(v) = doc.get_str("sim", "metrics") {
            cfg.sim.metrics = v.to_string();
        }
        if let Some(v) = doc.get_usize("sim", "share_sketch") {
            cfg.sim.share_sketch = Some(v);
        }
        if let Some(v) = doc.get_bool("sim", "audit") {
            cfg.sim.audit = v;
        }
        // shards accepts both a bare integer and the string "auto"
        if let Some(v) = doc.get_usize("sim", "shards") {
            cfg.sim.shards = v.to_string();
        } else if let Some(v) = doc.get_str("sim", "shards") {
            cfg.sim.shards = v.to_string();
        }
        if let Some(v) = doc.get_str("scheduler", "policy") {
            cfg.scheduler.policy = v.to_string();
        }
        if let Some(v) = doc.get_usize("scheduler", "slots_per_max") {
            cfg.scheduler.slots_per_max = v;
        }
        let f = &mut cfg.faults;
        if let Some(v) = doc.get_f64("faults", "crash_rate") {
            f.gen.crash_rate = v;
        }
        if let Some(v) = doc.get_f64("faults", "mean_downtime") {
            f.gen.mean_downtime = v;
        }
        if let Some(v) = doc.get_usize("faults", "rack_size") {
            f.gen.rack_size = v;
        }
        if let Some(v) = doc.get_f64("faults", "rack_outage_rate") {
            f.gen.rack_outage_rate = v;
        }
        if let Some(v) = doc.get_f64("faults", "rack_downtime") {
            f.gen.rack_downtime = v;
        }
        if let Some(v) = doc.get_f64("faults", "flash_at") {
            f.gen.flash_at = Some(v);
        }
        if let Some(v) = doc.get_f64("faults", "flash_fraction") {
            f.gen.flash_fraction = v;
        }
        if let Some(v) = doc.get_f64("faults", "flash_downtime") {
            f.gen.flash_downtime = v;
        }
        if let Some(v) = doc.get_f64("faults", "envy_eps") {
            f.gen.envy_eps = v;
        }
        if let Some(v) = doc.get("faults", "seed").and_then(|v| v.as_u64())
        {
            f.seed = Some(v);
        }
        if let Some(v) = doc.get_usize("faults", "retry_max_attempts") {
            f.retry_max_attempts = v as u32;
        }
        if let Some(v) = doc.get_f64("faults", "retry_base") {
            f.retry_base = v;
        }
        if let Some(v) = doc.get_f64("faults", "retry_cap") {
            f.retry_cap = v;
        }
        if let Some(v) = doc.get_f64("faults", "retry_jitter") {
            f.retry_jitter = v;
        }
        let ch = &mut cfg.churn;
        if let Some(v) = doc.get_f64("churn", "leave_rate") {
            ch.gen.leave_rate = v;
        }
        if let Some(v) = doc.get_f64("churn", "rejoin_rate") {
            ch.gen.rejoin_rate = v;
        }
        if let Some(v) = doc.get_f64("churn", "absent_frac") {
            ch.gen.absent_frac = v;
        }
        if let Some(v) = doc.get_f64("churn", "flash_at") {
            ch.gen.flash_at = Some(v);
        }
        if let Some(v) = doc.get_f64("churn", "flash_frac") {
            ch.gen.flash_fraction = v;
        }
        if let Some(v) = doc.get_f64("churn", "flash_hold") {
            ch.gen.flash_hold = v;
        }
        if let Some(v) = doc.get_f64("churn", "diurnal_amp") {
            ch.gen.diurnal_amp = v;
        }
        if let Some(v) = doc.get_f64("churn", "diurnal_period") {
            ch.gen.diurnal_period = v;
        }
        if let Some(v) = doc.get("churn", "seed").and_then(|v| v.as_u64()) {
            ch.seed = Some(v);
        }
        Ok(cfg)
    }

    /// Load from a file.
    pub fn load(path: &std::path::Path) -> Result<Self> {
        let s = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_toml(&s)
    }

    /// Sample the cluster.
    pub fn build_cluster(&self) -> Cluster {
        let mut rng = Pcg32::new(self.seed, 0xc1u64);
        Cluster::google_sample(self.cluster.servers, &mut rng)
    }

    /// Generate the trace.
    pub fn build_trace(&self) -> crate::workload::Trace {
        TraceGenerator::new(self.workload.clone()).generate(self.seed)
    }

    /// Instantiate the scheduler policy.
    pub fn build_scheduler(
        &self,
        cluster: &Cluster,
    ) -> Result<Box<dyn Scheduler>> {
        Ok(match self.scheduler.policy.as_str() {
            "bestfit" => Box::new(BestFitDrfh::default()),
            "firstfit" => Box::new(FirstFitDrfh::default()),
            "slots" => Box::new(SlotsScheduler::new(
                cluster,
                self.scheduler.slots_per_max,
            )),
            "bestfit-xla" => {
                let rt = std::sync::Arc::new(
                    crate::runtime::XlaRuntime::load_default()?,
                );
                Box::new(crate::sched::XlaBestFit::new(rt))
            }
            other => bail!("unknown scheduler policy '{other}'"),
        })
    }

    /// Simulation options (validating the queue / metrics choices).
    pub fn sim_opts(&self) -> Result<SimOpts> {
        let queue = match self.sim.queue.as_str() {
            "wheel" => QueueKind::Wheel,
            "auto" => QueueKind::Auto,
            "heap" => QueueKind::Heap,
            other => {
                bail!("unknown sim queue '{other}' (wheel | auto | heap)")
            }
        };
        let metrics = match self.sim.metrics.as_str() {
            "full" => MetricsMode::Full,
            "streaming" => MetricsMode::streaming(),
            other => {
                bail!("unknown sim metrics '{other}' (full | streaming)")
            }
        };
        let shards = match self.sim.shards.as_str() {
            "auto" => ShardCount::Auto,
            s => match s.parse::<usize>() {
                Ok(n) if n >= 1 => ShardCount::Fixed(n),
                _ => bail!(
                    "unknown sim shards '{s}' (\"auto\" | N >= 1)"
                ),
            },
        };
        Ok(SimOpts {
            horizon: self.sim.horizon,
            sample_dt: self.sim.sample_dt,
            track_user_series: self.sim.track_user_series,
            queue,
            metrics,
            share_sketch: self.sim.share_sketch,
            shards,
            audit: self.sim.audit,
            faults: FaultPlan::none(),
            retry: self.retry_policy(),
            churn: ChurnPlan::none(),
        })
    }

    /// The `[faults]` retry policy.
    pub fn retry_policy(&self) -> RetryPolicy {
        RetryPolicy {
            max_attempts: self.faults.retry_max_attempts,
            base: self.faults.retry_base,
            cap: self.faults.retry_cap,
            jitter: self.faults.retry_jitter,
        }
    }

    /// Compile the `[faults]` processes into a plan for a
    /// `servers`-sized cluster ([`crate::workload::generate_faults`]).
    /// Empty (and free) when every process is off; callers drop it into
    /// `SimOpts::faults` — [`Self::sim_opts`] deliberately returns the
    /// empty plan since it does not know the cluster size.
    pub fn build_fault_plan(&self, servers: usize) -> FaultPlan {
        generate_faults(
            &self.faults.gen,
            servers,
            self.sim.horizon,
            self.faults.seed.unwrap_or(self.seed),
        )
    }

    /// Compile the `[churn]` processes into a join/leave plan for a
    /// `users`-sized population ([`crate::workload::generate_churn`]).
    /// Empty (and free) when every process is off; callers drop it into
    /// `SimOpts::churn` — like [`Self::build_fault_plan`] this stays out
    /// of [`Self::sim_opts`], which does not know the population size.
    pub fn build_churn_plan(&self, users: usize) -> ChurnPlan {
        generate_churn(
            &self.churn.gen,
            users,
            self.sim.horizon,
            self.churn.seed.unwrap_or(self.seed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_parse_from_empty() {
        let c = ExperimentConfig::from_toml("").unwrap();
        assert_eq!(c.cluster.servers, 2000);
        assert_eq!(c.scheduler.policy, "bestfit");
        assert_eq!(c.scheduler.slots_per_max, 14);
    }

    #[test]
    fn full_toml_roundtrip() {
        let toml_src = r#"
            seed = 7
            [cluster]
            servers = 100
            [workload]
            users = 3
            duration = 2000.0
            [sim]
            horizon = 2000.0
            sample_dt = 10.0
            track_user_series = true
            [scheduler]
            policy = "slots"
            slots_per_max = 16
        "#;
        let c = ExperimentConfig::from_toml(toml_src).unwrap();
        assert_eq!(c.seed, 7);
        assert_eq!(c.cluster.servers, 100);
        assert_eq!(c.workload.users, 3);
        assert_eq!(c.scheduler.slots_per_max, 16);
        assert!(c.sim.track_user_series);
        let cluster = c.build_cluster();
        assert_eq!(cluster.len(), 100);
        let sched = c.build_scheduler(&cluster).unwrap();
        assert_eq!(sched.name(), "slots");
    }

    #[test]
    fn queue_and_metrics_parse_and_validate() {
        let c = ExperimentConfig::from_toml("").unwrap();
        let opts = c.sim_opts().unwrap();
        assert_eq!(opts.queue, QueueKind::Wheel);
        assert_eq!(opts.metrics, MetricsMode::Full);

        let c = ExperimentConfig::from_toml(
            "[sim]\nqueue = 'heap'\nmetrics = 'streaming'",
        )
        .unwrap();
        let opts = c.sim_opts().unwrap();
        assert_eq!(opts.queue, QueueKind::Heap);
        assert!(matches!(opts.metrics, MetricsMode::Streaming { .. }));

        let c = ExperimentConfig::from_toml(
            "[sim]\nqueue = 'auto'\nshare_sketch = 128",
        )
        .unwrap();
        let opts = c.sim_opts().unwrap();
        assert_eq!(opts.queue, QueueKind::Auto);
        assert_eq!(opts.share_sketch, Some(128));

        let c =
            ExperimentConfig::from_toml("[sim]\nqueue = 'nope'").unwrap();
        assert!(c.sim_opts().is_err());
        let c =
            ExperimentConfig::from_toml("[sim]\nmetrics = 'nope'").unwrap();
        assert!(c.sim_opts().is_err());
    }

    #[test]
    fn shards_parse_and_validate() {
        // default: sequential
        let c = ExperimentConfig::from_toml("").unwrap();
        assert_eq!(c.sim_opts().unwrap().shards, ShardCount::Fixed(1));
        // bare integer
        let c = ExperimentConfig::from_toml("[sim]\nshards = 8").unwrap();
        assert_eq!(c.sim_opts().unwrap().shards, ShardCount::Fixed(8));
        // quoted integer and "auto"
        let c = ExperimentConfig::from_toml("[sim]\nshards = '4'").unwrap();
        assert_eq!(c.sim_opts().unwrap().shards, ShardCount::Fixed(4));
        let c =
            ExperimentConfig::from_toml("[sim]\nshards = 'auto'").unwrap();
        assert_eq!(c.sim_opts().unwrap().shards, ShardCount::Auto);
        // rejects zero and junk
        let c = ExperimentConfig::from_toml("[sim]\nshards = 0").unwrap();
        assert!(c.sim_opts().is_err());
        let c =
            ExperimentConfig::from_toml("[sim]\nshards = 'many'").unwrap();
        assert!(c.sim_opts().is_err());
    }

    #[test]
    fn audit_parses_and_defaults_off() {
        let c = ExperimentConfig::from_toml("").unwrap();
        assert!(!c.sim_opts().unwrap().audit);
        let c =
            ExperimentConfig::from_toml("[sim]\naudit = true").unwrap();
        assert!(c.sim_opts().unwrap().audit);
    }

    #[test]
    fn faults_parse_and_default_off() {
        let c = ExperimentConfig::from_toml("").unwrap();
        assert!(c.faults.gen.is_empty());
        assert!(c.build_fault_plan(100).is_empty());
        let opts = c.sim_opts().unwrap();
        assert!(opts.faults.is_empty());
        assert_eq!(opts.retry, crate::sim::RetryPolicy::default());

        let c = ExperimentConfig::from_toml(
            "seed = 3\n[faults]\ncrash_rate = 0.001\nmean_downtime = \
             120.0\nrack_size = 8\nrack_outage_rate = \
             0.0001\nflash_at = 500.0\nflash_fraction = \
             0.2\nenvy_eps = 0.1\nretry_max_attempts = \
             5\nretry_base = 10.0\nretry_jitter = 0.0",
        )
        .unwrap();
        assert!(!c.faults.gen.is_empty());
        assert_eq!(c.faults.gen.rack_size, 8);
        assert_eq!(c.faults.retry_max_attempts, 5);
        let plan = c.build_fault_plan(64);
        assert!(!plan.is_empty());
        assert_eq!(plan.seed, 3, "defaults to the experiment seed");
        assert_eq!(plan.envy_eps, 0.1);
        let retry = c.retry_policy();
        assert_eq!(retry.max_attempts, 5);
        assert_eq!(retry.base, 10.0);
        assert_eq!(retry.jitter, 0.0);
        // a dedicated fault seed overrides the experiment seed
        let c = ExperimentConfig::from_toml(
            "seed = 3\n[faults]\nflash_at = 500.0\nseed = 11",
        )
        .unwrap();
        assert_eq!(c.build_fault_plan(10).seed, 11);
    }

    #[test]
    fn churn_parse_and_default_off() {
        let c = ExperimentConfig::from_toml("").unwrap();
        assert!(c.churn.gen.is_empty());
        assert!(c.build_churn_plan(100).is_empty());
        assert!(c.sim_opts().unwrap().churn.is_empty());

        let c = ExperimentConfig::from_toml(
            "seed = 3\n[churn]\nleave_rate = 0.001\nrejoin_rate = \
             0.002\nabsent_frac = 0.25\nflash_at = 400.0\nflash_frac = \
             0.5\nflash_hold = 100.0\ndiurnal_amp = 0.3",
        )
        .unwrap();
        assert!(!c.churn.gen.is_empty());
        assert_eq!(c.churn.gen.rejoin_rate, 0.002);
        assert_eq!(c.churn.gen.flash_at, Some(400.0));
        assert_eq!(c.churn.gen.flash_fraction, 0.5);
        assert_eq!(c.churn.gen.diurnal_amp, 0.3);
        let plan = c.build_churn_plan(64);
        assert!(!plan.is_empty());
        assert_eq!(plan.seed, 3, "defaults to the experiment seed");
        assert!(!plan.absent_at_start.is_empty(), "absent_frac = 0.25");
        // a dedicated churn seed overrides the experiment seed
        let c = ExperimentConfig::from_toml(
            "seed = 3\n[churn]\nleave_rate = 0.001\nseed = 11",
        )
        .unwrap();
        assert_eq!(c.build_churn_plan(10).seed, 11);
    }

    #[test]
    fn bad_policy_rejected() {
        let c = ExperimentConfig::from_toml("[scheduler]\npolicy = 'nope'")
            .unwrap();
        let cluster = c.build_cluster();
        assert!(c.build_scheduler(&cluster).is_err());
    }

    #[test]
    fn deterministic_cluster_and_trace() {
        let c = ExperimentConfig::from_toml("seed = 5").unwrap();
        let a = c.build_cluster();
        let b = c.build_cluster();
        for (x, y) in a.servers.iter().zip(&b.servers) {
            assert_eq!(x.capacity, y.capacity);
        }
        assert_eq!(c.build_trace().total_tasks(), c.build_trace().total_tasks());
    }
}
