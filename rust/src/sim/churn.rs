//! Deterministic user churn: join/leave plans for dynamic user sets.
//!
//! A [`ChurnPlan`] is a pre-compiled, fully deterministic schedule of
//! user join/leave transitions plus the set of users absent when the
//! simulation starts. Plans are built offline — by the seeded
//! generators in [`crate::workload::gen`] (per-user alternating
//! leave/rejoin renewal processes, flash-crowd bursts, diurnal rate
//! modulation) or by hand from raw transitions
//! ([`ChurnPlan::from_transitions`]) — and handed to the engine
//! through [`crate::sim::SimOpts::churn`]. The engine compiles the
//! plan into `UserJoin`/`UserLeave` events at construction time and
//! drains them through the one total `(time, seq)` order every other
//! event obeys, so the same plan and seed replay bit-identically at
//! every shard count, and [`ChurnPlan::none`] pushes *zero* events
//! and marks nobody absent — the churn-free engine is byte-for-byte
//! the pre-churn engine (`tests/engine_parity.rs` pins both
//! properties).
//!
//! Semantics at the engine boundary: a *leave* evicts the user's
//! running tasks (their consumed work is counted in
//! `SimReport::abandoned_s`), discards its queued and retry-parked
//! work (`SimReport::tasks_abandoned`), and removes it from every
//! scheduler index; a *join* re-admits the user with a clean slate.
//! Arrivals for an absent user are dropped and counted — degradation
//! under churn is a measured outcome, not an error. Both transitions
//! are idempotent: canonical plans never contain a redundant event,
//! but hand-built ones may, and the engine treats a join of a present
//! user (or a leave of an absent one) as a no-op.

/// One user transition in a churn plan (absolute simulation time).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChurnEvent {
    /// When the transition happens (seconds).
    pub time: f64,
    /// Which user (index into the trace's user set).
    pub user: usize,
    /// `true` = the user joins (enters service), `false` = it leaves.
    pub join: bool,
}

/// A deterministic schedule of user joins and departures.
///
/// Invariants maintained by the constructors: events are sorted by
/// `(time, user, join)` (a leave orders before a join at the same
/// instant), `absent_at_start` is sorted and deduplicated, and no
/// event is redundant — each one flips its user's presence given the
/// initial state, so an absent-at-start user's first event is always
/// a join.
#[derive(Clone, Debug, PartialEq)]
pub struct ChurnPlan {
    /// Seed the plan was generated from (recorded for replay
    /// provenance; every deterministic draw happened at build time).
    pub seed: u64,
    /// Users absent when the simulation starts (sorted, deduped).
    pub absent_at_start: Vec<usize>,
    /// The compiled transition schedule.
    pub events: Vec<ChurnEvent>,
}

impl ChurnPlan {
    /// The empty plan: everybody present, no transitions. The engine
    /// running under `ChurnPlan::none()` produces a bit-identical
    /// [`crate::sim::SimReport`] to the pre-churn engine at every
    /// shard count.
    pub fn none() -> Self {
        ChurnPlan { seed: 0, absent_at_start: Vec::new(), events: Vec::new() }
    }

    /// True when the plan schedules no transitions and marks nobody
    /// absent.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty() && self.absent_at_start.is_empty()
    }

    /// Is `user` absent when the simulation starts?
    pub fn initially_absent(&self, user: usize) -> bool {
        self.absent_at_start.binary_search(&user).is_ok()
    }

    /// Build a canonical plan from raw transitions: negative times
    /// clamp to 0, events sort by `(time, user, join)`, and redundant
    /// transitions (a join while present, a leave while absent, given
    /// `absent_at_start`) are dropped — so the engine's seq
    /// assignment, and therefore the whole replay, is a pure function
    /// of the surviving transitions.
    pub fn from_transitions(
        seed: u64,
        mut absent_at_start: Vec<usize>,
        mut raw: Vec<ChurnEvent>,
    ) -> Self {
        absent_at_start.sort_unstable();
        absent_at_start.dedup();
        for e in &mut raw {
            if e.time < 0.0 {
                e.time = 0.0;
            }
        }
        raw.sort_by(|a, b| {
            a.time
                .total_cmp(&b.time)
                .then_with(|| a.user.cmp(&b.user))
                .then_with(|| a.join.cmp(&b.join))
        });
        // presence tracking over the densest user id mentioned
        let max_user = raw
            .iter()
            .map(|e| e.user)
            .chain(absent_at_start.iter().copied())
            .max();
        let mut present = vec![true; max_user.map_or(0, |m| m + 1)];
        for &u in &absent_at_start {
            present[u] = false;
        }
        let mut events = Vec::with_capacity(raw.len());
        for e in raw {
            if e.join != present[e.user] {
                present[e.user] = e.join;
                events.push(e);
            }
        }
        ChurnPlan { seed, absent_at_start, events }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_empty_and_cheap() {
        let p = ChurnPlan::none();
        assert!(p.is_empty());
        assert_eq!(p.events.len(), 0);
        assert!(!p.initially_absent(0));
    }

    #[test]
    fn transitions_sort_and_drop_redundant() {
        // user 2 starts absent: its leave at t=5 is redundant, its
        // join at t=10 applies; user 0 starts present: its join at
        // t=1 is redundant, its leave at t=20 applies.
        let p = ChurnPlan::from_transitions(
            9,
            vec![2, 2],
            vec![
                ChurnEvent { time: 20.0, user: 0, join: false },
                ChurnEvent { time: 5.0, user: 2, join: false },
                ChurnEvent { time: 1.0, user: 0, join: true },
                ChurnEvent { time: 10.0, user: 2, join: true },
            ],
        );
        assert_eq!(p.absent_at_start, vec![2]);
        assert_eq!(p.events, vec![
            ChurnEvent { time: 10.0, user: 2, join: true },
            ChurnEvent { time: 20.0, user: 0, join: false },
        ]);
        assert!(p.initially_absent(2));
        assert!(!p.initially_absent(0));
    }

    #[test]
    fn alternation_holds_per_user() {
        // whatever the raw soup, the canonical stream alternates
        // join/leave per user starting from the initial state
        let raw = vec![
            ChurnEvent { time: 3.0, user: 1, join: false },
            ChurnEvent { time: 4.0, user: 1, join: false },
            ChurnEvent { time: 7.0, user: 1, join: true },
            ChurnEvent { time: 9.0, user: 1, join: true },
            ChurnEvent { time: 11.0, user: 1, join: false },
        ];
        let p = ChurnPlan::from_transitions(0, vec![], raw);
        let mine: Vec<bool> =
            p.events.iter().filter(|e| e.user == 1).map(|e| e.join).collect();
        assert_eq!(mine, vec![false, true, false]);
    }

    #[test]
    fn negative_times_clamp_and_ties_order_leave_first() {
        let p = ChurnPlan::from_transitions(
            0,
            vec![],
            vec![
                ChurnEvent { time: -3.0, user: 0, join: false },
                ChurnEvent { time: 0.0, user: 0, join: true },
            ],
        );
        // leave clamps to 0, sorts before the join at the same
        // instant (join: false < true), both survive: net present
        assert_eq!(p.events.len(), 2);
        assert!(!p.events[0].join);
        assert!(p.events[1].join);
    }
}
