"""Pallas kernel for progressive-filling user selection.

Selects the eligible user with the lowest *weighted global dominant
share* ``share_i / weight_i`` (paper Sec. V-A/V-B): the user that
progressive filling serves next. Eligibility (active AND has a feasible
server) arrives as an i32 mask. Returns -1 when no user is eligible.
Semantics match ``ref.select_user`` exactly (first-occurrence ties).

TPU mapping: shares/weights/mask are tiny 1-D vectors tiled in VMEM;
the running (best value, best index) scalar pair is carried across the
sequential grid in (1,)-shaped output refs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

USER_TILE = 128


def _select_kernel(share_ref, weight_ref, mask_ref, val_ref, idx_ref):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        val_ref[0] = jnp.float32(jnp.inf)
        idx_ref[0] = jnp.int32(-1)

    share = share_ref[...]
    weight = weight_ref[...]
    mask = mask_ref[...] != 0
    wsafe = jnp.where(weight != 0.0, weight, 1.0)
    key = jnp.where(mask, share / wsafe, jnp.inf)

    tile_min = jnp.min(key)
    tile_arg = jnp.argmin(key).astype(jnp.int32) + t * share.shape[0]

    @pl.when(tile_min < val_ref[0])
    def _update():
        val_ref[0] = tile_min
        idx_ref[0] = tile_arg


@functools.partial(jax.jit, static_argnames=("tile",))
def select_user(share, weight, mask, *, tile=USER_TILE):
    """Pallas-backed masked argmin of share/weight.

    Args:
      share:  f32[n]; weight: f32[n] (positive); mask: i32[n] nonzero=ok.

    Returns:
      i32[1] selected user index (-1 if the mask is empty).
    """
    share = jnp.asarray(share, jnp.float32)
    weight = jnp.asarray(weight, jnp.float32)
    mask = jnp.asarray(mask, jnp.int32)
    n = share.shape[0]
    t = min(tile, n)
    if n % t != 0:
        raise ValueError(f"n={n} not divisible by tile={t}")
    grid = n // t
    _, idx = pl.pallas_call(
        _select_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((t,), lambda i: (i,)),
            pl.BlockSpec((t,), lambda i: (i,)),
            pl.BlockSpec((t,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(share, weight, mask)
    return idx
