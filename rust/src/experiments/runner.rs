//! Parallel sweep runtime: fan policy variants (or any independent
//! simulation jobs) out across scoped threads.
//!
//! Every multi-policy harness (Fig. 5–8, Table II, the ablations) used
//! to run its variants sequentially on clones of the same cluster +
//! trace; the runs are completely independent, so on the standard
//! 3-policy comparison a thread-per-variant fan-out cuts wall-clock by
//! ~3× (and more on the 5-point Table II sweep and Fig. 8's per-user
//! dedicated clouds). `benches/engine_scale.rs` measures the speedup
//! and records it in `BENCH_engine.json`.
//!
//! ## Why factories, not schedulers
//!
//! [`crate::sched::Scheduler`] is deliberately `!Send` — the XLA
//! policy wraps PJRT handles that must stay on their creating thread —
//! so a scheduler can never cross the spawn boundary. The runner
//! instead ships a `Send` *factory* ([`SchedFactory`]) to each worker,
//! which builds the scheduler on the thread that will run it (from the
//! worker's own cluster clone, so constructors like
//! `SlotsScheduler::new(&cluster, 14)` see the cluster they will
//! schedule).
//!
//! ## Determinism
//!
//! Each job runs on its own cluster clone with its own scheduler
//! instance and the simulator is single-threaded and seed-driven, so
//! results are identical to a sequential sweep regardless of worker
//! interleaving — [`sweep_sequential`] exists only as the wall-clock
//! baseline (and as the `DRFH_SEQ=1` escape hatch for debugging).

use crate::cluster::Cluster;
use crate::sched::Scheduler;
use crate::sim::{run, SimOpts, SimReport};
use crate::workload::Trace;
use std::sync::Mutex;

/// Builds one scheduler on the worker thread that will run it. The
/// factory must be `Send` (it crosses the spawn boundary); the
/// scheduler it returns never does.
pub type SchedFactory =
    Box<dyn Fn(&Cluster) -> Box<dyn Scheduler> + Send + Sync>;

/// One independent simulation job (fig8-style harnesses build their
/// own per-job cluster/trace inside the closure).
pub type Job<'env, T> = Box<dyn FnOnce() -> T + Send + 'env>;

/// Run independent jobs across scoped worker threads and return their
/// results in job order. Worker count is `available_parallelism`
/// capped at the job count (override with `DRFH_SWEEP_THREADS`);
/// `DRFH_SEQ=1` forces in-place sequential execution.
pub fn run_parallel<'env, T: Send>(jobs: Vec<Job<'env, T>>) -> Vec<T> {
    run_parallel_budgeted(jobs, 1)
}

/// [`run_parallel`] for jobs that are themselves multi-threaded:
/// `threads_per_job` is the worker threads each job spawns internally
/// (the engine's shard count under `[sim] shards`), and the fan-out is
/// divided down so `sweep workers × threads_per_job` never
/// oversubscribes the machine ([`worker_count_budgeted`]).
pub fn run_parallel_budgeted<'env, T: Send>(
    jobs: Vec<Job<'env, T>>,
    threads_per_job: usize,
) -> Vec<T> {
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = worker_count_budgeted(n, threads_per_job);
    if workers <= 1 {
        return jobs.into_iter().map(|job| job()).collect();
    }
    // LIFO work queue + slot-indexed results: completion order is
    // irrelevant, the output is re-assembled by job index.
    let queue: Mutex<Vec<(usize, Job<'env, T>)>> =
        Mutex::new(jobs.into_iter().enumerate().rev().collect());
    let out: Mutex<Vec<Option<T>>> =
        Mutex::new((0..n).map(|_| None).collect());
    std::thread::scope(|s| {
        for _ in 0..workers {
            // handles are auto-joined when the scope ends
            let _worker = s.spawn(|| loop {
                let next = queue.lock().expect("queue poisoned").pop();
                let Some((i, job)) = next else { break };
                let r = job();
                out.lock().expect("results poisoned")[i] = Some(r);
            });
        }
    });
    out.into_inner()
        .expect("results poisoned")
        .into_iter()
        .map(|r| r.expect("worker exited before finishing its job"))
        .collect()
}

/// Worker threads [`run_parallel`] will actually use for `jobs` jobs:
/// `available_parallelism` capped at the job count and the
/// `DRFH_SWEEP_THREADS` override, 1 under `DRFH_SEQ=1`. Public so
/// benches can report the true denominator next to their speedups.
pub fn worker_count(jobs: usize) -> usize {
    worker_count_budgeted(jobs, 1)
}

/// [`worker_count`] with an internal-parallelism budget: each job is
/// assumed to keep `threads_per_job` cores busy on its own (the
/// sharded engine's propose workers), so the sweep fan-out is
/// `available_parallelism / threads_per_job` — `shards × variants`
/// stays at or under the machine instead of multiplying. An explicit
/// `DRFH_SWEEP_THREADS` still wins (the operator asked for that exact
/// fan-out), and `DRFH_SEQ=1` still forces 1.
pub fn worker_count_budgeted(jobs: usize, threads_per_job: usize) -> usize {
    if std::env::var_os("DRFH_SEQ").is_some() {
        return 1;
    }
    let hw = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1);
    let budget = (hw / threads_per_job.max(1)).max(1);
    let cap = std::env::var("DRFH_SWEEP_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(budget);
    cap.clamp(1, jobs.max(1))
}

/// Run every policy variant on its own clone of `cluster` + `trace`
/// in parallel; reports come back in factory order. When `opts`
/// requests a sharded engine (`[sim] shards`), the variant fan-out is
/// budgeted so `shards × concurrent variants` stays at or under
/// `available_parallelism` ([`worker_count_budgeted`]).
pub fn sweep(
    cluster: &Cluster,
    trace: &Trace,
    opts: &SimOpts,
    factories: Vec<SchedFactory>,
) -> Vec<SimReport> {
    let threads_per_job = opts.shards.resolve(cluster.len());
    let jobs: Vec<Job<'_, SimReport>> = factories
        .into_iter()
        .map(|f| {
            let job: Job<'_, SimReport> = Box::new(move || {
                let c = cluster.clone();
                let sched = f(&c);
                run(c, trace, sched, opts.clone())
            });
            job
        })
        .collect();
    run_parallel_budgeted(jobs, threads_per_job)
}

/// The sequential reference sweep: identical results, one variant at a
/// time. Kept as the wall-clock baseline for `benches/engine_scale.rs`.
pub fn sweep_sequential(
    cluster: &Cluster,
    trace: &Trace,
    opts: &SimOpts,
    factories: &[SchedFactory],
) -> Vec<SimReport> {
    factories
        .iter()
        .map(|f| {
            let c = cluster.clone();
            let sched = f(&c);
            run(c, trace, sched, opts.clone())
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::EvalSetup;
    use crate::sched::{BestFitDrfh, FirstFitDrfh, SlotsScheduler};

    fn three_factories() -> Vec<SchedFactory> {
        vec![
            Box::new(|_: &Cluster| {
                Box::new(BestFitDrfh::default()) as Box<dyn Scheduler>
            }),
            Box::new(|_: &Cluster| {
                Box::new(FirstFitDrfh::default()) as Box<dyn Scheduler>
            }),
            Box::new(|c: &Cluster| {
                Box::new(SlotsScheduler::new(c, 14)) as Box<dyn Scheduler>
            }),
        ]
    }

    /// The budgeted worker count never oversubscribes: `workers ×
    /// threads_per_job` stays at or under the machine (unless the
    /// machine itself is smaller than one job), it never exceeds the
    /// plain fan-out, and it is always at least 1.
    #[test]
    fn budgeted_worker_count_never_oversubscribes() {
        if std::env::var_os("DRFH_SEQ").is_some()
            || std::env::var_os("DRFH_SWEEP_THREADS").is_some()
        {
            return; // operator overrides bypass the budget by design
        }
        let hw = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1);
        for jobs in [1usize, 3, 8, 40] {
            for tpj in [1usize, 2, 8, 64] {
                let w = worker_count_budgeted(jobs, tpj);
                assert!(w >= 1, "jobs {jobs} tpj {tpj}");
                assert!(w <= jobs, "jobs {jobs} tpj {tpj}");
                assert!(
                    w <= worker_count(jobs),
                    "budget must only shrink the fan-out"
                );
                // the oversubscription bound, modulo the >=1 floor
                assert!(
                    w * tpj <= hw.max(tpj),
                    "jobs {jobs} tpj {tpj}: {w} workers on {hw} cores"
                );
            }
        }
        assert_eq!(worker_count_budgeted(5, 0), worker_count_budgeted(5, 1));
    }

    #[test]
    fn run_parallel_preserves_job_order() {
        let jobs: Vec<Job<'static, usize>> = (0..17)
            .map(|i| {
                let job: Job<'static, usize> = Box::new(move || i * i);
                job
            })
            .collect();
        let got = run_parallel(jobs);
        assert_eq!(got, (0..17).map(|i| i * i).collect::<Vec<_>>());
        assert!(run_parallel::<u8>(Vec::new()).is_empty());
    }

    /// The parallel sweep is bit-identical to the sequential one: same
    /// per-variant placement counts, completions, and utilization
    /// series, in factory order.
    #[test]
    fn sweep_matches_sequential_reference() {
        let setup = EvalSetup::with_duration(29, 60, 6, 4_000.0);
        let par = sweep(
            &setup.cluster,
            &setup.trace,
            &setup.opts,
            three_factories(),
        );
        let seq = sweep_sequential(
            &setup.cluster,
            &setup.trace,
            &setup.opts,
            &three_factories(),
        );
        assert_eq!(par.len(), 3);
        for (p, s) in par.iter().zip(&seq) {
            assert_eq!(p.scheduler, s.scheduler);
            assert_eq!(p.tasks_placed, s.tasks_placed);
            assert_eq!(p.tasks_completed, s.tasks_completed);
            assert_eq!(p.cpu_util.v, s.cpu_util.v);
            assert_eq!(p.mem_util.v, s.mem_util.v);
        }
        // the three variants are genuinely different policies
        assert_eq!(par[0].scheduler, "bestfit-drfh");
        assert_eq!(par[1].scheduler, "firstfit-drfh");
        assert_eq!(par[2].scheduler, "slots");
    }
}
