//! Dense two-phase primal simplex with a warm-startable [`Solver`].
//!
//! Substrate for the exact fluid DRFH allocator (paper eq. (7) is a
//! linear program). Solves
//!
//! ```text
//!   maximize    c · x
//!   subject to  A_ub x <= b_ub
//!               A_eq x  = b_eq
//!               x >= 0
//! ```
//!
//! Two entry points:
//!
//! * [`solve`] — the one-shot reference path: build, two-phase solve,
//!   discard. Kept as the parity baseline for the incremental path
//!   (`allocator::solve` uses it on every progressive-filling round).
//! * [`Solver`] — a *stateful* problem that survives edits. After a
//!   solve it records the optimal **basis** (which columns were basic);
//!   subsequent RHS/coefficient edits, appended or deactivated rows,
//!   and frozen variables re-solve *from that basis* instead of from
//!   scratch: refactorize, then a handful of dual/primal pivots instead
//!   of a full phase-1 + phase-2 pass. `allocator::incremental` builds
//!   the event-driven dynamic-DRFH allocator on top of this.
//!
//! ## Basis-reuse invariants
//!
//! The recorded basis is a **set of column identities** — structural
//! variable, the slack of row *r*, or the phase-1 artificial of row *r*
//! (kept only as a placeholder for redundant rows) — never tableau
//! positions or numeric state. Every warm solve rebuilds the raw
//! tableau from the *current* row data and refactorizes by pivoting the
//! recorded columns back in (partial row pivoting), so no numerical
//! error survives across solves; only the combinatorial basis does.
//! Edits maintain the set: an appended `<=` row contributes its own
//! slack, a deactivated row retires its own slack/artificial. Edits
//! that cannot keep the set valid (appending an equality row, fixing a
//! basic variable, deactivating a row whose slack is not basic) simply
//! invalidate it — the next solve is cold. The warm path never trades
//! correctness for speed: a singular refactorization, a basis that is
//! neither primal- nor dual-feasible, or a nonzero artificial
//! placeholder all fall back to the cold two-phase solve.
//!
//! Pivoting uses Dantzig's rule (most negative reduced cost) with a
//! stall detector that falls back to Bland's rule when the objective
//! stops improving, which guarantees termination on degenerate
//! instances; pivot counts are surfaced in [`PivotCounts`] so benches
//! can report warm-start savings, not just wall-clock.
//!
//! Sized for the allocator's use: a few hundred rows by a few thousand
//! columns (server *classes* × users, not raw servers —
//! `Cluster::classes()` collapses identical servers first).

/// A linear program in standard inequality/equality form.
#[derive(Clone, Debug, Default)]
pub struct Lp {
    /// Number of structural variables.
    pub n: usize,
    /// Objective coefficients (maximized), length n.
    pub c: Vec<f64>,
    /// Inequality rows a·x <= b.
    pub a_ub: Vec<Vec<f64>>,
    pub b_ub: Vec<f64>,
    /// Equality rows a·x == b.
    pub a_eq: Vec<Vec<f64>>,
    pub b_eq: Vec<f64>,
}

/// Pivot-level accounting for one solve, surfaced in
/// [`LpResult::Optimal`] so callers can report warm-start savings.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PivotCounts {
    /// Phase-1 (feasibility search) pivots — cold solves only.
    pub phase1: u32,
    /// Phase-2 (optimality search) pivots.
    pub phase2: u32,
    /// Dual-simplex repair pivots — warm solves only.
    pub dual: u32,
    /// Refactorization eliminations (one per basic column) — warm
    /// solves only. Deterministic O(rows) work, kept separate from the
    /// *search* pivots above.
    pub factor: u32,
    /// Stall events that tripped the Bland's-rule fallback.
    pub stalls: u32,
    /// True when the solve started from a reused basis.
    pub warm: bool,
}

impl PivotCounts {
    /// Search pivots: phase-1 + phase-2 + dual repair (excludes the
    /// deterministic refactorization eliminations).
    pub fn search(&self) -> u32 {
        self.phase1 + self.phase2 + self.dual
    }
}

/// Solver outcome.
#[derive(Clone, Debug, PartialEq)]
pub enum LpResult {
    Optimal { x: Vec<f64>, obj: f64, pivots: PivotCounts },
    Infeasible,
    Unbounded,
}

/// Cumulative [`Solver`] accounting across solves.
#[derive(Clone, Copy, Debug, Default)]
pub struct SolveStats {
    pub solves: u64,
    pub warm_solves: u64,
    pub cold_solves: u64,
    /// Warm attempts abandoned to a cold solve (singular basis, lost
    /// primal+dual feasibility, nonzero artificial placeholder, ...).
    pub fallbacks: u64,
    /// Search pivots (phase-1 + phase-2 + dual) across all solves.
    pub pivots: u64,
    /// Refactorization eliminations across all warm solves.
    pub factor_elims: u64,
    pub stall_events: u64,
}

const EPS: f64 = 1e-9;
/// Minimum acceptable pivot magnitude when refactorizing a recorded
/// basis; anything smaller is treated as singular (cold fallback).
const SINGULAR_EPS: f64 = 1e-8;

/// Handle to a structural variable of a [`Solver`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct VarId(usize);

impl VarId {
    /// Index of the variable in solution vectors returned by
    /// [`Solver::solve`].
    #[inline]
    pub fn index(&self) -> usize {
        self.0
    }
}

/// Handle to a constraint row of a [`Solver`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RowId(usize);

impl RowId {
    pub fn index(&self) -> usize {
        self.0
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum RowKind {
    Le,
    Eq,
}

/// One column identity of the recorded basis set (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Basic {
    /// Structural variable (index into the solver's variable list).
    Var(usize),
    /// Slack of row `r` (also stands in for the surplus of a row the
    /// cold path flipped: the surplus of `-a·x <= -b` *is* `b - a·x`,
    /// the same quantity as the slack of `a·x <= b`).
    Slack(usize),
    /// Phase-1 artificial of row `r`, basic at zero on a redundant row.
    Art(usize),
}

#[derive(Clone, Debug)]
struct RowData {
    /// Dense coefficients over all structural variables.
    coeffs: Vec<f64>,
    rhs: f64,
    kind: RowKind,
    active: bool,
}

struct Tableau {
    rows: usize,
    cols: usize, // structural + slack + artificial + rhs
    t: Vec<f64>,
    basis: Vec<usize>,
}

enum DualOutcome {
    /// Primal feasibility restored after `n` pivots.
    Feasible(u32),
    /// A row certifies primal infeasibility (after `n` pivots).
    Infeasible(u32),
    /// Pivot budget exhausted after `n` pivots — caller should fall
    /// back to cold (and still account for the wasted pivots).
    GaveUp(u32),
}

impl Tableau {
    #[inline]
    fn at(&self, r: usize, c: usize) -> f64 {
        self.t[r * self.cols + c]
    }
    #[inline]
    fn at_mut(&mut self, r: usize, c: usize) -> &mut f64 {
        &mut self.t[r * self.cols + c]
    }

    fn pivot(&mut self, pr: usize, pc: usize) {
        let cols = self.cols;
        let pv = self.at(pr, pc);
        debug_assert!(pv.abs() > EPS);
        let inv = 1.0 / pv;
        for c in 0..cols {
            *self.at_mut(pr, c) *= inv;
        }
        for r in 0..self.rows {
            if r == pr {
                continue;
            }
            let f = self.at(r, pc);
            if f.abs() > 0.0 {
                for c in 0..cols {
                    let v = self.at(pr, c);
                    *self.at_mut(r, c) -= f * v;
                }
            }
        }
        self.basis[pr - 1] = pc; // row 0 is the objective
    }

    /// Primal simplex on the current objective row (row 0), maximizing.
    /// Dantzig entering rule; a stall (no objective improvement for
    /// `rows + 16` consecutive pivots) switches to Bland's rule until
    /// the next strict improvement, which guarantees termination on
    /// degenerate instances. Returns `(bounded, pivots, stalls)`.
    fn optimize(&mut self, allowed_cols: usize) -> (bool, u32, u32) {
        let mut pivots = 0u32;
        let mut stalls = 0u32;
        let mut bland = false;
        let mut since_improve = 0u32;
        let stall_limit = self.rows as u32 + 16;
        let mut last_obj = self.at(0, self.cols - 1);
        loop {
            // entering column: reduced profit must be positive
            let mut enter = None;
            if bland {
                // lowest-index rule (anti-cycling)
                for c in 0..allowed_cols {
                    if self.at(0, c) < -EPS {
                        enter = Some(c);
                        break;
                    }
                }
            } else {
                // most negative reduced cost
                let mut best = -EPS;
                for c in 0..allowed_cols {
                    let v = self.at(0, c);
                    if v < best {
                        best = v;
                        enter = Some(c);
                    }
                }
            }
            let Some(pc) = enter else { return (true, pivots, stalls) };
            // leaving: min ratio, ties -> lowest basis index (Bland)
            let mut leave: Option<(usize, f64)> = None;
            for r in 1..self.rows {
                let a = self.at(r, pc);
                if a > EPS {
                    let ratio = self.at(r, self.cols - 1) / a;
                    match leave {
                        None => leave = Some((r, ratio)),
                        Some((br, bratio)) => {
                            if ratio < bratio - EPS
                                || (ratio < bratio + EPS
                                    && self.basis[r - 1] < self.basis[br - 1])
                            {
                                leave = Some((r, ratio));
                            }
                        }
                    }
                }
            }
            let Some((pr, _)) = leave else { return (false, pivots, stalls) };
            self.pivot(pr, pc);
            pivots += 1;
            let obj = self.at(0, self.cols - 1);
            if obj > last_obj + EPS {
                last_obj = obj;
                since_improve = 0;
                bland = false;
            } else {
                since_improve += 1;
                if !bland && since_improve >= stall_limit {
                    bland = true;
                    stalls += 1;
                }
            }
        }
    }

    /// Dual simplex: restore `rhs >= 0` while keeping all reduced costs
    /// over the first `allowed_cols` columns non-negative. Requires a
    /// dual-feasible start. Artificial placeholder columns (beyond
    /// `allowed_cols`) are not real variables and are excluded from the
    /// entering set *and* from the infeasibility certificate.
    fn dual_simplex(&mut self, allowed_cols: usize) -> DualOutcome {
        let mut pivots = 0u32;
        let cap = 200 + 4 * (self.rows as u32 + self.cols as u32);
        loop {
            // leaving row: most negative basic value
            let mut leave: Option<(usize, f64)> = None;
            for r in 1..self.rows {
                let b = self.at(r, self.cols - 1);
                if b < -EPS && leave.map_or(true, |(_, bb)| b < bb) {
                    leave = Some((r, b));
                }
            }
            let Some((pr, _)) = leave else {
                return DualOutcome::Feasible(pivots);
            };
            // entering: min |reduced cost / coeff| over negative
            // coefficients (first index wins ties — Bland-ish)
            let mut enter: Option<(usize, f64)> = None;
            for c in 0..allowed_cols {
                let a = self.at(pr, c);
                if a < -EPS {
                    let ratio = self.at(0, c) / (-a);
                    if enter.map_or(true, |(_, br)| ratio < br - EPS) {
                        enter = Some((c, ratio));
                    }
                }
            }
            let Some((pc, _)) = enter else {
                return DualOutcome::Infeasible(pivots);
            };
            self.pivot(pr, pc);
            pivots += 1;
            if pivots > cap {
                return DualOutcome::GaveUp(pivots);
            }
        }
    }
}

/// Search pivots burnt by an abandoned warm attempt, carried into the
/// cold fallback so per-solve pivot reporting never undercounts the
/// warm path's true work: `(dual, phase2, stalls)`.
type WastedPivots = (u32, u32, u32);

/// A stateful LP that records its optimal basis and re-solves
/// incrementally after edits. See the module docs for the basis-reuse
/// invariants; [`solve`] stays as the one-shot reference wrapper.
#[derive(Clone, Debug)]
pub struct Solver {
    obj: Vec<f64>,
    fixed: Vec<Option<f64>>,
    rows: Vec<RowData>,
    basis: Vec<Basic>,
    has_basis: bool,
    stats: SolveStats,
}

impl Default for Solver {
    fn default() -> Self {
        Self::new()
    }
}

impl Solver {
    /// An empty problem (no variables, no rows).
    pub fn new() -> Self {
        Solver {
            obj: Vec::new(),
            fixed: Vec::new(),
            rows: Vec::new(),
            basis: Vec::new(),
            has_basis: false,
            stats: SolveStats::default(),
        }
    }

    /// Build a solver from a one-shot [`Lp`] (variables in order, then
    /// the `a_ub` rows, then the `a_eq` rows).
    pub fn from_lp(lp: &Lp) -> Self {
        let n = lp.n;
        assert_eq!(lp.c.len(), n);
        assert_eq!(lp.a_ub.len(), lp.b_ub.len());
        assert_eq!(lp.a_eq.len(), lp.b_eq.len());
        for row in lp.a_ub.iter().chain(&lp.a_eq) {
            assert_eq!(row.len(), n);
        }
        let mut s = Solver::new();
        let vars: Vec<VarId> = lp.c.iter().map(|&c| s.add_var(c)).collect();
        for (a, &b) in lp.a_ub.iter().zip(&lp.b_ub) {
            let coeffs: Vec<(VarId, f64)> =
                vars.iter().zip(a).map(|(&v, &x)| (v, x)).collect();
            s.add_row_le(&coeffs, b);
        }
        for (a, &b) in lp.a_eq.iter().zip(&lp.b_eq) {
            let coeffs: Vec<(VarId, f64)> =
                vars.iter().zip(a).map(|(&v, &x)| (v, x)).collect();
            s.add_row_eq(&coeffs, b);
        }
        s
    }

    /// Number of structural variables.
    pub fn num_vars(&self) -> usize {
        self.obj.len()
    }

    /// Cumulative solve accounting.
    pub fn stats(&self) -> SolveStats {
        self.stats
    }

    /// True when the next [`Solver::solve`] will attempt a warm start.
    pub fn has_warm_basis(&self) -> bool {
        self.has_basis
    }

    /// Append a structural variable (objective coefficient `obj`,
    /// zero coefficients in every existing row). Keeps any recorded
    /// basis valid: the new variable enters nonbasic at 0.
    pub fn add_var(&mut self, obj: f64) -> VarId {
        let id = self.obj.len();
        self.obj.push(obj);
        self.fixed.push(None);
        for row in &mut self.rows {
            row.coeffs.push(0.0);
        }
        VarId(id)
    }

    fn add_row(&mut self, kind: RowKind, rhs: f64) -> RowId {
        let id = self.rows.len();
        self.rows.push(RowData {
            coeffs: vec![0.0; self.obj.len()],
            rhs,
            kind,
            active: true,
        });
        if self.has_basis {
            match kind {
                // the new row's own slack joins the basis (B gains a
                // unit row/column: still nonsingular); a negative
                // residual is repaired by the dual simplex
                RowKind::Le => self.basis.push(Basic::Slack(id)),
                // an equality row has no slack to hide behind
                RowKind::Eq => self.invalidate_basis(),
            }
        }
        RowId(id)
    }

    /// Append a `coeffs · x <= rhs` row.
    pub fn add_row_le(&mut self, coeffs: &[(VarId, f64)], rhs: f64) -> RowId {
        let r = self.add_row(RowKind::Le, rhs);
        for &(v, a) in coeffs {
            self.rows[r.0].coeffs[v.0] = a;
        }
        r
    }

    /// Append a `coeffs · x == rhs` row (invalidates any warm basis —
    /// prefer paired `<=` rows for incrementally maintained problems).
    pub fn add_row_eq(&mut self, coeffs: &[(VarId, f64)], rhs: f64) -> RowId {
        let r = self.add_row(RowKind::Eq, rhs);
        for &(v, a) in coeffs {
            self.rows[r.0].coeffs[v.0] = a;
        }
        r
    }

    /// Replace a row's right-hand side. Basis-preserving.
    pub fn set_rhs(&mut self, r: RowId, rhs: f64) {
        self.rows[r.0].rhs = rhs;
    }

    /// Replace one coefficient of a row. Basis-preserving (the warm
    /// refactorization revalidates numerically).
    pub fn set_coeff(&mut self, r: RowId, v: VarId, a: f64) {
        self.rows[r.0].coeffs[v.0] = a;
    }

    /// Replace a variable's objective coefficient. Basis-preserving.
    pub fn set_obj(&mut self, v: VarId, c: f64) {
        self.obj[v.0] = c;
    }

    /// Drop a row from the problem (it can be re-activated later).
    pub fn deactivate_row(&mut self, r: RowId) {
        if !self.rows[r.0].active {
            return;
        }
        self.rows[r.0].active = false;
        if self.has_basis {
            // retire the row's own slack/artificial from the basis; if
            // neither is basic (the row was tight) the set no longer
            // matches the rows and the next solve is cold
            if let Some(pos) = self.basis.iter().position(
                |b| matches!(b, Basic::Slack(i) | Basic::Art(i) if *i == r.0),
            ) {
                self.basis.swap_remove(pos);
            } else {
                self.invalidate_basis();
            }
        }
    }

    /// Re-introduce a previously deactivated row.
    pub fn activate_row(&mut self, r: RowId) {
        if self.rows[r.0].active {
            return;
        }
        self.rows[r.0].active = true;
        if self.has_basis {
            match self.rows[r.0].kind {
                RowKind::Le => self.basis.push(Basic::Slack(r.0)),
                RowKind::Eq => self.invalidate_basis(),
            }
        }
    }

    /// Freeze a variable at `value`: it leaves the column set and its
    /// contribution folds into every row's rhs. Invalidates the basis
    /// only if the variable is currently basic.
    pub fn fix_var(&mut self, v: VarId, value: f64) {
        self.fixed[v.0] = Some(value);
        if self.has_basis
            && self
                .basis
                .iter()
                .any(|b| matches!(b, Basic::Var(i) if *i == v.0))
        {
            self.invalidate_basis();
        }
    }

    /// Release a frozen variable (re-enters nonbasic at 0).
    pub fn unfix_var(&mut self, v: VarId) {
        self.fixed[v.0] = None;
    }

    /// Forget the recorded basis; the next solve is cold.
    pub fn invalidate_basis(&mut self) {
        self.has_basis = false;
        self.basis.clear();
    }

    /// Solve the current problem: warm from the recorded basis when one
    /// is valid, falling back to the cold two-phase solve otherwise.
    /// Pivots burnt by an abandoned warm attempt are folded into the
    /// fallback solve's [`PivotCounts`], so per-solve reporting counts
    /// the warm path's full cost.
    pub fn solve(&mut self) -> LpResult {
        self.stats.solves += 1;
        let mut wasted: WastedPivots = (0, 0, 0);
        if self.has_basis {
            match self.try_warm() {
                Ok(res) => {
                    self.stats.warm_solves += 1;
                    return res;
                }
                Err(w) => {
                    self.stats.fallbacks += 1;
                    self.stats.pivots += (w.0 + w.1) as u64;
                    self.stats.stall_events += w.2 as u64;
                    self.invalidate_basis();
                    wasted = w;
                }
            }
        }
        self.stats.cold_solves += 1;
        let res = self.cold();
        match res {
            LpResult::Optimal { x, obj, mut pivots } => {
                pivots.dual += wasted.0;
                pivots.phase2 += wasted.1;
                pivots.stalls += wasted.2;
                LpResult::Optimal { x, obj, pivots }
            }
            other => other,
        }
    }

    fn record(&mut self, tab: &Tableau, owner: &[Basic]) {
        self.basis = tab.basis.iter().map(|&c| owner[c]).collect();
        self.has_basis = true;
    }

    /// Warm solve: rebuild the raw tableau from current row data,
    /// refactorize by pivoting the recorded basis columns back in, then
    /// repair with dual/primal pivots. `Err` = fall back to cold,
    /// carrying any search pivots the abandoned attempt burnt.
    fn try_warm(&mut self) -> Result<LpResult, WastedPivots> {
        let act: Vec<usize> =
            (0..self.rows.len()).filter(|&i| self.rows[i].active).collect();
        let m = act.len();
        if self.basis.len() != m {
            return Err((0, 0, 0));
        }
        let nvars = self.obj.len();
        let mut col_of_var = vec![usize::MAX; nvars];
        let mut free: Vec<usize> = Vec::new();
        for v in 0..nvars {
            if self.fixed[v].is_none() {
                col_of_var[v] = free.len();
                free.push(v);
            }
        }
        let nf = free.len();

        // column layout: free vars | slack per active <= row |
        // artificial placeholders (rows with a recorded Art entry) | rhs
        let mut owner: Vec<Basic> = Vec::with_capacity(nf + m + 4);
        for &v in &free {
            owner.push(Basic::Var(v));
        }
        let mut slack_col = vec![usize::MAX; self.rows.len()];
        for &ri in &act {
            if self.rows[ri].kind == RowKind::Le {
                slack_col[ri] = owner.len();
                owner.push(Basic::Slack(ri));
            }
        }
        let allowed = owner.len();
        let mut art_col = vec![usize::MAX; self.rows.len()];
        for b in &self.basis {
            if let Basic::Art(ri) = *b {
                if art_col[ri] == usize::MAX {
                    art_col[ri] = owner.len();
                    owner.push(Basic::Art(ri));
                }
            }
        }
        let cols = owner.len() + 1;
        let rhs_c = cols - 1;

        let mut tab = Tableau {
            rows: m + 1,
            cols,
            t: vec![0.0; (m + 1) * cols],
            basis: vec![usize::MAX; m],
        };
        // objective row (phase-2 style): -c over the free columns
        for (c, &v) in free.iter().enumerate() {
            *tab.at_mut(0, c) = -self.obj[v];
        }
        // constraint rows, fixed variables folded into the rhs; no
        // sign normalization — the dual simplex handles negative rhs
        for (k, &ri) in act.iter().enumerate() {
            let r = k + 1;
            let mut b = self.rows[ri].rhs;
            for v in 0..nvars {
                let a = self.rows[ri].coeffs[v];
                if a == 0.0 {
                    continue;
                }
                match self.fixed[v] {
                    Some(val) => b -= a * val,
                    None => *tab.at_mut(r, col_of_var[v]) = a,
                }
            }
            if slack_col[ri] != usize::MAX {
                *tab.at_mut(r, slack_col[ri]) = 1.0;
            }
            if art_col[ri] != usize::MAX {
                *tab.at_mut(r, art_col[ri]) = 1.0;
            }
            *tab.at_mut(r, rhs_c) = b;
        }

        // map the recorded basis set to columns
        let mut bcols: Vec<usize> = Vec::with_capacity(m);
        for b in &self.basis {
            let c = match *b {
                Basic::Var(v) => {
                    if self.fixed[v].is_some() {
                        return Err((0, 0, 0));
                    }
                    col_of_var[v]
                }
                Basic::Slack(ri) => slack_col[ri],
                Basic::Art(ri) => art_col[ri],
            };
            if c == usize::MAX {
                return Err((0, 0, 0));
            }
            bcols.push(c);
        }
        {
            let mut seen = bcols.clone();
            seen.sort_unstable();
            if seen.windows(2).any(|w| w[0] == w[1]) {
                return Err((0, 0, 0)); // duplicate basis column: singular
            }
        }

        // refactorize: Gauss-Jordan, partial row pivoting per column.
        // Pivoting through row 0 prices the objective out as we go.
        let mut done = vec![false; m];
        let mut factor = 0u32;
        for &bc in &bcols {
            let mut best_r = usize::MAX;
            let mut best_a = SINGULAR_EPS;
            for r in 1..=m {
                if done[r - 1] {
                    continue;
                }
                let a = tab.at(r, bc).abs();
                if a > best_a {
                    best_a = a;
                    best_r = r;
                }
            }
            if best_r == usize::MAX {
                return Err((0, 0, 0)); // singular refactorization
            }
            tab.pivot(best_r, bc);
            done[best_r - 1] = true;
            factor += 1;
        }
        self.stats.factor_elims += factor as u64;
        let mut counts = PivotCounts { factor, warm: true, ..Default::default() };

        let primal_ok = (1..=m).all(|r| tab.at(r, rhs_c) >= -EPS);
        let dual_ok = (0..allowed).all(|c| tab.at(0, c) >= -EPS);
        if !primal_ok {
            if !dual_ok {
                // neither simplex applies from here; don't guess
                return Err((0, 0, 0));
            }
            match tab.dual_simplex(allowed) {
                DualOutcome::Feasible(p) => {
                    counts.dual = p;
                }
                DualOutcome::Infeasible(p) => {
                    counts.dual = p;
                    self.stats.pivots += p as u64;
                    self.record(&tab, &owner);
                    return Ok(LpResult::Infeasible);
                }
                DualOutcome::GaveUp(p) => return Err((p, 0, 0)),
            }
        }
        let (ok, p2, stalls) = tab.optimize(allowed);
        counts.phase2 = p2;
        counts.stalls = stalls;
        if !ok {
            self.stats.pivots += (counts.dual + p2) as u64;
            self.stats.stall_events += stalls as u64;
            self.record(&tab, &owner);
            return Ok(LpResult::Unbounded);
        }
        // artificial placeholders are not real variables: if one ended
        // basic at a nonzero value the solution violates its row —
        // only the cold phase-1 can repair that
        for r in 1..=m {
            if tab.basis[r - 1] >= allowed && tab.at(r, rhs_c).abs() > 1e-7 {
                return Err((counts.dual, p2, stalls));
            }
        }
        self.stats.pivots += (counts.dual + p2) as u64;
        self.stats.stall_events += stalls as u64;

        let mut x = vec![0.0; nvars];
        for v in 0..nvars {
            if let Some(val) = self.fixed[v] {
                x[v] = val;
            }
        }
        for r in 1..=m {
            let bc = tab.basis[r - 1];
            if bc < nf {
                x[free[bc]] = tab.at(r, rhs_c).max(0.0);
            }
        }
        let obj = self.obj.iter().zip(&x).map(|(a, b)| a * b).sum();
        self.record(&tab, &owner);
        Ok(LpResult::Optimal { x, obj, pivots: counts })
    }

    /// Cold two-phase solve, recording the final basis for warm reuse.
    fn cold(&mut self) -> LpResult {
        let act: Vec<usize> =
            (0..self.rows.len()).filter(|&i| self.rows[i].active).collect();
        let m = act.len();
        let nvars = self.obj.len();
        let mut col_of_var = vec![usize::MAX; nvars];
        let mut free: Vec<usize> = Vec::new();
        for v in 0..nvars {
            if self.fixed[v].is_none() {
                col_of_var[v] = free.len();
                free.push(v);
            }
        }
        let nf = free.len();

        // Normalize rows to b >= 0 over the free columns (fixed
        // variables folded into the rhs).
        // <= with b>=0 -> slack(+1);  flipped(>=) -> surplus(-1)+artificial;
        // == -> artificial.
        let mut rows_a: Vec<Vec<f64>> = Vec::with_capacity(m);
        let mut rows_b: Vec<f64> = Vec::with_capacity(m);
        let mut kind: Vec<u8> = Vec::with_capacity(m); // 0 = <=, 1 = >=, 2 = ==
        for &ri in &act {
            let row = &self.rows[ri];
            let mut a = vec![0.0; nf];
            let mut b = row.rhs;
            for v in 0..nvars {
                let coeff = row.coeffs[v];
                if coeff == 0.0 {
                    continue;
                }
                match self.fixed[v] {
                    Some(val) => b -= coeff * val,
                    None => a[col_of_var[v]] = coeff,
                }
            }
            let flip = b < 0.0;
            if flip {
                for x in a.iter_mut() {
                    *x = -*x;
                }
                b = -b;
            }
            rows_a.push(a);
            rows_b.push(b);
            kind.push(match (row.kind, flip) {
                (RowKind::Le, false) => 0,
                (RowKind::Le, true) => 1,
                (RowKind::Eq, _) => 2,
            });
        }

        let n_slack = kind.iter().filter(|&&k| k != 2).count();
        let n_art = kind.iter().filter(|&&k| k != 0).count();
        let art_start = nf + n_slack;
        let cols = nf + n_slack + n_art + 1;

        // column owners, for recording the basis after the solve (the
        // surplus of a flipped row is the same quantity as its slack)
        let mut owner: Vec<Basic> = Vec::with_capacity(cols - 1);
        for &v in &free {
            owner.push(Basic::Var(v));
        }
        for (r, &ri) in act.iter().enumerate() {
            if kind[r] != 2 {
                owner.push(Basic::Slack(ri));
            }
        }
        for (r, &ri) in act.iter().enumerate() {
            if kind[r] != 0 {
                owner.push(Basic::Art(ri));
            }
        }

        let mut tab = Tableau {
            rows: m + 1,
            cols,
            t: vec![0.0; (m + 1) * cols],
            basis: vec![0; m],
        };

        // fill constraint rows
        let mut slack_i = 0;
        let mut art_i = 0;
        for r in 0..m {
            for c in 0..nf {
                *tab.at_mut(r + 1, c) = rows_a[r][c];
            }
            *tab.at_mut(r + 1, cols - 1) = rows_b[r];
            match kind[r] {
                0 => {
                    *tab.at_mut(r + 1, nf + slack_i) = 1.0;
                    tab.basis[r] = nf + slack_i;
                    slack_i += 1;
                }
                1 => {
                    *tab.at_mut(r + 1, nf + slack_i) = -1.0; // surplus
                    slack_i += 1;
                    *tab.at_mut(r + 1, art_start + art_i) = 1.0;
                    tab.basis[r] = art_start + art_i;
                    art_i += 1;
                }
                _ => {
                    *tab.at_mut(r + 1, art_start + art_i) = 1.0;
                    tab.basis[r] = art_start + art_i;
                    art_i += 1;
                }
            }
        }

        let mut counts = PivotCounts::default();

        // ---- Phase 1: maximize -(sum of artificials) ----
        if n_art > 0 {
            for c in art_start..art_start + n_art {
                *tab.at_mut(0, c) = 1.0; // minimize sum == maximize negative
            }
            // price out: subtract artificial basic rows from objective
            for r in 0..m {
                if tab.basis[r] >= art_start {
                    for c in 0..cols {
                        let v = tab.at(r + 1, c);
                        *tab.at_mut(0, c) -= v;
                    }
                }
            }
            let (ok, p1, s1) = tab.optimize(cols - 1);
            counts.phase1 = p1;
            counts.stalls += s1;
            self.stats.pivots += p1 as u64;
            self.stats.stall_events += s1 as u64;
            if !ok {
                // phase 1 cannot be unbounded
                self.record(&tab, &owner);
                return LpResult::Infeasible;
            }
            let obj1 = -tab.at(0, cols - 1);
            if obj1.abs() > 1e-6 {
                self.record(&tab, &owner);
                return LpResult::Infeasible;
            }
            // drive remaining basic artificials out of the basis
            for r in 0..m {
                if tab.basis[r] >= art_start {
                    for c in 0..art_start {
                        if tab.at(r + 1, c).abs() > EPS {
                            tab.pivot(r + 1, c);
                            break;
                        }
                    }
                    // no structural pivot available: redundant row,
                    // leave the artificial basic at 0
                }
            }
        }

        // ---- Phase 2: maximize c·x ----
        for c in 0..cols {
            *tab.at_mut(0, c) = 0.0;
        }
        for (c, &v) in free.iter().enumerate() {
            *tab.at_mut(0, c) = -self.obj[v];
        }
        // price out basic structural variables
        for r in 0..m {
            let b = tab.basis[r];
            if b < nf {
                let f = self.obj[free[b]];
                if f != 0.0 {
                    for c in 0..cols {
                        let v = tab.at(r + 1, c);
                        *tab.at_mut(0, c) += f * v;
                    }
                }
            }
        }
        // forbid artificials from re-entering: only structural + slack
        let (ok, p2, s2) = tab.optimize(art_start);
        counts.phase2 = p2;
        counts.stalls += s2;
        self.stats.pivots += p2 as u64;
        self.stats.stall_events += s2 as u64;
        self.record(&tab, &owner);
        if !ok {
            return LpResult::Unbounded;
        }

        let mut x = vec![0.0; nvars];
        for v in 0..nvars {
            if let Some(val) = self.fixed[v] {
                x[v] = val;
            }
        }
        for r in 0..m {
            let b = tab.basis[r];
            if b < nf {
                x[free[b]] = tab.at(r + 1, cols - 1).max(0.0);
            }
        }
        let obj = self.obj.iter().zip(&x).map(|(a, b)| a * b).sum();
        LpResult::Optimal { x, obj, pivots: counts }
    }
}

/// Solve the LP one-shot. See module docs for the accepted form. Thin
/// wrapper over a throwaway [`Solver`] — the parity reference for the
/// warm-started paths.
pub fn solve(lp: &Lp) -> LpResult {
    Solver::from_lp(lp).solve()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn optimal(lp: &Lp) -> (Vec<f64>, f64) {
        match solve(lp) {
            LpResult::Optimal { x, obj, .. } => (x, obj),
            other => panic!("expected optimal, got {other:?}"),
        }
    }

    #[test]
    fn basic_2d() {
        // max x + y st x <= 2, y <= 3, x + y <= 4
        let lp = Lp {
            n: 2,
            c: vec![1.0, 1.0],
            a_ub: vec![
                vec![1.0, 0.0],
                vec![0.0, 1.0],
                vec![1.0, 1.0],
            ],
            b_ub: vec![2.0, 3.0, 4.0],
            ..Default::default()
        };
        let (_, obj) = optimal(&lp);
        assert!((obj - 4.0).abs() < 1e-9);
    }

    #[test]
    fn equality_constraints() {
        // max 3x + 2y st x + y == 4, x <= 3
        let lp = Lp {
            n: 2,
            c: vec![3.0, 2.0],
            a_ub: vec![vec![1.0, 0.0]],
            b_ub: vec![3.0],
            a_eq: vec![vec![1.0, 1.0]],
            b_eq: vec![4.0],
        };
        let (x, obj) = optimal(&lp);
        assert!((x[0] - 3.0).abs() < 1e-9 && (x[1] - 1.0).abs() < 1e-9);
        assert!((obj - 11.0).abs() < 1e-9);
    }

    #[test]
    fn infeasible_detected() {
        // x <= 1, x == 2
        let lp = Lp {
            n: 1,
            c: vec![1.0],
            a_ub: vec![vec![1.0]],
            b_ub: vec![1.0],
            a_eq: vec![vec![1.0]],
            b_eq: vec![2.0],
        };
        assert_eq!(solve(&lp), LpResult::Infeasible);
    }

    #[test]
    fn unbounded_detected() {
        let lp = Lp {
            n: 2,
            c: vec![1.0, 0.0],
            a_ub: vec![vec![-1.0, 0.0]],
            b_ub: vec![0.0],
            ..Default::default()
        };
        assert_eq!(solve(&lp), LpResult::Unbounded);
    }

    #[test]
    fn negative_rhs_flips_to_ge() {
        // max -x st -x <= -2  (i.e. x >= 2); optimum x = 2
        let lp = Lp {
            n: 1,
            c: vec![-1.0],
            a_ub: vec![vec![-1.0]],
            b_ub: vec![-2.0],
            ..Default::default()
        };
        let (x, obj) = optimal(&lp);
        assert!((x[0] - 2.0).abs() < 1e-9);
        assert!((obj + 2.0).abs() < 1e-9);
    }

    #[test]
    fn degenerate_does_not_cycle() {
        // classic degeneracy example (cycles under unguarded Dantzig;
        // the stall detector's Bland fallback must terminate it)
        let lp = Lp {
            n: 4,
            c: vec![0.75, -150.0, 0.02, -6.0],
            a_ub: vec![
                vec![0.25, -60.0, -0.04, 9.0],
                vec![0.5, -90.0, -0.02, 3.0],
                vec![0.0, 0.0, 1.0, 0.0],
            ],
            b_ub: vec![0.0, 0.0, 1.0],
            ..Default::default()
        };
        let (_, obj) = optimal(&lp);
        assert!((obj - 0.05).abs() < 1e-6, "obj={obj}");
    }

    #[test]
    fn drfh_fig3_shape() {
        // the paper's eq.(7) for the Fig.1 example, class-aggregated:
        // users d1=(1/5,1), d2=(1,1/5); servers c1=(2,12), c2=(12,2)
        // (absolute units; demand normalized vectors scaled by dominant
        //  D: user1 dom share unit consumes (0.2, 1.0), user2 (1.0, 0.2)
        //  per *task*; with task = 1 GB mem for u1, 1 CPU for u2 —
        //  variables g_il in units of dominant-resource *fraction*).
        // Here we solve in task units: x_il tasks of user i on server l.
        // max g; per server: sum_i x_il * D_i <= c_l; per user:
        // sum_l x_il * Ddom_i/total_dom = g.
        // u1: D=(0.2,1), dom resource mem, total mem 14.
        // u2: D=(1,0.2), dom cpu, total cpu 14.
        let lp = Lp {
            n: 5, // x11 x12 x21 x22 g
            c: vec![0.0, 0.0, 0.0, 0.0, 1.0],
            a_ub: vec![
                // server 1 cpu: .2 x11 + 1 x21 <= 2
                vec![0.2, 0.0, 1.0, 0.0, 0.0],
                // server 1 mem: 1 x11 + .2 x21 <= 12
                vec![1.0, 0.0, 0.2, 0.0, 0.0],
                // server 2 cpu: .2 x12 + 1 x22 <= 12
                vec![0.0, 0.2, 0.0, 1.0, 0.0],
                // server 2 mem: 1 x12 + .2 x22 <= 2
                vec![0.0, 1.0, 0.0, 0.2, 0.0],
            ],
            b_ub: vec![2.0, 12.0, 12.0, 2.0],
            a_eq: vec![
                // user 1: (x11 + x12)/14 == g
                vec![1.0 / 14.0, 1.0 / 14.0, 0.0, 0.0, -1.0],
                // user 2: (x21 + x22)/14 == g
                vec![0.0, 0.0, 1.0 / 14.0, 1.0 / 14.0, -1.0],
            ],
            b_eq: vec![0.0, 0.0],
        };
        let (x, obj) = optimal(&lp);
        // paper: g = 5/7, 10 tasks each
        assert!((obj - 5.0 / 7.0).abs() < 1e-6, "g={obj}");
        assert!((x[0] + x[1] - 10.0).abs() < 1e-6);
        assert!((x[2] + x[3] - 10.0).abs() < 1e-6);
    }

    #[test]
    fn random_lps_feasible_and_consistent() {
        use crate::util::Pcg32;
        let mut rng = Pcg32::seeded(99);
        for trial in 0..50 {
            let n = 2 + rng.below(4);
            let mu = 1 + rng.below(4);
            let c: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let a_ub: Vec<Vec<f64>> = (0..mu)
                .map(|_| (0..n).map(|_| rng.uniform(0.0, 1.0)).collect())
                .collect();
            let b_ub: Vec<f64> = (0..mu).map(|_| rng.uniform(0.5, 2.0)).collect();
            let lp = Lp { n, c, a_ub, b_ub, ..Default::default() };
            // all-positive rows with positive b and bounded x -> optimal
            match solve(&lp) {
                LpResult::Optimal { x, obj, .. } => {
                    for (row, &b) in lp.a_ub.iter().zip(&lp.b_ub) {
                        let lhs: f64 =
                            row.iter().zip(&x).map(|(a, v)| a * v).sum();
                        assert!(lhs <= b + 1e-6, "trial {trial} violated");
                    }
                    assert!(x.iter().all(|&v| v >= -1e-9));
                    let cobj: f64 =
                        lp.c.iter().zip(&x).map(|(a, v)| a * v).sum();
                    assert!((cobj - obj).abs() < 1e-6);
                    // objective at least as good as x = 0
                    assert!(obj >= -1e-9);
                }
                LpResult::Unbounded => {
                    // possible if some c_j > 0 has a zero column; rows are
                    // dense positive so only if a coefficient drew ~0 —
                    // accept but ensure some positive c exists
                    assert!(lp.c.iter().any(|&v| v > 0.0));
                }
                LpResult::Infeasible => panic!("trial {trial} infeasible"),
            }
        }
    }

    // ---- Solver (warm-start) tests --------------------------------

    fn solver_optimal(s: &mut Solver) -> (Vec<f64>, f64, PivotCounts) {
        match s.solve() {
            LpResult::Optimal { x, obj, pivots } => (x, obj, pivots),
            other => panic!("expected optimal, got {other:?}"),
        }
    }

    #[test]
    fn warm_rhs_edit_resolves_from_basis() {
        // max x + y st x <= 2, y <= 3, x + y <= 4
        let mut s = Solver::new();
        let x = s.add_var(1.0);
        let y = s.add_var(1.0);
        s.add_row_le(&[(x, 1.0)], 2.0);
        s.add_row_le(&[(y, 1.0)], 3.0);
        let rxy = s.add_row_le(&[(x, 1.0), (y, 1.0)], 4.0);
        let (_, obj, p) = solver_optimal(&mut s);
        assert!((obj - 4.0).abs() < 1e-9);
        assert!(!p.warm);
        // loosen the joint cap: primal re-optimization from the basis
        s.set_rhs(rxy, 6.0);
        let (xv, obj, p) = solver_optimal(&mut s);
        assert!((obj - 5.0).abs() < 1e-9, "obj={obj}");
        assert!((xv[0] - 2.0).abs() < 1e-9 && (xv[1] - 3.0).abs() < 1e-9);
        assert!(p.warm, "expected a warm solve");
        assert!(p.search() <= 3, "too many warm pivots: {p:?}");
        // tighten it below the current point: dual-simplex repair
        s.set_rhs(rxy, 3.0);
        let (_, obj, p) = solver_optimal(&mut s);
        assert!((obj - 3.0).abs() < 1e-9, "obj={obj}");
        assert!(p.warm);
        assert!(p.dual >= 1, "expected dual repair pivots: {p:?}");
        let st = s.stats();
        assert_eq!(st.solves, 3);
        assert_eq!(st.cold_solves, 1);
        assert_eq!(st.warm_solves, 2);
    }

    #[test]
    fn warm_append_and_deactivate_row() {
        let mut s = Solver::new();
        let x = s.add_var(1.0);
        s.add_row_le(&[(x, 1.0)], 5.0);
        let (_, obj, _) = solver_optimal(&mut s);
        assert!((obj - 5.0).abs() < 1e-9);
        // appended binding row: warm dual repair down to x = 2
        let tight = s.add_row_le(&[(x, 1.0)], 2.0);
        let (_, obj, p) = solver_optimal(&mut s);
        assert!((obj - 2.0).abs() < 1e-9, "obj={obj}");
        assert!(p.warm && p.dual >= 1, "{p:?}");
        // appended slack row stays warm through deactivation
        let loose = s.add_row_le(&[(x, 1.0)], 9.0);
        let (_, obj, p) = solver_optimal(&mut s);
        assert!((obj - 2.0).abs() < 1e-9);
        assert!(p.warm);
        s.deactivate_row(loose);
        let (_, obj, p) = solver_optimal(&mut s);
        assert!((obj - 2.0).abs() < 1e-9);
        assert!(p.warm, "slack-basic row removal should stay warm");
        // removing the binding row (its slack is nonbasic) goes cold,
        // and must still be correct
        s.deactivate_row(tight);
        let (_, obj, _) = solver_optimal(&mut s);
        assert!((obj - 5.0).abs() < 1e-9, "obj={obj}");
    }

    #[test]
    fn fix_and_unfix_var() {
        // max x + y st x + y <= 4, x <= 2
        let mut s = Solver::new();
        let x = s.add_var(1.0);
        let y = s.add_var(1.0);
        s.add_row_le(&[(x, 1.0), (y, 1.0)], 4.0);
        s.add_row_le(&[(x, 1.0)], 2.0);
        let (_, obj, _) = solver_optimal(&mut s);
        assert!((obj - 4.0).abs() < 1e-9);
        s.fix_var(y, 1.0);
        let (xv, obj, _) = solver_optimal(&mut s);
        assert!((obj - 3.0).abs() < 1e-9, "obj={obj}");
        assert!((xv[0] - 2.0).abs() < 1e-9 && (xv[1] - 1.0).abs() < 1e-9);
        s.unfix_var(y);
        let (_, obj, _) = solver_optimal(&mut s);
        assert!((obj - 4.0).abs() < 1e-9);
    }

    #[test]
    fn appended_var_enters_warm() {
        // max x st x <= 3; then add y with obj 2, y <= 1 coupled row
        let mut s = Solver::new();
        let x = s.add_var(1.0);
        s.add_row_le(&[(x, 1.0)], 3.0);
        let (_, obj, _) = solver_optimal(&mut s);
        assert!((obj - 3.0).abs() < 1e-9);
        let y = s.add_var(2.0);
        s.add_row_le(&[(y, 1.0)], 1.0);
        let (xv, obj, p) = solver_optimal(&mut s);
        assert!((obj - 5.0).abs() < 1e-9, "obj={obj}");
        assert!((xv[1] - 1.0).abs() < 1e-9);
        assert!(p.warm, "new column should enter from the warm basis");
    }

    #[test]
    fn warm_matches_cold_on_random_edits() {
        use crate::util::Pcg32;
        let mut rng = Pcg32::seeded(4242);
        for trial in 0..30 {
            let n = 2 + rng.below(4);
            let mu = 2 + rng.below(4);
            let c: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 1.0)).collect();
            let a_ub: Vec<Vec<f64>> = (0..mu)
                .map(|_| (0..n).map(|_| rng.uniform(0.05, 1.0)).collect())
                .collect();
            let b_ub: Vec<f64> =
                (0..mu).map(|_| rng.uniform(0.5, 2.0)).collect();
            let mut lp = Lp { n, c, a_ub, b_ub, ..Default::default() };
            let mut s = Solver::from_lp(&lp);
            s.solve();
            for edit in 0..4 {
                let r = rng.below(mu);
                let nb = rng.uniform(0.3, 2.5);
                lp.b_ub[r] = nb;
                s.set_rhs(RowId(r), nb);
                let warm = s.solve();
                let cold = solve(&lp);
                match (warm, cold) {
                    (
                        LpResult::Optimal { obj: ow, x: xw, .. },
                        LpResult::Optimal { obj: oc, .. },
                    ) => {
                        assert!(
                            (ow - oc).abs() < 1e-7,
                            "trial {trial} edit {edit}: {ow} vs {oc}"
                        );
                        // warm solution must satisfy the edited rows
                        for (row, &b) in lp.a_ub.iter().zip(&lp.b_ub) {
                            let lhs: f64 = row
                                .iter()
                                .zip(&xw)
                                .map(|(a, v)| a * v)
                                .sum();
                            assert!(
                                lhs <= b + 1e-6,
                                "trial {trial} edit {edit} violated"
                            );
                        }
                    }
                    (w, c) => {
                        panic!("trial {trial} edit {edit}: {w:?} vs {c:?}")
                    }
                }
            }
            let st = s.stats();
            assert!(st.warm_solves > 0, "trial {trial}: never warm");
        }
    }

    #[test]
    fn pivot_counts_surfaced() {
        let lp = Lp {
            n: 2,
            c: vec![1.0, 1.0],
            a_ub: vec![vec![1.0, 0.0], vec![0.0, 1.0], vec![1.0, 1.0]],
            b_ub: vec![2.0, 3.0, 4.0],
            ..Default::default()
        };
        match solve(&lp) {
            LpResult::Optimal { pivots, .. } => {
                assert!(pivots.phase2 > 0, "{pivots:?}");
                assert!(!pivots.warm);
                assert_eq!(pivots.dual, 0);
            }
            other => panic!("{other:?}"),
        }
    }
}
