//! Streaming per-user dominant-share sketches: Fig. 4-style share
//! trajectories at a fixed point budget per user.
//!
//! The paper's Fig. 4 plots each user's global dominant share over
//! time. [`crate::sim::SimOpts::track_user_series`] reproduces that
//! exactly — one retained sample per user per tick — which is the
//! right tool for the 3-user Fig. 4 scenario and untenable at the
//! ROADMAP's millions of users. [`ShareSketch`] is the bounded
//! alternative ([`crate::sim::SimOpts::share_sketch`]): per user it
//! keeps
//!
//! * exact O(1) streaming summaries of the sampled trajectory —
//!   Welford count/mean/variance/min/max
//!   ([`crate::util::stats::StreamStats`]) and P² median / p90
//!   estimates ([`crate::util::stats::P2Quantile`]) — plus the latest
//!   sample, and
//! * a plottable trajectory held under a fixed point budget by the
//!   same stride-doubling decimation the streaming metrics mode
//!   applies to utilization series
//!   ([`crate::metrics::TimeSeries::enforce_cap`]): the retained grid
//!   always spans the whole horizon, at a coarsening stride.
//!
//! Memory per user is `O(budget)` — independent of horizon length and
//! sample rate — so a million-user run with a 64-point budget holds
//! ~1.5 KiB/user of trajectory instead of an unbounded series.
//!
//! ## Parity reference
//!
//! [`ShareSketch::exact`] (budget 0 = never decimate) follows the
//! crate's `::naive()` convention: its series is the exact
//! trajectory, and the streaming summaries are *bit-identical*
//! between exact and budgeted sketches (they fold every sample before
//! decimation touches anything). The bounded-error guarantees of the
//! decimated series and the P² quantiles are pinned by this module's
//! tests against the exact reference.

use crate::metrics::TimeSeries;
use crate::util::stats::{P2Quantile, StreamStats};

/// A bounded-memory sketch of one user's dominant-share trajectory.
#[derive(Clone, Debug, PartialEq)]
pub struct ShareSketch {
    /// Point budget for the retained trajectory (0 = exact: never
    /// decimate).
    budget: usize,
    /// The retained trajectory (decimated to `budget` points).
    pub series: TimeSeries,
    /// Exact streaming moments over every sample ever pushed.
    pub stats: StreamStats,
    /// P² estimate of the trajectory median.
    pub p50: P2Quantile,
    /// P² estimate of the trajectory 90th percentile.
    pub p90: P2Quantile,
    /// Most recent sample value (the "current share").
    pub last: f64,
}

impl ShareSketch {
    /// Sketch with a trajectory budget of `budget` points (0 keeps
    /// every point — see [`ShareSketch::exact`]).
    pub fn with_budget(budget: usize) -> Self {
        ShareSketch {
            budget,
            series: TimeSeries::default(),
            stats: StreamStats::default(),
            p50: P2Quantile::new(0.50),
            p90: P2Quantile::new(0.90),
            last: 0.0,
        }
    }

    /// The exact-mode parity reference: unbounded retention, same
    /// summary accumulators.
    pub fn exact() -> Self {
        Self::with_budget(0)
    }

    /// The configured point budget (0 = exact).
    pub fn budget(&self) -> usize {
        self.budget
    }

    pub fn is_exact(&self) -> bool {
        self.budget == 0
    }

    /// Samples folded in so far (decimation does not change this).
    pub fn count(&self) -> u64 {
        self.stats.count()
    }

    /// Fold in one sample of the share trajectory.
    pub fn push(&mut self, t: f64, v: f64) {
        self.stats.push(v);
        self.p50.push(v);
        self.p90.push(v);
        self.last = v;
        self.series.push(t, v);
        self.series.enforce_cap(self.budget);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;
    use crate::util::Pcg32;

    /// Satellite guarantee: a budgeted sketch vs the exact-trajectory
    /// reference — identical streaming summaries (bit-exact), bounded
    /// trajectory memory, horizon-spanning grid, and bounded error on
    /// the derived quantities (time average, P² quantiles).
    #[test]
    fn sketch_vs_exact_trajectory_bounded_error() {
        let mut rng = Pcg32::seeded(3131);
        let budget = 64;
        let mut sketch = ShareSketch::with_budget(budget);
        let mut exact = ShareSketch::exact();
        assert!(exact.is_exact() && !sketch.is_exact());
        assert_eq!(sketch.budget(), budget);
        // a Fig. 4-shaped trajectory: ramp in, plateau with noise,
        // drain out — 20k samples, far beyond the budget
        let n = 20_000usize;
        let mut vals = Vec::with_capacity(n);
        for i in 0..n {
            let t = i as f64;
            let base = if i < n / 4 {
                i as f64 / (n / 4) as f64
            } else if i < 3 * n / 4 {
                1.0
            } else {
                (n - i) as f64 / (n / 4) as f64
            };
            let v = (0.5 * base + rng.uniform(-0.02, 0.02)).max(0.0);
            sketch.push(t, v);
            exact.push(t, v);
            vals.push(v);
        }
        // streaming summaries are bit-identical to the exact ones:
        // decimation never touches the accumulators
        assert_eq!(sketch.stats, exact.stats);
        assert_eq!(sketch.p50, exact.p50);
        assert_eq!(sketch.p90, exact.p90);
        assert_eq!(sketch.last, exact.last);
        assert_eq!(sketch.count(), n as u64);
        // memory bound holds; exact mode retained everything
        assert!(sketch.series.len() <= budget);
        assert!(sketch.series.len() > budget / 2);
        assert_eq!(exact.series.len(), n);
        // the decimated grid still spans the horizon
        assert_eq!(sketch.series.t[0], 0.0);
        assert!(*sketch.series.t.last().unwrap() > (n - 1) as f64 * 0.99);
        // bounded error on the derived quantities
        let avg_err =
            (sketch.series.time_avg() - exact.series.time_avg()).abs();
        assert!(avg_err < 0.05, "time-avg drift {avg_err}");
        let p50_exact = stats::percentile(&vals, 50.0);
        let p90_exact = stats::percentile(&vals, 90.0);
        assert!(
            (sketch.p50.quantile() - p50_exact).abs() < 0.05,
            "p50 {} vs exact {p50_exact}",
            sketch.p50.quantile()
        );
        assert!(
            (sketch.p90.quantile() - p90_exact).abs() < 0.05,
            "p90 {} vs exact {p90_exact}",
            sketch.p90.quantile()
        );
    }

    #[test]
    fn empty_sketch_defaults() {
        let s = ShareSketch::with_budget(8);
        assert_eq!(s.count(), 0);
        assert_eq!(s.last, 0.0);
        assert!(s.series.is_empty());
        assert_eq!(s.stats.mean(), 0.0);
    }
}
