//! Native (pure-Rust) picker mirroring the AOT `sched_step` semantics
//! exactly — same f32 arithmetic, same operation order, same
//! first-occurrence tie-breaking — so XLA and native decisions can be
//! asserted identical in `rust/tests/picker_parity.rs` and swapped
//! freely at runtime.

/// Best feasible server per user: H(i,l) score (paper eq. 9) and
/// argmin, f32 arithmetic identical to `kernels/bestfit.py`.
pub fn score_servers(
    avail: &[f32],
    demand: &[f32],
    n: usize,
    k: usize,
    m: usize,
) -> (Vec<f32>, Vec<i32>) {
    debug_assert_eq!(avail.len(), k * m);
    debug_assert_eq!(demand.len(), n * m);
    let mut best_h = vec![f32::INFINITY; n];
    let mut best_s = vec![-1i32; n];
    // precompute per-user demand ratios (relative to resource 0)
    let mut dratio = vec![0.0f32; n * m];
    for i in 0..n {
        let d0 = demand[i * m];
        let den = if d0 != 0.0 { d0 } else { 1.0 };
        for r in 0..m {
            dratio[i * m + r] = demand[i * m + r] / den;
        }
    }
    for l in 0..k {
        let a = &avail[l * m..l * m + m];
        let a0 = a[0];
        let aden = if a0 != 0.0 { a0 } else { 1.0 };
        for i in 0..n {
            // feasibility: all resources fit
            let mut fit = true;
            for r in 0..m {
                if a[r] < demand[i * m + r] {
                    fit = false;
                    break;
                }
            }
            if !fit {
                continue;
            }
            let mut h = 0.0f32;
            for r in 0..m {
                h += (dratio[i * m + r] - a[r] / aden).abs();
            }
            if h < best_h[i] {
                best_h[i] = h;
                best_s[i] = l as i32;
            }
        }
    }
    (best_h, best_s)
}

/// Masked argmin of share/weight (first occurrence), mirroring
/// `kernels/dominant.py`. -1 when no user is eligible. Zero weights
/// fall back to 1.0 — the f32 twin of `sched::effective_weight`, so
/// the engine-side and kernel-side rankings agree (asserted in
/// `sched::tests::share_key_matches_picker_select_user`).
pub fn select_user(share: &[f32], weight: &[f32], mask: &[bool]) -> i32 {
    let mut best = f32::INFINITY;
    let mut idx = -1i32;
    for i in 0..share.len() {
        if !mask[i] {
            continue;
        }
        let w = if weight[i] != 0.0 { weight[i] } else { 1.0 };
        let key = share[i] / w;
        if key < best {
            best = key;
            idx = i as i32;
        }
    }
    idx
}

/// One progressive-filling decision, mirroring `model.sched_step`.
///
/// Decision-equivalent to scoring every (user, server) pair like the
/// XLA kernel does, but restructured for a scalar CPU (§Perf, see
/// EXPERIMENTS.md): pass 1 finds `has_fit[i]` with early exit on the
/// first feasible server; only the *selected* user's servers are then
/// H-scored. Selection and tie-breaking are unchanged, so decisions
/// stay bit-identical to `score_servers` + `select_user`.
pub fn sched_step(
    avail: &[f32],
    demand: &[f32],
    share: &[f32],
    weight: &[f32],
    active: &[i32],
    n: usize,
    k: usize,
    m: usize,
) -> (i32, i32) {
    // pass 1: eligibility = active AND fits somewhere (early exit)
    let mut best = f32::INFINITY;
    let mut u = -1i32;
    for i in 0..n {
        if active[i] == 0 {
            continue;
        }
        let w = if weight[i] != 0.0 { weight[i] } else { 1.0 };
        let key = share[i] / w;
        if key >= best {
            continue; // cannot win selection; skip the fit scan
        }
        let d = &demand[i * m..i * m + m];
        let fits_somewhere = (0..k).any(|l| {
            let a = &avail[l * m..l * m + m];
            (0..m).all(|r| a[r] >= d[r])
        });
        if fits_somewhere {
            best = key;
            u = i as i32;
        }
    }
    if u < 0 {
        return (-1, -1);
    }
    // pass 2: best-fit server for the selected user only
    let ui = u as usize;
    let d = &demand[ui * m..ui * m + m];
    let d0 = d[0];
    let dden = if d0 != 0.0 { d0 } else { 1.0 };
    let mut best_h = f32::INFINITY;
    let mut best_s = -1i32;
    for l in 0..k {
        let a = &avail[l * m..l * m + m];
        let mut fit = true;
        for r in 0..m {
            if a[r] < d[r] {
                fit = false;
                break;
            }
        }
        if !fit {
            continue;
        }
        let aden = if a[0] != 0.0 { a[0] } else { 1.0 };
        let mut h = 0.0f32;
        for r in 0..m {
            h += (d[r] / dden - a[r] / aden).abs();
        }
        if h < best_h {
            best_h = h;
            best_s = l as i32;
        }
    }
    (u, best_s)
}

/// `steps` decisions with the same state updates as `model.sched_loop`.
#[allow(clippy::too_many_arguments)]
pub fn sched_loop(
    avail: &mut [f32],
    demand: &[f32],
    share: &mut [f32],
    weight: &[f32],
    pending: &mut [i32],
    n: usize,
    k: usize,
    m: usize,
    steps: usize,
) -> Vec<(i32, i32)> {
    let mut decisions = Vec::with_capacity(steps);
    for _ in 0..steps {
        let active: Vec<i32> =
            pending.iter().map(|&p| i32::from(p > 0)).collect();
        let (u, s) = sched_step(avail, demand, share, weight, &active, n, k, m);
        if u >= 0 {
            let (ui, si) = (u as usize, s as usize);
            for r in 0..m {
                avail[si * m + r] -= demand[ui * m + r];
            }
            let dom = (0..m)
                .map(|r| demand[ui * m + r])
                .fold(f32::MIN, f32::max);
            share[ui] += dom;
            pending[ui] -= 1;
        }
        decisions.push((u, s));
    }
    decisions
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_routing() {
        // server 0: (2 CPU, 12 GB); server 1: (12 CPU, 2 GB)
        let avail = [2.0, 12.0, 12.0, 2.0];
        let demand = [0.2, 1.0, 1.0, 0.2]; // u0 mem-heavy, u1 cpu-heavy
        let (_, bs) = score_servers(&avail, &demand, 2, 2, 2);
        assert_eq!(bs, vec![0, 1]);
    }

    #[test]
    fn select_user_ties_first_occurrence() {
        let share = [0.5, 0.5, 0.2, 0.2];
        let weight = [1.0; 4];
        let mask = [true, true, true, true];
        assert_eq!(select_user(&share, &weight, &mask), 2);
        let mask = [true, true, false, true];
        assert_eq!(select_user(&share, &weight, &mask), 3);
        assert_eq!(select_user(&share, &weight, &[false; 4]), -1);
    }

    #[test]
    fn sched_step_no_fit_returns_minus_one() {
        let avail = [0.01f32, 0.01];
        let demand = [0.5f32, 0.5];
        let (u, s) = sched_step(
            &avail,
            &demand,
            &[0.0],
            &[1.0],
            &[1],
            1,
            1,
            2,
        );
        assert_eq!((u, s), (-1, -1));
    }

    #[test]
    fn sched_loop_places_until_pending_exhausted() {
        let mut avail = vec![10.0f32, 10.0];
        let demand = vec![1.0f32, 1.0];
        let mut share = vec![0.0f32];
        let mut pending = vec![3i32];
        let dec = sched_loop(
            &mut avail,
            &demand,
            &mut share,
            &[1.0],
            &mut pending,
            1,
            1,
            2,
            5,
        );
        let placed = dec.iter().filter(|d| d.0 >= 0).count();
        assert_eq!(placed, 3);
        assert_eq!(pending[0], 0);
        assert!((avail[0] - 7.0).abs() < 1e-6);
        assert!((share[0] - 3.0).abs() < 1e-6);
    }
}
