//! Optimization substrates: a dense two-phase simplex LP solver used by
//! the exact fluid DRFH allocator.

pub mod simplex;

pub use simplex::{solve, Lp, LpResult};
