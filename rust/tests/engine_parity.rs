//! Decision parity: the indexed scheduling core (`sched::index`) must
//! emit a placement sequence *bit-identical* to the seed's linear-scan
//! path — same `Pick` stream, same blocked/unblocked churn, same
//! metrics — on randomized traces that exercise saturation (blocking),
//! completions (unblocking), and weighted users.
//!
//! The wrapper records every `pick` outcome flowing through the
//! engine, so the comparison covers the full blocked-user protocol,
//! not just aggregate counts.

use drfh::cluster::{Cluster, ResVec};
use drfh::sched::{BestFitDrfh, FirstFitDrfh, Pick, Scheduler, UserState};
use drfh::sim::{run, SimOpts};
use drfh::util::Pcg32;
use drfh::workload::{
    GoogleLikeConfig, JobSpec, TaskSpec, Trace, TraceGenerator, UserSpec,
};
use std::cell::RefCell;
use std::rc::Rc;

/// Records every `pick` outcome while delegating everything (including
/// the incremental-index notifications) to the wrapped policy.
struct Recording<S> {
    inner: S,
    log: Rc<RefCell<Vec<Pick>>>,
}

impl<S: Scheduler> Scheduler for Recording<S> {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn pick(
        &mut self,
        cluster: &Cluster,
        users: &[UserState],
        eligible: &[bool],
    ) -> Pick {
        let p = self.inner.pick(cluster, users, eligible);
        self.log.borrow_mut().push(p);
        p
    }

    fn can_fit(
        &self,
        cluster: &Cluster,
        users: &[UserState],
        user: usize,
        server: usize,
    ) -> bool {
        self.inner.can_fit(cluster, users, user, server)
    }

    fn allows_overcommit(&self) -> bool {
        self.inner.allows_overcommit()
    }

    fn on_free(&mut self, server: usize) {
        self.inner.on_free(server);
    }

    fn on_place(&mut self, user: usize, server: usize) {
        self.inner.on_place(user, server);
    }

    fn on_complete(&mut self, user: usize, server: usize) {
        self.inner.on_complete(user, server);
    }

    fn on_ready(&mut self, user: usize) {
        self.inner.on_ready(user);
    }
}

/// Run `trace` through both paths of a policy pair and assert the full
/// decision streams (and headline metrics) are identical.
fn assert_parity<A, B>(
    label: &str,
    cluster: &Cluster,
    trace: &Trace,
    opts: &SimOpts,
    indexed: A,
    naive: B,
) where
    A: Scheduler + 'static,
    B: Scheduler + 'static,
{
    let log_a = Rc::new(RefCell::new(Vec::new()));
    let log_b = Rc::new(RefCell::new(Vec::new()));
    let ra = run(
        cluster.clone(),
        trace,
        Box::new(Recording { inner: indexed, log: log_a.clone() }),
        opts.clone(),
    );
    let rb = run(
        cluster.clone(),
        trace,
        Box::new(Recording { inner: naive, log: log_b.clone() }),
        opts.clone(),
    );
    let a = log_a.borrow();
    let b = log_b.borrow();
    assert_eq!(a.len(), b.len(), "{label}: pick-stream lengths differ");
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert_eq!(x, y, "{label}: decision {i} diverged");
    }
    assert_eq!(ra.tasks_placed, rb.tasks_placed, "{label}: placed");
    assert_eq!(ra.tasks_completed, rb.tasks_completed, "{label}: completed");
    assert_eq!(ra.cpu_util.v, rb.cpu_util.v, "{label}: cpu util series");
    assert_eq!(ra.mem_util.v, rb.mem_util.v, "{label}: mem util series");
    assert!(ra.tasks_placed > 0, "{label}: degenerate run placed nothing");
}

/// The constructors must select the path their name promises — the
/// parity runs below are meaningless if both sides are the same path.
#[test]
fn constructors_select_the_expected_path() {
    assert!(BestFitDrfh::default().is_indexed());
    assert!(!BestFitDrfh::naive().is_indexed());
    assert!(!BestFitDrfh::strict_filling().is_indexed());
    assert!(FirstFitDrfh::default().is_indexed());
    assert!(!FirstFitDrfh::naive().is_indexed());
}

/// Randomized Google-like traces on a deliberately tight cluster so
/// blocking/unblocking dominates — the paths that could diverge.
#[test]
fn randomized_traces_bestfit() {
    for seed in 0..5u64 {
        let mut rng = Pcg32::seeded(9_100 + seed);
        let cluster = Cluster::google_sample(30 + rng.below(50), &mut rng);
        let gen = TraceGenerator::new(GoogleLikeConfig {
            users: 4 + rng.below(8),
            duration: 4_000.0,
            jobs_per_user: 6.0,
            max_tasks_per_job: 80,
            ..Default::default()
        });
        let trace = gen.generate(seed * 31 + 7);
        let opts = SimOpts {
            horizon: 4_000.0,
            sample_dt: 100.0,
            track_user_series: false,
        };
        assert_parity(
            &format!("bestfit seed {seed}"),
            &cluster,
            &trace,
            &opts,
            BestFitDrfh::default(),
            BestFitDrfh::naive(),
        );
    }
}

#[test]
fn randomized_traces_firstfit() {
    for seed in 0..5u64 {
        let mut rng = Pcg32::seeded(9_500 + seed);
        let cluster = Cluster::google_sample(30 + rng.below(50), &mut rng);
        let gen = TraceGenerator::new(GoogleLikeConfig {
            users: 4 + rng.below(8),
            duration: 4_000.0,
            jobs_per_user: 6.0,
            max_tasks_per_job: 80,
            ..Default::default()
        });
        let trace = gen.generate(seed * 37 + 5);
        let opts = SimOpts {
            horizon: 4_000.0,
            sample_dt: 100.0,
            track_user_series: false,
        };
        assert_parity(
            &format!("firstfit seed {seed}"),
            &cluster,
            &trace,
            &opts,
            FirstFitDrfh::default(),
            FirstFitDrfh::naive(),
        );
    }
}

/// Heavily saturated hand-built instance: more demand than capacity,
/// long and short tasks, so every completion re-opens the blocked set.
#[test]
fn saturated_blocking_churn() {
    let mut rng = Pcg32::seeded(777);
    let cluster = Cluster::google_sample(12, &mut rng);
    let users: Vec<UserSpec> = (0..6)
        .map(|_| UserSpec {
            demand: ResVec::cpu_mem(
                rng.uniform(0.1, 0.45),
                rng.uniform(0.1, 0.45),
            ),
            weight: rng.uniform(0.5, 2.0),
        })
        .collect();
    let jobs: Vec<JobSpec> = (0..18)
        .map(|j| JobSpec {
            id: j,
            user: j % 6,
            submit: (j as f64) * 40.0,
            tasks: vec![
                TaskSpec { duration: 150.0 + 70.0 * (j % 5) as f64 };
                25
            ],
        })
        .collect();
    let trace = Trace { users, jobs };
    let opts = SimOpts {
        horizon: 5_000.0,
        sample_dt: 50.0,
        track_user_series: false,
    };
    assert_parity(
        "saturated bestfit",
        &cluster,
        &trace,
        &opts,
        BestFitDrfh::default(),
        BestFitDrfh::naive(),
    );
    assert_parity(
        "saturated firstfit",
        &cluster,
        &trace,
        &opts,
        FirstFitDrfh::default(),
        FirstFitDrfh::naive(),
    );
}

/// Weighted users including a zero-weight one: both paths must apply
/// the same guarded `effective_weight` semantics.
#[test]
fn zero_weight_user_parity() {
    let cluster = Cluster::from_capacities(&[
        ResVec::cpu_mem(4.0, 4.0),
        ResVec::cpu_mem(2.0, 6.0),
    ]);
    let users = vec![
        UserSpec { demand: ResVec::cpu_mem(0.5, 0.5), weight: 0.0 },
        UserSpec { demand: ResVec::cpu_mem(0.4, 0.6), weight: 2.0 },
        UserSpec { demand: ResVec::cpu_mem(0.6, 0.4), weight: 1.0 },
    ];
    let jobs: Vec<JobSpec> = (0..3)
        .map(|u| JobSpec {
            id: u,
            user: u,
            submit: 0.0,
            tasks: vec![TaskSpec { duration: 200.0 }; 30],
        })
        .collect();
    let trace = Trace { users, jobs };
    let opts = SimOpts {
        horizon: 2_000.0,
        sample_dt: 50.0,
        track_user_series: false,
    };
    assert_parity(
        "zero-weight bestfit",
        &cluster,
        &trace,
        &opts,
        BestFitDrfh::default(),
        BestFitDrfh::naive(),
    );
}
