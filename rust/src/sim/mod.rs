//! Discrete-event cluster simulation: the engine behind every figure in
//! the paper's evaluation (Sec. VI).
//!
//! The trace-scale data plane (timer-wheel event queue, SoA task
//! arena, streaming metrics) is documented in [`engine`] §Perf; the
//! queue implementations live in [`wheel`]; the wave-boundary
//! invariant auditor ([`SimOpts::audit`] / `DRFH_AUDIT=1`) lives in
//! [`audit`]; the deterministic fault-injection layer (server
//! crash/recovery plans, retry with backoff, fairness-recovery
//! measurement) lives in [`faults`]; the deterministic user-churn
//! layer (join/leave plans, flash crowds) lives in [`churn`].

pub mod audit;
pub mod churn;
pub mod engine;
pub mod faults;
pub mod wheel;

pub use crate::cluster::ShardCount;
pub use crate::metrics::MetricsMode;
pub use churn::{ChurnEvent, ChurnPlan};
pub use engine::{run, SimOpts, SimReport, Simulation};
pub use faults::{FaultEvent, FaultPlan, OutageRecord, RetryPolicy};
pub use wheel::{
    EventQueue, HeapQueue, QueueKind, ShardedQueue, SimQueue, TimerWheel,
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, ResVec};
    use crate::sched::{BestFitDrfh, FirstFitDrfh, SlotsScheduler};
    use crate::workload::{JobSpec, TaskSpec, Trace, UserSpec};

    fn one_user_trace(tasks: usize, duration: f64) -> Trace {
        Trace {
            users: vec![UserSpec {
                demand: ResVec::cpu_mem(1.0, 1.0),
                weight: 1.0,
            }],
            jobs: vec![JobSpec {
                id: 0,
                user: 0,
                submit: 0.0,
                tasks: vec![TaskSpec { duration }; tasks],
            }],
        }
    }

    #[test]
    fn single_task_completes_at_duration() {
        let cluster =
            Cluster::from_capacities(&[ResVec::cpu_mem(2.0, 2.0)]);
        let r = run(
            cluster,
            &one_user_trace(1, 10.0),
            Box::new(BestFitDrfh::default()),
            SimOpts { horizon: 100.0, sample_dt: 1.0, track_user_series: false, ..SimOpts::default() },
        );
        assert_eq!(r.tasks_placed, 1);
        assert_eq!(r.tasks_completed, 1);
        assert_eq!(r.jobs.len(), 1);
        assert!((r.jobs[0].finish - 10.0).abs() < 1e-9);
    }

    #[test]
    fn capacity_serializes_tasks() {
        // server fits one task at a time; 3 tasks of 10 s each -> job
        // completes at 30 s
        let cluster =
            Cluster::from_capacities(&[ResVec::cpu_mem(1.0, 1.0)]);
        let r = run(
            cluster,
            &one_user_trace(3, 10.0),
            Box::new(BestFitDrfh::default()),
            SimOpts { horizon: 100.0, sample_dt: 1.0, track_user_series: false, ..SimOpts::default() },
        );
        assert_eq!(r.tasks_completed, 3);
        assert!((r.jobs[0].finish - 30.0).abs() < 1e-6, "{}", r.jobs[0].finish);
    }

    #[test]
    fn parallel_servers_run_concurrently() {
        let cluster = Cluster::from_capacities(&[
            ResVec::cpu_mem(1.0, 1.0),
            ResVec::cpu_mem(1.0, 1.0),
            ResVec::cpu_mem(1.0, 1.0),
        ]);
        let r = run(
            cluster,
            &one_user_trace(3, 10.0),
            Box::new(FirstFitDrfh::default()),
            SimOpts { horizon: 100.0, sample_dt: 1.0, track_user_series: false, ..SimOpts::default() },
        );
        assert!((r.jobs[0].finish - 10.0).abs() < 1e-6);
    }

    #[test]
    fn horizon_cuts_off_completions() {
        let cluster =
            Cluster::from_capacities(&[ResVec::cpu_mem(1.0, 1.0)]);
        let r = run(
            cluster,
            &one_user_trace(3, 10.0),
            Box::new(BestFitDrfh::default()),
            SimOpts { horizon: 15.0, sample_dt: 1.0, track_user_series: false, ..SimOpts::default() },
        );
        assert_eq!(r.tasks_completed, 1);
        assert_eq!(r.user_tasks[0].submitted, 3);
        assert!(r.jobs.is_empty());
        assert!((r.user_tasks[0].ratio() - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn two_users_share_fairly_under_bestfit() {
        // two identical users, capacity for 4 concurrent tasks: each
        // should end up with ~2 running at all times
        let cluster = Cluster::from_capacities(&[
            ResVec::cpu_mem(2.0, 2.0),
            ResVec::cpu_mem(2.0, 2.0),
        ]);
        let trace = Trace {
            users: vec![
                UserSpec { demand: ResVec::cpu_mem(1.0, 1.0), weight: 1.0 },
                UserSpec { demand: ResVec::cpu_mem(1.0, 1.0), weight: 1.0 },
            ],
            jobs: vec![
                JobSpec {
                    id: 0,
                    user: 0,
                    submit: 0.0,
                    tasks: vec![TaskSpec { duration: 100.0 }; 10],
                },
                JobSpec {
                    id: 1,
                    user: 1,
                    submit: 0.0,
                    tasks: vec![TaskSpec { duration: 100.0 }; 10],
                },
            ],
        };
        let r = run(
            cluster,
            &trace,
            Box::new(BestFitDrfh::default()),
            SimOpts { horizon: 50.0, sample_dt: 5.0, track_user_series: true, ..SimOpts::default() },
        );
        assert_eq!(r.tasks_placed, 4);
        // equal dominant shares after the initial fill
        let s0 = r.user_dom_share[0].v.last().unwrap();
        let s1 = r.user_dom_share[1].v.last().unwrap();
        assert!((s0 - s1).abs() < 1e-9, "{s0} vs {s1}");
    }

    #[test]
    fn slots_overcommit_slows_tasks() {
        // one server (1,1), 2 slots, but each task demands the whole
        // server: two concurrent tasks -> load 2 -> thrashing rate
        // 1/8, so a 10 s task takes 80 s.
        let cluster =
            Cluster::from_capacities(&[ResVec::cpu_mem(1.0, 1.0)]);
        let slots = SlotsScheduler::new(&cluster, 2);
        let trace = one_user_trace(2, 10.0);
        let r = run(
            cluster,
            &trace,
            Box::new(slots),
            SimOpts { horizon: 100.0, sample_dt: 1.0, track_user_series: false, ..SimOpts::default() },
        );
        assert_eq!(r.tasks_placed, 2);
        assert_eq!(r.tasks_completed, 2);
        assert!(
            (r.jobs[0].finish - 80.0).abs() < 1e-6,
            "finish = {}",
            r.jobs[0].finish
        );
    }

    #[test]
    fn ps_rate_recovers_after_partial_drain() {
        // server (1,1), 2 slots; task A (10 s) and B (30 s) both demand
        // the whole server. Load 2 -> thrashing rate 1/8. A finishes at
        // vt=10 -> t=80; then load 1 -> rate 1; B has 20 v-units left
        // -> finishes at t=100.
        let cluster =
            Cluster::from_capacities(&[ResVec::cpu_mem(1.0, 1.0)]);
        let slots = SlotsScheduler::new(&cluster, 2);
        let trace = Trace {
            users: vec![UserSpec {
                demand: ResVec::cpu_mem(1.0, 1.0),
                weight: 1.0,
            }],
            jobs: vec![
                JobSpec {
                    id: 0,
                    user: 0,
                    submit: 0.0,
                    tasks: vec![TaskSpec { duration: 10.0 }],
                },
                JobSpec {
                    id: 1,
                    user: 0,
                    submit: 0.0,
                    tasks: vec![TaskSpec { duration: 30.0 }],
                },
            ],
        };
        let r = run(
            cluster,
            &trace,
            Box::new(slots),
            SimOpts { horizon: 200.0, sample_dt: 1.0, track_user_series: false, ..SimOpts::default() },
        );
        assert_eq!(r.jobs.len(), 2);
        let mut finishes: Vec<f64> =
            r.jobs.iter().map(|j| j.finish).collect();
        finishes.sort_by(|a, b| a.total_cmp(b));
        assert!((finishes[0] - 80.0).abs() < 1e-6, "A at {}", finishes[0]);
        assert!((finishes[1] - 100.0).abs() < 1e-6, "B at {}", finishes[1]);
    }

    #[test]
    fn conservation_invariants() {
        use crate::util::Pcg32;
        use crate::workload::{GoogleLikeConfig, TraceGenerator};
        let mut rng = Pcg32::seeded(40);
        let cluster = Cluster::google_sample(50, &mut rng);
        let gen = TraceGenerator::new(GoogleLikeConfig {
            users: 10,
            duration: 5_000.0,
            jobs_per_user: 5.0,
            max_tasks_per_job: 100,
            ..Default::default()
        });
        let trace = gen.generate(41);
        let r = run(
            cluster.clone(),
            &trace,
            Box::new(BestFitDrfh::default()),
            SimOpts { horizon: 50_000.0, sample_dt: 100.0, track_user_series: false, ..SimOpts::default() },
        );
        // with a generous horizon everything completes
        assert_eq!(r.tasks_placed, trace.total_tasks());
        assert_eq!(r.tasks_completed, trace.total_tasks());
        for (u, c) in r.user_tasks.iter().enumerate() {
            assert_eq!(c.completed, c.submitted, "user {u}");
        }
        assert_eq!(r.jobs.len(), trace.jobs.len());
        // utilization bounded
        for &v in r.cpu_util.v.iter().chain(&r.mem_util.v) {
            assert!((0.0..=1.0 + 1e-9).contains(&v));
        }
    }
}
