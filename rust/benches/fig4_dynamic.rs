//! Regenerates paper Fig. 4 (dynamic allocation with users joining and
//! departing) and times the run.
//!
//! Run: `cargo bench --bench fig4_dynamic`

use drfh::experiments::fig4;
use drfh::util::bench::{bench, header};
use std::time::Duration;

fn main() {
    // regenerate the figure once, with the full printed summary
    let res = fig4::run_fig4(42);
    fig4::print(&res);

    header("fig4: full dynamic-allocation run (100 servers, 2000 s)");
    bench("fig4 run", Duration::from_secs(5), 50, || {
        fig4::run_fig4(42).report.tasks_placed
    });
}
