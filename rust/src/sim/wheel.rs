//! Event queues for the discrete-event engine: the naive binary heap
//! and a calendar-style hierarchical timer wheel, behind one
//! [`EventQueue`] trait. (The engine these feed reproduces the
//! paper's evaluation, Sec. VI — every figure is a pure function of
//! the drain order pinned down here.)
//!
//! ## Ordering contract
//!
//! Both implementations drain events in strictly increasing
//! `(time, seq)` order, where `time` is compared with
//! [`f64::total_cmp`] and `seq` is a unique engine-assigned push
//! counter. Because `seq` is unique the order is *total*: for any
//! multiset of pushed events with non-NaN times (all the engine can
//! produce — `Trace::validate` rejects NaN durations, and pushes are
//! `debug_assert`ed), every implementation pops the exact same
//! sequence regardless of insertion order or internal layout. That is
//! what lets `tests/engine_parity.rs` demand *bit-identical*
//! [`crate::sim::SimReport`]s between the heap and the wheel — the
//! engine's event interleaving (and therefore every scheduling
//! decision and every float it derives) is a pure function of the
//! drain order.
//!
//! ## §Perf: why a wheel
//!
//! A `BinaryHeap` pays `O(log N)` comparisons per push/pop with N the
//! *live* event count — at trace scale (≈10⁶ tasks ⇒ ≳2·10⁶ events,
//! tens of thousands live at once) the heap walks long cache-hostile
//! paths on every operation. The [`TimerWheel`] buckets events by
//! `tick = ⌊time / width⌋` over a sliding window of `nb` buckets,
//! with a `far` spillover for events beyond the window:
//!
//! * **push** is O(1): index into the window (or append to `far`);
//! * **pop** sorts the *current* bucket once when it is first
//!   touched (events are sorted at most once each, in bucket-sized
//!   batches that fit in cache) and then pops from a contiguous
//!   `Vec`;
//! * when the window drains, it advances to the earliest `far` event
//!   and re-buckets — with the default 32768 s window a bounded-Pareto
//!   task duration (≤ 21600 s) is re-bucketed at most once, so the
//!   amortized cost per event stays O(sort share + O(1) moves).
//!
//! Parameters only affect performance, never order: any `width`/`nb`
//! degrade gracefully toward "one sorted vec" behavior while the
//! drain order stays the total `(time, seq)` order.

/// One scheduled event: an opaque payload due at `time`, tie-broken
/// by the engine-assigned unique `seq`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Event<T> {
    pub time: f64,
    pub seq: u64,
    pub payload: T,
}

/// The total drain order: earliest `time` first ([`f64::total_cmp`]),
/// then lowest `seq`. Shared by both queues so their orders cannot
/// drift apart.
#[inline]
fn drain_cmp<T>(a: &Event<T>, b: &Event<T>) -> Ordering {
    a.time.total_cmp(&b.time).then_with(|| a.seq.cmp(&b.seq))
}

/// Minimal queue surface the engine drives. `peek`/`pop` take
/// `&mut self` because the wheel locates (and lazily sorts) its
/// earliest bucket on demand; reorganization never changes the drain
/// order.
pub trait EventQueue<T: Copy> {
    fn push(&mut self, ev: Event<T>);
    /// Remove and return the earliest event in `(time, seq)` order.
    fn pop(&mut self) -> Option<Event<T>>;
    /// The earliest event without removing it.
    fn peek(&mut self) -> Option<Event<T>>;
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

// ---------------------------------------------------------------- heap

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Reversed-order wrapper so `BinaryHeap` (a max-heap) pops earliest
/// `(time, seq)` first — byte-for-byte the seed engine's ordering.
#[derive(Clone, Copy, Debug)]
struct HeapEv<T>(Event<T>);

impl<T> PartialEq for HeapEv<T> {
    fn eq(&self, other: &Self) -> bool {
        // must agree with Ord (time then seq), per the Ord contract
        drain_cmp(&self.0, &other.0) == Ordering::Equal
    }
}
impl<T> Eq for HeapEv<T> {}
impl<T> PartialOrd for HeapEv<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for HeapEv<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed: BinaryHeap is a max-heap, we want earliest first
        drain_cmp(&other.0, &self.0)
    }
}

/// The seed's `BinaryHeap` event queue — kept as the naive parity
/// reference ([`SimQueue::naive`] / [`QueueKind::Heap`]).
pub struct HeapQueue<T> {
    heap: BinaryHeap<HeapEv<T>>,
}

impl<T: Copy> HeapQueue<T> {
    pub fn new() -> Self {
        HeapQueue { heap: BinaryHeap::new() }
    }

    /// Visit every queued event in unspecified order (diagnostics /
    /// the [`crate::sim::audit`] invariant auditor; never on the hot
    /// path).
    pub fn for_each(&self, mut f: impl FnMut(&Event<T>)) {
        for h in self.heap.iter() {
            f(&h.0);
        }
    }
}

impl<T: Copy> Default for HeapQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: Copy> EventQueue<T> for HeapQueue<T> {
    fn push(&mut self, ev: Event<T>) {
        self.heap.push(HeapEv(ev));
    }

    fn pop(&mut self) -> Option<Event<T>> {
        self.heap.pop().map(|h| h.0)
    }

    fn peek(&mut self) -> Option<Event<T>> {
        self.heap.peek().map(|h| h.0)
    }

    fn len(&self) -> usize {
        self.heap.len()
    }
}

// --------------------------------------------------------------- wheel

/// Default bucket width (seconds). Trace times are seconds; 8 s
/// buckets keep Fig. 5-scale bucket occupancy in the hundreds.
const DEFAULT_WIDTH: f64 = 8.0;
/// Default bucket count: a 4096 × 8 s = 32768 s window, wider than the
/// generator's longest task duration (21600 s), so a completion event
/// is re-bucketed from `far` at most once.
const DEFAULT_BUCKETS: usize = 4096;

/// Calendar-queue timer wheel over non-negative event times.
///
/// Invariants:
/// * every stored event has `tick >= win_lo` (times never precede the
///   last pop — the engine only schedules at or after `now`; a
///   defensive clamp files any earlier push under the cursor bucket,
///   which still drains in exact `(time, seq)` order);
/// * buckets `0..cursor` are empty;
/// * `buckets[i]` holds exactly the events with
///   `tick - win_lo == i` (cursor bucket: `<= i`, via the clamp);
/// * `far` holds exactly the events with `tick - win_lo >= nb`;
/// * `buckets[cursor]` is sorted descending by `(time, seq)` iff
///   `sorted` (pop takes from the back).
pub struct TimerWheel<T> {
    buckets: Vec<Vec<Event<T>>>,
    far: Vec<Event<T>>,
    /// Tick of `buckets[0]`.
    win_lo: u64,
    /// First possibly-non-empty bucket.
    cursor: usize,
    /// Is `buckets[cursor]` currently sorted (descending by key)?
    sorted: bool,
    /// Events in `buckets` (excludes `far`).
    near_len: usize,
    len: usize,
    width: f64,
    nb: usize,
}

impl<T: Copy> TimerWheel<T> {
    pub fn new() -> Self {
        Self::with_params(DEFAULT_WIDTH, DEFAULT_BUCKETS)
    }

    /// Custom geometry (tests use tiny windows to force rotation).
    /// Any `width > 0`, `nb >= 1` is correct; geometry is perf-only.
    pub fn with_params(width: f64, nb: usize) -> Self {
        assert!(width > 0.0 && width.is_finite(), "bucket width {width}");
        assert!(nb >= 1, "need at least one bucket");
        TimerWheel {
            buckets: (0..nb).map(|_| Vec::new()).collect(),
            far: Vec::new(),
            win_lo: 0,
            cursor: 0,
            sorted: false,
            near_len: 0,
            len: 0,
            width,
            nb,
        }
    }

    #[inline]
    fn tick_of(&self, time: f64) -> u64 {
        // negative times are clamped to tick 0; the `as` cast
        // saturates (huge-but-finite times land in `far` and are
        // ordered by their actual f64 key when re-bucketed/sorted)
        (time.max(0.0) / self.width) as u64
    }

    /// Slide the window to the earliest `far` event and re-bucket
    /// everything that now falls inside it. Caller guarantees the
    /// near window is empty and `far` is not.
    fn advance_window(&mut self) {
        debug_assert_eq!(self.near_len, 0);
        debug_assert!(!self.far.is_empty());
        let min_tick = self
            .far
            .iter()
            .map(|e| self.tick_of(e.time))
            .min()
            .expect("far is non-empty");
        self.win_lo = min_tick;
        self.cursor = 0;
        self.sorted = false;
        let mut far = std::mem::take(&mut self.far);
        far.retain(|&ev| {
            let off = self.tick_of(ev.time) - self.win_lo;
            if off < self.nb as u64 {
                self.buckets[off as usize].push(ev);
                self.near_len += 1;
                false
            } else {
                true
            }
        });
        self.far = far;
        debug_assert!(self.near_len > 0);
    }

    /// Advance `cursor` to the first non-empty bucket, rotating the
    /// window as needed. Caller guarantees `len > 0`. Afterwards
    /// `buckets[cursor]` is non-empty and sorted.
    fn settle(&mut self) {
        debug_assert!(self.len > 0);
        if self.near_len == 0 {
            self.advance_window();
        }
        while self.buckets[self.cursor].is_empty() {
            self.cursor += 1;
            self.sorted = false;
            if self.cursor == self.nb {
                // near window exhausted mid-scan
                debug_assert_eq!(self.near_len, 0);
                self.advance_window();
            }
        }
        if !self.sorted {
            // descending so pop() takes the earliest from the back
            self.buckets[self.cursor]
                .sort_unstable_by(|a, b| drain_cmp(b, a));
            self.sorted = true;
        }
    }

    /// Visit every queued event in unspecified order (diagnostics /
    /// the [`crate::sim::audit`] invariant auditor; never on the hot
    /// path). Covers both the near window and the `far` overflow.
    pub fn for_each(&self, mut f: impl FnMut(&Event<T>)) {
        for bucket in &self.buckets {
            for ev in bucket {
                f(ev);
            }
        }
        for ev in &self.far {
            f(ev);
        }
    }
}

impl<T: Copy> Default for TimerWheel<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: Copy> EventQueue<T> for TimerWheel<T> {
    fn push(&mut self, ev: Event<T>) {
        debug_assert!(!ev.time.is_nan(), "event time is NaN");
        let tick = self.tick_of(ev.time);
        if self.len == 0 {
            // empty queue: re-anchor the window at this event
            self.win_lo = tick;
            self.cursor = 0;
            self.sorted = false;
            self.buckets[0].push(ev);
            self.near_len = 1;
            self.len = 1;
            return;
        }
        let off = tick.saturating_sub(self.win_lo);
        if off < self.nb as u64 {
            // clamp to the cursor: a bucket behind it was already
            // drained, and the cursor bucket sorts by (time, seq)
            // anyway, so an early event still pops in exact order
            let idx = (off as usize).max(self.cursor);
            self.buckets[idx].push(ev);
            if idx == self.cursor {
                self.sorted = false;
            }
            self.near_len += 1;
        } else {
            self.far.push(ev);
        }
        self.len += 1;
    }

    fn pop(&mut self) -> Option<Event<T>> {
        if self.len == 0 {
            return None;
        }
        self.settle();
        let ev = self.buckets[self.cursor].pop().expect("settled bucket");
        self.near_len -= 1;
        self.len -= 1;
        Some(ev)
    }

    fn peek(&mut self) -> Option<Event<T>> {
        if self.len == 0 {
            return None;
        }
        self.settle();
        self.buckets[self.cursor].last().copied()
    }

    fn len(&self) -> usize {
        self.len
    }
}

// ------------------------------------------------------------ dispatch

/// Which [`EventQueue`] the engine runs on (see
/// [`crate::sim::SimOpts::queue`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum QueueKind {
    /// Hierarchical timer wheel ([`TimerWheel`]) — the trace-scale
    /// data plane.
    #[default]
    Wheel,
    /// Timer wheel with geometry auto-tuned from the trace's observed
    /// task-duration distribution ([`auto_geometry`]). Perf-only:
    /// the drain order is geometry-independent.
    Auto,
    /// Binary heap ([`HeapQueue`]) — the seed's queue, kept as the
    /// naive parity reference.
    Heap,
}

/// Pick a [`TimerWheel`] geometry `(width, buckets)` for a trace whose
/// task durations are `durations` — the [`QueueKind::Auto`] mode.
///
/// The tuning goal mirrors the rationale behind the defaults: the
/// window (`width × buckets`) should cover the longest task duration
/// with slack, so a completion event scheduled `duration` ahead of
/// `now` spills to the `far` overflow at most once before it drains.
/// The window never *shrinks* below the default one: the engine
/// enqueues every arrival for the whole horizon up front, so a window
/// tuned only to short durations would re-scan that arrival backlog
/// on every one of its (many more) window advances — the tuning only
/// ever widens the window for duration distributions the default
/// cannot cover. Geometry only affects performance, never the drain
/// order (see the module docs), so any outcome here is semantically
/// safe; an empty or degenerate duration set falls back to the
/// defaults.
pub fn auto_geometry(
    durations: impl IntoIterator<Item = f64>,
) -> (f64, usize) {
    let mut max_d: f64 = 0.0;
    let mut seen = false;
    for d in durations {
        if d.is_finite() && d > max_d {
            max_d = d;
        }
        seen = true;
    }
    let nb = DEFAULT_BUCKETS;
    if !seen || max_d <= 0.0 {
        return (DEFAULT_WIDTH, nb);
    }
    // 1.25x slack over the observed maximum, floored at the default
    // window; the upper clamp keeps bucket sorts cache-sized for
    // degenerate multi-year durations
    let window = (max_d * 1.25).max(DEFAULT_WIDTH * nb as f64);
    let width = (window / nb as f64).min(3_600.0);
    (width, nb)
}

/// Enum-dispatched queue so [`crate::sim::Simulation`] stays
/// non-generic (and pays a predictable two-way branch instead of a
/// virtual call on the hot path).
pub enum SimQueue<T> {
    Heap(HeapQueue<T>),
    Wheel(TimerWheel<T>),
}

impl<T: Copy> SimQueue<T> {
    /// Build the queue for `kind`. [`QueueKind::Auto`] without trace
    /// context falls back to the default wheel geometry — the engine
    /// resolves `Auto` itself via [`auto_geometry`] where the trace is
    /// in hand.
    pub fn new(kind: QueueKind) -> Self {
        match kind {
            QueueKind::Heap => SimQueue::Heap(HeapQueue::new()),
            QueueKind::Wheel | QueueKind::Auto => {
                SimQueue::Wheel(TimerWheel::new())
            }
        }
    }

    /// The parity-reference queue (mirrors the `::naive()` scheduler
    /// constructors).
    pub fn naive() -> Self {
        Self::new(QueueKind::Heap)
    }

    /// Visit every queued event in unspecified order (diagnostics /
    /// the [`crate::sim::audit`] invariant auditor).
    pub fn for_each(&self, f: impl FnMut(&Event<T>)) {
        match self {
            SimQueue::Heap(q) => q.for_each(f),
            SimQueue::Wheel(q) => q.for_each(f),
        }
    }
}

impl<T: Copy> EventQueue<T> for SimQueue<T> {
    fn push(&mut self, ev: Event<T>) {
        match self {
            SimQueue::Heap(q) => q.push(ev),
            SimQueue::Wheel(q) => q.push(ev),
        }
    }

    fn pop(&mut self) -> Option<Event<T>> {
        match self {
            SimQueue::Heap(q) => q.pop(),
            SimQueue::Wheel(q) => q.pop(),
        }
    }

    fn peek(&mut self) -> Option<Event<T>> {
        match self {
            SimQueue::Heap(q) => q.peek(),
            SimQueue::Wheel(q) => q.peek(),
        }
    }

    fn len(&self) -> usize {
        match self {
            SimQueue::Heap(q) => q.len(),
            SimQueue::Wheel(q) => q.len(),
        }
    }
}

// ------------------------------------------------------------- sharded

/// §Perf: per-shard event lanes behind one merged drain order — the
/// queue side of the engine's sharded data plane (see
/// [`crate::sim::engine`] §Perf and [`crate::cluster::ShardSpec`]).
///
/// Each lane is an independent [`SimQueue`]; the engine routes every
/// `ServerCheck` to the lane of the shard owning its server (arrivals
/// and samples ride lane 0) so shard-local pushes never contend on a
/// shared structure. `pop`/`peek` run a merge cursor: the lane heads
/// are compared under the same total `(time, seq)` order
/// ([`drain_cmp`]) every queue in this module uses, and the earliest
/// head wins.
///
/// **Why the merge is exact for any routing:** each lane individually
/// drains in `(time, seq)` order, so the globally earliest remaining
/// event is always at the head of *some* lane, and the argmin over
/// lane heads finds it. Lane assignment therefore only affects cache
/// locality and contention — never the drain sequence — which is what
/// keeps the sharded engine bit-identical to the sequential one
/// (`tests/engine_parity.rs`). A single-lane queue short-circuits the
/// cursor and behaves exactly like its inner [`SimQueue`].
pub struct ShardedQueue<T> {
    lanes: Vec<SimQueue<T>>,
    len: usize,
}

impl<T: Copy> ShardedQueue<T> {
    /// `lanes` queues of the given kind (lane count = shard count in
    /// the engine; must be at least 1).
    pub fn new(kind: QueueKind, lanes: usize) -> Self {
        Self::from_fn(lanes, || SimQueue::new(kind))
    }

    /// Build each lane from a closure — the engine uses this to give
    /// every lane one shared auto-tuned wheel geometry.
    pub fn from_fn(
        lanes: usize,
        mut mk: impl FnMut() -> SimQueue<T>,
    ) -> Self {
        assert!(lanes >= 1, "need at least one event lane");
        ShardedQueue {
            lanes: (0..lanes).map(|_| mk()).collect(),
            len: 0,
        }
    }

    #[inline]
    pub fn lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Push `ev` onto a specific lane. Routing is the caller's policy
    /// and is semantically free (see the type docs); the default
    /// [`EventQueue::push`] routes everything to lane 0.
    #[inline]
    pub fn push_to(&mut self, lane: usize, ev: Event<T>) {
        self.lanes[lane].push(ev);
        self.len += 1;
    }

    /// Visit every queued event together with the lane it sits on, in
    /// unspecified order. The [`crate::sim::audit`] auditor uses this
    /// to prove the engine's shard-ownership routing (every
    /// `ServerCheck` on its owner's lane); never on the hot path.
    pub fn for_each_lane(&self, mut f: impl FnMut(usize, &Event<T>)) {
        for (i, lane) in self.lanes.iter().enumerate() {
            lane.for_each(|ev| f(i, ev));
        }
    }

    /// The lane whose head is the globally earliest event, or `None`
    /// when empty. `&mut` because peeking a lane may settle its wheel.
    fn min_lane(&mut self) -> Option<usize> {
        let mut best: Option<(f64, u64, usize)> = None;
        for (i, lane) in self.lanes.iter_mut().enumerate() {
            if let Some(head) = lane.peek() {
                let earlier = match best {
                    None => true,
                    Some((t, s, _)) => head
                        .time
                        .total_cmp(&t)
                        .then_with(|| head.seq.cmp(&s))
                        .is_lt(),
                };
                if earlier {
                    best = Some((head.time, head.seq, i));
                }
            }
        }
        best.map(|(_, _, i)| i)
    }
}

impl<T: Copy> EventQueue<T> for ShardedQueue<T> {
    fn push(&mut self, ev: Event<T>) {
        self.push_to(0, ev);
    }

    fn pop(&mut self) -> Option<Event<T>> {
        let lane = if self.lanes.len() == 1 {
            0
        } else {
            self.min_lane()?
        };
        let ev = self.lanes[lane].pop();
        if ev.is_some() {
            self.len -= 1;
        }
        ev
    }

    fn peek(&mut self) -> Option<Event<T>> {
        if self.lanes.len() == 1 {
            return self.lanes[0].peek();
        }
        let lane = self.min_lane()?;
        self.lanes[lane].peek()
    }

    fn len(&self) -> usize {
        self.len
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn ev(time: f64, seq: u64) -> Event<u32> {
        Event { time, seq, payload: seq as u32 }
    }

    fn drain(q: &mut impl EventQueue<u32>) -> Vec<(f64, u64)> {
        let mut out = Vec::new();
        while let Some(e) = q.pop() {
            out.push((e.time, e.seq));
        }
        out
    }

    /// Both queues share one comparator; spot-check its total order
    /// on the edge times the engine can produce.
    #[test]
    fn drain_cmp_is_time_then_seq() {
        assert_eq!(drain_cmp(&ev(1.0, 5), &ev(2.0, 1)), Ordering::Less);
        assert_eq!(drain_cmp(&ev(2.0, 1), &ev(2.0, 2)), Ordering::Less);
        assert_eq!(drain_cmp(&ev(0.0, 1), &ev(0.0, 1)), Ordering::Equal);
        assert_eq!(
            drain_cmp(&ev(f64::INFINITY, 1), &ev(1e18, 9)),
            Ordering::Greater
        );
    }

    /// Satellite regression guard: simultaneous events must drain in
    /// seq order from BOTH queues, whatever the insertion order.
    #[test]
    fn equal_timestamps_drain_in_seq_order() {
        // push order deliberately scrambled; three distinct
        // timestamps, several seqs per timestamp (the engine's
        // Arrival / ServerCheck / Sample collision shape)
        let evs = [
            ev(30.0, 7),
            ev(10.0, 4),
            ev(30.0, 2),
            ev(10.0, 1),
            ev(30.0, 5),
            ev(10.0, 9),
            ev(0.0, 3),
            ev(0.0, 8),
        ];
        let want = vec![
            (0.0, 3),
            (0.0, 8),
            (10.0, 1),
            (10.0, 4),
            (10.0, 9),
            (30.0, 2),
            (30.0, 5),
            (30.0, 7),
        ];
        let mut heap = HeapQueue::new();
        let mut wheel = TimerWheel::new();
        // a tiny wheel forces the same-time events through the
        // cursor-bucket sort rather than one big bucket
        let mut tiny = TimerWheel::with_params(0.5, 4);
        for &e in &evs {
            heap.push(e);
            wheel.push(e);
            tiny.push(e);
        }
        assert_eq!(drain(&mut heap), want);
        assert_eq!(drain(&mut wheel), want);
        assert_eq!(drain(&mut tiny), want);
    }

    #[test]
    fn window_rotation_preserves_order() {
        // window = 2.0 * 4 = 8 s; events spread over 100 s force
        // several far re-bucketings
        let mut wheel = TimerWheel::with_params(2.0, 4);
        let mut heap = HeapQueue::new();
        // ascending pushes: everything beyond the first 8 s window
        // spills to `far` and is re-bucketed window by window during
        // the drain (~12 rotations)
        for i in 0..100u64 {
            let e = ev(i as f64 * 1.01, i + 1);
            wheel.push(e);
            heap.push(e);
        }
        assert_eq!(drain(&mut wheel), drain(&mut heap));
    }

    #[test]
    fn push_into_sorted_cursor_bucket_resorts() {
        let mut wheel = TimerWheel::with_params(10.0, 4);
        wheel.push(ev(5.0, 1));
        wheel.push(ev(7.0, 2));
        assert_eq!(wheel.peek().unwrap().seq, 1); // sorts the bucket
        // land behind the now-sorted back of the cursor bucket
        wheel.push(ev(1.0, 3));
        assert_eq!(wheel.pop().unwrap().seq, 3);
        assert_eq!(wheel.pop().unwrap().seq, 1);
        assert_eq!(wheel.pop().unwrap().seq, 2);
        assert!(wheel.pop().is_none());
    }

    #[test]
    fn past_time_push_clamps_to_cursor() {
        let mut wheel = TimerWheel::with_params(1.0, 8);
        for s in 0..6 {
            wheel.push(ev(s as f64, s + 1));
        }
        // drain to t=3 so the cursor sits mid-window
        assert_eq!(wheel.pop().unwrap().time, 0.0);
        assert_eq!(wheel.pop().unwrap().time, 1.0);
        assert_eq!(wheel.pop().unwrap().time, 2.0);
        // a push earlier than the cursor's bucket must still pop
        // first (defensive clamp; the engine never does this)
        wheel.push(ev(0.5, 99));
        assert_eq!(wheel.pop().unwrap(), ev(0.5, 99));
        assert_eq!(
            drain(&mut wheel),
            vec![(3.0, 4), (4.0, 5), (5.0, 6)]
        );
    }

    #[test]
    fn empty_queue_reanchors_on_push() {
        let mut wheel = TimerWheel::with_params(1.0, 4);
        wheel.push(ev(2.0, 1));
        assert_eq!(wheel.pop().unwrap().seq, 1);
        assert!(wheel.pop().is_none());
        // far beyond the old window: must re-anchor, not spill
        wheel.push(ev(1e6, 2));
        assert_eq!(wheel.len(), 1);
        assert_eq!(wheel.peek().unwrap().seq, 2);
        assert_eq!(wheel.pop().unwrap().seq, 2);
    }

    /// The core guarantee: on randomized interleaved push/pop streams
    /// (the engine's actual access pattern) the wheel and the heap
    /// agree on every single pop, across several wheel geometries.
    #[test]
    fn randomized_interleaved_parity_with_heap() {
        for (width, nb) in [(8.0, 4096), (1.0, 16), (0.25, 3), (100.0, 2)] {
            let mut rng = Pcg32::seeded(1234 + nb as u64);
            let mut heap = HeapQueue::new();
            let mut wheel = TimerWheel::with_params(width, nb);
            let mut seq = 0u64;
            // `now` only advances (like the engine's clock) so pushes
            // are never scheduled before the last popped time
            let mut now = 0.0f64;
            for _ in 0..3_000 {
                let r = rng.f64();
                if r < 0.55 || heap.len() == 0 {
                    seq += 1;
                    // mix of near, same-tick, far, and exactly-now
                    let dt = match seq % 4 {
                        0 => 0.0,
                        1 => rng.uniform(0.0, 2.0 * width),
                        2 => rng.uniform(0.0, 50.0 * width),
                        _ => rng.uniform(0.0, 2000.0 * width),
                    };
                    let e = ev(now + dt, seq);
                    heap.push(e);
                    wheel.push(e);
                } else {
                    let a = heap.pop().unwrap();
                    let b = wheel.pop().unwrap();
                    assert_eq!(
                        (a.time, a.seq),
                        (b.time, b.seq),
                        "divergence at seq {seq} (width {width}, nb {nb})"
                    );
                    now = a.time;
                }
                assert_eq!(heap.len(), wheel.len());
                // peeks agree too (and never disturb the order)
                if heap.len() > 0 {
                    let pa = heap.peek().unwrap();
                    let pb = wheel.peek().unwrap();
                    assert_eq!((pa.time, pa.seq), (pb.time, pb.seq));
                }
            }
            assert_eq!(drain(&mut heap), drain(&mut wheel));
        }
    }

    #[test]
    fn simqueue_dispatch_matches_kinds() {
        let mut q = SimQueue::new(QueueKind::Wheel);
        assert!(matches!(q, SimQueue::Wheel(_)));
        q.push(ev(1.0, 1));
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop().unwrap().seq, 1);
        let n = SimQueue::<u32>::naive();
        assert!(matches!(n, SimQueue::Heap(_)));
        // Auto without trace context degrades to the default wheel
        let a = SimQueue::<u32>::new(QueueKind::Auto);
        assert!(matches!(a, SimQueue::Wheel(_)));
    }

    /// Auto geometry covers the longest observed duration with slack
    /// (so completions re-bucket from `far` at most once), never
    /// shrinks the window below the default (the horizon's arrival
    /// backlog would thrash the far overflow), falls back to the
    /// defaults on degenerate input — and, being perf-only, drains in
    /// the exact heap order.
    #[test]
    fn auto_geometry_covers_durations_and_preserves_order() {
        let (w, nb) = auto_geometry([120.0, 21_600.0, 600.0]);
        assert_eq!(nb, DEFAULT_BUCKETS);
        // window covers the longest duration with slack...
        assert!(w * nb as f64 >= 21_600.0 * 1.25 - 1e-6);
        // ...and never narrows below the default geometry
        assert!(w >= DEFAULT_WIDTH && w <= 3_600.0);
        // long-duration traces widen the window
        let (w_long, _) = auto_geometry([200_000.0]);
        assert!(w_long * DEFAULT_BUCKETS as f64 >= 250_000.0 - 1e-6);
        // short-task traces keep the default window untouched
        assert_eq!(auto_geometry([100.0]), (DEFAULT_WIDTH, DEFAULT_BUCKETS));
        // degenerate inputs fall back to the defaults
        assert_eq!(
            auto_geometry(std::iter::empty::<f64>()),
            (DEFAULT_WIDTH, DEFAULT_BUCKETS)
        );
        assert_eq!(
            auto_geometry([f64::NAN, -3.0, 0.0]),
            (DEFAULT_WIDTH, DEFAULT_BUCKETS)
        );
        // drain parity at a tuned geometry
        let mut rng = Pcg32::seeded(77);
        let mut heap = HeapQueue::new();
        let mut wheel = TimerWheel::with_params(w_long, 64);
        for seq in 1..=500u64 {
            let e = ev(rng.uniform(0.0, 400_000.0), seq);
            heap.push(e);
            wheel.push(e);
        }
        assert_eq!(drain(&mut heap), drain(&mut wheel));
    }

    /// The merge cursor must produce the exact single-queue drain
    /// order for ANY lane routing — randomized streams, every queue
    /// kind, adversarial lane assignment.
    #[test]
    fn sharded_merge_matches_single_queue_for_any_routing() {
        for kind in [QueueKind::Heap, QueueKind::Wheel] {
            for lanes in [1usize, 2, 3, 8] {
                let mut rng = Pcg32::seeded(900 + lanes as u64);
                let mut reference = HeapQueue::new();
                let mut sharded = ShardedQueue::new(kind, lanes);
                assert_eq!(sharded.lanes(), lanes);
                let mut seq = 0u64;
                let mut now = 0.0f64;
                for _ in 0..2_000 {
                    if rng.f64() < 0.55 || reference.len() == 0 {
                        seq += 1;
                        // same-time bursts included: ties must break
                        // by seq across lanes
                        let dt = match seq % 3 {
                            0 => 0.0,
                            1 => rng.uniform(0.0, 40.0),
                            _ => rng.uniform(0.0, 5_000.0),
                        };
                        let e = ev(now + dt, seq);
                        reference.push(e);
                        // adversarial routing: lane chosen at random,
                        // uncorrelated with time or seq
                        let lane =
                            (rng.f64() * lanes as f64) as usize % lanes;
                        sharded.push_to(lane, e);
                    } else {
                        let a = reference.pop().unwrap();
                        let b = sharded.pop().unwrap();
                        assert_eq!(
                            (a.time, a.seq),
                            (b.time, b.seq),
                            "lanes {lanes} kind {kind:?}"
                        );
                        now = a.time;
                    }
                    assert_eq!(reference.len(), sharded.len());
                    if reference.len() > 0 {
                        let pa = reference.peek().unwrap();
                        let pb = sharded.peek().unwrap();
                        assert_eq!((pa.time, pa.seq), (pb.time, pb.seq));
                    }
                }
                assert_eq!(drain(&mut reference), drain(&mut sharded));
            }
        }
    }

    /// Same-timestamp events scattered across lanes drain in global
    /// seq order (the cross-shard simultaneous-event tie-break).
    #[test]
    fn sharded_ties_break_by_seq_across_lanes() {
        let mut q = ShardedQueue::new(QueueKind::Wheel, 3);
        // one timestamp, seqs interleaved over all lanes
        for (lane, seq) in [(2, 4), (0, 1), (1, 5), (2, 2), (0, 6), (1, 3)]
        {
            q.push_to(lane, ev(10.0, seq));
        }
        // plus a default-routed (lane 0) earlier event
        q.push(ev(5.0, 7));
        assert_eq!(
            drain(&mut q),
            vec![
                (5.0, 7),
                (10.0, 1),
                (10.0, 2),
                (10.0, 3),
                (10.0, 4),
                (10.0, 5),
                (10.0, 6),
            ]
        );
        assert!(q.pop().is_none());
    }
}
