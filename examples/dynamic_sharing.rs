//! Dynamic sharing (paper Fig. 4): three users join a 100-server
//! heterogeneous pool at t = 0 / 200 / 500 s; DRFH re-equalizes global
//! dominant shares on every arrival and departure.
//!
//! ```bash
//! cargo run --release --example dynamic_sharing
//! ```
//!
//! Prints the phase table (paper: 62% alone -> 44%/44% -> 26% x3 ->
//! rebalance after user 1 departs) and writes the full share time
//! series to results/fig4_dynamic_shares.csv.

use drfh::experiments::fig4;

fn main() {
    let res = fig4::run_fig4(42);
    fig4::print(&res);

    // a compact ASCII sketch of the dominant-share trajectories
    println!("\ndominant share over time (each row = 50 s):");
    println!("{:>6}  {:<24} u1:* u2:+ u3:o", "t", "0%....................50%");
    let ts = &res.report.user_dom_share[0].t;
    let step = (50.0 / 5.0) as usize; // samples every 5 s
    for i in (0..ts.len()).step_by(step) {
        let mut line = vec![b' '; 51];
        for (u, ch) in [(0usize, b'*'), (1, b'+'), (2, b'o')] {
            let v = res.report.user_dom_share[u].v[i];
            let pos = ((v * 100.0).min(50.0)) as usize;
            line[pos] = ch;
        }
        println!("{:>6.0}  {}", ts[i], String::from_utf8_lossy(&line));
    }
}
