//! # drfh — Dominant Resource Fairness with Heterogeneous Servers
//!
//! A full reproduction of Wang, Li & Liang, *"Dominant Resource Fairness
//! in Cloud Computing Systems with Heterogeneous Servers"* (2013):
//!
//! * [`cluster`] — the heterogeneous server pool (paper Sec. III-A),
//!   including the Google Table I configuration distribution;
//! * [`workload`] — users/jobs/tasks and the Google-like trace generator
//!   substituting the original (unavailable) cluster traces;
//! * [`solver`] — dense two-phase simplex, the LP substrate for eq. (7);
//! * [`allocator`] — the *exact fluid* DRFH allocation (paper Sec. IV),
//!   weighted users, finite demands, and the naive per-server DRF
//!   baseline of Sec. III-D;
//! * [`sched`] — discrete task schedulers: Best-Fit DRFH, First-Fit
//!   DRFH (paper Sec. V-B) and the slot-based baseline (Table II);
//! * [`sim`] — the discrete-event cluster simulator behind every figure
//!   in the evaluation (Sec. VI);
//! * [`metrics`] — utilization time series, JCT CDFs, completion ratios;
//! * [`runtime`] — the PJRT bridge executing the AOT-compiled XLA
//!   scheduling kernels (L1 Pallas / L2 JAX) from the Rust hot path;
//! * [`coordinator`] — the online (tokio) scheduling service;
//! * [`experiments`] — one harness per paper table/figure.
//!
//! See DESIGN.md for the experiment index and EXPERIMENTS.md for
//! measured-vs-paper results.

pub mod allocator;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod metrics;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod solver;
pub mod util;
pub mod workload;
