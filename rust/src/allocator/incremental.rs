//! Event-driven dynamic DRFH: the exact fluid allocation (paper
//! eq. (7) + the progressive-filling rounds of Sec. V-A) maintained
//! *incrementally* across user churn.
//!
//! [`IncrementalDrfh`] owns one [`crate::solver::Solver`] for the whole
//! lifetime of the cluster and caches everything that survives events:
//! the server-class aggregation, normalized demands, the class capacity
//! rows, and — crucially — the simplex **basis**. `add_user`,
//! `remove_user`, `set_cap` and `set_weight` mutate the standing LP in
//! place; every [`IncrementalDrfh::allocate`] then runs the *same*
//! progressive-filling rounds as the from-scratch reference
//! ([`crate::allocator::solve`]) but re-solves each round warm from the
//! previous basis instead of rebuilding a tableau and running a full
//! two-phase solve. On dynamic-sharing sweeps (Fig. 4 style) this makes
//! consecutive solves near-incremental: a handful of dual/primal repair
//! pivots per event instead of hundreds of phase-1/phase-2 pivots.
//!
//! ## LP shape and basis-reuse invariants
//!
//! Variables: one `x_ic` per (user slot, server class) — the dominant
//! share user *i* draws from class *c* — plus one shared *cumulative*
//! growth variable `G` (the filling level since the current
//! `allocate()` began; the objective). Rows:
//!
//! * class capacity rows `Σ_i x_ic · d_ir <= cap_cr` — created once,
//!   never touched except to rewire a slot's demand coefficients;
//! * per slot, the user's growth equality — `Σ_c x_ic − w_i G = 0`
//!   while the user is actively filling, `Σ_c x_ic = cap_i` once its
//!   task cap saturates — split into a **pair of `<=` rows**
//!   (`row_up` / `row_lo`). The pairing is what keeps every event
//!   warm-startable: appending or re-targeting a `<=` row only
//!   adds/retunes a slack, which the dual simplex repairs from the
//!   current basis, whereas a true equality row would need a fresh
//!   phase-1 artificial (see `solver::simplex` docs);
//! * one `G <= g_max` cap row whose rhs is retuned every round. When
//!   no finite task cap remains among the active users the row must
//!   not bind, and its stand-in rhs must stay **O(1)**: `G` provably
//!   never exceeds `1/max_active_weight` (an active user's dominant
//!   share `w·G` is at most the whole pool), so `2/max_active_weight`
//!   is slack and scale-safe. A huge sentinel (say 1e12) would be
//!   numerically catastrophic here: whenever a warm refactorization
//!   pivots `G` on the cap row, the sentinel rhs is eliminated into
//!   every row containing `G` and its absorption error (~1e12 · ε)
//!   wipes out the 1e-9 parity budget.
//!
//! The growth variable is *cumulative* (`Σx = w·G`, not
//! `Σx = f + w·δ` with per-round resets) precisely so that active
//! rows keep `rhs = 0` across rounds and the round-*r* optimum stays
//! feasible — literally the same point — after a saturation switch:
//! the newly saturated user's rows flip to `Σ_c x_ic = cap_i`, which
//! the current solution already satisfies (`w·G* = cap_i` up to the
//! clamp epsilon). The refactorized basis is therefore primal
//! feasible and the next round continues with ordinary warm primal
//! pivots instead of falling back to a cold solve; only the *first*
//! round after user churn may go cold (its coefficient edits can lose
//! both feasibilities).
//!
//! Departed users keep their slot: the pair rows get `rhs 0` and a zero
//! `δ` coefficient, which pins `Σ_c x_ic = 0` (hence every `x_ic = 0`,
//! releasing the capacity) without deactivating anything — the basis
//! stays valid and the slot is rewired on the next join. Saturation
//! (a user hitting its task cap mid-filling) likewise only edits the
//! pair rows' `δ` coefficient and rhs.
//!
//! Parity: the round structure, `delta_max` computation, saturation
//! thresholds and termination tests mirror `drfh::solve_classes`
//! line-for-line, and each round's LP has the identical feasible set,
//! so the per-user dominant shares `g` (unique across alternate LP
//! optima) match the from-scratch path to solver precision;
//! `tests/incremental_parity.rs` enforces this across randomized event
//! sequences. The per-class split `x` may differ between the two paths
//! when the optimum is non-unique — both splits are optimal.

use super::drfh::{FluidAllocation, FluidUser};
use super::NormalizedDemand;
use crate::cluster::{Cluster, ResVec, ServerClass};
use crate::sched::effective_weight;
use crate::solver::{LpResult, RowId, SolveStats, Solver, VarId};

/// Placeholder rhs for the growth-cap row at construction; every
/// `allocate()` round overwrites it before solving.
const GROWTH_CAP_INIT: f64 = 1.0;

/// Handle to a user slot inside an [`IncrementalDrfh`]. Stays valid
/// until `remove_user`; never reused while the user is present.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct UserId(usize);

#[derive(Clone, Debug)]
struct SlotUser {
    spec: FluidUser,
    demand: NormalizedDemand,
    /// Guarded weight (`sched::effective_weight`).
    weight: f64,
    /// Task cap in dominant-share units (`inf` when uncapped).
    cap: f64,
}

#[derive(Clone, Debug)]
struct Slot {
    /// One x_ic variable per server class.
    vars: Vec<VarId>,
    /// `Σ_c x_ic − w δ <= f`
    row_up: RowId,
    /// `−Σ_c x_ic + w δ <= −f`
    row_lo: RowId,
    user: Option<SlotUser>,
}

/// The warm-started incremental fluid DRFH allocator. See module docs.
#[derive(Clone, Debug)]
pub struct IncrementalDrfh {
    classes: Vec<ServerClass>,
    total: ResVec,
    m: usize,
    solver: Solver,
    delta: VarId,
    delta_cap: RowId,
    /// Class capacity rows, `[class][resource]`.
    cap_rows: Vec<Vec<RowId>>,
    slots: Vec<Slot>,
    /// Free (departed) slot indices, reused LIFO.
    free: Vec<usize>,
    /// Occupied slots in insertion order — the user order of every
    /// [`FluidAllocation`] this allocator returns.
    order: Vec<usize>,
}

impl IncrementalDrfh {
    /// Build the standing LP skeleton for `cluster` (classes + totals
    /// are cached; the cluster itself is not retained).
    pub fn new(cluster: &Cluster) -> Self {
        Self::from_classes(cluster.classes(), cluster.total_capacity())
    }

    /// Same, over pre-aggregated server classes.
    pub fn from_classes(classes: Vec<ServerClass>, total: ResVec) -> Self {
        let m = total.dims();
        let mut solver = Solver::new();
        let delta = solver.add_var(1.0);
        let mut cap_rows = Vec::with_capacity(classes.len());
        for class in &classes {
            let mut rows = Vec::with_capacity(m);
            for r in 0..m {
                let cap_share =
                    class.capacity[r] * class.count as f64 / total[r];
                rows.push(solver.add_row_le(&[], cap_share));
            }
            cap_rows.push(rows);
        }
        let delta_cap = solver.add_row_le(&[(delta, 1.0)], GROWTH_CAP_INIT);
        IncrementalDrfh {
            classes,
            total,
            m,
            solver,
            delta,
            delta_cap,
            cap_rows,
            slots: Vec::new(),
            free: Vec::new(),
            order: Vec::new(),
        }
    }

    /// Number of present users.
    pub fn len(&self) -> usize {
        self.order.len()
    }

    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }

    /// The server classes the standing LP is expressed over.
    pub fn classes(&self) -> &[ServerClass] {
        &self.classes
    }

    /// Pool totals (absolute units).
    pub fn total(&self) -> &ResVec {
        &self.total
    }

    /// Present users in allocation order — a ready-made argument for
    /// the from-scratch reference `allocator::solve`.
    pub fn users(&self) -> Vec<FluidUser> {
        self.order
            .iter()
            .map(|&si| self.slots[si].user.as_ref().unwrap().spec.clone())
            .collect()
    }

    /// Cumulative solver accounting (warm/cold solves, pivots, ...).
    pub fn solver_stats(&self) -> SolveStats {
        self.solver.stats()
    }

    /// Join event. Reuses a departed slot's variables and pair rows
    /// when one is free; otherwise appends fresh ones (which keeps the
    /// warm basis either way).
    pub fn add_user(&mut self, user: FluidUser) -> UserId {
        let demand = NormalizedDemand::from_absolute(&user.demand, &self.total);
        let weight = effective_weight(user.weight);
        let cap = user
            .task_cap
            .map(|t| t * demand.share[demand.dominant])
            .unwrap_or(f64::INFINITY);
        let nc = self.classes.len();
        let si = match self.free.pop() {
            Some(si) => si,
            None => {
                let vars: Vec<VarId> =
                    (0..nc).map(|_| self.solver.add_var(0.0)).collect();
                let up: Vec<(VarId, f64)> =
                    vars.iter().map(|&v| (v, 1.0)).collect();
                let lo: Vec<(VarId, f64)> =
                    vars.iter().map(|&v| (v, -1.0)).collect();
                let row_up = self.solver.add_row_le(&up, 0.0);
                let row_lo = self.solver.add_row_le(&lo, 0.0);
                self.slots.push(Slot { vars, row_up, row_lo, user: None });
                self.slots.len() - 1
            }
        };
        // (re)wire the slot's demand coefficients into the capacity rows
        for c in 0..nc {
            for r in 0..self.m {
                let row = self.cap_rows[c][r];
                let var = self.slots[si].vars[c];
                self.solver.set_coeff(row, var, demand.norm[r]);
            }
        }
        self.slots[si].user = Some(SlotUser { spec: user, demand, weight, cap });
        self.order.push(si);
        UserId(si)
    }

    /// Departure event. The slot's pair rows collapse to
    /// `Σ_c x_ic = 0`, which releases the user's capacity without
    /// disturbing the basis; the slot is recycled on the next join.
    pub fn remove_user(&mut self, id: UserId) {
        let si = id.0;
        assert!(
            self.slots[si].user.is_some(),
            "remove_user on an empty slot"
        );
        self.slots[si].user = None;
        let (up, lo) = (self.slots[si].row_up, self.slots[si].row_lo);
        self.solver.set_coeff(up, self.delta, 0.0);
        self.solver.set_coeff(lo, self.delta, 0.0);
        self.solver.set_rhs(up, 0.0);
        self.solver.set_rhs(lo, 0.0);
        self.order.retain(|&s| s != si);
        self.free.push(si);
    }

    /// Task-cap change event (paper Sec. V-A finite demands).
    pub fn set_cap(&mut self, id: UserId, task_cap: Option<f64>) {
        let u = self.slots[id.0]
            .user
            .as_mut()
            .expect("set_cap on a removed user");
        u.spec.task_cap = task_cap;
        u.cap = task_cap
            .map(|t| t * u.demand.share[u.demand.dominant])
            .unwrap_or(f64::INFINITY);
    }

    /// Weight change event.
    pub fn set_weight(&mut self, id: UserId, weight: f64) {
        let u = self.slots[id.0]
            .user
            .as_mut()
            .expect("set_weight on a removed user");
        u.spec.weight = weight;
        u.weight = effective_weight(weight);
    }

    /// Re-equalize: run the progressive-filling rounds for the current
    /// user set, warm from the standing basis. Mirrors
    /// `drfh::solve_classes` round for round (same `delta_max`, same
    /// saturation thresholds, same termination) so the resulting
    /// dominant shares match the from-scratch path.
    pub fn allocate(&mut self) -> FluidAllocation {
        let nc = self.classes.len();
        let n = self.order.len();
        let demands: Vec<NormalizedDemand> = self
            .order
            .iter()
            .map(|&si| self.slots[si].user.as_ref().unwrap().demand.clone())
            .collect();
        if n == 0 {
            return FluidAllocation {
                classes: self.classes.clone(),
                total: self.total,
                demands,
                x: Vec::new(),
                g: Vec::new(),
                tasks: Vec::new(),
                lp_pivots: 0,
                lp_solves: 0,
            };
        }
        let weights: Vec<f64> = self
            .order
            .iter()
            .map(|&si| self.slots[si].user.as_ref().unwrap().weight)
            .collect();
        let caps: Vec<f64> = self
            .order
            .iter()
            .map(|&si| self.slots[si].user.as_ref().unwrap().cap)
            .collect();

        // Reset the filling state: every present user grows from zero
        // again (dynamic DRFH re-equalizes the whole allocation on
        // every event; only the solver basis carries over). Active
        // rows are `Σx − w·G = 0` and stay untouched until the user
        // saturates — see the module docs for why the growth variable
        // is cumulative.
        let mut frozen = vec![0.0f64; n];
        let mut saturated: Vec<bool> =
            caps.iter().map(|&c| c <= 1e-15).collect();
        let mut x = vec![vec![0.0f64; nc]; n];
        let mut lp_pivots = 0u64;
        let mut lp_solves = 0u32;
        for k in 0..n {
            let si = self.order[k];
            let (up, lo) = (self.slots[si].row_up, self.slots[si].row_lo);
            let w = if saturated[k] { 0.0 } else { weights[k] };
            self.solver.set_coeff(up, self.delta, -w);
            self.solver.set_coeff(lo, self.delta, w);
            self.solver.set_rhs(up, 0.0);
            self.solver.set_rhs(lo, 0.0);
        }

        // cumulative filling level committed so far (G in the docs)
        let mut g_cum = 0.0f64;
        for _round in 0..n + 1 {
            if saturated.iter().all(|&s| s) {
                break;
            }
            // G bounded by the tightest cap among active users; equals
            // the reference's `frozen + delta_max` since active users
            // hold frozen = w·G exactly. With no finite cap the row
            // gets the O(1) never-binding stand-in (see module docs).
            let mut g_max = f64::INFINITY;
            let mut max_w = 0.0f64;
            for k in 0..n {
                if !saturated[k] {
                    max_w = max_w.max(weights[k]);
                    if caps[k].is_finite() {
                        g_max = g_max.min(caps[k] / weights[k]);
                    }
                }
            }
            // any bound >= 2/max_w can never bind (G <= 1/max_w), so
            // clamping there changes nothing while keeping the tableau
            // free of large-magnitude rhs values
            let rhs = g_max.max(0.0).min(2.0 / max_w);
            self.solver.set_rhs(self.delta_cap, rhs);

            let (sol, g_star) = match self.solver.solve() {
                LpResult::Optimal { x, obj, pivots } => {
                    lp_pivots += pivots.search() as u64;
                    lp_solves += 1;
                    (x, obj)
                }
                other => {
                    panic!("incremental DRFH round LP not optimal: {other:?}")
                }
            };
            for k in 0..n {
                let si = self.order[k];
                for c in 0..nc {
                    x[k][c] = sol[self.slots[si].vars[c].index()];
                }
            }
            // the reference's per-round progressive-filling increment
            let delta = g_star - g_cum;
            if delta <= 1e-12 {
                break; // capacity exhausted for all active users
            }
            g_cum = g_star;
            let mut newly = 0;
            for k in 0..n {
                if saturated[k] {
                    continue;
                }
                frozen[k] += weights[k] * delta;
                if caps[k].is_finite() && frozen[k] >= caps[k] - 1e-9 {
                    frozen[k] = caps[k];
                    saturated[k] = true;
                    newly += 1;
                    // freeze: Σx = cap — the current optimum already
                    // satisfies this (w·G* = cap up to the clamp
                    // epsilon), so the basis stays primal feasible
                    let si = self.order[k];
                    let (up, lo) =
                        (self.slots[si].row_up, self.slots[si].row_lo);
                    self.solver.set_coeff(up, self.delta, 0.0);
                    self.solver.set_coeff(lo, self.delta, 0.0);
                    self.solver.set_rhs(up, caps[k]);
                    self.solver.set_rhs(lo, -caps[k]);
                }
            }
            if newly == 0 {
                break; // no cap hit: capacity-limited optimum reached
            }
        }

        let g: Vec<f64> = x.iter().map(|xi| xi.iter().sum()).collect();
        let tasks: Vec<f64> = g
            .iter()
            .zip(&demands)
            .map(|(&gi, d)| gi / d.share[d.dominant])
            .collect();
        FluidAllocation {
            classes: self.classes.clone(),
            total: self.total,
            demands,
            x,
            g,
            tasks,
            lp_pivots,
            lp_solves,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allocator;
    use crate::cluster::Cluster;

    fn fig1_users() -> Vec<FluidUser> {
        vec![
            FluidUser::unweighted(ResVec::cpu_mem(0.2, 1.0)),
            FluidUser::unweighted(ResVec::cpu_mem(1.0, 0.2)),
        ]
    }

    fn assert_matches_scratch(inc: &mut IncrementalDrfh, cluster: &Cluster) {
        let warm = inc.allocate();
        let scratch = allocator::solve(cluster, &inc.users());
        assert_eq!(warm.g.len(), scratch.g.len());
        for i in 0..warm.g.len() {
            assert!(
                (warm.g[i] - scratch.g[i]).abs() < 1e-8,
                "user {i}: warm g {} vs scratch {}",
                warm.g[i],
                scratch.g[i]
            );
        }
        assert!(warm.is_feasible(1e-7));
    }

    #[test]
    fn matches_scratch_on_fig1() {
        let cluster = Cluster::fig1_example();
        let mut inc = IncrementalDrfh::new(&cluster);
        for u in fig1_users() {
            inc.add_user(u);
        }
        let a = inc.allocate();
        assert!((a.g[0] - 5.0 / 7.0).abs() < 1e-6, "g1={}", a.g[0]);
        assert!((a.g[1] - 5.0 / 7.0).abs() < 1e-6, "g2={}", a.g[1]);
        assert!((a.tasks[0] - 10.0).abs() < 1e-5);
        assert!((a.tasks[1] - 10.0).abs() < 1e-5);
    }

    #[test]
    fn join_depart_rejoin_reuses_slot() {
        let cluster = Cluster::fig1_example();
        let mut inc = IncrementalDrfh::new(&cluster);
        let users = fig1_users();
        let id0 = inc.add_user(users[0].clone());
        inc.add_user(users[1].clone());
        inc.allocate();
        inc.remove_user(id0);
        assert_eq!(inc.len(), 1);
        assert_matches_scratch(&mut inc, &cluster);
        // rejoin with a different demand: the freed slot is rewired
        inc.add_user(FluidUser::unweighted(ResVec::cpu_mem(0.5, 0.5)));
        assert_eq!(inc.len(), 2);
        // slot recycled, no new slot appended
        assert_eq!(inc.slots.len(), 2);
        assert_matches_scratch(&mut inc, &cluster);
    }

    #[test]
    fn cap_and_weight_events_apply() {
        let cluster = Cluster::fig1_example();
        let mut inc = IncrementalDrfh::new(&cluster);
        let ids: Vec<UserId> =
            fig1_users().into_iter().map(|u| inc.add_user(u)).collect();
        inc.allocate();
        // cap user 1 at 2 tasks: user 2 absorbs the release
        inc.set_cap(ids[0], Some(2.0));
        let a = inc.allocate();
        assert!((a.tasks[0] - 2.0).abs() < 1e-5, "tasks={:?}", a.tasks);
        assert!(a.tasks[1] > 10.0, "user 2 should absorb: {:?}", a.tasks);
        assert_matches_scratch(&mut inc, &cluster);
        // uncap + double the weight: shares go 2:1
        inc.set_cap(ids[0], None);
        inc.set_weight(ids[0], 2.0);
        let a = inc.allocate();
        assert!(
            (a.g[0] - 2.0 * a.g[1]).abs() < 1e-6,
            "weighted shares {:?}",
            a.g
        );
        assert_matches_scratch(&mut inc, &cluster);
    }

    #[test]
    fn zero_weight_user_uses_guarded_semantics() {
        let cluster = Cluster::fig1_example();
        let mut inc = IncrementalDrfh::new(&cluster);
        let mut users = fig1_users();
        users[0].weight = 0.0;
        for u in users {
            inc.add_user(u);
        }
        let a = inc.allocate();
        assert!(a.g.iter().all(|g| g.is_finite()), "g = {:?}", a.g);
        // guarded to weight 1.0: the unweighted Fig. 3 optimum
        assert!((a.g[0] - 5.0 / 7.0).abs() < 1e-6, "g1 = {}", a.g[0]);
        assert!((a.g[1] - 5.0 / 7.0).abs() < 1e-6, "g2 = {}", a.g[1]);
    }

    #[test]
    fn empty_and_single_user() {
        let cluster = Cluster::fig1_example();
        let mut inc = IncrementalDrfh::new(&cluster);
        let a = inc.allocate();
        assert!(a.g.is_empty() && a.tasks.is_empty());
        let id = inc.add_user(fig1_users()[0].clone());
        assert_matches_scratch(&mut inc, &cluster);
        inc.remove_user(id);
        let a = inc.allocate();
        assert!(a.g.is_empty());
    }

    #[test]
    fn warm_solves_dominate_after_first_event() {
        let cluster = Cluster::fig1_example();
        let mut inc = IncrementalDrfh::new(&cluster);
        for u in fig1_users() {
            inc.add_user(u);
        }
        inc.allocate();
        for i in 0..6usize {
            // non-binding caps (fair share is 10 tasks): the churn is
            // rhs-only, so every round after the first solve re-solves
            // warm from the standing basis
            inc.set_cap(UserId(i % 2), Some(30.0 + i as f64));
            let a = inc.allocate();
            assert!((a.g[0] - 5.0 / 7.0).abs() < 1e-6, "g={:?}", a.g);
        }
        let st = inc.solver_stats();
        assert!(
            st.warm_solves > st.cold_solves + st.fallbacks,
            "warm path barely used: {st:?}"
        );
    }
}
