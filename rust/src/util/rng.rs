//! Deterministic PCG32 random number generator plus the distribution
//! samplers the workload generator needs (uniform, exponential, normal,
//! log-normal, bounded Pareto, Zipf, weighted choice).
//!
//! We deliberately avoid external RNG crates: every experiment in
//! EXPERIMENTS.md must be reproducible from a single `u64` seed across
//! platforms, so the generator implementation is pinned here.

/// PCG-XSH-RR 64/32 (O'Neill 2014). Deterministic and fast.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Create a generator from a seed and stream id. Different streams
    /// with the same seed are statistically independent.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience: stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our purposes (n << 2^32 bias ok
        // is NOT acceptable for reproducible science; use rejection).
        let n32 = n as u32;
        let threshold = n32.wrapping_neg() % n32;
        loop {
            let r = self.next_u32();
            if r >= threshold {
                return (r % n32) as usize;
            }
        }
    }

    /// Exponential with the given rate (mean 1/rate).
    pub fn exp(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        let u = 1.0 - self.f64(); // (0, 1]
        -u.ln() / rate
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with the given mu/sigma of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Bounded Pareto on [lo, hi] with tail index alpha.
    pub fn pareto_bounded(&mut self, lo: f64, hi: f64, alpha: f64) -> f64 {
        debug_assert!(lo > 0.0 && hi > lo && alpha > 0.0);
        let u = self.f64();
        let la = lo.powf(alpha);
        let ha = hi.powf(alpha);
        // inverse CDF of the truncated Pareto
        (-(u * ha - u * la - ha) / (ha * la)).powf(-1.0 / alpha)
    }

    /// Zipf-like integer in [1, n]: P(x) ∝ 1/x^s, via inverse-CDF on a
    /// harmonic table free approximation (rejection sampling, Devroye).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n >= 1);
        if n == 1 {
            return 1;
        }
        // rejection method valid for s > 0, s != 1 handled via limits
        let s = if (s - 1.0).abs() < 1e-9 { 1.0 + 1e-9 } else { s };
        let nf = n as f64;
        let t = (nf.powf(1.0 - s) - s) / (1.0 - s);
        loop {
            let u = self.f64() * t;
            let x = if u <= 1.0 {
                u.max(f64::MIN_POSITIVE)
            } else {
                (u * (1.0 - s) + s).powf(1.0 / (1.0 - s))
            };
            let k = (x.floor() as usize).clamp(1, n);
            let ratio = (k as f64).powf(-s)
                / if x <= 1.0 { 1.0 } else { x.powf(-s) };
            if self.f64() < ratio {
                return k;
            }
        }
    }

    /// Index sampled proportionally to `weights` (need not normalize).
    pub fn choice_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg32::new(42, 7);
        let mut b = Pcg32::new(42, 7);
        for _ in 0..1000 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_streams_differ() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 2);
        let same = (0..100).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 5);
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut rng = Pcg32::seeded(1);
        let xs: Vec<f64> = (0..20_000).map(|_| rng.f64()).collect();
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut rng = Pcg32::seeded(2);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[rng.below(7)] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn exp_mean() {
        let mut rng = Pcg32::seeded(3);
        let mean = (0..50_000).map(|_| rng.exp(2.0)).sum::<f64>() / 50_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::seeded(4);
        let xs: Vec<f64> = (0..50_000).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
                / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn pareto_bounds() {
        let mut rng = Pcg32::seeded(5);
        for _ in 0..10_000 {
            let x = rng.pareto_bounded(1.0, 100.0, 1.5);
            assert!((1.0..=100.0).contains(&x), "x={x}");
        }
    }

    #[test]
    fn zipf_bounds_and_skew() {
        let mut rng = Pcg32::seeded(6);
        let mut ones = 0;
        for _ in 0..10_000 {
            let x = rng.zipf(50, 1.2);
            assert!((1..=50).contains(&x));
            if x == 1 {
                ones += 1;
            }
        }
        // Zipf(1.2) puts a large mass on 1
        assert!(ones > 2_000, "ones={ones}");
    }

    #[test]
    fn choice_weighted_prefers_heavy() {
        let mut rng = Pcg32::seeded(7);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[rng.choice_weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > 8_000);
    }
}
