//! Experiment harnesses: one per table/figure of the paper's
//! evaluation (Sec. VI). Each regenerates the paper's rows/series on
//! the synthetic Google-like substrate and prints paper-vs-measured
//! summaries; see DESIGN.md §5 for the index and EXPERIMENTS.md for
//! recorded results.
//!
//! §Perf: every multi-variant harness fans its independent simulation
//! runs out through [`runner`] (scoped threads, per-thread scheduler
//! factories); results are bit-identical to the old sequential loops.

pub mod churn;
pub mod faults;
pub mod fig4;
pub mod fig4_fluid;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod runner;
pub mod sim_scale;
pub mod table2;
pub mod user_scale;

use crate::cluster::Cluster;
use crate::sim::SimOpts;
use crate::util::Pcg32;
use crate::workload::{GoogleLikeConfig, Trace, TraceGenerator};

/// Shared setup for the trace-driven evaluations (Fig. 5-8, Table II):
/// a cluster sampled from Table I and a 24-hour Google-like trace.
///
/// The paper evaluates on 2,000 servers; `servers` scales that down for
/// quicker runs (the paper itself scales 12K -> 2K "so that fairness
/// becomes relevant" — we keep k >> n at every scale).
#[derive(Clone, Debug)]
pub struct EvalSetup {
    pub cluster: Cluster,
    pub trace: Trace,
    pub opts: SimOpts,
    pub seed: u64,
}

impl EvalSetup {
    /// The standard evaluation workload: `servers` Table I servers,
    /// `users` tenants, 24 h of Poisson job arrivals heavy enough to
    /// oversubscribe the pool (the paper's saturated regime).
    pub fn standard(seed: u64, servers: usize, users: usize) -> Self {
        Self::with_duration(seed, servers, users, 86_400.0)
    }

    /// Same, with a custom trace duration (benches use shorter runs).
    pub fn with_duration(
        seed: u64,
        servers: usize,
        users: usize,
        duration: f64,
    ) -> Self {
        let mut rng = Pcg32::new(seed, 0xc1);
        let cluster = Cluster::google_sample(servers, &mut rng);
        // Oversubscription scaled to pool size. Back-of-envelope: the
        // pool offers ~0.5 units of each resource per server; the mean
        // dominant demand per task is ~0.095 units, so ~5.3 tasks fit
        // per server concurrently; with a ~500 s mean duration and a
        // ~72-task mean job size, ~2.2e-4 jobs per server-second keep
        // the offered load at ~80-90% of DRFH capacity: bursts backlog the
        // slot scheduler while DRFH drains — the paper's regime (slots
        // utilization ~45%, small jobs mostly unqueued).
        let jobs_per_user =
            (2.2e-4 * servers as f64 * duration / users as f64).max(2.0);
        let cfg = GoogleLikeConfig {
            users,
            duration,
            jobs_per_user,
            dur_lo: 120.0,
            dur_hi: 21_600.0,
            dur_alpha: 1.1,
            ..Default::default()
        };
        let trace = TraceGenerator::new(cfg).generate(seed);
        let opts = SimOpts {
            horizon: duration,
            sample_dt: (duration / 720.0).max(10.0),
            track_user_series: false,
            ..SimOpts::default()
        };
        EvalSetup { cluster, trace, opts, seed }
    }
}

/// Write a CSV file under `results/` (created on demand); best-effort —
/// experiments still print their tables when the filesystem is
/// read-only.
pub fn write_csv(name: &str, header: &str, rows: &[String]) {
    let dir = std::path::Path::new("results");
    if std::fs::create_dir_all(dir).is_err() {
        return;
    }
    let mut body = String::with_capacity(rows.len() * 32 + header.len() + 1);
    body.push_str(header);
    body.push('\n');
    for r in rows {
        body.push_str(r);
        body.push('\n');
    }
    let _ = std::fs::write(dir.join(name), body);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standard_setup_is_consistent() {
        let s = EvalSetup::with_duration(3, 100, 10, 4000.0);
        assert_eq!(s.cluster.len(), 100);
        assert_eq!(s.trace.users.len(), 10);
        s.trace.validate().unwrap();
        assert!(s.opts.horizon == 4000.0);
    }

    #[test]
    fn setup_deterministic() {
        let a = EvalSetup::with_duration(5, 50, 5, 2000.0);
        let b = EvalSetup::with_duration(5, 50, 5, 2000.0);
        assert_eq!(a.trace.total_tasks(), b.trace.total_tasks());
        for (x, y) in a.cluster.servers.iter().zip(&b.cluster.servers) {
            assert_eq!(x.capacity, y.capacity);
        }
    }
}
