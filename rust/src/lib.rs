//! # drfh — Dominant Resource Fairness with Heterogeneous Servers
//!
//! A full reproduction of Wang, Li & Liang, *"Dominant Resource Fairness
//! in Cloud Computing Systems with Heterogeneous Servers"* (2013):
//!
//! * [`cluster`] — the heterogeneous server pool (paper Sec. III-A),
//!   including the Google Table I configuration distribution;
//! * [`workload`] — users/jobs/tasks and the Google-like trace generator
//!   substituting the original (unavailable) cluster traces, plus the
//!   trace-scale data layout ([`workload::TaskArena`],
//!   [`workload::DemandTable`]);
//! * [`solver`] — dense two-phase simplex, the LP substrate for eq. (7),
//!   warm-startable across edits ([`solver::Solver`]);
//! * [`allocator`] — the *exact fluid* DRFH allocation (paper Sec. IV),
//!   weighted users, finite demands, the naive per-server DRF
//!   baseline of Sec. III-D, and the event-driven incremental
//!   allocator ([`allocator::incremental`]);
//! * [`sched`] — discrete task schedulers: Best-Fit DRFH, First-Fit
//!   DRFH (paper Sec. V-B) and the slot-based baseline (Table II),
//!   with the incremental decision indexes ([`sched::index`]) and the
//!   class-keyed user state that scales them to millions of users
//!   ([`sched::users`]);
//! * [`sim`] — the discrete-event cluster simulator behind every figure
//!   in the evaluation (Sec. VI): timer-wheel event queue
//!   ([`sim::wheel`]), batched drain, streaming metrics;
//! * [`metrics`] — utilization time series, JCT CDFs, completion
//!   ratios, and bounded-memory share sketches ([`metrics::shares`]);
//! * [`runtime`] — the PJRT bridge executing the AOT-compiled XLA
//!   scheduling kernels (L1 Pallas / L2 JAX) from the Rust hot path;
//! * [`coordinator`] — the online (tokio) scheduling service;
//! * [`experiments`] — one harness per paper table/figure, plus the
//!   §Perf harnesses (`sim-scale`, `user-scale`) on the parallel
//!   sweep runner ([`experiments::runner`]);
//! * [`analysis`] — the in-tree determinism conformance linter behind
//!   `drfh lint` (see also the wave-boundary invariant auditor,
//!   [`sim::audit`]).
//!
//! ARCHITECTURE.md (repo root) maps these modules, the event-wave
//! data flow, the parity-reference convention, and which bench emits
//! which `BENCH_*.json`; README.md has the CLI quickstart.

pub mod allocator;
pub mod analysis;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod metrics;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod solver;
pub mod util;
pub mod workload;
