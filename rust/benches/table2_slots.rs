//! Regenerates paper Table II (Slots scheduler utilization vs slot
//! size) and times one sweep point.
//!
//! Run: `cargo bench --bench table2_slots`
//! Full-scale sweep: `drfh exp table2 --servers 2000`

use drfh::experiments::{table2, EvalSetup};
use drfh::sched::SlotsScheduler;
use drfh::sim::run;
use drfh::util::bench::{bench, header};
use std::time::Duration;

fn main() {
    // bench-scale setup: 300 servers / 30 users / 6 h keeps the sweep
    // shape while finishing quickly (scale with `drfh exp table2`)
    let setup = EvalSetup::with_duration(42, 300, 30, 21_600.0);
    let rows = table2::run_table2(&setup);
    table2::print(&rows);

    header("table2: one slots-scheduler simulation");
    for &slots in &[10usize, 14, 20] {
        bench(
            &format!("slots={slots} sim (300 servers, 6 h)"),
            Duration::from_secs(5),
            20,
            || {
                run(
                    setup.cluster.clone(),
                    &setup.trace,
                    Box::new(SlotsScheduler::new(&setup.cluster, slots)),
                    setup.opts.clone(),
                )
                .tasks_completed
            },
        );
    }
}
