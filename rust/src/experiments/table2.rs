//! Table II — resource utilization of the Slots scheduler as a
//! function of slot size (10/12/14/16/20 slots per maximum server).
//!
//! Paper reference: CPU utilization rises from 35.1% (10 slots) to a
//! peak of 43.9-45.4% around 14-16 slots and falls back at 20 (40.6%);
//! memory peaks at 14 slots (28.0%). Too few slots leave resources
//! stranded; too many overcommit servers and the processor-sharing
//! slowdown wastes throughput.

use super::runner::{self, SchedFactory};
use super::{write_csv, EvalSetup};
use crate::cluster::Cluster;
use crate::sched::{Scheduler, SlotsScheduler};

/// One row of Table II.
#[derive(Clone, Debug)]
pub struct SlotRow {
    pub slots: usize,
    pub cpu_util: f64,
    pub mem_util: f64,
}

pub const SLOT_SIZES: [usize; 5] = [10, 12, 14, 16, 20];

/// Run the sweep on a shared setup, one slot size per worker thread.
pub fn run_table2(setup: &EvalSetup) -> Vec<SlotRow> {
    let factories: Vec<SchedFactory> = SLOT_SIZES
        .iter()
        .map(|&slots| {
            let f: SchedFactory = Box::new(move |c: &Cluster| {
                Box::new(SlotsScheduler::new(c, slots)) as Box<dyn Scheduler>
            });
            f
        })
        .collect();
    runner::sweep(&setup.cluster, &setup.trace, &setup.opts, factories)
        .into_iter()
        .zip(SLOT_SIZES)
        .map(|(report, slots)| SlotRow {
            slots,
            cpu_util: report.avg_cpu_util,
            mem_util: report.avg_mem_util,
        })
        .collect()
}

/// Print the table and dump CSV.
pub fn print(rows: &[SlotRow]) {
    println!("== Table II: Slots scheduler utilization vs slot size ==");
    println!(
        "{:<24} {:>14} {:>18}",
        "slots per max server", "CPU util", "memory util"
    );
    let paper = [(35.1, 23.4), (42.2, 27.4), (43.9, 28.0), (45.4, 24.2), (40.6, 20.0)];
    for (row, p) in rows.iter().zip(paper.iter()) {
        println!(
            "{:<24} {:>8.1}% (paper {:>4.1}%) {:>6.1}% (paper {:>4.1}%)",
            row.slots,
            row.cpu_util * 100.0,
            p.0,
            row.mem_util * 100.0,
            p.1
        );
    }
    let best = rows
        .iter()
        .max_by(|a, b| {
            (a.cpu_util + a.mem_util)
                .total_cmp(&(b.cpu_util + b.mem_util))
        })
        .unwrap();
    println!("best overall: {} slots (paper: 14)", best.slots);
    write_csv(
        "table2_slots.csv",
        "slots,cpu_util,mem_util",
        &rows
            .iter()
            .map(|r| format!("{},{:.4},{:.4}", r.slots, r.cpu_util, r.mem_util))
            .collect::<Vec<_>>(),
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slot_sweep_shape() {
        // small but saturated setup so the sweep shape is visible
        let setup = EvalSetup::with_duration(11, 120, 12, 12_000.0);
        let rows = run_table2(&setup);
        assert_eq!(rows.len(), 5);
        for r in &rows {
            assert!(r.cpu_util > 0.0 && r.cpu_util <= 1.0);
            assert!(r.mem_util > 0.0 && r.mem_util <= 1.0);
        }
        // utilization with very few slots is below the best observed
        let best_cpu =
            rows.iter().map(|r| r.cpu_util).fold(0.0f64, f64::max);
        assert!(
            rows[0].cpu_util <= best_cpu + 1e-9,
            "10-slot run should not beat the sweep max"
        );
    }
}
