//! The determinism conformance linter behind `drfh lint`.
//!
//! Five rules, each encoding an invariant the repo's parity tests rely
//! on but no compiler pass enforces:
//!
//! | rule id         | invariant                                               |
//! | --------------- | ------------------------------------------------------- |
//! | `hash-iter`     | no `HashMap`/`HashSet` *iteration* in decision modules  |
//! | `float-sort`    | float ordering uses `total_cmp`, never `partial_cmp`    |
//! | `wall-clock`    | no `Instant::now`/`SystemTime`/entropy in decision code |
//! | `naive-parity`  | every `Scheduler` impl has a `naive()` parity reference |
//! | `unsafe-safety` | `unsafe` requires a `// SAFETY:` comment                |
//!
//! Decision modules are `sched`, `sim`, `cluster` and `workload` —
//! the code whose outputs must be bit-identical across shard counts,
//! queue kinds and index implementations. Keyed hash lookups
//! (`get`/`entry`/`contains_key`) stay legal there; only iteration
//! order can leak `RandomState` nondeterminism into decisions.
//!
//! The harness trees — `benches/**` and `tests/**`, walked by
//! [`lint_crate`] alongside `src/` — get the `float-sort` and
//! `wall-clock` rules: bench checksums and parity assertions sorted
//! with `partial_cmp` can mis-rank on NaN exactly like decision code,
//! and raw `Instant`/`SystemTime` reads there bypass the repo's
//! unreliable-container-timer policy (timing belongs in
//! `util::bench`, which reports mean/min/max from one audited site).
//!
//! Findings carry `file:line` plus the rule id and are suppressible
//! with a `// lint:allow(rule-id)` pragma on the same line or the
//! line above, followed by prose justifying the exemption. The linter
//! self-tests against [`VIOLATION_CORPUS`], an embedded set of
//! minimal violating sources; `drfh lint --corpus true` runs the same
//! corpus from the CLI and must exit non-zero, which CI checks.

use std::collections::BTreeMap;
use std::fmt;
use std::path::{Path, PathBuf};

/// A lint rule identifier. Ordering is the report order.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    /// `HashMap`/`HashSet` iteration inside a decision module.
    HashIter,
    /// `partial_cmp` used for float ordering (want `total_cmp`).
    FloatSort,
    /// Wall-clock or entropy source inside a decision module.
    WallClock,
    /// `impl Scheduler for T` without a `naive()` parity reference.
    NaiveParity,
    /// `unsafe` without a `// SAFETY:` comment.
    UnsafeSafety,
}

impl Rule {
    /// All rules, in report order.
    pub const ALL: [Rule; 5] = [
        Rule::HashIter,
        Rule::FloatSort,
        Rule::WallClock,
        Rule::NaiveParity,
        Rule::UnsafeSafety,
    ];

    /// The stable id used in reports and `lint:allow(...)` pragmas.
    pub fn id(self) -> &'static str {
        match self {
            Rule::HashIter => "hash-iter",
            Rule::FloatSort => "float-sort",
            Rule::WallClock => "wall-clock",
            Rule::NaiveParity => "naive-parity",
            Rule::UnsafeSafety => "unsafe-safety",
        }
    }

    /// Parse a pragma id back into a rule.
    pub fn from_id(id: &str) -> Option<Rule> {
        Rule::ALL.iter().copied().find(|r| r.id() == id)
    }
}

/// One linter finding: file, 1-based line, rule, human message.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    /// Path relative to the linted source root, `/`-separated.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Which rule fired.
    pub rule: Rule,
    /// Human-readable explanation.
    pub msg: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}",
            self.file,
            self.line,
            self.rule.id(),
            self.msg
        )
    }
}

/// Modules whose code decides placements; hash iteration and clock
/// reads are banned here (top-level directory names under `src/`).
const DECISION_MODULES: [&str; 4] = ["sched", "sim", "cluster", "workload"];

fn in_decision_module(rel_path: &str) -> bool {
    let first = rel_path.split('/').next().unwrap_or("");
    let stem = first.strip_suffix(".rs").unwrap_or(first);
    DECISION_MODULES.contains(&stem)
}

/// Harness trees ([`lint_crate`] walks them with `benches/` and
/// `tests/` path prefixes): only `float-sort` and `wall-clock` apply.
fn in_harness_tree(rel_path: &str) -> bool {
    let first = rel_path.split('/').next().unwrap_or("");
    first == "benches" || first == "tests"
}

// ---------------------------------------------------------------------
// Lexing: split each source line into code text and comment text, with
// string/char-literal contents blanked out of the code text so rule
// patterns never match inside literals. Tracks multi-line constructs
// (block comments, plain and raw strings) across lines.
// ---------------------------------------------------------------------

#[derive(Clone, Copy, PartialEq)]
enum LexState {
    Code,
    /// Nested block comment, with depth.
    Block(u32),
    /// Inside a `"..."` string literal.
    Str,
    /// Inside a raw string, with the number of `#` delimiters.
    RawStr(u32),
}

/// Per-line lexer output.
struct Stripped {
    /// Code text with comments and literal contents replaced by
    /// spaces (column-preserving).
    code: Vec<String>,
    /// Comment text per line (line + block comments concatenated).
    comments: Vec<String>,
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

fn strip(src: &str) -> Stripped {
    let chars: Vec<char> = src.chars().collect();
    let mut code = Vec::new();
    let mut comments = Vec::new();
    let mut cur_code = String::new();
    let mut cur_com = String::new();
    let mut st = LexState::Code;
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            code.push(std::mem::take(&mut cur_code));
            comments.push(std::mem::take(&mut cur_com));
            i += 1;
            continue;
        }
        match st {
            LexState::Code => {
                if c == '/' && chars.get(i + 1) == Some(&'/') {
                    // Line comment: rest of the line is comment text.
                    while i < chars.len() && chars[i] != '\n' {
                        cur_com.push(chars[i]);
                        cur_code.push(' ');
                        i += 1;
                    }
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    st = LexState::Block(1);
                    cur_code.push_str("  ");
                    i += 2;
                } else if c == '"' {
                    st = LexState::Str;
                    cur_code.push('"');
                    i += 1;
                } else if c == 'r'
                    && !prev_is_ident(&chars, i)
                    && raw_str_hashes(&chars, i + 1).is_some()
                {
                    let h = raw_str_hashes(&chars, i + 1).unwrap();
                    st = LexState::RawStr(h);
                    // r, the hashes, and the opening quote.
                    for _ in 0..(h as usize + 2) {
                        cur_code.push(' ');
                    }
                    i += h as usize + 2;
                } else if c == '\'' {
                    // Char literal vs lifetime: a literal closes with
                    // `'` after one (possibly escaped) character.
                    if let Some(end) = char_literal_end(&chars, i) {
                        for _ in i..=end {
                            cur_code.push(' ');
                        }
                        i = end + 1;
                    } else {
                        cur_code.push('\'');
                        i += 1;
                    }
                } else {
                    cur_code.push(c);
                    i += 1;
                }
            }
            LexState::Block(depth) => {
                if c == '*' && chars.get(i + 1) == Some(&'/') {
                    st = if depth == 1 {
                        LexState::Code
                    } else {
                        LexState::Block(depth - 1)
                    };
                    cur_code.push_str("  ");
                    i += 2;
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    st = LexState::Block(depth + 1);
                    cur_code.push_str("  ");
                    i += 2;
                } else {
                    cur_com.push(c);
                    cur_code.push(' ');
                    i += 1;
                }
            }
            LexState::Str => {
                if c == '\\' && i + 1 < chars.len() && chars[i + 1] != '\n' {
                    cur_code.push_str("  ");
                    i += 2;
                } else if c == '"' {
                    st = LexState::Code;
                    cur_code.push('"');
                    i += 1;
                } else {
                    cur_code.push(' ');
                    i += 1;
                }
            }
            LexState::RawStr(h) => {
                if c == '"' && closes_raw(&chars, i, h) {
                    st = LexState::Code;
                    for _ in 0..(h as usize + 1) {
                        cur_code.push(' ');
                    }
                    i += h as usize + 1;
                } else {
                    cur_code.push(' ');
                    i += 1;
                }
            }
        }
    }
    code.push(cur_code);
    comments.push(cur_com);
    Stripped { code, comments }
}

fn prev_is_ident(chars: &[char], i: usize) -> bool {
    i > 0 && is_ident(chars[i - 1])
}

/// At `chars[i]` just after an `r`: `Some(n)` if `#`*n `"` starts a
/// raw string here.
fn raw_str_hashes(chars: &[char], i: usize) -> Option<u32> {
    let mut n = 0u32;
    let mut j = i;
    while chars.get(j) == Some(&'#') {
        n += 1;
        j += 1;
    }
    (chars.get(j) == Some(&'"')).then_some(n)
}

/// Does the `"` at `chars[i]` close a raw string with `h` hashes?
fn closes_raw(chars: &[char], i: usize, h: u32) -> bool {
    (1..=h as usize).all(|k| chars.get(i + k) == Some(&'#'))
}

/// If a char literal starts at `chars[i] == '\''`, return the index
/// of its closing quote; `None` for lifetimes.
fn char_literal_end(chars: &[char], i: usize) -> Option<usize> {
    match chars.get(i + 1) {
        Some('\\') => {
            // Escaped: skip to the next unescaped quote (covers
            // \n, \', \u{..}).
            let mut j = i + 2;
            while j < chars.len() && chars[j] != '\'' && chars[j] != '\n' {
                j += 1;
            }
            (chars.get(j) == Some(&'\'')).then_some(j)
        }
        Some(_) => (chars.get(i + 2) == Some(&'\'')).then_some(i + 2),
        None => None,
    }
}

// ---------------------------------------------------------------------
// Pragmas
// ---------------------------------------------------------------------

/// Lines (1-based) on which each rule is suppressed. A pragma on line
/// `n` suppresses its rule on lines `n` and `n + 1`, so it can sit
/// either on the flagged line or directly above it.
fn collect_allows(comments: &[String]) -> BTreeMap<Rule, Vec<usize>> {
    let mut allows: BTreeMap<Rule, Vec<usize>> = BTreeMap::new();
    for (idx, com) in comments.iter().enumerate() {
        let mut rest = com.as_str();
        while let Some(p) = rest.find("lint:allow(") {
            rest = &rest[p + "lint:allow(".len()..];
            if let Some(close) = rest.find(')') {
                if let Some(rule) = Rule::from_id(rest[..close].trim()) {
                    let e = allows.entry(rule).or_default();
                    e.push(idx + 1);
                    e.push(idx + 2);
                }
                rest = &rest[close + 1..];
            } else {
                break;
            }
        }
    }
    allows
}

fn allowed(allows: &BTreeMap<Rule, Vec<usize>>, rule: Rule, line: usize) -> bool {
    allows.get(&rule).is_some_and(|v| v.contains(&line))
}

// ---------------------------------------------------------------------
// Pattern helpers (word-boundary aware, on stripped code text)
// ---------------------------------------------------------------------

/// Byte offsets of word-boundary occurrences of `word` in `line`:
/// neither neighbour is an identifier character.
fn word_positions(line: &str, word: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(p) = line[from..].find(word) {
        let at = from + p;
        let before_ok = at == 0
            || !line[..at].chars().next_back().is_some_and(is_ident);
        let after = at + word.len();
        let after_ok =
            !line[after..].chars().next().is_some_and(is_ident);
        if before_ok && after_ok {
            out.push(at);
        }
        from = at + word.len();
    }
    out
}

fn contains_word(line: &str, word: &str) -> bool {
    !word_positions(line, word).is_empty()
}

// ---------------------------------------------------------------------
// The rules
// ---------------------------------------------------------------------

/// Lint a single source file. `rel_path` is the `/`-separated path
/// relative to the source root (it selects decision-module rules).
pub fn lint_source(rel_path: &str, src: &str) -> Vec<Finding> {
    let stripped = strip(src);
    let allows = collect_allows(&stripped.comments);
    let decision = in_decision_module(rel_path);
    let mut out = Vec::new();
    let mut push = |rule: Rule, line: usize, msg: String| {
        if !allowed(&allows, rule, line) {
            out.push(Finding { file: rel_path.to_string(), line, rule, msg });
        }
    };

    if in_harness_tree(rel_path) {
        // Bench/test harness files: float ordering and timer
        // discipline only — hash iteration and unsafe are the
        // harness's own business there.
        rule_float_sort(&stripped.code, &mut push);
        rule_wall_clock(&stripped.code, "a bench/test harness", &mut push);
    } else {
        rule_float_sort(&stripped.code, &mut push);
        rule_unsafe_safety(&stripped.code, &stripped.comments, &mut push);
        rule_naive_parity(&stripped.code, &mut push);
        if decision {
            rule_wall_clock(&stripped.code, "a decision module", &mut push);
            rule_hash_iter(&stripped.code, &mut push);
        }
    }

    out.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    out
}

fn rule_float_sort(code: &[String], push: &mut impl FnMut(Rule, usize, String)) {
    for (idx, line) in code.iter().enumerate() {
        if !contains_word(line, "partial_cmp") {
            continue;
        }
        // `fn partial_cmp` is PartialOrd impl boilerplate, not a use.
        if line.contains("fn partial_cmp") {
            continue;
        }
        push(
            Rule::FloatSort,
            idx + 1,
            "partial_cmp on floats can panic or misorder on NaN; \
             use f64::total_cmp"
                .to_string(),
        );
    }
}

fn rule_unsafe_safety(
    code: &[String],
    comments: &[String],
    push: &mut impl FnMut(Rule, usize, String),
) {
    for (idx, line) in code.iter().enumerate() {
        if !contains_word(line, "unsafe") {
            continue;
        }
        // Accept a SAFETY: comment on the same line or up to three
        // lines above.
        let lo = idx.saturating_sub(3);
        let documented = comments[lo..=idx]
            .iter()
            .any(|c| c.contains("SAFETY:"));
        if !documented {
            push(
                Rule::UnsafeSafety,
                idx + 1,
                "unsafe without a `// SAFETY:` comment in the three \
                 lines above"
                    .to_string(),
            );
        }
    }
}

fn rule_naive_parity(
    code: &[String],
    push: &mut impl FnMut(Rule, usize, String),
) {
    let has_naive = code.iter().any(|l| l.contains("fn naive("));
    for (idx, line) in code.iter().enumerate() {
        if line.contains("impl") && line.contains("Scheduler for ") && !has_naive
        {
            push(
                Rule::NaiveParity,
                idx + 1,
                "Scheduler impl without a naive() parity reference in \
                 this file; add one or document the exemption"
                    .to_string(),
            );
        }
    }
}

fn rule_wall_clock(
    code: &[String],
    where_: &str,
    push: &mut impl FnMut(Rule, usize, String),
) {
    const BANNED: [(&str, &str); 4] = [
        ("Instant", "std::time::Instant"),
        ("SystemTime", "std::time::SystemTime"),
        ("thread_rng", "ambient RNG"),
        ("from_entropy", "entropy-seeded RNG"),
    ];
    for (idx, line) in code.iter().enumerate() {
        for (pat, what) in BANNED {
            if contains_word(line, pat) {
                push(
                    Rule::WallClock,
                    idx + 1,
                    format!(
                        "{what} in {where_}; use seeded util::rng::Pcg32 \
                         for randomness and util::bench for timing"
                    ),
                );
            }
        }
    }
}

/// Methods on a hash container whose results depend on hash order.
const HASH_ITER_METHODS: [&str; 8] = [
    ".iter()",
    ".iter_mut()",
    ".keys()",
    ".values()",
    ".values_mut()",
    ".drain(",
    ".into_iter()",
    ".retain(",
];

fn rule_hash_iter(code: &[String], push: &mut impl FnMut(Rule, usize, String)) {
    // Pass A: names bound to HashMap/HashSet in this file (lets,
    // struct fields, consts — anything of the form `name: HashMap<`
    // or `name = HashMap::`).
    let mut hash_names: Vec<String> = Vec::new();
    for line in code {
        for ty in ["HashMap", "HashSet"] {
            for at in word_positions(line, ty) {
                if let Some(name) = bound_name(&line[..at]) {
                    if !hash_names.contains(&name) {
                        hash_names.push(name);
                    }
                }
            }
        }
    }
    // Pass B: flag hash-order iteration over any such name. Matches
    // are word-boundary occurrences of the name followed by an
    // order-dependent method, or preceded by a `for … in` header.
    for (idx, line) in code.iter().enumerate() {
        for name in &hash_names {
            let hit = word_positions(line, name).into_iter().any(|at| {
                let after = &line[at + name.len()..];
                let before = &line[..at];
                let method = HASH_ITER_METHODS
                    .iter()
                    .any(|m| after.starts_with(m));
                let for_loop = contains_word(line, "for")
                    && (before.ends_with("in ")
                        || before.ends_with("in &")
                        || before.ends_with("in &mut "));
                method || for_loop
            });
            if hit {
                push(
                    Rule::HashIter,
                    idx + 1,
                    format!(
                        "iteration over hash container `{name}` in a \
                         decision module; hash order is \
                         nondeterministic — use BTreeMap/Vec or prove \
                         order-independence with lint:allow"
                    ),
                );
            }
        }
    }
}

/// Given the code text before a `HashMap`/`HashSet` occurrence,
/// extract the name it is bound to: the last identifier immediately
/// followed (modulo spaces) by `:` or `=`.
fn bound_name(before: &str) -> Option<String> {
    let trimmed = before.trim_end();
    let sep = trimmed.chars().next_back()?;
    let head = match sep {
        ':' => {
            // Exclude paths (`std::collections::HashMap`).
            let h = trimmed[..trimmed.len() - 1].trim_end();
            if h.ends_with(':') {
                return None;
            }
            h
        }
        '=' => trimmed[..trimmed.len() - 1].trim_end(),
        _ => return None,
    };
    let name: String = head
        .chars()
        .rev()
        .take_while(|&c| is_ident(c))
        .collect::<Vec<_>>()
        .into_iter()
        .rev()
        .collect();
    (!name.is_empty() && !name.chars().next().unwrap().is_numeric())
        .then_some(name)
}

// ---------------------------------------------------------------------
// Tree walking
// ---------------------------------------------------------------------

/// Lint every `.rs` file under `src_root`, in sorted path order.
/// Returns findings sorted by `(file, line, rule)`.
pub fn lint_tree(src_root: &Path) -> std::io::Result<Vec<Finding>> {
    let mut files = Vec::new();
    collect_rs(src_root, &mut files)?;
    files.sort();
    let mut out = Vec::new();
    for f in files {
        let rel = f
            .strip_prefix(src_root)
            .unwrap_or(&f)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let src = std::fs::read_to_string(&f)?;
        out.extend(lint_source(&rel, &src));
    }
    Ok(out)
}

/// Lint the whole crate: the source tree at `src_root` with the full
/// rule set, plus the sibling `benches/` and `tests/` harness trees
/// (when present) with the `float-sort` and `wall-clock` rules.
pub fn lint_crate(src_root: &Path) -> std::io::Result<Vec<Finding>> {
    let mut out = lint_tree(src_root)?;
    let crate_root = src_root.parent().unwrap_or(Path::new(""));
    for sub in ["benches", "tests"] {
        let dir = crate_root.join(sub);
        if !dir.is_dir() {
            continue;
        }
        let mut files = Vec::new();
        collect_rs(&dir, &mut files)?;
        files.sort();
        for f in files {
            let rel = f
                .strip_prefix(&dir)
                .unwrap_or(&f)
                .components()
                .map(|c| c.as_os_str().to_string_lossy())
                .collect::<Vec<_>>()
                .join("/");
            let src = std::fs::read_to_string(&f)?;
            out.extend(lint_source(&format!("{sub}/{rel}"), &src));
        }
    }
    Ok(out)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Embedded violation corpus
// ---------------------------------------------------------------------

/// Minimal sources that each violate exactly one rule, as
/// `(virtual path, source)` pairs. The linter must report at least
/// one finding on every entry: the self-tests assert per-rule hits,
/// and `drfh lint --corpus true` must exit non-zero in CI.
pub const VIOLATION_CORPUS: [(&str, &str); 9] = [
    (
        "sched/corpus_hash_iter.rs",
        r#"use std::collections::HashMap;
fn f() {
    let m: HashMap<u32, f64> = HashMap::new();
    for (k, v) in &m {
        println!("{k} {v}");
    }
    let total: f64 = m.values().sum();
    let _ = total;
}
"#,
    ),
    (
        "metrics/corpus_float_sort.rs",
        r#"fn f(xs: &mut Vec<f64>) {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
}
"#,
    ),
    (
        "sim/corpus_wall_clock.rs",
        r#"fn f() -> std::time::Instant {
    std::time::Instant::now()
}
"#,
    ),
    (
        "sched/corpus_naive_parity.rs",
        r#"struct P;
impl Scheduler for P {
    fn name(&self) -> &'static str { "p" }
}
"#,
    ),
    (
        "util/corpus_unsafe.rs",
        r#"fn f(xs: &[u64]) -> u64 {
    unsafe { *xs.get_unchecked(0) }
}
"#,
    ),
    (
        "benches/corpus_bench_wall_clock.rs",
        r#"fn f() -> u128 {
    let t0 = std::time::Instant::now();
    t0.elapsed().as_nanos()
}
"#,
    ),
    (
        "tests/corpus_test_float_sort.rs",
        r#"fn f(xs: &mut Vec<f64>) {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
}
"#,
    ),
    // the fault layer's retry backoff must stay a pure function of
    // (seed, task, attempt): this entry pins that `sim/faults.rs`
    // sits inside the linted decision-module set, so an ambient
    // clock sneaking into the backoff path fails CI
    (
        "sim/faults.rs",
        r#"fn backoff_ms() -> u128 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap()
        .as_millis()
}
"#,
    ),
    // the churn generator's renewal/flash/diurnal draws must stay
    // pure functions of (config, seed): this entry pins that
    // `workload/gen.rs` sits inside the linted decision-module set,
    // so an ambient RNG sneaking into a churn stream fails CI
    (
        "workload/gen.rs",
        r#"fn next_leave(rate: f64) -> f64 {
    let r = rand::thread_rng().gen::<f64>();
    -r.ln() / rate
}
"#,
    ),
];

/// Lint the embedded corpus, as the CLI `--corpus true` mode does.
pub fn lint_corpus() -> Vec<Finding> {
    let mut out = Vec::new();
    for (path, src) in VIOLATION_CORPUS {
        out.extend(lint_source(path, src));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_hit(findings: &[Finding]) -> Vec<Rule> {
        let mut r: Vec<Rule> = findings.iter().map(|f| f.rule).collect();
        r.sort();
        r.dedup();
        r
    }

    #[test]
    fn corpus_trips_every_rule() {
        let findings = lint_corpus();
        assert_eq!(rules_hit(&findings), Rule::ALL.to_vec());
        // Each corpus entry produces at least one finding.
        for (path, src) in VIOLATION_CORPUS {
            assert!(
                !lint_source(path, src).is_empty(),
                "corpus entry {path} produced no findings"
            );
        }
    }

    #[test]
    fn hash_iter_flags_iteration_not_lookup() {
        let (path, src) = VIOLATION_CORPUS[0];
        let f = lint_source(path, src);
        // Both the for-loop and the .values() sum are flagged.
        assert_eq!(f.iter().filter(|x| x.rule == Rule::HashIter).count(), 2);

        // Keyed lookups are fine.
        let ok = "use std::collections::HashMap;\n\
                  fn f() {\n\
                  let mut m: HashMap<u32, f64> = HashMap::new();\n\
                  m.entry(3).or_insert(1.0);\n\
                  let _ = m.get(&3);\n\
                  }\n";
        assert!(lint_source("sched/x.rs", ok).is_empty());
    }

    #[test]
    fn hash_iter_scoped_to_decision_modules() {
        let (_, src) = VIOLATION_CORPUS[0];
        // Same source outside sched/sim/cluster/workload: legal.
        assert!(lint_source("experiments/x.rs", src)
            .iter()
            .all(|f| f.rule != Rule::HashIter));
    }

    #[test]
    fn float_sort_spares_partialord_boilerplate() {
        let src = "impl PartialOrd for K {\n\
                   fn partial_cmp(&self, o: &K) -> Option<Ordering> {\n\
                   Some(self.cmp(o))\n\
                   }\n\
                   }\n";
        assert!(lint_source("sched/k.rs", src).is_empty());
        let bad = "let m = xs.iter().max_by(|a, b| \
                   a.partial_cmp(b).unwrap());\n";
        assert_eq!(lint_source("sched/k.rs", bad).len(), 1);
    }

    #[test]
    fn wall_clock_in_decision_modules_and_harness_trees() {
        let (_, src) = VIOLATION_CORPUS[2];
        assert_eq!(lint_source("sim/t.rs", src).len(), 1);
        assert!(lint_source("util/bench.rs", src).is_empty());
        // harness trees get the rule too (corpus entry [5])
        let (path, src) = VIOLATION_CORPUS[5];
        let f = lint_source(path, src);
        assert!(
            f.iter().any(|x| x.rule == Rule::WallClock),
            "bench harness Instant not flagged: {f:?}"
        );
        assert!(lint_source("tests/t.rs", src)
            .iter()
            .any(|x| x.rule == Rule::WallClock));
    }

    #[test]
    fn harness_trees_get_float_sort_but_not_hash_iter() {
        // corpus entry [6]: partial_cmp in tests/ fires
        let (path, src) = VIOLATION_CORPUS[6];
        let f = lint_source(path, src);
        assert!(
            f.iter().any(|x| x.rule == Rule::FloatSort),
            "test harness partial_cmp not flagged: {f:?}"
        );
        // hash iteration in a test harness is the harness's business
        let (_, hash_src) = VIOLATION_CORPUS[0];
        assert!(lint_source("tests/h.rs", hash_src).is_empty());
        assert!(lint_source("benches/h.rs", hash_src).is_empty());
    }

    #[test]
    fn naive_parity_satisfied_by_naive_constructor() {
        let src = "impl S {\n\
                   pub fn naive() -> Self { S }\n\
                   }\n\
                   impl Scheduler for S {}\n";
        assert!(lint_source("sched/s.rs", src).is_empty());
    }

    #[test]
    fn unsafe_with_safety_comment_passes() {
        let src = "fn f(xs: &[u64]) -> u64 {\n\
                   // SAFETY: caller guarantees xs is non-empty.\n\
                   unsafe { *xs.get_unchecked(0) }\n\
                   }\n";
        assert!(lint_source("util/u.rs", src).is_empty());
    }

    #[test]
    fn pragma_suppresses_same_and_next_line() {
        let same = "fn f(xs: &mut Vec<f64>) {\n\
                    xs.sort_by(|a, b| a.partial_cmp(b).unwrap()); \
                    // lint:allow(float-sort) upstream sanitized\n\
                    }\n";
        assert!(lint_source("sim/p.rs", same).is_empty());
        let above = "fn f(xs: &mut Vec<f64>) {\n\
                     // lint:allow(float-sort) upstream sanitized\n\
                     xs.sort_by(|a, b| a.partial_cmp(b).unwrap());\n\
                     }\n";
        assert!(lint_source("sim/p.rs", above).is_empty());
        // A pragma for a different rule does not suppress.
        let wrong = "fn f(xs: &mut Vec<f64>) {\n\
                     // lint:allow(hash-iter)\n\
                     xs.sort_by(|a, b| a.partial_cmp(b).unwrap());\n\
                     }\n";
        assert_eq!(lint_source("sim/p.rs", wrong).len(), 1);
    }

    #[test]
    fn literals_and_comments_do_not_trip_rules() {
        let src = "fn f() {\n\
                   let s = \"partial_cmp unsafe Instant\";\n\
                   let r = r#\"thread_rng SystemTime\"#;\n\
                   // partial_cmp unsafe in prose is fine\n\
                   /* Instant::now() in a block comment */\n\
                   let c = 'u';\n\
                   let _ = (s, r, c);\n\
                   }\n";
        assert!(lint_source("sim/lit.rs", src).is_empty());
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        // A lifetime after `<` must not start a fake literal that
        // swallows the rest of the line.
        let src = "fn f<'a>(xs: &'a [f64]) -> &'a f64 {\n\
                   xs.iter().max_by(|a, b| a.partial_cmp(b).unwrap()).unwrap()\n\
                   }\n";
        assert_eq!(lint_source("sim/lt.rs", src).len(), 1);
    }

    #[test]
    fn multiline_block_comment_tracked() {
        let src = "/*\n\
                   partial_cmp() over lines\n\
                   unsafe too\n\
                   */\n\
                   fn f() {}\n";
        assert!(lint_source("sched/m.rs", src).is_empty());
    }

    #[test]
    fn fault_module_is_lint_covered() {
        // corpus entry [7]: an ambient clock in `sim/faults.rs` — the
        // retry backoff's home — is flagged like any decision module,
        // so the backoff stays a pure function of (seed, task, attempt)
        let (path, src) = VIOLATION_CORPUS[7];
        assert_eq!(path, "sim/faults.rs");
        let f = lint_source(path, src);
        assert!(
            f.iter().any(|x| x.rule == Rule::WallClock),
            "wall clock in the fault module not flagged: {f:?}"
        );
        // and the real module lints clean under the same rules
        let real =
            lint_source("sim/faults.rs", include_str!("../sim/faults.rs"));
        assert!(real.is_empty(), "sim/faults.rs: {real:?}");
    }

    #[test]
    fn churn_generator_is_lint_covered() {
        // corpus entry [8]: an ambient RNG in `workload/gen.rs` — the
        // churn/fault/trace generators' home — is flagged like any
        // decision module, so every churn stream stays a pure
        // function of (config, seed)
        let (path, src) = VIOLATION_CORPUS[8];
        assert_eq!(path, "workload/gen.rs");
        let f = lint_source(path, src);
        assert!(
            f.iter().any(|x| x.rule == Rule::WallClock),
            "ambient RNG in the generator module not flagged: {f:?}"
        );
        // and the real module lints clean under the same rules
        let real = lint_source(
            "workload/gen.rs",
            include_str!("../workload/gen.rs"),
        );
        assert!(real.is_empty(), "workload/gen.rs: {real:?}");
    }

    #[test]
    fn tree_walk_is_deterministic_and_clean_on_self() {
        // The linter's own source must lint clean (it lives outside
        // the decision modules, and its pattern constants are string
        // literals the lexer blanks).
        let f = lint_source("analysis/lint.rs", include_str!("lint.rs"));
        assert!(f.is_empty(), "self-lint: {f:?}");
    }
}
